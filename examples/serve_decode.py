"""Serving example: prefill a batch of prompts, then batched greedy decode
against the KV cache — the serve path the decode_32k / long_500k dry-run
shapes exercise at production scale.

  PYTHONPATH=src python examples/serve_decode.py --arch gemma2-9b --new 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    m = build_model(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new
    cache = m.init_cache(args.batch, max_len, jnp.float32)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    enc_out = None
    if cfg.encoder is not None:
        enc_out = m._encode(
            params,
            jax.random.normal(jax.random.PRNGKey(2),
                              (args.batch, cfg.encoder.enc_seq, cfg.d_model)) * 0.1,
        )

    t0 = time.time()
    logits, cache = jax.jit(m.prefill)(params, prompts, cache) if enc_out is None \
        else m.prefill(params, prompts, cache)
    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], -1)[:, None]
    print(f"prefill {args.batch}×{args.prompt_len}: {time.time()-t0:.2f}s")

    decode = jax.jit(m.decode_step) if enc_out is None else m.decode_step
    out = [tok]
    t0 = time.time()
    for i in range(args.new - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, tok, pos) if enc_out is None else \
            decode(params, cache, tok, pos, enc_out=enc_out)
        tok = jnp.argmax(logits[:, 0, : cfg.vocab_size], -1)[:, None]
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.new} tokens × {args.batch} seqs in {dt:.2f}s "
          f"({args.new*args.batch/dt:.1f} tok/s on CPU)")
    print("sample:", gen[0, :16].tolist())
    assert bool(jnp.isfinite(logits).all())
    print("OK")


if __name__ == "__main__":
    main()
