"""Compare fault-tolerance policies on a simulated 32K-GPU cluster over a
Llama3-calibrated failure month (paper Figs. 4/6/7 in one sweep).

  PYTHONPATH=src python examples/failure_policy_sweep.py
"""
import numpy as np

from repro.core.availability import ClusterSpec, sample_failed_domains
from repro.core.failure_model import FailureTraceConfig, simulate_trace
from repro.core.policies import cluster_throughput, spares_analysis


def main():
    spec = ClusterSpec(n_gpus=32_768, domain_size=32, domains_per_replica=8)
    cfg = FailureTraceConfig(n_gpus=spec.n_gpus, days=30, seed=11)
    t, failed = simulate_trace(cfg)
    rng = np.random.default_rng(0)
    print(f"30-day trace: mean failed {failed.mean():.0f} GPUs "
          f"({failed.mean()/spec.n_gpus:.2%}), peak {failed.max()}")

    samples = [
        sample_failed_domains(spec.n_gpus, spec.domain_size, int(n), rng)
        for n in failed[::24]
    ]
    print(f"\n{'policy':10s} {'mean tput':>10s} {'worst tput':>11s} "
          f"{'GPU-days lost/30d':>18s}")
    for method in ("dpdrop", "ntp", "ntp_pw"):
        tputs = [cluster_throughput(spec, c, method)["throughput"] for c in samples]
        lost = (1 - np.mean(tputs)) * spec.n_gpus * 30
        print(f"{method:10s} {np.mean(tputs):10.4f} {np.min(tputs):11.4f} "
              f"{lost:18.0f}")

    print("\nspares needed for a FIXED minibatch (paper Fig. 7):")
    for method, spares in (("dpdrop", (0, 30, 60, 90, 120)),
                           ("ntp", (0, 8, 16, 24)),
                           ("ntp_pw", (0, 8))):
        res = spares_analysis(spec, samples, spares, method)
        best = next((r for r in res if r["uptime"] >= 0.999), res[-1])
        print(f"  {method:8s}: {best['spares']:4d} spare domains -> "
              f"uptime {best['uptime']:.3f}, "
              f"per-GPU throughput {best['throughput_per_gpu']:.4f}")


if __name__ == "__main__":
    main()
