"""Quickstart: train a small decoder-only model on the synthetic pipeline.

  PYTHONPATH=src python examples/quickstart.py [--steps 60]

Uses the runtime session API — the same entry point the NTP failure demo and
the launcher route through (`NTPSession.from_arch` wraps configs →
make_setup → jit_step): pick any assigned arch with --arch; --reduced-style
smoke scale is applied automatically so it runs in seconds on CPU.
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.optim import AdamWConfig, warmup_cosine
from repro.runtime import NTPSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    session = NTPSession.from_arch(
        cfg, ShapeSpec("quickstart", args.seq, args.batch, "train"), None,
        param_dtype=jnp.float32, opt_cfg=AdamWConfig(lr=2e-3),
        lr_schedule=functools.partial(warmup_cosine, warmup=10, total=5000),
        key=jax.random.PRNGKey(0),
    )
    n_par = sum(p.size for p in jax.tree.leaves(session.params))
    print(f"{cfg.arch_id}: {n_par/1e6:.2f}M params (mode {session.mode.value})")

    pipe = SyntheticLMPipeline(DataConfig(cfg.vocab_size, args.seq, args.batch, noise=0.02))
    t0 = time.time()
    for i in range(args.steps):
        batch = pipe.batch(i)
        if cfg.encoder is not None:
            batch["enc_input"] = jnp.zeros((args.batch, cfg.encoder.enc_seq, cfg.d_model))
        m = session.step(batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"({time.time()-t0:.1f}s)")
    print("done — loss should have dropped well below ln(vocab) =",
          f"{jnp.log(cfg.vocab_size):.2f}")


if __name__ == "__main__":
    main()
