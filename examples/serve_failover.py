"""Serving failover demo (DESIGN.md §2.5/§3.3): a stream of requests decodes
through a ServeSession while its scale-up domain loses GPUs mid-decode and
gets them back — per-request state is resharded in place at every
transition, so every in-flight request's greedy token stream is IDENTICAL
to an uninterrupted run's (asserted below against a second, never-failed
session). ``--arch`` picks what that state is: GQA KV heads (attn), Mamba-2
SSD recurrent state (mamba2: channel-block units), or RecurrentGemma's
rgLRU gate blocks mixed with sliding-window KV (griffin) — all served by
the same unified reshard engine.

  PYTHONPATH=src python examples/serve_failover.py --requests 24
  PYTHONPATH=src python examples/serve_failover.py --requests 100  # CI smoke
  PYTHONPATH=src python examples/serve_failover.py --arch mamba2 --requests 16
"""
import argparse
import time

import numpy as np
import jax

from repro.configs import get_arch, reduced
from repro.configs.base import ArchConfig
from repro.runtime import FailureEvent, RecoveryEvent
from repro.serve import Request, Router, ServeSession

# 4 KV heads over a 4-wide domain: every TP transition physically moves
# heads between ranks (kvh >= n1, like the paper's head-granular sharding)
SMOKE_CFG = ArchConfig(
    arch_id="serve-failover-smoke", family="dense", citation="demo",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, layer_pattern=("attn_sw", "attn"), window=64,
)


def arch_config(name: str) -> ArchConfig:
    """The demo's serveable archs: the attention smoke config, plus the
    real Mamba2/RecurrentGemma configs at `reduced()` smoke scale — the
    recurrent-state families the unified reshard engine opened up."""
    if name == "attn":
        return SMOKE_CFG
    if name == "mamba2":
        return reduced(get_arch("mamba2-780m"))
    if name == "griffin":
        return reduced(get_arch("recurrentgemma-9b"))
    raise ValueError(name)


def run(cfg, events, requests, *, policy, seed, use_kernel=False):
    session = ServeSession.create(
        cfg, replicas=1, n1=4, slots=8, max_len=64, prefill_len=16,
        policy=policy, key=jax.random.PRNGKey(seed), use_kernel=use_kernel,
    )
    router = Router(session)
    pending = {r.rid: r for r in requests}
    arrivals = {r.rid: int(r.arrival) for r in requests}
    tick = 0
    while pending or router.queue or session.engines[0].n_active:
        for rid in [r for r, a in arrivals.items() if a <= tick and r in pending]:
            router.submit(pending.pop(rid))
        for at, ev in events:
            if at == tick:
                router.apply(ev)
                e = session.engines[0]
                kind = "repair " if isinstance(ev, RecoveryEvent) else "failure"
                print(f"  tick {tick:4d}: {kind} -> TP {e.tp}, "
                      f"speed {e.rel_speed:.3f}, boost {e.power_boost:.2f}, "
                      f"capacity {e.capacity}, "
                      f"reshard moved {e.last_reshard.get('bytes_moved', 0)} B "
                      f"in {e.last_reshard.get('messages', 0)} msgs")
        router.step()
        tick += 1
        if tick > 50_000:
            raise RuntimeError("failover demo did not converge")
    return router


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=["attn", "mamba2", "griffin"],
                    default="attn",
                    help="what state reshards mid-decode: KV heads (attn), "
                         "SSD channel blocks (mamba2), rgLRU gate blocks + "
                         "KV heads (griffin)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--policy", choices=["ntp", "ntp_pw"], default="ntp_pw")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route send-bucket packing through the Pallas "
                         "reshard_pack kernel")
    args = ap.parse_args()
    cfg = arch_config(args.arch)

    def make_requests():
        rng = np.random.default_rng(args.seed)  # identical stream per run
        out = []
        for i in range(args.requests):
            r = Request(
                rid=i,
                prompt=rng.integers(
                    1, cfg.vocab_size, size=int(rng.integers(4, 15))
                ).astype(np.int32),
                max_new=args.max_new,
            )
            r.arrival = float(2 * i)  # staggered arrivals: true continuous batching
            out.append(r)
        return out

    # failures land while earlier requests are mid-decode and later ones are
    # still arriving; repairs restore full TP before the stream ends
    n = args.requests
    events = [
        (3, FailureEvent(domain=0)),           # TP 4 -> 3
        (2 * n // 3, FailureEvent(domain=0)),  # TP 3 -> 2 (capacity shrinks)
        (2 * n, RecoveryEvent(domain=0)),      # TP 2 -> 3
        (2 * n + 4, RecoveryEvent(domain=0)),  # TP 3 -> 4
    ]

    print(f"failover run ({cfg.arch_id}, {args.policy}, "
          f"{args.requests} requests):")
    t0 = time.time()
    faulty = run(cfg, events, make_requests(), policy=args.policy,
                 seed=args.seed, use_kernel=args.use_kernel)
    print("reference run (no failures):")
    ref = run(cfg, [], make_requests(), policy=args.policy, seed=args.seed)

    got = {r.rid: list(r.generated) for r in faulty.completed}
    want = {r.rid: list(r.generated) for r in ref.completed}
    assert set(got) == set(want) and len(got) == args.requests
    for rid in want:
        assert got[rid] == want[rid], (
            f"request {rid}: tokens diverged through the reshard:\n"
            f"  faulty {got[rid]}\n  ref    {want[rid]}"
        )

    gf, gr = faulty.goodput(), ref.goodput()
    print(f"\nall {args.requests} token streams identical through "
          f"fail->fail->repair->repair ({time.time()-t0:.1f}s)")
    print(f"goodput: faulty {gf['tokens_per_tick']:.2f} tok/tick "
          f"({gf['preemptions']} preemptions) vs healthy "
          f"{gr['tokens_per_tick']:.2f} tok/tick")
    print("OK")


if __name__ == "__main__":
    main()
