"""End-to-end NTP driver (the paper's scenario, §3/§5): train a model
data-parallel over two scale-up domains, kill a GPU mid-run, and keep
training THE SAME weights with nonuniform tensor parallelism (TP4 + TP3) —
loss continues smoothly while DP-DROP would have lost a replica (and the
fixed minibatch).

Everything routes through the runtime session API: the failure is a
`FailureEvent` consumed by `NTPSession.apply()`, which replans via the
resource manager and repacks params + AdamW state in place — no hand-rolled
unpack/pack round-trip, no checkpoint restart.

Run with 8 simulated devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/train_ntp_failure.py [--steps 300] [--big]

(--big ≈ 100M params; the default ≈ 11M so the demo finishes in ~a minute
on this CPU container. The code path is identical.)
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.policies import table1_settings
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.optim import AdamWConfig, adamw
from repro.runtime import FailureEvent, NTPModelConfig, NTPSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=None, help="default steps//2")
    ap.add_argument("--big", action="store_true", help="~100M params")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-batch", type=int, default=4)
    args = ap.parse_args()
    fail_at = args.fail_at or args.steps // 2

    if len(jax.devices()) < 8:
        raise SystemExit(
            "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    if args.big:
        cfg = NTPModelConfig(d_model=768, n_kv_groups=8, q_per_kv=2,
                             head_dim=48, d_ff=3072, unit_rows=128,
                             n_layers=12, vocab=8192)
    else:
        cfg = NTPModelConfig(d_model=256, n_kv_groups=8, q_per_kv=2,
                             head_dim=32, d_ff=1024, unit_rows=128,
                             n_layers=4, vocab=2048)

    session = NTPSession.create(
        cfg, mesh, local_batch=args.local_batch,
        optimizer=adamw(AdamWConfig(lr=args.lr)),
        key=jax.random.PRNGKey(0),
    )
    n_par = sum(p.size for p in jax.tree.leaves(session.canonical_params()))
    print(f"model: {n_par/1e6:.1f}M params; mesh data=2 model=4 "
          f"(2 DP replicas × TP4 scale-up domains); plan {session.plan}")

    pipe = SyntheticLMPipeline(
        DataConfig(cfg.vocab, args.seq, 2 * args.local_batch, noise=0.0)
    )

    def batches(step):
        return jnp.asarray(pipe._batch_np(step))

    # ---- phase 1: healthy uniform training --------------------------------
    losses = []
    t0 = time.time()
    for i in range(fail_at):
        losses.append(float(session.step(batches(i))["loss"]))
        if i % 20 == 0:
            print(f"[healthy TP4+TP4] step {i:4d} loss {losses[-1]:.4f}")

    # ---- failure: a GPU dies in one replica's scale-up domain --------------
    print(f"\n*** step {fail_at}: GPU failure in replica 1's scale-up domain —"
          " replanning with NTP ***")
    plan = session.apply(FailureEvent(step=fail_at, replica=1))
    lb = plan.local_batch_fraction(args.local_batch)
    print(f"    resource manager: degraded domain packed to the lowest rank;"
          f" new plan {plan};")
    print(f"    degraded replica local batch {args.local_batch} -> "
          f"{lb.min()} (paper §3.1);")
    print("    params + AdamW moments repacked in place; Alg-1 reshard "
          "tables rebuilt.\n")

    for i in range(fail_at, args.steps):
        losses.append(float(session.step(batches(i))["loss"]))
        if i % 20 == 0:
            print(f"[NTP TP4+TP3]     step {i:4d} loss {losses[-1]:.4f}")

    dt = time.time() - t0
    pre = np.mean(losses[fail_at - 10 : fail_at])
    post = np.mean(losses[fail_at : fail_at + 10])
    print(f"\nloss around the failure: {pre:.4f} -> {post:.4f} "
          f"(continuity gap {abs(post-pre):.4f})")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); {dt:.1f}s total")
    print("\nWith power boosting (NTP-PW) the degraded replica would keep "
          f"local batch {args.local_batch};")
    for r in table1_settings():
        print("  ", r)
    assert losses[-1] < losses[0], "training diverged"
    print("\nOK: training survived the failure with nonuniform TP "
          f"(events consumed: {len(session.events)}).")


if __name__ == "__main__":
    main()
