"""End-to-end NTP driver (the paper's scenario, §3/§5): train a model
data-parallel over two scale-up domains, kill a GPU mid-run, restart with
nonuniform tensor parallelism (TP4 + TP3) per the resource manager, and keep
training THE SAME weights — loss continues smoothly while DP-DROP would have
lost a replica (and the fixed minibatch).

Run with 8 simulated devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/train_ntp_failure.py [--steps 300] [--big]

(--big ≈ 100M params; the default ≈ 11M so the demo finishes in ~a minute
on this CPU container. The code path is identical.)
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import nonuniform as nu
from repro.core import ntp_train as nt
from repro.core.policies import table1_settings
from repro.data.pipeline import DataConfig, SyntheticLMPipeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=None, help="default steps//2")
    ap.add_argument("--big", action="store_true", help="~100M params")
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-batch", type=int, default=4)
    args = ap.parse_args()
    fail_at = args.fail_at or args.steps // 2

    if len(jax.devices()) < 8:
        raise SystemExit(
            "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    if args.big:
        cfg = nt.NTPModelConfig(d_model=768, n_kv_groups=8, q_per_kv=2,
                                head_dim=48, d_ff=3072, unit_rows=128,
                                n_layers=12, vocab=8192)
    else:
        cfg = nt.NTPModelConfig(d_model=256, n_kv_groups=8, q_per_kv=2,
                                head_dim=32, d_ff=1024, unit_rows=128,
                                n_layers=4, vocab=2048)

    canon = nt.init_canonical(cfg, jax.random.PRNGKey(0))
    n_par = sum(p.size for p in jax.tree.leaves(canon))
    print(f"model: {n_par/1e6:.1f}M params; mesh data=2 model=4 "
          f"(2 DP replicas × TP4 scale-up domains)")

    healthy = nu.FailurePlan(n1=4, replica_tp=(4, 4))
    degraded = nu.FailurePlan(n1=4, replica_tp=(4, 3))  # GPU (1,3) died

    pipe = SyntheticLMPipeline(
        DataConfig(cfg.vocab, args.seq, 2 * args.local_batch, noise=0.0)
    )

    def batches(step):
        return jnp.asarray(pipe._batch_np(step))

    # ---- phase 1: healthy uniform training --------------------------------
    params = nt.pack_params(cfg, canon, healthy)
    step_fn, _ = nt.make_ntp_train_step(
        cfg, healthy, mesh, mode="uniform", local_batch=args.local_batch,
        lr=args.lr,
    )
    losses = []
    t0 = time.time()
    for i in range(fail_at):
        params, loss = step_fn(params, batches(i))
        losses.append(float(loss))
        if i % 20 == 0:
            print(f"[healthy TP4+TP4] step {i:4d} loss {losses[-1]:.4f}")

    # ---- failure: GPU dies in replica 1's domain ---------------------------
    print(f"\n*** step {fail_at}: GPU failure in replica 1's scale-up domain —"
          " restarting with NTP (TP4 + TP3) ***")
    print("    resource manager: degraded domain packed to replica index 1;")
    print(f"    replica 1 local batch {args.local_batch} -> "
          f"{degraded.local_batch_fraction(args.local_batch)[1]} (paper §3.1);")
    print("    sync layout: contiguous over TP3; Alg-1 reshard tables built.\n")

    # carry the same weights across the restart (checkpoint-equivalent)
    canon_now = nt.unpack_params(cfg, params, healthy, replica=0)
    params = nt.pack_params(cfg, canon_now, degraded)
    step_fn, _ = nt.make_ntp_train_step(
        cfg, degraded, mesh, mode="ntp", local_batch=args.local_batch,
        lr=args.lr,
    )
    for i in range(fail_at, args.steps):
        params, loss = step_fn(params, batches(i))
        losses.append(float(loss))
        if i % 20 == 0:
            print(f"[NTP TP4+TP3]     step {i:4d} loss {losses[-1]:.4f}")

    dt = time.time() - t0
    pre = np.mean(losses[fail_at - 10 : fail_at])
    post = np.mean(losses[fail_at : fail_at + 10])
    print(f"\nloss around the failure: {pre:.4f} -> {post:.4f} "
          f"(continuity gap {abs(post-pre):.4f})")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); {dt:.1f}s total")
    print("\nWith power boosting (NTP-PW) replica 1 would keep local batch 4;")
    for r in table1_settings():
        print("  ", r)
    assert losses[-1] < losses[0], "training diverged"
    print("\nOK: training survived the failure with nonuniform TP.")


if __name__ == "__main__":
    main()
