"""Chaos lifecycle demo: the paper's availability story (§2.3, §6.1) live —
a continuous stream of GPU failures AND repairs replayed against one
training session, with the power policy deciding NTP vs NTP-PW at every
transition.

A Llama3-calibrated failure trace (core/failure_model.py) is sampled for a
tiny 2-replica × TP4 cluster at a hugely inflated failure rate (so a
minutes-long CPU run sees several lifecycle transitions), converted into a
timed FailureEvent/RecoveryEvent schedule, and driven through
`runtime.orchestrator.TraceRunner`: every failure lowers a replica's TP and
every repair raises it back, repacking params + AdamW state in place both
ways — the training loss never restarts.

Run (8 simulated devices are set up automatically):
  PYTHONPATH=src python examples/chaos_lifecycle.py [--steps 120] \\
      [--policy ntp_pw] [--seed 0] [--verify]

--verify co-trains a dense single-copy reference and asserts f32-exact
agreement at every step and transition (with SGD, where the equivalence is
exact at any horizon; tests/dist/session_lifecycle.py enforces the same for
AdamW at short horizon in CI).
"""
import argparse
import os
import time

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.failure_model import FailureTraceConfig
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.optim import AdamWConfig, adamw
from repro.runtime import (
    NTPModelConfig, NTPSession, RecoveryEvent, TraceRunner, power_policy,
    schedule_from_trace,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--policy", choices=["ntp", "ntp_pw"], default="ntp_pw")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate-mult", type=float, default=300.0,
                    help="failure-rate inflation (8 GPUs need a lot of luck)")
    ap.add_argument("--local-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--verify", action="store_true",
                    help="co-train a dense reference and assert f32-exact "
                         "equivalence at every step (switches to SGD: AdamW's "
                         "rsqrt update amplifies f32 rounding noise without "
                         "bound on long runs — its short-horizon equivalence "
                         "is enforced by tests/dist/session_lifecycle.py)")
    args = ap.parse_args()

    if len(jax.devices()) < 8:
        raise SystemExit("needs 8 devices (XLA_FLAGS was preset — do not "
                         "override it with fewer)")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = NTPModelConfig(d_model=128, n_kv_groups=4, q_per_kv=2, head_dim=32,
                         d_ff=512, unit_rows=128, n_layers=2, vocab=512)

    from repro.optim import sgd

    optimizer = sgd(args.lr) if args.verify else adamw(AdamWConfig(lr=args.lr))
    session = NTPSession.create(
        cfg, mesh, local_batch=args.local_batch, optimizer=optimizer,
        key=jax.random.PRNGKey(args.seed),
        power_policy=power_policy(args.policy),
    )
    n_par = sum(p.size for p in jax.tree.leaves(session.canonical_params()))
    print(f"model {n_par/1e6:.1f}M params | mesh data=2 model=4 | "
          f"policy {args.policy} | plan {session.plan}\n")

    # sample the fail/repair schedule: 1 sim-hour per step, recovery in
    # hours-not-days so repairs land inside the run
    trace_cfg = FailureTraceConfig(
        n_gpus=8, domain_size=4, days=args.steps / 24.0,
        rate_multiplier=args.rate_mult, seed=args.seed,
        hw_recovery_days=(0.3, 0.6), sw_recovery_hours=4.0,
    )
    schedule = schedule_from_trace(trace_cfg, steps=args.steps)
    n_fail = sum(1 for s in schedule if not isinstance(s.event, RecoveryEvent))
    print(f"trace: {n_fail} failures / {len(schedule) - n_fail} repairs "
          f"scheduled over {args.steps} steps "
          f"(Llama3 rate × {args.rate_mult:g})\n")

    pipe = SyntheticLMPipeline(
        DataConfig(cfg.vocab, args.seq, 2 * args.local_batch, noise=0.0,
                   seed=args.seed)
    )

    def on_event(ev, plan):
        kind = "REPAIR " if isinstance(ev, RecoveryEvent) else "FAILURE"
        d = session.power_decision
        print(f"  *** step {ev.step}: {kind} domain {ev.domain} -> "
              f"plan {plan.replica_tp}, method {d.method}, "
              f"boost {d.max_boost:.2f}, batches {list(d.local_batches)}, "
              f"predicted rel_iter {d.rel_iter_time:.3f}")

    runner = TraceRunner(session, schedule, verify=args.verify,
                         on_event=on_event)
    t0 = time.time()
    for start in range(0, args.steps, 10):
        hist = runner.run(lambda i: jnp.asarray(pipe._batch_np(i)),
                          min(10, args.steps - start))
        h = hist[-1]
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"tp {h['replica_tp']}  boost {h.get('power_boost', 1.0):.2f}  "
              f"({time.time() - t0:.1f}s)")

    s = runner.summary()
    losses = [h["loss"] for h in runner.history]
    print(f"\nlifecycle summary: {s['failures']} failures, {s['repairs']} "
          f"repairs, goodput {s['goodput']:.3f}, final plan "
          f"{s['final_plan'].replica_tp}")
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} across "
          f"{len(runner.transitions)} plan transitions — no restarts")
    if args.verify:
        print("verified: dense-reference equivalence held at every step")
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


if __name__ == "__main__":
    main()
