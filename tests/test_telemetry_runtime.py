"""Telemetry threaded through the live runtime (ISSUE 8): the orchestrator's
batched metric drain, kernel dispatch counters, the allocator's plan span,
and the end-to-end acceptance runs.

Fast half (host-side, 1 device):

* the `TraceRunner` eager-host-sync fix — device scalars stay in the
  history records and ONE ``jax.device_get`` per ``drain_every`` steps
  converts them (counted via monkeypatch), with values identical to the
  per-step ``float()`` they replaced;
* per-step ``train.goodput`` / ``train.goodput_unboosted`` gauges use the
  same local-batch arithmetic as `TraceRunner.goodput()`;
* `kernels.dispatch` counters from the named `kernels.ops` wrappers
  (and silence with telemetry off);
* the `GreedyAllocator.plan` span — search attrs on success, emission even
  when packing raises `DeadReplicaError`.

Slow half (subprocess):

* `tests/dist/session_telemetry.py` — the ISSUE 8 acceptance trace
  (fail → boost → repair with ``ntp_pw``): report goodput == runner
  accounting to < 0.1 %, transition span bytes == the executed
  `TransferStats` ledger exactly, a loadable Perfetto trace, and
  recorder-on losses == recorder-off losses bit-for-bit;
* ``launch.profile --measure --telemetry`` writes a JSONL stream the
  report CLI folds (the satellite smoke).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core.nonuniform import FailurePlan
from repro.core.ntp_train import Mode
from repro.runtime.orchestrator import TraceRunner
from repro.telemetry import MemorySink, Recorder

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


class FakeSession:
    """The minimal TraceRunner surface: plan/mode/batch accounting plus a
    step() returning DEVICE scalars (the thing _drain must convert)."""

    backend = "ntp"

    def __init__(self, plan, local_batch=4):
        self._plan = plan
        self._lb = local_batch
        self._i = 0

    plan = property(lambda self: self._plan)
    mode = property(lambda self: Mode.NTP)
    local_batch = property(lambda self: self._lb)

    @property
    def local_batches(self):
        from repro.core import ntp_train as nt

        return list(nt.default_local_batches(self._plan, Mode.NTP, self._lb))

    def step(self, batch):
        i, self._i = self._i, self._i + 1
        return {"loss": jnp.asarray(i + 0.5, jnp.float32),
                "grad_norm": jnp.asarray(i * 2.0, jnp.float32)}


def make_runner(drain_every=4):
    sess = FakeSession(FailurePlan(n1=4, replica_tp=(2, 4)))
    return TraceRunner(sess, [], drain_every=drain_every)


def test_run_batches_host_syncs(monkeypatch):
    """Regression for the eager ``float(metrics['loss'])`` stall: 10 steps
    with drain_every=4 must host-sync exactly 3 times (steps 4, 8, end),
    not 10, and the drained floats must equal the device values."""
    calls = []
    real = jax.device_get

    def counting(x):
        calls.append(x)
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    runner = make_runner(drain_every=4)
    hist = runner.run(lambda i: None, 10)
    assert len(calls) == 3
    assert [len(c) for c in calls] == [8, 8, 4]   # 2 scalars x 4/4/2 steps
    assert [h["loss"] for h in hist] == [i + 0.5 for i in range(10)]
    assert [h["grad_norm"] for h in hist] == [i * 2.0 for i in range(10)]
    assert all(isinstance(h["loss"], float) for h in hist)
    # returned history aliases runner.history: both see the drained floats
    assert hist[0] is runner.history[0]
    # re-drain with nothing pending is a no-op (no extra syncs)
    runner._drain()
    assert len(calls) == 3


def test_run_drains_tail_on_exit(monkeypatch):
    """A run shorter than drain_every still ends with plain floats."""
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(x) or real(x))
    runner = make_runner(drain_every=100)
    hist = runner.run(lambda i: None, 3)
    assert len(calls) == 1
    assert [h["loss"] for h in hist] == [0.5, 1.5, 2.5]


def test_run_records_goodput_gauges():
    """The per-step gauges fold to EXACTLY TraceRunner.goodput() — the
    <0.1% acceptance is an equality by construction."""
    rec = Recorder(sinks=[MemorySink()])
    runner = make_runner()
    with telemetry.recording(rec):
        runner.run(lambda i: None, 5)
    vals = rec.values("train.goodput", policy="none")
    # plan (2,4)/4 under NTP: local batches (2,4) of 2x4 full -> 0.75
    assert vals == [0.75] * 5
    assert float(np.mean(vals)) == runner.goodput()
    assert rec.values("train.goodput_unboosted", policy="none") == [0.75] * 5


def test_runner_off_path_records_nothing():
    rec = Recorder(sinks=[MemorySink()])
    runner = make_runner()
    runner.run(lambda i: None, 2)        # telemetry NOT active
    assert len(rec.sinks[0]) == 0


# ---------------------------------------------------------------------------
# kernel dispatch counters

def test_kernel_dispatch_counter_counts_named_wrappers():
    from repro.kernels import ops

    x = jnp.ones((4, 32), jnp.float32)
    w = jnp.ones((32,), jnp.float32)
    rec = Recorder(sinks=[MemorySink()])
    with telemetry.recording(rec):
        ops.rmsnorm(x, w)
        ops.rmsnorm(x, w)
    mode = "interpret" if jax.default_backend() == "cpu" else "compiled"
    assert rec.total("kernels.dispatch", kernel="rmsnorm", mode=mode) == 2
    # labels are a contract for the report's kernel table
    ev = rec.sinks[0].events(kind="counter", name="kernels.dispatch")[0]
    assert set(ev["labels"]) == {"kernel", "mode"}
    # off path: same call leaves no trace
    before = len(rec.sinks[0])
    ops.rmsnorm(x, w)
    assert len(rec.sinks[0]) == before


# ---------------------------------------------------------------------------
# allocator plan span

def _staged_health(counts, n1=4):
    from repro.runtime.events import ClusterHealth, StagedHealth

    return StagedHealth(tuple(
        ClusterHealth(n1, tuple(int(x) for x in c)) for c in counts
    ))


def test_allocator_plan_span_attrs():
    from repro.cluster import GreedyAllocator

    rec = Recorder(sinks=[MemorySink()])
    with telemetry.recording(rec):
        gp = GreedyAllocator().plan(_staged_health([(1, 0)]), spares=1)
    (sp,) = rec.spans("cluster.plan")
    assert sp["labels"] == {"spares": 1}
    a = sp["attrs"]
    assert a["moves_taken"] == len(gp.spare_sites) + len(gp.swaps)
    assert a["moves_considered"] >= a["moves_taken"]
    assert a["predicted_bytes"] == gp.predicted_bytes
    assert a["goodput"] == pytest.approx(gp.goodput, abs=1e-6)
    assert rec.values("cluster.transition_bytes", source="predicted") \
        == [gp.predicted_bytes]


def test_allocator_plan_span_emitted_on_dead_replica():
    """Span lands in the stream even when the search raises — a rejected
    plan is observable, not silent."""
    from repro.cluster import GreedyAllocator
    from repro.runtime.events import DeadReplicaError

    rec = Recorder(sinks=[MemorySink()])
    with telemetry.recording(rec):
        with pytest.raises(DeadReplicaError):
            GreedyAllocator().plan(_staged_health([(4, 0)]))
    (sp,) = rec.spans("cluster.plan")
    assert "moves_taken" not in sp["attrs"]     # died before the verdict


# ---------------------------------------------------------------------------
# end-to-end acceptance (subprocess, 8 fake devices)

@pytest.mark.slow
def test_session_telemetry_lifecycle(run_dist):
    """ISSUE 8 acceptance: fail -> boost -> repair with --telemetry-style
    recording; goodput report == orchestrator accounting, span bytes ==
    the executed TransferStats exactly, Perfetto loads, off-path losses
    bit-identical."""
    out = run_dist("session_telemetry.py")
    assert "SESSION_TELEMETRY_OK" in out


@pytest.mark.slow
def test_profile_measure_telemetry_smoke(tmp_path):
    """`launch.profile --measure --telemetry out.jsonl` (ISSUE 8 satellite):
    the stream exists, holds the timed session.step spans, and the report
    CLI folds it."""
    stream = str(tmp_path / "profile.jsonl")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.profile", "--measure",
         "--steps", "2", "--microbatches", "2", "--telemetry", stream],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert r.returncode == 0, f"profile failed:\n{r.stdout}\n{r.stderr[-3000:]}"
    assert f"telemetry stream written to {stream}" in r.stdout

    from repro.telemetry import load_jsonl

    events = load_jsonl(stream)
    steps = [e for e in events
             if e["kind"] == "span" and e["name"] == "session.step"]
    # emulation + submesh sessions, 2 warmup + 2 timed steps each
    assert len(steps) == 8
    assert {e["labels"]["pp"] for e in steps} == {2}
    assert all(e["dur"] > 0 for e in steps)

    report_json = str(tmp_path / "report.json")
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.telemetry_report", stream,
         "--json", report_json],
        capture_output=True, text=True, timeout=300, env=env,
    )
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "telemetry events:" in r2.stdout
    with open(report_json) as f:
        assert json.load(f)["events"] == len(events)
