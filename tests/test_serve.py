"""repro.serve end-to-end: continuous batching, the mid-decode failure
acceptance criterion (greedy token streams identical to an uninterrupted
dense reference through KV reshards, preemptions, and recoveries), policy
semantics, KV-bearing checkpointing, and the analytic serving-goodput
targets (NTP+boost >= 95% of healthy goodput on the Llama3-calibrated
trace; drop-replica loses ∝ the replica blast radius)."""
import numpy as np
import pytest

pytestmark = pytest.mark.serve

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.runtime import FailureEvent, RecoveryEvent
from repro.serve import Request, Router, ServeSession

N1 = 4


def _cfg(pattern=("attn",), kvh=4, **kw):
    base = dict(
        arch_id=f"serve-test-{'-'.join(pattern)}-kv{kvh}", family="dense",
        citation="test", n_layers=2, d_model=64, n_heads=4, n_kv_heads=kvh,
        head_dim=16, d_ff=128, vocab_size=128, layer_pattern=pattern,
        window=64, chunk_size=64,
    )
    base.update(kw)
    return ArchConfig(**base)


CFG_FULL = _cfg()                       # MHA-granular full attention
CFG_GQA_SW = _cfg(("attn_sw", "attn"), kvh=2)   # GQA + sliding-window mix

# recurrent-state archs (ISSUE 4): SSD heads / rgLRU gate blocks are the
# partition units — served through the generic reshard.ShardedState
from repro.configs.base import RGLRUSpec, SSMSpec  # noqa: E402

CFG_SSM = ArchConfig(
    arch_id="serve-test-ssm", family="ssm", citation="test",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0,
    vocab_size=128, layer_pattern=("ssm",),
    ssm=SSMSpec(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=16),
    use_rope=False, tie_embeddings=True,
)
CFG_GRIFFIN = ArchConfig(
    arch_id="serve-test-griffin", family="hybrid", citation="test",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=128, layer_pattern=("rglru", "rglru", "attn_sw"), window=64,
    rglru=RGLRUSpec(d_conv=4, block_width=16), tie_embeddings=True,
)


def _requests(n, rng, *, max_new=8, lo=4, hi=14, stagger=2):
    out = []
    for i in range(n):
        r = Request(
            rid=i,
            prompt=rng.integers(1, 128, size=int(rng.integers(lo, hi))).astype(
                np.int32),
            max_new=max_new,
        )
        r.arrival = float(stagger * i)
        out.append(r)
    return out


def _run(cfg, events, requests, *, policy="ntp", slots=4, dtype=jnp.float32,
         max_ticks=3000, seed=0):
    session = ServeSession.create(
        cfg, replicas=1, n1=N1, slots=slots, max_len=64, prefill_len=16,
        policy=policy, dtype=dtype, key=jax.random.PRNGKey(seed),
    )
    router = Router(session)
    pending = {r.rid: r for r in requests}
    tick = 0
    while pending or router.queue or any(
        e.n_active for e in session.engines
    ):
        for rid in [r for r, q in list(pending.items()) if q.arrival <= tick]:
            router.submit(pending.pop(rid))
        for at, ev in events:
            if at == tick:
                router.apply(ev)
        router.step()
        tick += 1
        assert tick < max_ticks, "serve run did not converge"
    return session, router


# ---------------------------------------------------------------------------
# continuous batching

def test_continuous_batching_completes_and_reuses_slots():
    rng = np.random.default_rng(0)
    reqs = _requests(10, rng)
    session, router = _run(CFG_FULL, [], reqs, slots=3)
    g = router.goodput()
    assert g["completed"] == 10 and g["rejected"] == 0
    assert all(len(r.generated) == r.max_new for r in router.completed)
    # 10 requests through 3 slots: slots were recycled
    assert session.engines[0].stats["prefills"] == 10
    assert g["tokens_per_tick"] > 0


# ---------------------------------------------------------------------------
# the acceptance criterion: mid-decode failure == uninterrupted reference

@pytest.mark.parametrize("cfg", [CFG_FULL, CFG_GQA_SW, CFG_SSM],
                         ids=lambda c: c.arch_id)
@pytest.mark.parametrize("policy", ["ntp", "ntp_pw"])
def test_mid_decode_failure_token_equivalence(cfg, policy):
    """FailureEvents injected between decode steps (TP 4→3→2, then repairs
    back to 4) must leave every request's greedy token stream identical to
    an uninterrupted run's — the KV reshard is logit-transparent."""
    rng = np.random.default_rng(1)
    events = [
        (2, FailureEvent(domain=0)),
        (7, FailureEvent(domain=0)),
        (16, RecoveryEvent(domain=0)),
        (20, RecoveryEvent(domain=0)),
    ]
    _, faulty = _run(cfg, events, _requests(8, rng), policy=policy)
    rng = np.random.default_rng(1)
    _, ref = _run(cfg, [], _requests(8, rng), policy=policy)

    got = {r.rid: list(r.generated) for r in faulty.completed}
    want = {r.rid: list(r.generated) for r in ref.completed}
    assert set(got) == set(want) and len(got) == 8
    for rid in want:
        assert got[rid] == want[rid], (rid, got[rid], want[rid])


def test_griffin_failover_and_preemption_token_equivalence():
    """Mixed rgLRU + sliding-window attention (RecurrentGemma pattern):
    gate blocks AND KV heads reshard through fail→repair in the same fused
    transition; a TP-1 squeeze forces preemption, and the recurrent
    re-prefill resumes bit-identically (f32 state)."""
    rng = np.random.default_rng(7)
    events = [
        (2, FailureEvent(domain=0)),
        (6, FailureEvent(domain=0, n_gpus=2)),      # TP 1: capacity squeeze
        (14, RecoveryEvent(domain=0, n_gpus=2)),
        (18, RecoveryEvent(domain=0)),
    ]
    _, faulty = _run(CFG_GRIFFIN, events, _requests(6, rng), policy="ntp")
    rng = np.random.default_rng(7)
    _, ref = _run(CFG_GRIFFIN, [], _requests(6, rng), policy="ntp")
    got = {r.rid: list(r.generated) for r in faulty.completed}
    want = {r.rid: list(r.generated) for r in ref.completed}
    assert got == want and len(got) == 6
    assert faulty.goodput()["preemptions"] >= 1


def test_encdec_failover_token_equivalence():
    """Whisper-style encoder-decoder (ISSUE 6 satellite): the cross-attention
    K/V bank (``ek``/``ev`` leaves, ``enc_kv_head`` units) is filled once at
    prefill and resharded through fail→repair like self-attention KV — greedy
    streams must match an uninterrupted run through TP 4→3→2 and back."""
    from repro.configs.base import EncoderSpec

    cfg = _cfg(kvh=2, arch_id="serve-test-encdec", use_rope=False,
               tie_embeddings=True, encoder=EncoderSpec(n_layers=2, enc_seq=16))

    def enc_reqs(n, rng):
        reqs = _requests(n, rng)
        for r in reqs:
            r.enc_input = rng.standard_normal(
                (cfg.encoder.enc_seq, cfg.d_model)).astype(np.float32) * 0.1
        return reqs

    events = [
        (2, FailureEvent(domain=0)),
        (7, FailureEvent(domain=0)),
        (16, RecoveryEvent(domain=0)),
        (20, RecoveryEvent(domain=0)),
    ]
    _, faulty = _run(cfg, events, enc_reqs(6, np.random.default_rng(3)))
    _, ref = _run(cfg, [], enc_reqs(6, np.random.default_rng(3)))
    got = {r.rid: list(r.generated) for r in faulty.completed}
    want = {r.rid: list(r.generated) for r in ref.completed}
    assert set(got) == set(want) and len(got) == 6
    for rid in want:
        assert got[rid] == want[rid], (rid, got[rid], want[rid])


def test_encdec_requires_enc_input():
    """enc-dec admission validates Request.enc_input (present + right shape,
    message naming (enc_seq, d_model))."""
    from repro.configs.base import EncoderSpec

    cfg = _cfg(kvh=2, arch_id="serve-test-encdec2", use_rope=False,
               tie_embeddings=True, encoder=EncoderSpec(n_layers=2, enc_seq=16))
    session = ServeSession.create(
        cfg, replicas=1, n1=N1, slots=2, max_len=64, prefill_len=16,
        policy="ntp", key=jax.random.PRNGKey(0),
    )
    eng = session.engines[0]
    with pytest.raises(ValueError, match=r"enc_input"):
        eng.admit(Request(rid=0, prompt=np.ones(4, np.int32), max_new=2))
    with pytest.raises(ValueError, match=r"\(16, 64\)"):
        eng.admit(Request(rid=1, prompt=np.ones(4, np.int32), max_new=2,
                          enc_input=np.zeros((8, 64), np.float32)))


def test_encdec_recurrent_failover_token_equivalence():
    """Recurrent encoder-decoder (ISSUE 10 satellite): the cross-attention
    K/V bank is filled on the length-1 prefill that seeds the token-by-token
    recurrent admit, read by every teacher-forced and decode step, and
    resharded through fail→repair with the recurrent state — greedy streams
    must match an uninterrupted run through TP 4→3→2 and back."""
    from repro.configs.base import EncoderSpec

    cfg = ArchConfig(
        arch_id="serve-test-encdec-rec", family="hybrid", citation="test",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, layer_pattern=("ssm", "rglru"),
        ssm=SSMSpec(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=16),
        rglru=RGLRUSpec(d_conv=4, block_width=16), use_rope=False,
        tie_embeddings=True, encoder=EncoderSpec(n_layers=2, enc_seq=16),
    )

    def enc_reqs(n, rng):
        reqs = _requests(n, rng)
        for r in reqs:
            r.enc_input = rng.standard_normal(
                (cfg.encoder.enc_seq, cfg.d_model)).astype(np.float32) * 0.1
        return reqs

    events = [
        (2, FailureEvent(domain=0)),
        (7, FailureEvent(domain=0)),
        (16, RecoveryEvent(domain=0)),
        (20, RecoveryEvent(domain=0)),
    ]
    _, faulty = _run(cfg, events, enc_reqs(6, np.random.default_rng(3)))
    _, ref = _run(cfg, [], enc_reqs(6, np.random.default_rng(3)))
    got = {r.rid: list(r.generated) for r in faulty.completed}
    want = {r.rid: list(r.generated) for r in ref.completed}
    assert set(got) == set(want) and len(got) == 6
    for rid in want:
        assert got[rid] == want[rid], (rid, got[rid], want[rid])


def test_degradation_drain_then_retarget():
    """§2.11 serving: straggler/link events reprice a replica IN PLACE —
    same TP, same cache, nothing preempted — and an SDC suspicion drains it
    (in-flight finishes, no new admits) until the clear. A degraded-but-
    complete replica is slowed, never dropped, even under ``drop``."""
    from repro.runtime import (
        LinkDegradeEvent, LinkRepairEvent, SdcClearEvent, SdcSuspectEvent,
        StragglerClearEvent, StragglerEvent,
    )

    session = ServeSession.create(
        CFG_FULL, replicas=2, n1=N1, slots=2, max_len=64, prefill_len=16,
        policy="ntp", key=jax.random.PRNGKey(0))
    e0, e1 = session.engines
    pre = session.apply(StragglerEvent(replica=0, slowdown=2.0))
    assert pre == [] and e0.tp == N1 and not e0.dead
    assert 0.0 < e0.rel_speed < 1.0 and e1.rel_speed == 1.0
    assert session.transitions[-1]["kind"] == "retarget"
    slowed = e0.rel_speed
    pre = session.apply(LinkDegradeEvent(replica=0, bw_frac=0.5))
    assert pre == [] and e0.rel_speed < slowed  # compounding degradation
    session.apply(StragglerClearEvent(replica=0, slowdown=2.0))
    session.apply(LinkRepairEvent(replica=0, bw_frac=0.5))
    assert e0.rel_speed == 1.0  # exact per-kind inverses
    session.apply(SdcSuspectEvent(replica=0))
    assert e0.draining and not e0.can_admit() and e1.can_admit()
    assert not e0.dead and e0.rel_speed > 0.0  # drains, doesn't die
    session.apply(SdcClearEvent(replica=0))
    assert not e0.draining and e0.can_admit()
    assert session.health.healthy

    drop = ServeSession.create(
        CFG_FULL, replicas=1, n1=N1, slots=2, max_len=64, prefill_len=16,
        policy="drop", key=jax.random.PRNGKey(0))
    drop.apply(StragglerEvent(replica=0, slowdown=2.0))
    assert not drop.engines[0].dead
    assert 0.0 < drop.engines[0].rel_speed < 1.0


def test_tokens_match_raw_dense_model():
    """Anchor the engine against the raw model: prefill + decode_step loop
    (no slots, no sharding, no vmap) produces the same greedy stream as the
    engine running through two failures."""
    from repro.models import build_model

    cfg = CFG_FULL
    rng = np.random.default_rng(2)
    reqs = _requests(3, rng, stagger=1)
    events = [(2, FailureEvent(domain=0)), (5, FailureEvent(domain=0))]
    session, router = _run(cfg, events, reqs)

    m = build_model(cfg, remat=False)
    params = session.params
    for q in router.completed:
        cache = m.init_cache(1, 64, jnp.float32)
        logits, cache = m.prefill(params, jnp.asarray(q.prompt[None]), cache)
        tok = int(jnp.argmax(logits[0, len(q.prompt) - 1, : cfg.vocab_size]))
        out, pos = [tok], len(q.prompt)
        for _ in range(q.max_new - 1):
            lg, cache = m.decode_step(
                params, cache, jnp.full((1, 1), tok, jnp.int32), jnp.int32(pos)
            )
            tok = int(jnp.argmax(lg[0, 0, : cfg.vocab_size]))
            pos += 1
            out.append(tok)
        assert out == q.generated, (q.rid, out, q.generated)


# ---------------------------------------------------------------------------
# policy semantics

def test_preemption_resumes_identically():
    """Degrading 4 slots to TP 1 shrinks capacity to 1: preempted requests
    requeue with their generated prefix and still finish with the exact
    reference stream (greedy resume == uninterrupted)."""
    rng = np.random.default_rng(3)
    reqs = _requests(4, rng, stagger=0, max_new=6)
    events = [(3, FailureEvent(domain=0, n_gpus=3)),
              (12, RecoveryEvent(domain=0, n_gpus=3))]
    session, faulty = _run(CFG_FULL, events, reqs)
    assert faulty.goodput()["preemptions"] >= 1
    rng = np.random.default_rng(3)
    _, ref = _run(CFG_FULL, [], _requests(4, rng, stagger=0, max_new=6))
    got = {r.rid: list(r.generated) for r in faulty.completed}
    want = {r.rid: list(r.generated) for r in ref.completed}
    assert got == want


def test_drop_policy_kills_and_revives_replica():
    rng = np.random.default_rng(4)
    reqs = _requests(5, rng, stagger=1, max_new=5)
    events = [(3, FailureEvent(domain=0)),      # 1 GPU gone -> whole replica
              (8, RecoveryEvent(domain=0))]     # fully healthy -> back
    session, router = _run(CFG_FULL, events, reqs, policy="drop")
    e = session.engines[0]
    assert not e.dead and e.tp == N1
    assert router.goodput()["completed"] == 5
    kinds = [t["tp_to"] for t in session.transitions]
    assert 0 in kinds and N1 in kinds           # died, then revived
    # while dead no decoding happened: the death preempted in-flight work
    assert any(t["preempted"] > 0 for t in session.transitions
               if t["tp_to"] == 0) or e.stats["preemptions"] == 0


def test_clamped_failures_leave_repair_debt():
    """5 failures into a 4-wide domain clamp the ledger at 4 (replica dead)
    but leave 1 GPU of repair DEBT: the first paired repair of the clamped
    trace is absorbed, so the replica only revives once repairs outnumber
    the real outstanding failures — matching the analytic replay."""
    session = ServeSession.create(
        CFG_FULL, replicas=1, n1=N1, slots=2, max_len=64, prefill_len=16,
        policy="ntp", key=jax.random.PRNGKey(0),
    )
    for _ in range(5):
        session.apply(FailureEvent(domain=0))
    assert session.replica_tp == (0,) and session.engines[0].dead
    session.apply(RecoveryEvent(domain=0))      # absorbed against the debt
    assert session.replica_tp == (0,) and session.engines[0].dead
    assert session.transitions[-1]["kind"] == "absorbed"
    session.apply(RecoveryEvent(domain=0))      # first REAL repair
    assert session.replica_tp == (1,) and not session.engines[0].dead
    # death/revival transitions report no phantom reshard traffic
    revival = session.transitions[-1]["reshard"]
    assert revival["bytes_moved"] == 0 and revival["tp_from"] == 0


def test_session_events_replica_addressing():
    session = ServeSession.create(
        CFG_FULL, replicas=2, n1=N1, slots=2, max_len=64, prefill_len=16,
        policy="ntp", key=jax.random.PRNGKey(0),
    )
    session.apply(FailureEvent(replica=1))      # alias for domain 1
    assert session.replica_tp == (4, 3)
    assert session.engines[1].tp == 3 and session.engines[0].tp == 4
    session.apply(RecoveryEvent(domain=1))
    assert session.replica_tp == (4, 4)
    assert session.plan is not None and session.plan.healthy


# ---------------------------------------------------------------------------
# KV-bearing checkpointing (bf16 caches through the dtype-recording fix)

def test_session_save_restore_bf16_kv_and_resumes_decoding(tmp_path):
    rng = np.random.default_rng(5)
    session = ServeSession.create(
        CFG_FULL, replicas=1, n1=N1, slots=3, max_len=64, prefill_len=16,
        policy="ntp", dtype=jnp.bfloat16, key=jax.random.PRNGKey(0),
    )
    router = Router(session)
    for r in _requests(3, rng, stagger=0, max_new=16):
        router.submit(r)
    for _ in range(4):
        router.step()
    path = str(tmp_path / "serve.npz")
    session.save(path)

    other = ServeSession.create(
        CFG_FULL, replicas=1, n1=N1, slots=3, max_len=64, prefill_len=16,
        policy="ntp", dtype=jnp.bfloat16, key=jax.random.PRNGKey(9),
    )
    other.apply(FailureEvent(domain=0))         # restore under a DIFFERENT TP
    ro_pre = Router(other)                      # give it its own in-flight work
    ro_pre.submit(Request(rid=77, prompt=np.ones(4, np.int32), max_new=30))
    ro_pre.step()
    assert other.engines[0].n_active == 1
    preempted = other.restore(path)
    # restore preempts the target's own in-flight request (rid 77) AND the
    # checkpointed slot beyond TP-3 capacity (3*3//4 = 2) — the same
    # preempt-and-return invariant apply() enforces; nothing silently drops
    assert len(preempted) == 2 and other.engines[0].n_active == 2
    assert 77 in {r.rid for r in preempted}
    got = other.engines[0].cache
    for a in jax.tree.leaves(got):
        assert a.dtype == jnp.bfloat16

    # the restored session is LIVE: in-flight requests were rebuilt from
    # the checkpoint (the preempted ones requeue) and the never-preempted
    # ones finish with the same streams as the original's. (The preempted
    # one is excluded: bf16 preempt-resume is not bit-identical — resume
    # re-prefills fresh f32 K/V where the original decode read the bf16
    # cache; test_preemption_resumes_identically covers exact f32 resume.)
    ro = Router(other)
    ro.requeue(preempted)
    ro.drain()
    router.drain()
    got_t = {r.rid: list(r.generated) for r in ro.completed}
    want_t = {r.rid: list(r.generated) for r in router.completed}
    assert len(got_t) == 4 and len(want_t) == 3      # 3 checkpointed + rid 77
    clean = {r.rid for r in ro.completed if r.preemptions == 0} - {77}
    assert len(clean) == 2
    for rid in clean:
        assert got_t[rid] == want_t[rid], rid
    assert all(len(got_t[r]) == 16 for r in got_t if r != 77)
    assert len(got_t[77]) == 30


# ---------------------------------------------------------------------------
# SLO admission + accounting

def test_router_slo_admission_sheds_hopeless_requests():
    session = ServeSession.create(
        CFG_FULL, replicas=1, n1=N1, slots=2, max_len=64, prefill_len=16,
        policy="ntp", key=jax.random.PRNGKey(0),
    )
    router = Router(session)
    rng = np.random.default_rng(6)
    ok = 0
    for i in range(12):
        r = Request(rid=i, prompt=rng.integers(1, 128, 8).astype(np.int32),
                    max_new=12, deadline=20.0)
        ok += int(router.submit(r))
    # 12 × 12 tokens against ~2 tok/tick for 20 ticks: most must be shed
    assert router.rejected > 0 and ok < 12
    router.drain()
    g = router.goodput()
    assert g["completed"] == ok
    assert g["slo_attainment"] >= 0.99  # admitted ones were admitted to meet it


def test_prefill_bounds_raise_value_error_naming_config_field():
    """Ring-cache prefill bounds must raise ValueError (an assert would
    vanish under `python -O` and silently corrupt the ring cache), and the
    message must NAME the offending config field so the misconfiguration is
    actionable (ISSUE 5 satellite)."""
    key = jax.random.PRNGKey(0)
    # sliding-window ring: prefill 128 > window 64 -> names `window`
    with pytest.raises(ValueError, match=r"prefill_len=128.*window=64"):
        ServeSession.create(CFG_GQA_SW, replicas=1, n1=N1, slots=2,
                            max_len=256, prefill_len=128, key=key)
    # chunked ring: prefill 128 > chunk_size 64 -> names `chunk_size`
    cfg_chunked = _cfg(("attn_chunked",))
    with pytest.raises(ValueError, match=r"prefill_len=128.*chunk_size=64"):
        ServeSession.create(cfg_chunked, replicas=1, n1=N1, slots=2,
                            max_len=256, prefill_len=128, key=key)
    # prefill past the slot budget -> names both prefill_len and max_len
    with pytest.raises(ValueError, match=r"prefill_len=96.*max_len=64"):
        ServeSession.create(CFG_FULL, replicas=1, n1=N1, slots=2,
                            max_len=64, prefill_len=96, key=key)


def test_oversize_request_rejected():
    session = ServeSession.create(
        CFG_FULL, replicas=1, n1=N1, slots=2, max_len=64, prefill_len=16,
        policy="ntp", key=jax.random.PRNGKey(0),
    )
    router = Router(session)
    r = Request(rid=0, prompt=np.ones(60, np.int32), max_new=10)
    assert not router.submit(r)
    assert router.rejected == 1


# ---------------------------------------------------------------------------
# analytic serving goodput (the fig_serving_goodput acceptance numbers)

def test_serving_goodput_acceptance_targets():
    from repro.core.availability import ClusterSpec
    from repro.core.failure_model import FailureTraceConfig
    from repro.serve import blast_radius_goodput, serving_goodput_trace

    spec = ClusterSpec(n_gpus=32_768, domain_size=32, domains_per_replica=8)
    tc = FailureTraceConfig(n_gpus=spec.n_gpus, domain_size=spec.domain_size,
                            days=15.0, seed=3)
    res = serving_goodput_trace(spec, tc)
    # NTP+power-boost keeps >= 95% of healthy-cluster goodput ...
    assert res["ntp_pw"]["goodput"] >= 0.95
    assert res["ntp_pw"]["slo_attainment"] >= 0.99
    # ... strictly ordered over the policies, with drop far behind
    assert (res["drop"]["goodput"] < res["ntp"]["goodput"]
            < res["ntp_pw"]["goodput"])
    assert res["drop"]["goodput"] < 0.9

    # drop-replica loses ∝ the replica blast radius; NTP+boost localizes it
    br = blast_radius_goodput(spec, tc, radii=(1, 2, 4, 8))
    drop_loss = [1 - br[d]["drop"] for d in (1, 2, 4, 8)]
    assert all(a < b for a, b in zip(drop_loss, drop_loss[1:]))
    assert drop_loss[3] / drop_loss[0] > 4.0
    assert all(br[d]["ntp_pw"] >= 0.95 for d in (1, 2, 4, 8))


def test_replica_serve_speed_quantization():
    from repro.serve import SERVE_GEOM, replica_serve_speed

    assert replica_serve_speed(32, 32, "ntp") == (1.0, 1.0)
    assert replica_serve_speed(0, 32, "ntp")[0] == 0.0
    assert replica_serve_speed(31, 32, "drop")[0] == 0.0
    s_ntp, b_ntp = replica_serve_speed(31, 32, "ntp")
    s_pw, b_pw = replica_serve_speed(31, 32, "ntp_pw")
    assert s_ntp < s_pw <= 1.0 and b_ntp == 1.0 and b_pw > 1.0
