"""AdamW on packed NTP buffers == canonical AdamW, INCLUDING global-norm
gradient clipping (the `norm_weights` 1/D correction, DESIGN.md §2.3):
grad norms match to f32 exactness, params stay within AdamW's early-step
noise amplification. 8 fake CPU devices."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import ntp_train as nt
from repro.optim import AdamWConfig, adamw, adamw_init, adamw_update
from repro.runtime import FailurePlan, NTPModelConfig, NTPSession

LB = 4
cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=2, vocab=128)
mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = FailurePlan(n1=4, replica_tp=(3, 4))
ocfg = AdamWConfig(lr=1e-2, grad_clip=0.5)  # tight clip so it engages

canon = nt.init_canonical(cfg, jax.random.PRNGKey(0))
sess = NTPSession.create(cfg, mesh, plan=plan, local_batch=LB,
                         optimizer=adamw(ocfg), params=canon)

lb = plan.local_batch_fraction(LB)
mask = jnp.asarray(np.concatenate(
    [(np.arange(LB) < lb[d]).astype(np.float32) for d in range(plan.d)]))
ref_grad = jax.jit(jax.value_and_grad(nt.make_reference_loss(cfg)))
ref, ref_opt = canon, adamw_init(canon, ocfg)

rng = np.random.default_rng(0)
for i in range(4):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (plan.d * LB, 33)))
    m = sess.step(tokens)
    rl, g = ref_grad(ref, tokens, mask)
    ref, ref_opt, rm = adamw_update(g, ref_opt, ref, ocfg)
    gdiff = abs(float(m["grad_norm"]) - float(rm["grad_norm"]))
    print(f"step {i}: loss diff {abs(float(m['loss'])-float(rl)):.2e}  "
          f"gnorm diff {gdiff:.2e}")
    assert abs(float(m["loss"]) - float(rl)) < 1e-4, "loss mismatch"
    assert gdiff < 1e-5, "packed grad norm != canonical grad norm"

for r in range(plan.d):
    got = sess.canonical_params(replica=r)
    err = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
              for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)))
    print(f"replica {r}: max param err vs canonical AdamW {err:.2e}")
    assert err < 5e-4, f"replica {r} params diverged"
print("NTP_ADAMW_OK")
