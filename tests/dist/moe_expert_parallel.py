"""§Perf A1: the shard_map all-to-all expert-parallel MoE dispatch is
numerically identical to the O(E·N) dense oracle when capacity drops
nothing. 8 fake CPU devices, experts split over the 4-wide model axis."""
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.launch.mesh import make_test_mesh
from repro.models import ShardCtx
from repro.models.common import NO_SHARD
from repro.models import mlp as mlp_mod

B, S = 8, 16
mesh = make_test_mesh(2, 4)

for arch in ("llama4-scout-17b-a16e", "arctic-480b"):
    cfg = reduced(get_arch(arch))
    # drop-free capacity so the oracle comparison is exact
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
    )
    p = mlp_mod.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)

    ctx = ShardCtx(mesh=mesh, dp=("data",))

    @jax.jit
    def ep(p, x):
        out, aux = mlp_mod.moe_apply_expert_parallel(cfg, p, x, ctx)
        return out, aux["moe_aux_loss"]

    out_ep, aux_ep = ep(p, x)
    out_ref = mlp_mod.moe_apply_dense_ref(cfg, p, x, NO_SHARD)
    _, aux_ref = mlp_mod.moe_apply(cfg, p, x, NO_SHARD)

    err = float(jnp.max(jnp.abs(out_ep - out_ref)))
    aux_err = abs(float(aux_ep) - float(aux_ref["moe_aux_loss"]))
    print(f"{arch}: |ep - dense_ref| max {err:.2e}  aux diff {aux_err:.2e}")
    assert err < 1e-4, f"{arch}: expert-parallel dispatch != dense oracle"
    assert aux_err < 1e-6, f"{arch}: aux loss mismatch"
print("MOE_EP_OK")
