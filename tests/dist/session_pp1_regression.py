"""pp=1 regression (ISSUE 5 acceptance): the stage-aware session must be
BIT-identical to the pre-PR NTPSession path across a random fail/repair
chain. The oracle is the unstaged machinery driven by hand — the exact
pre-PR flow: `make_ntp_train_step(cfg, FailurePlan, ...)` plus manual
`repack_params` of params and AdamW moments at every transition. Any graph
or packing change on the pp=1 path shows up as a bit mismatch in params,
optimizer state, or per-step metrics. 8 fake CPU devices.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import ntp_train as nt
from repro.core.nonuniform import FailurePlan, StagedPlan
from repro.optim import AdamWConfig, adamw
from repro.runtime import FailureEvent, NTPModelConfig, NTPSession, RecoveryEvent

LB, SEQ, STEPS = 4, 24, 12
cfg = NTPModelConfig(d_model=32, n_kv_groups=4, q_per_kv=1, head_dim=8,
                     d_ff=128, unit_rows=32, n_layers=2, vocab=64)
mesh = jax.make_mesh((2, 4), ("data", "model"))
opt = adamw(AdamWConfig(lr=1e-2))

session = NTPSession.create(cfg, mesh, local_batch=LB, optimizer=opt,
                            key=jax.random.PRNGKey(0))
assert session.pp == 1 and isinstance(session.plan, FailurePlan)

# the oracle: pre-PR-style manual driving of the unstaged primitives
canon = nt.init_canonical(cfg, jax.random.PRNGKey(0))
plan = FailurePlan(4, (4, 4))
params = nt.pack_params(cfg, canon, plan)
state = opt.init(params)
step = nt.make_ntp_train_step(cfg, plan, mesh, local_batch=LB, optimizer=opt)

# random fail/repair chain (seeded): domain 0 takes hits and heals
rng = np.random.default_rng(7)
events = {}
failed = 0
for s in sorted(rng.choice(np.arange(1, STEPS), size=5, replace=False)):
    if failed < 3 and rng.random() < 0.6:
        events[int(s)] = FailureEvent(step=int(s), domain=0)
        failed += 1
    elif failed:
        events[int(s)] = RecoveryEvent(step=int(s), domain=0)
        failed -= 1

assert any(isinstance(e, FailureEvent) for e in events.values())

batch_rng = np.random.default_rng(0)
for i in range(STEPS):
    if i in events:
        new_plan = session.apply(events[i])
        params = nt.repack_params(cfg, jax.device_get(params), plan, new_plan)
        st = jax.device_get(state)
        for k in (k for k in opt.param_like if k in st):
            st[k] = nt.repack_params(cfg, st[k], plan, new_plan)
        state = st
        plan = new_plan
        step = nt.make_ntp_train_step(cfg, plan, mesh, local_batch=LB,
                                      optimizer=opt)
    b = jnp.asarray(batch_rng.integers(0, cfg.vocab, (2 * LB, SEQ + 1)))
    m1 = session.step(b)
    params, state, m2 = step(params, state, b)
    assert float(m1["loss"]) == float(m2["loss"]), (i, m1["loss"], m2["loss"])
    assert float(m1["grad_norm"]) == float(m2["grad_norm"]), i
    assert "stage_rel_iter_time" not in m1   # pp=1 metrics shape unchanged

for a, b in zip(jax.tree.leaves(session.params), jax.tree.leaves(params)):
    assert np.array_equal(np.asarray(a), np.asarray(b)), "params diverged"
for k in (k for k in opt.param_like if k in state):
    for a, b in zip(jax.tree.leaves(session.opt_state[k]),
                    jax.tree.leaves(state[k])):
        assert np.array_equal(np.asarray(a), np.asarray(b)), f"opt[{k}] diverged"

# a pp=1 StagedPlan degenerates to the same unstaged session
s2 = NTPSession.create(cfg, mesh, local_batch=LB, optimizer=opt,
                       key=jax.random.PRNGKey(0),
                       plan=StagedPlan((FailurePlan(4, (4, 4)),)))
assert s2.pp == 1 and isinstance(s2.plan, FailurePlan)
b = jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab,
                                                  (2 * LB, SEQ + 1)))
s3 = NTPSession.create(cfg, mesh, local_batch=LB, optimizer=opt,
                       key=jax.random.PRNGKey(0))
assert float(s2.step(b)["loss"]) == float(s3.step(b)["loss"])

print(f"chain: {[(s, type(e).__name__) for s, e in sorted(events.items())]}")
print("SESSION_PP1_REGRESSION_OK")
