"""Mixed health-state taxonomy lifecycle under test (ISSUE 10 acceptance):
a scripted straggler -> link-degrade -> SDC-quarantine -> clear trace replayed
through a live NTPSession must match the dense uniform reference to f32
exactness at EVERY step — including the quarantine ROLLBACK, where the
session discards its updates and repacks the canonical snapshot while the
runner mirrors the same restore point onto the reference. 8 fake CPU devices.

Phase 1: NTP policy with SGD (exact math) — stragglers and degraded links
shed batch via the §2.11 degrade pricing, the SDC suspicion zeroes replica
1's batch and rolls back to step 0, and each clear/repair unwinds exactly.
Phase 2: NTP-PW with AdamW — boosts ride the degradation ledger and the
moments survive the rollback.
Phase 3: quarantine OFF — the same SDC event is recorded but prices as
healthy (no batch change, no rollback).
"""
import numpy as np

import jax

from repro.core.power import PowerModel
from repro.optim import AdamWConfig, adamw, sgd
from repro.runtime import (
    LinkDegradeEvent, LinkRepairEvent, NTPModelConfig, NTPSession,
    PowerPolicy, ScheduledEvent, SdcClearEvent, SdcSuspectEvent,
    StragglerClearEvent, StragglerEvent, TraceRunner,
)

LB, SEQ, STEPS = 4, 32, 15
cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=2, vocab=128)
mesh = jax.make_mesh((2, 4), ("data", "model"))


def mixed_schedule():
    return [
        # replica 0 straggles 2.0x: degrade multiplier 0.85*2 + 0.15 = 1.85
        ScheduledEvent(2, StragglerEvent(step=2, replica=0, slowdown=2.0)),
        # replica 1's scale-up link at half bandwidth: 0.85 + 0.15/0.5 = 1.15
        ScheduledEvent(5, LinkDegradeEvent(step=5, replica=1, bw_frac=0.5)),
        # straggler clears — exact inverse, replica 0 back to full batch
        ScheduledEvent(7, StragglerClearEvent(step=7, replica=0, slowdown=2.0)),
        # SDC suspicion on replica 1: quarantine (batch 0) + rollback
        ScheduledEvent(9, SdcSuspectEvent(step=9, replica=1)),
        # suspicion clears — replica 1 rejoins, still link-degraded
        ScheduledEvent(11, SdcClearEvent(step=11, replica=1)),
        # link repaired — pristine again
        ScheduledEvent(13, LinkRepairEvent(step=13, replica=1, bw_frac=0.5)),
    ]


def run_phase(name, optimizer, policy, expect_batches, atol=1e-4):
    session = NTPSession.create(cfg, mesh, local_batch=LB, optimizer=optimizer,
                                key=jax.random.PRNGKey(0), power_policy=policy)
    rng = np.random.default_rng(0)

    def batch(i):
        import jax.numpy as jnp
        return jnp.asarray(rng.integers(0, cfg.vocab, (2 * LB, SEQ + 1)))

    runner = TraceRunner(session, mixed_schedule(), verify=True, atol=atol)
    hist = runner.run(batch, STEPS)

    seen = {h["step"]: tuple(h["local_batches"]) for h in hist}
    for step, want in expect_batches.items():
        assert seen[step] == want, (name, step, seen[step], want)
    # degradation never touches the TP plan — only failures do
    assert all(h["replica_tp"] == (4, 4) for h in hist)
    assert seen[9][1] == 0 and 9 in {
        h["step"] for h in hist if h.get("quarantined")
    }, "SDC suspicion must quarantine replica 1"
    s = runner.summary()
    assert s["rollbacks"] == 1, s
    assert s["events_by_kind"] == {
        "straggler": 1, "straggler_clear": 1, "link_degrade": 1,
        "link_repair": 1, "sdc_suspect": 1, "sdc_clear": 1,
    }, s["events_by_kind"]
    assert s["failures"] == 0 and s["repairs"] == 0, s
    assert session.plan.healthy and session.health.healthy
    errs = [t["canonical_err"] for t in runner.transitions
            if "canonical_err" in t]
    assert errs, "the rollback must be canonically verified"
    assert all(e < runner.param_atol for e in errs), errs
    print(f"{name}: {len(hist)} steps, kinds "
          f"{[(t['step'], t['kind']) for t in runner.transitions]}, "
          f"rollbacks {s['rollbacks']}, goodput {runner.goodput():.3f}")
    return runner


# phase 1 — NTP policy, SGD: straggler 1.85x -> floor(4/1.85)=2 samples,
# link 1.15x -> 3 samples, quarantine zeroes, clears restore exactly
run_phase(
    "phase1/sgd+ntp", sgd(0.05), PowerPolicy(name="ntp"),
    expect_batches={0: (4, 4), 2: (2, 4), 5: (2, 3), 7: (4, 3),
                    9: (4, 0), 11: (4, 3), 13: (4, 4)},
)

# phase 2 — NTP-PW with a 2.5x-boost rack and AdamW: the boost absorbs the
# 1.15x link slowdown entirely (full batch kept); quarantine still wins
pw = PowerPolicy(name="ntp_pw", model=PowerModel(max_boost=2.5))
runner = run_phase(
    "phase2/adamw+ntp_pw", adamw(AdamWConfig(lr=1e-2)), pw,
    expect_batches={0: (4, 4), 9: (4, 0), 13: (4, 4)},
)
boosted = [h for h in runner.history
           if tuple(h["local_batches"]) not in ((4, 4), (4, 0))
           or (2 <= h["step"] < 13 and h["step"] not in (9, 10))]
assert any(h["power_boost"] > 1.0 for h in runner.history
           if 2 <= h["step"] < 13), "NTP-PW must boost degraded steps"

# phase 3 — quarantine OFF: the SDC suspicion is ledger-only
session = NTPSession.create(cfg, mesh, local_batch=LB, optimizer=sgd(0.05),
                            key=jax.random.PRNGKey(0),
                            power_policy=PowerPolicy(name="ntp"),
                            quarantine=False)
rng = np.random.default_rng(0)
runner3 = TraceRunner(
    session,
    [ScheduledEvent(2, SdcSuspectEvent(step=2, replica=1)),
     ScheduledEvent(5, SdcClearEvent(step=5, replica=1))],
    verify=True, atol=1e-4,
)
import jax.numpy as jnp
hist3 = runner3.run(
    lambda i: jnp.asarray(rng.integers(0, cfg.vocab, (2 * LB, SEQ + 1))),
    8,
)
assert all(tuple(h["local_batches"]) == (4, 4) for h in hist3), hist3
assert runner3.summary()["rollbacks"] == 0
assert not session.quarantine and session.quarantined == ()
print("phase3/quarantine-off: ledger-only SDC, no rollback, full batches")

print("SESSION_MIXED_LIFECYCLE_OK")
