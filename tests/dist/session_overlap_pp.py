"""Overlapped bucketed gradient sync lifecycle (ISSUE 9 acceptance), 8 fake
CPU devices, emulated pp=2 on a (2 data, 4 model) mesh.

Phase A: overlap-on vs overlap-off sessions in LOCKSTEP through a
stage-addressed fail -> fail -> repair -> repair chain: same key, same
batches, same events. Per-step losses and canonical parameters must agree
to f32 tolerance (the overlapped step reorders the same f32 sums, it never
changes the math — DESIGN.md §2.10), and the overlapped step must launch
strictly fewer collectives at every point of the chain.

Phase B: TraceRunner verify=True drives an overlap-on microbatches=2
session against the DENSE UNIFORM REFERENCE through the same kind of
chain — the overlapped step is not just self-consistent, it trains
identically to the paper's oracle through fail -> repair.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.optim import sgd
from repro.runtime import (
    FailureEvent, NTPModelConfig, NTPSession, RecoveryEvent, ScheduledEvent,
    TraceRunner,
)

LB, SEQ, STEPS = 4, 32, 12
cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=4, vocab=128)
mesh = jax.make_mesh((2, 4), ("data", "model"))


def make(overlap, **kw):
    return NTPSession.create(cfg, mesh, local_batch=LB, optimizer=sgd(0.05),
                             key=jax.random.PRNGKey(0), pp=2,
                             overlap=overlap, **kw)


# --- phase A: lockstep off vs on through a stage-addressed chain -----------
EVENTS = {
    2: FailureEvent(step=2, stage=1, domain=0),
    5: FailureEvent(step=5, stage=0, domain=1),
    8: RecoveryEvent(step=8, stage=1, domain=0),
    10: RecoveryEvent(step=10, stage=0, domain=1),
}
s_off, s_on = make(False), make(True)
assert not s_off.overlap and s_on.overlap
rng = np.random.default_rng(0)
max_dloss = max_dp = 0.0
for i in range(STEPS):
    if i in EVENTS:
        s_off.apply(EVENTS[i])
        s_on.apply(EVENTS[i])
    # bucketing must collapse the launch count in EVERY plan state
    assert s_on._step_fn.collectives < s_off._step_fn.collectives, (
        i, s_on._step_fn.collectives, s_off._step_fn.collectives)
    b = jnp.asarray(rng.integers(0, cfg.vocab, (2 * LB, SEQ + 1)))
    m_off, m_on = s_off.step(b), s_on.step(b)
    max_dloss = max(max_dloss,
                    abs(float(m_off["loss"]) - float(m_on["loss"])))
    for a, c in zip(jax.tree.leaves(s_off.canonical_params()),
                    jax.tree.leaves(s_on.canonical_params())):
        max_dp = max(max_dp, float(jnp.max(jnp.abs(a - c))))
assert s_off.plan.healthy and s_on.plan.healthy
assert max_dloss < 1e-5, max_dloss
assert max_dp < 1e-4, max_dp
print(f"phaseA: lockstep off/on over {STEPS} steps + {len(EVENTS)} events, "
      f"max dloss {max_dloss:.2e}, max dparam {max_dp:.2e}")

# --- phase B: overlap-on vs the dense reference (TraceRunner oracle) -------
schedule = [
    ScheduledEvent(2, FailureEvent(step=2, stage=1, domain=0)),
    ScheduledEvent(5, FailureEvent(step=5, stage=0, domain=0)),
    ScheduledEvent(8, RecoveryEvent(step=8, stage=1, domain=0)),
    ScheduledEvent(10, RecoveryEvent(step=10, stage=0, domain=0)),
]
sess = make(True, microbatches=2)
rng_b = np.random.default_rng(1)


def batch(i):
    return jnp.asarray(rng_b.integers(0, cfg.vocab, (2 * LB, SEQ + 1)))


runner = TraceRunner(sess, schedule, verify=True, atol=1e-4)
hist = runner.run(batch, STEPS)
assert sess.plan.healthy
errs = [t["canonical_err"] for t in runner.transitions]
print(f"phaseB: overlap-on mb=2 vs dense reference, {len(hist)} steps, "
      f"max canonical err {max(errs):.2e}")

print("SESSION_OVERLAP_PP_OK")
