"""Production arch stack on a real (2, 4) mesh through the session API:
a few sharded train steps must reduce the loss, and the prefill→decode path
must run under the same shardings. 8 fake CPU devices."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.launch.mesh import make_test_mesh
from repro.optim import AdamWConfig
from repro.runtime import NTPSession
from repro.train.steps import make_setup

BATCH, SEQ, STEPS = 8, 32, 8

cfg = reduced(get_arch("qwen2-7b"))
mesh = make_test_mesh(2, 4)
session = NTPSession.from_arch(
    cfg, ShapeSpec("dist", SEQ, BATCH, "train"), mesh,
    param_dtype=jnp.float32, opt_cfg=AdamWConfig(lr=2e-3),
    key=jax.random.PRNGKey(0),
)
pipe = SyntheticLMPipeline(DataConfig(cfg.vocab_size, SEQ, BATCH, noise=0.0), mesh)

losses = []
for i in range(STEPS):
    m = session.step(pipe.batch(i))
    losses.append(float(m["loss"]))
    print(f"train step {i}: loss {losses[-1]:.4f} gnorm {float(m['grad_norm']):.3f}")
assert np.isfinite(losses).all(), "non-finite loss"
assert losses[-1] < losses[0], f"loss did not drop: {losses[0]} -> {losses[-1]}"

# prefill + decode under the same mesh reuse the trained params
pf = make_setup(cfg, ShapeSpec("dist", SEQ, BATCH, "prefill"), mesh)
logits, cache = pf.jit_step()(
    session.params, {"tokens": pipe.batch(0)["tokens"]}
)
assert np.isfinite(np.asarray(jax.device_get(logits))).all()
print("prefill ok:", logits.shape)

dc = make_setup(cfg, ShapeSpec("dist", SEQ, BATCH, "decode"), mesh)
tok = jnp.asarray(np.argmax(np.asarray(jax.device_get(logits)), -1)[:, None])
batch = {"tokens": tok, "pos": jnp.asarray(SEQ - 1, jnp.int32)}
step_logits, cache = dc.jit_step()(session.params, cache, batch)
assert np.isfinite(np.asarray(jax.device_get(step_logits))).all()
print("decode ok:", step_logits.shape)
print("SHARDED_TRAIN_OK")
