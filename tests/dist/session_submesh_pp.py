"""ISSUE 7 acceptance: the MEASURED submesh pipeline (core/pp_submesh —
per-stage device slices, ppermute hand-off, tick-scheduled 1F1B) computes
the SAME training trajectory as the stage-sequential emulation on the same
`StagedPlan`, to f32 tolerance, healthy AND degraded; and its hand-off byte
table matches an independent computation from the transfer shapes.

16 fake CPU devices: submesh session on (stage=2, data=2, model=4), the
emulation session on a (2, 4) mesh over the first 8.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import pp_submesh
from repro.launch.mesh import make_staged_mesh
from repro.optim import sgd
from repro.runtime import FailureEvent, NTPModelConfig, NTPSession, RecoveryEvent

LB, SEQ, MB = 4, 32, 2
cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=4, vocab=128)

assert len(jax.devices()) >= 16, len(jax.devices())
mesh_emu = jax.make_mesh((2, 4), ("data", "model"))
mesh_sub = make_staged_mesh(2, 2, 4)


def make_sessions():
    kw = dict(local_batch=LB, optimizer=sgd(0.05), key=jax.random.PRNGKey(0),
              pp=2, microbatches=MB)
    return (NTPSession.create(cfg, mesh_emu, **kw),
            NTPSession.create(cfg, mesh_sub, **kw))


emu, sub = make_sessions()
assert getattr(sub._step_fn, "submesh", False), "submesh dispatch missed"
assert not getattr(emu._step_fn, "submesh", False), "emulation dispatch missed"

rng = np.random.default_rng(0)


def batch():
    return jnp.asarray(rng.integers(0, cfg.vocab, (2 * LB, SEQ + 1)))


def lockstep(i, b):
    me = emu.step(b)
    ms = sub.step(b)
    diff = abs(float(me["loss"]) - float(ms["loss"]))
    print(f"step {i}: emu {float(me['loss']):.6f} sub {float(ms['loss']):.6f} "
          f"|diff| {diff:.2e}")
    assert diff < 1e-4, "submesh loss diverged from the emulation"
    return ms


# --- phase 1: healthy pp=2, microbatched -----------------------------------
for i in range(4):
    ms = lockstep(i, batch())

# the submesh step annotates the measured pipeline schedule
ticks = MB + 2 - 1
assert ms["pipeline_ticks"] == ticks, ms["pipeline_ticks"]
hand = ms["handoff"]
assert hand == pp_submesh.handoff_accounting(
    cfg, sub.plan, local_batch=LB, microbatches=MB, seq_len=SEQ)
# ...and the table itself is what the ppermute transfer shapes imply: one
# f32 (mb, S, d_model) activation per sender rank per non-final tick, each
# direction (the backward pipeline is the ppermute transpose)
mb_rows = LB // MB
per_send = 4 * mb_rows * SEQ * cfg.d_model
senders = 1 * 2 * 4                       # (pp-1) boundaries x data x model
assert hand["act_bytes_per_send"] == per_send
assert hand["fwd_bytes"] == per_send * senders * (ticks - 1)
assert hand["bwd_bytes"] == hand["fwd_bytes"]
assert hand["total_bytes"] == 2 * hand["fwd_bytes"]
assert ms["loss"] is not None and "grad_norm" in ms

# --- phase 2: stage-addressed failure, trajectories stay locked -------------
for s in (emu, sub):
    s.apply(FailureEvent(step=4, stage=1, domain=0))
assert emu.plan.stage_tp == sub.plan.stage_tp == ((4, 3), (4, 4))
# the transition itself is the SAME stage-local repack on both sessions
assert emu.last_transition.moved_units == sub.last_transition.moved_units
assert set(emu.last_transition.per_pair) == set(sub.last_transition.per_pair)
for i in range(4, 8):
    ms = lockstep(i, batch())

# --- phase 3: repair, still locked ------------------------------------------
for s in (emu, sub):
    s.apply(RecoveryEvent(step=8, stage=1, domain=0))
assert sub.plan.healthy
for i in range(8, 11):
    ms = lockstep(i, batch())

# --- canonical params agree replica-by-replica ------------------------------
for r in range(2):
    a = emu.canonical_params(replica=r)
    b = sub.canonical_params(replica=r)
    err = max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )
    print(f"replica {r}: max canonical param err emu vs sub {err:.2e}")
    assert err < 1e-4, f"replica {r} params diverged"

print("SESSION_SUBMESH_PP_OK")
