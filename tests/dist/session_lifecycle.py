"""Full NTP lifecycle under test (ISSUE 2 acceptance): a scripted
fail -> boost -> repair trace replayed through a live NTPSession must match
the dense uniform reference to f32 exactness at EVERY step — including the
upward transitions where a repaired GPU restores TP to full and params +
optimizer state are repacked onto the revived ranks. 8 fake CPU devices.

Phase 1: plain-NTP policy-less session with SGD (exact math, ∝-TP batches).
Phase 2: NTP-PW session with AdamW and a high-boost rack — the power policy
keeps the degraded replica at FULL local batch where the boost covers the
slowdown, so the sample masks differ from phase 1 and the AdamW moments ride
through both downward and upward repacks.
"""
import numpy as np

import jax

from repro.core.power import PowerModel
from repro.optim import AdamWConfig, adamw, sgd
from repro.runtime import (
    FailureEvent, NTPModelConfig, NTPSession, PowerPolicy, RecoveryEvent,
    ScheduledEvent, TraceRunner,
)

LB, SEQ, STEPS = 4, 32, 15
cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=2, vocab=128)
mesh = jax.make_mesh((2, 4), ("data", "model"))


def lifecycle_schedule():
    return [
        # pristine (4,4) -> degraded (3,4): GPU dies in replica 0's domain
        ScheduledEvent(3, FailureEvent(step=3, replica=0)),
        # second hit on the same domain -> (2,4): past any boost budget
        ScheduledEvent(6, FailureEvent(step=6, domain=0)),
        # one GPU repaired -> back to (3,4): the upward repack direction
        ScheduledEvent(9, RecoveryEvent(step=9, domain=0)),
        # last GPU repaired -> pristine (4,4): TP restored to full
        ScheduledEvent(12, RecoveryEvent(step=12, replica=0)),
    ]


def run_phase(name, optimizer, policy, expect_batches):
    session = NTPSession.create(cfg, mesh, local_batch=LB, optimizer=optimizer,
                                key=jax.random.PRNGKey(0), power_policy=policy)
    rng = np.random.default_rng(0)

    def batch(i):
        import jax.numpy as jnp
        return jnp.asarray(rng.integers(0, cfg.vocab, (2 * LB, SEQ + 1)))

    runner = TraceRunner(session, lifecycle_schedule(), verify=True, atol=1e-4)
    hist = runner.run(batch, STEPS)

    seen = {h["step"]: tuple(h["local_batches"]) for h in hist}
    for step, want in expect_batches.items():
        assert seen[step] == want, (name, step, seen[step], want)
    tps = {h["step"]: h["replica_tp"] for h in hist}
    assert tps[0] == (4, 4) and tps[3] == (3, 4) and tps[6] == (2, 4)
    assert tps[9] == (3, 4) and tps[12] == (4, 4), tps
    assert session.plan.healthy and session.health.healthy
    assert len(session.events) == 4
    assert len(runner.transitions) == 4
    errs = [t["canonical_err"] for t in runner.transitions]
    assert all(e < 1e-4 for e in errs), errs
    if policy is not None:
        boosted = [h for h in hist if h["replica_tp"] != (4, 4)]
        assert all(h["power_boost"] > 1.0 for h in boosted), boosted
        assert all(h["power_boost"] == 1.0 for h in hist
                   if h["replica_tp"] == (4, 4))
    print(f"{name}: {len(hist)} steps, transitions "
          f"{[ (t['step'], t['kind']) for t in runner.transitions ]}, "
          f"max canonical err {max(errs):.2e}, goodput {runner.goodput():.3f}")
    return runner


# phase 1 — plain NTP (no policy), SGD, ∝-TP local batches
run_phase(
    "phase1/sgd+ntp", sgd(0.05), None,
    expect_batches={0: (4, 4), 3: (3, 4), 6: (2, 4), 9: (3, 4), 12: (4, 4)},
)

# phase 2 — NTP-PW with a 2.5×-boost rack: at (3,4) the boost covers the
# whole slowdown (full batch kept); at (2,4) it is past the cap but still
# sustains 3 of 4 samples (vs plain NTP's 2)
pw = PowerPolicy(name="ntp_pw", model=PowerModel(max_boost=2.5))
runner = run_phase(
    "phase2/adamw+ntp_pw", adamw(AdamWConfig(lr=1e-2)), pw,
    expect_batches={0: (4, 4), 3: (4, 4), 6: (3, 4), 9: (4, 4), 12: (4, 4)},
)
assert runner.goodput() > 0.9, runner.goodput()

print("SESSION_LIFECYCLE_OK")
