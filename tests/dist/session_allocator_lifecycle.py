"""Allocator-driven pp=2 lifecycle with one spare domain (ISSUE 6
acceptance): spares are legal at pp>1 ONLY through the global repack planner
(`repro.cluster`), and its moves must be exactly what travels.

Chain (stage-addressed fail -> repair, both stages hit):
  step  2: fail (stage 1, domain 0)  — the spare stands in: the PLAN stays
           pristine, zero state moves;
  step  5: fail (stage 0, domain 1)  — one spare cannot cover two wounded
           stages; the allocator RELOCATES it to the cheaper site, so a
           stage-0 failure repacks ONLY stage 1 (the cross-stage move
           stage-local packing cannot express);
  step  8: repair (stage 1, domain 0) — the spare covers the remaining
           failure again: plan back to pristine, stage 1 repacks up;
  step 11: repair (stage 0, domain 1) — ledger heals, plan unchanged,
           zero state moves.

Asserted throughout: f32 exactness vs the dense uniform reference
(TraceRunner verify), `session.last_transition` carries ONLY the priced
stage's units (no dense round-trip), and the allocator's predicted bytes
equal the executed TransferStats ledger bit-for-bit.
8 fake CPU devices, mesh (2 data, 4 model).
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.cluster import GreedyAllocator
from repro.optim import sgd
from repro.runtime import (
    FailureEvent, NTPModelConfig, NTPSession, RecoveryEvent, ScheduledEvent,
    StagedPlan, TraceRunner,
)

LB, SEQ, STEPS = 4, 32, 14
cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=4, vocab=128)
mesh = jax.make_mesh((2, 4), ("data", "model"))

schedule = [
    ScheduledEvent(2, FailureEvent(step=2, stage=1, domain=0)),
    ScheduledEvent(5, FailureEvent(step=5, stage=0, domain=1)),
    ScheduledEvent(8, RecoveryEvent(step=8, stage=1, domain=0)),
    ScheduledEvent(11, RecoveryEvent(step=11, stage=0, domain=1)),
]

allocator = GreedyAllocator()
session = NTPSession.create(cfg, mesh, local_batch=LB, optimizer=sgd(0.05),
                            key=jax.random.PRNGKey(0), pp=2, spares=1,
                            allocator=allocator)
assert session.pp == 2 and isinstance(session.plan, StagedPlan)
assert session.plan.healthy
assert allocator.cost is not None and allocator.goodput is not None, (
    "session must calibrate the allocator's models")

rng = np.random.default_rng(0)


def batch(i):
    return jnp.asarray(rng.integers(0, cfg.vocab, (2 * LB, SEQ + 1)))


observed = []


def on_event(ev, plan):
    observed.append((ev, plan, session.last_transition,
                     session.last_global_plan))


runner = TraceRunner(session, list(schedule), verify=True, atol=1e-4,
                     on_event=on_event)
hist = runner.run(batch, STEPS)

tps = {h["step"]: h["stage_tp"] for h in hist}
lbs = {h["step"]: h["local_batches"] for h in hist}

# --- step 2: spare absorbs the stage-1 failure — plan pristine, no traffic
ev, plan, stats, gp = observed[0]
assert tps[2] == ((4, 4), (4, 4)), tps[2]
assert plan.healthy
assert gp.spare_sites == ((1, 0, 1),), gp.spare_sites
assert gp.predicted_bytes == 0 and stats is None, (gp.predicted_bytes, stats)
assert gp.goodput == 1.0 and gp.baseline_goodput < 1.0, gp.summary()

# --- step 5: second failure (stage 0): the spare relocates — only ONE
# stage's units travel, and they are exactly the allocator's priced move
ev, plan, stats, gp = observed[1]
assert tps[5] == ((4, 3), (4, 4)), tps[5]            # stage 1 degraded
assert lbs[5] == (3, LB), lbs[5]                     # eff tp 3 of 4 gates
assert gp.spare_sites == ((0, 1, 1),), gp.spare_sites  # spare moved stages
assert stats is not None and stats.moved_units > 0
moved_stages = {k[0] for k in stats.per_pair}
priced_stages = {a.stage for a in gp.transitions}
assert moved_stages == priced_stages == {1}, (moved_stages, priced_stages)
assert gp.predicted_bytes == stats.bytes_moved, (
    gp.predicted_bytes, stats.bytes_moved)
assert stats.bytes_moved < stats.dense_bytes, "dense round-trip leaked in"
assert sum(a.bytes for a in gp.transitions) == gp.predicted_bytes

# --- step 8: repair lets the spare cover the leftover failure again
ev, plan, stats, gp = observed[2]
assert tps[8] == ((4, 4), (4, 4)), tps[8]
assert plan.healthy
assert gp.spare_sites == ((0, 1, 1),), gp.spare_sites
assert {k[0] for k in stats.per_pair} == {1}, stats.per_pair
assert gp.predicted_bytes == stats.bytes_moved

# --- step 11: full heal — plan already pristine, nothing moves
ev, plan, stats8, gp = observed[3]
assert plan.healthy and gp.predicted_bytes == 0
assert stats8 is stats, "no transition may run on a plan-preserving repair"
assert session.plan.healthy and session.health.healthy

# --- every allocator decision amortized (or rescued) within the horizon
for _, _, _, gp in observed:
    for a in gp.decisions:
        assert a.rescue or a.cost_s <= a.gain_s + 1e-12, a
    for a in gp.transitions:
        assert a.order is not None and a.bytes >= 0

errs = [t["canonical_err"] for t in runner.transitions if "canonical_err" in t]
print(f"{len(hist)} steps, {len(observed)} events, "
      f"{sum(1 for *_, s, _ in observed if s is not None)} transitions, "
      f"max canonical err {max(errs):.2e}, goodput {runner.goodput():.3f}")
print("SESSION_ALLOC_PP_OK")
