"""The paper's core claim, end to end through the runtime session API:
nonuniform (TP4 + TP3) replicas with reshard-synced gradients train
identically to a dense single-logical-copy reference (same batches, same
sample masking, SGD so the comparison is exact)."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import ntp_train as nt
from repro.optim import sgd
from repro.runtime import FailurePlan, NTPModelConfig, NTPSession

LR, LB, STEPS, SEQ = 0.05, 4, 6, 32

cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=2, vocab=128)
mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = FailurePlan(n1=4, replica_tp=(3, 4))

canon = nt.init_canonical(cfg, jax.random.PRNGKey(0))
session = NTPSession.create(cfg, mesh, plan=plan, local_batch=LB,
                            optimizer=sgd(LR), params=canon)

lb = plan.local_batch_fraction(LB)
mask = jnp.asarray(np.concatenate(
    [(np.arange(LB) < lb[d]).astype(np.float32) for d in range(plan.d)]
))
ref_loss = nt.make_reference_loss(cfg)
ref_grad = jax.jit(jax.value_and_grad(ref_loss))
ref = canon

rng = np.random.default_rng(0)
for i in range(STEPS):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (plan.d * LB, SEQ + 1)))
    m = session.step(tokens)
    rl, g = ref_grad(ref, tokens, mask)
    ref = jax.tree.map(lambda p, gg: p - LR * gg, ref, g)
    diff = abs(float(m["loss"]) - float(rl))
    print(f"step {i}: ntp {float(m['loss']):.6f} ref {float(rl):.6f} "
          f"|diff| {diff:.2e}")
    assert diff < 1e-4, "loss diverged from dense reference"

for r in range(plan.d):
    got = session.canonical_params(replica=r)
    err = max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref))
    )
    print(f"replica {r}: max param err vs dense reference {err:.2e}")
    assert err < 1e-4, f"replica {r} params diverged"
print("NTP_EQUIV_OK")
