"""Overlapped (bucketed) sync on the measured submesh pipeline (ISSUE 9),
16 fake CPU devices, pp=2 on a (2 stage, 2 data, 4 model) staged mesh.

On the submesh path overlap only changes HOW the sync is launched — leaves
sharing a (stage, WeightPlan) ride one fused flat buffer through the same
reshard -> psum -> reshard chain (kernels/bucket pack/unpack). Column
concatenation commutes with the row-indexed reshard gathers and the
elementwise psum, so overlap-on must match overlap-off EXACTLY (same
values, not just close) at every step of a stage-addressed fail -> repair
chain, while launching strictly fewer collectives.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_staged_mesh
from repro.optim import sgd
from repro.runtime import (
    FailureEvent, NTPModelConfig, NTPSession, RecoveryEvent,
)

LB, SEQ, MB, STEPS = 4, 32, 2, 10
cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=4, vocab=128)
mesh = make_staged_mesh(2, 2, 4)


def make(overlap):
    return NTPSession.create(cfg, mesh, local_batch=LB, optimizer=sgd(0.05),
                             key=jax.random.PRNGKey(0), pp=2,
                             microbatches=MB, overlap=overlap)


EVENTS = {
    2: FailureEvent(step=2, stage=1, domain=0),
    4: FailureEvent(step=4, stage=0, domain=1),
    6: RecoveryEvent(step=6, stage=1, domain=0),
    8: RecoveryEvent(step=8, stage=0, domain=1),
}
s_off, s_on = make(False), make(True)
assert not s_off.overlap and s_on.overlap
rng = np.random.default_rng(0)
for i in range(STEPS):
    if i in EVENTS:
        s_off.apply(EVENTS[i])
        s_on.apply(EVENTS[i])
    assert s_on._step_fn.collectives < s_off._step_fn.collectives, (
        i, s_on._step_fn.collectives, s_off._step_fn.collectives)
    b = jnp.asarray(rng.integers(0, cfg.vocab, (2 * LB, SEQ + 1)))
    m_off, m_on = s_off.step(b), s_on.step(b)
    assert float(m_off["loss"]) == float(m_on["loss"]), (
        i, float(m_off["loss"]), float(m_on["loss"]))
    for a, c in zip(jax.tree.leaves(s_off.params),
                    jax.tree.leaves(s_on.params)):
        assert np.array_equal(np.asarray(a), np.asarray(c)), i
assert s_off.plan.healthy and s_on.plan.healthy
print(f"lockstep off/on exact over {STEPS} steps + {len(EVENTS)} events, "
      f"collectives {s_off._step_fn.collectives} -> "
      f"{s_on._step_fn.collectives}")
print("SESSION_OVERLAP_SUBMESH_PP_OK")
