"""ISSUE 8 acceptance: the telemetry spine recording a real fail -> boost ->
repair lifecycle on 8 fake CPU devices, then folded offline.

One NTP-PW trace (the session_lifecycle.py schedule) runs with a JSONL +
memory recorder active; the checks are the issue's acceptance bullets:

* the goodput-decomposition report reconstructed FROM THE STREAM matches
  the orchestrator's own `TraceRunner.goodput()` to < 0.1 % (it is equal
  by construction — same per-step sums, same mean);
* every executed ``session.transition`` span carries byte/message counts
  equal to the session's `last_transition` TransferStats ledger EXACTLY,
  and the Perfetto trace rows carry the same numbers;
* the Chrome-trace export is loadable JSON with one swimlane per
  subsystem;
* a second identical run with the recorder OFF produces bit-identical
  losses — the off path cannot perturb numerics.
"""
import json
import os
import tempfile

import numpy as np

import jax

from repro import telemetry
from repro.core.power import PowerModel
from repro.launch.telemetry_report import GOODPUT_KEYS, report
from repro.optim import sgd
from repro.runtime import (
    FailureEvent, NTPModelConfig, NTPSession, PowerPolicy, RecoveryEvent,
    ScheduledEvent, TraceRunner,
)
from repro.telemetry import (
    JsonlSink, MemorySink, Recorder, load_jsonl, write_chrome_trace,
)

LB, SEQ, STEPS = 4, 32, 15
cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=2, vocab=128)
mesh = jax.make_mesh((2, 4), ("data", "model"))


def schedule():
    return [
        ScheduledEvent(3, FailureEvent(step=3, replica=0)),    # (4,4)->(3,4)
        ScheduledEvent(6, FailureEvent(step=6, domain=0)),     # ->(2,4)
        ScheduledEvent(9, RecoveryEvent(step=9, domain=0)),    # ->(3,4)
        ScheduledEvent(12, RecoveryEvent(step=12, replica=0)),  # ->(4,4)
    ]


def run_once(recorder, ledger=None):
    session = NTPSession.create(
        cfg, mesh, local_batch=LB, optimizer=sgd(0.05),
        key=jax.random.PRNGKey(0),
        power_policy=PowerPolicy(name="ntp_pw", model=PowerModel(max_boost=2.5)),
    )
    rng = np.random.default_rng(0)

    def batch(i):
        import jax.numpy as jnp
        return jnp.asarray(rng.integers(0, cfg.vocab, (2 * LB, SEQ + 1)))

    on_event = None
    if ledger is not None:
        def on_event(ev, plan):
            lt = session.last_transition
            ledger.append({"bytes_moved": lt.bytes_moved,
                           "messages": lt.messages})
    runner = TraceRunner(session, schedule(), on_event=on_event, drain_every=4)
    with telemetry.recording(recorder):
        hist = runner.run(batch, STEPS)
    return runner, hist


tmp = tempfile.mkdtemp(prefix="ntp-telemetry-")
stream = os.path.join(tmp, "run.jsonl")
mem = MemorySink()
rec = Recorder(sinks=[JsonlSink(stream), mem])
ledger = []
runner, hist_on = run_once(rec, ledger)
rec.close()

events = load_jsonl(stream)
assert events == list(mem.events()), "JSONL stream != memory ring"

# ---- goodput report == orchestrator accounting (< 0.1 %, equal in fact) ----
doc = report(events)
rows = doc["goodput"]
for pol, row in rows.items():
    assert tuple(sorted(row)) == tuple(sorted(GOODPUT_KEYS)), (pol, row)
total_steps = sum(r["steps"] for r in rows.values())
assert total_steps == STEPS, rows
folded = sum(r["goodput"] * r["steps"] for r in rows.values()) / total_steps
own = runner.goodput()
rel_err = abs(folded - own) / own
assert rel_err < 1e-3, (folded, own)   # acceptance: < 0.1 %
# the boosted policy rows exist: uniform while healthy, ntp_pw degraded
assert set(rows) == {"uniform", "ntp_pw"}, rows

# ---- executed transition spans carry the TransferStats ledger EXACTLY ----
trans = [e for e in events if e["kind"] == "span"
         and e["name"] == "session.transition"]
executed = [e for e in trans if e["attrs"].get("changed") is True]
assert len(executed) == len(ledger) == 4, (len(executed), len(ledger))
for sp, want in zip(executed, ledger):
    assert sp["attrs"]["bytes_moved"] == want["bytes_moved"], (sp, want)
    assert sp["attrs"]["messages"] == want["messages"], (sp, want)
    assert sp["attrs"]["marks"]["planned"] <= sp["attrs"]["marks"]["executed"]
assert [e["labels"]["kind"] for e in executed] == \
    ["failure", "failure", "repair", "repair"]
# the session's executed-bytes gauge mirrors the span series
gauge = [e["value"] for e in events if e["kind"] == "gauge"
         and e["name"] == "cluster.transition_bytes"
         and e["labels"].get("source") == "executed"]
assert gauge == [w["bytes_moved"] for w in ledger], gauge

# orchestrator.event spans wrap each consumed event with its outcome
oev = [e for e in events if e["kind"] == "span"
       and e["name"] == "orchestrator.event"]
assert len(oev) == 4 and all(e["attrs"]["outcome"] == "applied" for e in oev)

# per-step instrumentation: one step span per optimizer step, analytic
# rel_iter_time recorded whenever a policy decision exists
steps = [e for e in events if e["kind"] == "span"
         and e["name"] == "session.step"]
assert len(steps) == STEPS, len(steps)
rel = [e["value"] for e in events if e["kind"] == "gauge"
       and e["name"] == "train.rel_iter_time"
       and e["labels"].get("source") == "analytic"]
assert len(rel) == STEPS and all(r >= 0.0 for r in rel)

# ---- Perfetto export: loadable, same byte counts in the span args ----
trace_path = os.path.join(tmp, "trace.json")
write_chrome_trace(trace_path, events)
with open(trace_path) as f:
    trace = json.load(f)
rows_x = [r for r in trace["traceEvents"]
          if r.get("ph") == "X" and r["name"] == "session.transition"
          and r["args"].get("changed") is True]
assert [r["args"]["bytes_moved"] for r in rows_x] == \
    [w["bytes_moved"] for w in ledger]
lanes = {r["args"]["name"] for r in trace["traceEvents"]
         if r.get("ph") == "M"}   # swimlanes come from SPAN subsystems
assert {"session", "orchestrator"} <= lanes, lanes
tracks = {r["name"] for r in trace["traceEvents"] if r.get("ph") == "C"}
assert any(t.startswith("train.goodput{") for t in tracks), tracks
assert any(t.startswith("cluster.transition_bytes{") for t in tracks), tracks

# ---- recorder-off run is bit-identical ----
_, hist_off = run_once(None)
assert [h["loss"] for h in hist_on] == [h["loss"] for h in hist_off]
assert [h["grad_norm"] for h in hist_on] == [h["grad_norm"] for h in hist_off]
assert telemetry.get() is telemetry.NULL

print(f"goodput: folded {folded:.6f} == runner {own:.6f} "
      f"(rel err {rel_err:.2e}); transitions {len(executed)} "
      f"bytes {[w['bytes_moved'] for w in ledger]}; "
      f"trace rows {len(trace['traceEvents'])}")
print("SESSION_TELEMETRY_OK")
