"""Nonuniform-PP lifecycle under test (ISSUE 5 acceptance): a pp=2 session
with one stage at reduced TP must match the dense uniform reference to f32
exactness through fail -> repair, transitions must be STAGE-LOCAL (only the
hit stage's units travel), and the per-stage rel_iter_time metrics must obey
the slowest-stage rule that `perf_model.staged_iteration_time` encodes.
8 fake CPU devices, mesh (2 data, 4 model).

Phase 1: SGD, no policy — stage-addressed fail/fail/repair/repair chain
         hitting BOTH stages, verified against the dense reference at every
         step and transition.
Phase 2: AdamW + NTP-PW policy — the boost covers the slowdown, metrics
         carry stage_rel_iter_time, and rel_iter_time == max(stage rels).
Phase 3: microbatches=2 (1F1B chunking) still matches the dense reference.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core.perf_model import Hardware, Parallel, Workload, iteration_time, staged_iteration_time
from repro.core.power import PowerModel
from repro.optim import AdamWConfig, adamw, sgd
from repro.runtime import (
    FailureEvent, NTPModelConfig, NTPSession, PowerPolicy, RecoveryEvent,
    ScheduledEvent, StagedPlan, TraceRunner,
)

LB, SEQ, STEPS = 4, 32, 14
cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=4, vocab=128)
mesh = jax.make_mesh((2, 4), ("data", "model"))


def schedule():
    return [
        # stage 1, domain 0 loses a GPU: ONLY stage 1 degrades
        ScheduledEvent(2, FailureEvent(step=2, stage=1, domain=0)),
        # then stage 0 takes a hit too (both stages degraded, same replica)
        ScheduledEvent(5, FailureEvent(step=5, stage=0, domain=0)),
        # repairs, one stage at a time, back to pristine
        ScheduledEvent(8, RecoveryEvent(step=8, stage=1, domain=0)),
        ScheduledEvent(11, RecoveryEvent(step=11, stage=0, domain=0)),
    ]


def run_phase(name, optimizer, policy, *, microbatches=1, atol=1e-4,
              param_atol=None):
    session = NTPSession.create(cfg, mesh, local_batch=LB, optimizer=optimizer,
                                key=jax.random.PRNGKey(0), power_policy=policy,
                                pp=2, microbatches=microbatches)
    assert session.pp == 2 and isinstance(session.plan, StagedPlan)
    assert session.stage_boundaries == (0, 2, 4)
    rng = np.random.default_rng(0)

    def batch(i):
        return jnp.asarray(rng.integers(0, cfg.vocab, (2 * LB, SEQ + 1)))

    transitions = []

    def on_event(ev, plan):
        transitions.append((ev, plan, session.last_transition))

    runner = TraceRunner(session, schedule(), verify=True, atol=atol,
                         param_atol=param_atol, on_event=on_event)
    hist = runner.run(batch, STEPS)

    # --- stage trajectory: failures/repairs touch ONLY their stage
    tps = {h["step"]: h["stage_tp"] for h in hist}
    assert tps[0] == ((4, 4), (4, 4))
    assert tps[2] == ((4, 3), (4, 4)), tps[2]    # stage 1 only
    assert tps[5] == ((3, 3), (4, 4)), tps[5]    # now stage 0 too
    assert tps[8] == ((3, 4), (4, 4)), tps[8]    # stage 1 healed
    assert tps[11] == ((4, 4), (4, 4)), tps[11]  # pristine
    assert session.plan.healthy and session.health.healthy

    # --- transitions were stage-local: ledger tags name exactly one stage
    for ev, _, stats in transitions:
        stages_moved = {k[0] for k in stats.per_pair}
        assert stages_moved == {ev.stage}, (ev, stats.per_pair)
        assert stats.moved_units > 0

    # --- effective (slowest-stage) batch gating
    lbs = {h["step"]: h["local_batches"] for h in hist}
    assert lbs[0] == (LB, LB)
    if policy is None:
        assert lbs[2] == (3, LB), lbs[2]         # min stage tp 3 of 4

    # --- per-stage rel_iter_time metrics: slowest stage gates
    for h in hist:
        srel = h["stage_rel_iter_time"]
        assert len(srel) == 2
        assert h["rel_iter_time"] == max(srel)
        # the analytic perf model applies the same reduction: its staged
        # entry point equals iteration_time at the min stage TP
        min_tp = min(min(s) for s in h["stage_tp"])
        hw, wl = Hardware(domain_size=4), Workload(n_layers=4)
        par = Parallel(tp=4, pp=2, dp=2)
        stage_tps = tuple(min(s[st] for s in h["stage_tp"]) for st in (0, 1))
        assert staged_iteration_time(hw, wl, par, stage_tps) == iteration_time(
            hw, wl, par, tp_reduced=(None if min_tp == 4 else min_tp)
        )

    errs = [t["canonical_err"] for t in runner.transitions]
    print(f"{name}: {len(hist)} steps, {len(transitions)} transitions, "
          f"max canonical err {max(errs):.2e}, goodput {runner.goodput():.3f}")
    return hist


# phase 1 — SGD, no policy: exact math, stage-addressed chain
hist1 = run_phase("phase1/sgd+pp2", sgd(0.05), None)

# phase 2 — AdamW + NTP-PW (2.5x rack): boost covers the (4->3) slowdown so
# the degraded replica keeps the FULL batch; metrics carry the boost.
# NOTE the staged step graph is mathematically equivalent but not bit-equal
# to the dense reference's, and AdamW's rsqrt amplifies the resulting ~1e-7
# f32 gradient noise into ~1e-4 weight deltas PER STEP (the caveat on
# TraceRunner) — hence the looser tolerances; the exact-math phases are the
# SGD ones (1 and 3, tight at 1e-4).
pw = PowerPolicy(name="ntp_pw", model=PowerModel(max_boost=2.5))
hist2 = run_phase("phase2/adamw+ntp_pw+pp2", adamw(AdamWConfig(lr=1e-2)), pw,
                  atol=5e-3, param_atol=2e-2)
degraded = [h for h in hist2 if h["stage_tp"] != ((4, 4), (4, 4))]
assert degraded and all(h["power_boost"] > 1.0 for h in degraded)
assert all(h["local_batches"] == (LB, LB) for h in hist2[:6]), (
    "boost should keep full batch through the single-GPU loss"
)

# phase 3 — 1F1B microbatching: same math to f32 tolerance
hist3 = run_phase("phase3/sgd+pp2+mb2", sgd(0.05), None, microbatches=2)

print("SESSION_PP_LIFECYCLE_OK")
