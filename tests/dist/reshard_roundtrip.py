"""Reshard collective: comp->sync->comp round-trips exactly, and the full
NTP gradient sync equals the cross-replica unit sum. 8 fake CPU devices
(XLA_FLAGS set by the test runner)."""
import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import nonuniform as nu
from repro.core import reshard as rs

K, UNIT = 11, 6
mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = nu.FailurePlan(n1=4, replica_tp=(3, 4))
wp = nu.weight_plan(K, plan)

rng = np.random.default_rng(0)
canon = rng.standard_normal((K, UNIT)).astype(np.float32)
packed = jnp.asarray(nu.pack_global(canon, wp, 1))  # (D, n1*buf, 1, UNIT)
spec = P("data", "model")


def roundtrip(x):
    x = x.reshape(x.shape[1:])          # drop the replica block dim
    y = rs.reshard(x, wp.pre)
    y = rs.reshard(y, wp.post)
    return y.reshape((1,) + y.shape)


out = shard_map(roundtrip, mesh=mesh, in_specs=(spec,), out_specs=spec,
                check_vma=False)(packed)
assert np.allclose(np.asarray(out), np.asarray(packed)), "roundtrip mismatch"
for r in range(plan.d):
    got = nu.unpack_global(np.asarray(out), wp, 1, replica=r)
    assert np.allclose(got, canon), f"replica {r} units corrupted"
print("roundtrip exact on both replicas")


def scaled_sync(x):
    # give each replica a distinct contribution: replica d scales by (d+1)
    d = jax.lax.axis_index("data")
    x = x.reshape(x.shape[1:]) * (d + 1).astype(x.dtype)
    y = rs.ntp_sync_gradient(x, wp)
    return y.reshape((1,) + y.shape)


synced = shard_map(scaled_sync, mesh=mesh, in_specs=(spec,), out_specs=spec,
                   check_vma=False)(packed)
expect = canon * sum(d + 1 for d in range(plan.d))  # 1x + 2x = 3x
for r in range(plan.d):
    got = nu.unpack_global(np.asarray(synced), wp, 1, replica=r)
    assert np.allclose(got, expect, atol=1e-5), f"replica {r} sync wrong"
print("ntp_sync_gradient == cross-replica unit sum on every replica")
print("RESHARD_OK")
