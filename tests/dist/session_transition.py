"""NTPSession integration: healthy -> degraded transition via a
FailureEvent must (a) repack params AND AdamW moments exactly as the manual
unpack/repack path, (b) keep training (finite, improving loss), and
(c) round-trip through a canonical checkpoint. 8 fake CPU devices."""
import os
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import ntp_train as nt
from repro.optim import AdamWConfig, adamw
from repro.runtime import FailureEvent, NTPModelConfig, NTPSession

cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                     d_ff=256, unit_rows=64, n_layers=2, vocab=128)
mesh = jax.make_mesh((2, 4), ("data", "model"))
session = NTPSession.create(cfg, mesh, local_batch=4,
                            optimizer=adamw(AdamWConfig(lr=1e-2)),
                            key=jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
def batch(i):
    return jnp.asarray(rng.integers(0, cfg.vocab, (8, 33)))

losses = [float(session.step(batch(i))["loss"]) for i in range(4)]

params_before = jax.device_get(session.params)
opt_before = jax.device_get(session.opt_state)
old_plan = session.plan

new_plan = session.apply(FailureEvent(step=4, replica=1))
assert new_plan != old_plan and not new_plan.healthy, new_plan
assert session.health.failed == (0, 1), session.health

def trees_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )

# (a) exact equivalence with the manual unpack -> pack path
manual = nt.pack_params(cfg, nt.unpack_params(cfg, params_before, old_plan),
                        new_plan)
assert trees_equal(jax.device_get(session.params), manual), "param repack"
for k in ("m", "v"):
    manual_k = nt.pack_params(
        cfg, nt.unpack_params(cfg, opt_before[k], old_plan), new_plan
    )
    assert trees_equal(jax.device_get(session.opt_state[k]), manual_k), (
        f"opt[{k}] repack"
    )
assert int(jax.device_get(session.opt_state["step"])) == 4
print("repack equivalence (params + AdamW m/v) OK")

# (b) training continues on the same weights
post = [float(session.step(batch(i))["loss"]) for i in range(4, 10)]
assert np.isfinite(post).all(), post
assert np.mean(post) < np.mean(losses[:2]), (losses, post)
print(f"loss continuity across failure: {losses[-1]:.4f} -> {post[0]:.4f}")

# (c) canonical checkpoint round-trips into a degraded-plan session
with tempfile.TemporaryDirectory() as td:
    path = os.path.join(td, "ck.npz")
    session.save(path)
    other = NTPSession.create(cfg, mesh, plan=session.plan, local_batch=4,
                              optimizer=adamw(AdamWConfig(lr=1e-2)))
    step = other.restore(path)
    assert step == 10, step
    assert trees_equal(jax.device_get(other.params),
                       jax.device_get(session.params)), "restore params"
    assert trees_equal(jax.device_get(other.opt_state["m"]),
                       jax.device_get(session.opt_state["m"])), "restore m"
print("canonical save/restore OK")
print("SESSION_TRANSITION_OK")
