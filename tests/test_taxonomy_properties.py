"""Health-state taxonomy properties (ISSUE 10 satellite, DESIGN.md §2.11).

Host-side — the taxonomy algebra is pure python/numpy. Three contracts:

* **per-kind inverse identity** — ``apply(e) ∘ apply(inverse(e))`` restores
  the exact `ClusterHealth`, for every event kind, from pristine AND from
  already-degraded bases (multiset semantics make float severities exact);
* **twin-model equivalence** — random mixed-kind interleavings folded
  through `ClusterHealth.apply` match a naive per-domain dict twin field
  for field (failed counts, straggle/link multisets, effective factors,
  suspicion counters) after EVERY event;
* **rollback bit-exactness** — the session's SDC response (pack the
  canonical snapshot into the CURRENT plan) restores canonical content
  bit-exactly on every replica, including across a plan change between
  snapshot and rollback.

Deterministic seeded sweeps ALWAYS run; hypothesis (dev extra) fuzzes the
same properties when installed. The live-session trajectory — quarantine,
rollback against the dense reference, policy repricing — runs in
tests/dist/session_mixed_lifecycle.py.
"""
import numpy as np
import pytest

import jax

from repro.core import ntp_train as nt
from repro.runtime import (
    ClusterHealth, FailureEvent, LinkDegradeEvent, LinkRepairEvent,
    RecoveryEvent, SdcClearEvent, SdcSuspectEvent, StragglerClearEvent,
    StragglerEvent, event_kind, inverse, plan_from_health,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:               # dev extra absent: the sweep still runs
    HAVE_HYPOTHESIS = False

D, N1 = 2, 4


def _tiny_cfg():
    return nt.NTPModelConfig(d_model=32, n_kv_groups=4, q_per_kv=1,
                             head_dim=16, d_ff=128, unit_rows=32,
                             n_layers=1, vocab=64)


# ---------------------------------------------------------------------------
# per-kind inverse identity

EVERY_KIND = [
    FailureEvent(domain=0),
    StragglerEvent(domain=0, slowdown=1.7),
    LinkDegradeEvent(domain=0, bw_frac=0.55),
    SdcSuspectEvent(domain=0),
]

BASES = [
    (),                                          # pristine
    (FailureEvent(domain=1),),                   # binary damage elsewhere
    (StragglerEvent(domain=0, slowdown=2.5),     # stacked on the SAME site
     LinkDegradeEvent(domain=0, bw_frac=0.3),
     SdcSuspectEvent(domain=1)),
]


@pytest.mark.parametrize("ev", EVERY_KIND, ids=event_kind)
@pytest.mark.parametrize("base", BASES, ids=["pristine", "failed", "degraded"])
def test_apply_inverse_is_identity_per_kind(ev, base):
    h0 = ClusterHealth.pristine(D, N1)
    for b in base:
        h0 = h0.apply(b)
    h1 = h0.apply(ev).apply(inverse(ev))
    assert h1 == h0, (ev, h0, h1)


def test_inverse_round_trips_every_kind():
    for ev in EVERY_KIND:
        assert inverse(inverse(ev)) == ev


# ---------------------------------------------------------------------------
# twin-model equivalence: ClusterHealth.apply vs a naive per-domain dict

def _naive_pristine():
    return [{"failed": 0, "straggle": [], "link": [], "sdc": 0}
            for _ in range(D)]


def _draw_event(rng, naive):
    """One random VALID event: clears only retract severities actually
    outstanding in the twin (the clear carries the exact value its degrade
    pushed — the runtime's contract), repairs only touch failed sites.
    Mutates the twin; returns the runtime event."""
    d = int(rng.integers(0, D))
    nd = naive[d]
    choices = ["fail", "straggler", "link", "sdc"]
    if nd["failed"] > 0:
        choices.append("repair")
    if nd["straggle"]:
        choices.append("straggler_clear")
    if nd["link"]:
        choices.append("link_repair")
    if nd["sdc"] > 0:
        choices.append("sdc_clear")
    c = choices[int(rng.integers(0, len(choices)))]
    if c == "fail":
        if nd["failed"] >= N1:
            return None
        nd["failed"] += 1
        return FailureEvent(domain=d)
    if c == "repair":
        nd["failed"] -= 1
        return RecoveryEvent(domain=d)
    if c == "straggler":
        s = float(np.round(1.0 + rng.uniform(0.05, 3.0), 3))
        nd["straggle"].append(s)
        return StragglerEvent(domain=d, slowdown=s)
    if c == "straggler_clear":
        s = nd["straggle"].pop(int(rng.integers(0, len(nd["straggle"]))))
        return StragglerClearEvent(domain=d, slowdown=s)
    if c == "link":
        b = float(np.round(rng.uniform(0.05, 0.95), 3))
        nd["link"].append(b)
        return LinkDegradeEvent(domain=d, bw_frac=b)
    if c == "link_repair":
        b = nd["link"].pop(int(rng.integers(0, len(nd["link"]))))
        return LinkRepairEvent(domain=d, bw_frac=b)
    if c == "sdc":
        nd["sdc"] += 1
        return SdcSuspectEvent(domain=d)
    nd["sdc"] -= 1
    return SdcClearEvent(domain=d)


def _assert_twins_match(h, naive):
    for d, nd in enumerate(naive):
        dg = None if h.degraded is None else h.degraded[d]
        assert h.failed[d] == nd["failed"], (d, h, nd)
        straggle = () if dg is None else dg.straggle
        link = () if dg is None else dg.link
        sdc = 0 if dg is None else dg.sdc
        assert list(straggle) == sorted(nd["straggle"]), (d, h, nd)
        assert list(link) == sorted(nd["link"]), (d, h, nd)
        assert sdc == nd["sdc"], (d, h, nd)
        # effective factors are worst-of
        slow = dg.slow_factor if dg is not None else 1.0
        bw = dg.bw_frac if dg is not None else 1.0
        assert slow == (max(nd["straggle"]) if nd["straggle"] else 1.0)
        assert bw == (min(nd["link"]) if nd["link"] else 1.0)
    if all(nd["failed"] == 0 and not nd["straggle"] and not nd["link"]
           and nd["sdc"] == 0 for nd in naive):
        # all-clear must normalize back to the BINARY representation
        assert h == ClusterHealth.pristine(D, N1), h
        assert h.degraded is None, h


def test_random_mixed_interleavings_match_naive_twin():
    rng = np.random.default_rng(0)
    for _ in range(25):
        h = ClusterHealth.pristine(D, N1)
        naive = _naive_pristine()
        for _ in range(60):
            ev = _draw_event(rng, naive)
            if ev is None:
                continue
            h = h.apply(ev)
            _assert_twins_match(h, naive)


def test_unwinding_every_degradation_restores_pristine():
    """Apply a random mixed burst, then clear it all in a DIFFERENT order:
    the health must come back bit-identical to pristine (degraded=None, the
    binary fast path re-engages)."""
    rng = np.random.default_rng(7)
    for _ in range(10):
        h = ClusterHealth.pristine(D, N1)
        applied = []
        for _ in range(16):
            d = int(rng.integers(0, D))
            k = int(rng.integers(0, 3))
            if k == 0:
                ev = StragglerEvent(
                    domain=d,
                    slowdown=float(np.round(1.0 + rng.uniform(0.05, 2.0), 3)))
            elif k == 1:
                ev = LinkDegradeEvent(
                    domain=d,
                    bw_frac=float(np.round(rng.uniform(0.05, 0.95), 3)))
            else:
                ev = SdcSuspectEvent(domain=d)
            h = h.apply(ev)
            applied.append(ev)
        order = rng.permutation(len(applied))
        for i in order:
            h = h.apply(inverse(applied[int(i)]))
        assert h == ClusterHealth.pristine(D, N1), h
        assert h.degraded is None


# ---------------------------------------------------------------------------
# SDC rollback: pack the snapshot into the CURRENT plan, bit-exact

def test_sdc_rollback_restores_canonical_bit_exact():
    """`NTPSession.rollback` = `pack_params(snapshot, current_plan)`. Pack
    canonical weights, take the snapshot, corrupt the packed buffers
    (simulated SDC), move through a plan change, roll back: every replica
    must recover canonical content bit-exactly."""
    cfg = _tiny_cfg()
    canon = nt.init_canonical(cfg, jax.random.PRNGKey(0))
    plan0 = plan_from_health(ClusterHealth.pristine(D, N1))
    packed = nt.pack_params(cfg, canon, plan0)
    snapshot = jax.tree.map(lambda x: np.asarray(x).copy(), canon)

    # corruption strikes, then a failure repacks the (corrupt) buffers
    packed = jax.tree.map(lambda x: x + 1.0, packed)
    h1 = ClusterHealth.pristine(D, N1).apply(FailureEvent(domain=0))
    plan1 = plan_from_health(h1)
    packed = nt.repack_params(cfg, packed, plan0, plan1)

    rolled = nt.pack_params(cfg, snapshot, plan1)
    for r in range(plan1.d):
        back = nt.unpack_params(cfg, rolled, plan1, replica=r)
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(canon)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                f"replica {r} not bit-exact after rollback")


# ---------------------------------------------------------------------------
# hypothesis fuzz (dev extra): same properties, arbitrary interleavings

if HAVE_HYPOTHESIS:
    SEVERITY = st.floats(1.05, 4.0, allow_nan=False).map(
        lambda s: float(np.float32(s)))
    BW = st.floats(0.05, 0.95, allow_nan=False).map(
        lambda b: float(np.float32(min(b, 0.95))))
    ANY_EVENT = st.one_of(
        st.builds(lambda d: FailureEvent(domain=d), st.integers(0, D - 1)),
        st.builds(lambda d, s: StragglerEvent(domain=d, slowdown=s),
                  st.integers(0, D - 1), SEVERITY),
        st.builds(lambda d, b: LinkDegradeEvent(domain=d, bw_frac=b),
                  st.integers(0, D - 1), BW),
        st.builds(lambda d: SdcSuspectEvent(domain=d),
                  st.integers(0, D - 1)),
    )

    @settings(max_examples=50, deadline=None)
    @given(st.lists(ANY_EVENT, max_size=12), ANY_EVENT)
    def test_hypothesis_inverse_identity(base, ev):
        h0 = ClusterHealth.pristine(D, N1)
        for b in base:
            if isinstance(b, FailureEvent) and h0.failed[b.domain] >= N1:
                continue
            h0 = h0.apply(b)
        if isinstance(ev, FailureEvent) and h0.failed[ev.domain] >= N1:
            return
        assert h0.apply(ev).apply(inverse(ev)) == h0

    @settings(max_examples=50, deadline=None)
    @given(st.lists(ANY_EVENT, max_size=16))
    def test_hypothesis_unwind_restores_pristine(events):
        h = ClusterHealth.pristine(D, N1)
        applied = []
        for ev in events:
            if isinstance(ev, FailureEvent) and h.failed[ev.domain] >= N1:
                continue
            h = h.apply(ev)
            applied.append(ev)
        for ev in reversed(applied):
            h = h.apply(inverse(ev))
        assert h == ClusterHealth.pristine(D, N1)
        assert h.degraded is None
