"""Runtime API tests: event/health -> plan bridging, Mode dispatch, the
packed-tree repack algebra, and the lifecycle-orchestrator surface (policy
decisions + trace schedules) — all host-side; the live NTPSession lifecycle
runs in multi-device subprocesses (tests/dist/session_transition.py,
tests/dist/session_lifecycle.py)."""
import numpy as np
import pytest

import jax

from repro.core import ntp_train as nt
from repro.core.failure_model import FailureTraceConfig
from repro.core.nonuniform import FailurePlan
from repro.optim import AdamWConfig, adamw, sgd
from repro.runtime import (
    ClusterHealth, DeadReplicaError, FailureEvent, Mode, RecoveryEvent,
    plan_from_health, power_policy, resolve_serving_domain,
    schedule_from_trace,
)


# ---------------------------------------------------------------------------
# serving event addressing (ISSUE 4 satellite: validated ONCE, here)

def test_resolve_serving_domain_aliases_replica_one_to_one():
    ev = resolve_serving_domain(FailureEvent(replica=2, n_gpus=3), 4)
    assert isinstance(ev, FailureEvent)
    assert ev.domain == 2 and ev.replica is None and ev.n_gpus == 3
    rv = resolve_serving_domain(RecoveryEvent(domain=1), 4)
    assert isinstance(rv, RecoveryEvent) and rv.domain == 1


@pytest.mark.parametrize("bad", [-1, 4, 99])
@pytest.mark.parametrize("field", ["domain", "replica"])
def test_resolve_serving_domain_rejects_out_of_range(bad, field):
    ev = FailureEvent(**{field: bad})
    with pytest.raises(ValueError, match=rf"domain {bad}.*valid ids: 0\.\.3"):
        resolve_serving_domain(ev, 4)


# ---------------------------------------------------------------------------
# events / health / plan bridge

def test_pristine_health_gives_uniform_plan():
    h = ClusterHealth.pristine(4, 8)
    plan = plan_from_health(h)
    assert plan == FailurePlan(n1=8, replica_tp=(8, 8, 8, 8))
    assert plan.healthy and h.healthy


def test_plan_from_health_packs_failures_into_lowest_replicas():
    # failures scattered over domains 1 and 3 -> packed to replicas 0, 1
    h = ClusterHealth(domain_size=8, failed=(0, 2, 0, 1))
    plan = plan_from_health(h)
    assert plan.replica_tp == (6, 7, 8, 8)
    assert plan.n_sync == 6


def test_plan_from_health_round_trips_assignments():
    h = ClusterHealth(domain_size=4, failed=(1, 0))
    asg = h.assignments()
    plan = plan_from_health(h)
    assert tuple(a.tp for a in asg) == plan.replica_tp == (3, 4)
    # the degraded physical domain (id 0) sits at the lowest replica
    assert asg[0].domain_ids[0] == 0 and asg[0].failed[0] == 1


def test_plan_from_health_spares_absorb_failures():
    h = ClusterHealth(domain_size=8, failed=(3, 0, 1, 0))
    assert plan_from_health(h).replica_tp == (5, 7, 8, 8)
    assert plan_from_health(h, spares=1).replica_tp == (7, 8, 8, 8)
    assert plan_from_health(h, spares=2).replica_tp == (8, 8, 8, 8)


def test_dead_replica_raises():
    h = ClusterHealth(domain_size=4, failed=(4, 0))
    with pytest.raises(DeadReplicaError):
        plan_from_health(h)


def test_failure_event_validation():
    with pytest.raises(ValueError):
        FailureEvent()                       # neither address
    with pytest.raises(ValueError):
        FailureEvent(domain=0, replica=1)    # both addresses
    with pytest.raises(ValueError):
        FailureEvent(domain=0, n_gpus=0)


def test_health_apply_by_domain_and_replica():
    h = ClusterHealth(domain_size=4, failed=(0, 0))
    h1 = h.apply(FailureEvent(domain=1))
    assert h1.failed == (0, 1)
    # replica-addressed: replica 0 currently serves the degraded domain 1
    # (most-failed packs lowest), so the hit lands there again
    h2 = h1.apply(FailureEvent(replica=0))
    assert h2.failed == (0, 2)
    # saturates at domain_size
    h3 = h2.apply(FailureEvent(domain=1, n_gpus=99))
    assert h3.failed == (0, 4)


def test_health_from_plan_round_trip():
    plan = FailurePlan(n1=4, replica_tp=(3, 4))
    h = ClusterHealth.from_plan(plan)
    assert h.failed == (1, 0)
    assert plan_from_health(h) == plan


def test_recovery_event_validation_matches_failure_event():
    with pytest.raises(ValueError):
        RecoveryEvent()
    with pytest.raises(ValueError):
        RecoveryEvent(domain=0, replica=1)
    with pytest.raises(ValueError):
        RecoveryEvent(domain=0, n_gpus=0)


def test_health_recovery_by_domain_and_replica():
    h = ClusterHealth(domain_size=4, failed=(0, 2))
    # domain-addressed repair
    assert h.apply(RecoveryEvent(domain=1)).failed == (0, 1)
    # replica-addressed: replica 0 serves the degraded domain 1 (packed
    # lowest), so the repair lands there
    assert h.apply(RecoveryEvent(replica=0)).failed == (0, 1)
    # saturates at healthy; surplus repairs are no-ops
    assert h.apply(RecoveryEvent(domain=1, n_gpus=99)).failed == (0, 0)
    assert h.apply(RecoveryEvent(domain=0)).failed == (0, 2)


def test_fail_repair_cycle_restores_plan():
    h = ClusterHealth.pristine(2, 4)
    hurt = h.apply(FailureEvent(replica=1)).apply(FailureEvent(domain=0))
    assert plan_from_health(hurt).replica_tp == (3, 3)
    healed = hurt.apply(RecoveryEvent(domain=0)).apply(RecoveryEvent(domain=1))
    assert healed == h
    assert plan_from_health(healed).healthy


# ---------------------------------------------------------------------------
# power policy (the NTP vs NTP-PW decision hook)

def test_power_policy_table1_settings():
    """The policy's verdict at the paper's TP32 geometry must agree with
    table1_settings: TP30-PW keeps the full local batch within the 1.3× rack
    cap; plain TP30 sheds one sample."""
    plan = FailurePlan(n1=32, replica_tp=(30, 32))
    pw = power_policy("ntp_pw").decide(plan, local_batch=8)
    assert pw.method == "ntp_pw"
    assert pw.local_batches == (8, 8)
    assert 1.0 < pw.max_boost <= 1.3 + 1e-9
    assert pw.rel_iter_time <= 1.005

    ntp = power_policy("ntp").decide(plan, local_batch=8)
    assert ntp.method == "ntp"
    assert ntp.local_batches == (7, 8)
    assert ntp.max_boost == 1.0


def test_power_policy_beyond_cap_sheds_batch_but_never_below_ntp():
    """Past the rack cap the boosted replica sheds samples, but never ends
    up with fewer than the un-boosted ∝-TP share."""
    plan = FailurePlan(n1=4, replica_tp=(2, 4))
    pw = power_policy("ntp_pw").decide(plan, local_batch=4)
    ntp = power_policy("ntp").decide(plan, local_batch=4)
    assert pw.boost[0] == pytest.approx(1.3)
    assert ntp.local_batches[0] <= pw.local_batches[0] < 4


def test_power_policy_healthy_plan_is_uniform():
    d = power_policy("ntp_pw").decide(FailurePlan(n1=4, replica_tp=(4, 4)),
                                      local_batch=4)
    assert d.method == "uniform"
    assert d.boost == (1.0, 1.0) and d.rel_iter_time == 1.0


def test_power_policy_rejects_unknown_name():
    with pytest.raises(ValueError):
        power_policy("dvfs")


# ---------------------------------------------------------------------------
# trace -> schedule bridge

def test_schedule_from_trace_pairs_and_bounds():
    cfg = FailureTraceConfig(n_gpus=8, domain_size=4, days=40.0,
                             rate_multiplier=2000.0, seed=1,
                             hw_recovery_days=(0.2, 0.4),
                             sw_recovery_hours=2.0)
    steps = 400
    sched = schedule_from_trace(cfg, steps=steps)
    assert sched, "expected events at this rate"
    assert all(0 <= s.step < steps for s in sched)
    assert all(s.event.domain in (0, 1) for s in sched)
    assert [s.step for s in sched] == sorted(s.step for s in sched)
    fails = sum(1 for s in sched if not isinstance(s.event, RecoveryEvent))
    repairs = len(sched) - fails
    # every repair matches an in-window failure; tail failures may be unhealed
    assert 0 < repairs <= fails
    # same-step tiebreak: repairs first (a repair can legalize a failure)
    for a, b in zip(sched, sched[1:]):
        if a.step == b.step:
            assert not (isinstance(a.event, FailureEvent)
                        and isinstance(b.event, RecoveryEvent)), (a, b)
    # replaying against the ledger never under/overflows
    h = ClusterHealth.pristine(2, 4)
    for s in sched:
        h = h.apply(s.event)
        assert all(0 <= f <= 4 for f in h.failed)


class _LedgerSession:
    """Duck-typed stand-in for NTPSession: just the health/plan ledger, so
    TraceRunner's event-application semantics test without a mesh."""

    def __init__(self, d, n1):
        self.health = ClusterHealth.pristine(d, n1)
        self.plan = plan_from_health(self.health)

    def apply(self, event):
        new_health = self.health.apply(event)
        new_plan = plan_from_health(new_health)  # may raise DeadReplicaError
        self.health, self.plan = new_health, new_plan
        return new_plan


def test_trace_runner_absorbs_repairs_of_rejected_failures():
    """A failure rejected with DeadReplicaError never touched the ledger, so
    its paired repair must be absorbed — NOT applied — or the replayed TP
    trajectory overstates surviving capacity."""
    from repro.runtime import ScheduledEvent, TraceRunner

    session = _LedgerSession(2, 2)
    runner = TraceRunner(session, [
        ScheduledEvent(0, FailureEvent(step=0, domain=0)),    # tp (1, 2)
        ScheduledEvent(1, FailureEvent(step=1, domain=0)),    # tp 0: rejected
        ScheduledEvent(2, RecoveryEvent(step=2, domain=0)),   # pair of the
        ScheduledEvent(3, RecoveryEvent(step=3, domain=0)),   # rejected: absorb
    ])
    runner._apply_due(0)
    assert session.plan.replica_tp == (1, 2)
    runner._apply_due(1)
    assert session.plan.replica_tp == (1, 2)          # rejected, unmutated
    assert runner.transitions[-1]["kind"] == "rejected"
    runner._apply_due(2)
    # the dead GPU's repair heals the REAL failure; the orphaned repair of
    # the rejected event (step 3) must then be a pure no-op
    assert session.health.failed == (0, 0) or session.health.failed == (1, 0)
    runner._apply_due(3)
    assert session.health.failed == (0, 0)
    assert session.plan.healthy
    kinds = [t["kind"] for t in runner.transitions]
    assert kinds.count("rejected") == 1 and kinds.count("absorbed") == 1, kinds


# ---------------------------------------------------------------------------
# Mode enum dispatch

def test_mode_coerce_accepts_legacy_strings():
    assert Mode.coerce("uniform") is Mode.UNIFORM
    assert Mode.coerce("ntp") is Mode.NTP
    assert Mode.coerce("dpdrop") is Mode.DP_DROP
    assert Mode.coerce("dp_drop") is Mode.DP_DROP
    assert Mode.coerce(Mode.NTP) is Mode.NTP
    with pytest.raises(ValueError):
        Mode.coerce("bogus")


def _tiny_cfg():
    return nt.NTPModelConfig(d_model=32, n_kv_groups=2, q_per_kv=1,
                             head_dim=16, d_ff=64, unit_rows=32,
                             n_layers=1, vocab=64)


def test_mode_dispatch_local_batches():
    """UNIFORM keeps full local batches, NTP reduces ∝ TP, DP_DROP zeroes
    the degraded replica — the observable Mode semantics."""
    plan = FailurePlan(n1=2, replica_tp=(1, 2))
    lb = 4
    assert list(plan.local_batch_fraction(lb)) == [2, 4]           # NTP
    dropped = [lb if t == plan.n1 else 0 for t in plan.replica_tp]
    assert dropped == [0, 4]                                       # DP_DROP


# ---------------------------------------------------------------------------
# repack algebra (host-side; no mesh needed)

def test_repack_params_matches_manual_unpack_pack():
    cfg = _tiny_cfg()
    old = FailurePlan(n1=2, replica_tp=(2, 2))
    new = FailurePlan(n1=2, replica_tp=(1, 2))
    canon = nt.init_canonical(cfg, jax.random.PRNGKey(0))
    packed = nt.pack_params(cfg, canon, old)

    got = nt.repack_params(cfg, packed, old, new)
    want = nt.pack_params(cfg, nt.unpack_params(cfg, packed, old), new)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    # canonical content is preserved across the transition, from any replica
    for r in range(new.d):
        back = nt.unpack_params(cfg, got, new, replica=r)
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(canon)):
            assert np.allclose(np.asarray(a), np.asarray(b))


def test_repack_is_noop_for_same_plan():
    cfg = _tiny_cfg()
    plan = FailurePlan(n1=2, replica_tp=(1, 2))
    packed = nt.pack_params(cfg, nt.init_canonical(cfg, jax.random.PRNGKey(1)),
                            plan)
    assert nt.repack_params(cfg, packed, plan, plan) is packed


def test_optimizer_state_trees_are_repackable():
    """AdamW moments mirror the param structure, so the same repack applies
    (what NTPSession.apply relies on); SGD has no param-like state."""
    cfg = _tiny_cfg()
    old = FailurePlan(n1=2, replica_tp=(2, 2))
    new = FailurePlan(n1=2, replica_tp=(1, 2))
    packed = nt.pack_params(cfg, nt.init_canonical(cfg, jax.random.PRNGKey(2)),
                            old)
    opt = adamw(AdamWConfig(lr=1e-3))
    state = opt.init(packed)
    assert set(opt.param_like) >= {"m", "v"}
    for k in ("m", "v"):
        re = nt.repack_params(cfg, state[k], old, new)
        want = nt.pack_params(cfg, nt.unpack_params(cfg, state[k], old), new)
        for a, b in zip(jax.tree.leaves(re), jax.tree.leaves(want)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    assert sgd(1e-2).param_like == ()


def test_session_rejects_unpacked_plan_order():
    """A plan out of resource-manager packed order would mis-resolve
    replica-addressed events; create() must reject it before any compute."""
    from repro.runtime import NTPSession

    class StubMesh:  # only .shape is read before validation
        shape = {"data": 2, "model": 4}

    with pytest.raises(ValueError, match="packed order"):
        NTPSession.create(_tiny_cfg(), StubMesh(),
                          plan=FailurePlan(n1=4, replica_tp=(4, 3)))


def test_require_ntp_names_caller_and_alternative():
    """ISSUE 7 satellite: the arch backend's guard must name the public entry
    point that hit it (via the call stack), the missing feature, and the
    supported alternative (`--ntp instead of --arch`) — and the ntp backend
    must pass the same guard silently."""
    from conftest import reduced_cfg
    from repro.configs.shapes import ShapeSpec
    from repro.runtime import NTPSession

    arch = NTPSession.from_arch(reduced_cfg("qwen2-7b"),
                                ShapeSpec("t", 16, 2, "train"), None)
    assert arch.backend == "arch"
    with pytest.raises(NotImplementedError,
                       match=r"NTPSession\.canonical_params\(\) needs "
                             r"canonical weight reconstruction"):
        arch.canonical_params()
    with pytest.raises(NotImplementedError,
                       match=r"NTPSession\.apply\(\) needs lifecycle "
                             r"replanning.*--ntp instead of --arch"):
        arch.apply(FailureEvent(replica=0, n_gpus=1))
    with pytest.raises(NotImplementedError, match=r"NTPSession\.save\(\)"):
        arch.save("/nonexistent/never-written")

    class StubMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 2}

    ntp = NTPSession.create(_tiny_cfg(), StubMesh(),
                            plan=FailurePlan(n1=2, replica_tp=(2, 2)))
    assert ntp.backend == "ntp"
    assert ntp.local_batches == [4, 4]          # guard passes, no raise
    canon = ntp.canonical_params()
    assert set(canon) >= {"embed", "head", "layers"}


# ---------------------------------------------------------------------------
# live session transition (8 fake devices, subprocess)

@pytest.mark.slow
def test_session_failure_transition(run_dist):
    """healthy -> degraded via NTPSession.apply: params + AdamW state match
    the manual unpack/repack path exactly, training continues, and the
    canonical checkpoint round-trips."""
    out = run_dist("session_transition.py")
    assert "SESSION_TRANSITION_OK" in out


@pytest.mark.slow
def test_session_adamw_matches_canonical(run_dist):
    """AdamW on packed buffers (incl. global-norm clipping via the 1/D
    norm_weights correction) == canonical AdamW."""
    out = run_dist("ntp_adamw_equivalence.py")
    assert "NTP_ADAMW_OK" in out


@pytest.mark.slow
def test_session_pp1_bit_identical(run_dist):
    """ISSUE 5 acceptance: the stage-aware session at pp=1 is BIT-identical
    to the pre-PR NTPSession across a random fail/repair chain (params,
    AdamW state, per-step metrics)."""
    out = run_dist("session_pp1_regression.py")
    assert "SESSION_PP1_REGRESSION_OK" in out


@pytest.mark.slow
def test_session_pp_lifecycle(run_dist):
    """ISSUE 5 acceptance: pp=2 with one stage at reduced TP matches the
    dense reference through fail->repair; transitions are stage-local; the
    per-stage rel_iter_time metrics follow the slowest-stage rule."""
    out = run_dist("session_pp_lifecycle.py")
    assert "SESSION_PP_LIFECYCLE_OK" in out


@pytest.mark.slow
def test_session_submesh_pp_measured(run_dist):
    """ISSUE 7 acceptance: the measured submesh pipeline (per-stage device
    slices + ppermute hand-off, core/pp_submesh) matches the stage-sequential
    emulation step-for-step through fail->repair, and its hand-off byte
    table equals the independent accounting."""
    out = run_dist("session_submesh_pp.py", devices=16)
    assert "SESSION_SUBMESH_PP_OK" in out


@pytest.mark.slow
def test_allocator_pp_spares_lifecycle(run_dist):
    """ISSUE 6 acceptance: pp=2 with ONE spare domain, stage-addressed
    fail->repair chain — the allocator-driven session matches the dense
    reference to f32 exactness, the spare absorbs / relocates across stages,
    and `session.last_transition` carries only the allocator's priced moves
    (predicted bytes == executed ledger, no dense round-trip)."""
    out = run_dist("session_allocator_lifecycle.py")
    assert "SESSION_ALLOC_PP_OK" in out
