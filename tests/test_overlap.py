"""`core.overlap` host-side (ISSUE 9): bucket geometry, the Pallas
pack/unpack kernels, the bucketed-vs-sequential sync property against the
numpy reshard twin, and the overlap-aware perf-model entry points.

The live multi-device overlapped step (AD inside shard_map, chunked
backward, in-flight buckets) is covered by tests/dist/session_overlap_pp.py
and tests/dist/session_overlap_submesh_pp.py; everything here runs on one
host device.

The central property: WeightPlan reshard tables index unit ROWS only, so
column-concatenating leaves that share a (stage, plan) commutes with the
gather/scatter and the elementwise psum — the bucketed sync must equal the
sequential per-leaf sync EXACTLY, healthy or degraded, across arbitrary
fail/repair chains. A deterministic sweep always runs; the hypothesis
version widens the search when the dev dependency is installed.
"""
import numpy as np
import pytest

from repro.core import nonuniform as nu
from repro.core import perf_model as pm
from repro.core.nonuniform import FailurePlan
from repro.core.overlap import (
    Bucket, bucket_layout, chunk_ranges, coerce_overlap, sync_collectives,
)
from repro.kernels import ops
from repro.kernels.bucket import bucket_pack_ref, bucket_unpack_ref
from repro.runtime import NTPModelConfig

from test_reshard_properties import _rank_buffers, emulate_reshard

CFG4 = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                      d_ff=256, unit_rows=64, n_layers=4, vocab=128)


# --------------------------------------------------------------- geometry


def test_coerce_overlap():
    assert coerce_overlap(True) and not coerce_overlap(False)
    assert coerce_overlap("on") and coerce_overlap("true")
    assert not coerce_overlap("off") and not coerce_overlap("0")
    with pytest.raises(ValueError):
        coerce_overlap("sometimes")


@pytest.mark.parametrize("n_layers,pp,want", [
    (4, 1, ((0, 1), (1, 2), (2, 3), (3, 4))),   # pp=1: DEFAULT_CHUNKS ladder
    (4, 2, ((0, 2), (2, 4))),                   # pp>1: the stage boundaries
    (2, 1, ((0, 1), (1, 2))),                   # fewer layers than chunks
    (6, 2, ((0, 3), (3, 6))),
])
def test_chunk_ranges(n_layers, pp, want):
    got = chunk_ranges(n_layers, pp)
    assert got == want
    # always a contiguous, non-empty cover of [0, n_layers)
    assert got[0][0] == 0 and got[-1][1] == n_layers
    assert all(a[1] == b[0] for a, b in zip(got, got[1:]))
    assert all(hi > lo for lo, hi in got)


def test_bucket_layout_reversed_and_stage_pure():
    staged = nu.StagedPlan((FailurePlan(4, (4, 4)), FailurePlan(4, (3, 4))))
    layout = bucket_layout(CFG4, staged)
    # reversed chunk order: the backward reaches the LAST stage's grads first
    assert [b.stage for b in layout] == [1, 1, 0, 0]
    assert [b.kind for b in layout] == ["attn", "mlp", "attn", "mlp"]
    attn1 = layout[0]
    assert attn1 == Bucket(1, "attn", ((2, "wq"), (2, "wk"), (2, "wv"),
                                       (2, "wo"), (3, "wq"), (3, "wk"),
                                       (3, "wv"), (3, "wo")))
    # a chunk straddling stages is a geometry bug, not a silent merge
    with pytest.raises(AssertionError):
        bucket_layout(CFG4, staged, chunks=((0, 3), (3, 4)))


def test_sync_collectives_collapse():
    chunks = chunk_ranges(CFG4.n_layers, 1)
    healthy = FailurePlan(4, (4, 4))
    degraded = FailurePlan(4, (3, 4))
    # sequential: one launch per unit leaf (6/layer), x3 when degraded
    assert sync_collectives(CFG4, healthy, "ntp", bucketed=False) == 24
    assert sync_collectives(CFG4, degraded, "ntp", bucketed=False) == 72
    # bucketed on the pp=1 ladder: one launch per (chunk, kind)
    assert sync_collectives(CFG4, healthy, "ntp", bucketed=True,
                            chunks=chunks) == 8
    assert sync_collectives(CFG4, degraded, "ntp", bucketed=True,
                            chunks=chunks) == 24
    # uniform mode never reshards, even on a degraded-shaped plan
    assert sync_collectives(CFG4, degraded, "uniform", bucketed=False) == 24
    # staged: only the degraded STAGE pays the x3
    staged = nu.StagedPlan((FailurePlan(4, (4, 4)), FailurePlan(4, (2, 4))))
    assert sync_collectives(CFG4, staged, "ntp", bucketed=False) \
        == 12 * 1 + 12 * 3
    assert sync_collectives(CFG4, staged, "ntp", bucketed=True) \
        == 2 * 1 + 2 * 3


# --------------------------------------------------- pack/unpack kernels


def _leaves(rng, rows, widths):
    return [rng.standard_normal((rows, w)).astype(np.float32)
            for w in widths]


@pytest.mark.parametrize("widths", [(3,), (1, 1), (4, 2, 7), (8, 8, 8, 8)])
def test_bucket_pack_unpack_matches_ref(widths):
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    leaves = [jnp.asarray(a) for a in _leaves(rng, 16, widths)]
    flat = ops.bucket_pack(leaves, interpret=True)
    ref = bucket_pack_ref(leaves)
    assert flat.shape == (16, sum(widths))
    assert np.array_equal(np.asarray(flat), np.asarray(ref))
    parts = ops.bucket_unpack(flat, widths, interpret=True)
    ref_parts = bucket_unpack_ref(flat, widths)
    for p, rp, leaf in zip(parts, ref_parts, leaves):
        assert np.array_equal(np.asarray(p), np.asarray(leaf))
        assert np.array_equal(np.asarray(p), np.asarray(rp))


def test_bucket_pack_validates():
    import jax.numpy as jnp

    a = jnp.zeros((8, 3), jnp.float32)
    with pytest.raises(ValueError):
        ops.bucket_pack([a, jnp.zeros((4, 3), jnp.float32)],
                        interpret=True)  # row mismatch
    with pytest.raises(ValueError):
        ops.bucket_pack([a, jnp.zeros((8, 3), jnp.bfloat16)],
                        interpret=True)  # dtype mismatch
    with pytest.raises(ValueError):
        ops.bucket_pack([jnp.zeros((8,), jnp.float32)],
                        interpret=True)  # not 2-D


# ----------------------------------- bucketed == sequential sync property


def _ntp_sync(wp, bufs):
    """Numpy twin of the full Algorithm-1 sync: per-replica pre-reshard,
    psum('data'), per-replica post-reshard. bufs: (D, n1, buf, cols)."""
    d = bufs.shape[0]
    pre = np.stack([emulate_reshard(bufs[r], wp.pre, r) for r in range(d)])
    summed = pre.sum(axis=0)
    return np.stack([emulate_reshard(summed, wp.post, r) for r in range(d)])


def _check_bucketed_equals_sequential(plan, k, widths, seed):
    wp = nu.weight_plan(k, plan)
    rng = np.random.default_rng(seed)
    # independent per-replica gradients, one canonical (k, w) leaf each
    leaves = [[rng.standard_normal((k, w)).astype(np.float32)
               for w in widths] for _ in range(plan.d)]
    bufs = [np.stack([_rank_buffers(wp, leaves[r][i], 1)[r]
                      for r in range(plan.d)])
            for i in range(len(widths))]          # per-leaf (D, n1, buf, w)

    seq = [_ntp_sync(wp, b) for b in bufs]
    fused = _ntp_sync(wp, np.concatenate(bufs, axis=3))
    offs = np.cumsum((0,) + widths)
    for i in range(len(widths)):
        got = fused[..., offs[i]:offs[i + 1]]
        assert np.array_equal(got, seq[i]), (plan, k, widths, i)


def _random_chain(rng, events=4):
    """A fail/repair chain of plan states starting pristine."""
    n1, d = 4, int(rng.integers(2, 4))
    tp = [n1] * d
    chain = [FailurePlan(n1=n1, replica_tp=tuple(tp))]
    for _ in range(events):
        r = int(rng.integers(0, d))
        if tp[r] > 1 and rng.random() < 0.6:
            tp[r] -= 1                            # GPU failure
        else:
            tp[r] = n1                            # repair to pristine
        chain.append(FailurePlan(n1=n1, replica_tp=tuple(tp)))
    return chain


@pytest.mark.parametrize("seed", range(4))
def test_bucketed_equals_sequential_over_chain(seed):
    """Deterministic sweep: every plan state of a random fail/repair chain,
    bucketed == sequential bit-for-bit (the twin's sums are in the same
    order, so even degraded states compare exactly)."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(4, 12))
    widths = tuple(int(w) for w in rng.integers(1, 6, size=3))
    for plan in _random_chain(rng):
        _check_bucketed_equals_sequential(plan, k, widths, seed)


def test_bucketed_equals_sequential_hypothesis():
    """Property-based widening of the chain sweep (dev dependency)."""
    pytest.importorskip("hypothesis",
                        reason="dev dependency: pip install -e .[dev]")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           k=st.integers(4, 16),
           widths=st.lists(st.integers(1, 8), min_size=1, max_size=4))
    def prop(seed, k, widths):
        rng = np.random.default_rng(seed)
        for plan in _random_chain(rng, events=3):
            _check_bucketed_equals_sequential(plan, k, tuple(widths), seed)

    prop()


# ------------------------------------------------- perf-model overlap API


def test_exposed_comm_identity():
    assert pm.exposed_comm(3.0, 1.0) == 2.0
    assert pm.exposed_comm(1.0, 2.0) == 0.0
    assert pm.exposed_comm(0.0, 0.0) == 0.0


def test_overlap_iteration_time_decomposition():
    hw, wl, par = pm.Hardware(), pm.Workload(), pm.Parallel()
    o0 = pm.overlap_iteration_time(hw, wl, par, overlappable_fraction=0.0)
    # zero window: the whole sync is exposed and total decomposes exactly
    assert o0["exposed_comm"] == pytest.approx(o0["sync"])
    assert o0["total"] == pytest.approx(
        o0["compute"] + o0["tp_exposed"] + o0["pp_bubble"] + o0["sync"])
    o7 = pm.overlap_iteration_time(hw, wl, par, overlappable_fraction=0.7)
    assert o7["total"] <= o0["total"]
    assert o7["exposed_comm"] == pm.exposed_comm(o7["sync"],
                                                 o7["overlap_window"])
    # a big enough window hides the sync entirely
    o1 = pm.overlap_iteration_time(hw, wl, par, overlappable_fraction=1.0)
    if o1["overlap_window"] >= o1["sync"]:
        assert o1["exposed_comm"] == 0.0
        assert o1["total"] == pytest.approx(
            o1["compute"] + o1["tp_exposed"] + o1["pp_bubble"])


def test_overlap_iteration_time_collective_ratio_and_degraded():
    hw, wl, par = pm.Hardware(), pm.Workload(), pm.Parallel()
    a = pm.overlap_iteration_time(hw, wl, par, overlappable_fraction=0.0)
    b = pm.overlap_iteration_time(hw, wl, par, overlappable_fraction=0.0,
                                  collective_ratio=2.0)
    assert b["sync"] == pytest.approx(2.0 * a["sync"])
    # degraded replica: the reshard chain joins the sync term in full
    d = pm.overlap_iteration_time(hw, wl, par, overlappable_fraction=0.0,
                                  tp_reduced=par.tp // 2)
    assert d["reshard_exposed"] > 0
    assert d["sync"] == pytest.approx(d["dp_exposed"] + d["reshard_exposed"])


def test_iteration_time_reshard_overlap_knob_keeps_legacy_default():
    hw, wl, par = pm.Hardware(), pm.Workload(), pm.Parallel()
    kw = dict(tp_reduced=par.tp // 2)
    legacy = pm.iteration_time(hw, wl, par, **kw)
    full = pm.iteration_time(hw, wl, par, reshard_overlap=0.0, **kw)
    hidden = pm.iteration_time(hw, wl, par, reshard_overlap=1.0, **kw)
    # None keeps the Fig.-8 10%-exposed heuristic exactly
    assert legacy["reshard_exposed"] == pytest.approx(
        0.1 * full["reshard_exposed"])
    assert hidden["reshard_exposed"] == 0.0
