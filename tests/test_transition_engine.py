"""Unified reshard engine (ISSUE 4): the DIRECT packed→packed transition
must be bit-exact against the retired dense round-trip — which survives
here, composed from `pack_params ∘ unpack_params`, as the oracle — for
params AND AdamW-moment-shaped trees across random fail/repair chains; and
its numpy-twin transfer accounting must prove that ONLY units whose src
rank differs from their dst rank generate traffic, fused into one message
per (replica, src, dst) pair."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ntp_train as nt
from repro.core.nonuniform import FailurePlan
from repro.reshard import (
    expected_transfer, ntp_unit_specs, plan_cache_info,
    replica_transition_plans, transition_plan, transition_trees,
)

CFG = nt.NTPModelConfig(d_model=32, n_kv_groups=4, q_per_kv=1, head_dim=8,
                        d_ff=256, unit_rows=32, n_layers=2, vocab=64)
CFG_MOE = nt.NTPModelConfig(d_model=32, n_kv_groups=4, q_per_kv=1, head_dim=8,
                            d_ff=64, unit_rows=32, n_layers=1, vocab=64,
                            n_experts=8, top_k=2)


def _dense_roundtrip_oracle(cfg, packed, old, new):
    """The retired transition path, kept as the test oracle: recover the
    canonical tree from replica 0 of the old packing, re-pack under new."""
    return nt.pack_params(cfg, nt.unpack_params(cfg, packed, old), new)


def _random_tree(cfg, seed):
    """A params-shaped tree of random values (stands in for params or an
    AdamW moment — both transit identically)."""
    return nt.init_canonical(cfg, jax.random.PRNGKey(seed))


def _assert_trees_equal(a, b, ctx):
    for pa, (la, lb) in zip(
        jax.tree_util.tree_flatten_with_path(a)[0],
        zip(jax.tree.leaves(a), jax.tree.leaves(b)),
    ):
        assert np.array_equal(np.asarray(la), np.asarray(lb)), (pa[0], ctx)


def _check_chain(cfg, plans, seed=0):
    """Walk a fail/repair chain, asserting direct == oracle at EVERY hop
    for a params tree and two moment trees riding the same fused buckets."""
    trees = [nt.pack_params(cfg, _random_tree(cfg, seed + i), plans[0])
             for i in range(3)]
    for old, new in zip(plans, plans[1:]):
        moved, stats = transition_trees(cfg, trees, old, new)
        for t, m in zip(trees, moved):
            _assert_trees_equal(
                _dense_roundtrip_oracle(cfg, t, old, new), m, (old, new)
            )
        _check_accounting(cfg, stats, old, new, n_trees=len(trees))
        trees = moved


def _check_accounting(cfg, stats, old, new, *, n_trees):
    """The numpy twin's ledger must match the transfer matrices exactly:
    every bucketed unit has src_rank != dst_rank (apply_plan asserts the
    set is pure), totals match the off-diagonals, and buckets fuse to one
    message per (replica, src, dst) pair."""
    specs = ntp_unit_specs(cfg)
    leaves_per_family = {"kv_group": 4 * cfg.n_layers}
    mlp_kind = "expert" if cfg.is_moe else "rows128"
    leaves_per_family[mlp_kind] = 2 * cfg.n_layers
    if old == new:
        assert stats.moved_units == 0 and stats.messages == 0
        return
    want_moved = 0
    want_pairs = set()
    for spec in set(specs.values()):
        for d, plan in enumerate(replica_transition_plans(spec.k, old, new)):
            off = plan.transfer - np.diag(np.diag(plan.transfer))
            want_moved += int(off.sum()) * leaves_per_family[spec.kind] * n_trees
            want_pairs |= {(d,) + p for p in plan.pairs}
    assert stats.moved_units == want_moved, (old, new)
    assert stats.messages == len(want_pairs) == len(stats.per_pair)
    assert set(stats.per_pair) == want_pairs
    # and the family-level view agrees with expected_transfer
    exp = expected_transfer(cfg, old, new)
    per_leaf = {
        name: int((m - np.diag(np.diag(m))).sum()) for name, m in exp.items()
    }
    n_leaf_copies = {"wq": 1, "wk": 1, "wv": 1, "wo": 1, "A": 1, "B": 1}
    total = sum(per_leaf[n] * n_leaf_copies[n] for n in per_leaf)
    assert want_moved == total * cfg.n_layers * n_trees


CHAINS = [
    [(4, 4), (3, 4), (2, 4), (3, 4), (4, 4)],              # fail→fail→repair→repair
    [(4, 4, 4), (4, 4, 4), (2, 3, 4), (1, 4, 4), (4, 4, 4)],
    [(2, 2), (1, 2), (2, 2)],
]


@pytest.mark.parametrize("chain", CHAINS, ids=lambda c: "→".join(map(str, c)))
def test_direct_transition_equals_dense_roundtrip(chain):
    n1 = max(chain[0])
    plans = [FailurePlan(n1=n1, replica_tp=tp) for tp in chain]
    _check_chain(CFG, plans)


def test_direct_transition_moe_expert_units():
    plans = [FailurePlan(n1=4, replica_tp=tp)
             for tp in [(4, 4), (2, 4), (4, 4)]]
    _check_chain(CFG_MOE, plans)


def test_identity_transition_is_copy_not_alias():
    plan = FailurePlan(n1=4, replica_tp=(3, 4))
    packed = nt.pack_params(CFG, _random_tree(CFG, 0), plan)
    (out,), stats = transition_trees(CFG, [packed], plan, plan)
    _assert_trees_equal(packed, out, "identity")
    assert stats.moved_units == 0 and stats.messages == 0
    # donated-step-input safety: fresh buffers, never aliases
    for a, b in zip(jax.tree.leaves(packed), jax.tree.leaves(out)):
        assert a is not b


def test_planner_lru_cache_reuses_plans():
    a = transition_plan(("comp", 8, 4, 4, 3), ("comp", 8, 4, 3, 3), 3, 3)
    b = transition_plan(("comp", 8, 4, 4, 3), ("comp", 8, 4, 3, 3), 3, 3)
    assert a is b
    assert plan_cache_info()["transition_plan"]["hits"] >= 1


def test_stays_never_travel():
    """Every per_pair key is (replica, src, dst) with src != dst — the
    direct route cannot put a staying unit on the network."""
    old = FailurePlan(n1=4, replica_tp=(4, 4))
    new = FailurePlan(n1=4, replica_tp=(2, 4))
    packed = nt.pack_params(CFG, _random_tree(CFG, 1), old)
    _, stats = transition_trees(CFG, [packed], old, new)
    assert stats.per_pair and all(s != r for _, s, r in stats.per_pair)
    assert stats.moved_units > 0
    # O(moved units), not O(model): strictly less host traffic than the
    # dense round-trip's full-tree touch
    assert stats.bytes_moved < stats.dense_bytes


# ---------------------------------------------------------------------------
# the hypothesis property (CI: pip install -e .[dev])

try:
    from hypothesis import given, settings, strategies as st

    @st.composite
    def _chain_case(draw):
        n1 = draw(st.integers(2, 4))
        d = draw(st.integers(2, 3))
        hops = draw(st.integers(1, 4))
        chain = [(n1,) * d]
        for _ in range(hops):
            chain.append(tuple(
                draw(st.integers(1, n1)) for _ in range(d)
            ))
        seed = draw(st.integers(0, 2 ** 16))
        return n1, chain, seed

    @settings(max_examples=25, deadline=None)
    @given(_chain_case())
    def test_random_chain_direct_equals_oracle(case):
        n1, chain, seed = case
        plans = [FailurePlan(n1=n1, replica_tp=tp) for tp in chain]
        _check_chain(CFG, plans, seed=seed)

except ImportError:  # pragma: no cover — dev dependency
    pass
