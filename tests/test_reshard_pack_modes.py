"""Pallas `reshard_pack` kernel-vs-jnp parity across BOTH execution modes:
kernels are compiled by default wherever a non-CPU device exists and fall
back to interpret on CPU (`kernels.mode.pallas_interpret`: explicit
``interpret=`` > ``REPRO_PALLAS_COMPILE`` env > backend default, read per
call). Interpret mode must match the plain jnp gather bit-for-bit on every
backend; compiled mode is asserted identical too wherever the backend can
lower Pallas (TPU/GPU), and skips cleanly on CPU."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.reshard import engine as rse
from repro.reshard import planner


def _case(seed=0, k=6, n1=4, tp_to=2):
    rng = np.random.default_rng(seed)
    tables = planner.tables(
        planner.sync_key(k, n1, n1), planner.sync_key(k, n1, tp_to), k
    )
    x = jnp.asarray(rng.normal(size=(n1, k + 1, 16)), jnp.float32)  # padded
    return x, tables


def _jnp_gather(xp, send_idx):
    return jax.vmap(lambda xr, ir: xr[ir])(xp, jnp.asarray(send_idx))


def test_pallas_interpret_flag_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_COMPILE", raising=False)
    # backend default: compiled on an accelerator, interpret on CPU
    assert ops.pallas_interpret() is (jax.default_backend() == "cpu")
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "1")
    assert ops.pallas_interpret() is False          # env threads through
    assert ops.pallas_interpret(True) is True       # explicit override wins
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "0")
    assert ops.pallas_interpret() is True           # force-interpret debug
    assert ops.pallas_interpret(False) is False
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "")
    assert ops.pallas_interpret() is (jax.default_backend() == "cpu")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_kernel_interpret_matches_jnp(seed):
    x, tables = _case(seed)
    want = np.asarray(_jnp_gather(x, tables.send_idx))
    flat = x.reshape(x.shape[0], x.shape[1], -1)
    got = np.stack([
        np.asarray(ops.reshard_pack(flat[r], jnp.asarray(tables.send_idx[r]),
                                    interpret=True))
        for r in range(x.shape[0])
    ]).reshape(want.shape)
    assert np.array_equal(want, got)


def test_kernel_compiled_matches_jnp_or_skips():
    """Compiled mode (the REPRO_PALLAS_COMPILE=1 / --pallas-compile route):
    bit-identical to the jnp gather where the backend lowers Pallas."""
    x, tables = _case(3)
    flat = x.reshape(x.shape[0], x.shape[1], -1)
    idx = jnp.asarray(tables.send_idx[0])
    try:
        got = np.asarray(
            jax.block_until_ready(
                ops.reshard_pack(flat[0], idx, interpret=False)
            )
        )
    except Exception as e:  # pragma: no cover — CPU cannot lower Pallas TPU
        pytest.skip(f"backend {jax.default_backend()!r} cannot compile "
                    f"Pallas: {type(e).__name__}")
    want = np.asarray(_jnp_gather(x, tables.send_idx))[0].reshape(got.shape)
    assert np.array_equal(want, got)


def test_engine_kernel_route_matches_jnp_route():
    """`engine.reshard_ranks(use_kernel=True)` (the route `--use-kernel`
    serving and the state reshard take) == the pure-jnp route, bitwise —
    including the zero-pad slot semantics."""
    rng = np.random.default_rng(7)
    k, n1 = 6, 4
    tables = planner.tables(
        planner.sync_key(k, n1, 4), planner.sync_key(k, n1, 2), k
    )
    x = jnp.asarray(rng.normal(size=(n1, k, 3, 5)), jnp.float32)
    a = rse.reshard_ranks(x, tables, use_kernel=False)
    b = rse.reshard_ranks(x, tables, use_kernel=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    # and both agree with the numpy twin
    from repro.reshard.twin import emulate_tables

    c = emulate_tables(np.asarray(x), tables)
    assert np.array_equal(np.asarray(a), c)


def test_reshard_pack_env_threading(monkeypatch):
    """ops.reshard_pack with no explicit flag follows the env var at CALL
    time (not import time) — the CLI launchers rely on this."""
    x, tables = _case(4)
    flat = x.reshape(x.shape[0], x.shape[1], -1)
    idx = jnp.asarray(tables.send_idx[0])
    monkeypatch.delenv("REPRO_PALLAS_COMPILE", raising=False)
    want = np.asarray(ops.reshard_pack(flat[0], idx))
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "1")
    try:
        got = np.asarray(jax.block_until_ready(ops.reshard_pack(flat[0], idx)))
    except Exception as e:
        pytest.skip(f"backend {jax.default_backend()!r} cannot compile "
                    f"Pallas: {type(e).__name__}")
    assert np.array_equal(want, got)
