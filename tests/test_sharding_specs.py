"""Sharding-spec consistency: for every arch, the PartitionSpec tree must
mirror the param tree exactly, TP-sharded dims must divide by the mesh, and
ZeRO-1 must add a data axis without clobbering TP placement."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS
from repro.models import build_model
from repro.models.common import sanitize_spec

from conftest import reduced_cfg


def _is_spec(x):
    return isinstance(x, P)


@pytest.mark.parametrize("aid", sorted(ARCH_IDS))
def test_param_specs_mirror_params(aid):
    cfg = reduced_cfg(aid)
    m = build_model(cfg)
    pshape = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = m.param_specs()
    s1 = jax.tree_util.tree_structure(jax.tree.map(lambda x: 0, pshape))
    s2 = jax.tree_util.tree_structure(
        jax.tree.map(lambda s: 0, specs, is_leaf=_is_spec)
    )
    assert s1 == s2
    # every spec has rank <= leaf rank
    for spec, leaf in zip(
        jax.tree.leaves(specs, is_leaf=_is_spec), jax.tree.leaves(pshape)
    ):
        assert len(tuple(spec)) <= leaf.ndim, (spec, leaf.shape)


def test_full_arch_specs_divisible_on_production_mesh():
    """FULL configs: after sanitize, every sharded dim divides 16 (the
    `model` axis); embedding/lm-head stay vocab-sharded (padded vocab)."""
    from repro.configs import get_arch

    mesh_shape = {"data": 16, "model": 16}
    for aid in ARCH_IDS:
        cfg = get_arch(aid)
        m = build_model(cfg)
        pshape = jax.eval_shape(m.init, jax.random.PRNGKey(0))
        specs = m.param_specs()
        flat_specs = jax.tree.leaves(specs, is_leaf=_is_spec)
        flat_shapes = jax.tree.leaves(pshape)
        n_sharded = 0
        for spec, leaf in zip(flat_specs, flat_shapes):
            s = sanitize_spec(mesh_shape, leaf.shape, spec)
            for d, names in enumerate(tuple(s)):
                if names is None:
                    continue
                n_sharded += 1
                size = 1
                for n in (names if isinstance(names, tuple) else (names,)):
                    size *= mesh_shape[n]
                assert leaf.shape[d] % size == 0
        assert n_sharded > 0, aid
        # embedding must shard (padded vocab)
        emb_spec = sanitize_spec(mesh_shape, pshape["embed"].shape, specs["embed"])
        assert tuple(emb_spec)[0] is not None, aid


def test_zero1_adds_data_axis():
    from repro.sharding.specs import zero1_spec

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    s = zero1_spec(FakeMesh(), P(None, "model"), (4096, 1024))
    assert tuple(s)[0] == "data"
    # never steals a TP axis
    s2 = zero1_spec(FakeMesh(), P("model", None), (16, 7))  # nothing divisible
    assert tuple(s2) == ("model", None)
