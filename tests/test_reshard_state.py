"""Generic `ShardedState` over recurrent caches (ISSUE 4): the channel-block
UnitSpecs (SSD heads, rgLRU gate blocks) make SSM/Griffin state reshardable
with the same invariants the KV property suite pins for heads — shard∘gather
identity, TP-chain content preservation, pad hygiene, replicated-tail
(SSM conv B/C columns) integrity, and kernel-route parity."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RGLRUSpec, SSMSpec
from repro.models.transformer import build_model
from repro.reshard import (
    ShardedState, UnitSpec, arch_unit_counts, cache_unit_resolver,
    serve_unit_count,
)

SSM_CFG = ArchConfig(
    arch_id="reshard-state-ssm", family="ssm", citation="test",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0,
    vocab_size=64, layer_pattern=("ssm",),
    ssm=SSMSpec(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=16),
    use_rope=False, tie_embeddings=True,
)
GRIFFIN_CFG = ArchConfig(
    arch_id="reshard-state-griffin", family="hybrid", citation="test",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=64, layer_pattern=("rglru", "rglru", "attn_sw"), window=32,
    rglru=RGLRUSpec(d_conv=4, block_width=16), tie_embeddings=True,
)
N1 = 4


def _rand_cache(cfg, slots=3, max_len=16, seed=0):
    m = build_model(cfg, remat=False)
    cache = m.init_slot_cache(slots, max_len, jnp.float32)
    rng = np.random.default_rng(seed)
    return jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), x.dtype), cache
    )


def _trees_equal(a, b):
    return all(jax.tree.leaves(jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))), a, b
    )))


def test_unit_counts_and_geometry():
    assert arch_unit_counts(SSM_CFG) == {"ssm_head": 8}       # d_inner 128 / 16
    assert serve_unit_count(SSM_CFG) == 8
    counts = arch_unit_counts(GRIFFIN_CFG)
    assert counts == {"rglru_block": 4, "kv_head": 2}         # di 64 / 16
    assert serve_unit_count(GRIFFIN_CFG) == 2                 # coarsest pins it


@pytest.mark.parametrize("cfg", [SSM_CFG, GRIFFIN_CFG],
                         ids=lambda c: c.arch_id)
def test_shard_gather_identity(cfg):
    cache = _rand_cache(cfg)
    state = ShardedState(cache, cache_unit_resolver(cfg), N1)
    assert _trees_equal(cache, state.gather())


@pytest.mark.parametrize("cfg", [SSM_CFG, GRIFFIN_CFG],
                         ids=lambda c: c.arch_id)
@pytest.mark.parametrize("chain", [(3, 1, 2, 4), (2, 4), (1, 3, 1, 4)])
def test_tp_chain_preserves_state(cfg, chain):
    cache = _rand_cache(cfg, seed=hash(chain) % 2 ** 16)
    state = ShardedState(cache, cache_unit_resolver(cfg), N1)
    for tp in chain:
        st = state.apply_tp(tp)
        assert st["tp_to"] == tp and state.tp == tp
        assert _trees_equal(cache, state.gather()), (cfg.arch_id, chain, tp)
    if chain[-1] != N1:
        state.apply_tp(N1)
    assert _trees_equal(cache, state.gather())


def test_transition_accounting_and_fusion():
    cache = _rand_cache(GRIFFIN_CFG)
    state = ShardedState(cache, cache_unit_resolver(GRIFFIN_CFG), N1)
    st = state.apply_tp(2)
    assert st["bytes_moved"] > 0
    # fused: one message per (src, dst) pair across BOTH unit families
    # (rglru blocks and kv heads), never one per tensor
    n_leaves = len(jax.tree.leaves(cache))
    assert 0 < st["messages"] < n_leaves
    st2 = state.apply_tp(2)
    assert st2["bytes_moved"] == 0 and st2["messages"] == 0


def test_ssm_conv_tail_never_moves():
    """The conv state's trailing 2·d_state B/C columns are replicated — a
    TP transition must neither move nor corrupt them, even when NaN'd
    (they are outside the unit span, so the engine never touches them)."""
    cache = _rand_cache(SSM_CFG)
    s = SSM_CFG.ssm
    tail = 2 * s.d_state

    def poison(path, x):
        name = getattr(path[-1], "key", None)
        if name == "conv":
            return x.at[..., -tail:].set(jnp.nan)
        return x

    poisoned = jax.tree_util.tree_map_with_path(poison, cache)
    state = ShardedState(poisoned, cache_unit_resolver(SSM_CFG), N1)
    state.apply_tp(2)
    state.apply_tp(3)
    got = state.gather()
    flat = jax.tree_util.tree_flatten_with_path(got)[0]
    for path, leaf in flat:
        if getattr(path[-1], "key", None) == "conv":
            assert bool(jnp.isnan(leaf[..., -tail:]).all())   # tail intact
            assert bool(jnp.isfinite(leaf[..., :-tail]).all())  # units clean


def test_kernel_route_parity_on_recurrent_state():
    cache = _rand_cache(SSM_CFG, seed=9)
    a = ShardedState(cache, cache_unit_resolver(SSM_CFG), N1)
    b = ShardedState(cache, cache_unit_resolver(SSM_CFG), N1, use_kernel=True)
    for tp in (2, 3):
        a.apply_tp(tp)
        b.apply_tp(tp)
    assert _trees_equal(a.gather(), b.gather())


def test_resolver_rejects_unknown_state_leaves():
    res = cache_unit_resolver(SSM_CFG)
    bogus = {"layers": ({"mystery": jnp.zeros((2, 3))},)}
    flat = jax.tree_util.tree_flatten_with_path(bogus)[0]
    with pytest.raises(ValueError, match="no UnitSpec"):
        res(flat[0][0])


def test_unit_spec_geometry_validated():
    cache = {"layers": ({"h": jnp.zeros((2, 5))},)}   # 5 ≠ k·unit + tail
    bad = UnitSpec("rglru_block", 2, axis=-1, unit=2)
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    with pytest.raises(AssertionError):
        ShardedState(cache, lambda p: bad, N1)
