"""Host-side unit tests for the measured submesh pipeline (core/pp_submesh,
DESIGN.md §2.8): the stage-stacking geometry and its zero-pad invariants, the
hand-off byte ledger arithmetic, and the staged-mesh validation errors. The
live 16-device execution path is tests/dist/session_submesh_pp.py (run by
test_runtime.test_session_submesh_pp_measured)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import nonuniform as nu
from repro.core import ntp_train as nt
from repro.core import pp_submesh
from repro.core.nonuniform import FailurePlan
from repro.launch.mesh import make_staged_mesh


def _cfg(n_layers=3):
    return nt.NTPModelConfig(d_model=32, n_kv_groups=2, q_per_kv=1,
                             head_dim=16, d_ff=64, unit_rows=32,
                             n_layers=n_layers, vocab=64)


def _staged():
    # stage 1 degraded (replica 1 at tp=1): its unit buffers are WIDER than
    # stage 0's, so stacking must pad stage 0; n_layers=3 over pp=2 gives
    # stages of 2 and 1 layers, so stage 1 also pads a whole layer.
    return nu.StagedPlan((FailurePlan(n1=2, replica_tp=(2, 2)),
                          FailurePlan(n1=2, replica_tp=(2, 1))))


# ---------------------------------------------------------------------------
# stage stacking: geometry, specs, content, pad invariants

def test_stack_staged_params_geometry_and_content():
    cfg = _cfg()
    staged = _staged()
    canon = nt.init_canonical(cfg, jax.random.PRNGKey(0))
    packed = nt.pack_params(cfg, canon, staged)
    stacked, specs = pp_submesh.stack_staged_params(cfg, packed, staged)

    stage_layers, l_max, plans, u_max = pp_submesh._stage_geometry(cfg, staged)
    assert stage_layers == [(0, 1), (2,)] and l_max == 2
    # the degraded stage's redistribution widens the per-rank unit buffer
    assert u_max["attn"] == 2 and u_max["mlp"] == 2

    # global (non-layer) leaves pass through untouched and replicated
    for k in ("embed", "head", "final_norm"):
        assert stacked[k] is packed[k] and specs[k] == P()

    d, n1 = staged.d, staged.n1
    for key in nt.UNIT_KEYS:
        kind = "attn" if key in pp_submesh._ATTN_KEYS else "mlp"
        leaf = np.asarray(stacked["unit"][key])
        assert specs["unit"][key] == P("stage", None, "data", "model")
        unit_tail = np.asarray(packed["layers"][0][key]).shape[2:]
        assert leaf.shape == (2, l_max, d, n1 * u_max[kind], *unit_tail)
        for s, layers in enumerate(stage_layers):
            u_s = plans[s][kind].comp_slots.shape[2]
            for l, li in enumerate(layers):
                row = leaf[s, l].reshape(d, n1, u_max[kind], *unit_tail)
                want = np.asarray(packed["layers"][li][key]).reshape(
                    d, n1, u_s, *unit_tail)
                # real slots are the packed layer verbatim...
                assert np.array_equal(row[:, :, :u_s], want), (key, s, l)
                # ...and pad slots are exactly zero (algebraically inert)
                assert not row[:, :, u_s:].any(), (key, s, l)
            # stages owning fewer layers pad with all-zero layers
            for l in range(len(layers), l_max):
                assert not leaf[s, l].any(), (key, s, l)

    for key in ("ln1", "ln2"):
        leaf = np.asarray(stacked["rep"][key])
        # replicated on purpose: sharding these P("stage") trips a jax 0.4.x
        # partitioner bug when the stack is traced into the step's jit
        assert specs["rep"][key] == P()
        assert leaf.shape[:2] == (2, l_max)
        for s, layers in enumerate(stage_layers):
            for l, li in enumerate(layers):
                assert np.array_equal(
                    leaf[s, l], np.asarray(packed["layers"][li][key]))
            for l in range(len(layers), l_max):
                assert not leaf[s, l].any()


def test_stack_staged_params_no_pad_when_uniform():
    """Healthy plan, layers dividing evenly: stacking is pure reshape — every
    slot is a real weight, nothing padded."""
    cfg = _cfg(n_layers=4)
    staged = nu.StagedPlan((FailurePlan(n1=2, replica_tp=(2, 2)),) * 2)
    packed = nt.pack_params(cfg, nt.init_canonical(cfg, jax.random.PRNGKey(1)),
                            staged)
    stacked, _ = pp_submesh.stack_staged_params(cfg, packed, staged)
    for key in nt.UNIT_KEYS:
        leaf = np.asarray(stacked["unit"][key])
        assert leaf.shape[:2] == (2, 2)
        for s, layers in enumerate(((0, 1), (2, 3))):
            for l, li in enumerate(layers):
                assert np.array_equal(
                    leaf[s, l], np.asarray(packed["layers"][li][key]))


# ---------------------------------------------------------------------------
# hand-off ledger arithmetic

def test_handoff_accounting_table():
    cfg = _cfg()
    staged = _staged()
    t = pp_submesh.handoff_accounting(cfg, staged, local_batch=8,
                                      microbatches=4, seq_len=16)
    mb = 8 // 4
    assert t["ticks"] == 4 + 2 - 1
    assert t["act_bytes_per_send"] == 4 * mb * 16 * cfg.d_model
    assert t["sender_ranks"] == (2 - 1) * staged.d * staged.n1
    assert t["sends_per_boundary"] == t["ticks"] - 1
    assert t["fwd_bytes"] == (t["act_bytes_per_send"] * t["sender_ranks"]
                              * t["sends_per_boundary"])
    assert t["bwd_bytes"] == t["fwd_bytes"]            # ppermute transpose
    assert t["total_bytes"] == 2 * t["fwd_bytes"]


def test_handoff_accounting_scales_with_stages():
    """One boundary per extra stage; more microbatches -> smaller sends but
    more of them, total forward volume m*(pp-1)+... per the tick schedule."""
    cfg = _cfg(n_layers=8)
    p2 = nu.StagedPlan((FailurePlan(n1=2, replica_tp=(2, 2)),) * 2)
    p4 = nu.StagedPlan((FailurePlan(n1=2, replica_tp=(2, 2)),) * 4)
    t2 = pp_submesh.handoff_accounting(cfg, p2, local_batch=8,
                                       microbatches=2, seq_len=16)
    t4 = pp_submesh.handoff_accounting(cfg, p4, local_batch=8,
                                       microbatches=2, seq_len=16)
    assert t4["sender_ranks"] == 3 * t2["sender_ranks"]
    assert t4["ticks"] == 2 + 4 - 1
    assert t4["sends_per_boundary"] == t4["ticks"] - 1


# ---------------------------------------------------------------------------
# mesh predicates + validation errors

class _StubMesh:
    def __init__(self, names, shape):
        self.axis_names = tuple(names)
        self.shape = dict(shape)


def test_is_staged_mesh():
    assert not pp_submesh.is_staged_mesh(None)
    assert not pp_submesh.is_staged_mesh(
        _StubMesh(("data", "model"), {"data": 2, "model": 4}))
    assert not pp_submesh.is_staged_mesh(
        _StubMesh(pp_submesh.STAGE_AXES, {"stage": 1, "data": 2, "model": 4}))
    assert pp_submesh.is_staged_mesh(
        _StubMesh(pp_submesh.STAGE_AXES, {"stage": 2, "data": 2, "model": 4}))


def test_validate_staged_mesh_errors():
    good = _StubMesh(pp_submesh.STAGE_AXES,
                     {"stage": 2, "data": 2, "model": 4})
    pp_submesh.validate_staged_mesh(good, 2)   # no raise
    with pytest.raises(ValueError, match="make_staged_mesh"):
        pp_submesh.validate_staged_mesh(
            _StubMesh(("data", "model"), {"data": 2, "model": 4}), 2)
    with pytest.raises(ValueError, match="one submesh per pipeline stage"):
        pp_submesh.validate_staged_mesh(good, 3)


def test_make_staged_mesh_errors():
    with pytest.raises(ValueError, match="make_test_mesh"):
        make_staged_mesh(1, 2, 4)
    # geometry no host can satisfy -> the error counts the shortfall
    with pytest.raises(ValueError, match=r"needs 32768 devices"):
        make_staged_mesh(2, 128, 128)


def test_make_submesh_train_step_validates_microbatching():
    cfg = _cfg(n_layers=4)
    staged = nu.StagedPlan((FailurePlan(n1=2, replica_tp=(2, 2)),) * 2)
    mesh = _StubMesh(pp_submesh.STAGE_AXES,
                     {"stage": 2, "data": 2, "model": 2})
    with pytest.raises(ValueError, match="microbatches=0 outside"):
        pp_submesh.make_submesh_train_step(cfg, staged, mesh, local_batch=4,
                                           microbatches=0)
    with pytest.raises(ValueError, match="not divisible by"):
        pp_submesh.make_submesh_train_step(cfg, staged, mesh, local_batch=4,
                                           microbatches=3)
