"""`repro.cluster` — the global repack planner's invariants (ISSUE 6).

Host-side, no mesh. The allocator's contract, asserted as properties:

* **domain conservation** — swaps permute failure counts, spares zero a site
  while recording what they absorbed; the ledger's failure multiset is
  conserved exactly;
* **amortization** — no non-rescue decision's priced transfer time exceeds
  its goodput gain over the horizon; with a ZERO horizon (and the in-place
  plan matching stage-local packing) the allocator never moves state at all;
* **off-equivalence** — allocator off reproduces PR 5's stage-local plans
  bit-exactly; the allocator with nothing to gain does too;
* **determinism** — same ledger, same verdict, field for field.

Each property runs over a deterministic scenario sweep ALWAYS, and under
hypothesis when the dev extra is installed (the sweep is the floor, the
fuzzer is the ceiling). Plus unit tests: spare placement, swap
concentration, rescue priority, and the cost model's exactness against the
executed `TransferStats` ledger.
"""
import numpy as np
import pytest

import jax

from repro.cluster import (
    Action, AllocatorConfig, GlobalPlan, GoodputModel, GreedyAllocator,
    TransitionCost, TransitionCostModel, make_allocator,
)
from repro.core import ntp_train as nt
from repro.core.nonuniform import FailurePlan, StagedPlan
from repro.runtime.events import (
    ClusterHealth, DeadReplicaError, StagedHealth, staged_plan_from_health,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:               # dev extra absent: the sweep still runs
    HAVE_HYPOTHESIS = False


def health_of(n1, counts):
    return StagedHealth(tuple(
        ClusterHealth(n1, tuple(int(x) for x in c)) for c in counts
    ))


def synthetic_cost(pp, n_layers=4):
    """A calibrated-shaped cost model without trees: two unit families.
    Family keys are unit counts and must be >= n1 (shard_mapping's k >= n1
    invariant), so they clear every n1 the sweep uses."""
    return TransitionCostModel(family_layer_bytes={8: 4096, 16: 1024},
                               n_layers=n_layers, pp=pp)


# deterministic floor for the properties: (n1, counts, spares) covering
# pristine, absorbable, relocation-forcing, swap-skewed, dead+rescuable
SWEEP = [
    (4, ((0, 0), (0, 0)), 0),
    (4, ((0, 0), (0, 0)), 1),
    (4, ((0, 0), (1, 0)), 0),
    (4, ((0, 0), (1, 0)), 1),
    (4, ((0, 1), (1, 0)), 1),          # one spare, two wounded stages
    (4, ((2, 2), (0, 0)), 0),          # skew: swap concentrates
    (4, ((2, 0), (0, 2)), 0),          # anti-diagonal skew
    (4, ((4, 0), (1, 0)), 1),          # dead domain: rescue
    (4, ((3, 1), (1, 3)), 2),
    (2, ((1, 0, 1), (0, 1, 0), (1, 1, 0)), 1),   # pp=3, d=3
    (5, ((2, 4, 1), (0, 0, 3)), 2),
]


def _plan_or_none(alloc, h, **kw):
    try:
        return alloc.plan(h, **kw)
    except DeadReplicaError:
        return None


# ---------------------------------------------------------------------------
# properties (each: a check function + the sweep + optional hypothesis)

def check_conservation(n1, counts, spares):
    h = health_of(n1, counts)
    gp = _plan_or_none(GreedyAllocator(), h, spares=spares)
    if gp is None:      # even the allocator could not revive every replica
        return
    final = [list(c) for c in gp.counts]
    assert len(gp.spare_sites) <= spares
    for s, d, absorbed in gp.spare_sites:
        assert final[s][d] == 0, "a spare must zero the site it covers"
        final[s][d] = absorbed              # undo the absorption
    assert sorted(x for c in final for x in c) == \
        sorted(x for c in counts for x in c), (counts, gp.counts,
                                               gp.spare_sites)
    # and the packed plan realizes exactly the final counts, per stage
    for c, fp in zip(gp.counts, gp.staged_plan.stages):
        assert sorted(fp.replica_tp) == sorted(n1 - x for x in c)


def check_amortized(n1, counts, spares):
    pp = len(counts)
    cost = synthetic_cost(pp)
    current = staged_plan_from_health(StagedHealth.pristine(
        len(counts[0]), n1, pp=pp))
    alloc = GreedyAllocator(goodput=GoodputModel(n1=n1), cost=cost)
    gp = _plan_or_none(alloc, health_of(n1, counts), spares=spares,
                       current=current)
    if gp is None:
        return
    for a in gp.decisions:
        assert a.rescue or a.cost_s <= a.gain_s + 1e-12, a
        assert a.bytes >= 0 and a.cost_s >= 0
    # the priced total is exactly the sum of the per-stage movements
    assert gp.predicted_bytes == sum(a.bytes for a in gp.transitions)
    assert gp.predicted_bytes == cost.predict_bytes(current, gp.staged_plan)
    # global packing never loses to the spare-less stage-local baseline
    assert gp.goodput >= gp.baseline_goodput - 1e-12


def check_zero_horizon_is_stage_local(n1, counts):
    """No amortization budget + the stage-local plan already in place =>
    the allocator must not move state: its verdict IS stage-local packing."""
    h = health_of(n1, counts)
    try:
        sl = staged_plan_from_health(h)
    except DeadReplicaError:
        return          # baseline dead: only rescue moves apply, not covered
    alloc = GreedyAllocator(AllocatorConfig(horizon_steps=0),
                            goodput=GoodputModel(n1=n1),
                            cost=synthetic_cost(len(counts)))
    gp = alloc.plan(h, spares=0, current=sl)
    assert gp.staged_plan == sl, (counts, gp.summary())
    assert gp.predicted_bytes == 0 and not gp.moved


def check_deterministic(n1, counts, spares):
    h = health_of(n1, counts)
    a = _plan_or_none(GreedyAllocator(), h, spares=spares)
    b = _plan_or_none(GreedyAllocator(), h, spares=spares)
    assert a == b


@pytest.mark.parametrize("n1,counts,spares", SWEEP)
def test_sweep_properties(n1, counts, spares):
    check_conservation(n1, counts, spares)
    check_amortized(n1, counts, spares)
    check_zero_horizon_is_stage_local(n1, counts)
    check_deterministic(n1, counts, spares)


if HAVE_HYPOTHESIS:
    def _layouts():
        return st.integers(2, 5).flatmap(lambda n1: st.tuples(
            st.just(n1),
            st.integers(2, 3).flatmap(lambda d: st.lists(
                st.lists(st.integers(0, n1), min_size=d, max_size=d),
                min_size=2, max_size=3,
            ).map(lambda c: tuple(tuple(x) for x in c))),
            st.integers(0, 2),
        ))

    @settings(max_examples=80, deadline=None)
    @given(_layouts())
    def test_hypothesis_conservation(layout):
        check_conservation(*layout)

    @settings(max_examples=60, deadline=None)
    @given(_layouts())
    def test_hypothesis_amortized(layout):
        check_amortized(*layout)

    @settings(max_examples=60, deadline=None)
    @given(_layouts())
    def test_hypothesis_zero_horizon(layout):
        n1, counts, _ = layout
        check_zero_horizon_is_stage_local(n1, counts)

    @settings(max_examples=40, deadline=None)
    @given(_layouts())
    def test_hypothesis_deterministic(layout):
        check_deterministic(*layout)


# ---------------------------------------------------------------------------
# allocator behavior units

def test_allocator_off_is_stage_local():
    assert make_allocator("off") is None and make_allocator(None) is None
    h = health_of(4, ((0, 0), (1, 0)))
    gp = GreedyAllocator().plan(h, spares=0)
    assert gp.staged_plan == staged_plan_from_health(h)
    assert not gp.spare_sites and not gp.swaps
    with pytest.raises(ValueError, match="greedy"):
        make_allocator("annealed")


def test_spare_covers_the_worst_site():
    h = health_of(4, ((1, 0), (3, 0)))
    gp = GreedyAllocator().plan(h, spares=1)
    assert gp.spare_sites == ((1, 0, 3),), gp.spare_sites
    assert gp.counts == ((1, 0), (0, 0))
    assert gp.goodput > gp.baseline_goodput


def test_swap_concentrates_skewed_failures():
    """Two half-wounded replicas are worse than one wounded + one healthy:
    1F1B gates each replica at its slowest stage, so when BOTH of stage 0's
    domains are hit the allocator swaps one of them against a healthy
    stage-1 domain, concentrating the damage onto a single sacrificial
    replica (eff TP (2,2) -> (2,4)). Note the anti-diagonal ((2,0),(0,2))
    needs NO swap: per-stage packing already aligns worst-with-worst."""
    gm = GoodputModel(n1=4)
    h = health_of(4, ((2, 2), (0, 0)))
    gp = GreedyAllocator(goodput=gm).plan(h, spares=0)
    assert gp.swaps, gp.summary()
    assert gm.effective_tp([np.array(c) for c in gp.counts]).tolist() == [2, 4]
    assert gp.goodput > gp.baseline_goodput

    gp2 = GreedyAllocator(goodput=gm).plan(health_of(4, ((2, 0), (0, 2))),
                                           spares=0)
    assert not gp2.swaps and gp2.goodput == gp.goodput


def test_dead_domain_rescued_by_spare():
    h = health_of(4, ((4, 0), (1, 0)))        # domain 0 of stage 0 is dead
    with pytest.raises(DeadReplicaError):
        GreedyAllocator().plan(h, spares=0)
    gp = GreedyAllocator().plan(h, spares=1)
    rescue = [a for a in gp.decisions if a.rescue]
    assert rescue and rescue[0].site == (0, 0) and rescue[0].absorbed == 4
    assert gp.staged_plan.healthy is not None    # packable
    assert gp.baseline is None                   # stage-local alone was dead


def test_allocator_requires_staged_health():
    with pytest.raises(AssertionError):
        GreedyAllocator().plan(ClusterHealth(4, (0, 0)))


# ---------------------------------------------------------------------------
# degradation-weighted scoring (§2.11)

def test_goodput_degradation_weighting():
    """The ledger weights the score: stragglers/weak links drag their
    replica through replica_throughput's degradation kwargs, an SDC suspect
    prices its replica at 0, and a None/all-clear ledger is the binary
    path bit-identically."""
    from repro.core.policies import WorkloadGeometry, replica_throughput
    from repro.core.power import PowerModel
    from repro.runtime.events import DomainDegradation

    gm = GoodputModel(n1=4)
    counts = [np.array([0, 1]), np.array([0, 0])]
    base = gm.goodput(counts)
    assert gm.goodput(counts, None) == base
    clear = [[None, None], [DomainDegradation(), None]]
    assert gm.goodput(counts, clear) == base

    slow = [[None, None], [DomainDegradation(straggle=(2.0,)), None]]
    assert gm.goodput(counts, slow) < base
    # attach the weak link to the HEALTHY domain (stage-0 packing is most-
    # failed-first, so domain 0 pairs with the full-TP replica 1): a
    # degraded full-TP replica is priced below 1.0
    weak = [[DomainDegradation(link=(0.5,)), None], [None, None]]
    assert gm.goodput(counts, weak) < base

    # stage-0 packing order is most-failed-first: domain 1 (1 failed) pairs
    # with replica 0, so a suspicion on domain 0 quarantines replica 1
    sdc = [[DomainDegradation(sdc=1), None], [None, None]]
    wounded = replica_throughput(3, 4, WorkloadGeometry(), "ntp",
                                 PowerModel())
    assert gm.goodput(counts, sdc) == pytest.approx(wounded / 2)


def test_straggler_domain_spared_out():
    """A zero-failure straggler domain still drags its replica; with a
    spare in the pool the allocator evicts it outright (the stand-in is
    pristine, so the ledger clears with the counts) and the plan prices
    back to 1.0."""
    from repro.runtime.events import DomainDegradation

    deg = DomainDegradation(straggle=(2.0,))
    h = StagedHealth((
        ClusterHealth(4, (0, 0), degraded=(deg, None)),
        ClusterHealth(4, (0, 0)),
    ))
    gp = GreedyAllocator(AllocatorConfig(horizon_steps=1000)).plan(
        h, spares=1)
    assert gp.spare_sites == ((0, 0, 0),), gp.summary()
    assert gp.baseline_goodput < 1.0
    assert gp.goodput == 1.0


# ---------------------------------------------------------------------------
# cost model: exact against the executed ledger

def _cost_cfg():
    return nt.NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2,
                             head_dim=16, d_ff=256, unit_rows=64,
                             n_layers=4, vocab=128)


@pytest.mark.parametrize("old,new", [
    (StagedPlan((FailurePlan(2, (2, 2)), FailurePlan(2, (2, 2)))),
     StagedPlan((FailurePlan(2, (2, 2)), FailurePlan(2, (1, 2))))),
    (StagedPlan((FailurePlan(2, (1, 2)), FailurePlan(2, (2, 2)))),
     StagedPlan((FailurePlan(2, (2, 2)), FailurePlan(2, (1, 1))))),
    (StagedPlan((FailurePlan(2, (1, 2)), FailurePlan(2, (2, 1)))),
     StagedPlan((FailurePlan(2, (2, 2)), FailurePlan(2, (2, 2))))),
])
def test_cost_model_matches_executed_ledger(old, new):
    """`from_trees` + `predict_bytes` == the `TransferStats.bytes_moved` the
    reshard engine actually books for the same transition — both directions
    (degrade and repair), with an optimizer tree riding along."""
    from repro.reshard.transition import transition_staged_trees

    cfg = _cost_cfg()
    params = nt.pack_params(cfg, nt.init_canonical(cfg, jax.random.PRNGKey(0)),
                            old)
    m = jax.tree.map(np.zeros_like, params)      # one AdamW-moment-like tree
    trees = [params, m]
    cost = TransitionCostModel.from_trees(cfg, trees, pp=2)
    predicted = cost.predict(old, new)
    assert isinstance(predicted, TransitionCost)
    _, stats = transition_staged_trees(cfg, trees, old, new,
                                       copy_unchanged=False)
    assert predicted.total_bytes == stats.bytes_moved, (
        predicted.stage_bytes, stats.bytes_moved)
    # per-stage split matches the stage-tagged ledger too
    for s in range(2):
        booked = sum(v for k, v in stats.bytes_by_pair.items() if k[0] == s) \
            if hasattr(stats, "bytes_by_pair") else None
        if booked is not None:
            assert predicted.stage_bytes[s] == booked
    assert predicted.seconds == predicted.total_bytes / cost.scaleup_bw


def test_cost_model_unchanged_plan_is_free():
    cfg = _cost_cfg()
    sp = StagedPlan((FailurePlan(2, (2, 2)), FailurePlan(2, (1, 2))))
    params = nt.pack_params(cfg, nt.init_canonical(cfg, jax.random.PRNGKey(1)),
                            sp)
    cost = TransitionCostModel.from_trees(cfg, [params], pp=2)
    assert cost.predict_bytes(sp, sp) == 0
    assert cost.predict_bytes(None, sp) == 0     # fresh packing is free


def test_cost_model_analytic_and_goodput_for_perf():
    from repro.core.perf_model import (
        Hardware, Parallel, Workload, iteration_time,
    )

    hw, wl = Hardware(), Workload()
    par = Parallel(tp=hw.domain_size, pp=4, dp=8)
    cost = TransitionCostModel.analytic(wl, par)
    assert cost.pp == par.pp and cost.n_layers == wl.n_layers
    (k, per_layer), = cost.family_layer_bytes.items()
    assert per_layer * wl.n_layers * k == pytest.approx(
        wl.n_params * 12, rel=1e-6)
    gm = GoodputModel.for_perf(hw, wl, par)
    assert gm.n1 == par.tp
    assert gm.step_time_s == iteration_time(hw, wl, par)["total"]
    assert gm.goodput([np.zeros(4, int)] * par.pp) == 1.0
    assert gm.gain_seconds(0.25, 100) == pytest.approx(
        0.25 * gm.step_time_s * 100)
