"""`launch.telemetry_report` — the offline goodput-decomposition fold
(ISSUE 8 tentpole cap), tested on synthetic recorder streams.

The report is pure arithmetic over recorded events, so every table is
checkable against hand-built streams:

* goodput rows carry exactly `GOODPUT_KEYS` and the time decomposition
  (compute + bubble + reshard) sums to 1;
* ``reshard_frac`` counts ONLY transitions that executed
  (``attrs.changed is True``) — refused/no-op applies are planner
  overhead, not reshard traffic;
* boosted policies predicting rel_iter_time < 1 (overdrive) clamp the
  bubble at 0 — boost territory is not bubble;
* the transition table buckets spans into executed / noop / rejected by
  the presence+value of the ``changed`` attr (a span that never finished
  apply() has none — the session raised mid-span);
* serve + kernel tables aggregate their series; `report()` omits every
  empty section; the whole fold round-trips through a JSONL file.
"""
import numpy as np
import pytest

from repro.launch.telemetry_report import (
    GOODPUT_KEYS, SYNC_SPAN_KEYS, events_table, goodput_table, kernel_table,
    report, serve_table, sync_table, transition_table,
)
from repro.telemetry import JsonlSink, MemorySink, Recorder


class StreamClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def build_stream(rel=(1.0, 1.5, 1.5, 1.0)):
    """A tiny fail -> repair lifecycle: 4 steps of 0.1 s each, one executed
    failure transition of 0.4 s (bytes 4096), one no-op apply, one rejected
    apply, per-step goodput gauges for a single policy."""
    clock = StreamClock()
    sink = MemorySink()
    rec = Recorder(sinks=[sink], clock=clock)
    goodputs = [1.0, 0.75, 0.75, 1.0]
    for i in range(4):
        with rec.span("session.step", backend="ntp", pp=1):
            clock.t += 0.1
        rec.gauge("train.goodput", goodputs[i], policy="ntp_pw")
        rec.gauge("train.goodput_unboosted", 0.5 if goodputs[i] < 1 else 1.0,
                  policy="ntp_pw")
        if rel[i] != 1.0:
            rec.gauge("train.rel_iter_time", rel[i], source="analytic",
                      policy="ntp_pw")
        if i == 0:
            with rec.span("session.transition", kind="failure", pp=1) as sp:
                clock.t += 0.4
                sp.set(changed=True, bytes_moved=4096, messages=3)
        if i == 1:  # refused: the span never got a "changed" attr
            with rec.span("session.transition", kind="failure", pp=1):
                clock.t += 0.05
        if i == 2:  # no-op apply: planned, nothing moved
            with rec.span("session.transition", kind="repair", pp=1) as sp:
                clock.t += 0.05
                sp.set(changed=False)
    return rec, sink, clock


def test_goodput_row_schema_and_decomposition():
    _, sink, _ = build_stream()
    table = goodput_table(list(sink.events()))
    assert set(table) == {"ntp_pw"}
    row = table["ntp_pw"]
    assert tuple(sorted(row)) == tuple(sorted(GOODPUT_KEYS))
    assert row["steps"] == 4
    assert row["goodput"] == pytest.approx(np.mean([1.0, 0.75, 0.75, 1.0]))
    assert row["goodput_unboosted"] == pytest.approx(0.75)
    assert row["boost_recovered"] == pytest.approx(row["goodput"] - 0.75)
    # reshard: ONLY the executed 0.4 s span over 0.4 s of steps -> 0.5
    assert row["reshard_frac"] == pytest.approx(0.4 / (0.4 + 0.4))
    # bubble: two degraded steps at rel 1.5 padded with 1.0 to 4 steps
    bubble = np.mean([1 - 1 / 1.5, 1 - 1 / 1.5, 0.0, 0.0])
    assert row["bubble_frac"] == pytest.approx((1 - 0.5) * bubble)
    assert (row["compute_frac"] + row["bubble_frac"] + row["reshard_frac"]
            == pytest.approx(1.0))


def test_goodput_overdrive_rel_below_one_is_not_bubble():
    """ntp_pw can predict rel_iter_time < 1 (power overdrive); the bubble
    floor per step is 0, never negative."""
    _, sink, _ = build_stream(rel=(0.9, 0.9, 0.9, 0.9))
    row = goodput_table(list(sink.events()))["ntp_pw"]
    assert row["bubble_frac"] == 0.0
    assert row["compute_frac"] + row["reshard_frac"] == pytest.approx(1.0)


def test_goodput_no_transitions():
    clock = StreamClock()
    sink = MemorySink()
    rec = Recorder(sinks=[sink], clock=clock)
    for _ in range(3):
        with rec.span("session.step"):
            clock.t += 0.1
        rec.gauge("train.goodput", 1.0, policy="none")
    row = goodput_table(list(sink.events()))["none"]
    assert row["reshard_frac"] == 0.0 and row["bubble_frac"] == 0.0
    assert row["compute_frac"] == pytest.approx(1.0)


def test_lifecycle_events_and_degradation_fold():
    """§2.11: per-kind totals fold from the `orchestrator.events` counter
    (SDC rollbacks from the transition spans' rollback attr), and the
    goodput rows carry the mean per-step degradation_loss slice. A
    binary-era stream folds to zero loss and no lifecycle table."""
    clock = StreamClock()
    sink = MemorySink()
    rec = Recorder(sinks=[sink], clock=clock)
    for kind, n in (("failure", 2), ("straggler", 3), ("sdc_suspect", 1)):
        for _ in range(n):
            rec.counter("orchestrator.events", kind=kind)
    with rec.span("session.transition", kind="sdc_suspect") as sp:
        clock.t += 0.01
        sp.set(changed=False, degraded=True, rollback=True)
    for loss in (0.0, 0.25, 0.25):
        with rec.span("session.step"):
            clock.t += 0.1
        rec.gauge("train.goodput", 1.0 - loss, policy="ntp")
        rec.gauge("train.goodput_degradation_loss", loss, policy="ntp")
    events = list(sink.events())
    assert events_table(events) == {
        "failure": 2, "straggler": 3, "sdc_suspect": 1, "sdc_rollback": 1,
    }
    row = goodput_table(events)["ntp"]
    assert row["degradation_loss"] == pytest.approx(np.mean([0.0, 0.25, 0.25]))
    assert report(events)["lifecycle_events"] == events_table(events)

    _, sink2, _ = build_stream()
    binary = list(sink2.events())
    assert "lifecycle_events" not in report(binary)
    assert goodput_table(binary)["ntp_pw"]["degradation_loss"] == 0.0


def test_sync_table_and_exposed_comm_frac():
    """`train.sync` probe spans fold into the per-mode sync table and their
    ``exposed_s`` becomes the goodput rows' ``exposed_comm_frac`` (carved
    out of compute — the decomposition still sums to 1)."""
    clock = StreamClock()
    sink = MemorySink()
    rec = Recorder(sinks=[sink], clock=clock)
    for _ in range(2):
        with rec.span("session.step", backend="ntp"):
            clock.t += 0.1
        rec.gauge("train.goodput", 1.0, policy="ntp")
    with rec.span("train.sync", overlap="off", backend="ntp") as sp:
        clock.t += 0.04
        sp.set(collectives=48, sync_s=0.04, exposed_s=0.04)
    with rec.span("train.sync", overlap="on", backend="ntp") as sp:
        clock.t += 0.01
        sp.set(collectives=8, sync_s=0.01, exposed_s=0.002)
    events = list(sink.events())

    sy = sync_table(events)
    assert set(sy) == {"off", "on"}
    for row in sy.values():
        assert tuple(sorted(row)) == tuple(sorted(SYNC_SPAN_KEYS))
    assert sy["off"]["collectives"] == 48 and sy["on"]["collectives"] == 8
    assert sy["on"]["count"] == 1
    assert sy["on"]["sync_s"] == pytest.approx(0.01)
    assert sy["on"]["exposed_s"] == pytest.approx(0.002)

    row = goodput_table(events)["ntp"]
    # 0.042 s exposed over 0.2 s of steps (no transitions)
    assert row["exposed_comm_frac"] == pytest.approx(0.042 / 0.2)
    assert (row["compute_frac"] + row["bubble_frac"] + row["reshard_frac"]
            + row["exposed_comm_frac"] == pytest.approx(1.0))
    assert "sync" in report(events)


def test_pre_overlap_stream_reports_zero_exposed():
    """Streams recorded before the overlap engine carry no train.sync spans:
    they must re-fold unchanged with exposed_comm_frac == 0."""
    _, sink, _ = build_stream()
    events = list(sink.events())
    assert sync_table(events) == {}
    row = goodput_table(events)["ntp_pw"]
    assert row["exposed_comm_frac"] == 0.0
    assert "sync" not in report(events)


def test_transition_outcome_buckets():
    _, sink, _ = build_stream()
    table = transition_table(list(sink.events()))
    assert set(table) == {
        "session.transition:failure:executed",
        "session.transition:failure:rejected",
        "session.transition:repair:noop",
    }
    ex = table["session.transition:failure:executed"]
    assert ex["count"] == 1 and ex["bytes_moved"] == 4096
    assert ex["messages"] == 3 and ex["seconds"] == pytest.approx(0.4)
    assert table["session.transition:failure:rejected"]["bytes_moved"] == 0


def test_serve_and_kernel_tables():
    rec = Recorder(sinks=[MemorySink()], clock=StreamClock())
    sink = rec.sinks[0]
    assert serve_table(list(sink.events())) is None
    for v in (2.0, 4.0):
        rec.hist("serve.ttft", v)
    rec.hist("serve.tpot", 1.0)
    rec.counter("serve.admission", 3, outcome="admitted")
    rec.counter("serve.admission", outcome="rejected", reason="too_long")
    rec.counter("serve.preempted", 2, policy="ntp")
    sv = serve_table(list(sink.events()))
    assert sv["ttft"]["count"] == 2 and sv["ttft"]["mean"] == 3.0
    assert sv["admitted"] == 3 and sv["rejected"] == 1 and sv["preempted"] == 2
    rec.counter("kernels.dispatch", kernel="rmsnorm", mode="interpret")
    rec.counter("kernels.dispatch", kernel="rmsnorm", mode="interpret")
    rec.counter("kernels.dispatch", kernel="flash_attention", mode="compiled")
    kt = kernel_table(list(sink.events()))
    assert kt["rmsnorm"] == {"compiled": 0, "interpret": 2}
    assert kt["flash_attention"]["compiled"] == 1


def test_report_omits_empty_sections_and_roundtrips(tmp_path):
    assert report([]) == {"events": 0}
    path = str(tmp_path / "run.jsonl")
    clock = StreamClock()
    rec = Recorder(sinks=[JsonlSink(path), MemorySink()], clock=clock)
    goodputs = [1.0, 0.75]
    for g in goodputs:
        with rec.span("session.step"):
            clock.t += 0.1
        rec.gauge("train.goodput", g, policy="ntp")
    rec.close()
    from repro.telemetry import load_jsonl

    doc = report(load_jsonl(path))
    assert set(doc) == {"events", "goodput"}     # no serve/kernels/transitions
    assert doc["goodput"]["ntp"]["goodput"] == pytest.approx(0.875)
    # the in-memory fold agrees with the JSONL fold exactly
    mem_doc = report(list(rec.sinks[1].events()))
    assert mem_doc == doc
