"""Property-based tests (hypothesis) for the paper's Algorithm 1 and the
reshard tables — the system's core invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dependency: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.core import shard_mapping as sm

dims = st.integers(min_value=1, max_value=32).flatmap(
    lambda n1: st.tuples(
        st.integers(min_value=max(1, n1), max_value=512),  # k >= n1
        st.just(n1),
        st.integers(min_value=1, max_value=n1),
    )
)


@settings(max_examples=200, deadline=None)
@given(dims)
def test_comp_assignment_invariants(knn):
    k, n1, n2 = knn
    comp = sm.comp_assignment(k, n1, n2)
    sync = sm.sync_assignment(k, n2)
    # every unit placed on a valid rank
    assert comp.shape == (k,)
    assert comp.min() >= 0 and comp.max() < n1
    # balanced within one unit
    counts = np.bincount(comp, minlength=n1)
    assert counts.max() - counts.min() <= 1
    # sync layout: contiguous, balanced, only ranks < n2
    scounts = np.bincount(sync, minlength=n1)
    assert (scounts[n2:] == 0).all()
    assert scounts[:n2].max() - scounts[:n2].min() <= 1
    assert (np.diff(sync) >= 0).all()  # contiguous == monotone
    # degenerate: no failures -> identical layouts (zero reshard traffic)
    if n1 == n2:
        assert (comp == sync).all()


@settings(max_examples=100, deadline=None)
@given(dims)
def test_sync_ranks_keep_prefix(knn):
    """Sync rank j computes a prefix of its own sync shard (minimal motion)."""
    k, n1, n2 = knn
    comp = sm.comp_assignment(k, n1, n2)
    sync = sm.sync_assignment(k, n2)
    target = sm.balanced_sizes(k, n1)
    for j in range(n2):
        units = np.where(sync == j)[0]
        keep = min(len(units), target[j])
        assert (comp[units[:keep]] == j).all()


@settings(max_examples=100, deadline=None)
@given(dims)
def test_offload_traffic_balanced(knn):
    """Algorithm 1's rotation: pairwise offload transfers differ by <= 1 unit
    per (sync, offload) pair... bounded by ceil-fairness across offload ranks."""
    k, n1, n2 = knn
    c = sm.comp_layout(k, n1, n2)
    s = sm.sync_layout(k, n1, n2)
    tm = sm.transfer_matrix(c, s)
    if n1 > n2:
        recv_per_offload = tm[n2:, :].sum(axis=1)
        # each offload rank relays its full comp load
        assert recv_per_offload.max() - recv_per_offload.min() <= 1


@settings(max_examples=80, deadline=None)
@given(dims)
def test_reshard_tables_roundtrip(knn):
    """pre then post tables map every unit back where it started."""
    k, n1, n2 = knn
    c, s, pre, post = sm.plan(k, n1, n2)
    buf = pre.buf
    # simulate the data movement with numpy
    src = np.full((n1, buf), -1, dtype=np.int64)
    src[:, : c.max_count] = c.slots

    def apply(tables, src_state, dst_layout):
        out = np.full((n1, buf), -1, dtype=np.int64)
        for r in range(n1):
            for t in range(buf):
                u = tables.stay_idx[r, t]
                if u != tables.pad:
                    out[r, t] = src_state[r, u]
        for r in range(n1):
            for d in range(n1):
                for m in range(tables.s_max):
                    su = tables.send_idx[r, d, m]
                    du = tables.recv_idx[d, r, m]
                    if su != tables.pad and du != tables.pad:
                        out[d, du] = src_state[r, su]
        return out

    mid = apply(pre, src, s)
    # mid must equal the sync layout
    want_mid = np.full((n1, buf), -1, dtype=np.int64)
    want_mid[:, : s.max_count] = s.slots
    assert (mid == want_mid).all()
    back = apply(post, mid, c)
    assert (back == src).all()


@settings(max_examples=100, deadline=None)
@given(dims, st.integers(min_value=1, max_value=4096))
def test_reshard_bytes_sane(knn, unit_bytes):
    k, n1, n2 = knn
    c = sm.comp_layout(k, n1, n2)
    s = sm.sync_layout(k, n1, n2)
    per_rank = sm.reshard_bytes_per_rank(c, s, unit_bytes)
    assert (per_rank >= 0).all()
    if n1 == n2:
        assert per_rank.sum() == 0
    # total moved <= everything
    assert per_rank.max() <= k * unit_bytes
