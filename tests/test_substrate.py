"""Substrate unit tests: optimizer, data pipeline, checkpointing, loss,
MoE dispatch, HLO analyzer, attention flash path."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="dev dependency: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.train.steps import cross_entropy


# ---------------------------------------------------------------------------
# optimizer

def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_master_weights_bf16():
    cfg = AdamWConfig(lr=1e-3)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert "master" in state and state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((4,), 0.01, jnp.bfloat16)}
    params, state, _ = adamw_update(grads, state, params, cfg)
    assert params["w"].dtype == jnp.bfloat16


def test_grad_clip():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros((3,))}
    state = adamw_init(params, cfg)
    _, _, m = adamw_update({"w": jnp.full((3,), 100.0)}, state, params, cfg)
    assert float(m["grad_norm"]) > 100


def test_warmup_cosine_shape():
    assert float(warmup_cosine(jnp.int32(0), warmup=10, total=100)) == 0.0
    assert abs(float(warmup_cosine(jnp.int32(10), warmup=10, total=100)) - 1.0) < 1e-6
    assert float(warmup_cosine(jnp.int32(100), warmup=10, total=100)) <= 0.11


# ---------------------------------------------------------------------------
# loss

def test_cross_entropy_matches_naive():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 5, 16)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, 12, size=(2, 5)))
    got = cross_entropy(logits, targets, 16)
    want = -jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1), targets[..., None], -1
    ).mean()
    assert abs(float(got) - float(want)) < 1e-5


def test_cross_entropy_vocab_padding_invariant():
    """Adding padded vocab columns must not change the loss."""
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(2, 5, 12)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, 12, size=(2, 5)))
    padded = jnp.concatenate(
        [logits, jnp.full((2, 5, 4), 7.7, jnp.float32)], axis=-1
    )
    a = cross_entropy(logits, targets, 12)
    b = cross_entropy(padded, targets, 12)
    assert abs(float(a) - float(b)) < 1e-5


# ---------------------------------------------------------------------------
# data pipeline

def test_pipeline_deterministic_and_learnable():
    from repro.data.pipeline import DataConfig, SyntheticLMPipeline

    cfg = DataConfig(vocab_size=101, seq_len=32, global_batch=4, seed=7)
    p1, p2 = SyntheticLMPipeline(cfg), SyntheticLMPipeline(cfg)
    b1, b2 = p1.batch(3), p2.batch(3)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # targets are the shifted stream
    arr1 = p1._batch_np(5)
    assert np.array_equal(arr1[:, 1:-1], p1._batch_np(5)[:, 1:-1])
    # mostly-deterministic transitions (noise=0.05)
    toks, tgt = arr1[:, :-1], arr1[:, 1:]
    pred = (p1.a * toks + p1.b) % cfg.vocab_size
    assert (pred == tgt).mean() > 0.85


def test_input_specs_cover_all_shapes(arch_ids):
    from repro.configs import SHAPES, get_arch
    from repro.data.pipeline import input_specs

    for aid in arch_ids:
        for sh in SHAPES.values():
            specs = input_specs(get_arch(aid), sh)
            assert "tokens" in specs
            if sh.kind == "decode":
                assert specs["tokens"].shape[1] == 1 and "pos" in specs
            if get_arch(aid).encoder is not None and sh.kind != "decode":
                assert "enc_input" in specs


# ---------------------------------------------------------------------------
# checkpoint

def test_checkpoint_roundtrip():
    from repro.checkpoint import load_checkpoint, save_checkpoint

    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
        "list": [jnp.zeros((2,)), jnp.full((2,), 3.0)],
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save_checkpoint(path, tree, step=17)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        got, step = load_checkpoint(path, like)
        assert step == 17
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            assert np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# MoE dispatch vs dense oracle

def test_moe_sort_dispatch_matches_dense():
    import dataclasses

    from repro.configs import get_arch, reduced
    from repro.models.common import NO_SHARD
    from repro.models.mlp import moe_apply, moe_apply_dense_ref, moe_init

    cfg = reduced(get_arch("arctic-480b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
    )
    p = moe_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    got, aux = moe_apply(cfg, p, x, NO_SHARD)
    want = moe_apply_dense_ref(cfg, p, x, NO_SHARD)
    assert float(jnp.abs(got - want).max()) < 1e-4
    assert float(aux["moe_aux_loss"]) >= 0


def test_moe_capacity_drops_bounded():
    """With cf=1.0 some tokens may drop but output stays finite/close."""
    import dataclasses

    from repro.configs import get_arch, reduced
    from repro.models.common import NO_SHARD
    from repro.models.mlp import moe_apply, moe_init

    cfg = reduced(get_arch("llama4-scout-17b-a16e"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0)
    )
    p = moe_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    got, _ = moe_apply(cfg, p, x, NO_SHARD)
    assert bool(jnp.isfinite(got).all())


# ---------------------------------------------------------------------------
# flash (jnp double-scan) path == naive path

def test_attention_flash_path_matches_naive():
    from repro.configs import get_arch, reduced
    from repro.models import attention as am
    from repro.models.common import NO_SHARD

    cfg = reduced(get_arch("gemma2-9b"))  # softcap + sliding window coverage
    p = am.attn_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 2048, cfg.d_model)) * 0.3

    for kind in ("attn", "attn_sw"):
        naive, _ = am.attn_apply(cfg, p, x, kind=kind, ctx=NO_SHARD)
        old = am.FLASH_SEQ_THRESHOLD
        am.FLASH_SEQ_THRESHOLD = 1024  # force the blockwise path
        try:
            flash, _ = am.attn_apply(cfg, p, x, kind=kind, ctx=NO_SHARD)
        finally:
            am.FLASH_SEQ_THRESHOLD = old
        err = float(jnp.abs(naive - flash).max())
        assert err < 2e-4, (kind, err)


# ---------------------------------------------------------------------------
# HLO analyzer

def test_hlo_analyzer_exact_matmul():
    from repro.launch.hlo_analysis import analyze_hlo

    a = jnp.zeros((256, 512), jnp.float32)
    b = jnp.zeros((512, 128), jnp.float32)
    txt = jax.jit(lambda a, b: a @ b).lower(a, b).compile().as_text()
    r = analyze_hlo(txt)
    assert r["flops"] == 2 * 256 * 512 * 128


def test_hlo_analyzer_scan_trip_count():
    from repro.launch.hlo_analysis import analyze_hlo

    b0 = jnp.eye(128)

    def g(a):
        return jax.lax.scan(lambda c, _: (c @ b0, None), a, None, length=7)[0]

    txt = jax.jit(g).lower(jnp.zeros((128, 128))).compile().as_text()
    r = analyze_hlo(txt)
    want = 2 * 128**3 * 7
    assert want <= r["flops"] < want * 1.02
    assert r["unknown_trip_count_loops"] == 0
