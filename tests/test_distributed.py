"""Multi-device tests: each launches a subprocess with 8 fake CPU devices
(XLA_FLAGS must be set before jax import — never globally, per the brief).
The ``run_dist`` fixture lives in conftest.py."""
import pytest


@pytest.mark.slow
def test_reshard_collective_roundtrip_and_sync(run_dist):
    out = run_dist("reshard_roundtrip.py")
    assert "RESHARD_OK" in out


@pytest.mark.slow
def test_ntp_training_equivalence(run_dist):
    """The paper's core claim, end to end: nonuniform (TP4 + TP3) replicas
    with reshard-synced gradients train identically to a dense reference."""
    out = run_dist("ntp_equivalence.py")
    assert "NTP_EQUIV_OK" in out


@pytest.mark.slow
def test_sharded_training_and_decode(run_dist):
    out = run_dist("sharded_train.py")
    assert "SHARDED_TRAIN_OK" in out


@pytest.mark.slow
def test_ntp_moe_expert_units_equivalence(run_dist):
    """DESIGN.md §4 executable: NTP with the EXPERT as the partition unit —
    degraded TP4→TP3 MoE training == dense reference (router included)."""
    out = run_dist("ntp_moe_equivalence.py")
    assert "NTP_MOE_OK" in out


@pytest.mark.slow
def test_moe_expert_parallel_matches_oracle(run_dist):
    """§Perf A1: the all-to-all expert-parallel dispatch is numerically
    identical to the dense oracle (drop-free capacity)."""
    out = run_dist("moe_expert_parallel.py")
    assert "MOE_EP_OK" in out


@pytest.mark.slow
def test_session_overlap_pp_lifecycle(run_dist):
    """ISSUE 9 acceptance: the overlapped bucketed sync (core/overlap)
    matches overlap-off to f32 tolerance AND the dense reference through
    stage-addressed fail -> repair chains, with strictly fewer collective
    launches at every plan state."""
    out = run_dist("session_overlap_pp.py")
    assert "SESSION_OVERLAP_PP_OK" in out


@pytest.mark.slow
def test_session_overlap_submesh_pp_exact(run_dist):
    """Bucketing commutes exactly on the measured submesh path: overlap-on
    equals overlap-off value-for-value through fail -> repair."""
    out = run_dist("session_overlap_submesh_pp.py", devices=16)
    assert "SESSION_OVERLAP_SUBMESH_PP_OK" in out


@pytest.mark.slow
def test_session_lifecycle_fail_boost_repair(run_dist):
    """ISSUE 2 acceptance: a scripted fail -> boost -> repair trace replayed
    through NTPSession (via TraceRunner) matches the dense uniform reference
    to f32 exactness at every step, including after RecoveryEvents restore
    TP to full — with both plain-NTP/SGD and NTP-PW/AdamW policies."""
    out = run_dist("session_lifecycle.py")
    assert "SESSION_LIFECYCLE_OK" in out


@pytest.mark.slow
def test_session_mixed_lifecycle_taxonomy(run_dist):
    """ISSUE 10 acceptance: a mixed straggler + link + SDC trace replayed
    through NTPSession matches the dense reference to f32 exactness end to
    end — including through the SDC quarantine -> canonical rollback — with
    sgd/ntp, adamw/ntp_pw, and quarantine=off (ledger-only) phases."""
    out = run_dist("session_mixed_lifecycle.py")
    assert "SESSION_MIXED_LIFECYCLE_OK" in out
