"""Multi-device tests: each launches a subprocess with 8 fake CPU devices
(XLA_FLAGS must be set before jax import — never globally, per the brief)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def run_dist(script: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist", script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"{script} failed:\n{r.stdout}\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_reshard_collective_roundtrip_and_sync():
    out = run_dist("reshard_roundtrip.py")
    assert "RESHARD_OK" in out


@pytest.mark.slow
def test_ntp_training_equivalence():
    """The paper's core claim, end to end: nonuniform (TP4 + TP3) replicas
    with reshard-synced gradients train identically to a dense reference."""
    out = run_dist("ntp_equivalence.py")
    assert "NTP_EQUIV_OK" in out


@pytest.mark.slow
def test_sharded_training_and_decode():
    out = run_dist("sharded_train.py")
    assert "SHARDED_TRAIN_OK" in out


@pytest.mark.slow
def test_ntp_moe_expert_units_equivalence():
    """DESIGN.md §4 executable: NTP with the EXPERT as the partition unit —
    degraded TP4→TP3 MoE training == dense reference (router included)."""
    out = run_dist("ntp_moe_equivalence.py")
    assert "NTP_MOE_OK" in out


@pytest.mark.slow
def test_moe_expert_parallel_matches_oracle():
    """§Perf A1: the all-to-all expert-parallel dispatch is numerically
    identical to the dense oracle (drop-free capacity)."""
    out = run_dist("moe_expert_parallel.py")
    assert "MOE_EP_OK" in out
