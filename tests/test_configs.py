"""Config registry + derived-quantity sanity."""
import pytest

from repro.configs import ARCH_IDS, SHAPES, all_archs, get_arch, get_shape, reduced

EXPECTED = {
    "llama4-scout-17b-a16e": dict(n_layers=48, d_model=5120, n_heads=40,
                                  n_kv_heads=8, d_ff=8192, vocab_size=202048),
    "qwen2-7b": dict(n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
                     d_ff=18944, vocab_size=152064),
    "whisper-small": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                          d_ff=3072, vocab_size=51865),
    "mamba2-780m": dict(n_layers=48, d_model=1536, d_ff=0, vocab_size=50280),
    "recurrentgemma-9b": dict(n_layers=38, d_model=4096, n_heads=16,
                              n_kv_heads=1, d_ff=12288, vocab_size=256000),
    "gemma2-9b": dict(n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
                      d_ff=14336, vocab_size=256000),
    "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
                        d_ff=4864, vocab_size=32000),
    "granite-3-2b": dict(n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
                         d_ff=8192, vocab_size=49155),
    "chameleon-34b": dict(n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
                          d_ff=22016, vocab_size=65536),
    "minitron-4b": dict(n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
                        d_ff=9216, vocab_size=256000),
}


def test_all_ten_archs_registered():
    assert set(EXPECTED) == set(ARCH_IDS)
    assert len(ARCH_IDS) == 10


@pytest.mark.parametrize("aid", sorted(EXPECTED))
def test_assigned_dimensions(aid):
    cfg = get_arch(aid)
    for k, v in EXPECTED[aid].items():
        assert getattr(cfg, k) == v, (aid, k)
    assert cfg.citation


@pytest.mark.parametrize("aid", sorted(EXPECTED))
def test_padded_vocab_tp_divisible(aid):
    assert get_arch(aid).padded_vocab() % 16 == 0


def test_param_counts_plausible():
    # headline parameter counts within 20% of the model cards
    approx = {
        "qwen2-7b": 7.6e9, "gemma2-9b": 9.2e9, "granite-3-2b": 2.5e9,
        "chameleon-34b": 34e9, "minitron-4b": 4.2e9, "mamba2-780m": 0.78e9,
        "recurrentgemma-9b": 9.6e9, "arctic-480b": 482e9,
    }
    for aid, want in approx.items():
        got = get_arch(aid).n_params()
        assert 0.75 * want < got < 1.35 * want, (aid, got, want)


def test_moe_active_params():
    llama4 = get_arch("llama4-scout-17b-a16e")
    assert llama4.n_active_params() < 0.3 * llama4.n_params()
    arctic = get_arch("arctic-480b")
    assert arctic.n_active_params() < 0.1 * arctic.n_params()


def test_shapes_registry():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert get_shape("long_500k").seq_len == 524_288
    assert get_shape("train_4k").kind == "train"


@pytest.mark.parametrize("aid", sorted(EXPECTED))
def test_reduced_is_small(aid):
    r = reduced(get_arch(aid))
    assert r.d_model <= 512 and r.n_layers <= 4
    if r.moe is not None:
        assert r.moe.n_experts <= 4
    assert r.layer_pattern == get_arch(aid).layer_pattern  # same family
