"""`repro.telemetry` core — recorder / sinks / export unit tests (ISSUE 8).

Host-side, no mesh, deterministic via an injected clock. What's pinned:

* the FIXED per-kind event schema (`EVENT_KEYS`) — every emitted event
  carries exactly those keys, nothing else (the JSONL stream is a contract
  the offline report and the Perfetto exporter both parse);
* counter totals per labeled series, span marks/attrs, and span emission
  on ``__exit__`` even when an exception propagates (transition spans must
  survive a `DeadReplicaError` raised mid-apply);
* the NULL recorder off path: ``enabled`` False, zero allocation (one
  reusable span singleton), every method a no-op;
* scoped activation (`recording`) restore, exception-safe;
* `JsonlSink` lazy open + `load_jsonl` round-trip (+ corrupt-line error
  with a line number), `MemorySink` ring bounds and label-subset queries;
* the Chrome-trace mapping (span → ph "X" µs rows, gauge/counter →
  ph "C" tracks on the running total, hist skipped, one tid per dotted
  subsystem prefix).
"""
import json

import pytest

from repro import telemetry
from repro.telemetry import (
    EVENT_KEYS, EVENT_KINDS, JsonlSink, MemorySink, NULL, NullRecorder,
    Recorder, chrome_trace, load_jsonl, summarize_hist, write_chrome_trace,
)


class FakeClock:
    """Deterministic monotonic clock: advances only when told to."""

    def __init__(self):
        self.t = 100.0  # nonzero start: t0-relative timestamps must subtract

    def __call__(self):
        return self.t

    def tick(self, dt=1.0):
        self.t += dt


def make_rec():
    clock = FakeClock()
    sink = MemorySink()
    return Recorder(sinks=[sink], clock=clock), sink, clock


# ---------------------------------------------------------------------------
# event schema

def test_event_schema_is_fixed():
    rec, sink, clock = make_rec()
    rec.counter("c", 2, a="x")
    rec.gauge("g", 0.5, b="y")
    rec.hist("h", 3.0)
    with rec.span("s", c="z") as sp:
        clock.tick()
        sp.set(k=1).mark("phase")
    evs = {e["kind"]: e for e in sink.events()}
    assert set(evs) == set(EVENT_KINDS)
    for kind, ev in evs.items():
        assert tuple(sorted(ev)) == tuple(sorted(EVENT_KEYS[kind])), kind


def test_timestamps_are_recorder_relative():
    rec, sink, clock = make_rec()
    clock.tick(5.0)
    rec.gauge("g", 1.0)
    assert sink.events()[0]["t"] == 5.0   # not the raw clock's 105.0


def test_counter_totals_per_labeled_series():
    rec, sink, _ = make_rec()
    assert rec.counter("n", a="x") == 1
    assert rec.counter("n", 2, a="x") == 3
    assert rec.counter("n", a="y") == 1           # distinct series
    assert rec.total("n", a="x") == 3
    assert rec.total("n", a="y") == 1
    assert rec.total("never") == 0
    # every increment carries the running total (stream cut-anywhere safety)
    assert [e["total"] for e in sink.events(name="n", a="x")] == [1, 3]


def test_span_marks_attrs_and_duration():
    rec, sink, clock = make_rec()
    with rec.span("work", stage="0") as sp:
        clock.tick(2.0)
        sp.mark("planned")
        clock.tick(3.0)
        sp.set(bytes_moved=1024)
    (ev,) = sink.spans("work")
    assert ev["dur"] == 5.0
    assert ev["labels"] == {"stage": "0"}
    assert ev["attrs"]["bytes_moved"] == 1024
    assert ev["attrs"]["marks"] == {"planned": 2.0}


def test_span_emits_when_exception_propagates():
    """A transition span must land in the stream even when apply() raises
    (DeadReplicaError mid-span is the 'rejected' bucket in the report)."""
    rec, sink, clock = make_rec()
    with pytest.raises(RuntimeError):
        with rec.span("session.transition", kind="failure") as sp:
            sp.mark("planned")
            clock.tick()
            raise RuntimeError("replica dead")
    (ev,) = sink.spans("session.transition")
    assert ev["dur"] == 1.0
    assert "changed" not in ev["attrs"]  # never finished -> rejected bucket


# ---------------------------------------------------------------------------
# null recorder (the off path)

def test_null_recorder_is_inert():
    assert NULL.enabled is False
    assert isinstance(NULL, NullRecorder)
    assert NULL.counter("x") == 0
    assert NULL.gauge("x", 1.0) is None
    assert NULL.hist("x", 1.0) is None
    assert NULL.total("x") == 0
    # one reusable singleton span: no per-call allocation on the off path
    s1, s2 = NULL.span("a"), NULL.span("b", k="v")
    assert s1 is s2
    with NULL.span("x") as sp:
        assert sp.set(a=1) is sp
        assert sp.mark("p") is sp


def test_get_defaults_to_null_and_recording_restores():
    assert telemetry.get() is NULL
    rec, sink, _ = make_rec()
    with telemetry.recording(rec):
        assert telemetry.get() is rec
        telemetry.get().gauge("g", 1.0)
    assert telemetry.get() is NULL
    assert len(sink) == 1
    # exception-safe restore
    with pytest.raises(ValueError):
        with telemetry.recording(rec):
            raise ValueError
    assert telemetry.get() is NULL
    # recording(None) scopes telemetry OFF
    with telemetry.recording(rec):
        with telemetry.recording(None):
            assert telemetry.get() is NULL
        assert telemetry.get() is rec


def test_configure_and_shutdown(tmp_path):
    path = str(tmp_path / "t.jsonl")
    rec = telemetry.configure(jsonl=path, memory=True)
    try:
        assert telemetry.get() is rec
        rec.gauge("g", 2.0)
    finally:
        telemetry.shutdown()
    assert telemetry.get() is NULL
    evs = load_jsonl(path)
    assert [e["value"] for e in evs] == [2.0]


# ---------------------------------------------------------------------------
# sinks

def test_jsonl_sink_lazy_open_and_roundtrip(tmp_path):
    path = tmp_path / "out.jsonl"
    rec = Recorder(sinks=[JsonlSink(str(path))], clock=FakeClock())
    assert not path.exists()          # configuring never creates empty files
    rec.counter("c", a="x")
    with rec.span("s"):
        pass
    rec.close()
    evs = load_jsonl(str(path))
    assert [e["kind"] for e in evs] == ["counter", "span"]
    assert evs[0]["labels"] == {"a": "x"}
    # compact separators: no spaces after , or :
    raw = path.read_text().splitlines()[0]
    assert ", " not in raw and ": " not in raw


def test_load_jsonl_names_corrupt_line(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"kind":"gauge"}\n\nnot json\n')
    with pytest.raises(ValueError, match=r"bad\.jsonl:3"):
        load_jsonl(str(path))


def test_memory_sink_ring_and_queries():
    sink = MemorySink(maxlen=3)
    rec = Recorder(sinks=[sink], clock=FakeClock())
    for i in range(5):
        rec.gauge("g", float(i), run="a" if i % 2 == 0 else "b")
    assert len(sink) == 3             # oldest dropped
    assert sink.values("g") == [2.0, 3.0, 4.0]
    assert sink.values("g", run="b") == [3.0]   # label-subset match
    assert sink.values("missing") == []
    with rec.span("sp", run="a"):
        pass
    assert sink.durations("sp", run="a") == [0.0]
    sink.clear()
    assert len(sink) == 0


def test_recorder_queries_require_memory_sink():
    rec = Recorder(sinks=[])
    with pytest.raises(LookupError, match="MemorySink"):
        rec.values("g")


# ---------------------------------------------------------------------------
# export

def test_chrome_trace_mapping():
    rec, sink, clock = make_rec()
    with rec.span("session.step", pp=1) as sp:
        clock.tick(0.002)
        sp.set(bytes_moved=64)
    rec.counter("kernels.dispatch", kernel="rmsnorm")
    rec.counter("kernels.dispatch", kernel="rmsnorm")
    rec.gauge("train.goodput", 0.75, policy="ntp")
    rec.hist("serve.ttft", 3.0)
    doc = chrome_trace(sink.events())
    rows = doc["traceEvents"]
    meta = [r for r in rows if r["ph"] == "M"]
    spans = [r for r in rows if r["ph"] == "X"]
    counters = [r for r in rows if r["ph"] == "C"]
    # one swimlane per dotted subsystem prefix
    assert {m["args"]["name"] for m in meta} == {"session"}
    (sp_row,) = spans
    assert sp_row["name"] == "session.step"
    assert sp_row["dur"] == pytest.approx(2000.0)       # µs
    assert sp_row["args"] == {"pp": 1, "bytes_moved": 64}
    # counters plot the RUNNING TOTAL; labels fold into the track name
    tracks = {r["name"]: r["args"]["value"] for r in counters}
    assert tracks["kernels.dispatch{kernel=rmsnorm}"] == 2  # last total wins
    assert tracks["train.goodput{policy=ntp}"] == 0.75
    # hist events have no Chrome-trace counterpart
    assert not any("ttft" in r["name"] for r in rows)


def test_write_chrome_trace_is_loadable(tmp_path):
    rec, sink, clock = make_rec()
    with rec.span("a.b"):
        clock.tick()
    path = tmp_path / "trace.json"
    doc = write_chrome_trace(str(path), sink.events())
    with open(path) as f:
        loaded = json.load(f)
    assert loaded == doc
    assert loaded["displayTimeUnit"] == "ms"


def test_summarize_hist():
    assert summarize_hist([]) is None
    s = summarize_hist([1.0, 2.0, 3.0, 4.0])
    assert s["count"] == 4 and s["mean"] == 2.5 and s["max"] == 4.0
    assert s["p50"] == 2.5
