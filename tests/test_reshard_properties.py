"""Reshard-table properties (ISSUE 2 satellite), host-side: the collective
`core.reshard.reshard` is a rank-local gather + one tiled all-to-all + a
scatter, all driven by static tables — so its semantics can be emulated
exactly in numpy (recv_r[j] = send_j[r]) and property-checked without a
mesh. The live 8-device collective is covered by tests/dist/.

Checked here, per plan and weight:
* reshard(pre) ∘ reshard(post) is the identity on packed unit buffers;
* `ntp_sync_gradient` (pre → psum(data) → post) equals the plain
  `uniform_sync_gradient` DP all-reduce on a pristine plan;
* zero-pad slots never leak: garbage planted in pad slots does not reach any
  output, and output pad slots are always zero.
"""
import numpy as np
import pytest

from repro.core import nonuniform as nu
from repro.core.nonuniform import FailurePlan

PLANS = [
    FailurePlan(n1=4, replica_tp=(4, 4)),
    FailurePlan(n1=4, replica_tp=(3, 4)),
    FailurePlan(n1=4, replica_tp=(2, 4)),
    FailurePlan(n1=4, replica_tp=(1, 4)),
    FailurePlan(n1=4, replica_tp=(2, 3)),
    FailurePlan(n1=2, replica_tp=(1, 2, 2)),
]
KS = (4, 8, 11)


def emulate_reshard(x_ranks: np.ndarray, tables: nu.StackedTables,
                    replica: int) -> np.ndarray:
    """Numpy twin of core.reshard.reshard for one replica: x_ranks is the
    (n, U, ...) stack of every rank's local buffer."""
    n, U = x_ranks.shape[:2]
    send = np.asarray(tables.send_idx)[replica]   # (n, n, s_max)
    recv = np.asarray(tables.recv_idx)[replica]   # (n, n, s_max)
    stay = np.asarray(tables.stay_idx)[replica]   # (n, U)
    pad = tables.buf
    assert U == tables.buf, (U, tables.buf)

    zero_row = np.zeros((n, 1) + x_ranks.shape[2:], x_ranks.dtype)
    xp = np.concatenate([x_ranks, zero_row], axis=1)      # index U -> zeros
    send_buf = np.stack([xp[r][send[r]] for r in range(n)])   # (n, n, s_max, ...)
    # tiled all-to-all over the model axis: recv_r[j] = send_j[r]
    recv_buf = np.stack([send_buf[:, r] for r in range(n)])   # (n, n, s_max, ...)

    out = np.empty_like(x_ranks)
    for r in range(n):
        o = xp[r][stay[r]].copy()                         # stays (pad -> zero)
        flat = recv_buf[r].reshape((-1,) + recv_buf.shape[3:])
        slots = recv[r].reshape(-1)
        keep = slots != pad                               # mode="drop"
        o[slots[keep]] = flat[keep]
        out[r] = o
    return out


def _rank_buffers(wp: nu.WeightPlan, w: np.ndarray, unit: int):
    """(D, n1, buf, cols) per-rank packed buffers of canonical weight w."""
    packed = nu.pack_global(w, wp, unit)
    d, n1 = wp.comp_slots.shape[:2]
    return packed.reshape(d, n1, wp.buf, -1)


@pytest.mark.parametrize("plan", PLANS, ids=str)
@pytest.mark.parametrize("k", KS)
def test_pre_post_reshard_is_identity(plan, k):
    if k < plan.n1:
        pytest.skip("k >= n1 required")
    wp = nu.weight_plan(k, plan)
    rng = np.random.default_rng(0)
    w = rng.standard_normal((k, 5)).astype(np.float32)
    bufs = _rank_buffers(wp, w, 1)
    for d in range(plan.d):
        synced = emulate_reshard(bufs[d], wp.pre, d)
        back = emulate_reshard(synced, wp.post, d)
        assert np.array_equal(back, bufs[d]), (plan, k, d)


@pytest.mark.parametrize("k", KS)
def test_ntp_sync_equals_uniform_sync_on_pristine_plan(k):
    """Healthy plan: pre/post tables degenerate to the identity, so the NTP
    sync pipeline reduces to exactly the uniform DP all-reduce."""
    plan = FailurePlan(n1=4, replica_tp=(4, 4, 4))
    wp = nu.weight_plan(k, plan)
    rng = np.random.default_rng(1)
    grads = [rng.standard_normal((k, 3)).astype(np.float32)
             for _ in range(plan.d)]
    bufs = np.stack([_rank_buffers(wp, g, 1)[d]
                     for d, g in enumerate(grads)])      # (D, n1, buf, 3)

    uniform = bufs.sum(axis=0)                           # psum('data')

    pre = np.stack([emulate_reshard(bufs[d], wp.pre, d)
                    for d in range(plan.d)])
    summed = pre.sum(axis=0)
    ntp = np.stack([emulate_reshard(summed, wp.post, d)
                    for d in range(plan.d)])
    for d in range(plan.d):
        assert np.array_equal(ntp[d], uniform), (k, d)


@pytest.mark.parametrize("plan", PLANS, ids=str)
def test_pad_slots_never_leak(plan):
    """Plant NaN garbage in every pad slot of the input buffers: outputs must
    be identical to the clean run, and output pad slots must be zero."""
    k = 8
    if k < plan.n1:
        pytest.skip("k >= n1 required")
    wp = nu.weight_plan(k, plan)
    rng = np.random.default_rng(2)
    w = rng.standard_normal((k, 4)).astype(np.float32)
    bufs = _rank_buffers(wp, w, 1)

    comp_pad = wp.comp_slots < 0                         # (D, n1, buf)
    sync_pad = wp.sync_slots < 0
    dirty = bufs.copy()
    dirty[comp_pad] = np.nan

    for d in range(plan.d):
        clean_sync = emulate_reshard(bufs[d], wp.pre, d)
        dirty_sync = emulate_reshard(dirty[d], wp.pre, d)
        assert np.array_equal(clean_sync, dirty_sync), (plan, d)
        assert (clean_sync[sync_pad[d]] == 0).all(), (plan, d)

        clean_back = emulate_reshard(clean_sync, wp.post, d)
        dirty_in = clean_sync.copy()
        dirty_in[sync_pad[d]] = np.nan
        dirty_back = emulate_reshard(dirty_in, wp.post, d)
        assert np.array_equal(clean_back, dirty_back), (plan, d)
        assert (clean_back[comp_pad[d]] == 0).all(), (plan, d)
