"""Property tests for the serving KV-cache reshard (ISSUE 3 satellite),
mirroring `tests/test_reshard_properties.py`'s treatment of the weight
reshard: the head-redistribution all-to-all is static-table-driven, so its
semantics are checked exactly host-side on random head layouts.

* shard ∘ gather is the identity on dense cache contents;
* any chain of TP transitions (degrade AND restore) preserves the gathered
  cache bit-exactly, and returning to the starting degree restores the
  sharded buffers bit-exactly (pre ∘ post identity);
* pad slots stay exact zeros through every transition, and NaN garbage
  planted in pad slots never reaches the attention output (rank-local
  evaluation == dense evaluation, bitwise);
* the Pallas `reshard_pack` send-bucket gather matches the jnp path.
"""
import numpy as np
import pytest

pytestmark = pytest.mark.serve

pytest.importorskip("hypothesis", reason="dev dependency: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.serve import kv_shard as kvs

HD = 4


@st.composite
def shard_case(draw):
    n1 = draw(st.integers(1, 5))
    kvh = draw(st.integers(1, 8))
    tps = draw(st.lists(st.integers(1, n1), min_size=1, max_size=4))
    seed = draw(st.integers(0, 2**16))
    return n1, kvh, tps, seed


def _dense(rng, kvh, b=2, t=3):
    return jnp.asarray(rng.normal(size=(b, t, kvh, HD)), jnp.float32)


def _pads(layout, buf):
    return kvs.slots_at(layout, buf) < 0


@settings(max_examples=40, deadline=None)
@given(shard_case())
def test_reshard_chain_preserves_cache_and_pads(case):
    n1, kvh, tps, seed = case
    rng = np.random.default_rng(seed)
    dense = _dense(rng, kvh)

    tp0 = n1
    layout = kvs.head_layout(kvh, tp0, n1)
    x0 = kvs.shard_leaf(dense, layout, kvh)
    assert np.array_equal(np.asarray(kvs.gather_leaf(x0, layout)),
                          np.asarray(dense))

    x, tp = x0, tp0
    for new_tp in tps + [tp0]:           # ... and back to the start
        tables = kvs.head_reshard_tables(kvh, tp, new_tp, n1)
        x = kvs.reshard_leaf(x, tables)
        tp = new_tp
        layout = kvs.head_layout(kvh, tp, n1)
        # degrade/restore chains are content-identities on the dense view
        assert np.array_equal(
            np.asarray(kvs.gather_leaf(x, layout)), np.asarray(dense)
        ), (n1, kvh, tps, tp)
        # pad slots are exact zeros after every hop
        assert (np.asarray(x)[_pads(layout, kvh)] == 0).all(), (n1, kvh, tp)
    # pre ∘ post identity on the sharded buffers themselves
    assert np.array_equal(np.asarray(x), np.asarray(x0)), (n1, kvh, tps)


@settings(max_examples=40, deadline=None)
@given(shard_case())
def test_pad_slots_never_leak_into_attention(case):
    """NaN garbage planted in every pad slot of the sharded K/V buffers must
    not perturb a single output bit: the rank-local evaluation
    (`attend_from_sharded`) equals the dense `attend_heads` exactly."""
    n1, kvh, tps, seed = case
    tp = tps[0]
    rng = np.random.default_rng(seed)
    b, t, g, sq = 2, 4, 2, 4
    q = jnp.asarray(rng.normal(size=(b, kvh, g, sq, HD)), jnp.float32)
    k = _dense(rng, kvh, b, t)
    v = _dense(rng, kvh, b, t)
    mask = jnp.tril(jnp.ones((sq, t), bool))

    layout = kvs.head_layout(kvh, tp, n1)
    pads = _pads(layout, kvh)
    sk = np.array(kvs.shard_leaf(k, layout, kvh))
    sv = np.array(kvs.shard_leaf(v, layout, kvh))
    sk[pads] = np.nan
    sv[pads] = np.nan

    dense = np.asarray(kvs.attend_heads(q, k, v, mask))
    shard = np.asarray(kvs.attend_from_sharded(
        q, jnp.asarray(sk), jnp.asarray(sv), layout, mask
    ))
    assert np.isfinite(shard).all(), (n1, kvh, tp)
    assert np.array_equal(dense, shard), (n1, kvh, tp)


@settings(max_examples=20, deadline=None)
@given(shard_case())
def test_sharded_kv_container_roundtrip(case):
    """`ShardedKV` over a model-shaped cache pytree: insert/gather/update/
    apply_tp keep the dense view consistent."""
    n1, kvh, tps, seed = case
    rng = np.random.default_rng(seed)
    cache = {
        "layers": {"k": _dense(rng, kvh), "v": _dense(rng, kvh)},
        "tail": ({"k": _dense(rng, kvh), "v": _dense(rng, kvh)},),
    }
    skv = kvs.ShardedKV(cache, kvh, n1)
    for new_tp in tps:
        st_ = skv.apply_tp(new_tp)
        assert st_["tp_to"] == new_tp and skv.tp == new_tp
    got = skv.gather()
    for a, b in zip(
        np.asarray(got["layers"]["k"]), np.asarray(cache["layers"]["k"])
    ):
        assert np.array_equal(a, b)
    # update re-scatters into the CURRENT layout
    bumped = {k2: (v2 if k2 != "tail" else v2) for k2, v2 in got.items()}
    skv.update(bumped)
    got2 = skv.gather()
    assert np.array_equal(np.asarray(got2["tail"][0]["v"]),
                          np.asarray(cache["tail"][0]["v"]))


def test_reshard_kernel_path_matches_jnp():
    """use_kernel=True routes the send-bucket gather through the Pallas
    `kernels.reshard_pack` kernel (interpret mode on CPU) — same bits."""
    rng = np.random.default_rng(7)
    kvh, n1 = 6, 4
    dense = _dense(rng, kvh)
    layout = kvs.head_layout(kvh, n1, n1)
    x = kvs.shard_leaf(dense, layout, kvh)
    tables = kvs.head_reshard_tables(kvh, n1, 2, n1)
    a = kvs.reshard_leaf(x, tables, use_kernel=False)
    b = kvs.reshard_leaf(x, tables, use_kernel=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_sharded_kv_rejects_non_kv_leaves():
    with pytest.raises(ValueError, match="k/v leaves only"):
        kvs.ShardedKV({"h": jnp.zeros((2, 3, 4, HD))}, 4, 4)
