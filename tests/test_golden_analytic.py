"""Golden regression tests for the analytic layer (ISSUE 2 satellite): pin
`table1_settings`, `throughput_loss_curve` (small spec, fixed seed) and
`steady_state_failed_fraction` to checked-in JSON so policy/power refactors
cannot silently drift from the paper's Table 1 / Figs. 6-7 calibration.

On mismatch the freshly-computed values are written next to the golden file
as ``analytic_golden.actual.json`` (uploaded as a CI artifact) so the diff is
inspectable. To intentionally re-pin after a calibrated change:

    PYTHONPATH=src python tests/test_golden_analytic.py --regen
"""
import json
import os

import pytest

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_PATH = os.path.join(GOLDEN_DIR, "analytic_golden.json")
ACTUAL_PATH = os.path.join(GOLDEN_DIR, "analytic_golden.actual.json")
REL_TOL = 1e-6


def compute_golden():
    """Everything pinned, computed fresh. Must stay cheap (< a few s) and
    fully deterministic (fixed seeds, closed-form elsewhere)."""
    from repro.core.availability import ClusterSpec
    from repro.core.failure_model import (
        FailureTraceConfig, steady_state_failed_fraction,
    )
    from repro.core.policies import table1_settings, throughput_loss_curve
    from repro.serve import serving_goodput_trace

    spec = ClusterSpec(n_gpus=4096, domain_size=32, domains_per_replica=4)
    curve = throughput_loss_curve(
        spec, [1e-3, 2e-3, 4e-3], samples=4, seed=0
    )
    serve_trace = FailureTraceConfig(
        n_gpus=spec.n_gpus, domain_size=spec.domain_size, days=5.0,
        rate_multiplier=50.0, seed=1,
    )
    return {
        "table1_settings": table1_settings(),
        "throughput_loss_curve": {
            "spec": {"n_gpus": spec.n_gpus, "domain_size": spec.domain_size,
                     "domains_per_replica": spec.domains_per_replica},
            "failed_fractions": [1e-3, 2e-3, 4e-3],
            "samples": 4,
            "seed": 0,
            "curves": curve,
        },
        "steady_state_failed_fraction": {
            "rate_1x": steady_state_failed_fraction(FailureTraceConfig()),
            "rate_3x": steady_state_failed_fraction(
                FailureTraceConfig(rate_multiplier=3.0)
            ),
        },
        # ISSUE 3: the analytic serving-goodput curve (repro.serve.router) —
        # trace-mean decode goodput + SLO attainment per policy on a small
        # hot trace (50× rate so the 4096-GPU spec sees real degradation)
        "serving_goodput": {
            "spec": {"n_gpus": spec.n_gpus, "domain_size": spec.domain_size,
                     "domains_per_replica": spec.domains_per_replica},
            "trace": {"days": 5.0, "rate_multiplier": 50.0, "seed": 1},
            "curves": serving_goodput_trace(spec, serve_trace),
        },
    }


def _flatten(prefix, obj, out):
    if isinstance(obj, dict):
        for k in sorted(obj):
            _flatten(f"{prefix}.{k}", obj[k], out)
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            _flatten(f"{prefix}[{i}]", v, out)
    else:
        out[prefix] = obj


def test_analytic_layer_matches_golden():
    assert os.path.exists(GOLDEN_PATH), (
        f"missing golden file {GOLDEN_PATH}; generate it with "
        "PYTHONPATH=src python tests/test_golden_analytic.py --regen"
    )
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    actual = compute_golden()

    want, got = {}, {}
    _flatten("golden", golden, want)
    _flatten("golden", actual, got)

    mismatches = []
    for key in sorted(set(want) | set(got)):
        if key not in want or key not in got:
            mismatches.append(f"{key}: only in "
                              f"{'golden' if key in want else 'actual'}")
            continue
        w, g = want[key], got[key]
        if isinstance(w, float) or isinstance(g, float):
            ok = g == pytest.approx(w, rel=REL_TOL, abs=1e-12)
        else:
            ok = w == g
        if not ok:
            mismatches.append(f"{key}: golden {w!r} != actual {g!r}")

    if mismatches:
        with open(ACTUAL_PATH, "w") as f:
            json.dump(actual, f, indent=2, sort_keys=True)
        pytest.fail(
            "analytic layer drifted from the paper-calibrated golden values "
            f"({len(mismatches)} mismatches; fresh values written to "
            f"{ACTUAL_PATH}):\n  " + "\n  ".join(mismatches[:20])
        )


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(compute_golden(), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN_PATH}")
    else:
        print(__doc__)
