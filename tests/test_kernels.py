"""Pallas kernel validation: sweep shapes/dtypes/mask-kinds, allclose
against the pure-jnp oracles in kernels/ref.py (interpret mode on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 3e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kvh,s,d,bq,bk",
    [
        (1, 2, 1, 128, 64, 64, 64),
        (2, 4, 2, 256, 64, 64, 128),
        (1, 8, 8, 256, 128, 128, 256),   # MHA
        (2, 4, 1, 512, 32, 256, 512),    # MQA
    ],
)
@pytest.mark.parametrize("kind", ["causal", "sliding", "chunked", "bidir"])
def test_flash_attention(b, h, kvh, s, d, bq, bk, kind, dtype):
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, kvh, s, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, kvh, s, d)), dtype)
    kw = dict(window=96, chunk=128)
    got = ops.flash_attention(q, k, v, kind=kind, block_q=bq, block_k=bk, **kw)
    want = ref.flash_attention_ref(q, k, v, kind=kind, **kw)
    err = np.abs(np.asarray(got, np.float32) - np.asarray(want, np.float32)).max()
    assert err < _tol(dtype), (kind, dtype, err)


def test_flash_attention_softcap():
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    got = ops.flash_attention(q, k, v, kind="causal", softcap=30.0,
                              block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, kind="causal", softcap=30.0)
    assert np.abs(np.asarray(got) - np.asarray(want)).max() < 3e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,br", [(256, 128, 64), (512, 384, 256), (128, 512, 128)])
@pytest.mark.parametrize("plus_one", [False, True])
def test_rmsnorm(n, d, br, plus_one, dtype):
    x = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    w = jnp.asarray(RNG.normal(size=(d,)) * 0.1, dtype)
    got = ops.rmsnorm(x, w, plus_one=plus_one, block_rows=br)
    want = ref.rmsnorm_ref(x, w, plus_one=plus_one)
    err = np.abs(np.asarray(got, np.float32) - np.asarray(want, np.float32)).max()
    assert err < _tol(dtype)


@pytest.mark.parametrize("bh,s,hp,ds,chunk", [
    (2, 64, 16, 32, 16), (3, 128, 16, 32, 32), (1, 256, 64, 128, 64),
])
def test_ssd_scan(bh, s, hp, ds, chunk):
    x = jnp.asarray(RNG.normal(size=(bh, s, hp)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(bh, s)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(bh,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(bh, s, ds)) * 0.3, jnp.float32)
    C = jnp.asarray(RNG.normal(size=(bh, s, ds)) * 0.3, jnp.float32)
    got = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    want = ref.ssd_scan_ref(x, dt, A, B, C)
    err = np.abs(np.asarray(got) - np.asarray(want)).max()
    assert err < 5e-4, err


def test_ssd_kernel_matches_model_path():
    """Kernel == models/ssm.py chunked implementation (two formulations)."""
    from repro.models.ssm import _ssd_chunked

    b, s, nh, hp, ds = 2, 64, 3, 16, 32
    x = jnp.asarray(RNG.normal(size=(b, s, nh, hp)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(nh,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, s, ds)) * 0.3, jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, s, ds)) * 0.3, jnp.float32)
    h0 = jnp.zeros((b, nh, hp, ds), jnp.float32)
    want, _ = _ssd_chunked(x, dt, A, B, C, h0, 16)

    xk = x.transpose(0, 2, 1, 3).reshape(b * nh, s, hp)
    dtk = dt.transpose(0, 2, 1).reshape(b * nh, s)
    Ak = jnp.tile(A, b)
    Bk = jnp.repeat(B[:, None], nh, 1).reshape(b * nh, s, ds)
    Ck = jnp.repeat(C[:, None], nh, 1).reshape(b * nh, s, ds)
    got = ops.ssd_scan(xk, dtk, Ak, Bk, Ck, chunk=16)
    got = got.reshape(b, nh, s, hp).transpose(0, 2, 1, 3)
    assert np.abs(np.asarray(got) - np.asarray(want)).max() < 5e-4


@pytest.mark.parametrize("u,elems,n,smax", [(10, 8, 4, 5), (33, 128, 8, 9)])
def test_reshard_pack(u, elems, n, smax):
    src = jnp.asarray(
        np.vstack([RNG.normal(size=(u, elems)), np.zeros((1, elems))]),
        jnp.float32,
    )
    idx = jnp.asarray(RNG.integers(0, u + 1, size=(n, smax)), jnp.int32)
    got = ops.reshard_pack(src, idx)
    want = ref.reshard_pack_ref(src, idx)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# shape validation (ISSUE 7 satellite): must survive `python -O` — ValueError,
# not assert — and name BOTH the offending dimension and the block-size
# argument, so a bad launcher flag is diagnosable from the message alone.

def test_rmsnorm_rejects_indivisible_rows():
    x = jnp.zeros((96, 8), jnp.float32)
    w = jnp.ones((8,), jnp.float32)
    with pytest.raises(ValueError,
                       match=r"rmsnorm: row count n=96 .* block_rows=64"):
        ops.rmsnorm(x, w, block_rows=64, interpret=True)


def test_flash_attention_rejects_indivisible_blocks():
    q = jnp.zeros((1, 2, 48, 16), jnp.float32)
    k = jnp.zeros((1, 1, 48, 16), jnp.float32)
    v = jnp.zeros((1, 1, 48, 16), jnp.float32)
    with pytest.raises(
            ValueError,
            match=r"sequence length s=48 .* query-block size block_q=32"):
        ops.flash_attention(q, k, v, block_q=32, block_k=48, interpret=True)
    with pytest.raises(
            ValueError,
            match=r"sequence length s=48 .* key-block size block_k=32"):
        ops.flash_attention(q, k, v, block_q=48, block_k=32, interpret=True)


def test_ssd_scan_rejects_indivisible_chunk():
    x = jnp.zeros((2, 48, 4), jnp.float32)
    dt = jnp.zeros((2, 48), jnp.float32)
    A = jnp.zeros((2,), jnp.float32)
    B = jnp.zeros((2, 48, 8), jnp.float32)
    with pytest.raises(ValueError,
                       match=r"sequence length s=48 .* chunk length chunk=32"):
        ops.ssd_scan(x, dt, A, B, B, chunk=32, interpret=True)
