"""Checkpoint dtype round-trip (ISSUE 3 satellite): `save_checkpoint` widens
ml_dtypes leaves (bf16, fp8) to f32 for numpy's savez, and must RECORD the
original dtype so `load_checkpoint` casts back — even when the caller has no
target tree at all, or a freshly-f32-initialized one (the bf16-serving-KV
restore path `ServeSession.save/restore` rides on)."""
import os
import tempfile

import numpy as np
import pytest

pytestmark = pytest.mark.serve

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint


def _tree():
    return {
        "f32": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "kv": {"k": jnp.full((2, 4), 1.5, jnp.bfloat16),
               "v": jnp.full((2, 4), -0.25, jnp.bfloat16)},
        "ints": np.arange(4, dtype=np.int64),
    }


def test_bf16_round_trips_through_f32_target_tree():
    """The bug being fixed: restore used to inherit the TARGET tree's leaf
    dtype, silently widening a bf16 checkpoint into an f32 session."""
    tree = _tree()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save_checkpoint(path, tree, step=3)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.shape(x), jnp.float32)
            if jnp.asarray(x).dtype == jnp.bfloat16 else
            jax.ShapeDtypeStruct(np.shape(x), jnp.asarray(x).dtype),
            tree,
        )
        got, step = load_checkpoint(path, like)
        assert step == 3
        assert got["kv"]["k"].dtype == jnp.bfloat16
        assert got["kv"]["v"].dtype == jnp.bfloat16
        assert got["f32"].dtype == jnp.float32
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            assert np.array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))


def test_load_without_target_tree():
    """No like-tree at all: the flat dict comes back with original dtypes."""
    tree = _tree()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save_checkpoint(path, tree, step=11)
        flat, step = load_checkpoint(path)
        assert step == 11
        assert set(flat) == {"f32", "kv/k", "kv/v", "ints"}
        assert flat["kv/k"].dtype == jnp.bfloat16
        assert flat["ints"].dtype == jnp.int64
        assert np.array_equal(np.asarray(flat["kv/v"], np.float32),
                              np.full((2, 4), -0.25, np.float32))


def test_legacy_checkpoint_falls_back_to_target_dtype():
    """Checkpoints written before the dtype records existed (plain f32
    arrays, no __dtype__/ keys) restore to the target leaf dtype as
    before."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        np.savez(path, **{"a": np.ones((3,), np.float32),
                          "__step__": np.asarray(7)})
        like = {"a": jax.ShapeDtypeStruct((3,), jnp.bfloat16)}
        got, step = load_checkpoint(path, like)
        assert step == 7
        assert got["a"].dtype == jnp.bfloat16


def test_f8_round_trip():
    f8 = jnp.float8_e4m3fn
    tree = {"w": jnp.asarray(np.linspace(-2, 2, 8), f8)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save_checkpoint(path, tree)
        got, _ = load_checkpoint(path, {"w": jax.ShapeDtypeStruct((8,), jnp.float32)})
        assert got["w"].dtype == f8
        assert np.array_equal(np.asarray(got["w"], np.float32),
                              np.asarray(tree["w"], np.float32))
