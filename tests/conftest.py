"""Shared test fixtures. NOTE: no XLA_FLAGS here — unit tests see 1 device;
multi-device tests launch subprocesses (tests/dist/)."""
import dataclasses
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


@pytest.fixture(scope="session")
def run_dist():
    """Run tests/dist/<script> in a subprocess with N fake CPU devices
    (XLA_FLAGS must be set before jax import — never in-process)."""

    def _run(script: str, devices: int = 8, timeout: int = 900) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run(
            [sys.executable, os.path.join(HERE, "dist", script)],
            capture_output=True, text=True, timeout=timeout, env=env,
        )
        assert r.returncode == 0, f"{script} failed:\n{r.stdout}\n{r.stderr[-3000:]}"
        return r.stdout

    return _run


@pytest.fixture(scope="session")
def arch_ids():
    from repro.configs import ARCH_IDS

    return ARCH_IDS


def reduced_cfg(arch_id: str, capacity_factor: float = 8.0):
    """Smoke config; MoE capacity raised so dispatch drops nothing (tests
    compare against drop-free oracles)."""
    from repro.configs import get_arch, reduced

    cfg = reduced(get_arch(arch_id))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor)
        )
    return cfg
