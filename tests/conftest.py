"""Shared test fixtures. NOTE: no XLA_FLAGS here — unit tests see 1 device;
multi-device tests launch subprocesses (tests/dist/)."""
import dataclasses

import pytest


@pytest.fixture(scope="session")
def arch_ids():
    from repro.configs import ARCH_IDS

    return ARCH_IDS


def reduced_cfg(arch_id: str, capacity_factor: float = 8.0):
    """Smoke config; MoE capacity raised so dispatch drops nothing (tests
    compare against drop-free oracles)."""
    from repro.configs import get_arch, reduced

    cfg = reduced(get_arch(arch_id))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor)
        )
    return cfg
