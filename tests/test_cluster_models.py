"""Failure/availability/power/policy models vs the paper's own claims."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dependency: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.core.availability import (
    ClusterSpec, availability_analytic, availability_full_tp,
)
from repro.core.failure_model import (
    FailureTraceConfig, simulate_trace, steady_state_failed_fraction,
)
from repro.core.perf_model import Hardware, Parallel, Workload, best_config, iteration_time
from repro.core.policies import (
    WorkloadGeometry, cluster_throughput, stage_slowdown, table1_settings,
    throughput_loss_curve,
)
from repro.core.power import PowerModel
from repro.core.resource_manager import apply_spares, pack_replicas, packing_stats


def test_fig3_tp64_claim():
    """Paper §1: TP64 + 0.1% failed -> ~94% availability."""
    assert abs(availability_analytic(64, 0.001) - 0.938) < 0.004
    med, worst = availability_full_tp(ClusterSpec(domain_size=64), 0.001, samples=50)
    assert abs(med - 0.938) < 0.01
    assert worst <= med


def test_fig3_monotonic_in_tp():
    for f in (5e-4, 1e-3, 2e-3):
        av = [availability_analytic(tp, f) for tp in (8, 16, 32, 64)]
        assert av == sorted(av, reverse=True)


def test_fig4_steady_state():
    """Paper: cluster spends most time with > 0.1% failed (81% on a cold
    15-day trace; steady state is strictly above threshold)."""
    cfg = FailureTraceConfig()
    assert steady_state_failed_fraction(cfg) > 0.001
    t, failed = simulate_trace(cfg)
    assert (failed / cfg.n_gpus > 0.001).mean() > 0.8


def test_fig4_3x_rate():
    c1 = FailureTraceConfig()
    c3 = FailureTraceConfig(rate_multiplier=3.0)
    _, f1 = simulate_trace(c1)
    _, f3 = simulate_trace(c3)
    assert f3.max() > 1.8 * f1.max()  # paper: ~2x higher peak


def test_table1():
    rows = {r["config"]: r for r in table1_settings()}
    assert rows["TP32"]["rel_iter_time"] == 1.0
    assert rows["TP30"]["local_bs"] == 7          # paper Table 1
    assert rows["TP28"]["local_bs"] in (6, 7)     # paper: 6
    assert rows["TP30-PW"]["local_bs"] == 8
    assert rows["TP30-PW"]["rel_iter_time"] <= 1.005
    assert rows["TP28-PW"]["power"] <= 1.3 + 1e-9
    assert rows["TP28-PW"]["rel_iter_time"] <= 1.05


def test_fig6_ordering_and_magnitudes():
    spec = ClusterSpec(n_gpus=32_768, domain_size=32)
    curve = throughput_loss_curve(spec, [4e-3], samples=8, seed=1)
    dp, ntp, pw = curve["dpdrop"][0], curve["ntp"][0], curve["ntp_pw"][0]
    assert dp > ntp > pw
    assert 0.08 < dp < 0.16     # paper: up to 12%
    assert ntp < 0.035          # paper: ≤3%
    assert pw < 0.01            # paper: <1%


def test_fig10_blast_radius():
    spec = ClusterSpec(n_gpus=16_384, domain_size=32)
    l1 = throughput_loss_curve(spec, [2e-3], methods=("ntp",), samples=6,
                               blast_radius=1, seed=2)["ntp"][0]
    l4 = throughput_loss_curve(spec, [2e-3], methods=("ntp",), samples=6,
                               blast_radius=4, seed=2)["ntp"][0]
    dp4 = throughput_loss_curve(spec, [2e-3], methods=("dpdrop",), samples=6,
                                blast_radius=4, seed=2)["dpdrop"][0]
    assert l4 > l1              # larger blast radius hurts NTP
    assert l4 < dp4             # but still beats DP-DROP (paper §6.4)


def test_power_model():
    pm = PowerModel()
    assert pm.speedup(1.0) == 1.0
    assert pm.speedup(1.3) > 1.1
    assert pm.perf_per_watt_penalty(1.2) < 0   # §6.4: boosting costs perf/W
    assert pm.required_power(30, 32) > 1.0


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 31), st.integers(0, 5))
def test_stage_slowdown_properties(tp_red, extra):
    geom = WorkloadGeometry()
    s = stage_slowdown(tp_red, 32, geom)
    assert s >= 32 / tp_red - 1e-9 or s >= 1.0
    # more failures never speed things up
    if tp_red + extra <= 32:
        assert stage_slowdown(tp_red, 32, geom) >= stage_slowdown(tp_red + extra, 32, geom) - 1e-9


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=16, max_size=64))
def test_packing_properties(failed):
    failed = np.array(failed[: len(failed) - len(failed) % 8])
    if len(failed) < 8:
        return
    asg = pack_replicas(failed, 32, 8)
    st_ = packing_stats(asg, 32)
    # every domain assigned exactly once
    all_ids = np.concatenate([a.domain_ids for a in asg])
    assert sorted(all_ids) == list(range(len(failed)))
    # packing is optimal in count: affected replicas == ceil(bad/8)
    n_bad = int((failed > 0).sum())
    assert st_["affected_replicas"] == int(np.ceil(n_bad / 8))


def test_spares_reduce_damage():
    failed = np.zeros(64, dtype=int)
    failed[:10] = 1
    after = apply_spares(failed, 4)
    assert (after > 0).sum() == 6


def test_perf_model_fig2_trend():
    """Fig. 2b: at large scale, capping TP reduces per-GPU throughput."""
    hw = Hardware(domain_size=32)
    wl = Workload()
    big = 32_768
    r8 = best_config(hw, wl, big, tp_limit=8)
    r32 = best_config(hw, wl, big, tp_limit=32)
    assert r32["per_gpu_tput"] > r8["per_gpu_tput"]
    # and the gap shrinks at smaller scale (paper: 8K GPUs ~insensitive)
    s8 = best_config(hw, wl, 8_192, tp_limit=8)
    s32 = best_config(hw, wl, 8_192, tp_limit=32)
    gap_small = s32["per_gpu_tput"] / s8["per_gpu_tput"]
    gap_big = r32["per_gpu_tput"] / r8["per_gpu_tput"]
    assert gap_big > gap_small


def test_ntp_reshard_exposed_small():
    """§6.2: the simulated workload sits in the <1% slowdown regime."""
    hw = Hardware(domain_size=32)
    wl = Workload()
    base = iteration_time(hw, wl, Parallel())
    red = iteration_time(hw, wl, Parallel(), tp_reduced=30,
                         local_batch_scale=7 / 8)
    assert red["reshard_exposed"] / base["total"] < 0.01


# ---------------------------------------------------------------------------
# ISSUE 5 satellite: failed_counts_at must count DISTINCT failed GPUs

@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.floats(0.0, 50.0),    # start_h
            st.floats(0.1, 100.0),   # duration_h
            st.integers(0, 31),      # gpu id (4 domains of size 8)
        ),
        min_size=0, max_size=40,
    ),
    st.floats(0.0, 60.0),            # probe time
)
def test_failed_counts_never_exceed_distinct_failed_gpus(events, t_h):
    """`failed_counts_at` ≤ the number of DISTINCT live-failed GPU ids per
    domain, always — overlapping failure intervals on one GPU (independent
    event sampling can re-fail an already-down GPU) must count once."""
    from repro.core.failure_model import TraceEvents

    n_domains, domain_size = 4, 8
    start = np.array([e[0] for e in events], dtype=float)
    end = np.array([e[0] + e[1] for e in events], dtype=float)
    gpu = np.array([e[2] for e in events], dtype=int)
    ev = TraceEvents(start_h=start, end_h=end, gpu=gpu,
                     domain=gpu // domain_size,
                     is_hw=np.ones(len(events), bool))
    counts = ev.failed_counts_at(t_h, n_domains, domain_size)
    live = (start <= t_h) & (end > t_h)
    for d in range(n_domains):
        distinct = len({int(g) for g in gpu[live] if g // domain_size == d})
        assert counts[d] <= distinct, (d, counts[d], distinct)
        # and with the saturating clip, exactly min(distinct, domain_size)
        assert counts[d] == min(distinct, domain_size)
    assert (counts <= domain_size).all()
