"""Property-based lifecycle-transition tests (ISSUE 2 satellite): random
interleavings of fail/repair events on a 2×4 mesh must never corrupt the
canonical weights carried through the repack chain, and `ClusterHealth.apply`
must be inverse-consistent (fail then repair of the same GPU restores the
packed plan). Host-side — the repack algebra is pure numpy; the live-session
trajectory equivalence runs in tests/dist/session_lifecycle.py."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dependency: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

import jax

from repro.core import ntp_train as nt
from repro.runtime import (
    ClusterHealth, DeadReplicaError, FailureEvent, RecoveryEvent,
    plan_from_health,
)

D, N1 = 2, 4  # the 2×4 mesh of the live lifecycle test


def _tiny_cfg():
    return nt.NTPModelConfig(d_model=32, n_kv_groups=4, q_per_kv=1,
                             head_dim=16, d_ff=128, unit_rows=32,
                             n_layers=1, vocab=64)


# one lifecycle event: (is_failure, addressed_by_domain, index, n_gpus)
EVENT = st.tuples(st.booleans(), st.booleans(), st.integers(0, D - 1),
                  st.integers(1, 2))


def _to_event(is_fail, by_domain, idx, n_gpus):
    cls = FailureEvent if is_fail else RecoveryEvent
    return cls(domain=idx) if by_domain else cls(replica=idx, n_gpus=n_gpus)


@settings(max_examples=25, deadline=None)
@given(st.lists(EVENT, max_size=10))
def test_random_interleavings_preserve_canonical_weights(events):
    """Fold an arbitrary fail/repair interleaving through health -> plan ->
    repack; the canonical content of params AND an AdamW-moment-like tree
    must survive every transition bit-exactly, recoverable from EVERY
    replica. Events that would kill a replica (TP 0) are skipped, as the
    session would refuse them."""
    cfg = _tiny_cfg()
    canon = nt.init_canonical(cfg, jax.random.PRNGKey(0))
    moment = jax.tree.map(lambda x: x * 0.5, canon)  # stand-in AdamW m/v

    health = ClusterHealth.pristine(D, N1)
    plan = plan_from_health(health)
    packed = nt.pack_params(cfg, canon, plan)
    packed_m = nt.pack_params(cfg, moment, plan)

    n_applied = 0
    for ev_tuple in events:
        ev = _to_event(*ev_tuple)
        new_health = health.apply(ev)
        try:
            new_plan = plan_from_health(new_health)
        except DeadReplicaError:
            continue
        packed = nt.repack_params(cfg, packed, plan, new_plan)
        packed_m = nt.repack_params(cfg, packed_m, plan, new_plan)
        health, plan = new_health, new_plan
        n_applied += 1

    for r in range(plan.d):
        for got_tree, want_tree in ((packed, canon), (packed_m, moment)):
            back = nt.unpack_params(cfg, got_tree, plan, replica=r)
            for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(want_tree)):
                assert np.array_equal(np.asarray(a), np.asarray(b)), (
                    f"replica {r} corrupted after {n_applied} transitions "
                    f"(final plan {plan})"
                )


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, N1 - 1), min_size=D, max_size=D),
       st.integers(0, D - 1))
def test_fail_then_repair_same_domain_is_identity(failed, domain):
    """Failing one GPU in a domain and then repairing one GPU in the same
    domain restores both the health ledger and the packed FailurePlan —
    whenever the failure was not clamped at the domain size."""
    health = ClusterHealth(domain_size=N1, failed=tuple(failed))
    if health.failed[domain] >= N1 - 1:
        return  # failure would kill or clamp the domain
    round_trip = health.apply(FailureEvent(domain=domain)).apply(
        RecoveryEvent(domain=domain)
    )
    assert round_trip == health
    assert plan_from_health(round_trip) == plan_from_health(health)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, N1 - 2), min_size=D, max_size=D),
       st.integers(0, D - 1))
def test_replica_addressed_fail_repair_restores_plan(failed, replica):
    """Replica-addressed events resolve against the live packing: a failure
    lands on the replica's worst domain and an immediate repair of that
    replica undoes it at the PLAN level (health may migrate between equally
    degraded domains, the packed plan may not)."""
    health = ClusterHealth(domain_size=N1, failed=tuple(failed))
    hurt = health.apply(FailureEvent(replica=replica))
    if max(hurt.failed) >= N1:
        return  # a dead domain has no NTP plan to compare
    # the failure landed on exactly one domain; repair the replica that now
    # serves it (one domain per replica here, so the repair hits it exactly)
    (dom,) = [d for d in range(D) if hurt.failed[d] != health.failed[d]]
    asg = hurt.assignments()
    (victim,) = [r for r, a in enumerate(asg) if int(a.domain_ids[0]) == dom]
    healed = hurt.apply(RecoveryEvent(replica=victim))
    assert healed == health
    assert plan_from_health(healed) == plan_from_health(health)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, N1), min_size=D, max_size=D),
       st.integers(1, 3))
def test_repair_saturates_at_healthy(failed, n_gpus):
    """Surplus repairs are no-ops (the way up absorbs the clamped way down)."""
    health = ClusterHealth(domain_size=N1, failed=tuple(failed))
    for d in range(D):
        healed = health
        for _ in range(N1 + 2):
            healed = healed.apply(RecoveryEvent(domain=d, n_gpus=n_gpus))
        assert healed.failed[d] == 0
        assert all(healed.failed[j] == health.failed[j]
                   for j in range(D) if j != d)
