"""Decode-path correctness (ISSUE 3 satellite): incremental decode through
the KV cache — prefill then one token at a time — must equal the
full-sequence `attn_apply` oracle, for GQA and sliding-window/chunked
configs, on BOTH the naive and the blockwise ("flash") prefill paths.
`tests/test_kernels.py` only covers full-sequence attention; this is the
path `repro.serve` lives on."""
import numpy as np
import pytest

pytestmark = pytest.mark.serve

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models.common import NO_SHARD

S, PREFILL, B = 32, 16, 2


def _cfg(kvh: int, **kw) -> ArchConfig:
    base = dict(
        arch_id=f"decode-test-kv{kvh}", family="dense", citation="test",
        n_layers=1, d_model=32, n_heads=4, n_kv_heads=kvh, head_dim=8,
        d_ff=64, vocab_size=64, window=16, chunk_size=16,
    )
    base.update(kw)
    return ArchConfig(**base)


CASES = [
    ("attn", _cfg(2)),                       # GQA
    ("attn", _cfg(4)),                       # MHA
    ("attn", _cfg(1)),                       # MQA
    ("attn", _cfg(2, qk_norm=True, attn_softcap=30.0)),
    ("attn_sw", _cfg(2)),                    # sliding window (ring cache)
    ("attn_chunked", _cfg(2)),               # chunked-local (ring cache)
]


def _incremental(cfg, p, x, kind, cache_len):
    cache = attn_mod.init_kv_cache(cfg, B, cache_len, jnp.float32, kind=kind)
    out_p, cache = attn_mod.attn_apply(
        cfg, p, x[:, :PREFILL], kind=kind, ctx=NO_SHARD,
        positions=jnp.arange(PREFILL, dtype=jnp.int32),
        cache=cache, cache_pos=jnp.int32(0),
    )
    outs = [out_p]
    for t in range(PREFILL, S):
        o, cache = attn_mod.attn_apply(
            cfg, p, x[:, t:t + 1], kind=kind, ctx=NO_SHARD,
            positions=jnp.arange(t, t + 1, dtype=jnp.int32),
            cache=cache, cache_pos=jnp.int32(t),
        )
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("kind,cfg", CASES, ids=lambda c: getattr(c, "arch_id", c))
def test_incremental_decode_matches_full_naive(kind, cfg):
    rng = np.random.default_rng(0)
    p = attn_mod.attn_init(cfg, jax.random.PRNGKey(1), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    full, _ = attn_mod.attn_apply(
        cfg, p, x, kind=kind, ctx=NO_SHARD,
        positions=jnp.arange(S, dtype=jnp.int32),
    )
    inc = _incremental(cfg, p, x, kind, S)
    err = np.abs(np.asarray(full) - np.asarray(inc)).max()
    assert err < 2e-5, (kind, cfg.arch_id, err)


@pytest.mark.parametrize("kind,cfg", [
    ("attn", _cfg(2)), ("attn_sw", _cfg(2)),
], ids=["attn-gqa", "attn_sw-gqa"])
def test_incremental_decode_matches_full_flash(kind, cfg, monkeypatch):
    """Same oracle with the blockwise prefill engaged (threshold lowered so
    both the full pass and the multi-token prefill take the flash path;
    single-token decode stays naive by design)."""
    monkeypatch.setattr(attn_mod, "FLASH_SEQ_THRESHOLD", 8)
    monkeypatch.setattr(attn_mod, "FLASH_BLOCK_Q", 4)
    monkeypatch.setattr(attn_mod, "FLASH_BLOCK_K", 8)
    rng = np.random.default_rng(2)
    p = attn_mod.attn_init(cfg, jax.random.PRNGKey(3), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    full, _ = attn_mod.attn_apply(
        cfg, p, x, kind=kind, ctx=NO_SHARD,
        positions=jnp.arange(S, dtype=jnp.int32),
    )
    inc = _incremental(cfg, p, x, kind, S)
    err = np.abs(np.asarray(full) - np.asarray(inc)).max()
    assert err < 2e-5, (kind, err)


def test_padded_prefill_matches_exact_prefill():
    """repro.serve prefills right-PADDED prompts (one jit signature): pad
    positions write garbage K/V beyond the real length, but decode masking
    (slot <= last, ring p >= 0) must keep it out until overwritten — the
    generated stream must match an exact-length prefill's."""
    cfg = _cfg(2)
    for kind in ("attn", "attn_sw"):
        rng = np.random.default_rng(4)
        p = attn_mod.attn_init(cfg, jax.random.PRNGKey(5), jnp.float32)
        x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        n_real = 10  # real prompt length; PREFILL-long padded prefill

        def decode_from(prefill_x, prefill_len):
            cache = attn_mod.init_kv_cache(cfg, B, S, jnp.float32, kind=kind)
            _, cache = attn_mod.attn_apply(
                cfg, p, prefill_x, kind=kind, ctx=NO_SHARD,
                positions=jnp.arange(prefill_len, dtype=jnp.int32),
                cache=cache, cache_pos=jnp.int32(0),
            )
            outs = []
            for t in range(n_real, S):
                o, cache = attn_mod.attn_apply(
                    cfg, p, x[:, t:t + 1], kind=kind, ctx=NO_SHARD,
                    positions=jnp.arange(t, t + 1, dtype=jnp.int32),
                    cache=cache, cache_pos=jnp.int32(t),
                )
                outs.append(o)
            return jnp.concatenate(outs, axis=1)

        exact = decode_from(x[:, :n_real], n_real)
        padded_x = jnp.concatenate(
            [x[:, :n_real],
             jnp.full((B, PREFILL - n_real, cfg.d_model), 7.7, jnp.float32)],
            axis=1,
        )
        padded = decode_from(padded_x, PREFILL)
        err = np.abs(np.asarray(exact) - np.asarray(padded)).max()
        assert err < 2e-5, (kind, err)
