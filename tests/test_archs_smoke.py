"""Per-architecture smoke tests (brief requirement): reduced variant of each
family — one forward and one train step on CPU, asserting shapes + no NaNs —
plus prefill→decode consistency for every family's serve path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS
from repro.configs.shapes import ShapeSpec
from repro.models import build_model
from repro.optim import adamw_init
from repro.train.steps import make_setup

from conftest import reduced_cfg

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 2)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.encoder is not None:
        batch["enc_input"] = (
            jax.random.normal(ks[0], (B, cfg.encoder.enc_seq, cfg.d_model)) * 0.1
        )
    return batch


@pytest.mark.parametrize("aid", sorted(ARCH_IDS))
def test_forward_shapes_finite(aid):
    cfg = reduced_cfg(aid)
    m = build_model(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = m.forward(
        params, batch["tokens"], enc_input=batch.get("enc_input")
    )
    assert logits.shape == (B, S, cfg.padded_vocab())
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux["moe_aux_loss"]))


@pytest.mark.parametrize("aid", sorted(ARCH_IDS))
def test_train_step(aid):
    cfg = reduced_cfg(aid)
    su = make_setup(cfg, ShapeSpec("t", S, B, "train"), None,
                    param_dtype=jnp.float32)
    step = su.jit_step()
    params = su.model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, su.opt_cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(opt["step"]) == 1
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("aid", sorted(ARCH_IDS))
def test_decode_matches_full_forward(aid):
    cfg = reduced_cfg(aid)
    m = build_model(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    enc_out = None
    kwargs = {}
    if cfg.encoder is not None:
        enc_in = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder.enc_seq, cfg.d_model)
        ) * 0.1
        kwargs["enc_input"] = enc_in
        enc_out = m._encode(params, enc_in)
    full, _ = m.forward(params, toks, **kwargs)

    cache = m.init_cache(B, S, jnp.float32)
    _, cache = m.prefill(params, toks[:, : S - 1], cache,
                         enc_input=kwargs.get("enc_input"))
    last, _ = m.decode_step(params, cache, toks[:, S - 1 :], jnp.int32(S - 1),
                            enc_out=enc_out)
    err = float(jnp.abs(full[:, -1] - last[:, 0]).max())
    assert err < 2e-3, (aid, err)


def test_loss_decreases_on_learnable_data():
    """End-to-end sanity: a few steps on the synthetic Markov stream."""
    import functools

    from repro.data.pipeline import DataConfig, SyntheticLMPipeline
    from repro.optim import AdamWConfig, warmup_cosine

    cfg = reduced_cfg("granite-3-2b")
    su = make_setup(
        cfg, ShapeSpec("t", 64, 8, "train"), None, param_dtype=jnp.float32,
        opt_cfg=AdamWConfig(lr=2e-3),
        lr_schedule=functools.partial(warmup_cosine, warmup=5, total=10_000),
    )
    step = su.jit_step()
    params = su.model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, su.opt_cfg)
    pipe = SyntheticLMPipeline(DataConfig(cfg.vocab_size, 64, 8, noise=0.0))
    losses = []
    for i in range(30):
        params, opt, metrics = step(params, opt, pipe.batch(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_ring_cache_sliding_window_decode():
    """§Perf B2: window-sized ring KV caches decode identically to a
    full-length cache for sliding-window layers."""
    import dataclasses

    cfg = reduced_cfg("gemma2-9b")
    cfg = dataclasses.replace(cfg, window=8)  # tiny window << seq
    m = build_model(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    S = 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full, _ = m.forward(params, toks)

    cache = m.init_cache(B, S, jnp.float32)
    # local (attn_sw) layers must have ring-sized caches
    sw_cache_len = jax.tree.leaves(cache["layers"][0])[0].shape[2]
    assert sw_cache_len == 8, sw_cache_len

    _, cache = m.prefill(params, toks[:, : S - 1], cache)
    last, _ = m.decode_step(params, cache, toks[:, S - 1 :], jnp.int32(S - 1))
    err = float(jnp.abs(full[:, -1] - last[:, 0]).max())
    assert err < 2e-3, err


def test_ring_cache_multi_step_decode():
    """Roll 6 decode steps through the ring and compare each to teacher
    forcing (positions wrap several times at window=4)."""
    import dataclasses

    cfg = reduced_cfg("recurrentgemma-9b")
    cfg = dataclasses.replace(cfg, window=4)
    m = build_model(cfg, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    S = 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    cache = m.init_cache(B, S, jnp.float32)
    start = S - 6
    _, cache = m.prefill(params, toks[:, :start], cache)
    for i in range(start, S):
        logits, cache = m.decode_step(
            params, cache, toks[:, i : i + 1], jnp.int32(i)
        )
        full, _ = m.forward(params, toks[:, : i + 1])
        err = float(jnp.abs(full[:, -1] - logits[:, 0]).max())
        assert err < 2e-3, (i, err)
