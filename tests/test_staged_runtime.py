"""Nonuniform pipeline parallelism, host-side (ISSUE 5): stage geometry
helpers, `StagedPlan`/`StagedHealth` event algebra, per-stage packing and the
stage-local repack oracle, the staged trace schedule, the per-stage slowdown
prediction vs `perf_model.staged_iteration_time`, and the `best_config`
search-space cleanup. The live pp>=2 session runs in a multi-device
subprocess (tests/dist/session_pp_lifecycle.py); the pp=1 bit-identity
regression in tests/dist/session_pp1_regression.py."""
import numpy as np
import pytest

import jax

from repro.configs.shapes import (
    SUPPORTED_PP, candidate_pp, layer_stages, stage_boundaries,
)
from repro.core import ntp_train as nt
from repro.core.failure_model import TraceEvents
from repro.core.nonuniform import FailurePlan, StagedPlan, as_staged
from repro.core.perf_model import (
    Hardware, Parallel, Workload, best_config, iteration_time,
    staged_iteration_time,
)
from repro.core.policies import WorkloadGeometry, staged_rel_iter_times
from repro.runtime import (
    FailureEvent, RecoveryEvent, StagedHealth, power_policy,
    resolve_serving_domain, schedule_from_trace, staged_plan_from_health,
)


# ---------------------------------------------------------------------------
# stage geometry (configs/shapes)

def test_stage_boundaries_balanced_and_total():
    assert stage_boundaries(4, 1) == (0, 4)
    assert stage_boundaries(4, 2) == (0, 2, 4)
    assert stage_boundaries(5, 2) == (0, 3, 5)       # ceil-first
    assert stage_boundaries(7, 4) == (0, 2, 4, 6, 7)
    for n, p in [(100, 8), (13, 4), (32, 32)]:
        b = stage_boundaries(n, p)
        sizes = [b[i + 1] - b[i] for i in range(p)]
        assert b[0] == 0 and b[-1] == n
        assert max(sizes) - min(sizes) <= 1 and min(sizes) >= 1


def test_stage_boundaries_rejects_empty_stage():
    with pytest.raises(ValueError, match="exceeds n_layers"):
        stage_boundaries(2, 4)
    with pytest.raises(ValueError, match="pp must be"):
        stage_boundaries(2, 0)


def test_layer_stages_inverts_boundaries():
    assert layer_stages(5, 2) == (0, 0, 0, 1, 1)
    assert layer_stages(4, 4) == (0, 1, 2, 3)


def test_candidate_pp_filters_by_layers():
    assert candidate_pp(100) == SUPPORTED_PP
    assert candidate_pp(5) == (1, 2, 4)
    assert candidate_pp(1) == (1,)
    assert candidate_pp(100, max_pp=8) == (1, 2, 4, 8)


# ---------------------------------------------------------------------------
# StagedPlan algebra

def test_staged_plan_effective_is_min_over_stages():
    sp = StagedPlan((FailurePlan(4, (3, 4)), FailurePlan(4, (4, 2))))
    assert sp.pp == 2 and sp.d == 2 and sp.n1 == 4
    assert sp.effective == FailurePlan(4, (3, 2))
    assert sp.replica_tp == (3, 2)           # slowest stage gates
    assert sp.stage_tp == ((3, 4), (4, 2))
    assert not sp.healthy
    assert as_staged(FailurePlan(4, (4, 4))).pp == 1


def test_staged_plan_rejects_mismatched_stage_geometry():
    with pytest.raises(AssertionError):
        StagedPlan((FailurePlan(4, (4, 4)), FailurePlan(8, (8, 8))))


# ---------------------------------------------------------------------------
# StagedHealth event algebra

def test_staged_health_stage_addressed_events_are_stage_local():
    h = StagedHealth.pristine(2, 4, pp=2)
    h1 = h.apply(FailureEvent(stage=1, domain=0))
    assert [x.failed for x in h1.stages] == [(0, 0), (1, 0)]
    plan = staged_plan_from_health(h1)
    # only stage 1 degraded; stage 0 untouched
    assert plan.stages[0].healthy and plan.stages[1].replica_tp == (3, 4)
    assert h1.apply(RecoveryEvent(stage=1, domain=0)).healthy


def test_staged_health_global_domain_addressing_is_replica_major():
    # global domain g -> (stage g % pp, in-stage domain g // pp)
    h = StagedHealth.pristine(2, 4, pp=2)
    h1 = h.apply(FailureEvent(domain=3))
    assert [x.failed for x in h1.stages] == [(0, 0), (0, 1)]
    with pytest.raises(ValueError, match="global domain"):
        h.apply(FailureEvent(domain=4))


def test_staged_health_replica_addressed_lands_on_worst_stage():
    h = StagedHealth.pristine(2, 4, pp=2)
    h = h.apply(FailureEvent(stage=1, domain=0))
    # replica 0 now serves stage 1's degraded domain; a stage-less replica
    # hit lands there (the stage pinning its TP), not on healthy stage 0
    h2 = h.apply(FailureEvent(replica=0))
    assert [x.failed for x in h2.stages] == [(0, 0), (2, 0)]
    # and a stage-less repair heals the same worst site
    h3 = h2.apply(RecoveryEvent(replica=0)).apply(RecoveryEvent(replica=0))
    assert [x.failed for x in h3.stages] == [(0, 0), (0, 0)]


def test_staged_health_rejects_bad_stage():
    h = StagedHealth.pristine(2, 4, pp=2)
    with pytest.raises(ValueError, match="stage 5"):
        h.apply(FailureEvent(stage=5, domain=0))
    with pytest.raises(ValueError):
        FailureEvent(stage=-1, domain=0)


def test_single_stage_ledger_rejects_staged_events():
    from repro.runtime import ClusterHealth

    h = ClusterHealth.pristine(2, 4)
    with pytest.raises(ValueError, match="single-stage"):
        h.apply(FailureEvent(stage=1, domain=0))
    # stage=0 aliases the unstaged ledger 1:1
    assert h.apply(FailureEvent(stage=0, domain=1)).failed == (0, 1)


def test_serving_rejects_staged_events():
    with pytest.raises(ValueError, match="single-stage"):
        resolve_serving_domain(FailureEvent(stage=1, domain=0), 4)


def test_staged_spares_need_allocator():
    """Spares with pp > 1 are legal ONLY through the global allocator (a
    spare can absorb failures in any stage — DESIGN.md §2.7): without one
    the error names the --allocator escape hatch; with one the joint search
    runs and the spare absorbs the failure."""
    from repro.cluster import GreedyAllocator

    h = StagedHealth.pristine(2, 4, pp=2)
    with pytest.raises(ValueError, match="--allocator"):
        staged_plan_from_health(h, spares=1)
    h1 = h.apply(FailureEvent(stage=1, domain=0))
    plan = staged_plan_from_health(h1, spares=1, allocator=GreedyAllocator())
    assert plan.healthy   # the spare stood in for the failed stage-1 domain


def test_session_rejects_unstaged_plan_or_health_with_pp():
    """pp>1 with a plain FailurePlan/ClusterHealth is ambiguous (broadcast
    would silently multiply the blast radius across stages) — create() must
    reject it before any compute, not build a mismatched session."""
    from repro.runtime import ClusterHealth, NTPSession

    class StubMesh:  # only .shape is read before validation
        shape = {"data": 2, "model": 4}

    with pytest.raises(ValueError, match="staged plan"):
        NTPSession.create(_tiny_cfg(), StubMesh(), pp=2,
                          plan=FailurePlan(n1=4, replica_tp=(4, 4)))
    with pytest.raises(ValueError, match="staged health"):
        NTPSession.create(_tiny_cfg(), StubMesh(), pp=2,
                          health=ClusterHealth.pristine(2, 4))


def test_arch_microbatches_rejects_moe():
    """The MoE load-balance aux loss is not additive over microbatch chunks,
    so grad accumulation would silently differ from the full-batch step."""
    from repro.configs import get_arch, reduced
    from repro.configs.shapes import ShapeSpec
    from repro.train.steps import make_setup

    moe_cfg = next(
        reduced(get_arch(a))
        for a in ("llama4-scout-17b-a16e", "arctic-480b")
        if get_arch(a).moe is not None
    )
    with pytest.raises(ValueError, match="MoE"):
        make_setup(moe_cfg, ShapeSpec("t", 32, 8, "train"), None,
                   microbatches=2)


# ---------------------------------------------------------------------------
# staged packing / repack oracle (host-side, no mesh)

def _tiny_cfg(n_layers=4):
    return nt.NTPModelConfig(d_model=32, n_kv_groups=2, q_per_kv=1,
                             head_dim=16, d_ff=64, unit_rows=32,
                             n_layers=n_layers, vocab=64)


def test_staged_pack_unpack_roundtrip_and_per_stage_layout():
    cfg = _tiny_cfg()
    sp = StagedPlan((FailurePlan(2, (1, 2)), FailurePlan(2, (2, 2))))
    canon = nt.init_canonical(cfg, jax.random.PRNGKey(0))
    packed = nt.pack_params(cfg, canon, sp)
    # stage 0 (degraded) packs wider buffers than healthy stage 1
    buf0 = packed["layers"][0]["wq"].shape[1]
    buf1 = packed["layers"][2]["wq"].shape[1]
    assert buf0 > buf1, (buf0, buf1)
    back = nt.unpack_params(cfg, packed, sp)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(canon)):
        assert np.allclose(np.asarray(a), np.asarray(b))


def test_staged_repack_matches_pack_unpack_oracle():
    cfg = _tiny_cfg()
    old = StagedPlan((FailurePlan(2, (1, 2)), FailurePlan(2, (2, 2))))
    new = StagedPlan((FailurePlan(2, (2, 2)), FailurePlan(2, (1, 2))))
    packed = nt.pack_params(cfg, nt.init_canonical(cfg, jax.random.PRNGKey(1)),
                            old)
    got = nt.repack_params(cfg, packed, old, new)
    want = nt.pack_params(cfg, nt.unpack_params(cfg, packed, old), new)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_staged_transition_is_stage_local():
    """A transition that only degrades stage 1 must move ONLY stage-1 units
    (stage-0 buffers pass through bit-identical) and tag its transfer ledger
    by stage."""
    from repro.reshard.transition import transition_staged_trees

    cfg = _tiny_cfg()
    old = StagedPlan((FailurePlan(2, (2, 2)), FailurePlan(2, (2, 2))))
    new = StagedPlan((FailurePlan(2, (2, 2)), FailurePlan(2, (1, 2))))
    packed = nt.pack_params(cfg, nt.init_canonical(cfg, jax.random.PRNGKey(2)),
                            old)
    (moved,), stats = transition_staged_trees(cfg, [packed], old, new)
    assert stats.moved_units > 0
    # every ledger entry is tagged (stage, replica, src, dst) with stage == 1
    assert all(len(k) == 4 and k[0] == 1 for k in stats.per_pair)
    for li in (0, 1):   # stage-0 layers bit-identical
        for key in nt.UNIT_KEYS:
            assert np.array_equal(np.asarray(packed["layers"][li][key]),
                                  np.asarray(moved["layers"][li][key]))


# ---------------------------------------------------------------------------
# staged schedule + slowdown prediction

def test_schedule_from_trace_staged_addressing():
    from repro.core.failure_model import FailureTraceConfig
    from repro.runtime import StagedHealth

    pp = 2
    cfg = FailureTraceConfig(n_gpus=2 * pp * 4, domain_size=4, days=40.0,
                             rate_multiplier=2000.0, seed=1,
                             hw_recovery_days=(0.2, 0.4),
                             sw_recovery_hours=2.0)
    sched = schedule_from_trace(cfg, steps=400, pp=pp)
    assert sched, "expected events at this rate"
    assert all(s.event.stage in (0, 1) for s in sched)
    assert all(0 <= s.event.domain < 2 for s in sched)
    # replay never under/overflows any stage ledger
    h = StagedHealth.pristine(2, 4, pp=pp)
    for s in sched:
        h = h.apply(s.event)
        for stage in h.stages:
            assert all(0 <= f <= 4 for f in stage.failed)


def test_staged_rel_iter_times_slowest_stage_matches_power_decision():
    """max over stages of the per-stage rel == PowerDecision.rel_iter_time
    on the effective (min-over-stages) plan — the slowest-stage rule both
    sides implement."""
    sp = StagedPlan((FailurePlan(32, (30, 32)), FailurePlan(32, (32, 32)),
                     FailurePlan(32, (32, 32)), FailurePlan(32, (32, 32))))
    geom = WorkloadGeometry(n_heads=128, local_batch=8)
    for name in ("ntp", "ntp_pw"):
        pol = power_policy(name, geom=geom)
        dec = pol.decide(sp.effective, local_batch=8, geom=geom)
        rels = staged_rel_iter_times(
            sp.stage_tp, sp.n1, geom, local_batches=dec.local_batches,
            local_batch=8, boosts=dec.boost, power=pol.model,
        )
        assert len(rels) == sp.pp
        assert max(rels) == pytest.approx(dec.rel_iter_time, rel=1e-12)
        # healthy stages never dominate
        assert rels[0] == max(rels)


def test_staged_iteration_time_is_min_stage_reduction():
    hw, wl = Hardware(domain_size=32), Workload()
    par = Parallel(tp=32, pp=4, dp=64)
    stage_tps = (32, 30, 28, 32)
    got = staged_iteration_time(hw, wl, par, stage_tps)
    want = iteration_time(hw, wl, par, tp_reduced=28)
    assert got == want
    healthy = staged_iteration_time(hw, wl, par, (32, 32, 32, 32))
    assert healthy == iteration_time(hw, wl, par)
    with pytest.raises(AssertionError):
        staged_iteration_time(hw, wl, par, (32, 32))   # wrong pp


# ---------------------------------------------------------------------------
# best_config cleanup (ISSUE 5 satellite)

def test_best_config_search_space_derives_from_runtime_support():
    hw, wl = Hardware(domain_size=32), Workload()
    r = best_config(hw, wl, 32_768, tp_limit=32)
    assert r["pp"] in candidate_pp(wl.n_layers)
    # min_pp is honored now (it was dead before)
    r2 = best_config(hw, wl, 32_768, tp_limit=32, min_pp=4)
    assert r2["pp"] >= 4
    # a shallow model cannot be split deeper than its layers
    from dataclasses import replace

    shallow = replace(wl, n_layers=4)
    r3 = best_config(hw, shallow, 32_768, tp_limit=32)
    assert r3 is None or r3["pp"] <= 4


# ---------------------------------------------------------------------------
# failed_counts_at distinct-GPU regression (deterministic twin of the
# hypothesis property in test_cluster_models.py, which needs the dev extra)

def test_failed_counts_at_does_not_double_count_refailed_gpu():
    """Two overlapping failure intervals on ONE GPU are one dead GPU: the
    old interval count said 2 (and could push a domain past its size); the
    distinct-id count says 1."""
    start = np.array([0.0, 1.0, 0.5])
    end = np.array([10.0, 12.0, 4.0])
    gpu = np.array([3, 3, 9])            # gpu 3 re-failed while down
    ev = TraceEvents(start_h=start, end_h=end, gpu=gpu, domain=gpu // 8,
                     is_hw=np.ones(3, bool))
    counts = ev.failed_counts_at(2.0, 2, 8)
    assert counts.tolist() == [1, 1]     # not [2, 1]
    # after the short failure heals, only the long one remains
    assert ev.failed_counts_at(11.0, 2, 8).tolist() == [1, 0]
    assert ev.failed_counts_at(20.0, 2, 8).tolist() == [0, 0]
