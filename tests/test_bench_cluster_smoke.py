"""Bench-smoke schema guard for the BENCH_cluster 100k-GPU trace row
(ISSUE 10 satellite): the measured record's shape is pinned to
``benchmarks.bench_cluster.TRACE_100K_KEYS`` so the checked-in trajectory
stays machine-readable, and a reduced-scale run of the same code path
proves the generator + vectorized scan actually execute. The full-scale
acceptance gate (100k GPUs, 2 weeks, generate+scan < 10 s) is asserted
against the checked-in BENCH_cluster.json — the row is MEASURED, not
aspirational.
"""
import importlib.util
import json
import os

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks")
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_cluster.json")


def _bench_cluster():
    spec = importlib.util.spec_from_file_location(
        "_bench_cluster", os.path.join(BENCH_DIR, "bench_cluster.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_trace_row_smoke_and_schema():
    bench = _bench_cluster()
    row = bench.trace_100k(n_gpus=64 * 64, days=2.0)   # reduced-scale smoke
    assert tuple(sorted(row)) == tuple(sorted(bench.TRACE_100K_KEYS)), row
    assert row["events"] == sum(row["events_per_kind"].values())
    assert row["events_per_kind"]["straggler"] > 0
    assert row["events_per_kind"]["sdc"] > 0
    assert row["scan_samples"] == 48
    assert row["generate_s"] >= 0.0 and row["scan_s"] >= 0.0


def test_checked_in_run_carries_measured_100k_row():
    with open(BENCH_JSON) as f:
        doc = json.load(f)
    assert doc["runs"], "BENCH_cluster.json has no runs"
    latest = doc["runs"][-1]
    assert "trace_100k" in latest, (
        "latest BENCH_cluster run predates the taxonomy trace row — re-run "
        "PYTHONPATH=src python -m benchmarks.bench_cluster")
    bench = _bench_cluster()
    row = latest["trace_100k"]
    assert tuple(sorted(row)) == tuple(sorted(bench.TRACE_100K_KEYS)), row
    assert row["n_gpus"] >= 100_000 and row["days"] >= 14.0
    # the §2.11 scale gate, as measured on the recording machine
    assert row["generate_s"] + row["scan_s"] < 10.0, row
