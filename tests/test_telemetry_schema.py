"""Telemetry schema golden (ISSUE 8 satellite): the JSONL event schema
(`EVENT_KEYS`), the goodput-decomposition row schema (`GOODPUT_KEYS`) and
the BENCH_telemetry run-key set are pinned to checked-in JSON so a refactor
cannot silently change what a recorded stream means — old streams must stay
foldable by new code.

On mismatch the freshly-computed schema is written next to the golden as
``telemetry_schema.actual.json`` so the diff is inspectable. To
intentionally re-pin after a schema change (a breaking change for every
archived stream — say so in the commit):

    PYTHONPATH=src python tests/test_telemetry_schema.py --regen
"""
import importlib.util
import json
import os

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_PATH = os.path.join(GOLDEN_DIR, "telemetry_schema.json")
ACTUAL_PATH = os.path.join(GOLDEN_DIR, "telemetry_schema.actual.json")


def compute_schema():
    from repro.launch.telemetry_report import GOODPUT_KEYS, SYNC_SPAN_KEYS
    from repro.telemetry import EVENT_KEYS, EVENT_KINDS

    bench_path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                              "bench_telemetry.py")
    spec = importlib.util.spec_from_file_location("_bench_tel", bench_path)
    bench = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bench)
    return {
        "event_kinds": sorted(EVENT_KINDS),
        "event_keys": {k: sorted(v) for k, v in EVENT_KEYS.items()},
        "goodput_keys": sorted(GOODPUT_KEYS),
        "train_sync_keys": sorted(SYNC_SPAN_KEYS),
        "bench_telemetry_run_keys": sorted(bench.TELEMETRY_KEYS),
    }


def test_telemetry_schema_matches_golden():
    assert os.path.exists(GOLDEN_PATH), (
        f"missing golden file {GOLDEN_PATH}; generate it with "
        "PYTHONPATH=src python tests/test_telemetry_schema.py --regen"
    )
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    actual = compute_schema()
    if actual != golden:
        with open(ACTUAL_PATH, "w") as f:
            json.dump(actual, f, indent=2, sort_keys=True)
    assert actual == golden, (
        "telemetry schema drifted from the golden — archived JSONL streams "
        f"would stop folding. Diff {ACTUAL_PATH} against {GOLDEN_PATH}; "
        "re-pin with --regen ONLY for an intentional breaking change."
    )


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(compute_schema(), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN_PATH}")
    else:
        print(__doc__)
