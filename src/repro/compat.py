"""jax version-compatibility shims.

``shard_map`` graduated from jax.experimental (where the replication-check
kwarg is ``check_rep``) to the jax namespace (``check_vma``); wrap both so
the rest of the codebase writes one call.
"""
from __future__ import annotations

try:  # jax >= 0.5
    from jax import shard_map as _shard_map

    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: check_vma},
    )
