from repro.data.pipeline import DataConfig, SyntheticLMPipeline, input_specs  # noqa: F401
