"""Deterministic synthetic data pipeline + dry-run input specs.

``input_specs`` is the single source of truth for every model input's shape,
dtype and sharding (ShapeDtypeStruct stand-ins — no allocation), used by both
the real pipeline (for array layout) and the multi-pod dry-run.

The synthetic corpus is a seeded affine Markov stream: learnable structure
(so examples/quickstart loss actually drops) with zero I/O dependencies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.models.common import sanitize_spec


# ---------------------------------------------------------------------------
# dry-run input specs

def input_specs(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh: Optional[Mesh] = None,
    dp_axes: Tuple[str, ...] = ("data",),
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs (weak-type-correct, shardable, no allocation) for
    every *data* input of the step function for (cfg, shape).

    train   -> tokens/targets (B, S)
    prefill -> tokens (B, S)
    decode  -> tokens (B, 1) + pos ()   (the cache is built by the step; see
               repro.train.steps.cache_specs)
    Audio archs additionally get enc_input (B, enc_seq, d) frame embeddings
    (the frontend stub per the brief).
    """
    b, s = shape.global_batch, shape.seq_len

    def sds(shp, dtype, spec):
        if mesh is None:
            return jax.ShapeDtypeStruct(shp, dtype)
        sp = sanitize_spec(dict(mesh.shape), shp, spec)
        return jax.ShapeDtypeStruct(shp, dtype, sharding=NamedSharding(mesh, sp))

    batch_spec = P(dp_axes)
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        out["tokens"] = sds((b, s), jnp.int32, batch_spec)
        out["targets"] = sds((b, s), jnp.int32, batch_spec)
    elif shape.kind == "prefill":
        out["tokens"] = sds((b, s), jnp.int32, batch_spec)
    elif shape.kind == "decode":
        out["tokens"] = sds((b, 1), jnp.int32, batch_spec)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        raise ValueError(shape.kind)

    if cfg.encoder is not None and shape.kind in ("train", "prefill"):
        out["enc_input"] = sds(
            (b, cfg.encoder.enc_seq, cfg.d_model), jnp.bfloat16, batch_spec
        )
    if cfg.encoder is not None and shape.kind == "decode":
        out["enc_out"] = sds(
            (b, cfg.encoder.enc_seq, cfg.d_model), jnp.bfloat16, batch_spec
        )
    return out


# ---------------------------------------------------------------------------
# synthetic corpus

@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05  # fraction of uniformly-random tokens


class SyntheticLMPipeline:
    """Affine Markov token stream: t_{i+1} = (a·t_i + b) mod V, with a small
    uniform-noise fraction. Deterministic given (seed, step)."""

    def __init__(self, cfg: DataConfig, mesh: Optional[Mesh] = None,
                 dp_axes: Tuple[str, ...] = ("data",)):
        self.cfg = cfg
        self.mesh = mesh
        self.dp_axes = dp_axes
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # affine params coprime-ish with V for long cycles
        self.a = int(rng.integers(2, max(3, v - 1))) | 1
        self.b = int(rng.integers(1, v))

    def _batch_np(self, step: int) -> np.ndarray:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        t0 = rng.integers(0, c.vocab_size, size=(c.global_batch, 1))
        toks = [t0]
        for _ in range(c.seq_len):
            nxt = (self.a * toks[-1] + self.b) % c.vocab_size
            noise_mask = rng.random((c.global_batch, 1)) < c.noise
            rand = rng.integers(0, c.vocab_size, size=(c.global_batch, 1))
            toks.append(np.where(noise_mask, rand, nxt))
        return np.concatenate(toks, axis=1).astype(np.int32)  # (B, S+1)

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        arr = self._batch_np(step)
        tokens, targets = arr[:, :-1], arr[:, 1:]
        if self.mesh is not None:
            sh = NamedSharding(self.mesh, P(self.dp_axes))
            tokens = jax.device_put(tokens, sh)
            targets = jax.device_put(targets, sh)
        else:
            tokens, targets = jnp.asarray(tokens), jnp.asarray(targets)
        return {"tokens": tokens, "targets": targets}

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
