"""Failure-aware training runtime: the public entry point that unifies the
uniform and nonuniform-TP stacks behind one session API (DESIGN.md §2)."""
from repro.core.nonuniform import FailurePlan  # noqa: F401
from repro.core.ntp_train import Mode, NTPModelConfig  # noqa: F401
from repro.runtime.events import (  # noqa: F401
    ClusterHealth, DeadReplicaError, FailureEvent, plan_from_health,
)
from repro.runtime.session import NTPSession  # noqa: F401
