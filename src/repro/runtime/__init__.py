"""Failure-aware training runtime: the public entry point that unifies the
uniform and nonuniform-TP stacks behind one session API (DESIGN.md §2), plus
the trace-driven lifecycle orchestrator (DESIGN.md §2.4)."""
from repro.core.nonuniform import FailurePlan, StagedPlan, as_staged  # noqa: F401
from repro.core.ntp_train import Mode, NTPModelConfig  # noqa: F401
from repro.runtime.events import (  # noqa: F401
    CLEAR_DEGRADATION, DEGRADATION_EVENTS, EVENT_KIND_NAMES, ClusterHealth,
    DeadReplicaError, DomainDegradation, FailureEvent, HealthEvent,
    HealthState, LifecycleEvent, LinkDegradeEvent, LinkRepairEvent,
    RecoveryEvent, SdcClearEvent, SdcSuspectEvent, StagedHealth,
    StragglerClearEvent, StragglerEvent, event_kind, inverse,
    plan_from_health, resolve_serving_domain, staged_plan_from_health,
)
from repro.runtime.orchestrator import (  # noqa: F401
    PowerDecision, PowerPolicy, ScheduledEvent, TraceRunner, power_policy,
    schedule_from_trace,
)
from repro.runtime.session import NTPSession  # noqa: F401
