"""NTPSession — the single runtime entry point for training under failures
(DESIGN.md §2).

One façade over the two training stacks:

* the **NTP prototype** (core/ntp_train.py): unit-buffered nonuniform TP
  inside shard_map, failure-event-driven replanning, pluggable optimizer;
* the **production arch stack** (train/steps.py make_setup): GSPMD-sharded
  arch-config models — uniform only (a failure there is a full restart; the
  NTP backend is the paper's mitigation).

Session lifecycle::

    session = NTPSession.create(cfg, mesh, local_batch=4,
                                optimizer=optim.adamw(AdamWConfig(lr=1e-2)))
    for i, batch in ...:
        if gpu_died:
            session.apply(FailureEvent(step=i, replica=r))   # replan in place
        if gpu_repaired:
            session.apply(RecoveryEvent(step=i, domain=d))   # TP back up
        metrics = session.step(batch)                        # loss, grad_norm
    session.save("ckpt.npz")                                 # canonical layout

`apply()` transitions FailurePlan -> FailurePlan' by moving params AND
optimizer state through the unified reshard engine's direct packed→packed
transition (repro.reshard, DESIGN.md §3.3) — the checkpoint-free equivalent
of the paper's restart; only units whose rank changes move, fused into one
bucketed send per rank pair (`session.last_transition` has the ledger).
It runs in BOTH directions: a `FailureEvent` lowers a replica's TP, a
`RecoveryEvent` raises it back toward full (DESIGN.md §2.4). An optional
`PowerPolicy` (runtime/orchestrator.py) is consulted on every transition to
pick per-replica power boost + usable batch (NTP vs NTP-PW) and annotate
step metrics with the boost level and predicted relative iteration time.

The full health-state taxonomy (DESIGN.md §2.11) rides the same `apply()`
path: `StragglerEvent`/`LinkDegradeEvent` leave the TP plan alone but
reprice the policy decision (batch shrink / boost) through the degradation
ledger; `SdcSuspectEvent` quarantines the replica (batch 0) and — when a
`snapshot()` restore point exists — rolls params+optimizer back to it
(`rollback()`, flagged in ``session.last_rollback``); each `*Clear`/
`*Repair` inverse unwinds its onset exactly.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import ntp_train as nt
from repro.core.nonuniform import FailurePlan, StagedPlan, as_staged
from repro.core.ntp_train import Mode, NTPModelConfig
from repro.optim import AdamWConfig, Optimizer, adamw
from repro.runtime.events import (
    DEGRADATION_EVENTS, ClusterHealth, FailureEvent, LifecycleEvent,
    SdcSuspectEvent, StagedHealth, event_kind, plan_from_health,
    staged_plan_from_health,
)


class NTPSession:
    """Stateful training session: owns packed params + optimizer state, the
    jitted step for the current FailurePlan, and the health ledger."""

    # -------------------------------------------------------------- create

    def __init__(self, *_, **__):
        raise TypeError("use NTPSession.create(...) or NTPSession.from_arch(...)")

    @classmethod
    def _new(cls) -> "NTPSession":
        return object.__new__(cls)

    @classmethod
    def create(
        cls,
        cfg: NTPModelConfig,
        mesh,
        *,
        health: Optional[Union[ClusterHealth, StagedHealth]] = None,
        plan: Optional[Union[FailurePlan, StagedPlan]] = None,
        mode: Union[Mode, str] = Mode.NTP,
        local_batch: int = 4,
        optimizer: Optional[Optimizer] = None,
        params: Optional[Dict] = None,     # canonical; default random init
        key=None,
        power_policy=None,                 # orchestrator.PowerPolicy
        spares: int = 0,                   # spare domains absorbing failures
        pp: int = 1,                       # pipeline stages (DESIGN.md §2.6)
        microbatches: int = 1,             # 1F1B chunks per step (pp > 1)
        allocator=None,                    # cluster.GreedyAllocator (pp > 1)
        overlap: bool = False,             # overlapped bucketed sync (§2.10)
        quarantine: bool = True,           # SDC → batch 0 + rollback (§2.11)
    ) -> "NTPSession":
        """NTP-prototype session on a (data=D, model=N1) mesh. ``health``
        and/or ``plan`` seed the failure state (default: pristine).

        ``pp`` > 1 partitions the transformer into contiguous layer stages
        (boundaries from `configs.shapes.stage_boundaries`); health is then
        tracked per (replica, stage) and a failure reduces TP only for the
        stage whose scale-up domain lost the GPU. ``pp=1`` is bit-identical
        to the unstaged session (same step graph, same ledger types).

        ``allocator`` (a `repro.cluster.GreedyAllocator`) turns every
        replan into a GLOBAL search — spares assignable to any stage,
        cost-priced cross-stage swaps — and is what makes ``spares > 0``
        legal at pp > 1 (DESIGN.md §2.7). The session binds the allocator's
        goodput model to its own geometry and calibrates its transition cost
        model from the live packed trees, so predicted bytes match the
        executed `TransferStats` ledger exactly; the latest verdict is kept
        in ``session.last_global_plan``."""
        self = cls._new()
        self._backend = "ntp"
        self._cfg = cfg
        self._mesh = mesh
        self._mode = Mode.coerce(mode)
        self._local_batch = local_batch
        self._optimizer = optimizer or adamw(AdamWConfig(lr=1e-2))
        if power_policy is not None and self._mode is Mode.DP_DROP:
            raise ValueError(
                "a PowerPolicy decides NTP/NTP-PW batches — contradictory "
                "with Mode.DP_DROP (which zeroes degraded replicas)"
            )
        self._policy = power_policy
        self._spares = spares
        from repro.core.overlap import coerce_overlap

        self._overlap = coerce_overlap(overlap)
        self._decision = None
        self._quarantine = quarantine
        self._quarantined = ()
        self._snapshot = None
        self.last_transition = None   # TransferStats of the latest repack
        self.last_global_plan = None  # allocator's latest GlobalPlan verdict
        self.last_rollback = False    # latest apply() rolled back to snapshot
        d, n1 = mesh.shape["data"], mesh.shape["model"]
        if "stage" in getattr(mesh, "axis_names", ()):
            # measured submesh PP (core/pp_submesh, DESIGN.md §2.8): one
            # device slice per pipeline stage, validated before any compute
            from repro.core.pp_submesh import validate_staged_mesh

            validate_staged_mesh(mesh, pp)

        if allocator is not None and pp <= 1:
            raise ValueError(
                "allocator= is the pp>1 global repack planner; pp=1 sessions "
                "already pack globally (plan_from_health handles spares)"
            )
        self._allocator = allocator
        if allocator is not None:
            from repro.cluster import GoodputModel
            from repro.core.policies import WorkloadGeometry
            from repro.core.power import PowerModel

            method = ("ntp_pw" if power_policy is not None
                      and power_policy.name == "ntp_pw" else "ntp")
            allocator.bind(goodput=GoodputModel(
                n1=n1,
                geom=WorkloadGeometry(n_heads=cfg.n_kv_groups,
                                      local_batch=local_batch),
                method=method,
                power=(power_policy.model if power_policy is not None
                       else PowerModel()),
            ))

        if pp < 1:
            raise ValueError(f"pp must be >= 1, got {pp}")
        if isinstance(plan, StagedPlan) and plan.pp == 1:
            plan = plan.stages[0]
        if isinstance(health, StagedHealth) and health.pp == 1:
            health = health.stages[0]
        for given, what in ((plan, "plan"), (health, "health")):
            given_pp = getattr(given, "pp", None)
            if given_pp is not None and pp != 1 and given_pp != pp:
                raise ValueError(
                    f"{what} has {given_pp} stages but pp={pp} was requested"
                )
            if given_pp is not None:
                pp = given_pp
            elif given is not None and pp != 1:
                # a plain FailurePlan/ClusterHealth is ambiguous under pp>1
                # (per-stage state differs by stage); broadcasting failures
                # to every stage would silently change their blast radius
                raise ValueError(
                    f"pp={pp} needs a staged {what} "
                    f"(StagedPlan/StagedHealth), got {type(given).__name__}"
                )
        self._pp = pp
        self._microbatches = microbatches
        if pp == 1:
            if health is None:
                health = (
                    ClusterHealth.from_plan(plan) if plan is not None
                    else ClusterHealth.pristine(d, n1)
                )
            self._health = health
            packed = plan_from_health(health, spares=spares)
            if plan is not None and plan != packed:
                # a plan out of packed order would make replica-addressed
                # events resolve against the wrong physical domain
                raise ValueError(
                    f"plan {plan} is not in resource-manager packed order "
                    f"(most-degraded first); health {health.failed} packs to "
                    f"{packed}"
                )
        else:
            if health is None:
                health = (
                    StagedHealth.from_plan(as_staged(plan))
                    if plan is not None
                    else StagedHealth.pristine(d, n1, pp)
                )
            self._health = health
            packed = self._staged_replan(health, current=None)
            if plan is not None and as_staged(plan) != packed:
                raise ValueError(
                    f"staged plan {plan} is not in per-stage packed order "
                    f"(most-degraded first per stage); health packs to "
                    f"{packed}"
                )
        self._plan = packed
        assert self._plan.d == d and self._plan.n1 == n1, (
            f"plan {self._plan} does not fit mesh (data={d}, model={n1})"
        )
        if pp > 1:
            from repro.configs.shapes import stage_boundaries

            self._boundaries = stage_boundaries(cfg.n_layers, pp)

        canonical = params if params is not None else nt.init_canonical(
            cfg, key if key is not None else jax.random.PRNGKey(0)
        )
        self._params = nt.pack_params(cfg, canonical, self._plan)
        self._opt = self._optimizer.init(self._params)
        if self._allocator is not None:
            # calibrate move pricing from the LIVE trees: predicted bytes of
            # a candidate transition then equal the executed TransferStats
            # ledger exactly (params + every param-like optimizer tree ride
            # the same fused buckets)
            from repro.cluster import TransitionCostModel

            opt_keys = [k for k in self._optimizer.param_like
                        if k in self._opt]
            trees = [self._params] + [self._opt[k] for k in opt_keys]
            self._allocator.bind(cost=TransitionCostModel.from_trees(
                cfg, jax.device_get(trees), pp=pp))
        self._events: List[LifecycleEvent] = []
        self._last_metrics: Dict[str, Any] = {}
        self._decide()
        self._build_step()
        return self

    @classmethod
    def from_arch(
        cls,
        cfg,                    # repro.configs.base.ArchConfig
        shape,                  # repro.configs.shapes.ShapeSpec (kind="train")
        mesh=None,
        *,
        opt_cfg: Optional[AdamWConfig] = None,
        param_dtype=jnp.float32,
        lr_schedule=None,
        key=None,
    ) -> "NTPSession":
        """Uniform session over the production arch stack (make_setup)."""
        import functools

        from repro.optim import warmup_cosine
        from repro.train.steps import make_setup

        self = cls._new()
        self._backend = "arch"
        self._cfg = cfg
        self._mesh = mesh
        self._mode = Mode.UNIFORM
        kw = {}
        if lr_schedule is not None:
            kw["lr_schedule"] = lr_schedule
        self._setup = make_setup(cfg, shape, mesh, param_dtype=param_dtype,
                                 opt_cfg=opt_cfg, **kw)
        self._step_fn = self._setup.jit_step()
        key = key if key is not None else jax.random.PRNGKey(0)
        from repro.optim import adamw_init

        if mesh is not None:
            self._params = jax.jit(
                self._setup.model.init, out_shardings=self._setup.param_sharding
            )(key)
            self._opt = jax.jit(
                lambda p: adamw_init(p, self._setup.opt_cfg),
                out_shardings=self._setup.opt_sharding,
            )(self._params)
        else:
            self._params = self._setup.model.init(key)
            self._opt = adamw_init(self._params, self._setup.opt_cfg)
        self._health = None
        self._plan = None
        self._events = []
        self._last_metrics = {}
        self._policy = None
        self._spares = 0
        self._overlap = False
        self._pp = 1
        self._microbatches = 1
        self._decision = None
        self._stage_rel = None
        self._allocator = None
        self._quarantine = False
        self._quarantined = ()
        self._snapshot = None
        self.last_transition = None
        self.last_global_plan = None
        self.last_rollback = False
        return self

    # ------------------------------------------------------------- introspect

    @property
    def backend(self) -> str:
        """``"ntp"`` (NTPSession.create, full lifecycle surface) or
        ``"arch"`` (NTPSession.from_arch, uniform training only — lifecycle
        calls raise NotImplementedError naming the alternative)."""
        return self._backend

    @property
    def mode(self) -> Mode:
        return self._mode

    @property
    def plan(self) -> Optional[Union[FailurePlan, StagedPlan]]:
        """The live plan: a `FailurePlan` for pp=1 (exactly as before stages
        existed), a `StagedPlan` for pp > 1."""
        return self._plan

    @property
    def health(self) -> Optional[Union[ClusterHealth, StagedHealth]]:
        return self._health

    @property
    def pp(self) -> int:
        return self._pp

    @property
    def overlap(self) -> bool:
        """Whether the step runs the overlapped, bucketed gradient sync
        (core/overlap, DESIGN.md §2.10)."""
        return self._overlap

    @property
    def stage_boundaries(self):
        """Layer boundaries of the pipeline stages (pp+1 ints; pp>1 only)."""
        return self._boundaries if self._pp > 1 else (0, self._cfg.n_layers)

    @property
    def events(self) -> List[LifecycleEvent]:
        return list(self._events)

    @property
    def cfg(self):
        return self._cfg

    @property
    def optimizer(self) -> Optimizer:
        return self._optimizer

    @property
    def local_batch(self) -> int:
        return self._local_batch

    @property
    def local_batches(self):
        """Per-replica usable samples under the current plan (the power
        policy's decision, or the mode's default rule; quarantined replicas
        contribute 0 — DESIGN.md §2.11)."""
        self._require_ntp("local batch accounting")
        if self._decision is not None:
            return list(self._decision.local_batches)
        lbs = list(
            nt.default_local_batches(self._plan, self._mode, self._local_batch)
        )
        for r in self._quarantined:
            lbs[r] = 0
        return lbs

    @property
    def quarantine(self) -> bool:
        """Whether SDC suspicions quarantine their replica and roll back to
        the latest `snapshot()` (DESIGN.md §2.11). Off: SDC events are
        recorded but priced as healthy."""
        return self._quarantine

    @property
    def quarantined(self):
        """Replica indices currently quarantined by an open SDC suspicion
        (empty when quarantine is off or no suspicion is open)."""
        return tuple(self._quarantined)

    @property
    def power_decision(self):
        """The PowerPolicy's verdict for the current plan (None: no policy)."""
        return self._decision

    @property
    def params(self):
        """The live (packed / sharded) parameter tree."""
        return self._params

    @property
    def opt_state(self):
        return self._opt

    @property
    def opt_step(self) -> int:
        return int(jax.device_get(self._opt["step"]))

    def canonical_params(self, replica: int = 0) -> Dict:
        """Dense canonical weights recovered from one replica (NTP backend)."""
        self._require_ntp("canonical weight reconstruction")
        return nt.unpack_params(self._cfg, jax.device_get(self._params),
                                self._plan, replica=replica)

    # ---------------------------------------------------------------- train

    def step(self, batch) -> Dict[str, Any]:
        """One optimizer step; returns the metrics dict (loss, grad_norm, …).
        Under a PowerPolicy the dict additionally carries the policy verdict:
        ``policy``, ``power_boost`` (max ×TDP over replicas) and the
        predicted ``rel_iter_time``.

        With telemetry active the step is wrapped in a ``session.step``
        span. NOTE the span times DISPATCH (jax is async; nothing here
        blocks on device work — that would change recorder-off numerics'
        timing); wall-per-step lives in the orchestrator/bench spans that
        own the `block_until_ready`."""
        tel = telemetry.get()
        with tel.span("session.step", backend=self._backend, pp=self._pp,
                      overlap="on" if self._overlap else "off"):
            self._params, self._opt, metrics = self._step_fn(
                self._params, self._opt, batch
            )
        if tel.enabled and self._decision is not None:
            tel.gauge("train.rel_iter_time", self._decision.rel_iter_time,
                      source="analytic", policy=self._decision.method)
            tel.gauge("train.power_boost", self._decision.max_boost,
                      policy=self._decision.method)
        if self._decision is not None:
            metrics = dict(
                metrics,
                policy=self._decision.method,
                power_boost=self._decision.max_boost,
                rel_iter_time=self._decision.rel_iter_time,
            )
        if self._stage_rel is not None:
            # staged sessions always predict per-stage relative iteration
            # time (slowest stage gates the replica — perf_model's
            # staged_iteration_time rule), policy or not
            metrics = dict(
                metrics,
                stage_rel_iter_time=self._stage_rel,
                rel_iter_time=max(self._stage_rel),
            )
        if getattr(self._step_fn, "submesh", False):
            # the measured submesh path annotates its pipeline schedule: the
            # tick count behind the bubble and the per-step cross-stage
            # hand-off byte table (core/pp_submesh.handoff_accounting)
            metrics = dict(
                metrics,
                pipeline_ticks=self._step_fn.ticks,
                handoff=self._step_fn.handoff,
            )
        self._last_metrics = metrics
        return metrics

    def measure_sync(self, batch) -> Dict[str, Any]:
        """Measure the step's gradient sync in ISOLATION (the probe behind
        `BENCH_train.json`'s overlap rows and `launch/profile.py
        --measure`): run the step's ``grads_fn`` once to materialize a
        gradients tree, then execute ``sync_fn`` to completion under a
        ``train.sync`` telemetry span — phase marks ``issued`` /
        ``completed``, attrs ``collectives`` (static launch count),
        ``sync_s`` (blocking wall seconds) and ``exposed_s``. Measured in
        isolation the sync is fully exposed (``exposed_s == sync_s``); how
        much of it the overlapped step actually hides is the STEP-level
        difference bench_hotpath computes from the on/off pair, and
        `perf_model.exposed_comm` predicts from the overlappable-compute
        window. Returns the attrs dict. NTP backend only."""
        self._require_ntp("sync measurement")
        step = self._step_fn
        if not hasattr(step, "sync_fn"):
            raise NotImplementedError(
                "this step builder carries no sync probe")
        import time

        tel = telemetry.get()
        _, grads = step.grads_fn(self._params, batch)
        jax.block_until_ready(grads)
        label = "on" if getattr(step, "overlap", False) else "off"
        with tel.span("train.sync", overlap=label,
                      backend=self._backend) as sp:
            sp.mark("issued")
            t0 = time.perf_counter()
            out = step.sync_fn(grads)
            jax.block_until_ready(out)
            sync_s = time.perf_counter() - t0
            sp.mark("completed")
            attrs = {"collectives": int(step.collectives),
                     "sync_s": sync_s, "exposed_s": sync_s}
            sp.set(**attrs)
        return dict(attrs, overlap=label)

    # ---------------------------------------------------------------- events

    def apply(self, event: LifecycleEvent):
        """Consume a lifecycle event: update health, replan, and repack
        params and optimizer state into the new plan — training continues
        with the same logical weights. For a `FailureEvent` that is the
        paper's restart minus the restart (TP goes down); for a
        `RecoveryEvent` it is the missing inverse (TP comes back up, params
        and AdamW state spread back over the repaired ranks).

        On a staged (pp > 1) session the event resolves to ONE pipeline
        stage (`StagedHealth.resolve_site`); only that stage's layer slice
        repacks — stage-local `transition_trees`, zero cross-stage traffic.
        Returns the new plan (`FailurePlan` for pp=1, `StagedPlan` else).

        With telemetry active the whole replan+repack is one
        ``session.transition`` span: phase marks ``planned``/``executed``
        and, when state moved, the executed `TransferStats` ledger attached
        as attributes — the span's byte counts equal ``last_transition``
        exactly (the Perfetto trace carries the same numbers the tests
        assert against)."""
        self._require_ntp("lifecycle replanning")

        tel = telemetry.get()
        self.last_rollback = False
        with tel.span(
            "session.transition",
            kind=event_kind(event),
            pp=self._pp,
        ) as sp:
            new_health = self._health.apply(event)
            if self._pp == 1:
                new_plan = plan_from_health(new_health, spares=self._spares)
            else:
                new_plan = self._staged_replan(new_health, current=self._plan)
            sp.mark("planned")
            self._events.append(event)
            self._health = new_health
            if new_plan == self._plan:
                if isinstance(event, DEGRADATION_EVENTS):
                    # the TP plan is untouched (degradation never removes a
                    # GPU) but the decision surface moved: batches shrink on
                    # straggle/link, SDC quarantines, boosts re-aim
                    before = tuple(self.local_batches)
                    old_mode = self._mode
                    if self._mode is Mode.UNIFORM and not new_health.healthy:
                        self._mode = Mode.NTP
                    self._decide()
                    if (isinstance(event, SdcSuspectEvent)
                            and self._quarantine
                            and self._snapshot is not None):
                        self.rollback()
                    if (self._mode is not old_mode
                            or tuple(self.local_batches) != before):
                        self._build_step()
                    sp.set(changed=False, degraded=True,
                           rollback=self.last_rollback)
                    return self._plan
                sp.set(changed=False)
                return self._plan

            old_plan = self._plan
            if self._pp == 1:
                self._transition(old_plan, new_plan)
            else:
                self._transition_staged(old_plan, new_plan)
            sp.mark("executed")
            sp.set(changed=True, old_plan=str(old_plan),
                   new_plan=str(new_plan), **self.last_transition.as_dict())
            if tel.enabled:
                tel.gauge("cluster.transition_bytes",
                          self.last_transition.bytes_moved, source="executed")
            self._plan = new_plan
            if self._mode is Mode.UNIFORM and not new_plan.healthy:
                self._mode = Mode.NTP  # uniform degrades into NTP, not death
            self._decide()
            if (isinstance(event, SdcSuspectEvent) and self._quarantine
                    and self._snapshot is not None):
                self.rollback()
            self._build_step()
            return new_plan

    # ------------------------------------------------------------ checkpoint

    def save(self, path: str) -> None:
        """Write params + optimizer state in CANONICAL layout: restorable
        into a session running under any FailurePlan."""
        self._require_ntp("canonical checkpointing")
        tree = {
            "params": self.canonical_params(),
            "opt": self._canonical_opt(),
        }
        save_checkpoint(path, tree, step=self.opt_step)

    def restore(self, path: str) -> int:
        """Load a canonical checkpoint into the CURRENT plan's packing.
        Returns the saved step. Leaves restore at the dtype they were SAVED
        with (the checkpoint's recorded dtype wins over the live tree's —
        repro/checkpoint/checkpoint.py); cast after restore to convert."""
        self._require_ntp("canonical checkpointing")
        like = {
            "params": self.canonical_params(),
            "opt": self._canonical_opt(),
        }
        tree, step = load_checkpoint(path, like)
        self._params = nt.pack_params(self._cfg, tree["params"], self._plan)
        self._opt = self._pack_opt(tree["opt"])
        return step if step is not None else self.opt_step

    def snapshot(self) -> None:
        """Capture an in-memory canonical restore point — params AND
        optimizer state in the same layout `save` writes, minus the file.
        `rollback()` repacks it into whatever plan is live at rollback time,
        so the snapshot survives any number of fail/repair transitions in
        between (DESIGN.md §2.11: the quarantine rollback target)."""
        self._require_ntp("SDC rollback snapshots")
        self._snapshot = {
            "params": self.canonical_params(),
            "opt": self._canonical_opt(),
        }

    def rollback(self) -> int:
        """Restore the latest `snapshot()` into the CURRENT plan's packing —
        the checkpoint-free analogue of `restore`, used when an SDC
        suspicion quarantines a replica and its recent updates are
        untrusted. Returns the restored optimizer step. `apply()` invokes
        this automatically on `SdcSuspectEvent` when quarantine is on and a
        snapshot exists; ``session.last_rollback`` records that it fired."""
        self._require_ntp("SDC rollback snapshots")
        if self._snapshot is None:
            raise RuntimeError(
                "no restore point: call session.snapshot() before relying "
                "on SDC rollback"
            )
        self._params = nt.pack_params(
            self._cfg, self._snapshot["params"], self._plan
        )
        self._opt = self._pack_opt(self._snapshot["opt"])
        self.last_rollback = True
        return self.opt_step

    # ---------------------------------------------------------------- private

    def _require_ntp(self, what: str) -> None:
        """Guard for features the arch backend does not implement. The error
        names the caller that hit it (the public method, via the stack) and
        the ``what`` feature, so a trace replay or launcher flag that lands
        here is diagnosable without reading this file."""
        if self._backend != "ntp":
            import inspect

            frame = inspect.stack()[1]
            raise NotImplementedError(
                f"NTPSession.{frame.function}() needs {what}, which only the "
                "NTP prototype backend implements — this session was built "
                "with NTPSession.from_arch() (uniform training via "
                "train/steps.make_setup; a failure there is a full restart). "
                "Build the session with NTPSession.create(...) — e.g. "
                "launch/train.py --ntp instead of --arch — to use lifecycle "
                "events, canonical checkpoints, or power policies."
            )

    def _staged_replan(self, health: StagedHealth, *, current):
        """One pp>1 replan: the global allocator when bound (joint spares /
        swap search, moves priced against ``current``'s in-place state —
        verdict kept in ``last_global_plan``), stage-local packing
        otherwise."""
        if self._allocator is not None:
            gp = self._allocator.plan(health, spares=self._spares,
                                      current=current)
            self.last_global_plan = gp
            return gp.staged_plan
        return staged_plan_from_health(health, spares=self._spares)

    def _replica_degradations(self):
        """Per-replica merged degradation ledgers of the current health, or
        None when the health carries none — the binary fail/repair path then
        passes ``degradations=None`` everywhere and every decision stays
        bit-identical to the pre-taxonomy sessions. SDC entries are masked
        out when quarantine is off (the operator opted out of trusting the
        detector), so only straggle/link pricing remains."""
        h = self._health
        if isinstance(h, StagedHealth):
            if all(st.degraded is None for st in h.stages):
                return None
        elif h.degraded is None:
            return None
        degs = h.replica_degradations()
        if not self._quarantine and any(d.sdc for d in degs):
            from dataclasses import replace

            degs = tuple(replace(d, sdc=0) for d in degs)
        return degs

    def _decide(self) -> None:
        """Consult the PowerPolicy (if any) for the current plan. Geometry is
        derived from the live model: attention quantizes at kv-group (unit)
        granularity. A staged plan decides on its `effective` (slowest-stage)
        reduction and additionally predicts per-stage relative iteration
        times for the step metrics. The health's degradation ledgers ride
        along (§2.11): stragglers/links reprice the slowdown, open SDC
        suspicions quarantine their replica (batch 0)."""
        from repro.core.policies import WorkloadGeometry

        self._stage_rel = None
        degs = self._replica_degradations()
        self._quarantined = (
            tuple(r for r, dg in enumerate(degs) if dg.sdc > 0)
            if degs is not None else ()
        )
        eff_plan = self._plan.effective if self._pp > 1 else self._plan
        geom = (self._policy.geom if self._policy is not None else None) or \
            WorkloadGeometry(
                n_heads=self._cfg.n_kv_groups, local_batch=self._local_batch
            )
        if self._policy is None:
            self._decision = None
        else:
            self._decision = self._policy.decide(
                eff_plan, local_batch=self._local_batch, geom=geom,
                degradations=degs,
            )
        if self._pp > 1:
            from repro.core.policies import staged_rel_iter_times
            from repro.core.power import PowerModel

            if self._decision is not None:
                boosts = self._decision.boost
                lbs = self._decision.local_batches
                power = self._policy.model
            else:
                boosts = None
                lbs = [int(b) for b in nt.default_local_batches(
                    eff_plan, self._mode, self._local_batch
                )]
                for r in self._quarantined:
                    lbs[r] = 0
                lbs = tuple(lbs)
                power = PowerModel()
            if degs is not None:
                slow_factors = tuple(dg.slow_factor for dg in degs)
                bw_fracs = tuple(dg.bw_frac for dg in degs)
            else:
                slow_factors = bw_fracs = None
            self._stage_rel = staged_rel_iter_times(
                self._plan.stage_tp, self._plan.n1, geom,
                local_batches=lbs, local_batch=self._local_batch,
                boosts=boosts, power=power,
                slow_factors=slow_factors, bw_fracs=bw_fracs,
            )

    def _build_step(self) -> None:
        if self._decision is not None:
            lbs = self._decision.local_batches
        elif self._quarantined:
            lbs = tuple(self.local_batches)
        else:
            lbs = None  # the builder's default rule — binary path unchanged
        self._step_fn = nt.make_ntp_train_step(
            self._cfg, self._plan, self._mesh, mode=self._mode,
            local_batch=self._local_batch, optimizer=self._optimizer,
            local_batches=lbs,
            microbatches=self._microbatches,
            overlap=self._overlap,
        )

    def _transition(self, old: FailurePlan, new: FailurePlan) -> None:
        """One fused packed→packed transition for params AND every
        param-like optimizer leaf tree (AdamW m/v/master): all of them ride
        the same per-(replica, src, dst) buckets, so the whole fail/repair
        move is one bucketed send per rank pair — O(moved units), not
        O(model), host traffic (repro.reshard.transition). The transfer
        accounting is kept in `last_transition`."""
        from repro.reshard.transition import transition_trees

        opt = jax.device_get(self._opt)
        opt_keys = [k for k in self._optimizer.param_like if k in opt]
        trees = [jax.device_get(self._params)] + [opt[k] for k in opt_keys]
        moved, stats = transition_trees(self._cfg, trees, old, new)
        self._params = moved[0]
        self._opt = dict(opt, **dict(zip(opt_keys, moved[1:])))
        self.last_transition = stats

    def _transition_staged(self, old: StagedPlan, new: StagedPlan) -> None:
        """Stage-local transitions via `transition_staged_trees`: only the
        stages whose plan changed repack their layer slice (their own
        per-(replica, src, dst) buckets, tagged by stage). The session owns
        its trees exclusively, so untouched stages pass through with zero
        bytes and zero copies (``copy_unchanged=False``)."""
        from repro.reshard.transition import transition_staged_trees

        opt = jax.device_get(self._opt)
        opt_keys = [k for k in self._optimizer.param_like if k in opt]
        trees = [jax.device_get(self._params)] + [opt[k] for k in opt_keys]
        moved, stats = transition_staged_trees(
            self._cfg, trees, old, new, copy_unchanged=False
        )
        self._params = moved[0]
        self._opt = dict(opt, **dict(zip(opt_keys, moved[1:])))
        self.last_transition = stats

    def _canonical_opt(self) -> Dict:
        opt = jax.device_get(self._opt)
        return {
            k: (
                nt.unpack_params(self._cfg, v, self._plan)
                if k in self._optimizer.param_like else v
            )
            for k, v in opt.items()
        }

    def _pack_opt(self, canonical_opt: Dict) -> Dict:
        return {
            k: (
                nt.pack_params(self._cfg, v, self._plan)
                if k in self._optimizer.param_like else v
            )
            for k, v in canonical_opt.items()
        }
