"""Trace-driven failure/recovery orchestration: the full NTP lifecycle
(pristine → degraded → boosted → repaired) replayed against a live
`NTPSession` (DESIGN.md §2.4).

Three pieces:

* `PowerPolicy` — the NTP vs NTP-PW decision hook (paper §3.2, Table 1): on
  every lifecycle transition it consults `core.power.PowerModel` to pick each
  replica's power boost and usable local batch, and predicts the job's
  relative iteration time (recorded into step metrics by the session).
* `schedule_from_trace` — converts `core.failure_model.simulate_events`
  output (per-event (domain, gpu) placement + recovery times) into a timed
  `ScheduledEvent` list of `FailureEvent`/`RecoveryEvent` for a job-scale
  cluster (one scale-up domain per DP replica).
* `TraceRunner` — replays a schedule against a real session, optionally
  asserting canonical-equivalence to a dense uniform reference at every step
  and at every transition (the paper's availability story, §2.3/§6.1, as an
  executable test harness).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

import jax

from repro import telemetry
from repro.core import ntp_train as nt
from repro.core.failure_model import FailureTraceConfig, simulate_events
from repro.core.nonuniform import FailurePlan
from repro.core.policies import (
    WorkloadGeometry, boosted_operating_point, stage_slowdown,
)
from repro.core.power import PowerModel
from repro.runtime.events import (
    FailureEvent, LifecycleEvent, RecoveryEvent, SdcSuspectEvent, event_kind,
)

POLICY_NAMES = ("ntp", "ntp_pw")


@dataclass(frozen=True)
class PowerDecision:
    """One policy verdict for one `FailurePlan`."""

    method: str                      # "uniform" | "ntp" | "ntp_pw"
    boost: Tuple[float, ...]         # per-replica power multiplier (×TDP)
    local_batches: Tuple[int, ...]   # per-replica usable samples
    rel_iter_time: float             # predicted job iter time (1.0 = healthy)

    @property
    def max_boost(self) -> float:
        return max(self.boost)


@dataclass(frozen=True)
class PowerPolicy:
    """Decides, per lifecycle transition, how degraded replicas keep pace:

    * ``ntp``    — no boost; shrink local batch ∝ surviving TP (paper §3.1).
    * ``ntp_pw`` — repurpose the failed GPUs' power budget (capped at the
      rack's ``max_boost``, §3.2) and keep as much of the full local batch as
      the boosted speed sustains; shrink only past the cap (Table 1).
    """

    name: str = "ntp"
    model: PowerModel = PowerModel()
    geom: Optional[WorkloadGeometry] = None

    def __post_init__(self):
        if self.name not in POLICY_NAMES:
            raise ValueError(f"policy {self.name!r} not in {POLICY_NAMES}")

    def decide(self, plan: FailurePlan, *, local_batch: int,
               geom: Optional[WorkloadGeometry] = None,
               degradations=None) -> PowerDecision:
        """Per-replica operating points for ``plan``. ``degradations`` is
        the optional per-replica `DomainDegradation` view
        (`ClusterHealth.replica_degradations` /
        `StagedHealth.replica_degradations`): stragglers and degraded links
        ride the SAME NTP degrade math as GPU absence — the slow factor
        multiplies the stage slowdown, NTP sheds batch to not straggle,
        NTP-PW boosts the degraded domain's rack first (DESIGN.md §2.11). A
        replica with an open SDC suspicion is QUARANTINED: batch 0, no
        boost — the session rolls it back and it rejoins on the clear."""
        geom = geom or self.geom or WorkloadGeometry()
        geom = replace(geom, local_batch=local_batch)
        n1 = plan.n1
        ntp_lb = plan.local_batch_fraction(local_batch)
        boosts, lbs, rels = [], [], []
        for r, t in enumerate(plan.replica_tp):
            deg = degradations[r] if degradations is not None else None
            if deg is not None and deg.sdc > 0:
                # quarantined: contributes no samples and gates nothing
                boosts.append(1.0)
                lbs.append(0)
                rels.append(0.0)
                continue
            sf = deg.slow_factor if deg is not None else 1.0
            bw = deg.bw_frac if deg is not None else 1.0
            if t == n1 and sf == 1.0 and bw == 1.0:
                boosts.append(1.0)
                lbs.append(local_batch)
                rels.append(1.0)
                continue
            slow = stage_slowdown(t, n1, geom, slow_factor=sf, bw_frac=bw)
            # the un-boosted share: ∝-TP packing for pure GPU absence, the
            # full slowdown floor once degradation compounds it
            base_bs = (int(ntp_lb[r]) if sf == 1.0 and bw == 1.0
                       else min(int(ntp_lb[r]),
                                int(np.floor(local_batch / slow))))
            if self.name == "ntp_pw":
                # shared Table-1 operating point (core/policies.py); shed
                # batch only past the rack cap, and never below the
                # un-boosted share
                p, eff = boosted_operating_point(slow, self.model)
                bs = int(np.clip(np.floor(local_batch / eff),
                                 max(1, base_bs), local_batch))
            else:
                p = 1.0
                eff = slow
                bs = base_bs
            boosts.append(float(p))
            lbs.append(bs)
            rels.append(eff * bs / local_batch)
        degraded = degradations is not None and any(
            not d.clear for d in degradations
        )
        method = "uniform" if plan.healthy and not degraded else self.name
        return PowerDecision(
            method=method, boost=tuple(boosts), local_batches=tuple(lbs),
            rel_iter_time=float(max(rels)),
        )


def power_policy(name: str, *, model: Optional[PowerModel] = None,
                 geom: Optional[WorkloadGeometry] = None) -> PowerPolicy:
    """Factory for the CLI spelling (``ntp`` / ``ntp_pw`` / ``ntp-pw``)."""
    return PowerPolicy(name=name.lower().replace("-", "_"),
                       model=model or PowerModel(), geom=geom)


# ---------------------------------------------------------------------------
# trace -> timed event schedule

@dataclass(frozen=True)
class ScheduledEvent:
    step: int
    event: LifecycleEvent


def schedule_from_trace(
    cfg: FailureTraceConfig, *, steps: int, steps_per_hour: float = 1.0,
    pp: int = 1,
) -> List[ScheduledEvent]:
    """Timed fail/repair schedule for a job whose cluster is described by
    ``cfg`` — one scale-up domain per (DP replica × pipeline stage)
    (``cfg.n_gpus = D × pp × n1``, ``cfg.domain_size = n1``). Every
    simulated failure becomes a domain-addressed `FailureEvent` at its onset
    step and a matching `RecoveryEvent` at its repair step; failures already
    live at step 0 (lead-in) are injected at step 0, and repairs beyond the
    horizon are dropped (the GPU stays down for the rest of the run).

    With ``pp > 1`` the trace's global domain ids follow the replica-major
    numbering of `StagedHealth` (domain ``g`` → stage ``g % pp``, in-stage
    domain ``g // pp``) and events carry an explicit ``stage=`` so the
    session degrades ONLY the stage whose domain was hit.

    A MIXED trace (``cfg.straggler_rate_mult`` etc. — DESIGN.md §2.11) maps
    each degradation interval to its typed onset/clear event pair carrying
    the sampled severity: straggler → `StragglerEvent(slowdown)` /
    `StragglerClearEvent`, link → `LinkDegradeEvent(bw_frac)` /
    `LinkRepairEvent`, sdc → `SdcSuspectEvent` / `SdcClearEvent`. Binary
    traces take the identical code path with kind 0 everywhere."""
    from repro.core.failure_model import (
        KIND_LINK, KIND_SDC, KIND_STRAGGLER,
    )
    from repro.runtime.events import (
        LinkDegradeEvent, LinkRepairEvent, SdcClearEvent, SdcSuspectEvent,
        StragglerClearEvent, StragglerEvent,
    )

    ev = simulate_events(cfg)
    out: List[ScheduledEvent] = []
    for i in range(ev.n_events):
        s0 = max(0, int(np.ceil(ev.start_h[i] * steps_per_hour)))
        s1 = int(np.ceil(ev.end_h[i] * steps_per_hour))
        dom = int(ev.domain[i])
        if s1 <= 0 or s0 >= steps or s1 <= s0:
            continue
        addr = (
            {"domain": dom} if pp == 1
            else {"domain": dom // pp, "stage": dom % pp}
        )
        kind = int(ev.kind[i]) if ev.kind is not None else 0
        if kind == KIND_STRAGGLER:
            sev = {"slowdown": float(ev.severity[i])}
            onset, clear = StragglerEvent, StragglerClearEvent
        elif kind == KIND_LINK:
            sev = {"bw_frac": float(ev.severity[i])}
            onset, clear = LinkDegradeEvent, LinkRepairEvent
        elif kind == KIND_SDC:
            sev = {}
            onset, clear = SdcSuspectEvent, SdcClearEvent
        else:
            sev = {}
            onset, clear = FailureEvent, RecoveryEvent
        out.append(ScheduledEvent(s0, onset(step=s0, **addr, **sev)))
        if s1 < steps:
            out.append(ScheduledEvent(s1, clear(step=s1, **addr, **sev)))
    # clears/repairs before onsets at the same step: a same-step repair can
    # make an otherwise replica-killing failure legal (and never the reverse)
    return sorted(
        out,
        key=lambda e: (e.step, not isinstance(
            e.event,
            (RecoveryEvent, StragglerClearEvent, LinkRepairEvent,
             SdcClearEvent),
        )),
    )


# ---------------------------------------------------------------------------
# lifecycle replay

class TraceRunner:
    """Replays a `ScheduledEvent` list against a live `NTPSession`.

    With ``verify=True`` it co-trains a dense single-logical-copy reference
    (same optimizer, same batches, the session's per-replica sample masks)
    and asserts f32-level agreement of the loss at EVERY step (``atol``) and
    of the canonical weights at every lifecycle transition (``param_atol``,
    default ``atol``) — including the upward (repair) transitions that
    restore full TP. Requires a fresh session. Note AdamW's rsqrt update
    amplifies f32 rounding noise into ~1e-4 weight deltas per step even with
    identical math, so long AdamW runs need a looser ``param_atol``; SGD is
    tight at any length.

    Device metrics (loss, grad_norm) are BUFFERED, not synced per step:
    ``float(metrics["loss"])`` every step would block dispatch on the whole
    step's device work (the classic eager-host-sync stall). Instead the
    device scalars are kept in the history records and drained through one
    ``jax.device_get`` every ``drain_every`` steps and at the end of
    ``run()`` — callers still see plain floats, with identical values.
    ``verify=True`` keeps the eager sync (the per-step loss assertion needs
    the value immediately).

    With telemetry active every consumed event becomes an
    ``orchestrator.event`` span (phase marks over arrival→plan→execute→
    verified; the session's own ``session.transition`` span nests inside)
    and every step records the ``train.goodput`` /
    ``train.goodput_unboosted`` gauges the goodput-decomposition report
    folds (launch/telemetry_report.py).
    """

    def __init__(
        self,
        session,
        schedule: List[ScheduledEvent],
        *,
        verify: bool = False,
        atol: float = 1e-4,
        param_atol: Optional[float] = None,
        on_event: Optional[Callable[[LifecycleEvent, FailurePlan], None]] = None,
        drain_every: int = 16,
    ):
        self.session = session
        self.schedule = sorted(schedule, key=lambda e: e.step)
        self.verify = verify
        self.atol = atol
        self.param_atol = atol if param_atol is None else param_atol
        self.on_event = on_event
        self.history: List[Dict] = []
        self.transitions: List[Dict] = []
        self._next_step = 0
        self.drain_every = max(1, drain_every)
        self._undrained: List[Dict] = []
        self._repair_debt: Dict[int, int] = {}  # domain -> GPUs never failed
        self._ref_snapshot = None
        has_sdc = any(
            isinstance(e.event, SdcSuspectEvent) for e in self.schedule
        )
        if has_sdc and getattr(session, "quarantine", False):
            # arm the quarantine rollback target before any step runs —
            # without a snapshot an SdcSuspectEvent only zeroes the batch
            session.snapshot()
        if verify:
            if session.opt_step != 0:
                raise ValueError("verify=True needs a fresh (step-0) session")
            self._ref_loss = jax.jit(
                jax.value_and_grad(nt.make_reference_loss(session.cfg))
            )
            self._ref_params = session.canonical_params()
            self._ref_opt = session.optimizer.init(self._ref_params)
            # the dense reference rolls back to the SAME restore point the
            # session does (trees are replaced functionally per step, so
            # holding the references pins step-0 state)
            self._ref_snapshot = (self._ref_params, self._ref_opt)

    # ------------------------------------------------------------- internals

    def _mask(self):
        lb = self.session.local_batches
        full = self.session.local_batch
        return np.concatenate(
            [(np.arange(full) < b).astype(np.float32) for b in lb]
        )

    def _site(self, ev):
        """Debt-ledger key for an event's blast site: the (stage, domain)
        pair on a staged session, the plain domain id otherwise."""
        h = self.session.health
        return (
            h.resolve_site(ev) if hasattr(h, "resolve_site")
            else h.resolve_domain(ev)
        )

    def _check_canonical(self, where: str) -> float:
        got = self.session.canonical_params()
        err = max(
            float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
            for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(self._ref_params))
        )
        assert err < self.param_atol, (
            f"{where}: canonical params diverged from dense reference "
            f"(max abs err {err:.3e})"
        )
        return err

    def _apply_due(self, step: int) -> List[LifecycleEvent]:
        from repro.runtime.events import DeadReplicaError

        applied = []
        tel = telemetry.get()
        while self.schedule and self.schedule[0].step <= step:
            ev = self.schedule.pop(0).event
            kind = event_kind(ev)
            if tel.enabled:
                tel.counter("orchestrator.events", kind=kind)
            with tel.span("orchestrator.event", kind=kind) as sp:
                sp.set(step=step, replica=getattr(ev, "replica", None),
                       domain=getattr(ev, "domain", None),
                       stage=getattr(ev, "stage", None))
                applied += self._apply_one(step, ev, sp)
        return applied

    def _apply_one(self, step: int, ev, sp) -> List[LifecycleEvent]:
        """Consume ONE due event inside its ``orchestrator.event`` span
        (``sp``); returns [ev] if it mutated the session, [] when the event
        was absorbed against repair debt or rejected by the session."""
        from repro.runtime.events import DeadReplicaError

        old_plan = self.session.plan
        if isinstance(ev, RecoveryEvent):
            # a repair whose failure was rejected must not touch the
            # ledger: its GPU was never marked failed, and applying it
            # would raise TP for hardware that is actually still down
            site = self._site(ev)
            debt = self._repair_debt.get(site, 0)
            if debt:
                absorbed = min(debt, ev.n_gpus)
                self._repair_debt[site] = debt - absorbed
                if absorbed == ev.n_gpus:
                    self.transitions.append({
                        "step": step, "kind": "absorbed", "event": ev,
                        "old_plan": old_plan, "new_plan": old_plan,
                    })
                    sp.set(outcome="absorbed")
                    return []
                if isinstance(site, tuple):
                    ev = RecoveryEvent(step=ev.step, stage=site[0],
                                       domain=site[1],
                                       n_gpus=ev.n_gpus - absorbed)
                else:
                    ev = RecoveryEvent(step=ev.step, domain=site,
                                       n_gpus=ev.n_gpus - absorbed)
        sp.mark("plan")
        try:
            new_plan = self.session.apply(ev)
        except DeadReplicaError as e:
            # the blast would leave a replica with no GPUs — outside
            # NTP's regime (DP_DROP / spares territory, paper §3.3).
            # The session refused before mutating; remember the debt so
            # the GPU's matching repair is absorbed, not applied.
            site = self._site(ev)
            self._repair_debt[site] = (
                self._repair_debt.get(site, 0) + ev.n_gpus
            )
            self.transitions.append({
                "step": step, "kind": "rejected", "event": ev,
                "old_plan": old_plan, "new_plan": old_plan,
                "error": str(e),
            })
            sp.set(outcome="rejected", error=str(e))
            return []
        sp.mark("execute")
        rec = {
            "step": step,
            "kind": event_kind(ev),
            "event": ev,
            "old_plan": old_plan,
            "new_plan": new_plan,
        }
        if getattr(self.session, "last_rollback", False):
            # the session rolled back to its snapshot (SDC quarantine);
            # mirror the same restore point onto the dense reference so the
            # f32 equivalence survives the discarded updates
            rec["rollback"] = True
            sp.set(rollback=True)
            if self.verify:
                self._ref_params, self._ref_opt = self._ref_snapshot
                rec["canonical_err"] = self._check_canonical(
                    f"step {step} (sdc quarantine rollback)"
                )
                sp.mark("verified")
        gp = getattr(self.session, "last_global_plan", None)
        if gp is not None:
            # allocator-driven session: keep the global verdict (spare
            # sites, swaps, priced actions) with the transition record
            rec["global_plan"] = gp
        if self.verify and new_plan != old_plan:
            rec["canonical_err"] = self._check_canonical(
                f"step {step} ({rec['kind']} transition {old_plan} -> {new_plan})"
            )
            sp.mark("verified")
        self.transitions.append(rec)
        sp.set(outcome="applied", old_plan=str(old_plan),
               new_plan=str(new_plan))
        if self.on_event is not None:
            self.on_event(ev, new_plan)
        return [ev]

    # ------------------------------------------------------------------ run

    def run(self, batch_fn: Callable[[int], object], steps: int) -> List[Dict]:
        """Drive ``steps`` optimizer steps, consuming due events before each.
        ``batch_fn(step)`` must return the full (D·local_batch, S+1) token
        batch. Resumable: repeated calls continue the global step counter.
        Returns the metrics history of THIS call's steps."""
        first = self._next_step
        tel = telemetry.get()
        for i in range(first, first + steps):
            applied = self._apply_due(i)
            batch = batch_fn(i)
            metrics = self.session.step(batch)
            rec = {
                "step": i,
                # device scalars on purpose: float() here would host-sync
                # every step and stall async dispatch; _drain() converts
                # them in one batched jax.device_get
                "loss": metrics["loss"],
                "grad_norm": metrics["grad_norm"],
                "replica_tp": self.session.plan.replica_tp,
                "local_batches": tuple(int(b) for b in self.session.local_batches),
                "events_applied": len(applied),
            }
            if getattr(self.session.plan, "pp", 1) > 1:
                rec["stage_tp"] = self.session.plan.stage_tp
            if getattr(self.session, "quarantined", ()):
                rec["quarantined"] = self.session.quarantined
            for k in ("power_boost", "rel_iter_time", "stage_rel_iter_time",
                      "policy"):
                if k in metrics:
                    rec[k] = metrics[k]
            self.history.append(rec)
            self._undrained.append(rec)
            if tel.enabled:
                full = self.session.local_batch * self.session.plan.d
                policy = str(rec.get("policy", "none"))
                tel.gauge("train.goodput", sum(rec["local_batches"]) / full,
                          policy=policy)
                base = nt.default_local_batches(
                    self.session.plan, self.session.mode,
                    self.session.local_batch)
                tel.gauge("train.goodput_unboosted",
                          sum(int(b) for b in base) / full, policy=policy)
                # goodput lost to DEGRADATION (straggle/link shed +
                # quarantine) beyond what GPU absence alone implies — the
                # taxonomy-attributed slice telemetry_report folds (§2.11)
                deg_loss = max(
                    0, sum(int(b) for b in base) - sum(rec["local_batches"])
                ) / full
                tel.gauge("train.goodput_degradation_loss", deg_loss,
                          policy=policy)
            if self.verify:
                self._drain()  # the dense-reference compare needs host values
                rl = self._ref_step(batch)
                diff = abs(rec["loss"] - rl)
                assert diff < self.atol, (
                    f"step {i}: NTP loss {rec['loss']:.6f} diverged from dense "
                    f"reference {rl:.6f} (|diff| {diff:.3e})"
                )
                rec["ref_loss"] = rl
            elif len(self._undrained) >= self.drain_every:
                self._drain()
        self._next_step = first + steps
        self._drain()
        if self.verify:
            self._check_canonical("end of run")
        return self.history[first:]

    def _drain(self) -> None:
        """One batched host sync for the buffered step metrics. History
        records are mutated in place, so anything already handed out (the
        `run` return value aliases `self.history`) sees plain floats."""
        if not self._undrained:
            return
        pending = [(r, k) for r in self._undrained for k in ("loss", "grad_norm")
                   if not isinstance(r[k], float)]
        if pending:
            host = jax.device_get([r[k] for r, k in pending])
            for (r, k), v in zip(pending, host):
                r[k] = float(v)
        self._undrained.clear()

    def _ref_step(self, batch) -> float:
        import jax.numpy as jnp

        mask = jnp.asarray(self._mask())
        rl, g = self._ref_loss(self._ref_params, batch, mask)
        self._ref_params, self._ref_opt, _ = self.session.optimizer.update(
            g, self._ref_opt, self._ref_params
        )
        return float(rl)

    # ------------------------------------------------------------- reporting

    def goodput(self) -> float:
        """Mean fraction of the full minibatch actually trained on — the
        live-session analogue of the paper's lost-throughput metric."""
        if not self.history:
            return 1.0
        full = self.session.local_batch * self.session.plan.d
        return float(np.mean([sum(h["local_batches"]) / full for h in self.history]))

    def summary(self) -> Dict:
        by_kind: Dict[str, int] = {}
        for t in self.transitions:
            by_kind[t["kind"]] = by_kind.get(t["kind"], 0) + 1
        return {
            "steps": len(self.history),
            "failures": by_kind.get("failure", 0),
            "repairs": by_kind.get("repair", 0),
            "rejected": by_kind.get("rejected", 0),
            "absorbed_repairs": by_kind.get("absorbed", 0),
            # the full taxonomy histogram (EVENT_KIND_NAMES vocabulary);
            # binary traces show only failure/repair here
            "events_by_kind": {
                k: v for k, v in by_kind.items()
                if k not in ("rejected", "absorbed")
            },
            "rollbacks": sum(
                1 for t in self.transitions if t.get("rollback")
            ),
            "goodput": self.goodput(),
            "final_plan": self.session.plan,
        }
