"""Typed failure/recovery events and cluster health, and the bridge from the
resource manager's packing (`ReplicaAssignment`) into the nonuniform-TP
`FailurePlan` (DESIGN.md §2.1).

The paper's restart flow (§3.3): a GPU fails somewhere in a scale-up domain;
on restart the resource manager packs partially-failed domains into the
lowest-rank DP replicas and the job resumes with those replicas at reduced
TP. Here that flow is data: a `FailureEvent` updates `ClusterHealth`, and
`plan_from_health()` turns the packed assignment into the `FailurePlan` the
step builder and reshard tables consume. `RecoveryEvent` is the inverse — a
repaired GPU lowers a domain's failed count and the next packing raises the
affected replica's TP back toward full (DESIGN.md §2.4 lifecycle).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional, Tuple, Union

import numpy as np

from repro.core.nonuniform import FailurePlan, StagedPlan
from repro.core.resource_manager import (
    ReplicaAssignment, apply_spares, pack_replicas,
)


class DeadReplicaError(RuntimeError):
    """A replica's every scale-up domain lost all GPUs — NTP cannot keep it
    computing; the job needs DP_DROP or spare domains."""


@dataclass(frozen=True)
class _ClusterEvent:
    """Shared shape of failure/recovery notifications. Exactly one of
    ``domain`` (physical scale-up-domain index) or ``replica`` (current mesh
    DP index — resolved against the live packing) must identify the site.

    ``stage`` (pipeline-parallel jobs, DESIGN.md §2.6) narrows the site to
    one pipeline stage: ``domain`` then indexes WITHIN that stage's D
    domains. On a staged session an un-staged event resolves to the worst
    (stage, domain) of its site — the stage already pinning the replica's
    TP; on a pp=1 session ``stage`` must be absent or 0."""

    step: Optional[int] = None      # training step the event was observed at
    domain: Optional[int] = None
    replica: Optional[int] = None
    n_gpus: int = 1                 # GPUs affected in that domain
    stage: Optional[int] = None     # pipeline stage (None = unstaged/pp=1)

    def __post_init__(self):
        if (self.domain is None) == (self.replica is None):
            raise ValueError(
                f"{type(self).__name__} needs exactly one of domain= or replica="
            )
        if self.n_gpus < 1:
            raise ValueError("n_gpus must be >= 1")
        if self.stage is not None and self.stage < 0:
            raise ValueError(f"stage must be >= 0, got {self.stage}")


@dataclass(frozen=True)
class FailureEvent(_ClusterEvent):
    """One failure notification: ``n_gpus`` GPUs lost in the blast site's
    scale-up domain (replica-addressed events land on that replica's worst
    domain under the current packing)."""


@dataclass(frozen=True)
class RecoveryEvent(_ClusterEvent):
    """One repair notification — the inverse of `FailureEvent`: ``n_gpus``
    GPUs return to service. A replica-addressed repair lands on that
    replica's WORST domain (the one pinning its TP). Repairing an
    already-healthy domain is a no-op: failed counts saturate at the domain
    size on the way down, so the way up must absorb the matching surplus
    repairs of a clamped trace."""


LifecycleEvent = Union[FailureEvent, RecoveryEvent]


@dataclass(frozen=True)
class ClusterHealth:
    """Failed-GPU counts per physical scale-up domain."""

    domain_size: int
    failed: Tuple[int, ...]
    domains_per_replica: int = 1

    def __post_init__(self):
        assert self.domain_size >= 1
        assert all(0 <= f <= self.domain_size for f in self.failed)
        assert len(self.failed) % self.domains_per_replica == 0

    @classmethod
    def pristine(cls, n_domains: int, domain_size: int,
                 domains_per_replica: int = 1) -> "ClusterHealth":
        return cls(domain_size, (0,) * n_domains, domains_per_replica)

    @classmethod
    def from_plan(cls, plan: FailurePlan) -> "ClusterHealth":
        """One domain per replica, failures as implied by the plan's TPs."""
        return cls(plan.n1, tuple(plan.n1 - t for t in plan.replica_tp))

    @property
    def n_domains(self) -> int:
        return len(self.failed)

    @property
    def n_replicas(self) -> int:
        return self.n_domains // self.domains_per_replica

    @property
    def healthy(self) -> bool:
        return all(f == 0 for f in self.failed)

    def assignments(self) -> List[ReplicaAssignment]:
        """Current packing: most-failed domains into the lowest replicas."""
        return pack_replicas(
            list(self.failed), self.domain_size, self.domains_per_replica
        )

    def resolve_domain(self, event: LifecycleEvent) -> int:
        """Physical domain ``event`` lands on: its explicit ``domain``, or —
        replica-addressed — the worst domain of that replica under the
        CURRENT packing (the domain already pinning its TP: for a failure
        that is where another hit hurts least, for a repair where a fix
        helps most)."""
        if event.stage not in (None, 0):
            raise ValueError(
                f"{type(event).__name__} addresses pipeline stage "
                f"{event.stage}, but this health ledger is single-stage "
                "(pp=1) — only stage=None or stage=0 is valid"
            )
        domain = event.domain
        if domain is None:
            asg = self.assignments()
            if not 0 <= event.replica < len(asg):
                raise ValueError(f"no replica {event.replica}")
            a = asg[event.replica]
            domain = int(a.domain_ids[int(np.argmax(a.failed))])
        if not 0 <= domain < self.n_domains:
            raise ValueError(f"no domain {domain}")
        return domain

    def apply(self, event: LifecycleEvent) -> "ClusterHealth":
        """Health after ``event`` (site per `resolve_domain`). Failures
        saturate at the domain size; repairs saturate at fully healthy."""
        domain = self.resolve_domain(event)
        failed = list(self.failed)
        if isinstance(event, RecoveryEvent):
            failed[domain] = max(0, failed[domain] - event.n_gpus)
        else:
            failed[domain] = min(self.domain_size, failed[domain] + event.n_gpus)
        return replace(self, failed=tuple(failed))


def resolve_serving_domain(event: LifecycleEvent, n_domains: int) -> LifecycleEvent:
    """Normalize an event for DOMAIN-PINNED serving replicas (DESIGN.md
    §2.5): serving replicas are never repacked across domains (the KV state
    pins them), so ``replica=r`` aliases ``domain=r`` 1:1. Returns a
    domain-addressed event of the same type; raises `ValueError` naming the
    offending id when it is outside ``[0, n_domains)``.

    This is THE one place serving addressing is validated — call sites
    (`serve.session.ServeSession.apply`) must not re-implement the aliasing.
    """
    if event.stage is not None:
        raise ValueError(
            f"{type(event).__name__} addresses pipeline stage {event.stage}, "
            "but serving sessions are single-stage (PP serving is an open "
            "item — ROADMAP)"
        )
    if event.domain is None:
        event = type(event)(step=event.step, domain=event.replica,
                            n_gpus=event.n_gpus)
    if not 0 <= event.domain < n_domains:
        kind = type(event).__name__
        raise ValueError(
            f"{kind} addresses domain {event.domain}, but this serving "
            f"session has {n_domains} domain-pinned replicas "
            f"(valid ids: 0..{n_domains - 1})"
        )
    return event


@dataclass(frozen=True)
class StagedHealth:
    """Per-(replica, stage) failed-GPU ledger of a DP×PP×TP job (DESIGN.md
    §2.6): one `ClusterHealth` per pipeline stage, each over the job's D
    scale-up domains. Stage s of the job owns the physical domains
    ``{g : g % pp == s}`` of the global replica-major numbering (replica
    block r holds its pp stage domains contiguously), so a global domain id
    ``g`` addresses ``(stage=g % pp, domain=g // pp)``."""

    stages: Tuple[ClusterHealth, ...]

    def __post_init__(self):
        assert len(self.stages) >= 1
        h0 = self.stages[0]
        assert all(
            h.domain_size == h0.domain_size and h.n_domains == h0.n_domains
            and h.domains_per_replica == h0.domains_per_replica
            for h in self.stages
        ), self.stages

    @classmethod
    def pristine(cls, n_domains: int, domain_size: int, pp: int) -> "StagedHealth":
        return cls(tuple(
            ClusterHealth.pristine(n_domains, domain_size) for _ in range(pp)
        ))

    @classmethod
    def from_plan(cls, plan: StagedPlan) -> "StagedHealth":
        return cls(tuple(ClusterHealth.from_plan(p) for p in plan.stages))

    @property
    def pp(self) -> int:
        return len(self.stages)

    @property
    def domain_size(self) -> int:
        return self.stages[0].domain_size

    @property
    def n_replicas(self) -> int:
        return self.stages[0].n_replicas

    @property
    def healthy(self) -> bool:
        return all(h.healthy for h in self.stages)

    def _unstaged(self, event: LifecycleEvent) -> LifecycleEvent:
        return replace(event, stage=None)

    def resolve_site(self, event: LifecycleEvent) -> Tuple[int, int]:
        """(stage, domain) the event lands on. Explicit ``stage`` narrows to
        that stage's ledger; a stage-less replica-addressed event lands on
        the replica's WORST (stage, domain) — the stage pinning its TP is
        where a failure hurts least and a repair helps most (same rule as
        `ClusterHealth.resolve_domain`, lifted over stages)."""
        if event.stage is not None:
            if not 0 <= event.stage < self.pp:
                raise ValueError(
                    f"{type(event).__name__} addresses stage {event.stage}, "
                    f"but this job has {self.pp} pipeline stages "
                    f"(valid: 0..{self.pp - 1})"
                )
            ev = self._unstaged(event)
            return event.stage, self.stages[event.stage].resolve_domain(ev)
        if event.domain is not None:
            # stage-less domain address = GLOBAL domain id (replica-major)
            n_global = self.pp * self.stages[0].n_domains
            if not 0 <= event.domain < n_global:
                raise ValueError(
                    f"no global domain {event.domain} "
                    f"(valid: 0..{n_global - 1} = D*pp domains)"
                )
            return event.domain % self.pp, event.domain // self.pp
        # replica-addressed, stage-less: worst (stage, domain) of the replica
        best: Optional[Tuple[int, int, int]] = None   # (-failed, stage, dom)
        ev = self._unstaged(event)
        for s, h in enumerate(self.stages):
            asg = h.assignments()
            if not 0 <= event.replica < len(asg):
                raise ValueError(f"no replica {event.replica}")
            a = asg[event.replica]
            worst = int(np.argmax(a.failed))
            cand = (-int(a.failed[worst]), s, int(a.domain_ids[worst]))
            if best is None or cand < best:
                best = cand
        return best[1], best[2]

    def apply(self, event: LifecycleEvent) -> "StagedHealth":
        """Ledger after ``event``: only the resolved stage's `ClusterHealth`
        changes (stage-local blast radius)."""
        s, domain = self.resolve_site(event)
        ev = replace(event, stage=None, domain=domain, replica=None)
        stages = list(self.stages)
        stages[s] = stages[s].apply(ev)
        return StagedHealth(tuple(stages))


def staged_plan_from_health(
    health: StagedHealth,
    *,
    spares: int = 0,
    allocator=None,
    current: Optional[StagedPlan] = None,
) -> StagedPlan:
    """Per-stage `plan_from_health`: each stage packs its own failures into
    its lowest replicas independently (SPARe-style stage-local packing — no
    cross-stage repair traffic).

    Spare domains with pp > 1 need the GLOBAL allocator — a spare rack can
    stand in for ANY stage, which per-stage packing cannot express. Pass an
    ``allocator`` (`repro.cluster.GreedyAllocator`) to delegate the whole
    joint search (spares, cross-stage swaps, reordering) to it; ``current``
    is the plan whose state is in place, so the allocator can price
    transitions against it."""
    if allocator is not None and health.pp > 1:
        return allocator.plan(health, spares=spares, current=current).staged_plan
    if spares and health.pp > 1:
        raise ValueError(
            "spare domains with pp > 1 need the global allocator: a spare "
            "can absorb failures in any stage, which per-stage packing "
            "cannot express. Pass --allocator greedy (launch/train.py) or "
            "allocator=repro.cluster.GreedyAllocator(...) to the session."
        )
    return StagedPlan(tuple(
        plan_from_health(h, spares=spares) for h in health.stages
    ))


def plan_from_health(health: ClusterHealth, *, spares: int = 0) -> FailurePlan:
    """Bridge `pack_replicas` output into a `FailurePlan`.

    Spare domains (paper §3.3 / Fig. 7) absorb the worst failures first;
    whatever remains is packed and becomes per-replica operating TPs. Raises
    DeadReplicaError when packing still leaves a replica at TP 0.
    """
    counts = np.asarray(health.failed)
    if spares:
        counts = apply_spares(counts, spares)
    asg = pack_replicas(counts, health.domain_size, health.domains_per_replica)
    tp = tuple(a.tp for a in asg)
    if any(t == 0 for t in tp):
        raise DeadReplicaError(
            f"replica_tp={tp}: a replica has no surviving GPUs "
            "(use Mode.DP_DROP or add spare domains)"
        )
    return FailurePlan(n1=health.domain_size, replica_tp=tp)
