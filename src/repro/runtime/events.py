"""Typed health events and cluster health, and the bridge from the resource
manager's packing (`ReplicaAssignment`) into the nonuniform-TP `FailurePlan`
(DESIGN.md §2.1, §2.11).

The paper's restart flow (§3.3): a GPU fails somewhere in a scale-up domain;
on restart the resource manager packs partially-failed domains into the
lowest-rank DP replicas and the job resumes with those replicas at reduced
TP. Here that flow is data: a `FailureEvent` updates `ClusterHealth`, and
`plan_from_health()` turns the packed assignment into the `FailurePlan` the
step builder and reshard tables consume. `RecoveryEvent` is the inverse — a
repaired GPU lowers a domain's failed count and the next packing raises the
affected replica's TP back toward full (DESIGN.md §2.4 lifecycle).

Production fleets fail *partially* long before they fail outright (ByteDance
infra paper, PAPERS.md), so the ledger is a health-STATE machine, not a
binary fail/repair counter (DESIGN.md §2.11): each domain carries a
`DomainDegradation` — a multiset of straggler slow factors, a multiset of
link bandwidth fractions, and an SDC-suspicion counter — updated by the
degradation half of the `HealthEvent` taxonomy (`StragglerEvent`,
`LinkDegradeEvent`, `SdcSuspectEvent` and their per-kind inverses via
`inverse()`). Multiset semantics make every inverse EXACT: a clear event
removes one occurrence of the value its degrade event pushed (effective slow
factor = max of the multiset, effective bandwidth = min), so float-valued
severities round-trip bit-identically without dividing floats. Degradation
is orthogonal to packing — failed counts alone drive `pack_replicas`; the
policies (PowerPolicy / GoodputModel / serve retarget) consume the per-
replica degradation view. A health with no degradations normalizes its
``degraded`` field back to ``None`` so binary fail/repair traces replay
bit-identically through the refactored core.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, fields, replace
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.nonuniform import FailurePlan, StagedPlan
from repro.core.resource_manager import (
    ReplicaAssignment, apply_spares, pack_replicas,
)


class HealthState(enum.Enum):
    """Dominant health label of one scale-up domain (DESIGN.md §2.11).

    Per-GPU absence is tracked by the failed COUNT (a domain with some GPUs
    down but survivors computing still labels by its dominant *degradation*,
    or HEALTHY — reduced TP is the NTP normal, not a state of its own);
    FAILED means the whole domain is gone. Priority when several conditions
    coexist: FAILED > SDC_SUSPECT > STRAGGLER > LINK_DEGRADED > HEALTHY —
    the order in which the policies act on them (quarantine beats slowdown
    pricing beats comm repricing)."""

    HEALTHY = "healthy"
    FAILED = "failed"
    STRAGGLER = "straggler"
    LINK_DEGRADED = "link_degraded"
    SDC_SUSPECT = "sdc_suspect"


class DeadReplicaError(RuntimeError):
    """A replica's every scale-up domain lost all GPUs — NTP cannot keep it
    computing; the job needs DP_DROP or spare domains."""


@dataclass(frozen=True)
class _ClusterEvent:
    """Shared shape of failure/recovery notifications. Exactly one of
    ``domain`` (physical scale-up-domain index) or ``replica`` (current mesh
    DP index — resolved against the live packing) must identify the site.

    ``stage`` (pipeline-parallel jobs, DESIGN.md §2.6) narrows the site to
    one pipeline stage: ``domain`` then indexes WITHIN that stage's D
    domains. On a staged session an un-staged event resolves to the worst
    (stage, domain) of its site — the stage already pinning the replica's
    TP; on a pp=1 session ``stage`` must be absent or 0."""

    step: Optional[int] = None      # training step the event was observed at
    domain: Optional[int] = None
    replica: Optional[int] = None
    n_gpus: int = 1                 # GPUs affected in that domain
    stage: Optional[int] = None     # pipeline stage (None = unstaged/pp=1)

    def __post_init__(self):
        if (self.domain is None) == (self.replica is None):
            raise ValueError(
                f"{type(self).__name__} needs exactly one of domain= or replica="
            )
        if self.n_gpus < 1:
            raise ValueError("n_gpus must be >= 1")
        if self.stage is not None and self.stage < 0:
            raise ValueError(f"stage must be >= 0, got {self.stage}")


@dataclass(frozen=True)
class FailureEvent(_ClusterEvent):
    """One failure notification: ``n_gpus`` GPUs lost in the blast site's
    scale-up domain (replica-addressed events land on that replica's worst
    domain under the current packing)."""


@dataclass(frozen=True)
class RecoveryEvent(_ClusterEvent):
    """One repair notification — the inverse of `FailureEvent`: ``n_gpus``
    GPUs return to service. A replica-addressed repair lands on that
    replica's WORST domain (the one pinning its TP). Repairing an
    already-healthy domain is a no-op: failed counts saturate at the domain
    size on the way down, so the way up must absorb the matching surplus
    repairs of a clamped trace."""


@dataclass(frozen=True)
class StragglerEvent(_ClusterEvent):
    """The site's domain is DETECTED slow: its compute runs ``slowdown``×
    slower than spec (thermal throttle, sick HBM, a crashed SM — ByteDance
    taxonomy). The domain keeps its GPUs (no repack); the power policy
    prices it like a TP reduction and the allocator may evict it."""

    slowdown: float = 2.0

    def __post_init__(self):
        super().__post_init__()
        if not self.slowdown > 1.0:
            raise ValueError(
                f"slowdown must be > 1.0 (a {self.slowdown}× straggler is "
                "not a straggler)"
            )


@dataclass(frozen=True)
class StragglerClearEvent(_ClusterEvent):
    """Inverse of `StragglerEvent`: removes ONE occurrence of ``slowdown``
    from the site's straggle multiset (clearing a value that was never
    pushed is absorbed, like surplus repairs)."""

    slowdown: float = 2.0

    def __post_init__(self):
        super().__post_init__()
        if not self.slowdown > 1.0:
            raise ValueError(f"slowdown must be > 1.0, got {self.slowdown}")


@dataclass(frozen=True)
class LinkDegradeEvent(_ClusterEvent):
    """The site's scale-up interconnect is running at ``bw_frac`` of spec
    (lane drop, flapping NVLink/NIC). Comm-bound work slows by 1/bw_frac;
    the effective slow factor blends by the workload's comm share."""

    bw_frac: float = 0.5

    def __post_init__(self):
        super().__post_init__()
        if not 0.0 < self.bw_frac < 1.0:
            raise ValueError(
                f"bw_frac must be in (0, 1), got {self.bw_frac}"
            )


@dataclass(frozen=True)
class LinkRepairEvent(_ClusterEvent):
    """Inverse of `LinkDegradeEvent`: removes one ``bw_frac`` occurrence
    from the site's link multiset (absorbing when absent)."""

    bw_frac: float = 0.5

    def __post_init__(self):
        super().__post_init__()
        if not 0.0 < self.bw_frac < 1.0:
            raise ValueError(f"bw_frac must be in (0, 1), got {self.bw_frac}")


@dataclass(frozen=True)
class SdcSuspectEvent(_ClusterEvent):
    """Silent-data-corruption suspicion raised against the site (mismatched
    checksums, NaN watchdog, duplicate-compute divergence). Counts, does not
    fail: the replica owning the domain is QUARANTINED (batch 0) and rolled
    back to the canonical checkpoint by the session policy."""


@dataclass(frozen=True)
class SdcClearEvent(_ClusterEvent):
    """Inverse of `SdcSuspectEvent`: one suspicion retracted (floor 0)."""


#: The full taxonomy. `LifecycleEvent` remains as the historical alias —
#: every consumer annotated against it accepts the whole state machine.
HealthEvent = Union[
    FailureEvent, RecoveryEvent,
    StragglerEvent, StragglerClearEvent,
    LinkDegradeEvent, LinkRepairEvent,
    SdcSuspectEvent, SdcClearEvent,
]
LifecycleEvent = HealthEvent

#: Events that touch the degradation ledger (not the failed counts).
DEGRADATION_EVENTS = (
    StragglerEvent, StragglerClearEvent, LinkDegradeEvent, LinkRepairEvent,
    SdcSuspectEvent, SdcClearEvent,
)

_INVERSE_KIND = {
    FailureEvent: RecoveryEvent, RecoveryEvent: FailureEvent,
    StragglerEvent: StragglerClearEvent, StragglerClearEvent: StragglerEvent,
    LinkDegradeEvent: LinkRepairEvent, LinkRepairEvent: LinkDegradeEvent,
    SdcSuspectEvent: SdcClearEvent, SdcClearEvent: SdcSuspectEvent,
}

_EVENT_KIND = {
    FailureEvent: "failure", RecoveryEvent: "repair",
    StragglerEvent: "straggler", StragglerClearEvent: "straggler_clear",
    LinkDegradeEvent: "link_degrade", LinkRepairEvent: "link_repair",
    SdcSuspectEvent: "sdc_suspect", SdcClearEvent: "sdc_clear",
}

#: Canonical kind strings, degrade/clear pairs adjacent — the vocabulary
#: telemetry counters and the trace sampler share.
EVENT_KIND_NAMES = tuple(_EVENT_KIND.values())


def event_kind(event: HealthEvent) -> str:
    """Canonical kind string of ``event`` (telemetry/report vocabulary;
    binary events keep their historical "failure"/"repair" names)."""
    return _EVENT_KIND[type(event)]


def inverse(event: HealthEvent) -> HealthEvent:
    """The event that exactly undoes ``event`` at the same site: fail↔repair
    (same ``n_gpus``), straggle↔clear and degrade↔repair (same severity
    value — multiset semantics make the round trip exact), suspect↔clear.
    ``apply(e) ∘ apply(inverse(e))`` is the identity on any health where
    ``inverse(e)`` applies without saturating (the property suite's oracle).
    """
    cls = _INVERSE_KIND[type(event)]
    return cls(**{f.name: getattr(event, f.name) for f in fields(event)})


def _push(values: Tuple[float, ...], v: float) -> Tuple[float, ...]:
    return tuple(sorted(values + (float(v),)))


def _remove_one(values: Tuple[float, ...], v: float) -> Tuple[float, ...]:
    """Remove ONE occurrence of ``v`` (bit-equal float — the clear event
    carries the exact value its degrade pushed); absorb when absent."""
    out = list(values)
    try:
        out.remove(float(v))
    except ValueError:
        pass
    return tuple(out)


@dataclass(frozen=True)
class DomainDegradation:
    """Partial-health ledger of ONE scale-up domain (DESIGN.md §2.11).

    Multisets, not scalars: concurrent degradations stack (two independent
    stragglers in one domain), and each clear removes exactly the value its
    degrade event pushed — so per-kind inverses are exact for float-valued
    severities. Effective factors are worst-of: ``slow_factor`` is the max
    straggle (the slowest GPU gates the TP group), ``bw_frac`` the min link
    fraction (the weakest lane gates the collective)."""

    straggle: Tuple[float, ...] = ()   # sorted slow factors, each > 1
    link: Tuple[float, ...] = ()       # sorted bandwidth fractions, each < 1
    sdc: int = 0                       # outstanding corruption suspicions

    def __post_init__(self):
        assert self.straggle == tuple(sorted(self.straggle)), self.straggle
        assert self.link == tuple(sorted(self.link)), self.link
        assert all(s > 1.0 for s in self.straggle), self.straggle
        assert all(0.0 < b < 1.0 for b in self.link), self.link
        assert self.sdc >= 0, self.sdc

    @property
    def clear(self) -> bool:
        return not self.straggle and not self.link and self.sdc == 0

    @property
    def slow_factor(self) -> float:
        """Compute slowdown vs spec (1.0 = full speed)."""
        return self.straggle[-1] if self.straggle else 1.0

    @property
    def bw_frac(self) -> float:
        """Scale-up interconnect bandwidth vs spec (1.0 = full)."""
        return self.link[0] if self.link else 1.0

    def merge(self, other: "DomainDegradation") -> "DomainDegradation":
        """Worst-of union — the degradation a REPLICA sees across the
        domains (and stages) it is packed onto."""
        return DomainDegradation(
            straggle=tuple(sorted(self.straggle + other.straggle)),
            link=tuple(sorted(self.link + other.link)),
            sdc=self.sdc + other.sdc,
        )

    def apply(self, event: HealthEvent) -> "DomainDegradation":
        if isinstance(event, StragglerEvent):
            return replace(self, straggle=_push(self.straggle, event.slowdown))
        if isinstance(event, StragglerClearEvent):
            return replace(
                self, straggle=_remove_one(self.straggle, event.slowdown)
            )
        if isinstance(event, LinkDegradeEvent):
            return replace(self, link=_push(self.link, event.bw_frac))
        if isinstance(event, LinkRepairEvent):
            return replace(self, link=_remove_one(self.link, event.bw_frac))
        if isinstance(event, SdcSuspectEvent):
            return replace(self, sdc=self.sdc + 1)
        if isinstance(event, SdcClearEvent):
            return replace(self, sdc=max(0, self.sdc - 1))
        raise TypeError(f"not a degradation event: {type(event).__name__}")


#: The all-clear degradation (shared constant: `DomainDegradation` is frozen).
CLEAR_DEGRADATION = DomainDegradation()


@dataclass(frozen=True)
class ClusterHealth:
    """Failed-GPU counts per physical scale-up domain, plus the per-domain
    degradation ledger of the health-state machine (DESIGN.md §2.11).

    ``degraded`` is ``None`` when NO domain carries any degradation — the
    normalized all-clear — so binary fail/repair histories produce exactly
    the pre-taxonomy value (equality, hash, replay all bit-identical).
    When present it is one ``Optional[DomainDegradation]`` per domain with
    all-clear entries normalized to ``None``. Packing (`assignments`) reads
    only the failed counts: a straggling domain keeps its GPUs and its
    replica; the *policies* consume `replica_degradations()`."""

    domain_size: int
    failed: Tuple[int, ...]
    domains_per_replica: int = 1
    degraded: Optional[Tuple[Optional[DomainDegradation], ...]] = None

    def __post_init__(self):
        assert self.domain_size >= 1
        assert all(0 <= f <= self.domain_size for f in self.failed)
        assert len(self.failed) % self.domains_per_replica == 0
        if self.degraded is not None:
            assert len(self.degraded) == len(self.failed)
            assert any(d is not None for d in self.degraded), (
                "all-clear degradation must normalize to degraded=None"
            )
            assert all(d is None or not d.clear for d in self.degraded)

    @classmethod
    def pristine(cls, n_domains: int, domain_size: int,
                 domains_per_replica: int = 1) -> "ClusterHealth":
        return cls(domain_size, (0,) * n_domains, domains_per_replica)

    @classmethod
    def from_plan(cls, plan: FailurePlan) -> "ClusterHealth":
        """One domain per replica, failures as implied by the plan's TPs."""
        return cls(plan.n1, tuple(plan.n1 - t for t in plan.replica_tp))

    @property
    def n_domains(self) -> int:
        return len(self.failed)

    @property
    def n_replicas(self) -> int:
        return self.n_domains // self.domains_per_replica

    @property
    def healthy(self) -> bool:
        return all(f == 0 for f in self.failed) and self.degraded is None

    def degradation(self, domain: int) -> DomainDegradation:
        """The domain's degradation (the shared all-clear when none)."""
        if self.degraded is None or self.degraded[domain] is None:
            return CLEAR_DEGRADATION
        return self.degraded[domain]

    def domain_state(self, domain: int) -> HealthState:
        """Dominant `HealthState` label of ``domain`` (priority per the
        enum's docstring)."""
        if self.failed[domain] >= self.domain_size:
            return HealthState.FAILED
        d = self.degradation(domain)
        if d.sdc > 0:
            return HealthState.SDC_SUSPECT
        if d.straggle:
            return HealthState.STRAGGLER
        if d.link:
            return HealthState.LINK_DEGRADED
        return HealthState.HEALTHY

    def domain_states(self) -> Tuple[HealthState, ...]:
        return tuple(self.domain_state(g) for g in range(self.n_domains))

    def replica_degradations(self) -> Tuple[DomainDegradation, ...]:
        """Per-REPLICA degradation under the CURRENT packing: worst-of merge
        over the domains each replica is packed onto. This is the view the
        power policy, the serve retarget, and the allocator's goodput model
        consume (a straggler anywhere in the replica gates its whole TP×PP
        group, same reduction as the min-TP rule)."""
        if self.degraded is None:
            return (CLEAR_DEGRADATION,) * self.n_replicas
        out = []
        for a in self.assignments():
            d = CLEAR_DEGRADATION
            for g in a.domain_ids:
                d = d.merge(self.degradation(int(g)))
            out.append(d)
        return tuple(out)

    def assignments(self) -> List[ReplicaAssignment]:
        """Current packing: most-failed domains into the lowest replicas."""
        return pack_replicas(
            list(self.failed), self.domain_size, self.domains_per_replica
        )

    def resolve_domain(self, event: LifecycleEvent) -> int:
        """Physical domain ``event`` lands on: its explicit ``domain``, or —
        replica-addressed — the worst domain of that replica under the
        CURRENT packing (the domain already pinning its TP: for a failure
        that is where another hit hurts least, for a repair where a fix
        helps most)."""
        if event.stage not in (None, 0):
            raise ValueError(
                f"{type(event).__name__} addresses pipeline stage "
                f"{event.stage}, but this health ledger is single-stage "
                "(pp=1) — only stage=None or stage=0 is valid"
            )
        domain = event.domain
        if domain is None:
            asg = self.assignments()
            if not 0 <= event.replica < len(asg):
                raise ValueError(f"no replica {event.replica}")
            a = asg[event.replica]
            domain = int(a.domain_ids[int(np.argmax(a.failed))])
        if not 0 <= domain < self.n_domains:
            raise ValueError(f"no domain {domain}")
        return domain

    def apply(self, event: LifecycleEvent) -> "ClusterHealth":
        """Health after ``event`` (site per `resolve_domain`). Failures
        saturate at the domain size; repairs saturate at fully healthy.
        Degradation events fold into the site's `DomainDegradation`
        (clears absorb when the value is absent, mirroring surplus
        repairs); an all-clear ledger normalizes back to ``None``."""
        domain = self.resolve_domain(event)
        if isinstance(event, DEGRADATION_EVENTS):
            entries = (
                list(self.degraded) if self.degraded is not None
                else [None] * self.n_domains
            )
            d = (entries[domain] or CLEAR_DEGRADATION).apply(event)
            entries[domain] = None if d.clear else d
            if all(e is None for e in entries):
                return replace(self, degraded=None)
            return replace(self, degraded=tuple(entries))
        failed = list(self.failed)
        if isinstance(event, RecoveryEvent):
            failed[domain] = max(0, failed[domain] - event.n_gpus)
        else:
            failed[domain] = min(self.domain_size, failed[domain] + event.n_gpus)
        return replace(self, failed=tuple(failed))


def resolve_serving_domain(event: LifecycleEvent, n_domains: int) -> LifecycleEvent:
    """Normalize an event for DOMAIN-PINNED serving replicas (DESIGN.md
    §2.5): serving replicas are never repacked across domains (the KV state
    pins them), so ``replica=r`` aliases ``domain=r`` 1:1. Returns a
    domain-addressed event of the same type; raises `ValueError` naming the
    offending id when it is outside ``[0, n_domains)``.

    This is THE one place serving addressing is validated — call sites
    (`serve.session.ServeSession.apply`) must not re-implement the aliasing.
    """
    if event.stage is not None:
        raise ValueError(
            f"{type(event).__name__} addresses pipeline stage {event.stage}, "
            "but serving sessions are single-stage (PP serving is an open "
            "item — ROADMAP)"
        )
    if event.domain is None:
        # replace() (not re-construction) so kind-specific severity fields
        # (slowdown, bw_frac) survive the re-addressing
        event = replace(event, domain=event.replica, replica=None)
    if not 0 <= event.domain < n_domains:
        kind = type(event).__name__
        raise ValueError(
            f"{kind} addresses domain {event.domain}, but this serving "
            f"session has {n_domains} domain-pinned replicas "
            f"(valid ids: 0..{n_domains - 1})"
        )
    return event


@dataclass(frozen=True)
class StagedHealth:
    """Per-(replica, stage) failed-GPU ledger of a DP×PP×TP job (DESIGN.md
    §2.6): one `ClusterHealth` per pipeline stage, each over the job's D
    scale-up domains. Stage s of the job owns the physical domains
    ``{g : g % pp == s}`` of the global replica-major numbering (replica
    block r holds its pp stage domains contiguously), so a global domain id
    ``g`` addresses ``(stage=g % pp, domain=g // pp)``."""

    stages: Tuple[ClusterHealth, ...]

    def __post_init__(self):
        assert len(self.stages) >= 1
        h0 = self.stages[0]
        assert all(
            h.domain_size == h0.domain_size and h.n_domains == h0.n_domains
            and h.domains_per_replica == h0.domains_per_replica
            for h in self.stages
        ), self.stages

    @classmethod
    def pristine(cls, n_domains: int, domain_size: int, pp: int) -> "StagedHealth":
        return cls(tuple(
            ClusterHealth.pristine(n_domains, domain_size) for _ in range(pp)
        ))

    @classmethod
    def from_plan(cls, plan: StagedPlan) -> "StagedHealth":
        return cls(tuple(ClusterHealth.from_plan(p) for p in plan.stages))

    @property
    def pp(self) -> int:
        return len(self.stages)

    @property
    def domain_size(self) -> int:
        return self.stages[0].domain_size

    @property
    def n_replicas(self) -> int:
        return self.stages[0].n_replicas

    @property
    def healthy(self) -> bool:
        return all(h.healthy for h in self.stages)

    def replica_degradations(self) -> Tuple[DomainDegradation, ...]:
        """Per-replica worst-of merge ACROSS stages: 1F1B runs every
        microbatch through every stage, so a straggler in any stage gates
        the replica — the degradation analogue of the min-over-stages TP."""
        per_stage = [h.replica_degradations() for h in self.stages]
        out = []
        for r in range(self.n_replicas):
            d = CLEAR_DEGRADATION
            for s in range(self.pp):
                d = d.merge(per_stage[s][r])
            out.append(d)
        return tuple(out)

    def _unstaged(self, event: LifecycleEvent) -> LifecycleEvent:
        return replace(event, stage=None)

    def resolve_site(self, event: LifecycleEvent) -> Tuple[int, int]:
        """(stage, domain) the event lands on. Explicit ``stage`` narrows to
        that stage's ledger; a stage-less replica-addressed event lands on
        the replica's WORST (stage, domain) — the stage pinning its TP is
        where a failure hurts least and a repair helps most (same rule as
        `ClusterHealth.resolve_domain`, lifted over stages)."""
        if event.stage is not None:
            if not 0 <= event.stage < self.pp:
                raise ValueError(
                    f"{type(event).__name__} addresses stage {event.stage}, "
                    f"but this job has {self.pp} pipeline stages "
                    f"(valid: 0..{self.pp - 1})"
                )
            ev = self._unstaged(event)
            return event.stage, self.stages[event.stage].resolve_domain(ev)
        if event.domain is not None:
            # stage-less domain address = GLOBAL domain id (replica-major)
            n_global = self.pp * self.stages[0].n_domains
            if not 0 <= event.domain < n_global:
                raise ValueError(
                    f"no global domain {event.domain} "
                    f"(valid: 0..{n_global - 1} = D*pp domains)"
                )
            return event.domain % self.pp, event.domain // self.pp
        # replica-addressed, stage-less: worst (stage, domain) of the replica
        best: Optional[Tuple[int, int, int]] = None   # (-failed, stage, dom)
        ev = self._unstaged(event)
        for s, h in enumerate(self.stages):
            asg = h.assignments()
            if not 0 <= event.replica < len(asg):
                raise ValueError(f"no replica {event.replica}")
            a = asg[event.replica]
            worst = int(np.argmax(a.failed))
            cand = (-int(a.failed[worst]), s, int(a.domain_ids[worst]))
            if best is None or cand < best:
                best = cand
        return best[1], best[2]

    def apply(self, event: LifecycleEvent) -> "StagedHealth":
        """Ledger after ``event``: only the resolved stage's `ClusterHealth`
        changes (stage-local blast radius)."""
        s, domain = self.resolve_site(event)
        ev = replace(event, stage=None, domain=domain, replica=None)
        stages = list(self.stages)
        stages[s] = stages[s].apply(ev)
        return StagedHealth(tuple(stages))


def staged_plan_from_health(
    health: StagedHealth,
    *,
    spares: int = 0,
    allocator=None,
    current: Optional[StagedPlan] = None,
) -> StagedPlan:
    """Per-stage `plan_from_health`: each stage packs its own failures into
    its lowest replicas independently (SPARe-style stage-local packing — no
    cross-stage repair traffic).

    Spare domains with pp > 1 need the GLOBAL allocator — a spare rack can
    stand in for ANY stage, which per-stage packing cannot express. Pass an
    ``allocator`` (`repro.cluster.GreedyAllocator`) to delegate the whole
    joint search (spares, cross-stage swaps, reordering) to it; ``current``
    is the plan whose state is in place, so the allocator can price
    transitions against it."""
    if allocator is not None and health.pp > 1:
        return allocator.plan(health, spares=spares, current=current).staged_plan
    if spares and health.pp > 1:
        raise ValueError(
            "spare domains with pp > 1 need the global allocator: a spare "
            "can absorb failures in any stage, which per-stage packing "
            "cannot express. Pass --allocator greedy (launch/train.py) or "
            "allocator=repro.cluster.GreedyAllocator(...) to the session."
        )
    return StagedPlan(tuple(
        plan_from_health(h, spares=spares) for h in health.stages
    ))


def plan_from_health(health: ClusterHealth, *, spares: int = 0) -> FailurePlan:
    """Bridge `pack_replicas` output into a `FailurePlan`.

    Spare domains (paper §3.3 / Fig. 7) absorb the worst failures first;
    whatever remains is packed and becomes per-replica operating TPs. Raises
    DeadReplicaError when packing still leaves a replica at TP 0.
    """
    counts = np.asarray(health.failed)
    if spares:
        counts = apply_spares(counts, spares)
    asg = pack_replicas(counts, health.domain_size, health.domains_per_replica)
    tp = tuple(a.tp for a in asg)
    if any(t == 0 for t in tp):
        raise DeadReplicaError(
            f"replica_tp={tp}: a replica has no surviving GPUs "
            "(use Mode.DP_DROP or add spare domains)"
        )
    return FailurePlan(n1=health.domain_size, replica_tp=tp)
