"""Typed state-movement actions the allocator emits (DESIGN.md §2.7).

Two layers of actions live in a `GlobalPlan`:

* **decisions** (``spare`` / ``swap``) — the search moves the allocator
  accepted, each carrying the marginal goodput gain and the marginal priced
  transfer cost that justified it (the amortization gate's ledger);
* **transitions** — the ordered per-stage state movements executing the
  final plan against the session's current layout, each carrying the
  predicted traffic the reshard engine will put on the wire (and, for
  reordering, the stage's new pack permutation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class Action:
    """One allocator move, priced.

    ``gain_s`` is the useful-compute seconds the move recovers over the
    allocator's horizon; ``cost_s`` the marginal transfer seconds it adds.
    The allocator's invariant (property-tested): every emitted non-rescue
    action has ``cost_s <= gain_s``. ``rescue`` marks moves that revive a
    dead replica — the job is halted without them, so amortization does not
    apply (the gain is effectively unbounded).
    """

    kind: str                                # "spare" | "swap" | "transition"
    gain_s: float = 0.0
    cost_s: float = 0.0
    bytes: int = 0                           # marginal predicted traffic
    rescue: bool = False
    site: Optional[Tuple[int, int]] = None   # (stage, domain) acted on
    other: Optional[Tuple[int, int]] = None  # swap partner site
    absorbed: int = 0                        # failures a spare soaks up
    stage: Optional[int] = None              # transition: stage that moves
    order: Optional[Tuple[int, ...]] = None  # transition: pack permutation
    note: str = ""

    def __post_init__(self):
        assert self.kind in ("spare", "swap", "transition"), self.kind
        assert self.bytes >= 0 and self.cost_s >= 0.0, self
