"""Goodput model the allocator optimizes (DESIGN.md §2.7): relative cluster
samples/step of a CANDIDATE failure-count layout, before any plan exists.

Works on raw per-(stage, domain) failed counts rather than `FailurePlan`s so
dead layouts (a replica at TP 0 in some stage) are representable — their
goodput is simply 0 for the affected replica, which is exactly what makes
rescue moves (spares/swaps that revive a dead replica) come out as infinite-
priority to the allocator. The per-replica math is the SAME stack the runtime
meters itself with: per-stage packing + slowest-stage gating (`perf_model.
staged_iteration_time`'s reduction) + `policies.stage_slowdown` through
`policies.replica_throughput`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core.policies import WorkloadGeometry, replica_throughput
from repro.core.power import PowerModel


@dataclass(frozen=True)
class GoodputModel:
    """Relative goodput (1.0 = pristine) of a per-stage failed-count layout.

    ``step_time_s`` converts goodput deltas into wall seconds for the
    allocator's amortization gate (seconds of useful compute recovered per
    step = Δgoodput × step_time_s); derive it from the analytic perf model
    via `for_perf` when real hardware numbers matter.
    """

    n1: int                       # scale-up domain size (full TP)
    geom: WorkloadGeometry = field(default_factory=WorkloadGeometry)
    method: str = "ntp"           # "ntp" | "ntp_pw" (policies.replica_throughput)
    power: PowerModel = field(default_factory=PowerModel)
    step_time_s: float = 1.0

    @classmethod
    def for_perf(cls, hw, wl, par, *, geom: WorkloadGeometry = None,
                 method: str = "ntp",
                 power: PowerModel = None) -> "GoodputModel":
        """Bind step time to `perf_model.iteration_time` at full health."""
        from repro.core.perf_model import iteration_time

        step = iteration_time(hw, wl, par)["total"]
        return cls(n1=par.tp, geom=geom or WorkloadGeometry(),
                   method=method, power=power or PowerModel(),
                   step_time_s=float(step))

    # ------------------------------------------------------------ evaluation

    def effective_tp(self, counts: Sequence[np.ndarray]) -> np.ndarray:
        """Per-replica effective TP of a candidate layout: each stage packs
        its failures independently (most-failed first — `pack_replicas`'
        order), and 1F1B gates every replica at its slowest stage. May
        contain zeros (dead replicas) — callers price those as goodput 0."""
        packed = np.stack([
            np.sort(np.asarray(c, dtype=int))[::-1] for c in counts
        ])
        return self.n1 - packed.max(axis=0)

    def replica_degradations(self, counts: Sequence[np.ndarray],
                             degradations=None):
        """Per-replica merged `DomainDegradation` of a candidate layout, or
        None when every site is clear (the binary fast path).

        Each stage's ledger follows the SAME most-failed-first packing order
        `effective_tp` prices (stable descending argsort — deterministic on
        ties), and 1F1B merges across stages: a replica inherits the worst
        straggle / weakest link of every domain it spans."""
        if degradations is None or all(d is None for d in degradations):
            return None
        from repro.runtime.events import DomainDegradation

        merged = [DomainDegradation() for _ in counts[0]]
        clear = True
        for c, degs in zip(counts, degradations):
            if degs is None:
                continue
            order = np.argsort(-np.asarray(c, dtype=int), kind="stable")
            for r, dom in enumerate(order):
                dg = degs[int(dom)]
                if dg is not None and not dg.clear:
                    merged[r] = merged[r].merge(dg)
                    clear = False
        return None if clear else tuple(merged)

    def goodput(self, counts: Sequence[np.ndarray],
                degradations=None) -> float:
        """Mean relative replica throughput of the layout (0..1).

        ``degradations`` (per-stage sequences of Optional `DomainDegradation`
        aligned with ``counts``) weights each replica by its ledger: a
        straggling domain drags its replica through `replica_throughput`'s
        slow_factor, a weak link through bw_frac, and an un-cleared SDC
        suspicion prices the replica at 0 — quarantined until the clear, so
        sparing out a suspect domain scores as a full-replica rescue."""
        tps = self.effective_tp(counts)
        degs = self.replica_degradations(counts, degradations)
        if degs is None:
            return float(np.mean([
                replica_throughput(int(t), self.n1, self.geom, self.method,
                                   self.power)
                for t in tps
            ]))
        return float(np.mean([
            0.0 if dg.sdc > 0 else
            replica_throughput(int(t), self.n1, self.geom, self.method,
                               self.power, slow_factor=dg.slow_factor,
                               bw_frac=dg.bw_frac)
            for t, dg in zip(tps, degs)
        ]))

    def gain_seconds(self, delta_goodput: float, horizon_steps: int) -> float:
        """Useful-compute seconds a goodput delta recovers over the horizon."""
        return delta_goodput * self.step_time_s * horizon_steps
