"""repro.cluster — the global repack planner (DESIGN.md §2.7).

Given a `StagedHealth` ledger, search the JOINT assignment space stage-local
packing cannot reach — spares assignable to any stage, cross-stage domain
swaps at extreme skew, adaptive pack reordering — and emit a `GlobalPlan`
plus the ordered, cost-priced state-movement actions that realize it. Every
move is priced in the reshard engine's own `TransferStats` units and gated
by goodput amortization over a configurable horizon.
"""
from repro.cluster.actions import Action
from repro.cluster.allocator import (
    AllocatorConfig, GreedyAllocator, make_allocator,
)
from repro.cluster.cost import TransitionCost, TransitionCostModel
from repro.cluster.goodput import GoodputModel
from repro.cluster.plan import GlobalPlan

__all__ = [
    "Action",
    "AllocatorConfig",
    "GlobalPlan",
    "GoodputModel",
    "GreedyAllocator",
    "TransitionCost",
    "TransitionCostModel",
    "make_allocator",
]
