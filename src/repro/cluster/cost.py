"""Transition-traffic cost model for the global repack planner (DESIGN.md
§2.7): predict, in bytes, what a candidate `StagedPlan` change would make the
reshard engine move — BEFORE moving anything.

The prediction is not a heuristic: it is computed from the SAME per-replica
`planner.TransitionPlan`s the live transition executes (`repro.reshard.
transition.replica_transition_plans`), so for a model whose per-unit byte
sizes were calibrated from the live trees (`from_trees`) the predicted total
equals the `TransferStats.bytes_moved` ledger of the executed transition
EXACTLY — the invariant `BENCH_cluster.json` and the allocator lifecycle test
assert. An `analytic` constructor prices cluster-scale what-ifs from a
`perf_model.Workload` instead (benchmarks — fig4's global-vs-stage-local
crossover).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.nonuniform import FailurePlan, StagedPlan


@dataclass(frozen=True)
class TransitionCost:
    """One priced plan change: per-stage predicted traffic + wall time."""

    stage_bytes: Tuple[int, ...]
    scaleup_bw: float

    @property
    def total_bytes(self) -> int:
        return int(sum(self.stage_bytes))

    @property
    def seconds(self) -> float:
        return self.total_bytes / self.scaleup_bw


@dataclass(frozen=True)
class TransitionCostModel:
    """Bytes a packed→packed `StagedPlan` transition moves, per stage.

    ``family_layer_bytes[k]`` is the payload one MOVED unit of the k-unit
    family carries PER LAYER, summed over every tree that rides the
    transition (params + each param-like optimizer tree — they share the
    fused buckets, so their bytes add). Moved-unit counts come from the same
    `replica_transition_plans` the engine compiles, which is what makes the
    prediction exact rather than approximate.
    """

    family_layer_bytes: Mapping[int, int]   # k -> bytes / moved unit / layer
    n_layers: int
    pp: int
    scaleup_bw: float = 9e11                # Hardware.scaleup_bw default

    # ------------------------------------------------------------ constructors

    @classmethod
    def from_trees(cls, cfg, trees: Sequence[Dict], *, pp: int,
                   scaleup_bw: float = 9e11) -> "TransitionCostModel":
        """Calibrate per-unit bytes from the LIVE packed trees (params +
        param-like optimizer trees). Every unit leaf is (D, n1*buf, *unit);
        its per-unit payload is ``prod(unit) * itemsize``. Each layer
        contributes one identical set of leaves, so the per-layer figure is
        the tree-wide sum divided by ``cfg.n_layers`` (exact division)."""
        import jax

        from repro.reshard.transition import _leaf_key
        from repro.reshard.units import ntp_unit_specs

        specs = ntp_unit_specs(cfg)
        fam_total: Dict[int, int] = {}
        for t in trees:
            for path, leaf in jax.tree_util.tree_flatten_with_path(t)[0]:
                spec = specs.get(_leaf_key(path))
                if spec is None:
                    continue
                arr = np.asarray(leaf)
                per_unit = int(np.prod(arr.shape[2:], dtype=np.int64)
                               ) * arr.dtype.itemsize
                fam_total[spec.k] = fam_total.get(spec.k, 0) + per_unit
        fam = {k: v // cfg.n_layers for k, v in fam_total.items()}
        assert all(fam[k] * cfg.n_layers == fam_total[k] for k in fam), (
            "unit leaves are not layer-uniform", fam_total)
        return cls(family_layer_bytes=fam, n_layers=cfg.n_layers, pp=pp,
                   scaleup_bw=scaleup_bw)

    @classmethod
    def analytic(cls, wl, par, *, n_unit_families: int = 128,
                 bytes_per_param: int = 12,
                 scaleup_bw: float = 9e11) -> "TransitionCostModel":
        """Cluster-scale pricing from a `perf_model.Workload` + `Parallel`:
        the model's parameters split evenly over ``n_unit_families`` units
        per layer; each moved unit drags params + AdamW moments
        (``bytes_per_param`` = 4 + 4 + 4 by default)."""
        per_layer = wl.n_params * bytes_per_param // (
            wl.n_layers * n_unit_families)
        return cls(family_layer_bytes={n_unit_families: int(per_layer)},
                   n_layers=wl.n_layers, pp=par.pp, scaleup_bw=scaleup_bw)

    # ------------------------------------------------------------ prediction

    def stage_bytes_for(self, old: FailurePlan, new: FailurePlan,
                        n_stage_layers: int) -> int:
        """Predicted traffic of ONE stage's transition (its layer slice)."""
        from repro.reshard.transition import replica_transition_plans

        if new == old:
            return 0
        total = 0
        for k, layer_bytes in self.family_layer_bytes.items():
            moved = sum(p.n_moved for p in replica_transition_plans(k, old, new))
            total += moved * layer_bytes * n_stage_layers
        return total

    def predict(self, old: Optional[StagedPlan],
                new: StagedPlan) -> TransitionCost:
        """Price ``old → new``. ``old=None`` means a fresh packing (initial
        layout, nothing in place yet): zero traffic by definition."""
        if old is None:
            return TransitionCost((0,) * new.pp, self.scaleup_bw)
        from repro.configs.shapes import stage_boundaries

        assert old.pp == new.pp == self.pp, (old.pp, new.pp, self.pp)
        bounds = stage_boundaries(self.n_layers, self.pp)
        per_stage = tuple(
            self.stage_bytes_for(old.stages[s], new.stages[s],
                                 bounds[s + 1] - bounds[s])
            for s in range(self.pp)
        )
        return TransitionCost(per_stage, self.scaleup_bw)

    def predict_bytes(self, old: Optional[StagedPlan], new: StagedPlan) -> int:
        return self.predict(old, new).total_bytes

    def seconds(self, n_bytes: int) -> float:
        return n_bytes / self.scaleup_bw
