"""`GlobalPlan` — the global repack planner's output (DESIGN.md §2.7): the
final `StagedPlan` the session should run, plus the full audit trail of how
the allocator got there (decisions, transitions, conserved failure counts,
goodput before/after, total predicted traffic)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.cluster.actions import Action
from repro.core.nonuniform import StagedPlan


@dataclass(frozen=True)
class GlobalPlan:
    """One allocator verdict for one `StagedHealth` ledger.

    ``counts`` is the final per-(stage, domain) failed-count layout AFTER
    swaps, with spare-absorbed sites zeroed; together with ``spare_sites``
    (each recording how many failures the spare soaked up) it conserves the
    ledger's total failures exactly — the domain-conservation property the
    hypothesis suite asserts. ``staged_plan`` is that layout packed
    per-stage. ``predicted_bytes`` prices the single current→final
    transition the session will execute; when the allocator is calibrated
    from the live trees it equals the executed `TransferStats.bytes_moved`
    bit-for-bit.
    """

    staged_plan: StagedPlan
    actions: Tuple[Action, ...]
    counts: Tuple[Tuple[int, ...], ...]            # per stage, post-decisions
    spare_sites: Tuple[Tuple[int, int, int], ...]  # (stage, domain, absorbed)
    swaps: Tuple[Tuple[Tuple[int, int], Tuple[int, int]], ...]
    goodput: float
    baseline_goodput: float                        # stage-local, spare-less
    baseline: Optional[StagedPlan]                 # None: baseline is dead
    predicted_bytes: int
    horizon_steps: int

    @property
    def decisions(self) -> Tuple[Action, ...]:
        return tuple(a for a in self.actions if a.kind != "transition")

    @property
    def transitions(self) -> Tuple[Action, ...]:
        """Ordered state movements executing the plan (stage order)."""
        return tuple(a for a in self.actions if a.kind == "transition")

    @property
    def moved(self) -> bool:
        return self.predicted_bytes > 0

    def summary(self) -> dict:
        return {
            "goodput": self.goodput,
            "baseline_goodput": self.baseline_goodput,
            "spares_used": len(self.spare_sites),
            "swaps": len(self.swaps),
            "predicted_bytes": self.predicted_bytes,
            "stage_tp": tuple(p.replica_tp for p in self.staged_plan.stages),
        }
