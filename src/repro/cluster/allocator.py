"""The global repack planner (DESIGN.md §2.7): a greedy search over the
JOINT assignment space of a `StagedHealth` ledger that stage-local packing
cannot reach.

Stage-local packing (`staged_plan_from_health`) already aligns each stage's
worst failures onto the lowest replicas — the allocator's wins are the moves
that cross stages:

* **cluster-wide spares** — a spare domain stands in for the worst failure
  site across ALL stages (per-stage packing cannot express this; it was the
  runtime's `NotImplementedError`);
* **cross-stage swaps** — at skewed failure distributions, exchanging a
  badly-failed domain in one stage with a healthy domain of another
  CONCENTRATES failures onto fewer replicas (1F1B gates each replica at its
  slowest stage, so two half-wounded replicas are worse than one wounded +
  one healthy);
* **adaptive reordering** — each stage's pack permutation follows the moved
  counts; the allocator emits it with the per-stage transition actions.

Every candidate move is priced by the `TransitionCostModel` (the same
per-replica transition plans the reshard engine executes) and gated by
amortization: accepted only when the predicted goodput gain over
``horizon_steps`` recovers the transfer time. Rescue moves — revivals of a
replica some stage has at TP 0, i.e. the difference between a halted job and
a running one — bypass the gate. With nothing to gain the allocator returns
the stage-local packing unchanged: global packing is ≥ stage-local by
construction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro import telemetry
from repro.cluster.actions import Action
from repro.cluster.cost import TransitionCostModel
from repro.cluster.goodput import GoodputModel
from repro.cluster.plan import GlobalPlan
from repro.core.nonuniform import StagedPlan
from repro.core.resource_manager import pack_replicas


@dataclass(frozen=True)
class AllocatorConfig:
    """Search knobs. ``horizon_steps`` is the amortization window: a move
    must recover its transfer time within this many steps of predicted
    goodput gain. ``horizon_steps=0`` disables every priced move — the
    allocator then reproduces stage-local packing bit-exactly (the
    hypothesis suite's equivalence property)."""

    horizon_steps: int = 200
    min_gain: float = 1e-9          # goodput delta below this is noise
    max_rounds: Optional[int] = None  # None: spares + pp^2 + 8


class GreedyAllocator:
    """Greedy best-move-first search; deterministic (ties break toward the
    cheaper move, then the lower (stage, domain) site — so an idle spare
    stays pinned to its site across events instead of churning).

    ``goodput`` and ``cost`` are bound by the owner (`NTPSession.create`
    calibrates the cost model from its live trees); an unbound cost model
    prices every move at zero bytes (pure goodput mode — fine for planning
    games, wrong for a live session)."""

    name = "greedy"

    def __init__(self, config: Optional[AllocatorConfig] = None, *,
                 goodput: Optional[GoodputModel] = None,
                 cost: Optional[TransitionCostModel] = None):
        self.config = config or AllocatorConfig()
        self.goodput = goodput
        self.cost = cost
        self.last_plan: Optional[GlobalPlan] = None

    def bind(self, *, goodput: Optional[GoodputModel] = None,
             cost: Optional[TransitionCostModel] = None) -> "GreedyAllocator":
        if goodput is not None:
            self.goodput = goodput
        if cost is not None:
            self.cost = cost
        return self

    # ---------------------------------------------------------------- search

    def plan(self, health, *, spares: int = 0,
             current: Optional[StagedPlan] = None) -> GlobalPlan:
        """Allocate for one `StagedHealth` ledger. ``current`` is the plan
        whose state is in place (None = fresh packing: transitions are
        free). Raises `DeadReplicaError` (via per-stage packing) if even the
        allocated layout leaves a replica at TP 0 in some stage."""
        with telemetry.get().span("cluster.plan", spares=spares) as sp:
            return self._plan_spanned(health, spares, current, sp)

    def _plan_spanned(self, health, spares, current, sp) -> GlobalPlan:
        from repro.runtime.events import StagedHealth

        assert isinstance(health, StagedHealth), type(health)
        assert all(h.domains_per_replica == 1 for h in health.stages), (
            "the global allocator plans one-domain-per-stage replicas "
            "(the staged runtime's geometry)")
        n1, pp = health.domain_size, health.pp
        gm = self.goodput if self.goodput is not None else GoodputModel(n1=n1)
        assert gm.n1 == n1, (gm.n1, n1)
        cfgc = self.config
        horizon = cfgc.horizon_steps

        counts0 = [np.asarray(h.failed, dtype=int).copy()
                   for h in health.stages]
        # degradation ledgers ride along with their PHYSICAL domain: a swap
        # moves a site's straggle/link/sdc state together with its failed
        # count, and a spare stand-in clears both (the spare is pristine)
        degs0 = None
        if any(h.degraded is not None for h in health.stages):
            degs0 = [list(h.degraded) if h.degraded is not None
                     else [None] * len(h.failed) for h in health.stages]
        base_goodput = gm.goodput(counts0, degs0)
        baseline = self._try_pack(counts0, n1)

        work = [c.copy() for c in counts0]
        work_degs = (None if degs0 is None
                     else [list(d) for d in degs0])
        g_cur = gm.goodput(work, work_degs)
        price_cur = self._price_bytes(current, work, n1)
        pool = spares
        spare_sites: List[Tuple[int, int, int]] = []
        swaps: List[Tuple[Tuple[int, int], Tuple[int, int]]] = []
        actions: List[Action] = []

        max_rounds = cfgc.max_rounds
        if max_rounds is None:
            max_rounds = spares + pp * pp + 8
        considered = 0
        for _ in range(max_rounds):
            best = None
            n_dead = int((gm.effective_tp(work) <= 0).sum())
            for cand in self._candidates(work, pool, work_degs):
                considered += 1
                w2, deg2 = self._apply_move(work, cand, work_degs)
                g2 = gm.goodput(w2, deg2)
                dg = g2 - g_cur
                dead_fixed = n_dead - int((gm.effective_tp(w2) <= 0).sum())
                price2 = self._price_bytes(current, w2, n1)
                # a zero-gain move is still worth taking when it SHAVES the
                # predicted transition (e.g. relocating an idle spare so one
                # fewer stage repacks) — traffic saved at no goodput cost
                saves = price2 < price_cur and dg >= -cfgc.min_gain
                if dg <= cfgc.min_gain and dead_fixed <= 0 and not saves:
                    continue
                marg = max(0, price2 - price_cur)
                cost_s = (self.cost.seconds(marg)
                          if self.cost is not None else 0.0)
                gain_s = gm.gain_seconds(max(dg, 0.0), horizon)
                rescue = dead_fixed > 0
                if not rescue and cost_s > gain_s:
                    continue  # does not amortize within the horizon
                # rank: revive first, then net benefit; ties to the cheaper
                # final transition, then the lower site (deterministic +
                # anti-churn)
                key = (dead_fixed, gain_s - cost_s, dg, -price2,
                       cand[0] == "spare",
                       tuple(-x for x in cand[1]),
                       tuple(-x for x in (cand[2] or (0, 0))))
                if best is None or key > best[0]:
                    best = (key, cand, w2, deg2, g2, price2, marg, cost_s,
                            gain_s, rescue)
            if best is None:
                break
            (_, cand, w2, deg2, g2, price2, marg, cost_s, gain_s,
             rescue) = best
            kind, site, other = cand
            if kind == "spare":
                absorbed = int(work[site[0]][site[1]])
                pool -= 1
                spare_sites.append((site[0], site[1], absorbed))
                actions.append(Action(
                    "spare", gain_s=gain_s, cost_s=cost_s, bytes=marg,
                    rescue=rescue, site=site, absorbed=absorbed,
                    note=f"spare domain stands in for stage {site[0]} "
                         f"domain {site[1]} ({absorbed} failed)"))
            else:
                swaps.append((site, other))
                actions.append(Action(
                    "swap", gain_s=gain_s, cost_s=cost_s, bytes=marg,
                    rescue=rescue, site=site, other=other,
                    note=f"swap stage {site[0]} domain {site[1]} with "
                         f"stage {other[0]} domain {other[1]}"))
            work, work_degs, g_cur, price_cur = w2, deg2, g2, price2

        final = self._pack(work, n1)   # DeadReplicaError if still dead
        actions.extend(self._transition_actions(current, final, work))
        predicted = self._price_bytes(current, work, n1)
        gp = GlobalPlan(
            staged_plan=final,
            actions=tuple(actions),
            counts=tuple(tuple(int(x) for x in c) for c in work),
            spare_sites=tuple(spare_sites),
            swaps=tuple(swaps),
            goodput=g_cur,
            baseline_goodput=base_goodput,
            baseline=baseline,
            predicted_bytes=predicted,
            horizon_steps=horizon,
        )
        sp.set(moves_considered=considered,
               moves_taken=len(spare_sites) + len(swaps),
               predicted_bytes=predicted,
               goodput=round(g_cur, 6))
        tel = telemetry.get()
        if tel.enabled:
            # paired series with the session's source="executed" gauge:
            # predicted-vs-executed transition traffic on one track
            tel.gauge("cluster.transition_bytes", predicted,
                      source="predicted")
        self.last_plan = gp
        return gp

    # ------------------------------------------------------------ internals

    @staticmethod
    def _candidates(work, pool, degs=None):
        """Deterministic candidate moves for one round: every failed OR
        degraded site as a spare target (pool permitting), for each ordered
        stage pair the worst site of one against the best site of the other,
        and each degraded site against the most/least-failed site of every
        other stage (pairing a straggler with an already-slow replica or
        isolating it — `GoodputModel.goodput` decides which pays)."""
        pp = len(work)

        def degraded(s, d):
            return (degs is not None and degs[s][d] is not None
                    and not degs[s][d].clear)

        if pool > 0:
            for s in range(pp):
                for dom in range(len(work[s])):
                    if work[s][dom] > 0 or degraded(s, dom):
                        yield ("spare", (s, dom), None)
        for s1 in range(pp):
            if not work[s1].any():
                continue
            i = int(np.argmax(work[s1]))
            for s2 in range(pp):
                if s2 == s1:
                    continue
                j = int(np.argmin(work[s2]))
                if work[s1][i] > work[s2][j]:
                    yield ("swap", (s1, i), (s2, j))
        if degs is None:
            return
        for s1 in range(pp):
            for dom in range(len(work[s1])):
                if not degraded(s1, dom):
                    continue
                for s2 in range(pp):
                    if s2 == s1:
                        continue
                    for j in (int(np.argmax(work[s2])),
                              int(np.argmin(work[s2]))):
                        yield ("swap", (s1, dom), (s2, j))

    @staticmethod
    def _apply_move(work, cand, degs=None):
        kind, site, other = cand
        w2 = [c.copy() for c in work]
        d2 = None if degs is None else [list(d) for d in degs]
        if kind == "spare":
            w2[site[0]][site[1]] = 0
            if d2 is not None:
                d2[site[0]][site[1]] = None   # the stand-in is pristine
        else:
            a, b = w2[site[0]][site[1]], w2[other[0]][other[1]]
            w2[site[0]][site[1]], w2[other[0]][other[1]] = b, a
            if d2 is not None:
                da, db = d2[site[0]][site[1]], d2[other[0]][other[1]]
                d2[site[0]][site[1]], d2[other[0]][other[1]] = db, da
        return w2, d2

    @staticmethod
    def _pack(work, n1) -> StagedPlan:
        from repro.runtime.events import ClusterHealth, plan_from_health

        return StagedPlan(tuple(
            plan_from_health(ClusterHealth(n1, tuple(int(x) for x in c)))
            for c in work
        ))

    @classmethod
    def _try_pack(cls, work, n1) -> Optional[StagedPlan]:
        from repro.runtime.events import DeadReplicaError

        try:
            return cls._pack(work, n1)
        except DeadReplicaError:
            return None

    def _price_bytes(self, current: Optional[StagedPlan], work, n1) -> int:
        """Predicted traffic of the ONE transition current→pack(work). A
        layout that cannot pack (dead) prices at 0 — it is gated by goodput
        (0 for dead replicas), never executed."""
        if self.cost is None or current is None:
            return 0
        cand = self._try_pack(work, n1)
        if cand is None:
            return 0
        return self.cost.predict_bytes(current, cand)

    def _transition_actions(self, current: Optional[StagedPlan],
                            final: StagedPlan, work) -> List[Action]:
        """Ordered per-stage state movements executing ``final`` against
        ``current``, each with its predicted traffic and the stage's new
        pack permutation (the adaptive reordering)."""
        if current is None:
            return []
        from repro.configs.shapes import stage_boundaries

        acts: List[Action] = []
        n_layers = self.cost.n_layers if self.cost is not None else final.pp
        bounds = stage_boundaries(n_layers, final.pp)
        for s in range(final.pp):
            if final.stages[s] == current.stages[s]:
                continue
            nbytes = 0
            if self.cost is not None:
                nbytes = self.cost.stage_bytes_for(
                    current.stages[s], final.stages[s],
                    bounds[s + 1] - bounds[s])
            order = tuple(int(x) for x in np.argsort(-work[s], kind="stable"))
            acts.append(Action(
                "transition", stage=s, bytes=nbytes,
                cost_s=(self.cost.seconds(nbytes)
                        if self.cost is not None else 0.0),
                order=order,
                note=f"repack stage {s}: {current.stages[s].replica_tp} -> "
                     f"{final.stages[s].replica_tp}"))
        return acts


def make_allocator(name: Optional[str], **kwargs) -> Optional[GreedyAllocator]:
    """CLI/config factory: ``"greedy"`` → a fresh `GreedyAllocator` (kwargs
    feed `AllocatorConfig`), ``"off"``/``None`` → None (stage-local
    packing, PR-5 behavior)."""
    if name in (None, "off", "none"):
        return None
    if name == "greedy":
        return GreedyAllocator(AllocatorConfig(**kwargs))
    raise ValueError(f"unknown allocator {name!r} (choose: greedy, off)")
