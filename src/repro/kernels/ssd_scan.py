"""Mamba-2 SSD chunk scan — Pallas TPU kernel.

The compute core of mamba2-780m: per (batch, head), iterate chunks
sequentially (innermost grid dim), carrying the (hp, ds) SSD state in VMEM
scratch; within a chunk use the matmul-heavy dual form (decay-masked
C Bᵀ attention-like block plus state injection) — MXU-aligned with
chunk length L=256, hp=64, ds=128 tiles.

  grid = (B·NH, S/L)
  x  tile (1, L, hp)   dt tile (1, L)   B,C tiles (1, L, ds)
  y  tile (1, L, hp)   state scratch (hp, ds) fp32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mode import pallas_interpret


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, h_ref, *, L: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]                                   # scalar A (negative)
    x = x_ref[0].astype(jnp.float32)               # (L, hp)
    dt = dt_ref[0].astype(jnp.float32)             # (L,)
    B = b_ref[0].astype(jnp.float32)               # (L, ds)
    C = c_ref[0].astype(jnp.float32)               # (L, ds)

    da = dt * a                                    # (L,) ≤ 0
    acum = jnp.cumsum(da)                          # inclusive
    atot = acum[-1]

    # intra-chunk dual form
    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, L)
    decay = jnp.exp(jnp.clip(acum[:, None] - acum[None, :], -60.0, 0.0))
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    M = jnp.where(ii >= jj, G * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, hp)

    # inter-chunk: contribution of the carried state
    h = h_ref[...]                                 # (hp, ds)
    cdec = jnp.exp(jnp.clip(acum, -60.0, 0.0))[:, None] * C      # (L, ds)
    y = y + jax.lax.dot_general(cdec, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: h' = exp(atot) h + sum_j exp(atot - acum_j) dt_j x_j B_j^T
    w = jnp.exp(jnp.clip(atot - acum, -60.0, 0.0)) * dt          # (L,)
    inj = jax.lax.dot_general(x * w[:, None], B, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (hp, ds)
    h_ref[...] = jnp.exp(atot) * h + inj

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 256,
             interpret: bool | None = None):
    """SSD scan over (BH, S, ·) flattened batch·heads.

    x: (BH, S, hp); dt: (BH, S); A: (BH,); B, C: (BH, S, ds).
    Returns y: (BH, S, hp) fp32. (Zero initial state; the recurrent decode
    path lives in models/ssm.py — this kernel is the train/prefill hot loop.)

    ``interpret=None`` resolves via `kernels.mode.pallas_interpret`
    (compiled on TPU/GPU, interpret on CPU).
    """
    bh, s, hp = x.shape
    ds = B.shape[-1]
    L = min(chunk, s)
    if s % L != 0:
        raise ValueError(
            f"ssd_scan: sequence length s={s} is not divisible by the "
            f"chunk length chunk={L}; pad the sequence or pass a chunk "
            f"that divides {s}"
        )
    interpret = pallas_interpret(interpret)
    kernel = functools.partial(_ssd_kernel, L=L)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // L),
        in_specs=[
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, L, hp), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L), lambda b, c: (b, c)),
            pl.BlockSpec((1, L, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, ds), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, hp), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hp, ds), jnp.float32)],
        interpret=interpret,
    )(A, x, dt, B, C)
