"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, kind: str = "causal", window: int = 4096,
                        chunk: int = 8192, softcap: Optional[float] = None):
    """q: (B,H,S,D); k/v: (B,KVH,S,D)."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    k = jnp.repeat(k, h // kvh, axis=1)
    v = jnp.repeat(v, h // kvh, axis=1)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d ** -0.5)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    ok = kp <= qp
    if kind == "sliding":
        ok &= kp > qp - window
    elif kind == "chunked":
        ok &= (kp // chunk) == (qp // chunk)
    elif kind == "bidir":
        ok = jnp.ones_like(ok)
    scores = jnp.where(ok[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x, w, *, eps: float = 1e-6, plus_one: bool = False):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    w32 = w.astype(jnp.float32)
    if plus_one:
        w32 = 1.0 + w32
    return (y * w32).astype(x.dtype)


def ssd_scan_ref(x, dt, A, B, C):
    """Sequential SSD recurrence (exact). x: (BH,S,hp); dt: (BH,S); A: (BH,);
    B/C: (BH,S,ds). Returns (BH,S,hp) fp32."""
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    bh, s, hp = x.shape
    ds = B.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct, a = inp
        h = jnp.exp(dtt * a)[:, None, None] * h + (
            dtt[:, None, None] * x_outer(xt, bt)
        )
        y = jnp.einsum("bps,bs->bp", h, ct)
        return h, y

    def x_outer(xt, bt):
        return jnp.einsum("bp,bs->bps", xt, bt)

    h0 = jnp.zeros((bh, hp, ds), jnp.float32)
    xs = (
        x.transpose(1, 0, 2),
        dt.transpose(1, 0),
        B.transpose(1, 0, 2),
        C.transpose(1, 0, 2),
        jnp.broadcast_to(A[None], (s, bh)),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2)


def reshard_pack_ref(src, send_idx):
    """src: (U+1, elems) zero-padded; send_idx: (n, s_max)."""
    return src[send_idx]
