"""NTP reshard send-bucket packing — Pallas TPU kernel.

The inner loop of the paper's pre/post-sync reshard (§4.1): gather partition
units from the local comp/sync buffer into per-destination all-to-all send
buckets according to the static Algorithm-1 tables. On GPU this is the
torch.split + all_to_all prep in Fig. 12; on TPU we fuse the gather so the
send buffer is produced in one VMEM pass.

  grid = (n_dst,); per destination: s_max unit rows gathered by index from
  the (U+1)-row zero-padded source (index U = pad ⇒ zero row).

Unit rows are 128-element multiples by construction (DESIGN.md §3.2), so
each gathered row is lane-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.mode import pallas_interpret


def _pack_kernel(idx_ref, src_ref, out_ref, *, s_max: int):
    def body(s, _):
        u = idx_ref[0, s]
        row = src_ref[u]                       # dynamic gather (one unit row)
        out_ref[0, s] = row
        return 0

    jax.lax.fori_loop(0, s_max, body, 0)


def reshard_pack(src, send_idx, *, interpret: bool | None = None):
    """src: (U+1, unit_elems) — zero-padded unit buffer (last row zeros).
    send_idx: (n, s_max) int32 local slot per (dst, msg-slot), pad = U.
    Returns send buffer (n, s_max, unit_elems).

    ``interpret=None`` resolves via `kernels.mode.pallas_interpret`
    (compiled on TPU/GPU, interpret on CPU)."""
    up1, elems = src.shape
    n, s_max = send_idx.shape
    interpret = pallas_interpret(interpret)
    kernel = functools.partial(_pack_kernel, s_max=s_max)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, s_max), lambda i: (i, 0)),
            pl.BlockSpec((up1, elems), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s_max, elems), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s_max, elems), src.dtype),
        interpret=interpret,
    )(send_idx, src)
