"""Pallas execution-mode resolution, shared by every kernel module and the
jit'd wrappers in `kernels.ops` (kept out of ``ops`` so the kernel modules
can import it without a cycle).

Kernels are COMPILED BY DEFAULT wherever a non-CPU backend exists: the
kernels target TPU, so on real accelerators the compiled path is the hot
path and interpret mode is only a debugging tool. On CPU (this container,
CI) the TPU lowering does not exist, so interpret mode — executing the
kernel body op by op — stays the fallback that validates the kernel math.

Resolution order, per call:

1. an explicit ``interpret=`` argument wins;
2. else ``REPRO_PALLAS_COMPILE`` decides when set (``1`` → compiled,
   ``0`` → interpret; the launchers' ``--pallas-compile`` sets it, and it is
   read dynamically so flipping it mid-process takes effect on the next
   call);
3. else the backend decides: compiled on TPU/GPU, interpret on CPU.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro import telemetry


def pallas_interpret(override: Optional[bool] = None,
                     kernel: Optional[str] = None) -> bool:
    """True → run the kernel in interpret mode. See the module docstring for
    the resolution order (explicit > env var > backend default).

    ``kernel`` names the dispatch site for telemetry: each resolution with a
    name counts into the ``kernels.dispatch`` series (labels: kernel, mode),
    so a run can prove which kernels actually took the compiled path. Only
    the named public wrappers in `kernels.ops` pass it — internal re-entries
    resolve anonymously and are not double-counted."""
    if override is not None:
        interpret = bool(override)
    else:
        env = os.environ.get("REPRO_PALLAS_COMPILE")
        if env is not None and env != "":
            interpret = env != "1"
        else:
            interpret = jax.default_backend() == "cpu"
    if kernel is not None:
        tel = telemetry.get()
        if tel.enabled:
            tel.counter("kernels.dispatch", kernel=kernel,
                        mode="interpret" if interpret else "compiled")
    return interpret
