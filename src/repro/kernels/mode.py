"""Pallas execution-mode resolution, shared by every kernel module and the
jit'd wrappers in `kernels.ops` (kept out of ``ops`` so the kernel modules
can import it without a cycle).

Kernels are COMPILED BY DEFAULT wherever a non-CPU backend exists: the
kernels target TPU, so on real accelerators the compiled path is the hot
path and interpret mode is only a debugging tool. On CPU (this container,
CI) the TPU lowering does not exist, so interpret mode — executing the
kernel body op by op — stays the fallback that validates the kernel math.

Resolution order, per call:

1. an explicit ``interpret=`` argument wins;
2. else ``REPRO_PALLAS_COMPILE`` decides when set (``1`` → compiled,
   ``0`` → interpret; the launchers' ``--pallas-compile`` sets it, and it is
   read dynamically so flipping it mid-process takes effect on the next
   call);
3. else the backend decides: compiled on TPU/GPU, interpret on CPU.
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def pallas_interpret(override: Optional[bool] = None) -> bool:
    """True → run the kernel in interpret mode. See the module docstring for
    the resolution order (explicit > env var > backend default)."""
    if override is not None:
        return bool(override)
    env = os.environ.get("REPRO_PALLAS_COMPILE")
    if env is not None and env != "":
        return env != "1"
    return jax.default_backend() == "cpu"
