"""Fused RMSNorm — Pallas TPU kernel.

One pass over rows: fp32 mean-of-squares + scale, optional gemma-style
(1 + w) weighting. Tiling: grid over row blocks, (br, d) VMEM tiles
(br=256 rows ⇒ ≤ 256·8192·4B = 8 MB at the largest assigned d_model).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.mode import pallas_interpret


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, plus_one: bool):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    o_ref[...] = (y * w[None, :]).astype(o_ref.dtype)


def rmsnorm(x, w, *, eps: float = 1e-6, plus_one: bool = False,
            block_rows: int = 256, interpret: bool | None = None):
    """x: (N, d); w: (d,). Returns (N, d) in x.dtype.

    ``interpret=None`` resolves via `kernels.mode.pallas_interpret`
    (compiled on TPU/GPU, interpret on CPU)."""
    n, d = x.shape
    br = min(block_rows, n)
    if n % br != 0:
        raise ValueError(
            f"rmsnorm: row count n={n} is not divisible by the row-block "
            f"size block_rows={br}; pad the rows or pass a block_rows that "
            f"divides {n}"
        )
    interpret = pallas_interpret(interpret)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps, plus_one=plus_one)
    return pl.pallas_call(
        kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, w)
