"""Gradient-bucket pack/unpack — Pallas TPU kernels (DESIGN.md §2.10).

The overlapped gradient sync (`core.overlap`) fuses every leaf that shares a
reshard plan into ONE flat (rows, Σwidths) buffer before the collective, so
the NTP reshard→psum→reshard path issues one collective per (bucket, stage)
instead of one per leaf. This module is the copy engine for that fusion: the
send-bucket gather of `kernels/reshard_pack` generalized from "rows of one
source by index" to "column slices of many sources at static offsets" —
each leaf's flattened payload lands in its own contiguous column range of
the bucket, in one VMEM pass:

  pack:   (rows, w_0), ..., (rows, w_{k-1})  ->  (rows, w_0+...+w_{k-1})
  unpack: the exact inverse (the same offsets, read instead of written).

Row count is shared by construction — bucketed leaves share a `WeightPlan`,
whose tables index unit ROWS only, so the concatenated payload is opaque to
the Algorithm-1 tables and the fused buffer reshards with the per-leaf
tables unchanged. Offsets and widths are static (they come from the leaf
shapes), so both kernels are straight-line copies with no index traffic.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.mode import pallas_interpret


def _pack_kernel(*refs):
    """refs = (*leaf_refs, out_ref): copy each leaf into its column slice."""
    out_ref = refs[-1]
    off = 0
    for ref in refs[:-1]:
        w = ref.shape[1]
        out_ref[:, off:off + w] = ref[...]
        off += w


def _unpack_kernel(flat_ref, *out_refs):
    off = 0
    for ref in out_refs:
        w = ref.shape[1]
        ref[...] = flat_ref[:, off:off + w]
        off += w


def bucket_pack(leaves: Sequence, *, interpret: bool | None = None):
    """Fuse 2-D leaves ``(rows, w_i)`` (same rows, same dtype) into one
    ``(rows, sum(w_i))`` bucket. A single leaf passes through unchanged (no
    kernel launch — nothing to fuse).

    ``interpret=None`` resolves via `kernels.mode.pallas_interpret`
    (compiled on TPU/GPU, interpret on CPU)."""
    leaves = tuple(leaves)
    if not leaves:
        raise ValueError("bucket_pack needs at least one leaf")
    rows = leaves[0].shape[0]
    dtype = leaves[0].dtype
    for x in leaves:
        if x.ndim != 2 or x.shape[0] != rows or x.dtype != dtype:
            raise ValueError(
                f"bucket leaves must be 2-D (rows={rows}, w) of {dtype}; got "
                f"{[(tuple(l.shape), str(l.dtype)) for l in leaves]}"
            )
    if len(leaves) == 1:
        return leaves[0]
    total = sum(x.shape[1] for x in leaves)
    interpret = pallas_interpret(interpret)
    return pl.pallas_call(
        _pack_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec(x.shape, lambda i: (0, 0)) for x in leaves],
        out_specs=pl.BlockSpec((rows, total), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, total), dtype),
        interpret=interpret,
    )(*leaves)


def bucket_unpack(flat, widths: Tuple[int, ...], *,
                  interpret: bool | None = None):
    """Split a ``(rows, sum(widths))`` bucket back into per-leaf ``(rows,
    w_i)`` arrays — the exact inverse of `bucket_pack` (same static
    offsets). Returns a tuple, one array per width."""
    widths = tuple(int(w) for w in widths)
    rows, total = flat.shape
    if sum(widths) != total:
        raise ValueError(f"widths {widths} do not sum to {total}")
    if len(widths) == 1:
        return (flat,)
    interpret = pallas_interpret(interpret)
    return tuple(pl.pallas_call(
        _unpack_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((rows, total), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((rows, w), lambda i: (0, 0)) for w in widths],
        out_shape=[jax.ShapeDtypeStruct((rows, w), flat.dtype)
                   for w in widths],
        interpret=interpret,
    )(flat))


def bucket_pack_ref(leaves: Sequence):
    """jnp oracle for `bucket_pack` (concatenate along columns) — the parity
    baseline in tests/test_overlap.py."""
    leaves = tuple(leaves)
    return leaves[0] if len(leaves) == 1 else jnp.concatenate(leaves, axis=1)


def bucket_unpack_ref(flat, widths: Tuple[int, ...]):
    """jnp oracle for `bucket_unpack` (static column slices)."""
    out, off = [], 0
    for w in widths:
        out.append(flat[:, off:off + w])
        off += w
    return tuple(out)
