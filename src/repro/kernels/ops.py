"""Jit'd public wrappers for the Pallas kernels.

Execution mode is resolved PER CALL by `pallas_interpret`
(`kernels/mode.py`): COMPILED BY DEFAULT wherever a non-CPU device exists,
interpret as the CPU/CI fallback. Callers can force a mode through either
the ``interpret=`` keyword or the ``REPRO_PALLAS_COMPILE`` environment
variable (``--pallas-compile`` on the launchers sets it to ``1``; ``0``
forces interpret for debugging on an accelerator). The env var is read
dynamically, so flipping it mid-process takes effect on the next call; each
mode jit-caches separately (``interpret`` is a static argname).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import bucket as _bk
from repro.kernels import flash_attention as _fa
from repro.kernels import reshard_pack as _rp
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd
from repro.kernels.mode import pallas_interpret


@functools.partial(
    jax.jit, static_argnames=("kind", "window", "chunk", "softcap",
                              "block_q", "block_k", "interpret")
)
def _flash_attention(q, k, v, *, kind, window, chunk, softcap, block_q,
                     block_k, interpret):
    return _fa.flash_attention(
        q, k, v, kind=kind, window=window, chunk=chunk, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def flash_attention(q, k, v, *, kind="causal", window=4096, chunk=8192,
                    softcap=None, block_q=512, block_k=512, interpret=None):
    return _flash_attention(
        q, k, v, kind=kind, window=window, chunk=chunk, softcap=softcap,
        block_q=block_q, block_k=block_k,
        interpret=pallas_interpret(interpret, kernel="flash_attention"),
    )


@functools.partial(
    jax.jit, static_argnames=("eps", "plus_one", "block_rows", "interpret")
)
def _rmsnorm(x, w, *, eps, plus_one, block_rows, interpret):
    return _rn.rmsnorm(
        x, w, eps=eps, plus_one=plus_one, block_rows=block_rows,
        interpret=interpret,
    )


def rmsnorm(x, w, *, eps=1e-6, plus_one=False, block_rows=256,
            interpret=None):
    return _rmsnorm(
        x, w, eps=eps, plus_one=plus_one, block_rows=block_rows,
        interpret=pallas_interpret(interpret, kernel="rmsnorm"),
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_scan(x, dt, A, B, C, *, chunk, interpret):
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)


def ssd_scan(x, dt, A, B, C, *, chunk=256, interpret=None):
    return _ssd_scan(
        x, dt, A, B, C, chunk=chunk,
        interpret=pallas_interpret(interpret, kernel="ssd_scan"),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def _reshard_pack(src, send_idx, *, interpret):
    return _rp.reshard_pack(src, send_idx, interpret=interpret)


def reshard_pack(src, send_idx, *, interpret=None):
    return _reshard_pack(
        src, send_idx,
        interpret=pallas_interpret(interpret, kernel="reshard_pack"),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def _bucket_pack(leaves, *, interpret):
    return _bk.bucket_pack(leaves, interpret=interpret)


def bucket_pack(leaves, *, interpret=None):
    """Fuse same-row 2-D gradient leaves into one flat bucket
    (kernels/bucket.py — the overlapped-sync copy engine)."""
    return _bucket_pack(
        tuple(leaves),
        interpret=pallas_interpret(interpret, kernel="bucket_pack"),
    )


@functools.partial(jax.jit, static_argnames=("widths", "interpret"))
def _bucket_unpack(flat, *, widths, interpret):
    return _bk.bucket_unpack(flat, widths, interpret=interpret)


def bucket_unpack(flat, widths, *, interpret=None):
    """Split a packed bucket back into per-leaf arrays (inverse of
    `bucket_pack`, same static column offsets)."""
    return _bucket_unpack(
        flat, widths=tuple(int(w) for w in widths),
        interpret=pallas_interpret(interpret, kernel="bucket_unpack"),
    )
