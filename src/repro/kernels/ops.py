"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; the kernels
target TPU and are validated by executing the kernel body in interpret
mode). Set REPRO_PALLAS_COMPILE=1 on a real TPU to run compiled.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import reshard_pack as _rp
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@functools.partial(
    jax.jit, static_argnames=("kind", "window", "chunk", "softcap",
                              "block_q", "block_k")
)
def flash_attention(q, k, v, *, kind="causal", window=4096, chunk=8192,
                    softcap=None, block_q=512, block_k=512):
    return _fa.flash_attention(
        q, k, v, kind=kind, window=window, chunk=chunk, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=_INTERPRET,
    )


@functools.partial(jax.jit, static_argnames=("eps", "plus_one", "block_rows"))
def rmsnorm(x, w, *, eps=1e-6, plus_one=False, block_rows=256):
    return _rn.rmsnorm(
        x, w, eps=eps, plus_one=plus_one, block_rows=block_rows,
        interpret=_INTERPRET,
    )


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B, C, *, chunk=256):
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=_INTERPRET)


@jax.jit
def reshard_pack(src, send_idx):
    return _rp.reshard_pack(src, send_idx, interpret=_INTERPRET)
