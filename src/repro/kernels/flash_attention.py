"""Flash attention forward — Pallas TPU kernel.

Blockwise softmax-attention with causal / sliding-window / chunked-local
masks and gemma2-style logit softcap: the compute hot spot of every
attention arch in the assigned pool. Tiling:

  grid = (batch·q_heads, S/bq, S/bk);  the k axis is the innermost
  (sequential) dim, carrying running (m, l, acc) in VMEM scratch.

  q tile   (1, 1, bq, d)   VMEM
  k,v tile (1, 1, bk, d)   VMEM — index-mapped h -> h // q_per_kv, so GQA
                           never materializes repeated KV heads.
  out tile (1, 1, bq, d)   VMEM, written on the last k step.

bq/bk default 512/512 (multiples of the 128 MXU tile; ~(512·128 + 2·512·128
+ 512·512)·4B ≈ 1.6 MB of VMEM live per step at d=128).

Validated in interpret mode against ref.flash_attention_ref across
shapes/dtypes/mask kinds (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mode import pallas_interpret

NEG_INF = -1e30


def _mask(kind: str, q_pos, k_pos, window: int, chunk: int):
    q = q_pos[:, None]
    k = k_pos[None, :]
    ok = k <= q
    if kind == "sliding":
        ok &= k > q - window
    elif kind == "chunked":
        ok &= (k // chunk) == (q // chunk)
    elif kind == "bidir":
        ok = jnp.ones_like(ok)
    return ok


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, kind: str, window: int, chunk: int, softcap: Optional[float],
    scale: float, bq: int, bk: int, nk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                   # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq,), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    s = jnp.where(_mask(kind, q_pos, k_pos, window, chunk), s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q, k, v, *,
    kind: str = "causal",          # causal | sliding | chunked | bidir
    window: int = 4096,
    chunk: int = 8192,
    softcap: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
):
    """q: (B, H, S, D); k/v: (B, KVH, S, D) with H % KVH == 0.
    Returns (B, H, S, D) in q.dtype.

    ``interpret=None`` resolves via `kernels.mode.pallas_interpret`
    (compiled on TPU/GPU, interpret on CPU)."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    qpk = h // kvh
    bq = min(block_q, s)
    bk = min(block_k, s)
    if s % bq != 0:
        raise ValueError(
            f"flash_attention: sequence length s={s} is not divisible by the "
            f"query-block size block_q={bq}; pad the sequence or pass a "
            f"block_q that divides {s}"
        )
    if s % bk != 0:
        raise ValueError(
            f"flash_attention: sequence length s={s} is not divisible by the "
            f"key-block size block_k={bk}; pad the sequence or pass a "
            f"block_k that divides {s}"
        )
    nq, nk = s // bq, s // bk
    interpret = pallas_interpret(interpret)

    kernel = functools.partial(
        _flash_kernel, kind=kind, window=window, chunk=chunk,
        softcap=softcap, scale=d ** -0.5, bq=bq, bk=bk, nk=nk,
    )
    grid = (b * h, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bh, qi, ki: (bh // h, bh % h, qi, 0)),
            pl.BlockSpec(
                (1, 1, bk, d), lambda bh, qi, ki: (bh // h, (bh % h) // qpk, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, d), lambda bh, qi, ki: (bh // h, (bh % h) // qpk, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, d), lambda bh, qi, ki: (bh // h, bh % h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),    # running max
            pltpu.VMEM((bq,), jnp.float32),    # running denom
            pltpu.VMEM((bq, d), jnp.float32),  # running accumulator
        ],
        interpret=interpret,
    )(q, k, v)
