"""Pluggable optimizer interface for the step builders.

A tiny (init, update) pair — enough for train/steps.py-style loops and the
NTP runtime (core/ntp_train.py, runtime/session.py) to share one contract:

    state = opt.init(params)
    params, state, metrics = opt.update(grads, state, params,
                                        norm_weights=None)

``metrics`` always contains ``grad_norm`` and ``lr``; every state dict
carries an int32 ``step`` counter. ``norm_weights`` (optional pytree of
per-leaf scalars) corrects the global grad norm when the gradient tree holds
redundant copies — the NTP step passes 1/D for packed unit buffers so
clipping and the metric match canonical training exactly. States whose extra
leaves mirror the param tree (AdamW's ``m``/``v``/``master``) advertise them
in ``param_like`` so the NTP runtime can repack them across failure plans.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (grads, state, params) -> (params, state, metrics)
    param_like: Tuple[str, ...] = ()  # state keys structured like the params


def sgd(lr: float) -> Optimizer:
    """Plain SGD — used where exact equivalence to a hand-derived reference
    matters (no clipping, no adaptive state)."""

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, norm_weights=None):
        gnorm = global_norm(grads, norm_weights)
        new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
        return new_params, {"step": state["step"] + 1}, metrics

    return Optimizer(name="sgd", init=init, update=update)


def adamw(cfg: Optional[AdamWConfig] = None,
          lr_schedule: Optional[Callable] = None) -> Optimizer:
    """AdamW (repro.optim.adamw) with an optional lr schedule on the state's
    step counter."""
    cfg = cfg or AdamWConfig()

    def init(params):
        return adamw_init(params, cfg)

    def update(grads, state, params, norm_weights=None):
        scale = lr_schedule(state["step"]) if lr_schedule is not None else 1.0
        return adamw_update(grads, state, params, cfg, scale,
                            norm_weights=norm_weights)

    return Optimizer(
        name="adamw", init=init, update=update,
        param_like=("m", "v", "master"),
    )
