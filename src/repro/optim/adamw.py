"""AdamW with fp32 moments (+ optional fp32 master params when the model
params are bf16). Pure-pytree implementation; no external deps."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    keep_master: bool = True  # fp32 master copy when params are low-precision


def adamw_init(params, cfg: AdamWConfig) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.keep_master and any(
        p.dtype != jnp.float32 for p in jax.tree.leaves(params)
    ):
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree, norm_weights=None) -> jnp.ndarray:
    """L2 norm of a gradient tree. ``norm_weights`` (matching pytree of
    scalars) weights each leaf's squared contribution — the NTP step uses
    1/D for packed unit buffers, which hold D identical replica copies of
    every synced unit gradient, so the result equals the canonical norm."""
    if norm_weights is None:
        return jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(tree))
        )
    return jnp.sqrt(
        sum(w * jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g, w in zip(jax.tree.leaves(tree),
                            jax.tree.leaves(norm_weights)))
    )


def adamw_update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0,
                 norm_weights=None):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads, norm_weights)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    master = state.get("master", params)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (update + cfg.weight_decay * p32)
        return m_new, v_new, p_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(master)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])

    param_dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(
        lambda p32, dt: p32.astype(dt), new_master, param_dtypes
    )
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = new_master
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, new_state, metrics
