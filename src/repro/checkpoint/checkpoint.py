"""Minimal sharding-aware checkpointing: gather to host, write one .npz
atomically, restore with device_put back to the original shardings.

(The paper's recovery story — §3.3 — restarts from a checkpoint with ranks
re-packed; examples/train_ntp_failure.py uses exactly this path.)

numpy's savez cannot round-trip ml_dtypes leaves (bf16, fp8, ...): they are
widened to float32 on save, and the ORIGINAL dtype name is recorded next to
the array (``__dtype__/<key>``) so `load_checkpoint` casts back — even when
no target tree is supplied, or the target tree's leaves are float32 (a
fresh f32-initialized session restoring a bf16 serving KV cache must get
bf16 back, not silently doubled memory). Recorded dtypes win over the
target tree's leaf dtype; callers wanting a different dtype cast after
load. Checkpoints written before the records existed fall back to the
target leaf dtype as before.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Dict, Optional

import numpy as np

import jax

_DTYPE_KEY = "__dtype__/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[key] = leaf
    return flat


def save_checkpoint(path: str, tree, step: Optional[int] = None) -> None:
    flat = {}
    for k, v in _flatten(tree).items():
        if k.startswith(_DTYPE_KEY) or k == "__step__":
            raise ValueError(f"reserved checkpoint key {k!r}")
        a = np.asarray(jax.device_get(v))
        if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
            # widen for savez; record the original so load casts back
            flat[_DTYPE_KEY + k] = np.asarray(a.dtype.name)
            a = a.astype(np.float32)
        flat[k] = a
    if step is not None:
        flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str, like_tree=None, shardings=None):
    """Restore a checkpoint. With ``like_tree``: into its structure
    (shapes asserted; recorded original dtypes win over the target leaf
    dtype, which only fills in for pre-record checkpoints), optionally
    device_put with a matching pytree of shardings. Without: returns the
    flat ``{path-key: array}`` dict with original dtypes restored.
    Returns (tree_or_flat_dict, step|None)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    step = int(flat.pop("__step__")) if "__step__" in flat else None
    dtypes = {
        k[len(_DTYPE_KEY):]: str(flat.pop(k))
        for k in [k for k in flat if k.startswith(_DTYPE_KEY)]
    }

    def restore(key, arr, like=None):
        name = dtypes.get(key)
        if name is None and like is not None:
            name = like.dtype
        if name is None:
            return arr  # no-like path, no record: numpy as stored
        return jax.numpy.asarray(arr).astype(jax.numpy.dtype(name))

    if like_tree is None:
        return {k: restore(k, v) for k, v in flat.items()}, step

    paths = jax.tree_util.tree_flatten_with_path(like_tree)[0]
    treedef = jax.tree_util.tree_structure(like_tree)
    leaves = []
    for path, like in paths:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = flat[key]
        assert arr.shape == like.shape, (key, arr.shape, like.shape)
        leaves.append(restore(key, arr, like))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step
