"""Minimal sharding-aware checkpointing: gather to host, write one .npz
atomically, restore with device_put back to the original shardings.

(The paper's recovery story — §3.3 — restarts from a checkpoint with ranks
re-packed; examples/train_ntp_failure.py uses exactly this path.)
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Dict, Optional

import numpy as np

import jax


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[key] = leaf
    return flat


def save_checkpoint(path: str, tree, step: Optional[int] = None) -> None:
    def host(v):
        a = np.asarray(jax.device_get(v))
        # numpy can't round-trip ml_dtypes (bf16 etc.) through savez: store
        # as float32; load_checkpoint casts back to the target leaf dtype.
        if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
            a = a.astype(np.float32)
        return a

    flat = {k: host(v) for k, v in _flatten(tree).items()}
    if step is not None:
        flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally device_put with
    a matching pytree of shardings. Returns (tree, step|None)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    step = int(flat.pop("__step__")) if "__step__" in flat else None

    paths = jax.tree_util.tree_flatten_with_path(like_tree)[0]
    treedef = jax.tree_util.tree_structure(like_tree)
    leaves = []
    for path, like in paths:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = flat[key]
        assert arr.shape == like.shape, (key, arr.shape, like.shape)
        leaves.append(jax.numpy.asarray(arr).astype(like.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step
