"""Serving launcher: continuous-batching NTP inference under a
failure/recovery trace (DESIGN.md §2.5).

Examples (CPU container — smoke-scale archs execute):

  # 60 requests through one healthy replica
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --requests 60

  # the paper's scenario, serving-side: replay a Llama3-calibrated
  # fail/repair trace against 2 live replicas; the KV cache reshards
  # mid-decode instead of dropping in-flight requests
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b \\
      --replicas 2 --requests 120 --trace 2e2 --policy ntp_pw

  # the baseline for comparison: any failure drops the whole replica
  PYTHONPATH=src python -m repro.launch.serve --replicas 2 --requests 120 \\
      --trace 2e2 --policy drop
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b",
                    help="arch config id (served at reduced/smoke scale "
                         "unless --full)")
    ap.add_argument("--full", action="store_true",
                    help="serve the full-size config (slow on CPU)")
    ap.add_argument("--policy", choices=["drop", "ntp", "ntp_pw"],
                    default="ntp_pw")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--tp", type=int, default=4,
                    help="scale-up domain width (ranks per replica)")
    ap.add_argument("--slots", type=int, default=8,
                    help="KV-cache slots per replica (continuous batching)")
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--prefill-len", type=int, default=32)
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--arrival-every", type=float, default=1.0,
                    help="mean ticks between request arrivals")
    ap.add_argument("--slo", type=float, default=0.0, metavar="TICKS",
                    help="per-request completion deadline: arrival + SLO "
                         "ticks (0 = no SLO; admission rejects hopeless "
                         "requests up front)")
    ap.add_argument("--trace", type=float, default=None, metavar="RATE_MULT",
                    help="replay a Llama3-calibrated fail/repair trace at "
                         "this failure-rate multiplier (~2e2 suits the tiny "
                         "default cluster: hardware repairs take 72-120 "
                         "ticks, so much hotter rates drown the replica)")
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--trace-mix", default=None, metavar="KIND=RATE[,...]",
                    help="mix degradation kinds into the sampled trace: "
                         "comma list of straggler=R, link=R, sdc=R onset "
                         "rates as multiples of the binary failure rate "
                         "(e.g. straggler=0.5,sdc=0.1); needs --trace")
    ap.add_argument("--quarantine", choices=["on", "off"], default="on",
                    help="SDC policy (default on): drain the suspect "
                         "replica (in-flight requests finish, no new "
                         "admits) until the clear; off = reprice only")
    ap.add_argument("--ticks-per-hour", type=float, default=1.0,
                    help="serving wall ticks per simulated trace hour")
    ap.add_argument("--max-ticks", type=int, default=5000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=25)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route reshard send-bucket packing through the "
                         "Pallas reshard_pack kernel")
    ap.add_argument("--pallas-compile", action="store_true",
                    help="run Pallas kernels compiled (TPU) instead of "
                         "interpret mode; sets REPRO_PALLAS_COMPILE=1")
    ap.add_argument("--telemetry", default=None, metavar="OUT.jsonl",
                    help="record the run's telemetry stream (admission, "
                         "preemptions, TTFT/TPOT, transition spans) as "
                         "JSONL; fold it offline with python -m "
                         "repro.launch.telemetry_report OUT.jsonl")
    args = ap.parse_args()
    trace_mix_kwargs = {}
    if args.trace_mix is not None:
        if args.trace is None:
            ap.error("--trace-mix needs --trace (the mix rates scale the "
                     "same sampled trace)")
        from repro.core.failure_model import parse_trace_mix

        try:
            trace_mix_kwargs = parse_trace_mix(args.trace_mix)
        except ValueError as e:
            ap.error(f"--trace-mix: {e}")
    if args.quarantine == "off" and args.trace is None:
        ap.error("--quarantine shapes the trace-driven SDC response; it "
                 "needs --trace")
    if args.pallas_compile:
        import os

        os.environ["REPRO_PALLAS_COMPILE"] = "1"
    if args.telemetry:
        from repro import telemetry

        telemetry.configure(jsonl=args.telemetry)

    import numpy as np
    import jax

    from repro.configs import get_arch, reduced
    from repro.core.failure_model import FailureTraceConfig
    from repro.runtime import event_kind, schedule_from_trace
    from repro.serve import Request, Router, ServeSession

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced(cfg)

    session = ServeSession.create(
        cfg, replicas=args.replicas, n1=args.tp, slots=args.slots,
        max_len=args.max_len, prefill_len=args.prefill_len,
        policy=args.policy, key=jax.random.PRNGKey(args.seed),
        use_kernel=args.use_kernel, quarantine=args.quarantine == "on",
    )
    router = Router(session)
    n_par = sum(p.size for p in jax.tree.leaves(session.params))
    print(f"serve: arch={cfg.arch_id} params={n_par/1e6:.1f}M "
          f"replicas={args.replicas}×TP{args.tp} slots={args.slots} "
          f"policy={args.policy}")

    schedule = []
    if args.trace is not None:
        trace_cfg = FailureTraceConfig(
            n_gpus=args.replicas * args.tp, domain_size=args.tp,
            days=args.max_ticks / args.ticks_per_hour / 24.0,
            rate_multiplier=args.trace, seed=args.trace_seed,
            **trace_mix_kwargs,
        )
        schedule = schedule_from_trace(
            trace_cfg, steps=args.max_ticks, steps_per_hour=args.ticks_per_hour
        )
        from collections import Counter

        kinds = Counter(event_kind(s.event) for s in schedule)
        print(f"trace: {len(schedule)} events "
              f"({', '.join(f'{k}={n}' for k, n in sorted(kinds.items()))})")

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(
        rng.exponential(args.arrival_every, args.requests)
    ).astype(int)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab_size,
                                size=max(1, args.prompt_len)).astype(np.int32),
            max_new=args.max_new,
            deadline=(float(arrivals[i]) + args.slo) if args.slo else None,
        )
        for i in range(args.requests)
    ]

    t0 = time.time()
    next_req = 0
    tick = 0
    while tick < args.max_ticks:
        while schedule and schedule[0].step <= tick:
            ev = schedule.pop(0).event
            router.apply(ev)
            print(f"*** tick {tick}: {event_kind(ev)} domain {ev.domain} -> "
                  f"tp {session.replica_tp} "
                  f"speeds {[round(e.rel_speed, 3) for e in session.engines]}")
        while next_req < len(reqs) and arrivals[next_req] <= tick:
            router.submit(reqs[next_req])
            next_req += 1
        router.step()
        tick += 1
        if tick % args.log_every == 0:
            g = router.goodput()
            print(f"tick {tick:5d}  done {g['completed']:4d}/{args.requests}"
                  f"  queue {len(router.queue):3d}"
                  f"  tok/tick {g['tokens_per_tick']:.2f}"
                  f"  ({time.time()-t0:.1f}s)", flush=True)
        if (next_req == len(reqs) and not router.queue
                and all(e.n_active == 0 for e in session.engines)):
            break

    g = router.goodput()
    print(f"served {g['completed']}/{args.requests} requests in {tick} ticks "
          f"({time.time()-t0:.1f}s wall): goodput {g['tokens_per_tick']:.2f} "
          f"tok/tick, SLO attainment {g['slo_attainment']:.3f}, "
          f"{g['rejected']} rejected, {g['preemptions']} preemptions")
    for r, e in enumerate(session.engines):
        print(f"  replica {r}: tp {e.tp} tokens {e.stats['tokens']} "
              f"reshards {e.stats['reshards']} "
              f"({e.stats['reshard_bytes']/1e3:.1f} kB moved)")


if __name__ == "__main__":
    main()
