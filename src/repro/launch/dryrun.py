import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax import: jax locks the device
#   count on first init. 512 placeholder host devices back the production
#   meshes (16×16 single-pod, 2×16×16 multi-pod). Never set this globally —
#   smoke tests and benches see 1 device.
"""Multi-pod dry-run driver.

For every (arch × input-shape × mesh):
  jax.jit(step, in_shardings, out_shardings).lower(**input_specs).compile()
then record memory_analysis(), cost_analysis(), and the collective schedule
parsed from the optimized HLO, into results/dryrun/*.json — the source data
for EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline.py.
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, List

import numpy as np

import jax

from repro.configs import SHAPES, all_archs, get_arch, get_shape
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.train.steps import make_setup

# ---------------------------------------------------------------------------
# target hardware constants (TPU v5e-like, per chip)
PEAK_FLOPS = 197e12     # bf16 FLOP/s
HBM_BW = 819e9          # bytes/s
LINK_BW = 50e9          # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

_COLL_RE = re.compile(
    r"=\s+(?P<rtype>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> List[dict]:
    """Per-device moved-bytes estimate for each collective (ring formulas)."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rbytes = _bytes_of(m.group("rtype"))
        gi = _GROUPS_ITOTA_RE.search(line)
        if gi:
            n = int(gi.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            n = len(gl.group(1).split(",")) if gl else 1
        if n <= 1:
            moved = 0.0
        elif op == "all-reduce":
            moved = 2.0 * rbytes * (n - 1) / n
        elif op == "all-gather":
            moved = rbytes * (n - 1) / n
        elif op == "reduce-scatter":
            moved = float(rbytes) * (n - 1)
        elif op == "all-to-all":
            moved = rbytes * (n - 1) / n
        else:  # collective-permute
            moved = float(rbytes)
        out.append({"op": op, "result_bytes": rbytes, "group": n, "moved_bytes": moved})
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_one(arch_id: str, shape_name: str, multi_pod: bool, unroll: bool = False) -> Dict:
    cfg = get_arch(arch_id)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "ok": False,
    }
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        rec.update(skipped=True, reason=cfg.long_decode_note)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    su = make_setup(cfg, shape, mesh, dp_axes=dp_axes(mesh), scan_unroll=unroll)
    with mesh:
        step = su.jit_step()
        lowered = step.lower(*su.abstract_args())
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):
        xla_cost = xla_cost[0]
    # loop-aware analysis (launch/hlo_analysis.py): XLA's cost_analysis counts
    # while bodies once; ours multiplies by known_trip_count.
    hlo = analyze_hlo(compiled.as_text())

    flops_dev = float(hlo["flops"])
    bytes_dev = float(hlo["bytes"])
    coll_dev = float(hlo["collective_moved_bytes"])
    mflops = model_flops(cfg, shape)

    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))[1]

    per_type = hlo["collectives"]

    rec.update(
        ok=True,
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        hlo_flops_per_device=flops_dev,
        hlo_bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll_dev,
        collectives=per_type,
        unknown_trip_count_loops=hlo["unknown_trip_count_loops"],
        xla_cost_flops=float(xla_cost.get("flops", 0.0)),
        xla_bytes_accessed=float(xla_cost.get("bytes accessed", 0.0)),
        roofline={
            "compute_s": t_comp,
            "memory_s": t_mem,
            "collective_s": t_coll,
            "dominant": dom,
        },
        model_flops_total=mflops,
        model_flops_per_device=mflops / chips,
        useful_flops_ratio=(mflops / chips) / flops_dev if flops_dev else None,
        n_params=cfg.n_params(),
        n_active_params=cfg.n_active_params(),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--print-hlo-collectives", action="store_true")
    args = ap.parse_args()

    archs = list(all_archs()) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip existing] {path}")
                    continue
                print(f"=== dryrun {arch} × {shape} × {mesh_name}", flush=True)
                try:
                    rec = run_one(arch, shape, mp)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "ok": False, "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    failures += 1
                    print(rec["error"], file=sys.stderr, flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("ok"):
                    r = rec["roofline"]
                    print(
                        f"  ok: compute {r['compute_s']*1e3:.2f}ms  memory "
                        f"{r['memory_s']*1e3:.2f}ms  collective {r['collective_s']*1e3:.2f}ms"
                        f"  dominant={r['dominant']}  useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)}",
                        flush=True,
                    )
                elif rec.get("skipped"):
                    print(f"  skipped: {rec['reason']}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
