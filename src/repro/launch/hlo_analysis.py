"""Loop-aware cost analysis of optimized (post-SPMD) HLO text.

Why not ``compiled.cost_analysis()``: XLA counts a while-loop body ONCE, not
× trip-count, so any lax.scan (layer stacks, flash-attention tiles) is
massively under-counted. Optimized HLO annotates
``backend_config={"known_trip_count":{"n":...}}`` on while ops, so we walk the
call graph ourselves:

  flops  — dot ops exact (2·|result|·K from lhs_contracting_dims), other
           elementwise/reduce ops at 1 flop/element; fusion bodies descended
           for flops only.
  bytes  — memory traffic at materialization boundaries: every top-level
           instruction contributes |operands| + |result|, fusions counted at
           their boundary only (fused interiors live in registers/VMEM) —
           a closer model of HBM traffic than XLA's per-op sum.
  colls  — all-reduce / all-gather / reduce-scatter / all-to-all /
           collective-permute with ring-model moved-bytes, × trip counts.

Everything is per-device (the module is the post-partitioning per-device
program).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_ELTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "tanh", "rsqrt", "sqrt", "log",
    "log-plus-one", "negate", "abs", "sign", "floor", "ceil", "compare",
    "select", "and", "or", "xor", "not", "clamp", "cosine", "sine", "erf",
    "logistic", "atan2", "remainder", "round-nearest-afz",
    "round-nearest-even", "is-finite", "cbrt",
}
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
}
_COLLS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_TRIP_RE = re.compile(r"known_trip_count\\?\"?:\{\\?\"?n\\?\"?:\\?\"?(\d+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_info(type_str: str) -> Tuple[int, int]:
    """(bytes, elements) of a type string (tuples summed)."""
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_b, total_e


def _first_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    rtype: str
    op: str
    operands: List[str]
    attrs: str
    is_root: bool = False


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_moved: float = 0.0
    coll: Dict[str, Dict[str, float]] = field(default_factory=dict)
    unknown_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_moved += other.coll_moved * mult
        self.unknown_loops += other.unknown_loops
        for k, v in other.coll.items():
            d = self.coll.setdefault(k, {"count": 0.0, "moved_bytes": 0.0,
                                         "result_bytes": 0.0})
            d["count"] += v["count"] * mult
            d["moved_bytes"] += v["moved_bytes"] * mult
            d["result_bytes"] += v["result_bytes"] * mult


def _split_operands(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            depth += ch in "([{"
            depth -= ch in ")]}"
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    toks = []
    for o in out:
        # operand may be '%name' or 'f32[..]{..} %name'
        name = None
        for t in reversed(o.split()):
            if t.startswith("%"):
                name = t.lstrip("%")
                break
        toks.append(name if name is not None else o)
    return toks


_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR_HEAD_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def parse_module(text: str):
    """Returns (computations: name -> list[Instr], entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _HDR_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_HEAD_RE.match(line)
        if not m:
            continue
        rest = line[m.end():]
        # result type: '(...)' tuple or 'dtype[...]...' up to ' op('
        if rest.startswith("("):
            depth = 0
            for i, ch in enumerate(rest):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            rtype = rest[: i + 1]
            rest2 = rest[i + 1:].lstrip()
        else:
            sp = rest.find(" ")
            rtype = rest[:sp]
            rest2 = rest[sp + 1:].lstrip()
        par = rest2.find("(")
        if par < 0:
            continue
        op = rest2[:par].strip()
        depth = 0
        for j in range(par, len(rest2)):
            depth += rest2[j] == "("
            depth -= rest2[j] == ")"
            if depth == 0:
                break
        operand_str = rest2[par + 1 : j]
        attrs = rest2[j + 1 :]
        comps[cur].append(
            Instr(
                name=m.group(2),
                rtype=rtype,
                op=op,
                operands=_split_operands(operand_str) if operand_str.strip() else [],
                attrs=attrs,
                is_root=bool(m.group(1)),
            )
        )
    return comps, entry


def _group_size(attrs: str, default: int = 1) -> int:
    gi = _GROUPS_IOTA_RE.search(attrs)
    if gi:
        return int(gi.group(2))
    gl = _GROUPS_LIST_RE.search(attrs)
    if gl:
        return len([t for t in gl.group(1).split(",") if t.strip() != ""])
    return default


def _coll_moved(op: str, rbytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * rbytes * (n - 1) / n
    if op == "all-gather":
        return rbytes * (n - 1) / n
    if op == "reduce-scatter":
        return float(rbytes) * (n - 1)
    if op == "all-to-all":
        return rbytes * (n - 1) / n
    return float(rbytes)  # collective-permute


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self.symtab: Dict[str, Dict[str, str]] = {
            c: {i.name: i.rtype for i in instrs} for c, instrs in self.comps.items()
        }
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    def cost(self) -> Cost:
        return self._comp_cost(self.entry, count_bytes=True)

    # ------------------------------------------------------------------
    def _comp_cost(self, name: str, count_bytes: bool) -> Cost:
        key = (name, count_bytes)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        self._memo[key] = total  # guard (acyclic anyway)
        for ins in self.comps.get(name, []):
            total.add(self._instr_cost(name, ins, count_bytes))
        return total

    def _operand_bytes(self, comp: str, ins: Instr) -> float:
        st = self.symtab[comp]
        b = 0.0
        for o in ins.operands:
            t = st.get(o)
            if t:
                b += _shape_info(t)[0]
        return b

    def _instr_cost(self, comp: str, ins: Instr, count_bytes: bool) -> Cost:
        c = Cost()
        op = ins.op
        base = op[:-6] if op.endswith("-start") else op
        rbytes, relems = _shape_info(ins.rtype)

        if op in _NO_TRAFFIC or op.endswith("-done") or op == "copy-done":
            return c

        # ---- control flow ------------------------------------------------
        if op == "while":
            trip_m = _TRIP_RE.search(ins.attrs)
            trip = int(trip_m.group(1)) if trip_m else 1
            if not trip_m:
                c.unknown_loops += 1
            body = _BODY_RE.search(ins.attrs)
            cond = _COND_RE.search(ins.attrs)
            if body:
                c.add(self._comp_cost(body.group(1), count_bytes), trip)
            if cond:
                c.add(self._comp_cost(cond.group(1), count_bytes), trip)
            return c
        if op == "conditional":
            br = _BRANCHES_RE.search(ins.attrs)
            if br:
                names = [t.strip().lstrip("%") for t in br.group(1).split(",")]
                subs = [self._comp_cost(n, count_bytes) for n in names]
                if subs:
                    worst = max(subs, key=lambda s: s.flops + s.bytes)
                    c.add(worst)
            return c
        if op == "call":
            ap = _APPLY_RE.search(ins.attrs)
            if ap:
                c.add(self._comp_cost(ap.group(1), count_bytes))
            return c
        if op == "fusion":
            fc = _CALLS_RE.search(ins.attrs)
            if fc:
                inner = self._comp_cost(fc.group(1), count_bytes=False)
                c.flops += inner.flops
                c.coll_moved += inner.coll_moved
                for k, v in inner.coll.items():
                    c.add(Cost(coll=dict([(k, v)])))
            if count_bytes:
                c.bytes += rbytes + self._operand_bytes(comp, ins)
            return c

        # ---- collectives ---------------------------------------------------
        if base in _COLLS:
            n = _group_size(ins.attrs)
            moved = _coll_moved(base, rbytes, n)
            c.coll_moved += moved
            c.coll[base] = {"count": 1.0, "moved_bytes": moved,
                            "result_bytes": float(rbytes)}
            if count_bytes:
                c.bytes += rbytes + self._operand_bytes(comp, ins)
            return c

        # ---- slicing/scatter: in-place semantics, not full-operand copies --
        if op in ("dynamic-slice", "gather"):
            if count_bytes:
                c.bytes += 2.0 * rbytes        # read slice + write result
            return c
        if op in ("dynamic-update-slice", "scatter"):
            if count_bytes:
                st = self.symtab[comp]
                upd = ins.operands[1] if len(ins.operands) > 1 else None
                ub = _shape_info(st.get(upd, ""))[0] if upd else rbytes
                c.bytes += 2.0 * ub            # read update + write region
            return c
        if op in ("slice", "concatenate", "reshape", "transpose", "copy",
                  "broadcast", "pad", "reverse"):
            if count_bytes:
                c.bytes += rbytes + self._operand_bytes(comp, ins)
            return c

        # ---- compute -------------------------------------------------------
        if op == "dot":
            lhs_t = self.symtab[comp].get(ins.operands[0], "") if ins.operands else ""
            dims = _first_dims(lhs_t)
            cm = _LHS_CONTRACT_RE.search(ins.attrs)
            k = 1
            if cm and dims:
                for d in cm.group(1).split(","):
                    if d:
                        k *= dims[int(d)]
            _, relems_arr = _shape_info(ins.rtype)
            c.flops += 2.0 * relems_arr * k
        elif op == "convolution":
            c.flops += 2.0 * relems  # lower bound; no convs in these models
        elif op in _ELTWISE:
            c.flops += relems
        elif op in ("reduce", "reduce-window"):
            c.flops += self._operand_bytes(comp, ins) / 4.0  # ~1 flop/elem
        elif op in ("sort",):
            c.flops += relems * 10.0

        if count_bytes:
            c.bytes += rbytes + self._operand_bytes(comp, ins)
        return c


def analyze_hlo(text: str) -> Dict:
    model = HloCostModel(text)
    c = model.cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_moved_bytes": c.coll_moved,
        "collectives": c.coll,
        "unknown_trip_count_loops": c.unknown_loops,
    }


# ---------------------------------------------------------------------------
# profiling: top byte/flop contributors (the dry-run "profile" for §Perf)

_METADATA_RE = re.compile(r'op_name="([^"]*)"')


def top_contributors(text: str, n: int = 25) -> List[dict]:
    """Attribute bytes/flops to (opcode, jax op_name) pairs, with while-body
    trip multiplication. The §Perf hypothesis source."""
    model = HloCostModel(text)

    # computation -> multiplicity (entry=1; while bodies × trip)
    mult: Dict[str, float] = {model.entry: 1.0}
    changed = True
    while changed:
        changed = False
        for comp, instrs in model.comps.items():
            if comp not in mult:
                continue
            m = mult[comp]
            for ins in instrs:
                # NB: fusions (_CALLS_RE) are NOT propagated — their interior
                # is already attributed at the fusion boundary instruction.
                for regex, scale in ((_BODY_RE, None), (_COND_RE, None)):
                    mm = regex.search(ins.attrs)
                    if not mm:
                        continue
                    if scale is None:
                        tm = _TRIP_RE.search(ins.attrs)
                        scale_eff = float(tm.group(1)) if tm else 1.0
                    else:
                        scale_eff = scale
                    name = mm.group(1)
                    new = m * scale_eff
                    if mult.get(name, 0.0) < new:
                        mult[name] = new
                        changed = True

    agg: Dict[str, dict] = {}
    for comp, instrs in model.comps.items():
        m = mult.get(comp)
        if m is None:
            continue
        for ins in instrs:
            if ins.op in ("while", "conditional", "call") or ins.op in _NO_TRAFFIC:
                continue
            c = model._instr_cost(comp, ins, count_bytes=True)
            meta = _METADATA_RE.search(ins.attrs)
            tag = meta.group(1).split("/")[-1] if meta else ins.op
            key = f"{ins.op}:{tag}"
            d = agg.setdefault(key, {"bytes": 0.0, "flops": 0.0, "count": 0})
            d["bytes"] += (c.bytes) * m
            d["flops"] += c.flops * m
            d["count"] += m
    out = [dict(key=k, **v) for k, v in agg.items()]
    out.sort(key=lambda d: -d["bytes"])
    return out[:n]
