"""Offline telemetry folding: JSONL stream → goodput-decomposition table
(+ optional Perfetto trace) — DESIGN.md §2.9.

The recorder stream is lossless, so everything here is arithmetic over the
recorded events; nothing is re-simulated:

* **goodput table** (per power policy, from the `train.goodput` /
  `train.goodput_unboosted` gauge series the orchestrator records with the
  SAME local-batch arithmetic as `TraceRunner.goodput()` — the folded mean
  matches the runner's own accounting exactly);
* **time decomposition** — ``compute / bubble / reshard / exposed-comm``
  fractions of the run, from the `session.step` / `session.transition`
  span durations, the per-step `train.rel_iter_time` gauges (steps with no
  recorded slowdown count as rel 1.0), and the `train.sync` probe spans'
  ``exposed_s`` attrs (pre-overlap traces carry no sync spans and report
  ``exposed_comm_frac = 0.0`` — old streams re-fold unchanged);
* **sync table** — per overlap mode (`on`/`off`), collective-launch counts
  and total/exposed sync seconds from the `train.sync` spans that
  `NTPSession.measure_sync` records (DESIGN.md §2.10);
* **lifecycle-event table** — per-kind event totals (§2.11 taxonomy:
  failure/repair plus straggler/link/sdc onsets and clears) from the
  `orchestrator.events` counter, with SDC rollback executions from the
  transition spans; the goodput rows carry the matching
  ``degradation_loss`` slice (goodput lost to the degradation ledger
  beyond GPU absence);
* **transition table** — per-kind counts and byte totals from the
  transition spans' attached `TransferStats`;
* **serve table** — TTFT/TPOT percentile summaries + admission/preemption
  totals, when serve events are present;
* **kernel table** — compiled-vs-interpret dispatch totals per kernel.

CLI::

  python -m repro.launch.telemetry_report run.jsonl
  python -m repro.launch.telemetry_report run.jsonl --perfetto trace.json
  python -m repro.launch.telemetry_report run.jsonl --json report.json
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.telemetry import load_jsonl, summarize_hist, write_chrome_trace

# the goodput-decomposition row schema (guarded, with EVENT_KEYS, by the
# golden in tests/golden/telemetry_schema.json)
GOODPUT_KEYS = (
    "steps", "goodput", "goodput_unboosted", "boost_recovered",
    "degradation_loss", "compute_frac", "bubble_frac", "reshard_frac",
    "exposed_comm_frac",
)

# the per-overlap-mode sync row schema (train.sync spans, DESIGN.md §2.10)
SYNC_SPAN_KEYS = ("count", "collectives", "sync_s", "exposed_s")


def _series(events: Iterable[Dict], kind: str, name: str,
            label: Optional[Dict] = None) -> List[Dict]:
    label = label or {}
    return [
        e for e in events
        if e["kind"] == kind and e["name"] == name
        and all(e.get("labels", {}).get(k) == v for k, v in label.items())
    ]


def goodput_table(events: List[Dict]) -> Dict[str, Dict]:
    """One decomposition row per power policy seen in the stream.

    ``goodput`` is the mean of the recorded per-step `train.goodput` gauges
    — identical, by construction, to the orchestrator's own
    ``TraceRunner.goodput()`` (same per-step sums, same mean).
    ``boost_recovered`` is the goodput the power boost bought back relative
    to the unboosted local-batch rule on the same plans. The time fractions
    split the run's wall clock: ``reshard_frac`` from transition span
    durations, ``bubble_frac`` from the predicted per-step slowdown on the
    remaining step time, ``exposed_comm_frac`` from the `train.sync` probe
    spans' ``exposed_s`` attrs (gradient sync left on the critical path —
    zero when the trace predates the overlap engine), ``compute_frac`` as
    the rest."""
    step_spans = _series(events, "span", "session.step")
    # only transitions that EXECUTED moved any state; refused/no-op applies
    # are planner overhead, not reshard traffic
    trans_spans = [e for e in _series(events, "span", "session.transition")
                   if e["attrs"].get("changed") is True]
    sync_spans = _series(events, "span", "train.sync")
    step_s = float(sum(e["dur"] for e in step_spans))
    reshard_s = float(sum(e["dur"] for e in trans_spans))
    exposed_s = float(sum(e["attrs"].get("exposed_s", 0.0)
                          for e in sync_spans))
    denom = step_s + reshard_s
    reshard_frac = reshard_s / denom if denom > 0 else 0.0
    exposed_frac = min(exposed_s / denom, 1.0 - reshard_frac) \
        if denom > 0 else 0.0

    out: Dict[str, Dict] = {}
    policies = sorted({
        e["labels"]["policy"]
        for e in _series(events, "gauge", "train.goodput")
    })
    for pol in policies:
        g = [e["value"] for e in
             _series(events, "gauge", "train.goodput", {"policy": pol})]
        gu = [e["value"] for e in
              _series(events, "gauge", "train.goodput_unboosted",
                      {"policy": pol})]
        rel = [e["value"] for e in
               _series(events, "gauge", "train.rel_iter_time",
                       {"source": "analytic"})]
        # degraded steps record their predicted slowdown; healthy steps
        # record nothing — pad to the step count at rel 1.0. A boosted
        # policy can predict rel < 1 (overdrive); that is boost territory,
        # not bubble, so the bubble floor is 0 per step.
        n = max(len(g), len(step_spans))
        rel = rel[:n] + [1.0] * max(0, n - len(rel))
        bubble = (float(np.mean([1.0 - 1.0 / max(r, 1.0) for r in rel]))
                  if rel else 0.0)
        bubble_frac = (1.0 - reshard_frac) * bubble
        # exposed comm comes out of the compute share (it is step time the
        # backward window failed to hide); never let it push compute < 0
        ef = min(exposed_frac, 1.0 - reshard_frac - bubble_frac)
        goodput = float(np.mean(g)) if g else 1.0
        goodput_u = float(np.mean(gu)) if gu else goodput
        # degradation-attributed loss (§2.11): per-step goodput the ledger
        # cost beyond binary TP reductions — straggle/link repricing and
        # quarantined replicas. Old streams carry no such gauges and fold
        # to 0.0 (no degradation observed).
        dl = [e["value"] for e in
              _series(events, "gauge", "train.goodput_degradation_loss",
                      {"policy": pol})]
        out[pol] = {
            "steps": len(g),
            "goodput": goodput,
            "goodput_unboosted": goodput_u,
            "boost_recovered": goodput - goodput_u,
            "degradation_loss": float(np.mean(dl)) if dl else 0.0,
            "compute_frac": 1.0 - reshard_frac - bubble_frac - ef,
            "bubble_frac": bubble_frac,
            "reshard_frac": reshard_frac,
            "exposed_comm_frac": ef,
        }
    return out


def events_table(events: List[Dict]) -> Dict[str, int]:
    """Per-kind lifecycle event totals from the orchestrator's
    ``orchestrator.events`` counter — the §2.11 taxonomy (failure/repair
    plus straggler/link/sdc onsets and clears), with SDC rollback
    executions folded in from the ``session.transition`` spans' rollback
    attr. Binary-era streams fold to failure/repair rows only."""
    out: Dict[str, int] = {}
    for e in _series(events, "counter", "orchestrator.events"):
        kind = e["labels"].get("kind", "?")
        out[kind] = out.get(kind, 0) + int(e["value"])
    rollbacks = sum(
        1 for e in _series(events, "span", "session.transition")
        if e["attrs"].get("rollback") is True
    )
    if rollbacks:
        out["sdc_rollback"] = rollbacks
    return out


def transition_table(events: List[Dict]) -> Dict[str, Dict]:
    """Per-kind transition counts + byte/message totals from the span-borne
    `TransferStats` (train `session.transition` and serve
    `serve.transition` both land here)."""
    out: Dict[str, Dict] = {}
    for name in ("session.transition", "serve.transition"):
        for e in _series(events, "span", name):
            kind = e["labels"].get("kind", "?")
            # a train span without a "changed" attr never finished apply():
            # the session refused the event (DeadReplicaError) mid-span
            if name == "session.transition":
                changed = e["attrs"].get("changed")
                outcome = ("executed" if changed is True
                           else "noop" if changed is False else "rejected")
            else:
                outcome = "executed"
            row = out.setdefault(f"{name}:{kind}:{outcome}", {
                "count": 0, "bytes_moved": 0, "messages": 0, "seconds": 0.0,
            })
            row["count"] += 1
            row["bytes_moved"] += int(e["attrs"].get("bytes_moved", 0))
            row["messages"] += int(e["attrs"].get("messages", 0))
            row["seconds"] += float(e["dur"])
    return out


def sync_table(events: List[Dict]) -> Dict[str, Dict]:
    """Per-overlap-mode gradient-sync rollup from the `train.sync` probe
    spans (`NTPSession.measure_sync`, DESIGN.md §2.10): probe counts, the
    static collective-launch count of the compiled sync, and total /
    exposed sync seconds. Row keys are guarded by ``SYNC_SPAN_KEYS`` in the
    telemetry schema golden."""
    out: Dict[str, Dict] = {}
    for e in _series(events, "span", "train.sync"):
        mode = e["labels"].get("overlap", "?")
        row = out.setdefault(mode, {
            "count": 0, "collectives": 0, "sync_s": 0.0, "exposed_s": 0.0,
        })
        row["count"] += 1
        # static per-step launch count — identical across probes of one mode
        row["collectives"] = int(e["attrs"].get("collectives",
                                                row["collectives"]))
        row["sync_s"] += float(e["attrs"].get("sync_s", e["dur"]))
        row["exposed_s"] += float(e["attrs"].get("exposed_s", 0.0))
    return out


def serve_table(events: List[Dict]) -> Optional[Dict]:
    ttft = [e["value"] for e in _series(events, "hist", "serve.ttft")]
    tpot = [e["value"] for e in _series(events, "hist", "serve.tpot")]
    admission = _series(events, "counter", "serve.admission")
    preempted = _series(events, "counter", "serve.preempted")
    if not (ttft or tpot or admission or preempted):
        return None
    return {
        "ttft": summarize_hist(ttft),
        "tpot": summarize_hist(tpot),
        "admitted": sum(e["value"] for e in admission
                        if e["labels"].get("outcome") == "admitted"),
        "rejected": sum(e["value"] for e in admission
                        if e["labels"].get("outcome") == "rejected"),
        "preempted": sum(e["value"] for e in preempted),
    }


def kernel_table(events: List[Dict]) -> Dict[str, Dict[str, int]]:
    out: Dict[str, Dict[str, int]] = {}
    for e in _series(events, "counter", "kernels.dispatch"):
        k = e["labels"].get("kernel", "?")
        mode = e["labels"].get("mode", "?")
        out.setdefault(k, {"compiled": 0, "interpret": 0})
        out[k][mode] = out[k].get(mode, 0) + int(e["value"])
    return out


def report(events: List[Dict]) -> Dict:
    """The full folded report (every table; empty sections omitted)."""
    doc: Dict = {"events": len(events)}
    gp = goodput_table(events)
    if gp:
        doc["goodput"] = gp
    le = events_table(events)
    if le:
        doc["lifecycle_events"] = le
    tr = transition_table(events)
    if tr:
        doc["transitions"] = tr
    sy = sync_table(events)
    if sy:
        doc["sync"] = sy
    sv = serve_table(events)
    if sv is not None:
        doc["serve"] = sv
    kt = kernel_table(events)
    if kt:
        doc["kernels"] = kt
    return doc


def _print_report(doc: Dict) -> None:
    print(f"telemetry events: {doc['events']}")
    if "goodput" in doc:
        hdr = f"{'policy':10s}" + "".join(f"{k:>18s}" for k in GOODPUT_KEYS)
        print("\ngoodput decomposition:\n" + hdr)
        for pol, row in doc["goodput"].items():
            cells = "".join(
                f"{row[k]:18d}" if isinstance(row[k], int)
                else f"{row[k]:18.4f}" for k in GOODPUT_KEYS
            )
            print(f"{pol:10s}{cells}")
    if "lifecycle_events" in doc:
        print("\nlifecycle events:")
        for kind, n in sorted(doc["lifecycle_events"].items()):
            print(f"  {kind:18s} {n:6d}")
    if "transitions" in doc:
        print("\ntransitions:")
        for k, row in sorted(doc["transitions"].items()):
            print(f"  {k:28s} count {row['count']:4d}  "
                  f"bytes {row['bytes_moved']:>12,d}  "
                  f"msgs {row['messages']:5d}  {row['seconds']*1e3:8.1f} ms")
    if "sync" in doc:
        print("\ngradient sync (train.sync probes):")
        for mode, row in sorted(doc["sync"].items()):
            print(f"  overlap {mode:4s} probes {row['count']:3d}  "
                  f"collectives {row['collectives']:4d}  "
                  f"sync {row['sync_s']*1e3:8.1f} ms  "
                  f"exposed {row['exposed_s']*1e3:8.1f} ms")
    if "serve" in doc:
        sv = doc["serve"]
        print("\nserve:")
        for h in ("ttft", "tpot"):
            s = sv[h]
            if s:
                print(f"  {h}: n {s['count']:5d}  mean {s['mean']:.2f}  "
                      f"p50 {s['p50']:.2f}  p95 {s['p95']:.2f}  "
                      f"p99 {s['p99']:.2f}")
        print(f"  admitted {sv['admitted']:.0f}  rejected {sv['rejected']:.0f}"
              f"  preempted {sv['preempted']:.0f}")
    if "kernels" in doc:
        print("\nkernel dispatch:")
        for k, row in sorted(doc["kernels"].items()):
            print(f"  {k:20s} compiled {row.get('compiled', 0):6d}  "
                  f"interpret {row.get('interpret', 0):6d}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", help="recorder JSONL stream (--telemetry output)")
    ap.add_argument("--perfetto", default=None, metavar="TRACE.json",
                    help="also write a Chrome-trace/Perfetto JSON trace")
    ap.add_argument("--json", default=None, metavar="REPORT.json",
                    help="also write the folded report as JSON")
    args = ap.parse_args()
    events = load_jsonl(args.jsonl)
    doc = report(events)
    _print_report(doc)
    if args.perfetto:
        trace = write_chrome_trace(args.perfetto, events)
        print(f"\nperfetto trace ({len(trace['traceEvents'])} rows) -> "
              f"{args.perfetto}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"report json -> {args.json}")


if __name__ == "__main__":
    main()
