"""Training launcher.

Examples (CPU container — reduced configs execute, full configs dry-run):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \\
      --steps 20 --seq-len 128 --batch 8
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --dry-run

NTP mode — train the nonuniform-TP prototype through the runtime session and
inject a mid-run GPU failure (consumed as a FailureEvent, replanned in
place):
  PYTHONPATH=src python -m repro.launch.train --ntp --devices 8 \\
      --steps 40 --fail-at 20 [--fail-replica 1]

Trace mode — replay a Llama3-calibrated failure/recovery trace through the
lifecycle orchestrator (fail -> boost -> repair; DESIGN.md §2.4), with the
power policy deciding NTP vs NTP-PW per transition:
  PYTHONPATH=src python -m repro.launch.train --ntp --devices 8 --steps 200 \\
      --trace 2e5 --trace-seed 0 --power-policy ntp_pw
"""
import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch config id (required unless --ntp)")
    ap.add_argument("--ntp", action="store_true",
                    help="train the NTP prototype via the runtime session")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages for the NTP prototype (stage-"
                         "partitioned layers, per-(replica, stage) health; "
                         "a failure degrades only its stage — DESIGN.md §2.6)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="1F1B microbatch chunks per step (NTP mode; must "
                         "divide --batch)")
    ap.add_argument("--overlap", choices=["on", "off"], default="off",
                    help="overlapped, bucketed gradient sync (core/overlap, "
                         "DESIGN.md §2.10): hide the DP all-reduce / NTP "
                         "reshard chain behind backward compute; 'off' is "
                         "bit-identical to the pre-overlap step")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a GPU failure before this step (NTP mode)")
    ap.add_argument("--fail-replica", type=int, default=1,
                    help="DP replica whose scale-up domain loses a GPU")
    ap.add_argument("--fail-stage", type=int, default=None,
                    help="pipeline stage the failure lands on (--pp > 1; "
                         "default: the replica's worst stage)")
    ap.add_argument("--fail-gpus", type=int, default=1,
                    help="GPUs lost in the failure event")
    ap.add_argument("--trace", type=float, default=None, metavar="RATE_MULT",
                    help="replay a Llama3-calibrated fail/repair trace at "
                         "this failure-rate multiplier (NTP mode; try 1e5+ — "
                         "the tiny test cluster needs a huge multiplier to "
                         "see events in a short run)")
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="trace sampler seed (default 0)")
    ap.add_argument("--trace-mix", default=None, metavar="KIND=RATE[,...]",
                    help="mix degradation kinds into the sampled trace: "
                         "comma list of straggler=R, link=R, sdc=R onset "
                         "rates as multiples of the binary failure rate "
                         "(e.g. straggler=0.5,sdc=0.1); needs --trace")
    ap.add_argument("--quarantine", choices=["on", "off"], default="on",
                    help="SDC policy (default on): quarantine the suspect "
                         "replica and roll back to the canonical snapshot; "
                         "off = ledger the suspicion and keep training")
    ap.add_argument("--steps-per-hour", type=float, default=1.0,
                    help="training steps per simulated trace hour")
    ap.add_argument("--power-policy", choices=["ntp", "ntp_pw"], default=None,
                    help="per-transition NTP vs NTP-PW decision hook "
                         "(default: ntp when --trace is given)")
    ap.add_argument("--allocator", choices=["greedy", "off"], default="off",
                    help="global repack planner for --pp > 1 (repro.cluster, "
                         "DESIGN.md §2.7): spares assignable to ANY stage, "
                         "cost-priced cross-stage swaps; 'off' keeps PR-5 "
                         "stage-local packing")
    ap.add_argument("--spares", type=int, default=0,
                    help="spare scale-up domains absorbing the worst "
                         "failures (NTP mode; --pp > 1 needs --allocator "
                         "greedy)")
    ap.add_argument("--allocator-horizon", type=int, default=200,
                    help="amortization horizon (steps) a priced move must "
                         "pay for itself within (--allocator greedy)")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant of the arch family")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile at the production mesh instead of running")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="fake CPU devices for a (2, n/2) test mesh")
    ap.add_argument("--pallas-compile", action="store_true",
                    help="run Pallas kernels compiled (TPU) instead of "
                         "interpret mode; sets REPRO_PALLAS_COMPILE=1")
    ap.add_argument("--telemetry", default=None, metavar="OUT.jsonl",
                    help="record the run's telemetry stream (spans, "
                         "counters, gauges) as JSONL; fold it offline with "
                         "python -m repro.launch.telemetry_report OUT.jsonl")
    args = ap.parse_args()
    if args.pallas_compile:
        os.environ["REPRO_PALLAS_COMPILE"] = "1"
    if args.telemetry:
        from repro import telemetry

        telemetry.configure(jsonl=args.telemetry)
    if args.arch is None and not args.ntp:
        ap.error("--arch is required unless --ntp is given")
    if args.ntp and args.dry_run:
        ap.error("--ntp has no --dry-run path; use python -m "
                 "repro.launch.dryrun_ntp for compile-only NTP accounting")
    if (args.trace is not None or args.power_policy) and not args.ntp:
        ap.error("--trace/--power-policy need --ntp (lifecycle orchestration "
                 "is NTP-backend-only)")
    if args.trace is not None and args.fail_at is not None:
        ap.error("--trace and --fail-at are mutually exclusive")
    args.trace_mix_kwargs = {}
    if args.trace_mix is not None:
        if args.trace is None:
            ap.error("--trace-mix needs --trace (the mix rates scale the "
                     "same sampled trace)")
        from repro.core.failure_model import parse_trace_mix

        try:
            args.trace_mix_kwargs = parse_trace_mix(args.trace_mix)
        except ValueError as e:
            ap.error(f"--trace-mix: {e}")
    if args.quarantine == "off" and not args.ntp:
        ap.error("--quarantine is the NTP SDC rollback policy; it needs "
                 "--ntp")
    if args.pp != 1 or args.microbatches != 1:
        if not args.ntp:
            ap.error("--pp/--microbatches need --ntp (stage-partitioned "
                     "training is NTP-backend-only)")
        from repro.configs.shapes import SUPPORTED_PP

        if args.pp not in SUPPORTED_PP:
            ap.error(f"--pp {args.pp} not in supported ladder {SUPPORTED_PP}")
    if args.fail_stage is not None and args.pp == 1:
        ap.error("--fail-stage needs --pp > 1")
    if args.overlap == "on" and not args.ntp:
        ap.error("--overlap needs --ntp (the overlapped bucketed sync is "
                 "NTP-backend-only)")
    if (args.allocator != "off" or args.spares) and not args.ntp:
        ap.error("--allocator/--spares need --ntp (lifecycle replanning is "
                 "NTP-backend-only)")
    if args.allocator != "off" and args.pp == 1:
        ap.error("--allocator is the pp>1 global repack planner; pp=1 "
                 "sessions already pack globally (--spares works directly)")
    if args.spares and args.pp > 1 and args.allocator == "off":
        ap.error("spares with --pp > 1 need the global allocator: pass "
                 "--allocator greedy")

    if args.dry_run:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    elif args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    if args.ntp:
        _run_ntp(args)
        return

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, get_shape, reduced
    from repro.configs.shapes import ShapeSpec
    from repro.checkpoint import save_checkpoint
    from repro.data.pipeline import DataConfig, SyntheticLMPipeline
    from repro.launch.mesh import dp_axes, make_production_mesh, make_test_mesh
    from repro.optim import AdamWConfig, adamw_init
    from repro.train.steps import make_setup

    cfg = get_arch(args.arch)

    if args.dry_run:
        from repro.launch.dryrun import run_one

        rec = run_one(args.arch, args.shape, args.multi_pod)
        print(rec)
        return

    cfg = reduced(cfg) if args.reduced else cfg
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")
    mesh = None
    if args.devices:
        mesh = make_test_mesh(2, args.devices // 2)
    opt_cfg = AdamWConfig(lr=args.lr)
    su = make_setup(cfg, shape, mesh, param_dtype=jnp.float32, opt_cfg=opt_cfg,
                    dp_axes=("data",) if mesh else ("data",))
    step = su.jit_step()

    key = jax.random.PRNGKey(args.seed)
    if mesh is not None:
        params = jax.jit(su.model.init, out_shardings=su.param_sharding)(key)
        opt = jax.jit(lambda p: adamw_init(p, opt_cfg),
                      out_shardings=su.opt_sharding)(params)
    else:
        params = su.model.init(key)
        opt = adamw_init(params, opt_cfg)
    n_par = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} params={n_par/1e6:.1f}M devices={len(jax.devices())}")

    pipe = SyntheticLMPipeline(
        DataConfig(cfg.vocab_size, args.seq_len, args.batch, seed=args.seed), mesh
    )
    t0 = time.time()
    for i in range(args.steps):
        batch = pipe.batch(i)
        if su.cfg.encoder is not None:
            batch["enc_input"] = jnp.zeros(
                (args.batch, cfg.encoder.enc_seq, cfg.d_model), jnp.float32
            )
        params, opt, metrics = step(params, opt, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(
                f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"({(time.time()-t0):.1f}s)", flush=True,
            )
        if args.ckpt and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, {"params": params, "opt": opt}, step=i + 1)
            print(f"  saved checkpoint -> {args.ckpt}")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "opt": opt}, step=args.steps)
        print(f"final checkpoint -> {args.ckpt}")


def _run_ntp(args) -> None:
    """NTP prototype through the runtime session, with an optional injected
    mid-training failure (--fail-at) or a full trace-driven fail/repair
    lifecycle (--trace) — the paper's scenario as launcher flags."""
    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import DataConfig, SyntheticLMPipeline
    from repro.launch.mesh import make_test_mesh
    from repro.optim import AdamWConfig, adamw
    from repro.runtime import FailureEvent, NTPModelConfig, NTPSession, power_policy

    n_dev = args.devices or 8
    if len(jax.devices()) < n_dev:
        raise SystemExit(
            f"need {n_dev} devices: pass --devices {n_dev} (or set XLA_FLAGS)"
        )
    if args.fail_at is not None and not 0 <= args.fail_replica < 2:
        raise SystemExit(
            f"--fail-replica {args.fail_replica} out of range for 2 DP replicas"
        )
    mesh = make_test_mesh(2, n_dev // 2)
    n1 = n_dev // 2
    cfg = NTPModelConfig(
        d_model=256, n_kv_groups=2 * n1, q_per_kv=2, head_dim=32,
        d_ff=max(512, 128 * n1), unit_rows=128,
        n_layers=max(2, 2 * args.pp), vocab=2048,
    )
    policy_name = args.power_policy or ("ntp" if args.trace is not None else None)
    from repro.cluster import make_allocator

    allocator = make_allocator(args.allocator,
                               horizon_steps=args.allocator_horizon)
    session = NTPSession.create(
        cfg, mesh, local_batch=args.batch,
        optimizer=adamw(AdamWConfig(lr=args.lr)),
        key=jax.random.PRNGKey(args.seed),
        power_policy=power_policy(policy_name) if policy_name else None,
        pp=args.pp, microbatches=args.microbatches,
        spares=args.spares, allocator=allocator,
        overlap=args.overlap, quarantine=args.quarantine == "on",
    )
    n_par = sum(p.size for p in jax.tree.leaves(session.canonical_params()))
    print(f"ntp prototype: {n_par/1e6:.1f}M params  mesh data=2 model={n1}  "
          + (f"pp={args.pp} stages {session.stage_boundaries}  "
             if args.pp > 1 else "")
          + f"plan {session.plan}"
          + (f"  overlap {args.overlap}" if args.overlap == "on" else "")
          + (f"  policy {policy_name}" if policy_name else "")
          + (f"  allocator {args.allocator} spares {args.spares}"
             if args.allocator != "off" or args.spares else ""))

    pipe = SyntheticLMPipeline(
        DataConfig(cfg.vocab, args.seq_len, 2 * args.batch, seed=args.seed)
    )

    if args.trace is not None:
        _run_ntp_trace(args, session, pipe)
        return

    t0 = time.time()
    for i in range(args.steps):
        if args.fail_at is not None and i == args.fail_at:
            plan = session.apply(
                FailureEvent(step=i, replica=args.fail_replica,
                             n_gpus=args.fail_gpus, stage=args.fail_stage)
            )
            stage_s = (f"stage={args.fail_stage}, "
                       if args.fail_stage is not None else "")
            print(f"*** step {i}: FailureEvent(replica={args.fail_replica}, "
                  f"{stage_s}n_gpus={args.fail_gpus}) "
                  f"-> plan {plan} mode {session.mode.value}")
        metrics = session.step(jnp.asarray(pipe._batch_np(i)))
        if i % args.log_every == 0 or i == args.steps - 1:
            srel = metrics.get("stage_rel_iter_time")
            print(
                f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                + (f"stage_rel {tuple(round(r, 3) for r in srel)}  "
                   if srel is not None else "")
                + f"({(time.time()-t0):.1f}s)", flush=True,
            )
        if args.ckpt and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            session.save(args.ckpt)
            print(f"  saved canonical checkpoint -> {args.ckpt}")
    if args.ckpt:
        session.save(args.ckpt)
        print(f"final canonical checkpoint -> {args.ckpt}")


def _run_ntp_trace(args, session, pipe) -> None:
    """Replay a sampled failure/recovery trace against the live session via
    the lifecycle orchestrator (DESIGN.md §2.4)."""
    import jax.numpy as jnp

    from repro.core.failure_model import FailureTraceConfig
    from repro.runtime import TraceRunner, event_kind, schedule_from_trace

    d, n1 = session.plan.d, session.plan.n1
    pp = session.pp
    trace_cfg = FailureTraceConfig(
        n_gpus=d * pp * n1, domain_size=n1,
        days=args.steps / args.steps_per_hour / 24.0,
        rate_multiplier=args.trace, seed=args.trace_seed,
        **args.trace_mix_kwargs,
    )
    schedule = schedule_from_trace(
        trace_cfg, steps=args.steps, steps_per_hour=args.steps_per_hour,
        pp=pp,
    )
    from collections import Counter

    kinds = Counter(event_kind(s.event) for s in schedule)
    print(f"trace: {len(schedule)} events over {args.steps} steps "
          f"({', '.join(f'{k}={n}' for k, n in sorted(kinds.items()))})")

    t0 = time.time()

    def on_event(ev, plan):
        kind = event_kind(ev)
        site = (f"stage {ev.stage} domain {ev.domain}"
                if ev.stage is not None else f"domain {ev.domain}")
        print(f"*** step {ev.step}: {kind} {site} -> plan {plan}  "
              f"local_batches {session.local_batches}")
        gp = session.last_global_plan
        if gp is not None and (gp.spare_sites or gp.swaps):
            print(f"    allocator: spares at {gp.spare_sites} swaps "
                  f"{gp.swaps} predicted {gp.predicted_bytes}B "
                  f"(goodput {gp.goodput:.3f} vs stage-local "
                  f"{gp.baseline_goodput:.3f})")

    runner = TraceRunner(session, schedule, on_event=on_event)
    log_every = max(args.log_every, 1)
    for start in range(0, args.steps, log_every):
        n = min(log_every, args.steps - start)
        hist = runner.run(lambda i: jnp.asarray(pipe._batch_np(i)), n)
        h = hist[-1]
        extra = (f"  boost {h['power_boost']:.2f}  rel_iter "
                 f"{h['rel_iter_time']:.3f}" if "power_boost" in h else "")
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  tp {h['replica_tp']}{extra}  "
              f"({time.time() - t0:.1f}s)", flush=True)
    s = runner.summary()
    by_kind = ", ".join(f"{k}={v}" for k, v in sorted(
        s["events_by_kind"].items()))
    roll = f", rollbacks {s['rollbacks']}" if s.get("rollbacks") else ""
    print(f"lifecycle: {by_kind or 'no events'}{roll}, "
          f"goodput {s['goodput']:.3f}, final plan {s['final_plan']}")
    if args.ckpt:
        session.save(args.ckpt)
        print(f"final canonical checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()

