"""Training launcher.

Examples (CPU container — reduced configs execute, full configs dry-run):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \\
      --steps 20 --seq-len 128 --batch 8
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --dry-run

NTP mode — train the nonuniform-TP prototype through the runtime session and
inject a mid-run GPU failure (consumed as a FailureEvent, replanned in
place):
  PYTHONPATH=src python -m repro.launch.train --ntp --devices 8 \\
      --steps 40 --fail-at 20 [--fail-replica 1]
"""
import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch config id (required unless --ntp)")
    ap.add_argument("--ntp", action="store_true",
                    help="train the NTP prototype via the runtime session")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a GPU failure before this step (NTP mode)")
    ap.add_argument("--fail-replica", type=int, default=1,
                    help="DP replica whose scale-up domain loses a GPU")
    ap.add_argument("--fail-gpus", type=int, default=1,
                    help="GPUs lost in the failure event")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant of the arch family")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile at the production mesh instead of running")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="fake CPU devices for a (2, n/2) test mesh")
    args = ap.parse_args()
    if args.arch is None and not args.ntp:
        ap.error("--arch is required unless --ntp is given")
    if args.ntp and args.dry_run:
        ap.error("--ntp has no --dry-run path; use python -m "
                 "repro.launch.dryrun_ntp for compile-only NTP accounting")

    if args.dry_run:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    elif args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    if args.ntp:
        _run_ntp(args)
        return

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, get_shape, reduced
    from repro.configs.shapes import ShapeSpec
    from repro.checkpoint import save_checkpoint
    from repro.data.pipeline import DataConfig, SyntheticLMPipeline
    from repro.launch.mesh import dp_axes, make_production_mesh, make_test_mesh
    from repro.optim import AdamWConfig, adamw_init
    from repro.train.steps import make_setup

    cfg = get_arch(args.arch)

    if args.dry_run:
        from repro.launch.dryrun import run_one

        rec = run_one(args.arch, args.shape, args.multi_pod)
        print(rec)
        return

    cfg = reduced(cfg) if args.reduced else cfg
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")
    mesh = None
    if args.devices:
        mesh = make_test_mesh(2, args.devices // 2)
    opt_cfg = AdamWConfig(lr=args.lr)
    su = make_setup(cfg, shape, mesh, param_dtype=jnp.float32, opt_cfg=opt_cfg,
                    dp_axes=("data",) if mesh else ("data",))
    step = su.jit_step()

    key = jax.random.PRNGKey(args.seed)
    if mesh is not None:
        params = jax.jit(su.model.init, out_shardings=su.param_sharding)(key)
        opt = jax.jit(lambda p: adamw_init(p, opt_cfg),
                      out_shardings=su.opt_sharding)(params)
    else:
        params = su.model.init(key)
        opt = adamw_init(params, opt_cfg)
    n_par = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} params={n_par/1e6:.1f}M devices={len(jax.devices())}")

    pipe = SyntheticLMPipeline(
        DataConfig(cfg.vocab_size, args.seq_len, args.batch, seed=args.seed), mesh
    )
    t0 = time.time()
    for i in range(args.steps):
        batch = pipe.batch(i)
        if su.cfg.encoder is not None:
            batch["enc_input"] = jnp.zeros(
                (args.batch, cfg.encoder.enc_seq, cfg.d_model), jnp.float32
            )
        params, opt, metrics = step(params, opt, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(
                f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"({(time.time()-t0):.1f}s)", flush=True,
            )
        if args.ckpt and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, {"params": params, "opt": opt}, step=i + 1)
            print(f"  saved checkpoint -> {args.ckpt}")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "opt": opt}, step=args.steps)
        print(f"final checkpoint -> {args.ckpt}")


def _run_ntp(args) -> None:
    """NTP prototype through the runtime session, with an optional injected
    mid-training failure — the paper's scenario as a launcher flag."""
    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import DataConfig, SyntheticLMPipeline
    from repro.launch.mesh import make_test_mesh
    from repro.optim import AdamWConfig, adamw
    from repro.runtime import FailureEvent, NTPModelConfig, NTPSession

    n_dev = args.devices or 8
    if len(jax.devices()) < n_dev:
        raise SystemExit(
            f"need {n_dev} devices: pass --devices {n_dev} (or set XLA_FLAGS)"
        )
    if args.fail_at is not None and not 0 <= args.fail_replica < 2:
        raise SystemExit(
            f"--fail-replica {args.fail_replica} out of range for 2 DP replicas"
        )
    mesh = make_test_mesh(2, n_dev // 2)
    n1 = n_dev // 2
    cfg = NTPModelConfig(
        d_model=256, n_kv_groups=2 * n1, q_per_kv=2, head_dim=32,
        d_ff=max(512, 128 * n1), unit_rows=128, n_layers=2, vocab=2048,
    )
    session = NTPSession.create(
        cfg, mesh, local_batch=args.batch,
        optimizer=adamw(AdamWConfig(lr=args.lr)),
        key=jax.random.PRNGKey(args.seed),
    )
    n_par = sum(p.size for p in jax.tree.leaves(session.canonical_params()))
    print(f"ntp prototype: {n_par/1e6:.1f}M params  mesh data=2 model={n1}  "
          f"plan {session.plan}")

    pipe = SyntheticLMPipeline(
        DataConfig(cfg.vocab, args.seq_len, 2 * args.batch, seed=args.seed)
    )
    t0 = time.time()
    for i in range(args.steps):
        if args.fail_at is not None and i == args.fail_at:
            plan = session.apply(
                FailureEvent(step=i, replica=args.fail_replica,
                             n_gpus=args.fail_gpus)
            )
            print(f"*** step {i}: FailureEvent(replica={args.fail_replica}, "
                  f"n_gpus={args.fail_gpus}) -> plan {plan} "
                  f"mode {session.mode.value}")
        metrics = session.step(jnp.asarray(pipe._batch_np(i)))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(
                f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"({(time.time()-t0):.1f}s)", flush=True,
            )
        if args.ckpt and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            session.save(args.ckpt)
            print(f"  saved canonical checkpoint -> {args.ckpt}")
    if args.ckpt:
        session.save(args.ckpt)
        print(f"final canonical checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()

