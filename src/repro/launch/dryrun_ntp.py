import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first lines, before any jax import (see dryrun.py).
"""NTP-mode dry-run: lower the nonuniform-TP train step at the production
mesh (data=16 × model=16) with degraded replicas, and account the reshard
collectives from the optimized HLO — the paper's Fig. 9 overhead breakdown
derived structurally.

  PYTHONPATH=src python -m repro.launch.dryrun_ntp [--replica-tp 16,...,14]
"""
import argparse
import json

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import ntp_train as nt
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.dryrun import LINK_BW, PEAK_FLOPS
from repro.optim import sgd
from repro.runtime import ClusterHealth, Mode, plan_from_health


def build_cfg(d_model: int = 6144) -> nt.NTPModelConfig:
    # the paper's §5.1 prototype dims (hidden 6144, head dim 128, ffn 4x)
    return nt.NTPModelConfig(
        d_model=d_model,
        n_kv_groups=16, q_per_kv=3 if d_model == 6144 else 6, head_dim=128,
        d_ff=4 * d_model,
        unit_rows=128,
        n_layers=2, vocab=32000,
    )


def run(replica_tp, *, d_model: int = 6144, local_batch: int = 1, seq: int = 2048,
        mesh_shape=(16, 16)):
    import math
    n = math.prod(mesh_shape)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"),
                         devices=jax.devices()[:n])
    cfg = build_cfg(d_model)
    # event/health bridge: replica_tp -> per-domain failures -> packed plan
    health = ClusterHealth(
        domain_size=mesh_shape[1],
        failed=tuple(mesh_shape[1] - t for t in replica_tp),
    )
    fplan = plan_from_health(health)
    mode = Mode.UNIFORM if fplan.healthy else Mode.NTP
    optimizer = sgd(1e-2)  # memory-neutral: dryrun reports temp bytes
    step = nt.make_ntp_train_step(
        cfg, fplan, mesh, mode=mode, local_batch=local_batch,
        optimizer=optimizer,
    )
    canon_shapes = jax.eval_shape(
        lambda k: nt.init_canonical(cfg, k), jax.random.PRNGKey(0)
    )
    # abstract packed params: same structure, packed shapes
    canon = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), canon_shapes
    )
    packed = nt.pack_params(cfg, canon, fplan)
    tokens = jax.ShapeDtypeStruct((mesh_shape[0] * local_batch, seq + 1), jnp.int32)
    packed_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), packed
    )
    opt_abs = jax.eval_shape(optimizer.init, packed_abs)
    lowered = step.lower(packed_abs, opt_abs, tokens)
    compiled = lowered.compile()
    hlo = analyze_hlo(compiled.as_text())
    a2a = hlo["collectives"].get("all-to-all", {"count": 0, "moved_bytes": 0})
    ar = hlo["collectives"].get("all-reduce", {"count": 0, "moved_bytes": 0})
    return {
        "replica_tp": list(fplan.replica_tp),
        "mode": mode.value,
        "flops_per_device": hlo["flops"],
        "compute_s": hlo["flops"] / PEAK_FLOPS,
        "all_to_all": a2a,
        "all_reduce": ar,
        "reshard_s": a2a["moved_bytes"] / LINK_BW,
        "allreduce_s": ar["moved_bytes"] / LINK_BW,
        "collectives": hlo["collectives"],
        "memory_temp_bytes": compiled.memory_analysis().temp_size_in_bytes,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--degraded-tp", type=int, default=14)
    ap.add_argument("--d-model", type=int, default=6144)
    ap.add_argument("--mesh", default="16x16",
                    help="data x model, e.g. 4x8 (compiles much faster)")
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--local-batch", type=int, default=1)
    ap.add_argument("--out", default="results/ntp_dryrun.json")
    args = ap.parse_args()

    md, mm = (int(t) for t in args.mesh.split("x"))
    kw = dict(d_model=args.d_model, mesh_shape=(md, mm), seq=args.seq,
              local_batch=args.local_batch)
    healthy = run([mm] * md, **kw)
    degraded = run([args.degraded_tp] + [mm] * (md - 1), **kw)
    delta_ar = degraded["allreduce_s"] - healthy["allreduce_s"]
    report = {
        "healthy": healthy,
        "degraded": degraded,
        "overhead": {
            "reshard_s": degraded["reshard_s"],
            "allreduce_increase_s": delta_ar,
            "reshard_vs_compute": degraded["reshard_s"] / degraded["compute_s"],
            "note": "paper Fig. 9: reshard overlaps backward; all-reduce "
                    "volume grows ∝ TP reduction; both <1% e2e",
        },
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report["overhead"], indent=1))
    print(f"healthy: ar={healthy['allreduce_s']*1e3:.1f}ms a2a={healthy['reshard_s']*1e3:.2f}ms "
          f"compute={healthy['compute_s']*1e3:.1f}ms")
    print(f"degraded: ar={degraded['allreduce_s']*1e3:.1f}ms a2a={degraded['reshard_s']*1e3:.2f}ms "
          f"compute={degraded['compute_s']*1e3:.1f}ms")


if __name__ == "__main__":
    main()
