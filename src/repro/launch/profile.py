import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first lines, before any jax import (see dryrun.py).
"""Profiler with two modes.

Dry-run (default): compile one (arch × shape) at the production mesh and
print the top byte/FLOP contributors from the optimized HLO — the 'profile'
that drives §Perf hypotheses (no real-TPU timings exist here).

  PYTHONPATH=src python -m repro.launch.profile --arch chameleon-34b --shape train_4k

Measured (``--measure``, ISSUE 7): run the SAME pp=2 plan through the
stage-sequential emulation AND the measured submesh pipeline
(core/pp_submesh) on fake devices, print per-step `time.perf_counter` wall
times plus the measured-vs-analytic bubble factor and the cross-stage
hand-off byte table; ``--trace-dir`` additionally wraps the timed steps in
`jax.profiler.trace` so the per-op timeline can be inspected offline.

  PYTHONPATH=src python -m repro.launch.profile --measure --steps 5 \
      --trace-dir /tmp/ntp-trace
"""
import argparse
import time


def _dryrun(args):
    from repro.configs import get_arch, get_shape
    from repro.launch.hlo_analysis import analyze_hlo, top_contributors
    from repro.launch.mesh import dp_axes, make_production_mesh
    from repro.train.steps import make_setup

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    su = make_setup(get_arch(args.arch), get_shape(args.shape), mesh,
                    dp_axes=dp_axes(mesh))
    with mesh:
        compiled = su.jit_step().lower(*su.abstract_args()).compile()
    txt = compiled.as_text()
    tot = analyze_hlo(txt)
    print(f"total: {tot['flops']/1e12:.2f} TF, {tot['bytes']/1e12:.2f} TB, "
          f"coll {tot['collective_moved_bytes']/1e12:.2f} TB moved")
    print(f"{'op:jax_op_name':60s} {'GB':>10s} {'TF':>8s} {'count':>7s}")
    for row in top_contributors(txt, args.top):
        print(f"{row['key'][:60]:60s} {row['bytes']/1e9:10.1f} "
              f"{row['flops']/1e12:8.2f} {row['count']:7.0f}")


def _measure(args):
    import contextlib

    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_staged_mesh
    from repro.optim import sgd
    from repro.runtime import NTPModelConfig, NTPSession

    pp, d, n1 = args.pp, 2, 4
    lb, seq, mb = args.batch, args.seq_len, args.microbatches
    cfg = NTPModelConfig(d_model=64, n_kv_groups=4, q_per_kv=2, head_dim=16,
                         d_ff=256, unit_rows=64, n_layers=2 * pp, vocab=128)
    kw = dict(local_batch=lb, optimizer=sgd(0.05), key=jax.random.PRNGKey(0),
              pp=pp, microbatches=mb, overlap=args.overlap)
    emu = NTPSession.create(cfg, jax.make_mesh((d, n1), ("data", "model")),
                            **kw)
    sub = NTPSession.create(cfg, make_staged_mesh(pp, d, n1), **kw)
    rng = np.random.default_rng(0)

    def batch():
        return jnp.asarray(rng.integers(0, cfg.vocab, (d * lb, seq + 1)))

    def timed(sess, name):
        for _ in range(2):   # compile + donated-layout recompile warmup
            m = sess.step(batch())
            jax.block_until_ready((sess.params, m["loss"]))
        ts = []
        for i in range(args.steps):
            b = batch()
            t0 = time.perf_counter()
            m = sess.step(b)
            jax.block_until_ready((sess.params, m["loss"]))
            ts.append((time.perf_counter() - t0) * 1e3)
            print(f"  {name} step {i}: {ts[-1]:8.1f} ms  "
                  f"loss {float(m['loss']):.4f}")
        return float(np.median(ts)), m

    trace = (jax.profiler.trace(args.trace_dir) if args.trace_dir
             else contextlib.nullcontext())
    with trace:
        print(f"emulation: pp={pp} on a ({d}, {n1}) mesh, stage-sequential")
        t_emu, _ = timed(emu, "emu")
        print(f"submesh:   pp={pp} on a ({pp}, {d}, {n1}) staged mesh, "
              "ppermute hand-off")
        t_sub, ms = timed(sub, "sub")

    analytic = (mb + pp - 1) / mb
    for sess, name in ((emu, "emu"), (sub, "sub")):
        s = sess.measure_sync(batch())
        print(f"  {name} sync probe (overlap {s['overlap']}): "
              f"{s['sync_s'] * 1e3:.1f} ms, {s['collectives']} collectives")
    print(f"\nper-step median: emulation {t_emu:.1f} ms, "
          f"submesh {t_sub:.1f} ms")
    print(f"bubble factor: measured {t_sub / t_emu:.3f} vs analytic "
          f"(m+pp-1)/m = {analytic:.3f} "
          f"(rel err {abs(t_sub / t_emu - analytic) / analytic:.3f}; "
          "bench_hotpath gates this at its documented tolerance)")
    print(f"pipeline ticks/step: {ms['pipeline_ticks']}")
    print("cross-stage hand-off (bytes, from the ppermute transfer shapes):")
    for k, v in ms["handoff"].items():
        print(f"  {k:20s} {v}")
    if args.trace_dir:
        print(f"profiler trace written to {args.trace_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--measure", action="store_true",
                    help="time the emulated vs submesh pp step instead of "
                         "dry-run HLO analysis")
    ap.add_argument("--trace-dir", default=None,
                    help="with --measure: jax.profiler trace output dir")
    ap.add_argument("--telemetry", default=None, metavar="OUT.jsonl",
                    help="with --measure: record session.step spans etc. as "
                         "JSONL (repro.telemetry)")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8,
                    help="with --measure: per-replica batch")
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--overlap", choices=["on", "off"], default="off",
                    help="with --measure: overlapped bucketed gradient sync "
                         "(core/overlap, DESIGN.md §2.10) in both sessions")
    args = ap.parse_args()
    if args.telemetry and not args.measure:
        ap.error("--telemetry needs --measure (dry-run has no timed steps)")
    if args.measure:
        if args.telemetry:
            from repro import telemetry

            telemetry.configure(jsonl=args.telemetry)
        _measure(args)
        if args.telemetry:
            telemetry.shutdown()
            print(f"telemetry stream written to {args.telemetry}")
        return
    if not (args.arch and args.shape):
        ap.error("--arch and --shape are required unless --measure is set")
    _dryrun(args)


if __name__ == "__main__":
    main()
