import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first lines, before any jax import (see dryrun.py).
"""Dry-run profiler: compile one (arch × shape) at the production mesh and
print the top byte/FLOP contributors from the optimized HLO — the 'profile'
that drives §Perf hypotheses (no real-TPU timings exist here).

  PYTHONPATH=src python -m repro.launch.profile --arch chameleon-34b --shape train_4k
"""
import argparse

from repro.configs import get_arch, get_shape
from repro.launch.hlo_analysis import analyze_hlo, top_contributors
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.train.steps import make_setup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    su = make_setup(get_arch(args.arch), get_shape(args.shape), mesh,
                    dp_axes=dp_axes(mesh))
    with mesh:
        compiled = su.jit_step().lower(*su.abstract_args()).compile()
    txt = compiled.as_text()
    tot = analyze_hlo(txt)
    print(f"total: {tot['flops']/1e12:.2f} TF, {tot['bytes']/1e12:.2f} TB, "
          f"coll {tot['collective_moved_bytes']/1e12:.2f} TB moved")
    print(f"{'op:jax_op_name':60s} {'GB':>10s} {'TF':>8s} {'count':>7s}")
    for row in top_contributors(txt, args.top):
        print(f"{row['key'][:60]:60s} {row['bytes']/1e9:10.1f} "
              f"{row['flops']/1e12:8.2f} {row['count']:7.0f}")


if __name__ == "__main__":
    main()
