"""Production meshes. Functions, not module-level constants — importing this
module must never touch jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 DP × 16 TP — the `model` axis is the
    scale-up domain, the paper's NVL-domain analogue). Multi-pod: 2 pods =
    512 chips with a leading `pod` DP axis (DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for multi-device CPU tests (subprocesses set
    xla_force_host_platform_device_count accordingly)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
