"""Production meshes. Functions, not module-level constants — importing this
module must never touch jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 DP × 16 TP — the `model` axis is the
    scale-up domain, the paper's NVL-domain analogue). Multi-pod: 2 pods =
    512 chips with a leading `pod` DP axis (DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for multi-device CPU tests (subprocesses set
    xla_force_host_platform_device_count accordingly)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_staged_mesh(pp: int, n_data: int = 2, n_model: int = 4,
                     devices=None):
    """Per-stage submesh geometry for MEASURED pipeline parallelism
    (core/pp_submesh, DESIGN.md §2.8): ``pp × n_data × n_model`` devices on
    axes ``("stage", "data", "model")``. Stage ``s``'s layer weights live
    only on the ``stage == s`` slice; activations cross stages via
    `jax.lax.ppermute`. Needs ``pp·n_data·n_model`` visible devices."""
    if pp < 2:
        raise ValueError(
            f"pp={pp}: a staged mesh needs >= 2 stages; use make_test_mesh "
            "(the stage-sequential emulation) for pp=1"
        )
    devs = list(devices) if devices is not None else jax.devices()
    n = pp * n_data * n_model
    if len(devs) < n:
        raise ValueError(
            f"staged mesh (stage={pp}, data={n_data}, model={n_model}) needs "
            f"{n} devices, have {len(devs)}"
        )
    return jax.make_mesh((pp, n_data, n_model), ("stage", "data", "model"),
                         devices=devs[:n])
