"""KV-cache sharding on the TP axis + live head-redistribution reshard —
now a thin attention-specific layer over the unified reshard engine
(`repro.reshard`, DESIGN.md §3.3; the head-granular mechanics this module
pioneered in PR 3 are the engine's `UnitSpec("kv_head", …)` family).

GQA **KV heads are the partition units** over the scale-up domain: a
replica at TP degree ``t`` holds its heads contiguously balanced over its
first ``t`` live ranks (`head_layout` == the planner's ``sync`` degree
layout), and a TP transition moves heads between ranks with the SAME
Algorithm-1 static-table all-to-all as the weight reshard
(`planner.transition_plan` → `engine.reshard_ranks`; ``use_kernel=True``
routes the send-bucket gather through the Pallas `kernels.reshard_pack`).

`ShardedKV` remains the KV-only container (k/v leaves, head axis at -2);
recurrent caches (SSM h/conv, RG-LRU h/conv) are served by the generic
`repro.reshard.ShardedState` with their channel-block UnitSpecs — see
`serve/engine.py`, which uses `ShardedState` for every architecture.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import shard_mapping as sm
from repro.reshard import engine as rse
from repro.reshard import planner
from repro.reshard.state import ShardedState, degree_layout, widened_slots
from repro.reshard.units import UnitSpec

KV_LEAF_NAMES = ("k", "v")


def validate_kv_cache(cache) -> None:
    """Every leaf must be a ``k``/``v`` KV-cache tensor. Non-KV state
    (ssm/rglru ``h``/``conv``) has a channel-block NTP unit — serve it
    through the generic `repro.reshard.ShardedState` instead."""
    for path, _ in jax.tree_util.tree_flatten_with_path(cache)[0]:
        name = getattr(path[-1], "key", None)
        if name not in KV_LEAF_NAMES:
            raise ValueError(
                f"ShardedKV shards k/v leaves only; got {name!r} at {path} "
                "(ssm/rglru state caches have channel-block units — use "
                "repro.reshard.ShardedState with units.cache_unit_resolver)"
            )


# ---------------------------------------------------------------------------
# layouts (planner-backed)

def head_layout(kvh: int, tp: int, n1: int) -> sm.Layout:
    """Head → rank placement of a replica serving at TP degree ``tp``:
    the planner's degree layout (contiguously balanced over the first
    ``tp`` live ranks, expressed on the full ``n1``-wide domain axis).
    ``kvh < tp`` simply leaves some live ranks without a KV head (Megatron
    GQA replicates their weight-side K/V; the cache itself is never
    duplicated)."""
    return degree_layout(kvh, tp, n1)


def slots_at(layout: sm.Layout, buf: int) -> np.ndarray:
    """(n, buf) head id per buffer slot, -1 pad (`reshard.widened_slots`)."""
    return widened_slots(layout, buf)


@lru_cache(maxsize=None)
def head_reshard_tables(kvh: int, tp_from: int, tp_to: int,
                        n1: int) -> sm.ReshardTables:
    """Static all-to-all tables moving every KV head from its ``tp_from``
    placement to its ``tp_to`` placement (buf = kvh: the TP=1 worst case,
    so no reallocation on any transition)."""
    return planner.tables(
        planner.sync_key(kvh, n1, tp_from),
        planner.sync_key(kvh, n1, tp_to),
        kvh,
    )


# ---------------------------------------------------------------------------
# leaf ops  (dense leaf: (..., T, kvh, hd) — head axis at -2, as produced by
# models.attention.init_kv_cache under any stack of leading axes)

_KV_AXIS = -2


def _kv_spec(kvh: int) -> UnitSpec:
    return UnitSpec("kv_head", kvh, axis=_KV_AXIS)


def shard_leaf(dense, layout: sm.Layout, buf: int):
    """(..., T, kvh, hd) -> (n1, buf, ..., T, hd); pad slots exact zeros."""
    kvh = dense.shape[_KV_AXIS]
    assert kvh == layout.k, (kvh, layout.k)
    x = jnp.moveaxis(dense, _KV_AXIS, 0)                 # (kvh, ..., T, hd)
    xp = rse.zero_pad_slot(x, axis=0)                    # index kvh -> zeros
    slots = widened_slots(layout, buf)
    idx = jnp.asarray(np.where(slots >= 0, slots, kvh))
    return xp[idx]                                       # (n1, buf, ...)


def gather_leaf(sharded, layout: sm.Layout):
    """Inverse of `shard_leaf`: (n1, buf, ..., T, hd) -> (..., T, kvh, hd).
    Only live (rank, slot) pairs are read — pad contents never leak."""
    asg = jnp.asarray(layout.assignment)
    slot = jnp.asarray(layout.local_slot)
    x = sharded[asg, slot]                               # (kvh, ..., T, hd)
    return jnp.moveaxis(x, 0, _KV_AXIS)


def reshard_leaf(x, tables: sm.ReshardTables, *, use_kernel: bool = False):
    """Head-redistribution all-to-all on one sharded leaf (n1, buf, *rest):
    the engine's `reshard_ranks` (rank loop unrolled host-side;
    ``use_kernel`` routes the send-bucket gather through Pallas)."""
    return rse.reshard_ranks(x, tables, use_kernel=use_kernel)


# ---------------------------------------------------------------------------
# attention from sharded buffers (rank-local math; the pad-leak oracle)

def attend_heads(q, k, v, mask):
    """Dense GQA attention core, f32: q (B, H, g, Sq, hd); k/v (B, T, H, hd);
    mask (Sq, T) bool (True = attend). Per-head math is independent, which is
    what makes the rank-local sharded evaluation below bit-identical."""
    hd = q.shape[-1]
    s = jnp.einsum(
        "bhgqd,bthd->bhgqt", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd ** -0.5)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqt,bthd->bhgqd", p, v.astype(jnp.float32))


def attend_from_sharded(q, sk, sv, layout: sm.Layout, mask):
    """`attend_heads` evaluated rank-locally from sharded K/V buffers:
    each rank attends only its local head slots; per-head outputs are
    assembled by the head->rank map, so pad-slot contents (even NaN) can
    never reach the output. q (B, kvh, g, Sq, hd); sk/sv (n1, buf, B, T, hd).
    Returns (B, kvh, g, Sq, hd) f32, bit-equal to the dense evaluation."""
    n1, buf = sk.shape[:2]
    b, kvh, g, sq, hd = q.shape
    t = sk.shape[-2]
    slots = widened_slots(layout, buf).reshape(-1)        # (n1*buf,)
    q_sl = q[:, jnp.asarray(np.maximum(slots, 0))]       # (B, n1*buf, g, Sq, hd)
    # (n1, buf, B, T, hd) -> (B, T, n1*buf, hd): slot axis plays "head"
    k_sl = jnp.moveaxis(sk.reshape(n1 * buf, b, t, hd), 0, 2)
    v_sl = jnp.moveaxis(sv.reshape(n1 * buf, b, t, hd), 0, 2)
    out_sl = attend_heads(q_sl, k_sl, v_sl, mask)        # (B, n1*buf, g, Sq, hd)
    head_to_flat = jnp.asarray(
        layout.assignment * buf + layout.local_slot      # (kvh,)
    )
    return out_sl[:, head_to_flat]


# ---------------------------------------------------------------------------
# whole-cache container

class ShardedKV(ShardedState):
    """The sharded KV cache of ONE serving replica — the attention-only
    specialization of `repro.reshard.ShardedState` (every leaf a ``k``/``v``
    tensor with GQA-head units at axis -2, buf = kvh so every TP degree
    shares one geometry). Kept for the KV-specific validation/ergonomics;
    caches that also carry recurrent state go through `ShardedState` with
    `units.cache_unit_resolver`."""

    def __init__(self, cache, kvh: int, n1: int, *, tp: Optional[int] = None,
                 use_kernel: bool = False):
        validate_kv_cache(cache)
        self.kvh = kvh
        self.buf = kvh                                   # TP=1 worst case
        spec = _kv_spec(kvh)
        super().__init__(
            cache, lambda path: spec, n1, tp=tp, use_kernel=use_kernel
        )

    @property
    def layout(self) -> sm.Layout:
        return head_layout(self.kvh, self.tp, self.n1)

    def apply_tp(self, new_tp: int) -> Dict[str, Any]:
        stats = super().apply_tp(new_tp)
        # legacy key: PR-3 call sites/logs count "heads", the engine "units"
        stats["moved_heads_per_rank"] = stats["moved_units_per_rank"]
        self.last_reshard = stats
        return stats
