"""KV-cache sharding on the TP axis + live head-redistribution reshard —
the serving analogue of `core/nonuniform.py` + `core/reshard.py`
(DESIGN.md §2.5).

Training NTP reshards *weights* between comp and sync layouts; weights are
stateless with respect to requests, so a serving replica that loses a GPU
could in principle re-pack them from a canonical copy (the paper's §3.3
restart packing). The KV cache cannot: it is per-request state that took one
forward pass per cached token to build, and dropping it means re-prefilling
every in-flight request. This module makes the cache itself reshardable:

* GQA **KV heads are the partition units** over the scale-up domain
  (`n1` rank slots) — the same unit-choice principle as DESIGN.md §3.2;
* a replica at TP degree ``t`` holds its heads contiguously balanced over
  its first ``t`` live ranks (`head_layout`), expressed on the full
  n1-wide axis so one buffer geometry serves every degree;
* on a `FailureEvent` mid-decode, `ShardedKV.apply_tp` moves heads between
  ranks with the SAME static-table all-to-all as the weight reshard
  (`core.shard_mapping.reshard_tables`): rank-local gather of send buckets →
  tiled all-to-all (recv_r[j] = send_j[r]) → scatter, with pad slot = buf
  gathering a zero row / scatter-dropping. `RecoveryEvent` runs the same
  move upward (repack onto the revived ranks).

The collective is emulated rank-local on host (the numpy twin of
`core.reshard.reshard`, exactly the semantics property-tested in
`tests/test_reshard_properties.py`); on a real mesh the per-rank send-bucket
gather is `kernels.reshard_pack` (``use_kernel=True`` runs that Pallas
kernel here, in interpret mode on CPU) and the transpose is one
`jax.lax.all_to_all` over the model axis.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import shard_mapping as sm

KV_LEAF_NAMES = ("k", "v")


def validate_kv_cache(cache) -> None:
    """Every leaf must be a ``k``/``v`` KV-cache tensor. Non-KV cache state
    (ssm ``h``/``conv``) has a different NTP unit (channel block, not head)
    and is not servable yet."""
    for path, _ in jax.tree_util.tree_flatten_with_path(cache)[0]:
        name = getattr(path[-1], "key", None)
        if name not in KV_LEAF_NAMES:
            raise ValueError(
                f"ShardedKV shards k/v leaves only; got {name!r} at {path} "
                "(ssm/rglru state caches have a different NTP unit and are "
                "not servable yet)"
            )


# ---------------------------------------------------------------------------
# layouts

@lru_cache(maxsize=None)
def head_layout(kvh: int, tp: int, n1: int) -> sm.Layout:
    """Head -> rank placement of a replica serving at TP degree ``tp``:
    contiguously balanced over the first ``tp`` live ranks, expressed on the
    full ``n1``-wide domain axis (ranks >= tp are failed/idle and empty).
    ``kvh < tp`` simply leaves some live ranks without a KV head (Megatron
    GQA replicates their weight-side K/V; the cache itself is never
    duplicated)."""
    assert 1 <= tp <= n1, (tp, n1)
    return sm.make_layout(sm.sync_assignment(kvh, tp), n1)


def slots_at(layout: sm.Layout, buf: int) -> np.ndarray:
    """(n, buf) head id per buffer slot, -1 pad (layout.slots widened to a
    common ``buf`` so every TP degree shares one buffer geometry)."""
    assert buf >= layout.max_count
    out = np.full((layout.n, buf), -1, dtype=np.int64)
    out[:, : layout.max_count] = layout.slots
    return out


@lru_cache(maxsize=None)
def head_reshard_tables(kvh: int, tp_from: int, tp_to: int,
                        n1: int) -> sm.ReshardTables:
    """Static all-to-all tables moving every KV head from its ``tp_from``
    placement to its ``tp_to`` placement (buf = kvh: the TP=1 worst case,
    so no reallocation on any transition)."""
    return sm.reshard_tables(
        head_layout(kvh, tp_from, n1), head_layout(kvh, tp_to, n1), kvh
    )


# ---------------------------------------------------------------------------
# leaf ops  (dense leaf: (..., T, kvh, hd) — head axis at -2, as produced by
# models.attention.init_kv_cache under any stack of leading axes)

def shard_leaf(dense, layout: sm.Layout, buf: int):
    """(..., T, kvh, hd) -> (n1, buf, ..., T, hd); pad slots exact zeros."""
    kvh = dense.shape[-2]
    assert kvh == layout.k, (kvh, layout.k)
    x = jnp.moveaxis(dense, -2, 0)                       # (kvh, ..., T, hd)
    xp = jnp.concatenate(
        [x, jnp.zeros((1,) + x.shape[1:], x.dtype)], axis=0
    )                                                    # index kvh -> zeros
    slots = slots_at(layout, buf)
    idx = jnp.asarray(np.where(slots >= 0, slots, kvh))
    return xp[idx]                                       # (n1, buf, ...)


def gather_leaf(sharded, layout: sm.Layout):
    """Inverse of `shard_leaf`: (n1, buf, ..., T, hd) -> (..., T, kvh, hd).
    Only live (rank, slot) pairs are read — pad contents never leak."""
    asg = jnp.asarray(layout.assignment)
    slot = jnp.asarray(layout.local_slot)
    x = sharded[asg, slot]                               # (kvh, ..., T, hd)
    return jnp.moveaxis(x, 0, -2)


def reshard_leaf(x, tables: sm.ReshardTables, *, use_kernel: bool = False):
    """Head-redistribution all-to-all on one sharded leaf (n1, buf, *rest):
    the KV analogue of `core.reshard.reshard`, with the replica's rank loop
    unrolled host-side. ``use_kernel`` routes the per-rank send-bucket
    gather through the `kernels.reshard_pack` Pallas kernel."""
    n1, buf = x.shape[:2]
    rest = x.shape[2:]
    assert buf == tables.buf, (buf, tables.buf)
    xp = jnp.concatenate(
        [x, jnp.zeros((n1, 1) + rest, x.dtype)], axis=1
    )                                                    # slot buf -> zeros
    send_idx = jnp.asarray(tables.send_idx)              # (n, n, s_max)
    if use_kernel:
        from repro.kernels import ops

        flat = xp.reshape(n1, buf + 1, -1)
        send = jnp.stack(
            [ops.reshard_pack(flat[r], send_idx[r]) for r in range(n1)]
        ).reshape(n1, n1, tables.s_max, *rest)
    else:
        send = jax.vmap(lambda xr, ir: xr[ir])(xp, send_idx)
    recv = jnp.swapaxes(send, 0, 1)                      # recv_r[j] = send_j[r]

    out = jax.vmap(lambda xr, ir: xr[ir])(xp, jnp.asarray(tables.stay_idx))
    flat_recv = recv.reshape(n1, n1 * tables.s_max, *rest)
    recv_slots = jnp.asarray(tables.recv_idx).reshape(n1, -1)
    return jax.vmap(
        lambda o, s, v: o.at[s].set(v, mode="drop")      # pad (== buf) drops
    )(out, recv_slots, flat_recv)


# ---------------------------------------------------------------------------
# attention from sharded buffers (rank-local math; the pad-leak oracle)

def attend_heads(q, k, v, mask):
    """Dense GQA attention core, f32: q (B, H, g, Sq, hd); k/v (B, T, H, hd);
    mask (Sq, T) bool (True = attend). Per-head math is independent, which is
    what makes the rank-local sharded evaluation below bit-identical."""
    hd = q.shape[-1]
    s = jnp.einsum(
        "bhgqd,bthd->bhgqt", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd ** -0.5)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqt,bthd->bhgqd", p, v.astype(jnp.float32))


def attend_from_sharded(q, sk, sv, layout: sm.Layout, mask):
    """`attend_heads` evaluated rank-locally from sharded K/V buffers:
    each rank attends only its local head slots; per-head outputs are
    assembled by the head->rank map, so pad-slot contents (even NaN) can
    never reach the output. q (B, kvh, g, Sq, hd); sk/sv (n1, buf, B, T, hd).
    Returns (B, kvh, g, Sq, hd) f32, bit-equal to the dense evaluation."""
    n1, buf = sk.shape[:2]
    b, kvh, g, sq, hd = q.shape
    t = sk.shape[-2]
    slots = slots_at(layout, buf).reshape(-1)            # (n1*buf,)
    q_sl = q[:, jnp.asarray(np.maximum(slots, 0))]       # (B, n1*buf, g, Sq, hd)
    # (n1, buf, B, T, hd) -> (B, T, n1*buf, hd): slot axis plays "head"
    k_sl = jnp.moveaxis(sk.reshape(n1 * buf, b, t, hd), 0, 2)
    v_sl = jnp.moveaxis(sv.reshape(n1 * buf, b, t, hd), 0, 2)
    out_sl = attend_heads(q_sl, k_sl, v_sl, mask)        # (B, n1*buf, g, Sq, hd)
    head_to_flat = jnp.asarray(
        layout.assignment * buf + layout.local_slot      # (kvh,)
    )
    return out_sl[:, head_to_flat]


# ---------------------------------------------------------------------------
# whole-cache container

class ShardedKV:
    """The sharded KV cache of ONE serving replica.

    Owns every ``k``/``v`` leaf of a model cache pytree (any stack of
    leading axes — `Model.init_slot_cache` puts the slot axis first) in
    head-sharded ``(n1, buf, ..., T, hd)`` rank buffers, and reshards them
    in place when the replica's TP degree changes (`apply_tp`, the
    transition the engine runs mid-decode); `gather()`/`update()` convert
    to/from the dense view (a bit-exact identity pair). Non-KV cache leaves
    (ssm ``h``/``conv`` state) are rejected — their NTP unit is the channel
    block, not the head (open item)."""

    def __init__(self, cache, kvh: int, n1: int, *, tp: Optional[int] = None,
                 use_kernel: bool = False):
        self.kvh, self.n1 = kvh, n1
        self.buf = kvh                                   # TP=1 worst case
        self._tp = n1 if tp is None else tp
        self.use_kernel = use_kernel
        validate_kv_cache(cache)
        self._tree = jax.tree.map(
            lambda x: shard_leaf(x, self.layout, self.buf), cache
        )
        self.last_reshard: Dict[str, Any] = {}

    # -------------------------------------------------------------- views

    @property
    def tp(self) -> int:
        return self._tp

    @property
    def layout(self) -> sm.Layout:
        return head_layout(self.kvh, self._tp, self.n1)

    @property
    def sharded(self):
        """The raw (n1, buf, ...) rank buffers (tests / introspection)."""
        return self._tree

    def gather(self):
        """Dense cache pytree view (..., T, kvh, hd) for the decode step."""
        return jax.tree.map(lambda x: gather_leaf(x, self.layout), self._tree)

    def update(self, cache) -> None:
        """Re-scatter a dense cache (the decode step's output) into the
        current rank layout."""
        self._tree = jax.tree.map(
            lambda x: shard_leaf(x, self.layout, self.buf), cache
        )

    # ------------------------------------------------------------- reshard

    def apply_tp(self, new_tp: int) -> Dict[str, Any]:
        """Reshard every leaf from the current layout to the ``new_tp``
        layout (downward on failure, upward on recovery) and return the
        traffic stats of the move."""
        assert 1 <= new_tp <= self.n1, (new_tp, self.n1)
        if new_tp == self._tp:
            self.last_reshard = {"tp_from": self._tp, "tp_to": new_tp,
                                 "moved_heads_per_rank": 0, "bytes_moved": 0}
            return self.last_reshard
        tables = head_reshard_tables(self.kvh, self._tp, new_tp, self.n1)
        bytes_moved = 0
        n_moved = int((np.asarray(tables.send_idx) != tables.pad).sum())
        new_leaves: List = []
        leaves, treedef = jax.tree_util.tree_flatten(self._tree)
        for leaf in leaves:
            new_leaves.append(
                reshard_leaf(leaf, tables, use_kernel=self.use_kernel)
            )
            per_head = int(np.prod(leaf.shape[2:])) * leaf.dtype.itemsize
            bytes_moved += n_moved * per_head
        self._tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        self.last_reshard = {
            "tp_from": self._tp,
            "tp_to": new_tp,
            "moved_heads_per_rank": int(tables.moved_units_per_rank().max()),
            "bytes_moved": bytes_moved,
        }
        self._tp = new_tp
        return self.last_reshard
