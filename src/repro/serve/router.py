"""Request routing for NTP serving: SLO-aware admission + dispatch +
per-replica goodput accounting (live), and the analytic serving-goodput
model the benchmarks/golden tests pin (trace-driven, the serving twin of
`core/policies.py`).

The analytic half replays the SAME `core.failure_model.simulate_events`
traces the training orchestrator replays: at each sampled instant the
per-domain failed counts give every (domain-pinned) serving replica its
weakest-stage TP, each policy maps that to a relative decode rate —
``drop`` loses the whole replica (loss ∝ the replica's blast radius:
domains_per_replica × domain_size GPUs per single failure), ``ntp`` serves
on at 1/slowdown, ``ntp_pw`` boosts survivors through
`policies.boosted_operating_point` — and goodput / SLO attainment follow.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro import telemetry
from repro.core.availability import ClusterSpec
from repro.core.failure_model import FailureTraceConfig, simulate_events
from repro.core.policies import (
    WorkloadGeometry, boosted_operating_point, stage_slowdown,
)
from repro.core.power import PowerModel
from repro.runtime.events import LifecycleEvent
from repro.serve.engine import Request

# Decode-time workload geometry: at long context the per-token KV read makes
# attention (head-quantized over TP) the dominant cost, flipping training's
# 2/3 MLP FLOP share — decode is attention-⅔ (same stage_slowdown form).
SERVE_GEOM = WorkloadGeometry(n_heads=128, local_batch=8, mlp_flops_share=1 / 3)


# ---------------------------------------------------------------------------
# live router

class Router:
    """SLO-aware admission + dispatch over a `ServeSession`.

    Admission control is predictive: a request with a deadline is rejected
    up front when the backlog over the cluster's CURRENT aggregate decode
    rate cannot finish it in time (shedding at the door beats missing SLOs
    in flight). Preempted requests re-enter at the queue head."""

    def __init__(self, session):
        self.session = session
        self.queue: deque = deque()
        self.now = 0.0
        self.submitted = 0
        self.rejected = 0
        self.completed: List[Request] = []
        self._max_len = session.engines[0].max_len

    # ------------------------------------------------------------ admission

    def backlog_tokens(self) -> int:
        q = sum(r.remaining for r in self.queue)
        fl = sum(r.remaining for e in self.session.engines for r in e.in_flight)
        return q + fl

    def submit(self, req: Request) -> bool:
        self.submitted += 1
        req.arrival = self.now
        if len(req.prompt) + req.max_new > self._max_len:
            return self._reject("too_long")
        if req.deadline is not None:
            rate = self.session.total_rate()
            speed = max(
                (e.rel_speed for e in self.session.engines if not e.dead),
                default=0.0,
            )
            if rate <= 0 or speed <= 0:
                return self._reject("no_capacity")
            # queue wait at aggregate rate + the request's own SERIAL decode
            # (one slot decodes one token per credit-tick; extra slots don't
            # parallelize a single request)
            predicted = (self.now + self.backlog_tokens() / rate
                         + req.remaining / speed)
            if predicted > req.deadline:
                return self._reject("slo_miss_predicted")
        self.queue.append(req)
        telemetry.get().counter("serve.admission", outcome="admitted")
        return True

    def _reject(self, reason: str) -> bool:
        self.rejected += 1
        telemetry.get().counter("serve.admission", outcome="rejected",
                                reason=reason)
        return False

    def requeue(self, reqs: Iterable[Request]) -> None:
        """Preempted requests jump the queue (their KV was sacrificed once
        already)."""
        for r in reversed(list(reqs)):
            if not r.done:
                self.queue.appendleft(r)

    # --------------------------------------------------------------- events

    def apply(self, event: LifecycleEvent) -> None:
        self.requeue(self.session.apply(event))

    # ----------------------------------------------------------------- tick

    def step(self) -> List[Request]:
        """Dispatch whatever fits (fastest replicas first), run one wall
        tick, account completions. Returns this tick's finished requests."""
        engines = sorted(
            self.session.engines, key=lambda e: -e.rel_speed * e.capacity
        )
        for e in engines:
            while self.queue and e.can_admit():
                if not e.admit(self.queue.popleft()):  # pragma: no cover
                    break
        done = self.session.tick()
        self.now += 1.0
        tel = telemetry.get()
        if tel.enabled:
            # stamp TTFT at router-tick granularity: the first tick after
            # which a request has emitted any token
            for e in self.session.engines:
                for r in e.in_flight:
                    if r.generated and r.first_token_time is None:
                        r.first_token_time = self.now
        for r in done:
            r.finish_time = self.now
            self.completed.append(r)
            if tel.enabled:
                if r.first_token_time is None:
                    r.first_token_time = self.now
                tel.hist("serve.ttft", r.first_token_time - r.arrival)
                decode_toks = max(1, len(r.generated) - 1)
                tel.hist("serve.tpot",
                         (r.finish_time - r.first_token_time) / decode_toks)
        return done

    def drain(self, max_ticks: int = 10_000) -> None:
        """Run until queue + slots are empty (or the tick budget runs out)."""
        for _ in range(max_ticks):
            if not self.queue and all(
                e.n_active == 0 for e in self.session.engines
            ):
                return
            self.step()
        raise RuntimeError(f"drain did not converge in {max_ticks} ticks")

    # ------------------------------------------------------------ accounting

    def slo_attainment(self) -> float:
        """Fraction of completed requests that met their deadline (no
        deadline counts as met)."""
        if not self.completed:
            return 1.0
        ok = sum(
            1 for r in self.completed
            if r.deadline is None or r.finish_time <= r.deadline
        )
        return ok / len(self.completed)

    def goodput(self) -> Dict:
        """Tokens/tick per replica and overall, plus SLO attainment."""
        ticks = max(self.now, 1.0)
        per = [e.stats["tokens"] / ticks for e in self.session.engines]
        tel = telemetry.get()
        if tel.enabled:
            for r, g in enumerate(per):
                tel.gauge("serve.replica_goodput", g, replica=str(r))
        return {
            "per_replica": per,
            "tokens_per_tick": float(sum(per)),
            "slo_attainment": self.slo_attainment(),
            "completed": len(self.completed),
            "rejected": self.rejected,
            "preemptions": sum(
                e.stats["preemptions"] for e in self.session.engines
            ),
        }


# ---------------------------------------------------------------------------
# analytic serving-goodput model

def replica_serve_speed(
    tp: int,
    n1: int,
    method: str,
    *,
    geom: WorkloadGeometry = SERVE_GEOM,
    power: PowerModel = PowerModel(),
    slow_factor: float = 1.0,
    bw_frac: float = 1.0,
) -> Tuple[float, float]:
    """(relative decode rate, power boost) of one serving replica whose
    weakest scale-up domain has ``tp`` of ``n1`` GPUs surviving.

    ``slow_factor``/``bw_frac`` fold the domain's degradation ledger in
    (DESIGN.md §2.11). A degraded-but-complete replica is SLOWED, never
    dropped — even under the ``drop`` policy, which only reforms on actual
    GPU loss (there is nothing to reform when all GPUs are present);
    ``ntp_pw`` boosts the degradation away up to the rack cap."""
    if tp <= 0:
        return 0.0, 1.0
    from repro.core.policies import degradation_slowdown

    if tp >= n1:
        dm = degradation_slowdown(slow_factor, bw_frac, geom)
        if dm == 1.0:
            return 1.0, 1.0
        if method == "ntp_pw":
            p, eff = boosted_operating_point(dm, power)
            return 1.0 / eff, p
        return 1.0 / dm, 1.0
    if method == "drop":
        return 0.0, 1.0
    slow = stage_slowdown(tp, n1, geom,
                          slow_factor=slow_factor, bw_frac=bw_frac)
    if method == "ntp":
        return 1.0 / slow, 1.0
    if method == "ntp_pw":
        p, eff = boosted_operating_point(slow, power)
        return 1.0 / eff, p
    raise ValueError(method)


def _cluster_point(
    counts: np.ndarray,
    spec: ClusterSpec,
    method: str,
    *,
    slo_slowdown: float,
    geom: WorkloadGeometry,
    power: PowerModel,
) -> Tuple[float, float]:
    """(goodput, slo_attainment) of one failure sample. Replicas are pinned
    to their ``domains_per_replica`` consecutive domains (no serving-time
    repack — the KV state lives there); the weakest domain pins the
    replica, exactly like a PP stage."""
    dpr = spec.domains_per_replica
    n_rep = len(counts) // dpr
    worst = np.asarray(counts)[: n_rep * dpr].reshape(n_rep, dpr).max(axis=1)
    tp = spec.domain_size - worst
    speeds = np.array([
        replica_serve_speed(int(t), spec.domain_size, method,
                            geom=geom, power=power)[0]
        for t in tp
    ])
    total = float(speeds.sum())
    goodput = total / n_rep
    if total <= 0.0:
        return goodput, 0.0
    # traffic routes ∝ capacity; a request meets its latency SLO iff its
    # replica's per-token slowdown stays within the budget
    ok = speeds >= 1.0 / slo_slowdown - 1e-9
    return goodput, float(speeds[ok].sum() / total)


def serving_goodput_trace(
    spec: ClusterSpec,
    trace_cfg: FailureTraceConfig,
    methods: Sequence[str] = ("drop", "ntp", "ntp_pw"),
    *,
    slo_slowdown: float = 1.1,
    sample_every_h: float = 6.0,
    geom: WorkloadGeometry = SERVE_GEOM,
    power: PowerModel = PowerModel(),
) -> Dict[str, Dict[str, float]]:
    """Trace-mean serving goodput + SLO attainment per policy over one
    Llama3-calibrated failure/recovery trace (the serving fig4_end_to_end)."""
    ev = simulate_events(trace_cfg)
    n_dom = trace_cfg.n_gpus // trace_cfg.domain_size
    times = np.arange(0.0, trace_cfg.days * 24.0, sample_every_h)
    out: Dict[str, Dict[str, List[float]]] = {
        m: {"goodput": [], "slo_attainment": []} for m in methods
    }
    # one arrival-sorted scan over the whole trace instead of an
    # O(events) rescan per sample — bit-identical counts (§2.11)
    all_counts = ev.failed_counts_scan(times, n_dom, trace_cfg.domain_size)
    for counts in all_counts:
        for m in methods:
            g, a = _cluster_point(
                counts, spec, m, slo_slowdown=slo_slowdown, geom=geom,
                power=power,
            )
            out[m]["goodput"].append(g)
            out[m]["slo_attainment"].append(a)
    return {
        m: {k: float(np.mean(v)) for k, v in d.items()}
        for m, d in out.items()
    }


def blast_radius_goodput(
    base_spec: ClusterSpec,
    trace_cfg: FailureTraceConfig,
    radii: Sequence[int] = (1, 2, 4, 8),
    methods: Sequence[str] = ("drop", "ntp_pw"),
    *,
    slo_slowdown: float = 1.1,
    sample_every_h: float = 6.0,
    geom: WorkloadGeometry = SERVE_GEOM,
    power: PowerModel = PowerModel(),
) -> Dict[int, Dict[str, float]]:
    """Goodput vs the REPLICA blast radius (domains_per_replica): under
    ``drop`` a single GPU failure forfeits domains_per_replica × domain_size
    GPUs of serving capacity, so loss grows ∝ the radius; NTP policies
    localize it to the failed domain's slowdown."""
    out: Dict[int, Dict[str, float]] = {}
    for dpr in radii:
        spec = ClusterSpec(
            n_gpus=base_spec.n_gpus, domain_size=base_spec.domain_size,
            domains_per_replica=dpr,
        )
        res = serving_goodput_trace(
            spec, trace_cfg, methods, slo_slowdown=slo_slowdown,
            sample_every_h=sample_every_h, geom=geom, power=power,
        )
        out[dpr] = {m: res[m]["goodput"] for m in methods}
    return out
