"""Resilient NTP inference serving (DESIGN.md §2.5): continuous-batching
engine + live per-request-state reshard + SLO router behind a
`ServeSession` façade parallel to `runtime.NTPSession` — a `FailureEvent`
mid-decode reshards the KV cache AND recurrent state (SSM/rgLRU channel
blocks, via the unified engine's `repro.reshard.ShardedState`) to the
reduced TP degree instead of dropping the in-flight requests; a
`RecoveryEvent` repacks it back upward."""
from repro.reshard.state import ShardedState  # noqa: F401
from repro.serve.engine import Request, ServeEngine  # noqa: F401
from repro.serve.kv_shard import (  # noqa: F401
    ShardedKV, attend_from_sharded, attend_heads, gather_leaf,
    head_layout, head_reshard_tables, reshard_leaf, shard_leaf, slots_at,
)
from repro.serve.router import (  # noqa: F401
    SERVE_GEOM, Router, blast_radius_goodput, replica_serve_speed,
    serving_goodput_trace,
)
from repro.serve.session import SERVE_POLICIES, ServeSession  # noqa: F401
