"""ServeSession — the serving façade parallel to `runtime.NTPSession`
(DESIGN.md §2.5).

One session owns D serving replicas (one `ServeEngine` each, sharing one
weight copy), the per-domain failed-GPU ledger (`runtime.events.ClusterHealth`),
and the fault-tolerance policy deciding what a degraded replica does:

* ``drop``   — the baseline: any failure kills the whole replica (all
  in-flight requests preempted, cache lost); it returns only when its
  domain is fully repaired. The serving twin of training DP_DROP.
* ``ntp``    — the replica keeps serving at reduced TP: per-request state
  (KV cache and/or SSM/rgLRU recurrent state) resharded in place through
  the unified engine (`repro.reshard.ShardedState`), decode slowed by the
  unit-quantized `stage_slowdown`, slot pool shrunk ∝ surviving ranks.
* ``ntp_pw`` — NTP plus the paper's §3.2 power boost: survivors run up to
  the rack cap (`policies.boosted_operating_point`), erasing most or all of
  the slowdown at full slot shrinkage only.

Unlike training, serving replicas are NOT repacked across domains on
failure (`plan_from_health` is a job-wide re-shuffle; a serving replica is
pinned to its domain by the KV state living there), so events address
domains and replica ``r`` simply serves domain ``r``; `apply(event)`
reshards weights (re-derived per-rank head layout — weights are stateless,
the KV cache is what must physically move), KV cache, and slot map in
place and hands back whatever was preempted.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import ArchConfig
from repro.core.nonuniform import FailurePlan
from repro.core.policies import WorkloadGeometry
from repro.core.power import PowerModel
from repro.models.transformer import build_model
from repro.runtime.events import ClusterHealth, LifecycleEvent
from repro.serve.engine import Request, ServeEngine
from repro.serve.router import replica_serve_speed

SERVE_POLICIES = ("drop", "ntp", "ntp_pw")


class ServeSession:
    """Stateful serving session: D engines + health ledger + policy."""

    def __init__(self, *_, **__):
        raise TypeError("use ServeSession.create(...)")

    @classmethod
    def create(
        cls,
        cfg: ArchConfig,
        *,
        replicas: int = 1,
        n1: int = 4,
        slots: int = 8,
        max_len: int = 96,
        prefill_len: int = 32,
        policy: str = "ntp",
        power_model: PowerModel = PowerModel(),
        geom: Optional[WorkloadGeometry] = None,
        dtype=jnp.float32,
        params=None,
        key=None,
        use_kernel: bool = False,
        quarantine: bool = True,
    ) -> "ServeSession":
        if policy not in SERVE_POLICIES:
            raise ValueError(f"policy {policy!r} not in {SERVE_POLICIES}")
        from repro.serve.engine import validate_serve_cfg

        validate_serve_cfg(cfg)
        self = object.__new__(cls)
        self._cfg = cfg
        self._policy = policy
        self._power = power_model
        # decode quantizes at the model's COARSEST partition-unit family
        # (KV heads / SSD heads / rgLRU blocks — reshard.units), with the
        # analytic model's decode-time FLOP split — same blend as
        # SERVE_GEOM, only the unit count comes from the live model
        from dataclasses import replace as _replace

        from repro.reshard.units import serve_unit_count
        from repro.serve.router import SERVE_GEOM

        self._geom = geom or _replace(
            SERVE_GEOM, n_heads=serve_unit_count(cfg), local_batch=slots
        )
        model = build_model(cfg, remat=False)
        if params is None:
            params = model.init(key if key is not None else jax.random.PRNGKey(0))
        self._params = params
        self._health = ClusterHealth.pristine(replicas, n1)
        self._n1 = n1
        self._dtype = dtype
        # one model + one jit cache for every replica: the programs are
        # identical, only the (shared) params and per-engine caches differ
        compiled = (jax.jit(model.decode_slots), jax.jit(model.prefill),
                    jax.jit(model.decode_step))
        self.engines = [
            ServeEngine(cfg, params, n1=n1, slots=slots, max_len=max_len,
                        prefill_len=prefill_len, dtype=dtype,
                        use_kernel=use_kernel, model=model, compiled=compiled)
            for _ in range(replicas)
        ]
        self._events: List[LifecycleEvent] = []
        self._repair_debt: Dict[int, int] = {}   # domain -> clamp surplus
        self._quarantine = quarantine
        self.transitions: List[Dict] = []
        return self

    # ------------------------------------------------------------ introspect

    @property
    def cfg(self) -> ArchConfig:
        return self._cfg

    @property
    def params(self):
        return self._params

    @property
    def policy(self) -> str:
        return self._policy

    @property
    def quarantine(self) -> bool:
        """Whether an open SDC suspicion drains its replica (§2.11)."""
        return self._quarantine

    @property
    def health(self) -> ClusterHealth:
        return self._health

    @property
    def events(self) -> List[LifecycleEvent]:
        return list(self._events)

    @property
    def replica_tp(self) -> Tuple[int, ...]:
        """Surviving TP degree per (domain-pinned) serving replica."""
        return tuple(self._n1 - f for f in self._health.failed)

    @property
    def plan(self) -> Optional[FailurePlan]:
        """The session's health as a `FailurePlan` (None while any replica
        is fully dead — FailurePlan has no TP-0 representation)."""
        tp = self.replica_tp
        if any(t < 1 for t in tp):
            return None
        return FailurePlan(n1=self._n1, replica_tp=tp)

    def total_rate(self) -> float:
        """Upper-bound decode tokens per wall tick across live replicas."""
        return float(sum(e.rel_speed * e.capacity for e in self.engines))

    # ---------------------------------------------------------------- events

    def _operating_point(self, tp: int, deg=None) -> Tuple[int, float, float]:
        """(engine_tp, rel_speed, power_boost) the policy assigns to a
        replica whose domain has ``tp`` surviving GPUs — the SAME ladder and
        FLOP blend the analytic model pins (`router.replica_serve_speed`);
        only the head count is the live model's. ``deg`` is the domain's
        `DomainDegradation` ledger (§2.11): stragglers / degraded links slow
        the replica instead of dropping it."""
        speed, boost = replica_serve_speed(
            tp, self._n1, self._policy, geom=self._geom, power=self._power,
            slow_factor=deg.slow_factor if deg is not None else 1.0,
            bw_frac=deg.bw_frac if deg is not None else 1.0,
        )
        if speed == 0.0:  # tp 0, or drop policy with any failure: dead
            return 0, 0.0, 1.0
        return tp, speed, boost

    def apply(self, event: LifecycleEvent) -> List[Request]:
        """Consume a lifecycle event: update the ledger, retarget every
        affected engine (KV reshard / death / revival + speed + slot map),
        and return the preempted requests for the router to requeue.

        Failures beyond a domain's size clamp in the ledger but leave a
        per-domain repair DEBT, and the matching surplus repairs of the
        clamped trace are absorbed against it (the serving twin of
        `orchestrator.TraceRunner`'s debt) — otherwise a fully-dead replica
        would revive while its trace still has every GPU down, inflating
        live goodput relative to the analytic replay of the same trace.

        Degradation events (§2.11) DRAIN-THEN-RETARGET instead of dropping:
        the replica's TP (and with it cache layout + slot pool) never
        changes, so nothing is preempted — its decode rate is repriced
        through the degradation ledger, and an open SDC suspicion puts the
        engine in ``draining`` (in-flight requests finish, no new admits,
        the router routes around it) until the clear."""
        from repro.runtime.events import (
            DEGRADATION_EVENTS, RecoveryEvent, resolve_serving_domain,
        )

        # domain-pinned addressing (replica= aliases domain 1:1) is
        # validated/normalized ONCE, in runtime.events
        event = resolve_serving_domain(event, self._health.n_domains)
        dom = event.domain
        if isinstance(event, DEGRADATION_EVENTS):
            return self._apply_degradation(event, dom)
        if isinstance(event, RecoveryEvent):
            debt = self._repair_debt.get(dom, 0)
            absorbed = min(debt, event.n_gpus)
            if absorbed:
                self._repair_debt[dom] = debt - absorbed
                if absorbed == event.n_gpus:
                    self.transitions.append({
                        "event": event, "replica": dom, "kind": "absorbed",
                        "tp_from": self.replica_tp[dom],
                        "tp_to": self.replica_tp[dom], "preempted": 0,
                    })
                    return []
                event = RecoveryEvent(step=event.step, domain=dom,
                                      n_gpus=event.n_gpus - absorbed)
        else:
            overflow = self._health.failed[dom] + event.n_gpus - self._n1
            if overflow > 0:
                self._repair_debt[dom] = (
                    self._repair_debt.get(dom, 0) + overflow
                )
        old_tp = self.replica_tp
        self._health = self._health.apply(event)
        self._events.append(event)
        degs = self._health.replica_degradations()
        preempted: List[Request] = []
        tel = telemetry.get()
        from repro.runtime.events import event_kind

        with tel.span(
            "serve.transition",
            kind=event_kind(event),
            policy=self._policy,
        ) as sp:
            reshard_bytes = 0
            for r, engine in enumerate(self.engines):
                tp, speed, boost = self._operating_point(
                    self.replica_tp[r], degs[r]
                )
                if tp == engine.tp and not (engine.dead and tp > 0):
                    engine.rel_speed, engine.power_boost = speed, boost
                    continue
                pre = engine.apply_tp(tp, rel_speed=speed, power_boost=boost)
                preempted += pre
                reshard_bytes += engine.last_reshard.get("bytes_moved", 0)
                self.transitions.append({
                    "event": event, "replica": r,
                    "tp_from": old_tp[r], "tp_to": tp,
                    "preempted": len(pre),
                    "power_boost": boost, "rel_speed": speed,
                    "reshard": dict(engine.last_reshard),
                })
            sp.set(domain=dom, preempted=len(preempted),
                   bytes_moved=reshard_bytes)
            if tel.enabled:
                if preempted:
                    tel.counter("serve.preempted", len(preempted),
                                policy=self._policy)
                for r, engine in enumerate(self.engines):
                    tel.gauge("serve.replica_rate",
                              engine.rel_speed * engine.capacity,
                              replica=str(r))
        return preempted

    def _apply_degradation(self, event, dom: int) -> List[Request]:
        """Drain-then-retarget (§2.11): the TP plan, cache layout and slot
        pool are untouched (degradation never removes a GPU), so NOTHING is
        preempted — every live engine's decode rate is repriced through the
        updated ledger, and an open SDC suspicion flips the engine to
        ``draining`` until its clear. Returns [] (the `apply` contract's
        preempted list — always empty here)."""
        from repro.runtime.events import event_kind

        self._health = self._health.apply(event)
        self._events.append(event)
        degs = self._health.replica_degradations()
        tel = telemetry.get()
        with tel.span(
            "serve.transition", kind=event_kind(event), policy=self._policy,
        ) as sp:
            for r, engine in enumerate(self.engines):
                if engine.dead:
                    continue
                _, speed, boost = self._operating_point(
                    self.replica_tp[r], degs[r]
                )
                draining = self._quarantine and degs[r].sdc > 0
                changed = (speed != engine.rel_speed
                           or boost != engine.power_boost
                           or draining != engine.draining)
                engine.rel_speed, engine.power_boost = speed, boost
                engine.draining = draining
                if changed:
                    self.transitions.append({
                        "event": event, "replica": r, "kind": "retarget",
                        "tp_from": engine.tp, "tp_to": engine.tp,
                        "preempted": 0, "power_boost": boost,
                        "rel_speed": speed, "draining": draining,
                    })
            sp.set(domain=dom, preempted=0)
            if tel.enabled:
                for r, engine in enumerate(self.engines):
                    tel.gauge("serve.replica_rate",
                              engine.rel_speed * engine.capacity,
                              replica=str(r))
        return []

    # ------------------------------------------------------------------ run

    def tick(self) -> List[Request]:
        """One wall tick on every live engine; returns finished requests."""
        done: List[Request] = []
        for e in self.engines:
            done += e.tick()
        return done

    # ------------------------------------------------------------ checkpoint

    def _engine_state(self, e) -> Dict:
        """One engine's KV-bearing state as fixed-shape arrays: dense cache,
        slot tables, and the in-flight REQUEST BODIES (prompt + generated
        prefix, padded to max_len) — without these a restored session would
        have live slots pointing at requests it cannot name."""
        ml = e.max_len
        prompt = np.zeros((e.slots, ml), np.int32)
        p_len = np.zeros(e.slots, np.int32)
        gen = np.zeros((e.slots, ml), np.int32)
        g_len = np.zeros(e.slots, np.int32)
        max_new = np.zeros(e.slots, np.int32)
        for b in np.flatnonzero(e._rid >= 0):
            req = e._req[int(e._rid[b])]
            prompt[b, : len(req.prompt)] = req.prompt
            p_len[b] = len(req.prompt)
            gen[b, : len(req.generated)] = req.generated
            g_len[b] = len(req.generated)
            max_new[b] = req.max_new
        return {
            "kv": e.cache,
            "rid": np.asarray(e._rid, np.int32),
            "pos": np.asarray(e._pos, np.int32),
            "cur_tok": np.asarray(e._cur_tok, np.int32),
            "admit_order": np.asarray(e._admit_order, np.int32),
            "admitted": np.asarray(e._admitted, np.int32),
            "req_prompt": prompt, "req_prompt_len": p_len,
            "req_gen": gen, "req_gen_len": g_len, "req_max_new": max_new,
        }

    def save(self, path: str) -> None:
        """Write the KV-bearing state: weights + every replica's DENSE
        (layout-independent) cache + slot tables + in-flight request bodies.
        Cache dtypes (bf16 serving caches) round-trip via the checkpoint
        dtype records. The router's queue/accounting are not persisted —
        queued requests were never admitted, so resubmitting them is safe."""
        save_checkpoint(
            path,
            {"params": self._params,
             "engines": [self._engine_state(e) for e in self.engines]},
        )

    def restore(self, path: str) -> List[Request]:
        """Load a `save` checkpoint into the CURRENT per-replica layouts
        (a checkpoint taken under any TP restores under any other — the
        dense cache is canonical, like training's canonical weights).
        In-flight requests are rebuilt and decoding continues where it
        stopped; arrival/deadline are session-clock-relative and reset.
        The target session's OWN in-flight requests are preempted first and
        returned along with any checkpointed slots beyond a degraded
        replica's CURRENT capacity (the same preempt-and-return invariant
        `apply` enforces) — nothing is silently dropped."""
        like = {"params": self._params,
                "engines": [self._engine_state(e) for e in self.engines]}
        tree, _ = load_checkpoint(path, like)
        preempted: List[Request] = []
        for e in self.engines:
            while e.n_active:
                preempted.append(e._preempt_one())
        self._params = tree["params"]
        for r, e in enumerate(self.engines):
            st = tree["engines"][r]
            e.params = self._params
            e._cache = st["kv"]
            for k, attr in (("rid", "_rid"), ("pos", "_pos"),
                            ("cur_tok", "_cur_tok"),
                            ("admit_order", "_admit_order")):
                setattr(e, attr, np.asarray(st[k]).astype(np.int64))
            e._admitted = int(st["admitted"])
            e._req = {}
            for b in np.flatnonzero(e._rid >= 0):
                p_len = int(st["req_prompt_len"][b])
                g_len = int(st["req_gen_len"][b])
                req = Request(
                    rid=int(e._rid[b]),
                    prompt=np.asarray(st["req_prompt"][b][:p_len], np.int32),
                    max_new=int(st["req_max_new"][b]),
                    generated=[int(t) for t in st["req_gen"][b][:g_len]],
                )
                e._req[req.rid] = req
            while e.n_active > e.capacity:
                preempted.append(e._preempt_one())
        return preempted
