"""Continuous-batching serve engine for ONE NTP replica (DESIGN.md §2.5).

Slot-based prefill/decode scheduling over the existing arch-stack
transformers (`models.transformer.Model`): a fixed pool of KV-cache slots,
each slot one in-flight request at its own position (`Model.decode_slots`
vmaps the one-token decode over the slot axis). On a `FailureEvent` the
cache is resharded mid-decode through the unified engine's
`repro.reshard.ShardedState` — KV heads, SSD channel blocks and rgLRU gate
blocks move in one fused unit-redistribution all-to-all at the transition,
and the decode loop works on the dense view in between (shard ∘ gather is
the bit-exact identity, so nothing is lost by not round-tripping it per
token).

Degradation model (the serving twin of `core/ntp_train.py`'s local-batch
rule): a replica at TP ``t < n1`` decodes slower by the same head-quantized
`stage_slowdown` the training policies use, expressed here as a token-bucket
``rel_speed`` — the engine only runs a decode step when enough speed credit
has accrued, so wall-clock goodput shrinks exactly by the slowdown (or less,
under an NTP-PW power boost). Its KV memory also shrinks with the surviving
ranks, so slot capacity drops ∝ t/n1 and over-capacity requests are
preempted (their generated prefix survives in the `Request`, and greedy
decode makes the resumed stream identical to an uninterrupted one — exactly
so with full-precision caches; a reduced-precision cache (bf16) can diverge
on resume, because re-prefill attends freshly-computed full-precision K/V
where the original decode read the quantized cache entries).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import build_model
from repro.reshard.state import ShardedState
from repro.reshard.units import cache_unit_resolver

# every kind with a registered state UnitSpec serves through fail→repair:
# attention (KV-head units), Mamba-2 SSD (SSD-head channel blocks) and
# RG-LRU (Griffin gate blocks) — DESIGN.md §3.3
DECODER_KINDS = ("attn", "attn_sw", "attn_chunked", "ssm", "rglru")
RECURRENT_KINDS = ("ssm", "rglru")


def validate_serve_cfg(cfg: ArchConfig) -> set:
    """Reject configs the serve engine cannot run, BEFORE any model is
    built (a degenerate config may not even initialize). Returns the set of
    block kinds in the pattern."""
    kinds = {cfg.block_kind(i) for i in range(cfg.n_layers)}
    if not kinds <= set(DECODER_KINDS):
        raise ValueError(
            f"serve engine supports kinds {DECODER_KINDS}; "
            f"{cfg.arch_id} has kinds {sorted(kinds)}"
        )
    # enc-dec serves through failover for EVERY decoder kind: the encoder
    # K/V banked at the first prefill call (ek/ev, its own enc_kv_head
    # partition unit) reshards with the rest of the cache; recurrent
    # configs bank it on the length-1 prefill that seeds the
    # token-by-token teacher-forced admit, and every later decode step
    # reads the bank (models/attention.attn_apply).
    return kinds


@dataclass
class Request:
    """One generation request. ``generated`` survives preemption: a resumed
    request re-prefills prompt+generated and (greedy) continues the exact
    same token stream."""

    rid: int
    prompt: np.ndarray                   # (L,) int32
    max_new: int
    arrival: float = 0.0                 # router ticks
    deadline: Optional[float] = None     # SLO: completion-time bound (ticks)
    enc_input: Optional[np.ndarray] = None  # enc-dec only: (enc_seq, d_model)
                                         # frame embeddings; kept on the
                                         # request so a preempt-resume can
                                         # re-prefill (not checkpointed —
                                         # a restored slot keeps decoding
                                         # from its banked ek/ev instead)
    generated: List[int] = field(default_factory=list)
    done: bool = False
    first_token_time: Optional[float] = None  # router ticks (TTFT source)
    finish_time: Optional[float] = None
    preemptions: int = 0

    def __post_init__(self):
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.generated)

    def full_prompt(self) -> np.ndarray:
        return np.concatenate(
            [np.asarray(self.prompt, np.int64),
             np.asarray(self.generated, np.int64)]
        ).astype(np.int32)


class ServeEngine:
    """Slot-scheduled continuous-batching engine for one serving replica."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        n1: int,
        tp: Optional[int] = None,
        slots: int = 8,
        max_len: int = 96,
        prefill_len: int = 32,
        dtype=jnp.float32,
        use_kernel: bool = False,
        model=None,                     # share one Model across replicas
        compiled=None,                  # (decode_slots, prefill, decode_step)
    ):
        kinds = validate_serve_cfg(cfg)
        # ring caches (attn_sw/attn_chunked) only keep the trailing window:
        # a prefill longer than the ring would leave pad K/V posing as valid.
        # ValueError (not assert): these guard CALLER config, and an assert
        # vanishes under `python -O` — the bad prefill would then silently
        # corrupt the ring cache instead of failing loudly.
        if "attn_sw" in kinds and prefill_len > cfg.window:
            raise ValueError(
                f"prefill_len={prefill_len} exceeds the sliding-window ring "
                f"cache: {cfg.arch_id} has window={cfg.window} (attn_sw "
                "keeps only the trailing window, so a longer prefill would "
                "leave pad K/V posing as valid tokens)"
            )
        if "attn_chunked" in kinds and prefill_len > cfg.chunk_size:
            raise ValueError(
                f"prefill_len={prefill_len} exceeds the chunked-attention "
                f"ring cache: {cfg.arch_id} has chunk_size={cfg.chunk_size} "
                "(attn_chunked keeps only the current chunk, so a longer "
                "prefill would leave pad K/V posing as valid tokens)"
            )
        if prefill_len > max_len:
            raise ValueError(
                f"prefill_len={prefill_len} exceeds max_len={max_len}: a "
                "request could never decode past its own prefill"
            )
        # recurrent state is CUMULATIVE: a zero-padded prefill would fold
        # pad tokens into h/conv, so these configs admit token-by-token
        # (exact recurrent semantics, length-stable jit) — see `admit`
        self._recurrent = bool(kinds & set(RECURRENT_KINDS))

        self.cfg = cfg
        self.model = model if model is not None else build_model(cfg, remat=False)
        self.params = params
        self.n1 = n1
        self._tp = n1 if tp is None else tp
        self.slots, self.max_len, self.prefill_len = slots, max_len, prefill_len
        self._dtype = dtype
        self.use_kernel = use_kernel
        # working state is the DENSE slot cache: shard ∘ gather is the
        # bit-exact identity (tests/test_kv_shard_properties.py), so the
        # rank-sharded form is materialized only at TP transitions, where
        # the physical head all-to-all actually runs (apply_tp) — not
        # round-tripped on every decoded token. On a real mesh the cache is
        # resident sharded and the decode-time gather is the standard GQA
        # KV all-gather.
        self._cache = self.model.init_slot_cache(slots, max_len, dtype)
        # resolve every state leaf to its partition-unit family up front —
        # a leaf with no UnitSpec could not survive a TP transition
        self._unit_resolver = cache_unit_resolver(cfg)
        for path, _ in jax.tree_util.tree_flatten_with_path(self._cache)[0]:
            self._unit_resolver(path)
        self.last_reshard = {}
        self.dead = False
        self.draining = False                # SDC quarantine: no new admits
        self.rel_speed = 1.0                 # tokens per wall tick (<= 1)
        self.power_boost = 1.0
        self._credit = 0.0

        self._rid = np.full(slots, -1, np.int64)
        self._pos = np.zeros(slots, np.int64)
        self._cur_tok = np.zeros(slots, np.int64)
        self._admit_order = np.zeros(slots, np.int64)    # for preemption LIFO
        self._admitted = 0
        self._req: Dict[int, Request] = {}
        self._finished: List[Request] = []

        if compiled is not None:
            # one jit cache for all replicas — identical programs, and
            # jax.jit caches per wrapper instance, not per bound method
            self._decode, self._prefill, self._step1 = compiled
        else:
            self._decode = jax.jit(self.model.decode_slots)
            self._prefill = jax.jit(self.model.prefill)
            self._step1 = jax.jit(self.model.decode_step)  # prefill overflow
        self.stats = {"tokens": 0, "prefills": 0, "preemptions": 0,
                      "reshards": 0, "reshard_bytes": 0}

    # ------------------------------------------------------------ introspect

    @property
    def tp(self) -> int:
        return self._tp

    @property
    def n_active(self) -> int:
        return int((self._rid >= 0).sum())

    @property
    def capacity(self) -> int:
        """Usable slots: per-rank KV memory is fixed, so total cache memory
        (and with it the slot pool) shrinks ∝ surviving ranks."""
        if self.dead:
            return 0
        return max(1, (self.slots * self._tp) // self.n1)

    def can_admit(self) -> bool:
        return ((not self.dead) and (not self.draining)
                and self.n_active < self.capacity)

    @property
    def in_flight(self) -> List[Request]:
        return [self._req[r] for r in self._rid[self._rid >= 0]]

    @property
    def cache(self):
        """The dense slot-stacked KV cache (leaves (slots, ..., T, kvh, hd))."""
        return self._cache

    # ---------------------------------------------------------------- admit

    def admit(self, req: Request) -> bool:
        """Prefill ``req`` (prompt + any pre-preemption prefix) into a free
        slot. The prefill determines the request's next token, but it is
        only EMITTED by a later credited tick — every generated token pays
        the same ``rel_speed`` toll, so admission churn cannot dilute a
        degraded replica's measured slowdown."""
        if not self.can_admit():
            return False
        enc = None
        if self.cfg.encoder is not None:
            if req.enc_input is None:
                raise ValueError(
                    f"{self.cfg.arch_id} is enc-dec: Request.enc_input must "
                    f"carry the ({self.cfg.encoder.enc_seq}, "
                    f"{self.cfg.d_model}) frame embeddings"
                )
            enc = jnp.asarray(req.enc_input, jnp.float32)[None]
            if enc.shape[1:] != (self.cfg.encoder.enc_seq, self.cfg.d_model):
                raise ValueError(
                    f"Request.enc_input has shape {enc.shape[1:]}, expected "
                    f"({self.cfg.encoder.enc_seq}, {self.cfg.d_model})"
                )
        free = np.flatnonzero(self._rid < 0)
        b = int(free[0])
        toks = req.full_prompt()
        n = len(toks)
        assert 0 < n and n + req.remaining <= self.max_len, (n, req.remaining)

        cache1 = self.model.init_cache(1, self.max_len, self._dtype)
        if self._recurrent:
            # recurrent state accumulates over EVERY prefilled position, so
            # pad tokens are not inert — feed the prompt token-by-token
            # (prefill of length 1, then teacher-forced decode): exactly the
            # recurrent update semantics, with length-stable jit programs.
            # The length-1 prefill also banks the encoder K/V (enc-dec):
            # the teacher-forced decode steps then read the bank.
            logits, cache1 = self._prefill(
                self.params, jnp.asarray(toks[:1][None]), cache1,
                enc_input=enc,
            )
            last_logits, pos = logits[0, 0], 1
            for t in toks[1:]:
                last, cache1 = self._step1(
                    self.params, cache1, jnp.full((1, 1), t, jnp.int32),
                    jnp.int32(pos),
                )
                pos += 1
                last_logits = last[0, 0]
        else:
            p = self.prefill_len
            padded = np.zeros(p, np.int32)
            head = toks[: min(n, p)]
            padded[: len(head)] = head
            logits, cache1 = self._prefill(
                self.params, jnp.asarray(padded[None]), cache1,
                enc_input=enc,
            )
            if n <= p:
                last_logits = logits[0, n - 1]
                pos = n
            else:
                # resumed request longer than one prefill: feed the overflow
                # teacher-forced through the decode path (preemption only)
                pos = p
                for t in toks[p:]:
                    last, cache1 = self._step1(
                        self.params, cache1, jnp.full((1, 1), t, jnp.int32),
                        jnp.int32(pos),
                    )
                    pos += 1
                last_logits = last[0, 0]
        first = int(jnp.argmax(last_logits[: self.cfg.vocab_size]))

        self._cache = jax.tree.map(
            lambda full, one: full.at[b].set(one), self._cache, cache1
        )
        self._rid[b] = req.rid
        self._pos[b] = pos
        self._cur_tok[b] = first        # pending: emitted by the next tick
        self._admit_order[b] = self._admitted
        self._admitted += 1
        self._req[req.rid] = req
        self.stats["prefills"] += 1
        return True

    # ----------------------------------------------------------------- tick

    def tick(self) -> List[Request]:
        """One wall tick: iff enough speed credit accrued (a TP-degraded
        replica skips ticks ∝ its slowdown), every active slot EMITS its
        pending token and the batched decode computes the next one.
        Returns the requests that finished."""
        if self.dead or self.n_active == 0:
            return []
        self._credit += self.rel_speed
        if self._credit < 1.0:
            return []
        self._credit -= 1.0

        logits, self._cache = self._decode(
            self.params, self._cache,
            jnp.asarray(self._cur_tok, jnp.int32),
            jnp.asarray(self._pos, jnp.int32),
        )
        nxt = np.asarray(jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1))
        out, self._finished = [], []
        for b in np.flatnonzero(self._rid >= 0):
            req = self._req[int(self._rid[b])]
            req.generated.append(int(self._cur_tok[b]))
            self.stats["tokens"] += 1
            if req.remaining <= 0:
                self._finish(int(b))
            else:
                self._pos[b] += 1
                self._cur_tok[b] = nxt[b]
        out += self._finished
        self._finished = []
        return out

    def _finish(self, b: int) -> None:
        req = self._req.pop(int(self._rid[b]))
        req.done = True
        self._rid[b] = -1
        self._finished.append(req)

    # ------------------------------------------------------------ lifecycle

    def apply_tp(self, new_tp: int, *, rel_speed: float = 1.0,
                 power_boost: float = 1.0) -> List[Request]:
        """Consume a TP transition: reshard the live KV cache (or die/revive
        on 0 <-> >0), update the speed model, and preempt whatever no longer
        fits the shrunk slot pool. Returns the preempted requests (the
        router requeues them; their generated prefix rides along)."""
        preempted: List[Request] = []
        if new_tp == 0:
            if not self.dead:
                preempted = self._preempt_all()
                self.dead = True
                self.last_reshard = {"tp_from": self._tp, "tp_to": 0,
                                     "moved_units_per_rank": 0,
                                     "bytes_moved": 0}
                self._tp = 0
                self.rel_speed, self.power_boost = 0.0, 1.0
            return preempted
        if self.dead:
            # revival: no cache state survived death — fresh zero buffers
            self.dead = False
            self._cache = jax.tree.map(jnp.zeros_like, self._cache)
            self.last_reshard = {"tp_from": 0, "tp_to": new_tp,
                                 "moved_units_per_rank": 0, "bytes_moved": 0}
        elif new_tp != self._tp:
            # the physical move: shard into the OLD rank layout, run the
            # unit-redistribution all-to-all (KV heads, SSD channel blocks
            # and rgLRU gate blocks all ride the same fused messages), keep
            # the new dense view
            state = ShardedState(self._cache, self._unit_resolver, self.n1,
                                 tp=self._tp, use_kernel=self.use_kernel)
            st = state.apply_tp(new_tp)
            self._cache = state.gather()
            self.last_reshard = st
            self.stats["reshards"] += 1
            self.stats["reshard_bytes"] += st["bytes_moved"]
        self._tp = new_tp
        self.rel_speed, self.power_boost = rel_speed, power_boost
        while self.n_active > self.capacity:
            preempted.append(self._preempt_one())
        return preempted

    def _preempt_one(self) -> Request:
        """Preempt the most-recently-admitted active request (least sunk
        prefill+decode work to redo)."""
        active = np.flatnonzero(self._rid >= 0)
        b = int(active[np.argmax(self._admit_order[active])])
        req = self._req.pop(int(self._rid[b]))
        req.preemptions += 1
        self._rid[b] = -1
        self._cache = jax.tree.map(
            lambda x: x.at[b].set(jnp.zeros((), x.dtype)), self._cache
        )
        self.stats["preemptions"] += 1
        return req

    def _preempt_all(self) -> List[Request]:
        out = [self._preempt_one() for _ in range(self.n_active)]
        return out
