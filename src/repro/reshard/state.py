"""ShardedState: the sharded form of an arbitrary per-request state tree
(KV cache, SSM h/conv, RG-LRU h/conv) over one scale-up domain, with live
TP-transition resharding (DESIGN.md §3.3).

Generalizes the KV-head container that `serve.kv_shard.ShardedKV` pioneered
to EVERY registered `UnitSpec` family: each leaf's partition axis is split
into its family's units, units are placed by the planner's degree layouts
(``sync_key(k, n1, tp)`` — contiguously balanced over the first ``tp`` live
ranks), and a TP change moves units between ranks with the same
static-table all-to-all as the weight reshard, fused per unit family (one
message per (src, dst) rank pair for all leaves sharing a plan, not one
per tensor). Replicated tails (the SSM conv state's B/C columns) ride
along dense — they exist on every rank and never move.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import shard_mapping as sm
from repro.reshard import engine, planner
from repro.reshard.units import UnitSpec


@lru_cache(maxsize=None)
def degree_layout(k: int, tp: int, n1: int) -> sm.Layout:
    """Unit→rank placement of a replica serving at TP degree ``tp``:
    contiguously balanced over its first ``tp`` live ranks on the full
    ``n1``-wide domain axis."""
    assert 1 <= tp <= n1, (tp, n1)
    return planner.layout(planner.sync_key(k, n1, tp))


def widened_slots(layout: sm.Layout, buf: int) -> np.ndarray:
    """(n, buf) unit id per buffer slot, -1 pad (layout.slots widened to a
    common ``buf`` so every TP degree shares one buffer geometry)."""
    assert buf >= layout.max_count
    out = np.full((layout.n, buf), -1, dtype=np.int64)
    out[:, : layout.max_count] = layout.slots
    return out


def _norm_axis(spec: UnitSpec, ndim: int) -> int:
    ax = spec.axis if spec.axis >= 0 else spec.axis + ndim
    assert 0 <= ax < ndim, (spec, ndim)
    return ax


def shard_state_leaf(dense, spec: UnitSpec, layout: sm.Layout, buf: int):
    """Dense leaf → ((n1, buf, [unit,] *other) sharded part, dense tail).

    The ``spec.axis`` slice ``[0, k·unit)`` moves into per-rank unit
    buffers (pad slots exact zeros); the replicated ``tail`` channels stay
    dense. The unit channel dim is kept only when ``unit > 1``."""
    ax = _norm_axis(spec, dense.ndim)
    span = spec.k * spec.unit
    assert dense.shape[ax] == span + spec.tail, (dense.shape, ax, spec)
    main = jax.lax.slice_in_dim(dense, 0, span, axis=ax)
    tail = (
        jax.lax.slice_in_dim(dense, span, span + spec.tail, axis=ax)
        if spec.tail else None
    )
    x = jnp.moveaxis(main, ax, 0)                    # (k·unit, *other)
    if spec.unit > 1:
        x = x.reshape(spec.k, spec.unit, *x.shape[1:])
    xp = engine.zero_pad_slot(x, axis=0)             # index k → zeros
    slots = widened_slots(layout, buf)
    idx = jnp.asarray(np.where(slots >= 0, slots, spec.k))
    return xp[idx], tail                             # (n1, buf, ...)


def gather_state_leaf(sharded, tail, spec: UnitSpec, layout: sm.Layout,
                      ndim: int):
    """Inverse of `shard_state_leaf`: only live (rank, slot) pairs are read
    — pad contents never leak into the dense view."""
    asg = jnp.asarray(layout.assignment)
    slot = jnp.asarray(layout.local_slot)
    x = sharded[asg, slot]                           # (k, [unit,] *other)
    if spec.unit > 1:
        x = x.reshape(spec.k * spec.unit, *x.shape[2:])
    ax = _norm_axis(spec, ndim)
    out = jnp.moveaxis(x, 0, ax)
    if tail is not None:
        out = jnp.concatenate([out, tail], axis=ax)
    return out


class ShardedState:
    """The sharded per-request state of ONE serving replica.

    Owns every unit-bearing leaf of a state pytree in rank buffers over an
    ``n1``-wide scale-up domain and reshards them in place when the
    replica's TP degree changes (`apply_tp` — the transition the serve
    engine runs mid-decode). `gather()`/`update()` convert to/from the
    dense view (a bit-exact identity pair). ``resolver`` maps a leaf path
    to its `UnitSpec` (see `units.cache_unit_resolver`); every leaf must
    resolve — state with no registered unit cannot survive a transition.
    """

    def __init__(self, tree, resolver: Callable, n1: int, *,
                 tp: Optional[int] = None, use_kernel: bool = False):
        self.n1 = n1
        self._tp = n1 if tp is None else tp
        self.use_kernel = use_kernel
        leaves, self._treedef = jax.tree_util.tree_flatten_with_path(tree)
        self._specs: List[UnitSpec] = [resolver(path) for path, _ in leaves]
        self._ndims = [leaf.ndim for _, leaf in leaves]
        self._shard(leaves)
        self.last_reshard: Dict[str, Any] = {}

    def _layout(self, spec: UnitSpec, tp: int) -> sm.Layout:
        return degree_layout(spec.k, tp, self.n1)

    def _shard(self, leaves) -> None:
        self._bufs, self._tails = [], []
        for spec, (_, leaf) in zip(self._specs, leaves):
            b, t = shard_state_leaf(
                leaf, spec, self._layout(spec, self._tp), spec.k
            )
            self._bufs.append(b)
            self._tails.append(t)

    # -------------------------------------------------------------- views

    @property
    def tp(self) -> int:
        return self._tp

    @property
    def sharded(self) -> List:
        """The raw (n1, buf, ...) rank buffers (tests / introspection)."""
        return list(self._bufs)

    def gather(self):
        """Dense state pytree view for the decode step."""
        dense = [
            gather_state_leaf(b, t, spec, self._layout(spec, self._tp), nd)
            for b, t, spec, nd in zip(
                self._bufs, self._tails, self._specs, self._ndims
            )
        ]
        return jax.tree_util.tree_unflatten(self._treedef, dense)

    def update(self, tree) -> None:
        """Re-scatter a dense state tree (the decode step's output) into
        the current rank layout."""
        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        assert treedef == self._treedef
        self._shard(leaves)

    # ------------------------------------------------------------- reshard

    def apply_tp(self, new_tp: int) -> Dict[str, Any]:
        """Reshard every leaf from the current layout to the ``new_tp``
        layout (downward on failure, upward on recovery), fused per unit
        family, and return the traffic stats of the move.
        ``moved_units_per_rank`` counts unit INSTANCES (one per leaf
        carrying the unit, summed over families) through the busiest rank —
        the same accounting basis as ``bytes_moved``."""
        assert 1 <= new_tp <= self.n1, (new_tp, self.n1)
        stats = {
            "tp_from": self._tp, "tp_to": new_tp,
            "moved_units_per_rank": 0, "bytes_moved": 0, "messages": 0,
        }
        if new_tp == self._tp:
            self.last_reshard = stats
            return stats

        groups: Dict[int, List[int]] = {}
        for i, spec in enumerate(self._specs):
            groups.setdefault(spec.k, []).append(i)
        pairs = set()
        per_rank = np.zeros(self.n1, dtype=np.int64)
        for k, idxs in groups.items():
            plan = planner.transition_plan(
                planner.sync_key(k, self.n1, self._tp),
                planner.sync_key(k, self.n1, new_tp),
                k, k,
            )
            outs = engine.reshard_group(
                [self._bufs[i] for i in idxs], plan.tables,
                use_kernel=self.use_kernel,
            )
            for i, o in zip(idxs, outs):
                unit_bytes = int(
                    np.prod(self._bufs[i].shape[2:])
                ) * self._bufs[i].dtype.itemsize
                stats["bytes_moved"] += plan.n_moved * unit_bytes
                self._bufs[i] = o
            pairs.update(plan.pairs)   # families sharing a pair fuse
            # every family's moves land on the same ranks in the same
            # transition: per-rank traffic is the SUM over families
            per_rank += plan.tables.moved_units_per_rank() * len(idxs)
        stats["moved_units_per_rank"] = int(per_rank.max())
        stats["messages"] = len(pairs)
        self._tp = new_tp
        self.last_reshard = stats
        return stats
