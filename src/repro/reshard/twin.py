"""The numpy twin of the reshard engine: exact host-side emulation of the
static-table all-to-all plus the transfer accounting the tests and the
transition engine's invariants hang off (DESIGN.md §3.3).

Two routes, both bit-exact against the jnp engine:

* `emulate_tables` — the padded-message emulation (gather send buckets,
  transpose, scatter), semantically identical to `engine.reshard_ranks`;
* `apply_plan` — the DIRECT route a packed→packed transition takes: stays
  are rank-local slot renames, movers travel in one fused bucket per
  (src, dst) rank pair. `apply_plan` ASSERTS the central invariant — only
  units whose src rank differs from their dst rank ever enter a bucket —
  and returns a `TransferStats` accounting of exactly what moved.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from repro.core import shard_mapping as sm
from repro.reshard.planner import TransitionPlan


@dataclass
class TransferStats:
    """What one transition physically moved (the numpy twin's ledger)."""

    moved_units: int = 0      # units that changed ranks (network traffic)
    stayed_units: int = 0     # units renamed rank-locally (no traffic)
    messages: int = 0         # fused (src, dst) sends actually issued
    bytes_moved: int = 0      # payload bytes across all messages
    dense_bytes: int = 0      # what the dense host round-trip would touch
    per_pair: Dict[Tuple[int, ...], int] = field(default_factory=dict)

    def merge(self, other: "TransferStats") -> "TransferStats":
        self.moved_units += other.moved_units
        self.stayed_units += other.stayed_units
        self.bytes_moved += other.bytes_moved
        self.dense_bytes += other.dense_bytes
        for k, v in other.per_pair.items():
            if k not in self.per_pair:
                self.messages += 1    # shared tagged pairs fuse into one send
            self.per_pair[k] = self.per_pair.get(k, 0) + v
        return self

    def as_dict(self) -> Dict:
        return {
            "moved_units": self.moved_units,
            "stayed_units": self.stayed_units,
            "messages": self.messages,
            "bytes_moved": self.bytes_moved,
            "dense_bytes": self.dense_bytes,
        }


def emulate_tables(x_ranks: np.ndarray, tables: sm.ReshardTables) -> np.ndarray:
    """Message-table twin of `engine.reshard_ranks` on one (n, buf, ...)
    rank-buffer stack (recv_r[j] = send_j[r]; pad gathers zeros / drops)."""
    n, buf = x_ranks.shape[:2]
    assert buf == tables.buf, (buf, tables.buf)
    send, recv = tables.send_idx, tables.recv_idx
    zero = np.zeros((n, 1) + x_ranks.shape[2:], x_ranks.dtype)
    xp = np.concatenate([x_ranks, zero], axis=1)
    send_buf = np.stack([xp[r][send[r]] for r in range(n)])
    recv_buf = np.stack([send_buf[:, r] for r in range(n)])

    out = np.empty_like(x_ranks)
    for r in range(n):
        o = xp[r][tables.stay_idx[r]].copy()
        flat = recv_buf[r].reshape((-1,) + recv_buf.shape[3:])
        slots = recv[r].reshape(-1)
        keep = slots != tables.pad
        o[slots[keep]] = flat[keep]
        out[r] = o
    return out


def apply_plan(
    bufs: List[np.ndarray],
    plan: TransitionPlan,
    *,
    stats: TransferStats | None = None,
    pair_tag: Tuple = (),
) -> List[np.ndarray]:
    """Direct packed→packed transition of a GROUP of leaves sharing one
    plan: ``bufs`` is a list of (n, src_buf, *payload) rank-buffer stacks;
    returns the (n, dst_buf, *payload) stacks under the destination layout.

    Stays never leave their rank; movers are gathered into ONE fused bucket
    per (src, dst) pair across every leaf in the group — the bucket list is
    built explicitly so the accounting (and the tests) can assert that only
    ``src_rank != dst_rank`` units generate traffic. ``pair_tag`` prefixes
    the ``per_pair`` ledger keys (callers tag the replica so buckets of
    DIFFERENT unit families can later merge into one physical message per
    (replica, src, dst) — see `transition.transition_trees`).
    """
    n = plan.n
    outs = [
        np.zeros((n, plan.dst_buf) + b.shape[2:], b.dtype) for b in bufs
    ]
    for b, o in zip(bufs, outs):
        assert b.shape[:2] == (n, plan.src_buf), (b.shape, plan.src_buf)
        # stays: rank-local slot renames, zero network traffic
        o[plan.stay_rank, plan.stay_dst_slot] = b[plan.stay_rank,
                                                  plan.stay_src_slot]

    st = stats if stats is not None else TransferStats()
    st.stayed_units += plan.n_stay * len(bufs)
    st.dense_bytes += sum(int(b.nbytes) for b in bufs)
    src, dst = plan.move_src_rank, plan.move_dst_rank
    assert (src != dst).all(), "a stay leaked into the move set"
    for s, d in plan.pairs:
        sel = (src == s) & (dst == d)
        # ONE fused message for the whole group: every leaf's movers for
        # this (src, dst) pair ride together
        payload = [b[s, plan.move_src_slot[sel]] for b in bufs]
        n_units = int(sel.sum())
        key = pair_tag + (s, d)
        if key not in st.per_pair:
            st.messages += 1          # families sharing a tagged pair fuse
        st.moved_units += n_units * len(bufs)
        st.bytes_moved += sum(int(p.nbytes) for p in payload)
        st.per_pair[key] = st.per_pair.get(key, 0) + n_units
        for o, p in zip(outs, payload):
            o[d, plan.move_dst_slot[sel]] = p
    return outs
