"""The jnp reshard route: static-table gather → all-to-all → scatter on
``(n, buf, ...)`` rank-buffer stacks, shared by every caller that moves
state between layouts with the replica's rank loop unrolled host-side
(serving KV/recurrent caches, host-side transition emulation).

The in-shard_map training collective (`core.reshard.reshard`) is the SPMD
twin of `reshard_ranks`: identical table semantics, but each rank runs one
slice and the transpose is a real `jax.lax.all_to_all`. Both consume the
same planner tables and the same Pallas `reshard_pack` send-bucket route
(``use_kernel=True``; kernel vs interpret mode is env/CLI driven — see
`kernels.ops.pallas_interpret`).
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core import shard_mapping as sm


def zero_pad_slot(x, axis: int = 0):
    """Append one zero slot along ``axis`` — the pad sentinel every table
    gathers zeros from (index ``buf``)."""
    shape = list(x.shape)
    shape[axis] = 1
    return jnp.concatenate([x, jnp.zeros(shape, x.dtype)], axis=axis)


def gather_send_buckets(xp, send_idx, *, use_kernel: bool = False):
    """Per-rank send-bucket gather: ``xp`` (n, buf+1, *rest) zero-padded
    buffers, ``send_idx`` (n, n, s_max) → (n, n, s_max, *rest).
    ``use_kernel`` routes each rank's gather through the Pallas
    `kernels.reshard_pack` kernel (one VMEM pass per destination)."""
    n = xp.shape[0]
    bufp1 = xp.shape[1]
    rest = xp.shape[2:]
    s_max = send_idx.shape[-1]
    if use_kernel:
        from repro.kernels import ops

        flat = xp.reshape(n, bufp1, -1)
        return jnp.stack(
            [ops.reshard_pack(flat[r], send_idx[r]) for r in range(n)]
        ).reshape(n, n, s_max, *rest)
    return jax.vmap(lambda xr, ir: xr[ir])(xp, send_idx)


def reshard_ranks(x, tables: sm.ReshardTables, *, use_kernel: bool = False):
    """One layout change on a rank-buffer stack ``x`` (n, buf, *rest):
    gather send buckets → tiled all-to-all (host-unrolled transpose:
    recv_r[j] = send_j[r]) → stays + scatter. Pad slots (== buf) gather
    zeros and scatter-drop, so output pad slots are exact zeros."""
    n, buf = x.shape[:2]
    assert buf == tables.buf, (buf, tables.buf)
    xp = zero_pad_slot(x, axis=1)
    send = gather_send_buckets(
        xp, jnp.asarray(tables.send_idx), use_kernel=use_kernel
    )
    recv = jnp.swapaxes(send, 0, 1)              # recv_r[j] = send_j[r]

    out = jax.vmap(lambda xr, ir: xr[ir])(xp, jnp.asarray(tables.stay_idx))
    flat_recv = recv.reshape(n, n * tables.s_max, *x.shape[2:])
    recv_slots = jnp.asarray(tables.recv_idx).reshape(n, -1)
    return jax.vmap(
        lambda o, s, v: o.at[s].set(v, mode="drop")  # pad (== buf) drops
    )(out, recv_slots, flat_recv)


def reshard_group(
    xs: Sequence, tables: sm.ReshardTables, *, use_kernel: bool = False
) -> List:
    """Fused multi-leaf reshard: every leaf shares one plan, so their unit
    payloads are concatenated and the whole group moves through ONE
    gather/all-to-all/scatter — one message per (src, dst) rank pair for
    the group, instead of one per tensor."""
    xs = list(xs)
    if len(xs) == 1:
        return [reshard_ranks(xs[0], tables, use_kernel=use_kernel)]
    n, buf = xs[0].shape[:2]
    if len({x.dtype for x in xs}) > 1:
        # promotion must be value-exact: bf16/f16 → f32 round-trips are,
        # int → float is NOT (>2^24 corrupts silently) — loud, not silent
        assert all(jnp.issubdtype(x.dtype, jnp.floating) for x in xs), (
            "mixed-dtype reshard group must be all-floating; give non-float "
            "leaves their own group",
            [x.dtype for x in xs],
        )
    dtype = jnp.result_type(*[x.dtype for x in xs])
    flats, sizes = [], []
    for x in xs:
        assert x.shape[:2] == (n, buf), (x.shape, (n, buf))
        flats.append(x.reshape(n, buf, -1).astype(dtype))
        sizes.append(flats[-1].shape[-1])
    fused = jnp.concatenate(flats, axis=-1)
    out = reshard_ranks(fused, tables, use_kernel=use_kernel)
    outs, off = [], 0
    for x, e in zip(xs, sizes):
        outs.append(
            out[..., off:off + e].astype(x.dtype).reshape(x.shape)
        )
        off += e
    return outs
