"""The ONE Algorithm-1 planner (DESIGN.md §3.3): every stateful layout
change in the repo — training comp↔sync gradient reshard, fail/repair
packed→packed weight+optimizer transitions, serving KV/recurrent-state
head redistribution — resolves its layouts and static all-to-all tables
here, through one LRU-cached factory over `core.shard_mapping`.

Layouts are addressed by hashable **layout keys**:

* ``("sync", k, n1, n2)`` — k units contiguously balanced over the first
  ``n2`` of ``n1`` rank slots.  This is both the training sync layout AND a
  serving replica's placement at TP degree ``n2`` (serve/kv_shard.py's
  ``head_layout`` is exactly this key).
* ``("comp", k, n1, nr, n2)`` — Algorithm 1's balanced comp layout of a
  replica with ``nr`` live ranks syncing at degree ``n2``, expressed on the
  full ``n1``-wide domain axis (core/nonuniform.py's per-replica layout).

`transition_plan(src_key, dst_key)` compiles a src→dst layout change into a
`TransitionPlan`: the padded message tables for the collective route, plus
flat stay/move index arrays and the unit `transfer_matrix` for the direct
host route and its transfer accounting (tests assert a transition moves
ONLY units whose src rank differs from their dst rank).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Tuple

import numpy as np

from repro.core import shard_mapping as sm

LayoutKey = Tuple  # ("sync", k, n1, n2) | ("comp", k, n1, nr, n2)


def sync_key(k: int, n1: int, n2: int) -> LayoutKey:
    return ("sync", k, n1, n2)


def comp_key(k: int, n1: int, nr: int, n2: int) -> LayoutKey:
    return ("comp", k, n1, nr, n2)


@lru_cache(maxsize=None)
def layout(key: LayoutKey) -> sm.Layout:
    """Resolve a layout key to its `shard_mapping.Layout` (cached)."""
    kind = key[0]
    if kind == "sync":
        _, k, n1, n2 = key
        assert 1 <= n2 <= n1, key
        return sm.make_layout(sm.sync_assignment(k, n2), n1)
    if kind == "comp":
        _, k, n1, nr, n2 = key
        assert 1 <= n2 <= nr <= n1, key
        return sm.make_layout(sm.comp_assignment(k, nr, n2), n1)
    raise ValueError(f"unknown layout key kind {kind!r} in {key}")


@lru_cache(maxsize=None)
def tables(src: LayoutKey, dst: LayoutKey, buf: int) -> sm.ReshardTables:
    """Padded all-to-all tables for one src→dst layout change (cached)."""
    return sm.reshard_tables(layout(src), layout(dst), buf)


@dataclass(frozen=True)
class TransitionPlan:
    """A compiled src→dst layout change for one unit family.

    ``tables`` drive the padded-message collective/kernel route at the
    common ``buf``; the flat index arrays drive the direct host route
    (`repro.reshard.transition`): stays are rank-local slot renames, moves
    are grouped into one bucket per (src_rank, dst_rank) pair. ``transfer``
    is the unit `transfer_matrix` — its off-diagonal sum is the ONLY
    traffic a transition is allowed to generate.
    """

    k: int
    n: int
    src_buf: int                 # slots per rank in the source packing
    dst_buf: int                 # slots per rank in the destination packing
    buf: int                     # common buffer the message tables assume
    tables: sm.ReshardTables
    # stays (src_rank == dst_rank), unit-id order
    stay_rank: np.ndarray        # (n_stay,)
    stay_src_slot: np.ndarray
    stay_dst_slot: np.ndarray
    # moves (src_rank != dst_rank), unit-id order
    move_src_rank: np.ndarray    # (n_move,)
    move_src_slot: np.ndarray
    move_dst_rank: np.ndarray
    move_dst_slot: np.ndarray
    transfer: np.ndarray         # (n, n) units from src rank -> dst rank

    @property
    def n_moved(self) -> int:
        """Units that change ranks — the network traffic of the move."""
        return int(self.transfer.sum() - np.trace(self.transfer))

    @property
    def n_stay(self) -> int:
        return int(np.trace(self.transfer))

    @property
    def pairs(self) -> list:
        """Non-empty (src_rank, dst_rank) message pairs, src != dst — the
        transition issues exactly ONE fused send per pair."""
        off = self.transfer - np.diag(np.diag(self.transfer))
        return [tuple(p) for p in np.argwhere(off > 0)]

    @property
    def identity(self) -> bool:
        return self.n_moved == 0 and self.src_buf == self.dst_buf and bool(
            np.array_equal(self.stay_src_slot, self.stay_dst_slot)
        )


@lru_cache(maxsize=None)
def transition_plan(
    src: LayoutKey,
    dst: LayoutKey,
    src_buf: int | None = None,
    dst_buf: int | None = None,
) -> TransitionPlan:
    """Compile one src→dst layout change (cached — the LRU plan cache)."""
    ls, ld = layout(src), layout(dst)
    assert ls.k == ld.k and ls.n == ld.n, (src, dst)
    sb = ls.max_count if src_buf is None else int(src_buf)
    db = ld.max_count if dst_buf is None else int(dst_buf)
    assert sb >= ls.max_count and db >= ld.max_count, (sb, db, src, dst)
    buf = max(sb, db)

    moved = ls.assignment != ld.assignment
    stay = ~moved
    return TransitionPlan(
        k=ls.k,
        n=ls.n,
        src_buf=sb,
        dst_buf=db,
        buf=buf,
        tables=tables(src, dst, buf),
        stay_rank=ls.assignment[stay].copy(),
        stay_src_slot=ls.local_slot[stay].copy(),
        stay_dst_slot=ld.local_slot[stay].copy(),
        move_src_rank=ls.assignment[moved].copy(),
        move_src_slot=ls.local_slot[moved].copy(),
        move_dst_rank=ld.assignment[moved].copy(),
        move_dst_slot=ld.local_slot[moved].copy(),
        transfer=sm.transfer_matrix(ls, ld),
    )


def plan_cache_info() -> dict:
    """Hit/size stats of the planner's LRU caches (introspection/benchmarks)."""
    return {
        "layout": layout.cache_info()._asdict(),
        "tables": tables.cache_info()._asdict(),
        "transition_plan": transition_plan.cache_info()._asdict(),
    }
