"""UnitSpec registry: every NTP partition-unit family in one place
(DESIGN.md §3.2/§3.3).

A `UnitSpec` says how one leaf of a state tree splits into Algorithm-1
partition units along its partition axis:

| kind           | unit                              | used by                 |
|----------------|-----------------------------------|-------------------------|
| ``rows128``    | 128-row weight block              | dense MLP weights       |
| ``expert``     | whole expert                      | MoE FFN weights         |
| ``kv_group``   | GQA kv-group (train weight unit)  | attention weights       |
| ``kv_head``    | GQA KV head                       | serving KV cache        |
| ``enc_kv_head``| encoder KV head (cross-attn bank) | enc-dec serving cache   |
| ``ssm_head``   | SSD head (``head_dim`` channels)  | Mamba-2 h/conv state    |
| ``rglru_block``| Griffin gate block (``block_width``) | RG-LRU h/conv state  |

Training weights are already unit-buffered when they reach the engine, so
their specs only carry ``kind``/``k``; serving state trees are dense, so
cache specs additionally carry the leaf geometry: the ``axis`` holding the
units, ``unit`` channels per unit along it, and a replicated ``tail`` (the
SSM conv state carries its B/C columns on every rank — they never move).

`cache_unit_resolver(cfg)` maps a cache-tree leaf path to its spec by
reading the block kind out of ``cfg.layer_pattern`` (cache trees mirror the
pattern: ``cache["layers"][i]`` / ``cache["tail"][i]`` belong to
``layer_pattern[i]``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

from repro.configs.base import ATTN_KINDS, ArchConfig

STATE_LEAF_NAMES = ("k", "v", "h", "conv", "ek", "ev")


@dataclass(frozen=True)
class UnitSpec:
    """One partition-unit family on one leaf."""

    kind: str
    k: int            # number of partition units
    axis: int = 0     # leaf axis carrying the units (negative = from end)
    unit: int = 1     # channels per unit along that axis
    tail: int = 0     # trailing replicated channels (never resharded)

    def __post_init__(self):
        assert self.k >= 1, self


# ---------------------------------------------------------------------------
# training weight units (NTP prototype: packed unit buffers)

def ntp_unit_specs(cfg) -> Dict[str, UnitSpec]:
    """Leaf-name → UnitSpec for the NTP prototype's packed param/opt trees
    (``cfg``: `core.ntp_train.NTPModelConfig`, duck-typed to avoid an import
    cycle). Leaves not named here are replicated (norms, embed, router)."""
    mlp_kind = "expert" if cfg.is_moe else "rows128"
    attn = UnitSpec("kv_group", cfg.n_kv_groups)
    mlp = UnitSpec(mlp_kind, cfg.k_ff)
    return {"wq": attn, "wk": attn, "wv": attn, "wo": attn, "A": mlp, "B": mlp}


# ---------------------------------------------------------------------------
# serving state units (arch-stack cache trees)

def _kind_state_specs(cfg: ArchConfig, kind: str) -> Dict[str, UnitSpec]:
    """State-leaf specs of one block kind (leaf shapes as produced by
    `models.transformer.init_block_cache` under any stack of leading axes)."""
    if kind in ATTN_KINDS:
        # k/v: (..., T, kvh, hd) — head axis at -2
        kv = UnitSpec("kv_head", cfg.n_kv_heads, axis=-2)
        specs = {"k": kv, "v": kv}
    elif kind == "ssm":
        s = cfg.ssm
        nh, hp = s.n_heads(cfg.d_model), s.head_dim
        specs = {
            # h: (..., nh, hp, ds) — SSD-head axis at -3
            "h": UnitSpec("ssm_head", nh, axis=-3),
            # conv: (..., K-1, di + 2·ds) — di = nh·hp sharded channels,
            # the B/C tail replicated on every rank
            "conv": UnitSpec("ssm_head", nh, axis=-1, unit=hp,
                             tail=2 * s.d_state),
        }
    elif kind == "rglru":
        g = cfg.rglru
        di, w = g.d_inner(cfg.d_model), g.block_width
        nb = di // w
        specs = {
            "h": UnitSpec("rglru_block", nb, axis=-1, unit=w),
            "conv": UnitSpec("rglru_block", nb, axis=-1, unit=w),
        }
    else:
        raise ValueError(f"no state units for block kind {kind!r}")
    if cfg.encoder is not None:
        # enc-dec: EVERY decoder block (attention, SSD, rgLRU) banks the
        # encoder K/V at prefill (ek/ev, (..., Tenc, kvh, hd)) — its heads
        # reshard exactly like self-attention KV heads, as their own unit
        # family
        ekv = UnitSpec("enc_kv_head", cfg.n_kv_heads, axis=-2)
        specs = dict(specs, ek=ekv, ev=ekv)
    return specs


def arch_unit_counts(cfg: ArchConfig) -> Dict[str, int]:
    """Partition-unit count per state family present in ``cfg``'s pattern."""
    out: Dict[str, int] = {}
    for kind in dict.fromkeys(cfg.layer_pattern):
        for spec in _kind_state_specs(cfg, kind).values():
            out[spec.kind] = spec.k
    return out


def serve_unit_count(cfg: ArchConfig) -> int:
    """The quantization granularity serving slowdown models use: the
    COARSEST (smallest-k) unit family in the pattern pins the imbalance."""
    return max(1, min(arch_unit_counts(cfg).values()))


def cache_unit_resolver(cfg: ArchConfig) -> Callable:
    """Path → Optional[UnitSpec] resolver for ``cfg``'s cache trees.

    Cache trees mirror the layer pattern (`Model.init_cache`):
    ``cache["layers"]``/``cache["tail"]`` are tuples whose i-th element
    holds the state of block kind ``layer_pattern[i]``. Unknown leaf names
    raise — silently skipping a state leaf would strand it at the old
    layout through a TP transition.
    """
    per_kind = {
        kind: _kind_state_specs(cfg, kind)
        for kind in dict.fromkeys(cfg.layer_pattern)
    }

    def resolve(path) -> Optional[UnitSpec]:
        names = [getattr(p, "key", None) for p in path]
        idxs = [getattr(p, "idx", None) for p in path]
        leaf_name = names[-1]
        if leaf_name not in STATE_LEAF_NAMES:
            raise ValueError(
                f"unknown state leaf {leaf_name!r} at {path}: no UnitSpec "
                "registered — register one in repro.reshard.units before "
                "serving this state through TP transitions"
            )
        # the SequenceKey right after 'layers'/'tail' is the pattern index
        pat = None
        for i, nm in enumerate(names):
            if nm in ("layers", "tail") and i + 1 < len(path):
                pat = idxs[i + 1]
                break
        if pat is None:
            raise ValueError(f"cannot locate layer-pattern index in {path}")
        kind = cfg.layer_pattern[int(pat) % len(cfg.layer_pattern)]
        return per_kind[kind].get(leaf_name) or _bad_leaf(kind, leaf_name, path)

    return resolve


def _bad_leaf(kind, leaf_name, path):
    raise ValueError(
        f"block kind {kind!r} has no state leaf {leaf_name!r} (at {path})"
    )
