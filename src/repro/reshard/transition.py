"""Direct packed→packed fail/repair transitions for the NTP training stack
(DESIGN.md §3.3): re-express packed param/optimizer trees under a new
`FailurePlan` WITHOUT the dense host round-trip.

The retired path (`pack(unpack(...))`) rebuilt every weight densely from
replica 0 — O(model) host traffic per transition. This engine asks the
planner for each replica's comp→comp' `TransitionPlan` and applies it
directly on the packed buffers: stays are rank-local slot renames, and only
units whose rank changes travel, fused into ONE bucket per (replica, src,
dst) triple across EVERY unit leaf of every tree handed in (params and both
AdamW moments ride the same messages). The numpy twin's `TransferStats`
ledger records exactly what moved; a transition where nothing moves is a
plain reshape.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import nonuniform as nu
from repro.reshard import planner
from repro.reshard.twin import TransferStats, apply_plan
from repro.reshard.units import UnitSpec, ntp_unit_specs


def _leaf_key(path) -> str:
    return getattr(path[-1], "key", None)


def replica_transition_plans(
    k: int, old: nu.FailurePlan, new: nu.FailurePlan
) -> List[planner.TransitionPlan]:
    """Per-replica comp(old)→comp(new) plans for one k-unit weight family,
    at the packed buffer widths of the two whole-mesh `WeightPlan`s."""
    assert old.n1 == new.n1 and old.d == new.d, (old, new)
    old_wp, new_wp = nu.weight_plan(k, old), nu.weight_plan(k, new)
    return [
        planner.transition_plan(
            planner.comp_key(k, old.n1, old.replica_tp[d], old.n_sync),
            planner.comp_key(k, new.n1, new.replica_tp[d], new.n_sync),
            old_wp.buf,
            new_wp.buf,
        )
        for d in range(old.d)
    ]


def transition_trees(
    cfg,
    trees: Sequence[Dict],
    old: nu.FailurePlan,
    new: nu.FailurePlan,
    *,
    tag: Tuple[int, ...] = (),
) -> Tuple[List[Dict], TransferStats]:
    """Re-express packed trees (params, AdamW m/v, …) under ``new``.

    Every unit leaf across ALL ``trees`` joins the same per-(replica, src,
    dst) buckets, so the whole transition issues one fused send per rank
    pair per replica. Replicated leaves are copied through untouched (fresh
    buffers — step inputs are donated). Returns the transitioned trees and
    the fused `TransferStats`.
    """
    specs = ntp_unit_specs(cfg)
    stats = TransferStats()
    if new == old:
        return [jax.tree.map(lambda x: jnp.array(x, copy=True), t)
                for t in trees], stats

    plans = {s.k: replica_transition_plans(s.k, old, new)
             for s in set(specs.values())}
    n1, d_axis = old.n1, old.d

    # collect every unit leaf of every tree into its k-family group
    flats = [jax.tree_util.tree_flatten_with_path(t) for t in trees]
    groups: Dict[int, List[Tuple[int, int, np.ndarray]]] = {}
    for ti, (leaves, _) in enumerate(flats):
        for li, (path, leaf) in enumerate(leaves):
            spec = specs.get(_leaf_key(path))
            if spec is not None:
                groups.setdefault(spec.k, []).append(
                    (ti, li, np.asarray(leaf))
                )

    out_leaves = [[None] * len(leaves) for leaves, _ in flats]
    for k, members in groups.items():
        k_plans = plans[k]
        src_buf = k_plans[0].src_buf
        dst_buf = k_plans[0].dst_buf
        views, shapes = [], []
        for _, _, arr in members:
            assert arr.shape[1] == n1 * src_buf, (arr.shape, n1, src_buf)
            shapes.append(arr.shape[2:])
            views.append(arr.reshape(d_axis, n1, src_buf, -1))
        outs = [
            np.zeros((d_axis, n1, dst_buf) + v.shape[3:], v.dtype)
            for v in views
        ]
        for d in range(d_axis):
            # pair_tag=tag+(d,): buckets of DIFFERENT unit families targeting
            # the same (replica, src, dst) fuse into one physical message;
            # a caller-supplied tag (e.g. the pipeline stage) keeps sends of
            # DIFFERENT device groups from fusing, which would be unphysical
            moved = apply_plan(
                [v[d] for v in views], k_plans[d], stats=stats,
                pair_tag=tag + (d,),
            )
            for o, m in zip(outs, moved):
                o[d] = m
        for (ti, li, _), o, unit_shape in zip(members, outs, shapes):
            out_leaves[ti][li] = jnp.asarray(
                o.reshape(d_axis, n1 * dst_buf, *unit_shape)
            )

    # replicated leaves: fresh copies, no layout change
    for ti, (leaves, _) in enumerate(flats):
        for li, (path, leaf) in enumerate(leaves):
            if out_leaves[ti][li] is None:
                out_leaves[ti][li] = jnp.array(leaf, copy=True)

    return [
        jax.tree_util.tree_unflatten(treedef, out_leaves[ti])
        for ti, (_, treedef) in enumerate(flats)
    ], stats


def transition_params(
    cfg, packed: Dict, old: nu.FailurePlan, new: nu.FailurePlan
) -> Tuple[Dict, TransferStats]:
    """Single-tree convenience wrapper over `transition_trees`."""
    (tree,), stats = transition_trees(cfg, [packed], old, new)
    return tree, stats


def transition_staged_trees(
    cfg,
    trees: Sequence[Dict],
    old: "nu.StagedPlan",
    new: "nu.StagedPlan",
    *,
    copy_unchanged: bool = True,
) -> Tuple[List[Dict], TransferStats]:
    """Stage-local packed→packed transition for stage-partitioned trees
    (DESIGN.md §2.6): each pipeline stage's layer slice transitions under its
    OWN (old, new) stage-plan pair via `transition_trees` (ledger keys tagged
    by stage — sends of distinct device groups never fuse), so a failure in
    stage s moves only stage-s units: zero cross-stage traffic by
    construction. Top-level replicated leaves (embed/head/final_norm) never
    move. Returns the transitioned trees and the per-stage-merged
    `TransferStats`.

    ``copy_unchanged``: untouched stages (and replicated leaves) are
    re-materialized as fresh buffers by default, so results never alias
    caller-held trees (they may be donated to a jitted step). The live
    session passes False — it owns its trees exclusively, and passing
    unchanged stages through untouched makes a stage-local event truly
    zero-copy for every other stage."""
    from repro.configs.shapes import stage_boundaries

    assert old.pp == new.pp and old.n1 == new.n1 and old.d == new.d, (old, new)
    bounds = stage_boundaries(cfg.n_layers, old.pp)
    stats = TransferStats()
    outs = [
        {k: v for k, v in t.items() if k != "layers"} for t in trees
    ]
    if copy_unchanged:
        for o in outs:
            for k in o:
                o[k] = jax.tree.map(lambda x: jnp.array(x, copy=True), o[k])
    out_layers = [[None] * cfg.n_layers for _ in trees]
    for s in range(old.pp):
        lo, hi = bounds[s], bounds[s + 1]
        if not copy_unchanged and new.stages[s] == old.stages[s]:
            for ti, t in enumerate(trees):
                out_layers[ti][lo:hi] = list(t["layers"][lo:hi])
            continue
        subs = [{"layers": list(t["layers"][lo:hi])} for t in trees]
        moved, st = transition_trees(cfg, subs, old.stages[s], new.stages[s],
                                     tag=(s,))
        stats.merge(st)
        for ti, m in enumerate(moved):
            out_layers[ti][lo:hi] = m["layers"]
    for ti, o in enumerate(outs):
        o["layers"] = out_layers[ti]
    return outs, stats


def expected_transfer(
    cfg, old: nu.FailurePlan, new: nu.FailurePlan
) -> Dict[str, np.ndarray]:
    """Per-family (n, n) unit transfer matrices summed over replicas — the
    ground truth `transition_trees`' accounting must reproduce."""
    out: Dict[str, np.ndarray] = {}
    for name, spec in ntp_unit_specs(cfg).items():
        mats = [p.transfer for p in replica_transition_plans(spec.k, old, new)]
        out[name] = np.sum(mats, axis=0)
    return out
