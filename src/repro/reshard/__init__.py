"""repro.reshard — the unified partition-unit reshard engine (DESIGN.md
§3.3): one `UnitSpec` registry, one LRU-cached Algorithm-1 planner, one
numpy twin with transfer accounting, one jnp/Pallas collective route, and
the direct packed→packed transition that moves only the units the
`transfer_matrix` says move."""
from repro.reshard.engine import (  # noqa: F401
    gather_send_buckets, reshard_group, reshard_ranks, zero_pad_slot,
)
from repro.reshard.planner import (  # noqa: F401
    TransitionPlan, comp_key, layout, plan_cache_info, sync_key, tables,
    transition_plan,
)
from repro.reshard.state import (  # noqa: F401
    ShardedState, degree_layout, gather_state_leaf, shard_state_leaf,
    widened_slots,
)
from repro.reshard.transition import (  # noqa: F401
    expected_transfer, replica_transition_plans, transition_params,
    transition_trees,
)
from repro.reshard.twin import TransferStats, apply_plan, emulate_tables  # noqa: F401
from repro.reshard.units import (  # noqa: F401
    UnitSpec, arch_unit_counts, cache_unit_resolver, ntp_unit_specs,
    serve_unit_count,
)
