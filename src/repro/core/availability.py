"""Fleet availability vs failed fraction (paper §2.3, Fig. 3).

A scale-up domain is unusable at full TP if any of its GPUs failed; larger
domains amplify the same failed fraction. Analytic: E[clean] = (1-f)^S.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np


@dataclass(frozen=True)
class ClusterSpec:
    n_gpus: int = 32_768
    domain_size: int = 64          # scale-up domain == TP degree (Fig. 3)
    domains_per_replica: int = 8   # paper §6.1: 8 NVL domains per DP replica


def sample_failed_domains(
    n_gpus: int, domain_size: int, n_failed: int, rng, blast_radius: int = 1
):
    """Failed-GPU count per domain, failures uniform over the cluster.
    blast_radius b: one failure takes out b GPUs of its domain (§6.4)."""
    n_domains = n_gpus // domain_size
    idx = rng.choice(n_gpus, size=n_failed, replace=False)
    dom = idx // domain_size
    counts = np.bincount(dom, minlength=n_domains)
    if blast_radius > 1:
        counts = np.minimum(counts * blast_radius, domain_size)
    return counts


def availability_full_tp(
    spec: ClusterSpec, failed_fraction: float, *, samples: int = 100,
    blast_radius: int = 1, seed: int = 0,
):
    """Fraction of GPUs in fully-clean domains (= usable at TP=domain size).
    Returns (median, min) over samples — Fig. 3 plots median + worst shade."""
    rng = np.random.default_rng(seed)
    n_failed = int(round(failed_fraction * spec.n_gpus))
    n_domains = spec.n_gpus // spec.domain_size
    vals = []
    for _ in range(samples):
        counts = sample_failed_domains(
            spec.n_gpus, spec.domain_size, n_failed, rng, blast_radius
        )
        vals.append((counts == 0).sum() / n_domains)
    vals = np.array(vals)
    return float(np.median(vals)), float(vals.min())


def availability_analytic(domain_size: int, failed_fraction: float) -> float:
    return float((1.0 - failed_fraction) ** domain_size)
