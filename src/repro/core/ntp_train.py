"""NTP training: transformer layers computed under nonuniform tensor
parallelism inside shard_map, with NTP gradient synchronization.

This is the paper's prototype workload (§5.1: transformer layers, 2+ DP
replicas, one at reduced TP) expressed JAX-natively:

* every TP-sharded weight lives in a padded unit buffer (core/nonuniform.py);
  a degraded replica holds all units on its first n_r ranks, failed ranks
  hold zeros (algebraically inert — DESIGN.md §3.1);
* forward/backward is Megatron-TP: per-unit partial sums + psum('model');
* gradient sync is reshard → psum('data') → reshard (core/reshard.py), the
  paper's pre/post-sync resharding with a 1:1 sync-rank mapping;
* degraded replicas process a reduced local batch (sample masking — the
  paper's local-batch reduction; NTP-PW instead keeps full batch and
  power-boosts, which is modeled analytically in core/power.py).

Also provides the DP-DROP baseline (drop every replica containing a failure)
and a dense single-logical-copy reference for equivalence tests.
"""
from __future__ import annotations

import enum
import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import nonuniform as nu
from repro.optim.base import Optimizer, sgd


class Mode(enum.Enum):
    """Gradient-synchronization regime of one training job (DESIGN.md §2.2).

    UNIFORM  — every replica healthy; plain DP all-reduce.
    NTP      — nonuniform TP: reshard → psum('data') → reshard sync.
    DP_DROP  — baseline: replicas containing a failure contribute nothing.
    """

    UNIFORM = "uniform"
    NTP = "ntp"
    DP_DROP = "dpdrop"

    @classmethod
    def coerce(cls, v: Union["Mode", str]) -> "Mode":
        if isinstance(v, Mode):
            return v
        return cls(str(v).lower().replace("-", "").replace("_", ""))


@dataclass(frozen=True)
class NTPModelConfig:
    """The prototype transformer (paper §5.1 profiles hidden 6144/12288; we
    default smaller for CPU tests but the structure is identical)."""

    d_model: int = 256
    n_kv_groups: int = 8          # attention partition units
    q_per_kv: int = 2
    head_dim: int = 32
    d_ff: int = 1024
    unit_rows: int = 128          # MLP partition unit (TPU lane-aligned)
    n_layers: int = 2
    vocab: int = 512
    # MoE mode (DESIGN.md §4: the expert is the natural NTP unit — a lost
    # rank's experts are re-placed by Algorithm 1 like head/row units)
    n_experts: int = 0            # 0 = dense MLP
    top_k: int = 2

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def k_ff(self) -> int:
        if self.is_moe:
            return self.n_experts  # partition unit = whole expert
        assert self.d_ff % self.unit_rows == 0
        return self.d_ff // self.unit_rows


# ---------------------------------------------------------------------------
# canonical (dense) params + packing

def init_canonical(cfg: NTPModelConfig, key) -> Dict:
    ks = jax.random.split(key, cfg.n_layers + 1)
    d, g, q, h = cfg.d_model, cfg.n_kv_groups, cfg.q_per_kv, cfg.head_dim

    def layer(k):
        kk = jax.random.split(k, 7)
        s = d ** -0.5
        ffu = cfg.d_ff if cfg.is_moe else cfg.unit_rows  # rows per ffn unit
        p = {
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
            # unit-major layouts: (k_units, ...)
            "wq": jax.random.normal(kk[0], (g, d, q * h)) * s,
            "wk": jax.random.normal(kk[1], (g, d, h)) * s,
            "wv": jax.random.normal(kk[2], (g, d, h)) * s,
            "wo": jax.random.normal(kk[3], (g, q * h, d)) * (q * h) ** -0.5,
            "A": jax.random.normal(kk[4], (cfg.k_ff, d, ffu)) * s,
            "B": jax.random.normal(kk[5], (cfg.k_ff, ffu, d)) * cfg.d_ff ** -0.5,
        }
        if cfg.is_moe:
            p["router"] = jax.random.normal(kk[6], (d, cfg.n_experts)) * s
        return p

    return {
        "embed": jax.random.normal(ks[0], (cfg.vocab, d)) * 0.02,
        "head": jax.random.normal(ks[0], (d, cfg.vocab)) * d ** -0.5,
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": [layer(ks[i + 1]) for i in range(cfg.n_layers)],
    }


def _plans(cfg: NTPModelConfig, fplan: nu.FailurePlan):
    return {
        "attn": nu.weight_plan(cfg.n_kv_groups, fplan),
        "mlp": nu.weight_plan(cfg.k_ff, fplan),
    }


def _layer_plans(cfg: NTPModelConfig, fplan):
    """Per-layer {attn, mlp} weight plans. A plain `FailurePlan` (pp=1)
    gives every layer the same plan (the `lru_cache`d objects, so this is
    free); a `StagedPlan` gives each layer its OWN stage's plan — stage
    boundaries come from `configs.shapes.stage_boundaries` (DESIGN.md §2.6),
    so a layer's buffers are packed for exactly the scale-up domain that
    computes it."""
    staged = nu.as_staged(fplan)
    if staged.pp == 1:
        return [_plans(cfg, staged.stages[0])] * cfg.n_layers
    from repro.configs.shapes import layer_stages

    per_stage = [_plans(cfg, p) for p in staged.stages]
    return [per_stage[s] for s in layer_stages(cfg.n_layers, staged.pp)]


def _pack_unit(w, wp: nu.WeightPlan):
    """Canonical unit-major weight (k, *unit_shape) -> (D, n1*buf, *unit)."""
    k = w.shape[0]
    flat = np.asarray(w).reshape(k, 1, *w.shape[1:])  # unit dim = 1 group
    return jnp.asarray(nu.pack_global(flat.reshape(k, -1), wp, 1).reshape(
        wp.comp_slots.shape[0], -1, *w.shape[1:]
    ))


def _copy(x):
    # replicated leaves pass through pack/unpack unchanged: materialize a
    # fresh buffer so donated step inputs never alias caller-held trees
    return jnp.array(x, copy=True)


def pack_params(cfg: NTPModelConfig, canonical: Dict, fplan) -> Dict:
    """Canonical -> packed unit buffers under ``fplan`` (a `FailurePlan`, or
    a `StagedPlan` whose stages pack their own layers independently)."""
    lplans = _layer_plans(cfg, fplan)
    out = {
        "embed": _copy(canonical["embed"]),
        "head": _copy(canonical["head"]),
        "final_norm": _copy(canonical["final_norm"]),
        "layers": [],
    }
    for lp, plans in zip(canonical["layers"], lplans):
        out["layers"].append(
            {
                "ln1": _copy(lp["ln1"]),
                "ln2": _copy(lp["ln2"]),
                "wq": _pack_unit(lp["wq"], plans["attn"]),
                "wk": _pack_unit(lp["wk"], plans["attn"]),
                "wv": _pack_unit(lp["wv"], plans["attn"]),
                "wo": _pack_unit(lp["wo"], plans["attn"]),
                "A": _pack_unit(lp["A"], plans["mlp"]),
                "B": _pack_unit(lp["B"], plans["mlp"]),
                **({"router": _copy(lp["router"])} if "router" in lp else {}),
            }
        )
    return out


def unpack_params(cfg: NTPModelConfig, packed: Dict, fplan,
                  replica: int = 0) -> Dict:
    lplans = _layer_plans(cfg, fplan)

    def unp(w, wp):
        arr = np.asarray(w)
        flat = arr.reshape(arr.shape[0], arr.shape[1], 1, -1)  # explicit unit dim
        out = nu.unpack_global(flat, wp, 1, replica).reshape(wp.k, *arr.shape[2:])
        return jnp.asarray(out)

    out = {
        "embed": _copy(packed["embed"]),
        "head": _copy(packed["head"]),
        "final_norm": _copy(packed["final_norm"]),
        "layers": [],
    }
    for lp, plans in zip(packed["layers"], lplans):
        out["layers"].append(
            {
                "ln1": _copy(lp["ln1"]),
                "ln2": _copy(lp["ln2"]),
                "wq": unp(lp["wq"], plans["attn"]),
                "wk": unp(lp["wk"], plans["attn"]),
                "wv": unp(lp["wv"], plans["attn"]),
                "wo": unp(lp["wo"], plans["attn"]),
                "A": unp(lp["A"], plans["mlp"]),
                "B": unp(lp["B"], plans["mlp"]),
                **({"router": _copy(lp["router"])} if "router" in lp else {}),
            }
        )
    return out


def repack_params(cfg: NTPModelConfig, packed: Dict, old: nu.FailurePlan,
                  new: nu.FailurePlan, *, replica: int = 0) -> Dict:
    """Re-express a packed tree under a new failure plan (params or any tree
    mirroring the param structure, e.g. AdamW moments) via the DIRECT
    packed→packed transition (repro.reshard.transition): only units whose
    rank changes move, in one fused bucket per (replica, src, dst) pair —
    the dense ``pack(unpack(...))`` round-trip this replaced survives as the
    test oracle (tests/test_transition_engine.py). ``replica`` is retired
    (the direct route uses every replica's own buffers; after sync they all
    hold the same logical units) and kept only for signature compatibility.
    """
    del replica
    if new == old:
        return packed
    if isinstance(old, nu.StagedPlan) or isinstance(new, nu.StagedPlan):
        from repro.reshard.transition import transition_staged_trees

        (tree,), _ = transition_staged_trees(
            cfg, [packed], nu.as_staged(old), nu.as_staged(new)
        )
        return tree
    from repro.reshard.transition import transition_params

    tree, _ = transition_params(cfg, packed, old, new)
    return tree


# ---------------------------------------------------------------------------
# forward (local math inside shard_map; model_axis=None -> dense reference
# with no collectives — every unit on one logical rank)

def _psum(x, axis):
    return jax.lax.psum(x, axis) if axis is not None else x


def _rms(x, w):
    v = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(v + 1e-6) * w


def _attn_local(lp, h, cfg: NTPModelConfig, model_axis="model"):
    """h: (B,S,d) replicated; unit-buffered weights (U, d, ...)."""
    b, s, d = h.shape
    q = jnp.einsum("bsd,udr->bsur", h, lp["wq"])
    k = jnp.einsum("bsd,udh->bsuh", h, lp["wk"])
    v = jnp.einsum("bsd,udh->bsuh", h, lp["wv"])
    u = q.shape[2]
    q = q.reshape(b, s, u, cfg.q_per_kv, cfg.head_dim)
    scores = jnp.einsum("bsugh,btuh->bugst", q, k) * cfg.head_dim ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bugst,btuh->bsugh", probs.astype(h.dtype), v)
    out = out.reshape(b, s, u, cfg.q_per_kv * cfg.head_dim)
    y = jnp.einsum("bsur,urd->bsd", out, lp["wo"])
    return _psum(y, model_axis)


def _mlp_local(lp, h, model_axis="model"):
    a = jax.nn.gelu(jnp.einsum("bsd,udf->bsuf", h, lp["A"]))
    z = jnp.einsum("bsuf,ufd->bsd", a, lp["B"])
    return _psum(z, model_axis)


def _moe_local(lp, h, unit_ids, cfg: NTPModelConfig, model_axis="model"):
    """NTP-MoE ffn: partition unit = whole expert (DESIGN.md §4). Each rank
    computes its local expert units on all tokens (dense-masked prototype
    formulation), gated by the replicated router; zero-padded units are
    inert (gelu(0)·0) and their gates are masked.

    h: (B,S,d); unit_ids: (U,) global expert id per buffer slot, -1 = pad.
    """
    e, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("bsd,de->bse", h, lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    gates = (jax.nn.one_hot(idx, e, dtype=jnp.float32) * w[..., None]).sum(-2)

    a = jax.nn.gelu(jnp.einsum("bsd,udf->bsuf", h, lp["A"]))
    y = jnp.einsum("bsuf,ufd->bsud", a, lp["B"])
    gate_u = gates[..., jnp.clip(unit_ids, 0)] * (unit_ids >= 0)
    z = jnp.einsum("bsud,bsu->bsd", y, gate_u.astype(y.dtype))
    return _psum(z, model_axis)


def _forward_totals(cfg: NTPModelConfig, params, tokens, sample_mask,
                    moe_unit_ids=None, model_axis="model"):
    """One forward over all layers; returns the LOCAL (pre-data-psum)
    (token-loss total, token count) pair so callers can accumulate across
    microbatches before normalizing (the stage-sequential 1F1B emulation).

    The layer loop IS the pipeline: layers are visited in stage order and the
    residual stream `x` is the activation handed from stage s to stage s+1
    (in this emulation every rank plays each stage in turn, so the hand-off
    is a no-op data dependency rather than a ppermute; DESIGN.md §2.6).
    ``moe_unit_ids`` is either one (U,) slot-id array shared by every layer
    (uniform plan) or a per-layer sequence (staged plans differ by stage)."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    x = params["embed"][inp]
    per_layer = isinstance(moe_unit_ids, (list, tuple))
    for i, lp in enumerate(params["layers"]):
        uids = moe_unit_ids[i] if per_layer else moe_unit_ids
        x = x + _attn_local(lp, _rms(x, lp["ln1"]), cfg, model_axis)
        if cfg.is_moe:
            x = x + _moe_local(lp, _rms(x, lp["ln2"]), uids, cfg, model_axis)
        else:
            x = x + _mlp_local(lp, _rms(x, lp["ln2"]), model_axis)
    logits = jnp.einsum("bsd,dv->bsv", _rms(x, params["final_norm"]), params["head"])
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    tok_loss = (lse - ll) * sample_mask[:, None]
    return tok_loss.sum(), (sample_mask[:, None] * jnp.ones_like(tok_loss)).sum()


def _forward_local(cfg: NTPModelConfig, params, tokens, sample_mask,
                   moe_unit_ids=None, axes=("data", "model")):
    """tokens: (B, S+1) local; sample_mask: (B,) bool. Returns global loss.
    moe_unit_ids: (U,) this rank's global expert id per slot (MoE mode).
    axes=(None, None) runs the dense single-logical-copy reference."""
    data_axis, model_axis = axes
    total, count = _forward_totals(cfg, params, tokens, sample_mask,
                                   moe_unit_ids, model_axis)
    total = _psum(total, data_axis)
    count = _psum(count, data_axis)
    return total / jnp.maximum(count, 1.0)


def make_reference_loss(cfg: NTPModelConfig):
    """Dense single-logical-copy loss on CANONICAL params — no mesh, no
    collectives; the oracle for the NTP equivalence tests.

    loss(canonical_params, tokens (B,S+1), sample_mask (B,)) -> scalar.
    """
    uids = jnp.arange(cfg.k_ff, dtype=jnp.int32) if cfg.is_moe else None

    def loss(canonical, tokens, sample_mask):
        return _forward_local(cfg, canonical, tokens, sample_mask, uids,
                              axes=(None, None))

    return loss


# ---------------------------------------------------------------------------
# train step builder

UNIT_KEYS = ("wq", "wk", "wv", "wo", "A", "B")

_UNIT_SPEC = P("data", "model")
_REP_SPEC = P()


def _path_key(path):
    return path[-1].key if hasattr(path[-1], "key") else None


def _tree_specs(params):
    """shard_map specs: unit buffers split over (data, model), everything
    else replicated. Shared by the uniform and staged step builders."""
    return jax.tree_util.tree_map_with_path(
        lambda path, _: (
            _UNIT_SPEC if _path_key(path) in UNIT_KEYS else _REP_SPEC
        ),
        params,
    )


def _squeeze_unit(path, x):
    return x.reshape(x.shape[1:]) if _path_key(path) in UNIT_KEYS else x


def _norm_weights(params, d_axis: int):
    # packed unit buffers hold D identical copies of every synced unit
    # gradient: weight them 1/D so the global grad norm (clipping + the
    # grad_norm metric) equals the canonical-training norm exactly
    return jax.tree_util.tree_map_with_path(
        lambda path, _: 1.0 / d_axis if _path_key(path) in UNIT_KEYS else 1.0,
        params,
    )


def _validated_local_batches(local_batches, default_plan, mode, local_batch,
                             d_axis: int) -> np.ndarray:
    """The per-replica usable-sample table: the caller's override (bounds-
    checked) or the mode's default rule on ``default_plan``."""
    if local_batches is None:
        return default_local_batches(default_plan, mode, local_batch)
    lb = np.asarray(local_batches, dtype=np.int64)
    assert lb.shape == (d_axis,), (lb.shape, d_axis)
    assert ((lb >= 0) & (lb <= local_batch)).all(), (
        f"local_batches {lb} outside [0, {local_batch}]"
    )
    return lb


def default_local_batches(
    fplan, mode: Union[Mode, str], local_batch: int
) -> np.ndarray:
    """Per-replica usable local batch implied by the mode alone: UNIFORM
    keeps the full batch, NTP shrinks ∝ surviving TP (paper §3.1), DP_DROP
    zeroes every replica containing a failure. A `StagedPlan` is reduced by
    its slowest stage (`StagedPlan.effective`): 1F1B runs every microbatch
    through every stage, so the most-degraded stage gates the replica."""
    if isinstance(fplan, nu.StagedPlan):
        fplan = fplan.effective
    mode = Mode.coerce(mode)
    if mode is Mode.NTP:
        return fplan.local_batch_fraction(local_batch)
    if mode is Mode.DP_DROP:
        return np.array([
            local_batch if t == fplan.n1 else 0 for t in fplan.replica_tp
        ])
    return np.array([local_batch] * fplan.d)


def make_ntp_train_step(
    cfg: NTPModelConfig,
    fplan,
    mesh,
    *,
    mode: Union[Mode, str] = Mode.NTP,
    local_batch: int = 4,
    optimizer: Optional[Optimizer] = None,
    local_batches=None,
    microbatches: int = 1,
    overlap: bool = False,
):
    """Returns ``step`` with the same contract as train/steps.py:

        step(params, opt_state, batch) -> (params, opt_state, metrics)

    ``metrics`` carries at least ``loss`` and ``grad_norm``. The optimizer is
    pluggable (repro.optim.sgd / repro.optim.adamw) — the sync math, not the
    optimizer, is what NTP changes, so any elementwise update is legal on the
    packed buffers (every replica holds identical synced unit gradients and
    padded slots stay zero; DESIGN.md §2.3).

    ``local_batches``: optional per-replica usable-sample override (NTP-PW —
    a power-boosted degraded replica keeps MORE than its ∝-TP share, up to
    the full local batch; core/power.py + runtime/orchestrator.py decide).
    Defaults to the mode's own rule (`default_local_batches`).

    ``fplan`` may be a `StagedPlan` (nonuniform PP, DESIGN.md §2.6): each
    layer's gradients sync under its OWN stage's reshard plan (stage-local
    traffic). On a 2-axis ``(data, model)`` mesh the forward runs
    stage-sequentially over ``microbatches`` chunks (the 1F1B emulation;
    bubble cost is analytic — `core.perf_model`); on a staged
    ``(stage, data, model)`` mesh (`launch.mesh.make_staged_mesh`) the step
    is lowered onto per-stage submeshes with a `ppermute` activation
    hand-off, so bubble and cross-stage traffic are MEASURED
    (`core.pp_submesh`, DESIGN.md §2.8). A pp=1 `StagedPlan` (and
    ``microbatches=1``) takes the EXACT uniform-plan code path below, so the
    single-stage step is bit-identical to what this builder produced before
    stages existed.

    ``overlap=True`` switches to the overlapped, bucketed gradient sync
    (`core.overlap`, DESIGN.md §2.10): on the 2-axis mesh the step is
    rebuilt with a layer-chunked backward whose per-bucket sync issues
    while the previous chunk's backward runs (gradients match this
    builder's to f32 reassociation); on a staged submesh the pipeline step
    keeps its schedule and the sync collapses to one fused collective per
    (stage, plan-kind). ``overlap=False`` stays bit-identical to the
    pre-overlap step."""
    if isinstance(fplan, nu.StagedPlan) and fplan.pp == 1:
        fplan = fplan.stages[0]
    if isinstance(fplan, nu.StagedPlan) or microbatches > 1:
        from repro.core import pp_submesh

        if pp_submesh.is_staged_mesh(mesh):
            return pp_submesh.make_submesh_train_step(
                cfg, nu.as_staged(fplan), mesh, mode=mode,
                local_batch=local_batch, optimizer=optimizer,
                local_batches=local_batches, microbatches=microbatches,
                overlap=overlap,
            )
        if overlap:
            from repro.core import overlap as ov

            return ov.make_overlapped_train_step(
                cfg, nu.as_staged(fplan), mesh, mode=mode,
                local_batch=local_batch, optimizer=optimizer,
                local_batches=local_batches, microbatches=microbatches,
            )
        return _make_staged_train_step(
            cfg, nu.as_staged(fplan), mesh, mode=mode, local_batch=local_batch,
            optimizer=optimizer, local_batches=local_batches,
            microbatches=microbatches,
        )
    if overlap:
        from repro.core import overlap as ov

        return ov.make_overlapped_train_step(
            cfg, fplan, mesh, mode=mode, local_batch=local_batch,
            optimizer=optimizer, local_batches=local_batches,
            microbatches=microbatches,
        )
    mode = Mode.coerce(mode)
    optimizer = optimizer or sgd(1e-2)
    plans = _plans(cfg, fplan)
    d_axis = fplan.d
    lb = _validated_local_batches(local_batches, fplan, mode, local_batch,
                                  d_axis)
    lb_table = jnp.asarray(lb, jnp.int32)

    def global_loss(params, batch):
        """Scalar loss via shard_map; AD happens OUTSIDE the shard_map so
        jax seeds exactly one cotangent (grad-inside would seed one per rank
        and over-count every replicated path)."""
        specs = _tree_specs(params)

        moe_slots = (
            jnp.asarray(plans["mlp"].comp_slots, jnp.int32)
            if cfg.is_moe else None
        )

        def body(p_local, tokens_local):
            dd = jax.lax.axis_index("data")
            rr = jax.lax.axis_index("model")
            sample_mask = (
                jnp.arange(tokens_local.shape[0]) < lb_table[dd]
            ).astype(jnp.float32)
            p_sq = jax.tree_util.tree_map_with_path(_squeeze_unit, p_local)
            uids = moe_slots[dd, rr] if moe_slots is not None else None
            return _forward_local(cfg, p_sq, tokens_local, sample_mask, uids)

        return shard_map(
            body, mesh=mesh, in_specs=(specs, P("data", None)),
            out_specs=P(), check_vma=False,
        )(params, batch)

    # NTP gradient synchronization (paper §3.1/§4.1) on the global
    # unit-buffered grads: reshard -> psum('data') -> reshard, per weight —
    # the shared sequential body (core/overlap.make_sync_grads, a pp=1
    # StagedPlan degenerates to exactly the uniform-plan sync)
    from repro.core import overlap as ov

    sync_grads = ov.make_sync_grads(cfg, fplan, mesh, mode=mode)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(global_loss)(params, batch)
        grads = sync_grads(grads)
        new_params, new_state, metrics = optimizer.update(
            grads, opt_state, params, norm_weights=_norm_weights(grads, d_axis)
        )
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    step.overlap = False
    step.collectives = sync_grads.collectives
    step.grads_fn = jax.jit(jax.value_and_grad(global_loss))
    step.sync_fn = jax.jit(sync_grads)
    return step


def _make_staged_train_step(
    cfg: NTPModelConfig,
    staged: nu.StagedPlan,
    mesh,
    *,
    mode: Union[Mode, str] = Mode.NTP,
    local_batch: int = 4,
    optimizer: Optional[Optimizer] = None,
    local_batches=None,
    microbatches: int = 1,
):
    """Stage-aware twin of `make_ntp_train_step` (DESIGN.md §2.6): the model
    is partitioned into ``staged.pp`` contiguous layer groups (boundaries
    from `configs.shapes.stage_boundaries`), each packed and synced under its
    own stage `FailurePlan`. The forward is microbatched stage-sequential —
    every microbatch walks the stages in order, activations handed along the
    layer loop (on this emulation every rank plays each stage in turn); the
    1F1B bubble ((pp-1)/m) is accounted analytically by `core.perf_model`,
    not by wall clock. Gradient sync is STAGE-LOCAL: layer grads reshard
    under their own stage's plan, so a failure in stage s moves no bytes for
    any other stage."""
    from repro.configs.shapes import layer_stages

    mode = Mode.coerce(mode)
    optimizer = optimizer or sgd(1e-2)
    stage_of = layer_stages(cfg.n_layers, staged.pp)
    stage_plans = [_plans(cfg, p) for p in staged.stages]
    eff = staged.effective
    d_axis = staged.d

    if not 1 <= microbatches <= local_batch:
        raise ValueError(
            f"microbatches={microbatches} outside [1, local_batch={local_batch}]"
        )
    if local_batch % microbatches:
        raise ValueError(
            f"local_batch={local_batch} not divisible by "
            f"microbatches={microbatches}"
        )
    lb = _validated_local_batches(local_batches, eff, mode, local_batch,
                                  d_axis)
    lb_table = jnp.asarray(lb, jnp.int32)

    def global_loss(params, batch):
        """Scalar loss via shard_map (AD outside, exactly as the uniform
        builder). Microbatch totals/counts accumulate BEFORE the data psum
        and the final normalization, so the loss value is the same full-batch
        mean the dense reference computes."""
        specs = _tree_specs(params)

        moe_slots = (
            [jnp.asarray(sp["mlp"].comp_slots, jnp.int32) for sp in stage_plans]
            if cfg.is_moe else None
        )

        def body(p_local, tokens_local):
            dd = jax.lax.axis_index("data")
            rr = jax.lax.axis_index("model")
            p_sq = jax.tree_util.tree_map_with_path(_squeeze_unit, p_local)
            uids = (
                [moe_slots[s][dd, rr] for s in stage_of]
                if moe_slots is not None else None
            )
            mb = tokens_local.shape[0] // microbatches
            total = jnp.float32(0.0)
            count = jnp.float32(0.0)
            for j in range(microbatches):
                toks = tokens_local[j * mb:(j + 1) * mb]
                mask = (
                    (j * mb + jnp.arange(mb)) < lb_table[dd]
                ).astype(jnp.float32)
                t, c = _forward_totals(cfg, p_sq, toks, mask, uids)
                total = total + t
                count = count + c
            total = jax.lax.psum(total, "data")
            count = jax.lax.psum(count, "data")
            return total / jnp.maximum(count, 1.0)

        return shard_map(
            body, mesh=mesh, in_specs=(specs, P("data", None)),
            out_specs=P(), check_vma=False,
        )(params, batch)

    # Stage-local NTP gradient sync (shared body — core/overlap): each
    # layer's unit grads reshard → psum('data') → reshard under its OWN
    # stage's plan; a healthy stage takes the plain psum fast path even
    # while another stage is degraded (no cross-stage traffic — the sync
    # collective never mixes stages).
    from repro.core import overlap as ov

    sync_grads = ov.make_sync_grads(cfg, staged, mesh, mode=mode)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(global_loss)(params, batch)
        grads = sync_grads(grads)
        new_params, new_state, metrics = optimizer.update(
            grads, opt_state, params, norm_weights=_norm_weights(grads, d_axis)
        )
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    step.overlap = False
    step.collectives = sync_grads.collectives
    step.grads_fn = jax.jit(jax.value_and_grad(global_loss))
    step.sync_fn = jax.jit(sync_grads)
    return step
