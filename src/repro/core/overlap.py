"""Overlapped, bucketed gradient synchronization (DESIGN.md §2.10).

Every step builder in `core.ntp_train` runs the full backward, then
synchronizes ALL gradients serially — per-leaf reshard → psum('data') →
reshard — so DP sync time sits fully on the critical path. This module makes
the sync overlapped, bucketed, and shared:

* `make_sync_grads` is the ONE sync body the three step builders
  (`make_ntp_train_step`, `_make_staged_train_step`,
  `pp_submesh.make_submesh_train_step`) previously each carried a copy of.
  With ``bucketed=False`` it reproduces the per-leaf reshard→psum→reshard
  route bit-identically (a pp=1 `StagedPlan` degenerates to the uniform
  body: ``as_staged(plan).stages[0] is plan``). With ``bucketed=True`` every
  leaf that shares a (stage, WeightPlan) is fused into one flat buffer by
  the Pallas pack/unpack kernels (`kernels/bucket.py`) before the
  collective: one collective per (bucket, stage) instead of one per leaf.
  The fused buffer reshards under the per-leaf Algorithm-1 tables UNCHANGED
  — the tables index unit rows only, and column-concatenation commutes with
  the row gather/scatter and the elementwise psum, so the bucketed sync is
  bit-identical to the sequential one on healthy stages and exact to f32
  reassociation on degraded ones (tests/test_overlap.py proves both against
  the numpy reshard twin).

* `make_overlapped_train_step` is the AD-inside-shard_map twin of
  `make_ntp_train_step`: the backward is layer-chunked on the
  `stage_boundaries` ladder and each chunk's bucketed sync is ISSUED as
  soon as its grads exist, while the previous chunk's backward runs — a
  one-chunk-deep in-flight pipeline (issue chunk L, complete chunk L+1).
  On accelerators XLA's latency-hiding scheduler turns that program order
  into real comm/compute overlap; on the CPU emulation collectives are
  synchronous, so the measured win is the collective-count collapse — both
  are captured by `perf_model.overlap_iteration_time` (exposed_comm =
  max(0, sync − overlappable_compute), with a zero overlappable window on
  the emulation).

Gradient-correctness note (the reason the repo's builders keep AD OUTSIDE
shard_map): seeding a cotangent on every rank over-counts replicated paths,
because jax transposes ``psum`` to ``psum`` (the all-ones matrix is
symmetric). The overlapped builder seeds ``ct/n1`` per model rank instead;
the psum('model') transposes then restore the FULL cotangent on every
rank-local value (sum of n1 equal shares), unit-leaf grads come out exactly
as the AD-outside path's pre-sync grads (so psum('data') completes them),
and replicated-leaf grads sum to the true gradient under an explicit psum
over ('data', 'model'). Verified against the AD-outside step in
tests/test_overlap.py and the dist lifecycle runs.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.shapes import layer_stages, stage_boundaries
from repro.core import nonuniform as nu
from repro.core import ntp_train as nt
from repro.core import reshard as rs
from repro.kernels import ops
from repro.optim.base import Optimizer, sgd

_ATTN_KEYS = ("wq", "wk", "wv", "wo")
_REP_AXES = ("data", "model")

# pp=1 backward chunk ladder: enough chunks to pipeline sync behind
# backward, few enough that each bucket stays collective-worthy
DEFAULT_CHUNKS = 4


def coerce_overlap(v) -> bool:
    """CLI/config coercion: accepts bools and 'on'/'off' (+truthy spellings)."""
    if isinstance(v, bool):
        return v
    s = str(v).strip().lower()
    if s in ("on", "true", "1", "yes"):
        return True
    if s in ("off", "false", "0", "no"):
        return False
    raise ValueError(f"overlap must be on/off, got {v!r}")


def _layer_idx(path):
    # params["layers"][i][key] paths carry the layer index one hop up
    for e in reversed(path):
        if hasattr(e, "idx"):
            return e.idx
    return None


def chunk_ranges(n_layers: int, pp: int) -> Tuple[Tuple[int, int], ...]:
    """The backward chunk ladder: the stage boundaries at pp>1 (a chunk must
    never straddle stages — each bucket syncs under ONE stage plan), up to
    `DEFAULT_CHUNKS` even chunks at pp=1."""
    n = pp if pp > 1 else min(n_layers, DEFAULT_CHUNKS)
    b = stage_boundaries(n_layers, n)
    return tuple((b[i], b[i + 1]) for i in range(n) if b[i + 1] > b[i])


@dataclass(frozen=True)
class Bucket:
    """One fused sync group: every leaf in ``leaves`` shares ``stage``'s
    ``kind`` WeightPlan, so their row-aligned flats concatenate into one
    collective payload."""

    stage: int
    kind: str                            # "attn" | "mlp"
    leaves: Tuple[Tuple[int, str], ...]  # ((layer, key), ...)


def bucket_layout(cfg, staged, chunks=None) -> Tuple[Bucket, ...]:
    """One bucket per (chunk, plan-kind), chunk ladder defaulting to the
    stages, in REVERSED chunk order: the backward produces the last chunk's
    grads first, so its buckets issue first."""
    staged = nu.as_staged(staged)
    stage_of = layer_stages(cfg.n_layers, staged.pp)
    if chunks is None:
        chunks = chunk_ranges(cfg.n_layers, staged.pp) if staged.pp > 1 else \
            ((0, cfg.n_layers),)
    out = []
    for lo, hi in reversed(tuple(chunks)):
        s = stage_of[lo]
        assert all(stage_of[l] == s for l in range(lo, hi)), \
            f"chunk [{lo},{hi}) straddles stages {stage_of}"
        for kind, keys in (("attn", _ATTN_KEYS), ("mlp", ("A", "B"))):
            out.append(Bucket(s, kind,
                              tuple((l, k) for l in range(lo, hi)
                                    for k in keys)))
    return tuple(out)


def sync_collectives(cfg, staged, mode, *, bucketed: bool,
                     chunks=None) -> int:
    """Static count of collective launches one gradient sync performs — the
    quantity bucketing collapses. A degraded sync is reshard → psum →
    reshard = 3 launches (all_to_all + psum + all_to_all); a healthy one is
    a single psum. Replicated leaves are free on the AD-outside path (the
    shard_map transpose carries them) and one fused psum per chunk plus the
    tail/embed psums on the overlapped path — those are counted by
    `make_overlapped_train_step` itself."""
    staged = nu.as_staged(staged)
    mode = nt.Mode.coerce(mode)
    stage_of = layer_stages(cfg.n_layers, staged.pp)

    def cost(stage):
        degraded = mode is nt.Mode.NTP and not staged.stages[stage].healthy
        return 3 if degraded else 1

    if bucketed:
        return sum(cost(b.stage) for b in bucket_layout(cfg, staged, chunks))
    return sum(cost(stage_of[l]) * len(nt.UNIT_KEYS)
               for l in range(cfg.n_layers))


def _bucket_syncers(staged, stage_plans, mode):
    """(issue, complete) closures over one staged plan. ``issue`` packs a
    bucket's squeezed (u, *unit) leaf grads into one (u, ΣE) flat and — on a
    degraded stage — launches the pre-sync reshard (the first collective of
    the Algorithm-1 chain); ``complete`` runs the psum('data') (+ post
    reshard) and unpacks. The split is what the overlapped backward
    interleaves: issue chunk L while chunk L-1's backward runs."""

    def issue(bucket: Bucket, arrs):
        shapes = tuple(a.shape for a in arrs)
        flats = [a.reshape(a.shape[0], -1) for a in arrs]
        widths = tuple(f.shape[1] for f in flats)
        flat = ops.bucket_pack(flats)
        splan = staged.stages[bucket.stage]
        degraded = mode is nt.Mode.NTP and not splan.healthy
        if degraded:
            wp = stage_plans[bucket.stage][bucket.kind]
            flat = rs.reshard(flat.reshape(flat.shape[0], 1, -1), wp.pre)
        return (bucket, flat, widths, shapes, degraded)

    def complete(state):
        bucket, flat, widths, shapes, degraded = state
        flat = jax.lax.psum(flat, "data")
        if degraded:
            wp = stage_plans[bucket.stage][bucket.kind]
            flat = rs.reshard(flat, wp.post)
            flat = flat.reshape(flat.shape[0], -1)
        parts = ops.bucket_unpack(flat, widths)
        return [p.reshape(s) for p, s in zip(parts, shapes)]

    return issue, complete


def make_sync_grads(cfg, staged, mesh, *, mode, bucketed: bool = False):
    """The ONE gradient-sync body shared by every step builder (DESIGN.md
    §2.10): stage-local NTP sync on the packed per-layer grads tree — each
    layer's unit grads reshard → psum('data') → reshard under its OWN
    stage's plan; a healthy stage takes the plain psum fast path even while
    another stage is degraded (no cross-stage traffic — the sync collective
    never mixes stages). Replicated leaves pass through: on the AD-outside
    path the shard_map transpose already summed every rank's contribution.

    ``bucketed=False`` is the sequential per-leaf oracle, bit-identical to
    the bodies it replaced. ``bucketed=True`` fuses each (stage, plan-kind)
    group into one flat payload via `kernels/bucket.py` before the
    collective. The returned callable carries ``.collectives`` (static
    launch count) and ``.bucketed``."""
    staged = nu.as_staged(staged)
    mode = nt.Mode.coerce(mode)
    stage_of = layer_stages(cfg.n_layers, staged.pp)
    stage_plans = [nt._plans(cfg, p) for p in staged.stages]

    if not bucketed:
        def sync_grads(grads):
            specs = nt._tree_specs(grads)

            def body(g_local):
                def sync(path, g):
                    key = nt._path_key(path)
                    if key not in nt.UNIT_KEYS:
                        return g
                    s = stage_of[_layer_idx(path)]
                    sp = stage_plans[s]
                    wp = sp["attn"] if key in _ATTN_KEYS else sp["mlp"]
                    splan = staged.stages[s]
                    g = g.reshape(g.shape[1:])  # drop replica dim
                    orig_shape = g.shape
                    if mode is nt.Mode.NTP and not splan.healthy:
                        g = rs.ntp_sync_gradient(
                            g.reshape(g.shape[0], 1, -1), wp)
                        g = g.reshape(orig_shape)
                    else:
                        g = jax.lax.psum(g, "data")
                    return g.reshape((1,) + g.shape)

                return jax.tree_util.tree_map_with_path(sync, g_local)

            return shard_map(
                body, mesh=mesh, in_specs=(specs,), out_specs=specs,
                check_vma=False,
            )(grads)

        sync_grads.collectives = sync_collectives(cfg, staged, mode,
                                                  bucketed=False)
        sync_grads.bucketed = False
        return sync_grads

    buckets = bucket_layout(cfg, staged)
    issue, complete = _bucket_syncers(staged, stage_plans, mode)

    def sync_grads(grads):
        specs = nt._tree_specs(grads)

        def body(g_local):
            layers = [dict(lp) for lp in g_local["layers"]]
            # issue every bucket (pack + pre-reshard), then complete — the
            # standalone sync has no backward to hide behind, so give XLA
            # the full window of in-flight collectives at once
            states = [
                issue(b, [layers[l][k].reshape(layers[l][k].shape[1:])
                          for l, k in b.leaves])
                for b in buckets
            ]
            for b, st in zip(buckets, states):
                for (l, k), g in zip(b.leaves, complete(st)):
                    layers[l][k] = g.reshape((1,) + g.shape)
            out = dict(g_local)
            out["layers"] = layers
            return out

        return shard_map(
            body, mesh=mesh, in_specs=(specs,), out_specs=specs,
            check_vma=False,
        )(grads)

    sync_grads.collectives = sync_collectives(cfg, staged, mode,
                                              bucketed=True)
    sync_grads.bucketed = True
    return sync_grads


def make_overlapped_train_step(
    cfg,
    fplan,
    mesh,
    *,
    mode=nt.Mode.NTP,
    local_batch: int = 4,
    optimizer: Optional[Optimizer] = None,
    local_batches=None,
    microbatches: int = 1,
):
    """Overlapped twin of `make_ntp_train_step` on the 2-axis (data, model)
    mesh: same contract (``step(params, opt_state, batch) -> (params,
    opt_state, metrics)``), same loss, gradients equal to the AD-outside
    step's to f32 reassociation — but the backward is layer-chunked on the
    `stage_boundaries` ladder with each chunk's bucketed sync issued while
    the next (earlier) chunk's backward runs.

    Differences from the sequential step, all documented in DESIGN.md §2.10:

    * AD runs INSIDE the shard_map (per-rank vjp seeded ``ct/n1``; see the
      module docstring) so sync can interleave with backward compute.
    * ``microbatches`` is validated exactly as the staged builder's but the
      forward runs the full local batch in ONE chunked pass — the sample
      mask is microbatch-invariant, so the loss matches the microbatched
      emulation to f32 summation order.
    * Replicated leaves sync through one fused psum('data','model') bucket
      per chunk (ln1/ln2/router) plus a tail bucket (final_norm + head,
      issued FIRST — the backward's earliest grads — completed last) and
      the embed psum (the backward's last grad).

    The returned step carries probes for the bench/profile paths:
    ``.overlap`` (True), ``.chunks``, ``.collectives`` (static unit-bucket
    launch count), ``.grads_fn`` (jit'd loss+synced-grads, non-donating)
    and ``.sync_fn`` / ``.sync_off_fn`` (standalone bucketed / sequential
    sync of a grads tree, for timing sync in isolation)."""
    staged = nu.as_staged(fplan)
    mode = nt.Mode.coerce(mode)
    optimizer = optimizer or sgd(1e-2)
    pp = staged.pp
    d_axis = staged.d
    n1 = staged.n1
    stage_of = layer_stages(cfg.n_layers, pp)
    stage_plans = [nt._plans(cfg, p) for p in staged.stages]

    if not 1 <= microbatches <= local_batch:
        raise ValueError(
            f"microbatches={microbatches} outside [1, local_batch={local_batch}]"
        )
    if local_batch % microbatches:
        raise ValueError(
            f"local_batch={local_batch} not divisible by "
            f"microbatches={microbatches}"
        )
    lb = nt._validated_local_batches(local_batches, staged.effective, mode,
                                     local_batch, d_axis)
    lb_table = jnp.asarray(lb, jnp.int32)

    chunks = chunk_ranges(cfg.n_layers, pp)
    per_chunk_buckets = []
    for lo, hi in chunks:
        per_chunk_buckets.append(tuple(
            Bucket(stage_of[lo], kind,
                   tuple((l, k) for l in range(lo, hi) for k in keys))
            for kind, keys in (("attn", _ATTN_KEYS), ("mlp", ("A", "B")))
        ))
    issue, complete = _bucket_syncers(staged, stage_plans, mode)

    moe_slots = (
        [jnp.asarray(sp["mlp"].comp_slots, jnp.int32) for sp in stage_plans]
        if cfg.is_moe else None
    )
    rep_keys = ("ln1", "ln2") + (("router",) if cfg.is_moe else ())

    def _pack_rep(arrs):
        """Fuse replicated-leaf grads (any shapes, rows=1 flats) into one
        psum payload; returns in-flight (flat, widths, shapes)."""
        shapes = tuple(a.shape for a in arrs)
        flats = [a.reshape(1, -1) for a in arrs]
        widths = tuple(f.shape[1] for f in flats)
        return (ops.bucket_pack(flats), widths, shapes)

    def _complete_rep(state):
        flat, widths, shapes = state
        flat = jax.lax.psum(flat, _REP_AXES)
        parts = ops.bucket_unpack(flat, widths)
        return [p.reshape(s) for p, s in zip(parts, shapes)]

    def loss_and_grads(params, batch):
        specs = nt._tree_specs(params)

        def body(p_local, tokens_local):
            dd = jax.lax.axis_index("data")
            rr = jax.lax.axis_index("model")
            p_sq = jax.tree_util.tree_map_with_path(nt._squeeze_unit, p_local)
            uids = (
                [moe_slots[s][dd, rr] for s in stage_of]
                if moe_slots is not None else [None] * cfg.n_layers
            )
            B = tokens_local.shape[0]
            inp, tgt = tokens_local[:, :-1], tokens_local[:, 1:]
            mask = (jnp.arange(B) < lb_table[dd]).astype(jnp.float32)

            # ---- forward: embed | chunk_0 … chunk_{C-1} | tail, each under
            # its own vjp so the backward can interleave sync with compute.
            # The chunk bodies are the residual loop of `_forward_totals`,
            # verbatim (same primitives → same math).
            x, vjp_embed = jax.vjp(lambda emb: emb[inp], p_sq["embed"])
            vjps = []
            for lo, hi in chunks:
                cparams = [p_sq["layers"][l] for l in range(lo, hi)]

                def chunk_fn(cps, xx, lo=lo, hi=hi):
                    for l, lp in zip(range(lo, hi), cps):
                        xx = xx + nt._attn_local(lp, nt._rms(xx, lp["ln1"]),
                                                 cfg)
                        if cfg.is_moe:
                            xx = xx + nt._moe_local(
                                lp, nt._rms(xx, lp["ln2"]), uids[l], cfg)
                        else:
                            xx = xx + nt._mlp_local(lp, nt._rms(xx, lp["ln2"]))
                    return xx

                x, vjp = jax.vjp(chunk_fn, cparams, x)
                vjps.append(vjp)

            def tail_fn(fn_w, head_w, xx):
                logits = jnp.einsum("bsd,dv->bsv", nt._rms(xx, fn_w), head_w)
                logits = logits.astype(jnp.float32)
                lse = jax.nn.logsumexp(logits, axis=-1)
                ll = jnp.take_along_axis(logits, tgt[..., None],
                                         axis=-1)[..., 0]
                return ((lse - ll) * mask[:, None]).sum()

            total, vjp_tail = jax.vjp(tail_fn, p_sq["final_norm"],
                                      p_sq["head"], x)
            count = (mask[:, None]
                     * jnp.ones((B, tgt.shape[1]), jnp.float32)).sum()
            total_g = jax.lax.psum(total, "data")
            count_g = jax.lax.psum(count, "data")
            denom = jnp.maximum(count_g, 1.0)
            loss = total_g / denom

            # ---- backward with a one-chunk-deep in-flight sync pipeline
            seed = (1.0 / denom) / n1
            g_fn, g_head, dx = vjp_tail(seed)
            # tail rep bucket: the earliest grads — issue first, complete
            # last (the widest overlap window of the step)
            tail_state = _pack_rep([g_fn, g_head])

            def _issue_chunk(ci, g_layers):
                lo, hi = chunks[ci]
                unit_states = [
                    issue(b, [g_layers[l - lo][k] for l, k in b.leaves])
                    for b in per_chunk_buckets[ci]
                ]
                rep_state = _pack_rep([g_layers[j][k]
                                       for j in range(hi - lo)
                                       for k in rep_keys])
                return (unit_states, rep_state)

            def _complete_chunk(ci, state):
                lo, hi = chunks[ci]
                unit_states, rep_state = state
                out = [dict() for _ in range(hi - lo)]
                for b, st in zip(per_chunk_buckets[ci], unit_states):
                    for (l, k), g in zip(b.leaves, complete(st)):
                        out[l - lo][k] = g
                parts = iter(_complete_rep(rep_state))
                for j in range(hi - lo):
                    for k in rep_keys:
                        out[j][k] = next(parts)
                return out

            synced = [None] * len(chunks)
            pending = None
            for ci in reversed(range(len(chunks))):
                g_layers, dx = vjps[ci](dx)
                if pending is not None:
                    pj, st = pending
                    synced[pj] = _complete_chunk(pj, st)
                pending = (ci, _issue_chunk(ci, g_layers))
            pj, st = pending
            synced[pj] = _complete_chunk(pj, st)
            (g_embed,) = vjp_embed(dx)
            g_embed = jax.lax.psum(g_embed, _REP_AXES)
            g_fn, g_head = _complete_rep(tail_state)

            out_layers = []
            for ci, (lo, hi) in enumerate(chunks):
                for l in range(lo, hi):
                    ld = synced[ci][l - lo]
                    out_layers.append({
                        k: (ld[k].reshape((1,) + ld[k].shape)
                            if k in nt.UNIT_KEYS else ld[k])
                        for k in p_sq["layers"][l]
                    })
            grads = {"embed": g_embed, "head": g_head, "final_norm": g_fn,
                     "layers": out_layers}
            return loss, grads

        return shard_map(
            body, mesh=mesh, in_specs=(specs, P("data", None)),
            out_specs=(P(), specs), check_vma=False,
        )(params, batch)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt_state, batch):
        loss, grads = loss_and_grads(params, batch)
        new_params, new_state, metrics = optimizer.update(
            grads, opt_state, params,
            norm_weights=nt._norm_weights(grads, d_axis),
        )
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    step.overlap = True
    step.chunks = chunks
    step.collectives = sync_collectives(cfg, staged, mode, bucketed=True,
                                        chunks=chunks)
    step.grads_fn = jax.jit(loss_and_grads)
    step.sync_fn = jax.jit(
        make_sync_grads(cfg, staged, mesh, mode=mode, bucketed=True))
    step.sync_off_fn = jax.jit(
        make_sync_grads(cfg, staged, mesh, mode=mode, bucketed=False))
    return step
