"""Dynamic power allocation model (paper §3.2, Table 1, §6.4).

The proposed rack re-allocates failed GPUs' power budget to the survivors
(up to +30% TDP). We model achievable speedup as perf ∝ power^β in the boost
region, with β calibrated to Table 1:

  TP30-PW: full batch at 1.15× power, rel iter .978  -> needs 32/30 ≈ 1.067
           speedup:  β = ln(32/30)/ln(1.15) ≈ 0.46
  TP28-PW: full batch at 1.30× power, rel iter .999  -> needs 32/28 ≈ 1.143
           speedup:  β = ln(32/28)/ln(1.30) ≈ 0.51

We use β = 0.5 (sqrt law — consistent with published DVFS curves in the
boost region) and cap boost at 1.3× per §3.2.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

BETA = 0.5
MAX_BOOST = 1.30


@dataclass(frozen=True)
class PowerModel:
    beta: float = BETA
    max_boost: float = MAX_BOOST

    def speedup(self, power_mult: float) -> float:
        """Per-GPU compute speedup at power_mult × TDP."""
        return float(np.clip(power_mult, 0.1, self.max_boost) ** self.beta)

    def required_power(self, tp_reduced: int, tp_full: int) -> float:
        """Power multiplier for a TP-reduced domain to match healthy-domain
        iteration time at FULL local batch (NTP-PW). Work per surviving GPU
        scales by tp_full/tp_reduced."""
        need = tp_full / tp_reduced
        return float(need ** (1.0 / self.beta))

    def required_power_for_speedup(self, speedup: float) -> float:
        return float(max(speedup, 1.0) ** (1.0 / self.beta))

    def can_boost(self, tp_reduced: int, tp_full: int) -> bool:
        # §3.2: repurposing the failed GPUs' budget gives (tp_full/tp_reduced)×
        # power available; the rack supports at most max_boost.
        avail = min(tp_full / tp_reduced, self.max_boost)
        return self.required_power(tp_reduced, tp_full) <= avail + 1e-9

    def perf_per_watt_penalty(self, power_mult: float) -> float:
        """§6.4: perf/W loss of running boosted (negative = worse)."""
        return self.speedup(power_mult) / power_mult - 1.0
