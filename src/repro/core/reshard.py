"""The NTP reshard collective (paper §3.1/§4.1) as jax.lax primitives.

Runs inside shard_map over ('data', 'model'): a layout change of the local
unit buffer via one tiled all-to-all over the scale-up-domain axis — the
paper's "resharding is done within TP groups … without bottlenecking the
synchronization", mapped NVLink→ICI. Gradient sync is then
reshard(pre) → psum('data') → reshard(post), with the pre-reshard emitted
per-bucket so XLA's latency-hiding scheduler can overlap it with the
remaining backward computation (the paper's backward-hook overlap, §4.1).

The tables come from the ONE Algorithm-1 planner (`repro.reshard.planner`,
via `core.nonuniform.weight_plan`); this module is the planner's SPMD
route — `repro.reshard.engine.reshard_ranks` is the host-unrolled twin
with identical table semantics.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.nonuniform import StackedTables, WeightPlan
from repro.reshard.engine import zero_pad_slot as _zero_pad_row


def reshard(
    x,
    tables: StackedTables,
    *,
    model_axis: str = "model",
    data_axis: Optional[str] = "data",
):
    """Convert a local unit buffer (U, unit, ...) between layouts.

    Table selection is rank-dependent (every rank runs this same SPMD
    program): replica = axis_index(data_axis), rank = axis_index(model_axis).
    """
    d = jax.lax.axis_index(data_axis) if data_axis is not None else 0
    r = jax.lax.axis_index(model_axis)
    send = tables.send_idx[d, r]        # (n, s_max)
    recv_slots = tables.recv_idx[d, r]  # (n, s_max)
    stay = tables.stay_idx[d, r]        # (U,)

    xp = _zero_pad_row(x)               # (U+1, ...) — index U gathers zeros
    send_buf = xp[send]                 # (n, s_max, unit, ...)
    recv = jax.lax.all_to_all(send_buf, model_axis, 0, 0, tiled=True)

    out = xp[stay]                      # stays (pad slots -> zeros)
    flat = recv.reshape((-1,) + recv.shape[2:])
    out = out.at[recv_slots.reshape(-1)].set(flat, mode="drop")
    return out


def ntp_sync_gradient(
    g,
    wp: WeightPlan,
    *,
    model_axis: str = "model",
    data_axis: str = "data",
    scale=None,
):
    """Full NTP gradient synchronization for one unit-buffered gradient
    (U, unit, ...): pre-sync reshard → all-reduce over DP → post-sync reshard.

    ``scale``: optional per-replica contribution weight (e.g. local-batch
    fraction); the caller divides by the total weight afterwards or bakes it
    into ``scale``.
    """
    if scale is not None:
        g = g * scale
    g_sync = reshard(g, wp.pre, model_axis=model_axis, data_axis=data_axis)
    g_sync = jax.lax.psum(g_sync, data_axis)
    return reshard(g_sync, wp.post, model_axis=model_axis, data_axis=data_axis)


def uniform_sync_gradient(g, *, data_axis: str = "data", scale=None):
    """Healthy-path baseline: plain DP all-reduce (what NTP degenerates to
    when every replica is healthy — pre/post reshard become identity)."""
    if scale is not None:
        g = g * scale
    return jax.lax.psum(g, data_axis)
