"""Failure-trace simulation calibrated to the Llama-3 training report
(paper §2.3, Fig. 4): Poisson failure arrivals, 78% hardware failures with
multi-day recovery, 22% software failures with ~3h recovery.

`simulate_events` is the one sampler: every failure carries its (domain, gpu)
placement and its recovery time, so the same trace drives both the Fig.-4
counts (`simulate_trace`, a thin wrapper) and the live lifecycle replay
(`runtime.orchestrator.TraceRunner`, which needs to know WHERE each failure
lands and when it heals).

Beyond binary fail/repair, the sampler covers the production degradation
taxonomy (DESIGN.md §2.11): STRAGGLER onsets (a domain computing ``slowdown``×
slower until cleared), LINK degradations (scale-up bandwidth at ``bw_frac``
of spec) and SDC suspicion windows — each a Poisson stream at a configurable
multiple of the base failure rate, sampled from its OWN seeded RNG stream
(``default_rng([seed, kind])``) so a mixed trace never perturbs the legacy
binary stream: at the default zero mix rates the output is bit-identical to
the pre-taxonomy sampler.

Scanning is vectorized over the arrival-sorted arrays (merged per-GPU
intervals + searchsorted difference arrays), so generating AND scanning a
100k-GPU multi-week trace takes seconds — `benchmarks/bench_cluster.py`
measures it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

# Llama-3 (arXiv:2407.21783): 419 unexpected interruptions over 54 days of
# pre-training on 16,384 H100s  ->  per-GPU-hour rate:
LLAMA3_RATE_PER_GPU_HOUR = 419 / (54 * 24 * 16_384)   # ≈ 1.98e-5
HW_FRACTION = 0.78

# TraceEvents.kind codes (degrade/clear pairs share one interval: onset at
# start_h, cleared at end_h)
KIND_FAILURE, KIND_STRAGGLER, KIND_LINK, KIND_SDC = 0, 1, 2, 3
KIND_NAMES = ("failure", "straggler", "link", "sdc")


@dataclass(frozen=True)
class FailureTraceConfig:
    n_gpus: int = 32_768
    domain_size: int = 64               # scale-up domain width for placement
    days: float = 15.0
    rate_per_gpu_hour: float = LLAMA3_RATE_PER_GPU_HOUR
    rate_multiplier: float = 1.0        # §2.3 studies 3× spikes
    hw_fraction: float = HW_FRACTION
    hw_recovery_days: Tuple[float, float] = (3.0, 5.0)  # uniform in range
    sw_recovery_hours: float = 3.0
    dt_hours: float = 1.0
    seed: int = 0
    # --- degradation mix (DESIGN.md §2.11): Poisson onset rates as
    # multiples of the (multiplied) base failure rate; 0.0 = pure binary
    # trace, bit-identical to the pre-taxonomy sampler.
    straggler_rate_mult: float = 0.0
    link_rate_mult: float = 0.0
    sdc_rate_mult: float = 0.0
    straggler_slowdown: Tuple[float, float] = (1.2, 3.0)    # uniform in range
    straggler_duration_hours: Tuple[float, float] = (0.5, 6.0)
    link_bw_frac: Tuple[float, float] = (0.2, 0.9)
    link_duration_hours: Tuple[float, float] = (1.0, 12.0)
    sdc_clear_hours: float = 1.0      # suspicion window until cleared

    @property
    def n_domains(self) -> int:
        return self.n_gpus // self.domain_size

    @property
    def mixed(self) -> bool:
        """True when any degradation kind has a nonzero rate."""
        return bool(
            self.straggler_rate_mult or self.link_rate_mult
            or self.sdc_rate_mult
        )


_MIX_KEYS = {
    "straggler": "straggler_rate_mult",
    "link": "link_rate_mult",
    "sdc": "sdc_rate_mult",
}


def parse_trace_mix(spec: str) -> dict:
    """Parse the launchers' ``--trace-mix straggler=R,link=R,sdc=R`` spec
    into `FailureTraceConfig` kwargs (any subset of kinds, each a rate
    multiplier vs the binary failure base rate). Raises ValueError naming
    the offending segment — the launchers surface it via ``ap.error``."""
    out: dict = {}
    parts = [p.strip() for p in spec.split(",") if p.strip()]
    if not parts:
        raise ValueError(f"empty spec {spec!r} (want KIND=RATE[,KIND=RATE...])")
    for part in parts:
        kind, sep, rate_s = part.partition("=")
        kind = kind.strip()
        key = _MIX_KEYS.get(kind)
        if not sep or key is None:
            raise ValueError(
                f"bad segment {part!r} (want KIND=RATE with KIND one of "
                f"{', '.join(_MIX_KEYS)})")
        if key in out:
            raise ValueError(f"duplicate kind {kind!r}")
        try:
            rate = float(rate_s)
        except ValueError:
            raise ValueError(f"bad rate {rate_s!r} for {kind!r}") from None
        if rate < 0:
            raise ValueError(f"negative rate {rate} for {kind!r}")
        out[key] = rate
    return out


@dataclass(frozen=True)
class TraceEvents:
    """Per-event health trace: each entry is one GPU entering a degraded
    state at ``start_h`` and leaving it at ``end_h`` (hours since the start
    of the observation window; lead-in events have start_h < 0 but may still
    be live inside the window). Sorted by start time.

    ``kind`` (KIND_* codes) distinguishes hard failures from degradations;
    ``severity`` carries the straggler slow factor / link bandwidth fraction
    (0.0 for failure and SDC entries). Both default to ``None`` for
    pre-taxonomy call sites — an all-failure trace."""

    start_h: np.ndarray     # (E,) onset
    end_h: np.ndarray       # (E,) recovery/clear completion
    gpu: np.ndarray         # (E,) global gpu id
    domain: np.ndarray      # (E,) gpu // domain_size
    is_hw: np.ndarray       # (E,) bool — hardware vs software failure
    kind: Optional[np.ndarray] = None       # (E,) int8 KIND_* (None = fail)
    severity: Optional[np.ndarray] = None   # (E,) float per-kind severity

    @property
    def n_events(self) -> int:
        return len(self.start_h)

    def kind_mask(self, kind: int) -> np.ndarray:
        """(E,) bool mask of events of ``kind`` (``kind=None`` arrays are
        all-failure traces)."""
        if self.kind is None:
            return np.full(self.n_events, kind == KIND_FAILURE)
        return self.kind == kind

    def _merged_failures(self):
        """Per-GPU MERGED failure intervals ``(start, end, domain)``.

        Arrivals are sampled independently of GPU state, so a second failure
        can land on a GPU whose first interval is still open — one dead GPU,
        two live intervals. Counting intervals would double-count it;
        merging each GPU's overlapping/touching half-open intervals first
        makes interval counts equal DISTINCT-GPU counts at every t (the
        PR-5 semantics), while staying a pure vectorized pass: lexsort by
        (gpu, start), a segmented running-max of ``end`` via the per-group
        offset trick, and a run-id cut wherever a start exceeds the running
        end. Cached — the trace is frozen."""
        cached = getattr(self, "_merged_cache", None)
        if cached is not None:
            return cached
        fail = self.kind_mask(KIND_FAILURE)
        g = np.asarray(self.gpu)[fail]
        s = np.asarray(self.start_h, dtype=np.float64)[fail]
        e = np.asarray(self.end_h, dtype=np.float64)[fail]
        if len(g) == 0:
            out = (np.empty(0), np.empty(0), np.empty(0, dtype=np.int64))
        else:
            order = np.lexsort((s, g))
            g, s, e = g[order], s[order], e[order]
            # segmented cummax of e within each gpu group: add a per-group
            # offset that dominates the value range, so the global cummax
            # "resets" at every group boundary
            span = float(e.max() - min(e.min(), s.min())) + 1.0
            run_end = np.maximum.accumulate(e + g * span) - g * span
            new_run = np.empty(len(g), dtype=bool)
            new_run[0] = True
            # cut at every gpu change; within a gpu, an interval overlapping
            # or touching the running end merges ([a,b) ∪ [b,c) = [a,c))
            new_run[1:] = (g[1:] != g[:-1]) | (s[1:] > run_end[:-1])
            starts_idx = np.flatnonzero(new_run)
            ms = s[starts_idx]
            me = np.maximum.reduceat(e, starts_idx)
            mg = g[starts_idx]
            out = (ms, me, mg)
        object.__setattr__(self, "_merged_cache", out)
        return out

    def failed_counts_at(self, t_h: float, n_domains: int,
                         domain_size: int) -> np.ndarray:
        """Concurrently-failed DISTINCT GPUs per domain at time ``t_h``
        (single-time view of `failed_counts_scan`; the clip stays as a belt
        against malformed traces)."""
        return self.failed_counts_scan(
            np.asarray([t_h], dtype=np.float64), n_domains, domain_size
        )[0]

    def failed_counts_scan(self, t_h: np.ndarray, n_domains: int,
                           domain_size: int) -> np.ndarray:
        """(T, n_domains) concurrently-failed DISTINCT GPU counts at every
        (ascending) sample time — the vectorized arrival-sorted scan: each
        merged interval contributes +1/−1 at its searchsorted entry/exit
        sample, a cumulative sum folds the difference array. O(E log E +
        E log T + T·D) total instead of O(E·T); bit-identical to the
        per-time unique/bincount scan (merged per-GPU intervals cover
        exactly the instants where ≥1 of that GPU's intervals is live)."""
        t = np.asarray(t_h, dtype=np.float64)
        assert t.ndim == 1 and (len(t) < 2 or bool(np.all(np.diff(t) >= 0))), (
            "failed_counts_scan needs ascending sample times"
        )
        ms, me, mg = self._merged_failures()
        dom = mg // domain_size
        # bincount-compatible width: a trailing partial domain (n_gpus not
        # divisible by domain_size) widens the output past n_domains
        width = max(n_domains, int(dom.max()) + 1 if len(dom) else 0)
        # live at t ⇔ start <= t < end: enters at the first sample >= start,
        # exits at the first sample >= end
        i0 = np.searchsorted(t, ms, side="left")
        i1 = np.searchsorted(t, me, side="left")
        diff = np.zeros((len(t) + 1, width), dtype=np.int64)
        np.add.at(diff, (i0, dom), 1)
        np.add.at(diff, (i1, dom), -1)
        counts = np.cumsum(diff[:-1], axis=0)
        return np.minimum(counts, domain_size)

    def live_total_scan(self, t_h: np.ndarray,
                        kind: int = KIND_FAILURE) -> np.ndarray:
        """(T,) live-INTERVAL counts of ``kind`` at every ascending sample
        time (raw intervals, not distinct GPUs — the Fig.-4 counting). Same
        difference-array scan as `failed_counts_scan`."""
        t = np.asarray(t_h, dtype=np.float64)
        mask = self.kind_mask(kind)
        s = np.asarray(self.start_h, dtype=np.float64)[mask]
        e = np.asarray(self.end_h, dtype=np.float64)[mask]
        diff = np.zeros(len(t) + 1, dtype=np.int64)
        np.add.at(diff, np.searchsorted(t, s, side="left"), 1)
        np.add.at(diff, np.searchsorted(t, e, side="left"), -1)
        return np.cumsum(diff[:-1])


def simulate_events(cfg: FailureTraceConfig) -> TraceEvents:
    """Sample the per-event trace. Memoryless arrivals across the fleet; each
    failure picks an (independent) recovery time by type and lands on a
    uniformly-random GPU. Warm-started by simulating a lead-in window longer
    than the max recovery so the trace starts in steady state.

    The count draws reuse `simulate_trace`'s historical RNG stream (placement
    is drawn after them), so aggregate counts are bit-identical to the old
    count-only sampler at the same seed. Each degradation kind samples from
    its OWN ``default_rng([seed, kind])`` stream, so mixing degradations in
    (or out) never perturbs the binary failure trace.
    """
    rng = np.random.default_rng(cfg.seed)
    lead_h = cfg.hw_recovery_days[1] * 24.0
    total_h = cfg.days * 24.0 + lead_h
    rate_per_hour = cfg.rate_per_gpu_hour * cfg.rate_multiplier * cfg.n_gpus

    n_events = rng.poisson(rate_per_hour * total_h)
    starts = rng.uniform(0.0, total_h, n_events)
    is_hw = rng.random(n_events) < cfg.hw_fraction
    rec = np.where(
        is_hw,
        rng.uniform(*cfg.hw_recovery_days, n_events) * 24.0,
        cfg.sw_recovery_hours,
    )
    gpu = rng.integers(0, cfg.n_gpus, n_events)
    kind = np.zeros(n_events, dtype=np.int8)
    severity = np.zeros(n_events, dtype=np.float64)

    def _degradation_stream(code, rate_mult, duration_h, sev_range):
        if not rate_mult:
            return None
        krng = np.random.default_rng([cfg.seed, code])
        n = krng.poisson(rate_per_hour * rate_mult * total_h)
        s = krng.uniform(0.0, total_h, n)
        if isinstance(duration_h, tuple):
            dur = krng.uniform(*duration_h, n)
        else:
            dur = np.full(n, float(duration_h))
        g = krng.integers(0, cfg.n_gpus, n)
        sev = (
            krng.uniform(*sev_range, n) if sev_range is not None
            else np.zeros(n)
        )
        return (s, s + dur, g, np.zeros(n, dtype=bool),
                np.full(n, code, dtype=np.int8), sev)

    streams = [(starts, starts + rec, gpu, is_hw, kind, severity)]
    for args in (
        (KIND_STRAGGLER, cfg.straggler_rate_mult,
         cfg.straggler_duration_hours, cfg.straggler_slowdown),
        (KIND_LINK, cfg.link_rate_mult, cfg.link_duration_hours,
         cfg.link_bw_frac),
        (KIND_SDC, cfg.sdc_rate_mult, cfg.sdc_clear_hours, None),
    ):
        st = _degradation_stream(*args)
        if st is not None:
            streams.append(st)
    starts, ends, gpu, is_hw, kind, severity = (
        np.concatenate(cols) for cols in zip(*streams)
    )

    order = np.argsort(starts, kind="stable")
    return TraceEvents(
        start_h=starts[order] - lead_h,
        end_h=ends[order] - lead_h,
        gpu=gpu[order],
        domain=gpu[order] // cfg.domain_size,
        is_hw=is_hw[order],
        kind=kind[order] if cfg.mixed else None,
        severity=severity[order] if cfg.mixed else None,
    )


def simulate_trace(cfg: FailureTraceConfig):
    """Returns (t_hours, n_failed) arrays — concurrently-failed GPU counts
    (live failure intervals; degradation kinds are excluded — the Fig.-4
    analytics are about absence). Count-only view over `simulate_events`,
    scanned with the vectorized difference-array pass (bit-identical to the
    old O(events·samples) broadcast)."""
    ev = simulate_events(cfg)
    t = np.arange(0.0, cfg.days * 24.0, cfg.dt_hours)
    return t, ev.live_total_scan(t)


def fraction_time_above(cfg: FailureTraceConfig, frac_threshold: float) -> float:
    """Fig. 4's headline: fraction of time with > threshold of GPUs failed."""
    _, n_failed = simulate_trace(cfg)
    return float((n_failed / cfg.n_gpus > frac_threshold).mean())


def steady_state_failed_fraction(cfg: FailureTraceConfig) -> float:
    """Little's-law mean: rate × mean recovery."""
    mean_rec_h = (
        cfg.hw_fraction * np.mean(cfg.hw_recovery_days) * 24.0
        + (1 - cfg.hw_fraction) * cfg.sw_recovery_hours
    )
    return cfg.rate_per_gpu_hour * cfg.rate_multiplier * mean_rec_h
