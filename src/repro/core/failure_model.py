"""Failure-trace simulation calibrated to the Llama-3 training report
(paper §2.3, Fig. 4): Poisson failure arrivals, 78% hardware failures with
multi-day recovery, 22% software failures with ~3h recovery.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

# Llama-3 (arXiv:2407.21783): 419 unexpected interruptions over 54 days of
# pre-training on 16,384 H100s  ->  per-GPU-hour rate:
LLAMA3_RATE_PER_GPU_HOUR = 419 / (54 * 24 * 16_384)   # ≈ 1.98e-5
HW_FRACTION = 0.78


@dataclass(frozen=True)
class FailureTraceConfig:
    n_gpus: int = 32_768
    days: float = 15.0
    rate_per_gpu_hour: float = LLAMA3_RATE_PER_GPU_HOUR
    rate_multiplier: float = 1.0        # §2.3 studies 3× spikes
    hw_fraction: float = HW_FRACTION
    hw_recovery_days: Tuple[float, float] = (3.0, 5.0)  # uniform in range
    sw_recovery_hours: float = 3.0
    dt_hours: float = 1.0
    seed: int = 0


def simulate_trace(cfg: FailureTraceConfig):
    """Returns (t_hours, n_failed) arrays — concurrently-failed GPU counts.

    Memoryless arrivals across the fleet; each failure picks an (independent)
    recovery time by type. Warm-started by simulating a lead-in window longer
    than the max recovery so the trace starts in steady state.
    """
    rng = np.random.default_rng(cfg.seed)
    lead_h = cfg.hw_recovery_days[1] * 24.0
    total_h = cfg.days * 24.0 + lead_h
    rate_per_hour = cfg.rate_per_gpu_hour * cfg.rate_multiplier * cfg.n_gpus

    n_events = rng.poisson(rate_per_hour * total_h)
    starts = rng.uniform(0.0, total_h, n_events)
    is_hw = rng.random(n_events) < cfg.hw_fraction
    rec = np.where(
        is_hw,
        rng.uniform(*cfg.hw_recovery_days, n_events) * 24.0,
        cfg.sw_recovery_hours,
    )
    ends = starts + rec

    t = np.arange(lead_h, total_h, cfg.dt_hours)
    # concurrent failures at each sample time
    n_failed = (
        (starts[None, :] <= t[:, None]) & (ends[None, :] > t[:, None])
    ).sum(axis=1)
    return t - lead_h, n_failed


def fraction_time_above(cfg: FailureTraceConfig, frac_threshold: float) -> float:
    """Fig. 4's headline: fraction of time with > threshold of GPUs failed."""
    _, n_failed = simulate_trace(cfg)
    return float((n_failed / cfg.n_gpus > frac_threshold).mean())


def steady_state_failed_fraction(cfg: FailureTraceConfig) -> float:
    """Little's-law mean: rate × mean recovery."""
    mean_rec_h = (
        cfg.hw_fraction * np.mean(cfg.hw_recovery_days) * 24.0
        + (1 - cfg.hw_fraction) * cfg.sw_recovery_hours
    )
    return cfg.rate_per_gpu_hour * cfg.rate_multiplier * mean_rec_h
