"""Failure-trace simulation calibrated to the Llama-3 training report
(paper §2.3, Fig. 4): Poisson failure arrivals, 78% hardware failures with
multi-day recovery, 22% software failures with ~3h recovery.

`simulate_events` is the one sampler: every failure carries its (domain, gpu)
placement and its recovery time, so the same trace drives both the Fig.-4
counts (`simulate_trace`, a thin wrapper) and the live lifecycle replay
(`runtime.orchestrator.TraceRunner`, which needs to know WHERE each failure
lands and when it heals).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

# Llama-3 (arXiv:2407.21783): 419 unexpected interruptions over 54 days of
# pre-training on 16,384 H100s  ->  per-GPU-hour rate:
LLAMA3_RATE_PER_GPU_HOUR = 419 / (54 * 24 * 16_384)   # ≈ 1.98e-5
HW_FRACTION = 0.78


@dataclass(frozen=True)
class FailureTraceConfig:
    n_gpus: int = 32_768
    domain_size: int = 64               # scale-up domain width for placement
    days: float = 15.0
    rate_per_gpu_hour: float = LLAMA3_RATE_PER_GPU_HOUR
    rate_multiplier: float = 1.0        # §2.3 studies 3× spikes
    hw_fraction: float = HW_FRACTION
    hw_recovery_days: Tuple[float, float] = (3.0, 5.0)  # uniform in range
    sw_recovery_hours: float = 3.0
    dt_hours: float = 1.0
    seed: int = 0

    @property
    def n_domains(self) -> int:
        return self.n_gpus // self.domain_size


@dataclass(frozen=True)
class TraceEvents:
    """Per-event failure trace: each entry is one GPU failing at ``start_h``
    and coming back at ``end_h`` (hours since the start of the observation
    window; lead-in events have start_h < 0 but may still be down inside the
    window). Sorted by start time."""

    start_h: np.ndarray     # (E,) failure onset
    end_h: np.ndarray       # (E,) recovery completion
    gpu: np.ndarray         # (E,) global gpu id
    domain: np.ndarray      # (E,) gpu // domain_size
    is_hw: np.ndarray       # (E,) bool — hardware vs software failure

    @property
    def n_events(self) -> int:
        return len(self.start_h)

    def failed_counts_at(self, t_h: float, n_domains: int,
                         domain_size: int) -> np.ndarray:
        """Concurrently-failed GPUs per domain at time ``t_h``.

        Counts DISTINCT live-failed GPU ids: arrivals are sampled
        independently of GPU state, so a second failure can land on a GPU
        whose first failure interval is still open — one dead GPU, two live
        intervals. Counting intervals would double-count it (and could push
        a domain past its size); counting distinct ids cannot, but the clip
        stays as a belt against malformed traces."""
        live = (self.start_h <= t_h) & (self.end_h > t_h)
        uniq = np.unique(self.gpu[live])
        counts = np.bincount(uniq // domain_size, minlength=n_domains)
        return np.minimum(counts, domain_size)


def simulate_events(cfg: FailureTraceConfig) -> TraceEvents:
    """Sample the per-event trace. Memoryless arrivals across the fleet; each
    failure picks an (independent) recovery time by type and lands on a
    uniformly-random GPU. Warm-started by simulating a lead-in window longer
    than the max recovery so the trace starts in steady state.

    The count draws reuse `simulate_trace`'s historical RNG stream (placement
    is drawn after them), so aggregate counts are bit-identical to the old
    count-only sampler at the same seed.
    """
    rng = np.random.default_rng(cfg.seed)
    lead_h = cfg.hw_recovery_days[1] * 24.0
    total_h = cfg.days * 24.0 + lead_h
    rate_per_hour = cfg.rate_per_gpu_hour * cfg.rate_multiplier * cfg.n_gpus

    n_events = rng.poisson(rate_per_hour * total_h)
    starts = rng.uniform(0.0, total_h, n_events)
    is_hw = rng.random(n_events) < cfg.hw_fraction
    rec = np.where(
        is_hw,
        rng.uniform(*cfg.hw_recovery_days, n_events) * 24.0,
        cfg.sw_recovery_hours,
    )
    gpu = rng.integers(0, cfg.n_gpus, n_events)

    order = np.argsort(starts, kind="stable")
    return TraceEvents(
        start_h=starts[order] - lead_h,
        end_h=(starts + rec)[order] - lead_h,
        gpu=gpu[order],
        domain=gpu[order] // cfg.domain_size,
        is_hw=is_hw[order],
    )


def simulate_trace(cfg: FailureTraceConfig):
    """Returns (t_hours, n_failed) arrays — concurrently-failed GPU counts.
    Count-only view over `simulate_events` (kept for the Fig.-4 analytics)."""
    ev = simulate_events(cfg)
    t = np.arange(0.0, cfg.days * 24.0, cfg.dt_hours)
    n_failed = (
        (ev.start_h[None, :] <= t[:, None]) & (ev.end_h[None, :] > t[:, None])
    ).sum(axis=1)
    return t, n_failed


def fraction_time_above(cfg: FailureTraceConfig, frac_threshold: float) -> float:
    """Fig. 4's headline: fraction of time with > threshold of GPUs failed."""
    _, n_failed = simulate_trace(cfg)
    return float((n_failed / cfg.n_gpus > frac_threshold).mean())


def steady_state_failed_fraction(cfg: FailureTraceConfig) -> float:
    """Little's-law mean: rate × mean recovery."""
    mean_rec_h = (
        cfg.hw_fraction * np.mean(cfg.hw_recovery_days) * 24.0
        + (1 - cfg.hw_fraction) * cfg.sw_recovery_hours
    )
    return cfg.rate_per_gpu_hour * cfg.rate_multiplier * mean_rec_h
