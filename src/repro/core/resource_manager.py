"""Resource manager (paper §3.3): on restart, pack partially-failed scale-up
domains into as few DP replicas as possible (lowest ranks), fall back to
spare domains only when needed, and account GPUs donated to low-priority
jobs from healthy-but-bottlenecked domains.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np


@dataclass(frozen=True)
class ReplicaAssignment:
    domain_ids: np.ndarray      # (domains_per_replica,)
    failed: np.ndarray          # failed GPUs per domain
    tp: int                     # operating TP degree (min healthy in replica)
    donated_gpus: int           # healthy GPUs idled by the min-TP constraint


def apply_spares(failed_counts: np.ndarray, n_spare_domains: int) -> np.ndarray:
    """Replace the most-failed domains with spares (clean)."""
    out = failed_counts.copy()
    if n_spare_domains <= 0:
        return out
    worst = np.argsort(-out)[:n_spare_domains]
    out[worst[out[worst] > 0]] = 0
    return out


def pack_replicas(
    failed_counts: Sequence[int], domain_size: int, domains_per_replica: int
) -> List[ReplicaAssignment]:
    """Sort domains most-failed-first and group consecutively: failures are
    concentrated into the lowest-rank replicas (paper: "unhealthy racks are
    packed together by being placed in the lowest ranks")."""
    failed = np.asarray(failed_counts)
    order = np.argsort(-failed, kind="stable")
    n_rep = len(failed) // domains_per_replica
    out = []
    for r in range(n_rep):
        ids = order[r * domains_per_replica : (r + 1) * domains_per_replica]
        f = failed[ids]
        tp = int(domain_size - f.max())
        donated = int(((domain_size - f) - tp).clip(min=0).sum()) if tp > 0 else int((domain_size - f).sum())
        out.append(ReplicaAssignment(ids, f, max(tp, 0), donated))
    return out


def packing_stats(assignments: List[ReplicaAssignment], domain_size: int) -> dict:
    affected = [a for a in assignments if a.tp < domain_size]
    return {
        "replicas": len(assignments),
        "affected_replicas": len(affected),
        "dead_replicas": sum(1 for a in assignments if a.tp == 0),
        "donated_gpus": sum(a.donated_gpus for a in assignments),
    }
