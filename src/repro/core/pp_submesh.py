"""Measured multi-mesh pipeline parallelism: the staged train step lowered
onto per-stage submeshes (DESIGN.md §2.8).

`core.ntp_train._make_staged_train_step` EMULATES nonuniform PP: every rank
plays each stage in turn, the stage hand-off is a no-op data dependency, and
the 1F1B bubble is charged analytically by `core.perf_model`. This module is
the real thing on a ``("stage", "data", "model")`` mesh
(`launch.mesh.make_staged_mesh`): stage ``s``'s layer weights live ONLY on
the stage-``s`` device slice, the boundary activation is handed to stage
``s+1`` by `jax.lax.ppermute` over the ``stage`` axis, and the pipeline
schedule is executed tick by tick — ``microbatches + pp - 1`` ticks per
step, every stage computing one microbatch per tick (warmup/drain ticks idle
the edges, which IS the bubble; autodiff through the ppermute runs the
reverse pipeline for the backward). Bubble, overlap, and cross-stage
transfer bytes are therefore MEASURED quantities (`launch.profile
--measure`, `benchmarks.bench_hotpath`); the analytic
`perf_model.staged_iteration_time` number survives as the cross-check.

Geometry: per-stage unit buffers are padded to the widest stage's buffer
(pad slots hold zeros — algebraically inert, the same invariant the
single-mesh packing relies on) and stages owning fewer layers pad with
all-zero layers (exact identities: the residual stream passes through a
zero-weight block unchanged). The stacked tree is built INSIDE the jitted
step from the session's canonical packed tree, so transitions, checkpoints
and the stage-local gradient sync are byte-for-byte the ones the emulated
path uses — `make_ntp_train_step` dispatches here purely on the mesh.
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import nonuniform as nu
from repro.core import ntp_train as nt
from repro.optim.base import Optimizer, sgd

STAGE_AXES = ("stage", "data", "model")

_ATTN_KEYS = ("wq", "wk", "wv", "wo")


def is_staged_mesh(mesh) -> bool:
    """True for a mesh carrying a ``stage`` axis with >= 2 stages (the
    submesh execution path); the 2-axis ``(data, model)`` mesh — or a
    degenerate stage axis of size 1 — takes the stage-sequential emulation."""
    if mesh is None:
        return False
    names = getattr(mesh, "axis_names", ())
    return "stage" in names and mesh.shape["stage"] > 1


def validate_staged_mesh(mesh, pp: int) -> None:
    names = tuple(getattr(mesh, "axis_names", ()))
    if tuple(sorted(names)) != tuple(sorted(STAGE_AXES)):
        raise ValueError(
            f"submesh PP needs mesh axes {STAGE_AXES}, got {names} "
            "(build one with launch.mesh.make_staged_mesh)"
        )
    if mesh.shape["stage"] != pp:
        raise ValueError(
            f"mesh stage axis has {mesh.shape['stage']} stages but the plan "
            f"has pp={pp}; one submesh per pipeline stage is the contract"
        )


def _stage_geometry(cfg: nt.NTPModelConfig, staged: nu.StagedPlan):
    """Static packing geometry shared by the stacker and the step: per-stage
    layer ids, per-stage weight plans, padded layer count and per-kind
    padded buffer widths (max over stages)."""
    from repro.configs.shapes import stage_boundaries

    bounds = stage_boundaries(cfg.n_layers, staged.pp)
    stage_layers = [tuple(range(bounds[s], bounds[s + 1]))
                    for s in range(staged.pp)]
    l_max = max(len(ls) for ls in stage_layers)
    plans = [nt._plans(cfg, p) for p in staged.stages]
    u_max = {
        kind: max(sp[kind].comp_slots.shape[2] for sp in plans)
        for kind in ("attn", "mlp")
    }
    return stage_layers, l_max, plans, u_max


def _pad_unit_leaf(w, u_have: int, u_want: int):
    """(D, n1*u_have, *unit) -> (D, n1*u_want, *unit): zero pad slots laid
    per model rank, so the padded leaf still splits evenly over ``model``."""
    if u_have == u_want:
        return w
    d, cols = w.shape[0], w.shape[1]
    n1 = cols // u_have
    r = w.reshape(d, n1, u_have, *w.shape[2:])
    pad = [(0, 0), (0, 0), (0, u_want - u_have)] + [(0, 0)] * (w.ndim - 2)
    return jnp.pad(r, pad).reshape(d, n1 * u_want, *w.shape[2:])


def stack_staged_params(cfg: nt.NTPModelConfig, packed, staged: nu.StagedPlan):
    """Packed per-layer tree -> stage-stacked tree + shard_map in_specs.

    Unit leaves become ``(pp, l_max, D, n1*u_max, *unit)`` sharded
    ``P("stage", None, "data", "model")`` — each stage's device slice holds
    exactly its own layers. Replicated layer leaves (ln1/ln2/router) become
    ``(pp, l_max, ...)`` kept REPLICATED (``P()``): the step body
    dynamic-indexes its own stage's row by ``axis_index("stage")``. (They are
    small, and sharding them ``P("stage")`` trips a jax 0.4.x partitioner bug
    when this stacking is traced inside the same jit as the shard_map — the
    stage slices arrive corrupted; the 4-axis unit spec is unaffected.)
    Pure jnp reshape/pad/stack — differentiable, so the step's grads flow
    straight back to the packed tree this was built from (pad-slot and
    pad-layer cotangents are dropped by the transpose of the pad)."""
    stage_layers, l_max, plans, u_max = _stage_geometry(cfg, staged)

    rep_keys = ["ln1", "ln2"] + (["router"] if cfg.is_moe else [])
    unit, rep = {}, {}
    for key in nt.UNIT_KEYS:
        kind = "attn" if key in _ATTN_KEYS else "mlp"
        per_stage = []
        for s, layers in enumerate(stage_layers):
            u_s = plans[s][kind].comp_slots.shape[2]
            stk = jnp.stack([
                _pad_unit_leaf(packed["layers"][li][key], u_s, u_max[kind])
                for li in layers
            ])
            if stk.shape[0] < l_max:
                pad = [(0, l_max - stk.shape[0])] + [(0, 0)] * (stk.ndim - 1)
                stk = jnp.pad(stk, pad)
            per_stage.append(stk)
        unit[key] = jnp.stack(per_stage)
    for key in rep_keys:
        per_stage = []
        for layers in stage_layers:
            stk = jnp.stack([packed["layers"][li][key] for li in layers])
            if stk.shape[0] < l_max:
                pad = [(0, l_max - stk.shape[0])] + [(0, 0)] * (stk.ndim - 1)
                stk = jnp.pad(stk, pad)
            per_stage.append(stk)
        rep[key] = jnp.stack(per_stage)

    stacked = {
        "embed": packed["embed"],
        "head": packed["head"],
        "final_norm": packed["final_norm"],
        "unit": unit,
        "rep": rep,
    }
    specs = {
        "embed": P(),
        "head": P(),
        "final_norm": P(),
        "unit": {k: P("stage", None, "data", "model") for k in unit},
        "rep": {k: P() for k in rep},
    }
    return stacked, specs


def handoff_accounting(cfg: nt.NTPModelConfig, staged: nu.StagedPlan, *,
                       local_batch: int, microbatches: int, seq_len: int):
    """Static ledger of one step's cross-stage activation traffic: what the
    ppermute hand-off moves, counted from the actual transfer shapes (the
    backward pipeline moves the same volume of cotangents in reverse).
    tests/dist/session_submesh_pp.py checks this table against the submesh
    step's recorded attribute; `bench_hotpath` records it next to the
    reshard transition ledger in BENCH_train.json."""
    pp, d = staged.pp, staged.d
    mb = local_batch // microbatches
    ticks = microbatches + pp - 1
    act_bytes = 4 * mb * seq_len * cfg.d_model        # f32 (mb, S, d_model)
    senders = (pp - 1) * d * staged.n1                # rank columns that send
    sends = ticks - 1                                 # no send on final tick
    fwd = act_bytes * senders * sends
    return {
        "act_bytes_per_send": act_bytes,
        "sender_ranks": senders,
        "ticks": ticks,
        "sends_per_boundary": sends,
        "fwd_bytes": fwd,
        "bwd_bytes": fwd,                              # ppermute transpose
        "total_bytes": 2 * fwd,
    }


def make_submesh_train_step(
    cfg: nt.NTPModelConfig,
    staged: nu.StagedPlan,
    mesh,
    *,
    mode: Union[nt.Mode, str] = nt.Mode.NTP,
    local_batch: int = 4,
    optimizer: Optional[Optimizer] = None,
    local_batches=None,
    microbatches: int = 1,
    overlap: bool = False,
):
    """The measured twin of `_make_staged_train_step` on a staged mesh.

    Same contract — ``step(params, opt_state, batch) -> (params, opt_state,
    metrics)`` on the session's PACKED trees — and the same stage-local
    gradient sync and optimizer update; only the loss graph differs: the
    forward/backward is the real tick-scheduled pipeline over the ``stage``
    axis. pp=2 output matches the emulated step to f32 tolerance
    (tests/dist/session_submesh_pp.py). The returned step carries
    ``step.ticks``, ``step.submesh`` and ``step.handoff_for(seq_len)`` (the
    cross-stage byte table; ``step.handoff`` binds on first call).

    ``overlap=True`` keeps the pipeline schedule and swaps the per-leaf
    sync for the BUCKETED one (core/overlap, DESIGN.md §2.10): one fused
    collective per (stage, plan-kind) instead of one per (layer, leaf).
    The data-axis sync collectives then sit in the same program region as
    the backward drain ticks, where XLA's scheduler can hide them behind
    the stages that are still draining (healthy buckets stay bit-identical
    to the sequential sync; degraded ones are exact to f32 reassociation)."""
    from repro.configs.shapes import layer_stages

    validate_staged_mesh(mesh, staged.pp)
    mode = nt.Mode.coerce(mode)
    optimizer = optimizer or sgd(1e-2)
    pp, d_axis = staged.pp, staged.d
    _, l_max, stage_plans, u_max = _stage_geometry(cfg, staged)
    stage_of = layer_stages(cfg.n_layers, pp)
    eff = staged.effective

    if not 1 <= microbatches <= local_batch:
        raise ValueError(
            f"microbatches={microbatches} outside [1, local_batch={local_batch}]"
        )
    if local_batch % microbatches:
        raise ValueError(
            f"local_batch={local_batch} not divisible by "
            f"microbatches={microbatches}"
        )
    lb = nt._validated_local_batches(local_batches, eff, mode, local_batch,
                                     d_axis)
    lb_table = jnp.asarray(lb, jnp.int32)
    m = microbatches
    ticks = m + pp - 1

    # MoE: per-stage slot ids, padded to u_max with -1 (the masked pad id)
    if cfg.is_moe:
        tables = []
        for sp in stage_plans:
            slots = np.asarray(sp["mlp"].comp_slots)       # (D, n1, U_s)
            u_s = slots.shape[2]
            if u_s < u_max["mlp"]:
                slots = np.pad(slots, [(0, 0), (0, 0),
                                       (0, u_max["mlp"] - u_s)],
                               constant_values=-1)
            tables.append(slots)
        moe_slots = jnp.asarray(np.stack(tables), jnp.int32)  # (pp, D, n1, U)
    else:
        moe_slots = None

    def global_loss(params, batch):
        """Scalar full-batch mean loss, computed by the real pipeline (AD
        outside the shard_map, exactly as the emulated builders)."""
        stacked, specs = stack_staged_params(cfg, params, staged)

        def body(p_local, tokens_local):
            s = jax.lax.axis_index("stage")
            dd = jax.lax.axis_index("data")
            rr = jax.lax.axis_index("model")
            # my stage's layer stack: (l_max, U, *unit) / (l_max, ...) —
            # unit leaves arrive pre-sliced by the "stage" spec; rep leaves
            # arrive replicated and pick their stage row here
            uw = {k: v.reshape(v.shape[1], *v.shape[3:])
                  for k, v in p_local["unit"].items()}
            rw = {k: v[s] for k, v in p_local["rep"].items()}
            uids = moe_slots[s, dd, rr] if moe_slots is not None else None

            def my_layers(x):
                for l in range(l_max):
                    lp = {k: v[l] for k, v in uw.items()}
                    lp.update({k: v[l] for k, v in rw.items()})
                    x = x + nt._attn_local(lp, nt._rms(x, lp["ln1"]), cfg)
                    if cfg.is_moe:
                        x = x + nt._moe_local(lp, nt._rms(x, lp["ln2"]),
                                              uids, cfg)
                    else:
                        x = x + nt._mlp_local(lp, nt._rms(x, lp["ln2"]))
                return x

            mb = tokens_local.shape[0] // m
            seq = tokens_local.shape[1] - 1
            is_first = (s == 0)
            is_last = (s == pp - 1)
            recv = jnp.zeros((mb, seq, cfg.d_model), jnp.float32)
            total = jnp.float32(0.0)
            count = jnp.float32(0.0)
            for t in range(ticks):
                j0 = min(t, m - 1)          # stage 0's microbatch this tick
                toks0 = tokens_local[j0 * mb:(j0 + 1) * mb]
                emb = p_local["embed"][toks0[:, :-1]]
                x = jnp.where(is_first, emb, recv)
                y = my_layers(x)
                jl = t - (pp - 1)           # the microbatch draining at the
                if 0 <= jl < m:             # last stage this tick (static)
                    toksl = tokens_local[jl * mb:(jl + 1) * mb]
                    tgt = toksl[:, 1:]
                    mask = (
                        (jl * mb + jnp.arange(mb)) < lb_table[dd]
                    ).astype(jnp.float32)
                    logits = jnp.einsum(
                        "bsd,dv->bsv", nt._rms(y, p_local["final_norm"]),
                        p_local["head"],
                    ).astype(jnp.float32)
                    lse = jax.nn.logsumexp(logits, axis=-1)
                    ll = jnp.take_along_axis(
                        logits, tgt[..., None], axis=-1)[..., 0]
                    tok_loss = (lse - ll) * mask[:, None]
                    total = total + jnp.where(is_last, tok_loss.sum(), 0.0)
                    count = count + jnp.where(
                        is_last,
                        (mask[:, None] * jnp.ones_like(tok_loss)).sum(), 0.0,
                    )
                if t < ticks - 1:
                    # hand the boundary activation to the next stage; the
                    # last stage has no outgoing edge and stage 0 receives
                    # zeros it never reads
                    recv = jax.lax.ppermute(
                        y, "stage", [(i, i + 1) for i in range(pp - 1)]
                    )
            total = jax.lax.psum(total, ("stage", "data"))
            count = jax.lax.psum(count, ("stage", "data"))
            return total / jnp.maximum(count, 1.0)

        return shard_map(
            body, mesh=mesh, in_specs=(specs, P("data", None)),
            out_specs=P(), check_vma=False,
        )(stacked, batch)

    # Stage-local NTP gradient sync (shared body — core/overlap): grads
    # live on the packed per-layer tree (the stacking happened inside the
    # loss and its transpose undid it), so each layer reshards under its
    # OWN stage's plan over the ``data`` axis. ``overlap`` swaps in the
    # bucketed route: one fused collective per (stage, plan-kind).
    from repro.core import overlap as ov

    sync_grads = ov.make_sync_grads(cfg, staged, mesh, mode=mode,
                                    bucketed=overlap)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def _step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(global_loss)(params, batch)
        grads = sync_grads(grads)
        new_params, new_state, metrics = optimizer.update(
            grads, opt_state, params,
            norm_weights=nt._norm_weights(grads, d_axis),
        )
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    def step(params, opt_state, batch):
        if step.handoff is None:
            # seq len arrives with the first batch; bind the byte table then
            step.handoff = handoff_accounting(
                cfg, staged, local_batch=local_batch, microbatches=m,
                seq_len=batch.shape[1] - 1,
            )
        return _step(params, opt_state, batch)

    step.ticks = ticks
    step.submesh = True
    step.handoff = None
    step.handoff_for = lambda seq_len: handoff_accounting(
        cfg, staged, local_batch=local_batch, microbatches=m,
        seq_len=seq_len,
    )
    step.overlap = overlap
    step.collectives = sync_grads.collectives
    step.grads_fn = jax.jit(jax.value_and_grad(global_loss))
    step.sync_fn = jax.jit(sync_grads)
    return step
