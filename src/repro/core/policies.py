"""Fault-tolerance policy throughput models: DP-DROP vs NTP vs NTP-PW
(paper §6.1, Figs. 6/7/10).

All policies share the cluster geometry of §5.3: 32K GPUs, 32-wide scale-up
domains at TP32, 8 domains per DP replica (PP8), 128 DP replicas, 128
attention heads, local batch 8.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from repro.core.availability import ClusterSpec, sample_failed_domains
from repro.core.power import PowerModel
from repro.core.resource_manager import apply_spares, pack_replicas


@dataclass(frozen=True)
class WorkloadGeometry:
    n_heads: int = 128
    local_batch: int = 8
    mlp_flops_share: float = 2 / 3   # d_ff = 4d ⇒ MLP ≈ 2/3 of layer FLOPs
    tp_comm_share: float = 0.15      # exposed TP-collective share of an iter


def degradation_slowdown(slow_factor: float, bw_frac: float,
                         geom: WorkloadGeometry) -> float:
    """Iteration-time multiplier of a PARTIALLY-degraded domain at full TP
    (DESIGN.md §2.11): a straggler slows the compute share ``slow_factor``×
    (the slowest GPU gates the whole TP group), a degraded link scales the
    exposed TP-collective share by 1/bw_frac. Exactly 1.0 when healthy —
    the binary path never pays a float blend."""
    if slow_factor == 1.0 and bw_frac == 1.0:
        return 1.0
    return float(
        (1.0 - geom.tp_comm_share) * slow_factor
        + geom.tp_comm_share / bw_frac
    )


def stage_slowdown(tp_red: int, tp_full: int, geom: WorkloadGeometry, *,
                   slow_factor: float = 1.0, bw_frac: float = 1.0) -> float:
    """Iteration-time multiplier of a TP-reduced stage at equal batch.
    MLP work redistributes evenly (128-row units, k ≫ tp — §3.1: "the
    imbalance is typically very small"); attention is quantized at head
    granularity ("Attention usually has O(10) heads creating potential for
    substantially more imbalance"). Blend by FLOP share.

    ``slow_factor``/``bw_frac`` fold the domain's degradation ledger in
    (`degradation_slowdown`): a straggling or link-degraded stage is priced
    as its TP slowdown × its degradation multiplier — the health-state
    taxonomy rides the same NTP degrade math as GPU absence."""
    if tp_red <= 0:
        return np.inf
    even = tp_full / tp_red
    heads = np.ceil(geom.n_heads / tp_red) / (geom.n_heads / tp_full)
    base = float(
        geom.mlp_flops_share * even + (1 - geom.mlp_flops_share) * heads
    )
    dm = degradation_slowdown(slow_factor, bw_frac, geom)
    return base if dm == 1.0 else base * dm


def staged_rel_iter_times(
    stage_tp,
    tp_full: int,
    geom: WorkloadGeometry,
    *,
    local_batches,
    local_batch: int,
    boosts=None,
    power: PowerModel = PowerModel(),
    slow_factors=None,
    bw_fracs=None,
):
    """Per-STAGE predicted relative iteration time of a DP×PP×TP job
    (DESIGN.md §2.6): ``stage_tp[d][s]`` is replica d's surviving TP in
    pipeline stage s. Stage s's relative busy time is

        rel_s = max_d  slowdown(tp[d][s]) / speedup(boost_d) · lb_d / LB

    with the power boost applied only where the stage is actually degraded
    (the repurposed budget lives in the degraded domain's rack). The job's
    relative iteration time is ``max_s rel_s`` — the slowest stage gates the
    pipeline, exactly `perf_model.staged_iteration_time`'s reduction — and
    equals `PowerDecision.rel_iter_time` computed on the plan's effective
    (min-over-stages) TP.

    ``slow_factors``/``bw_fracs`` are per-REPLICA degradation factors
    (`StagedHealth.replica_degradations`, already merged across stages —
    1F1B runs every microbatch through every stage, so a straggler anywhere
    gates the replica in every stage)."""
    d_axis = len(stage_tp)
    pp = len(stage_tp[0])
    if boosts is None:
        boosts = (1.0,) * d_axis
    if slow_factors is None:
        slow_factors = (1.0,) * d_axis
    if bw_fracs is None:
        bw_fracs = (1.0,) * d_axis
    rels = []
    for s in range(pp):
        r_s = 0.0
        for d in range(d_axis):
            tp = stage_tp[d][s]
            degraded = slow_factors[d] != 1.0 or bw_fracs[d] != 1.0
            if tp == tp_full and not degraded:
                eff = 1.0
            else:
                slow = stage_slowdown(
                    tp, tp_full, geom,
                    slow_factor=slow_factors[d], bw_frac=bw_fracs[d],
                )
                eff = slow / power.speedup(boosts[d])
            r_s = max(r_s, eff * local_batches[d] / local_batch)
        rels.append(float(r_s))
    return tuple(rels)


def boosted_operating_point(slow: float, power: PowerModel):
    """NTP-PW operating point for one stage at slowdown ``slow`` (Table 1
    convention, shared with the runtime PowerPolicy): boost just enough to
    erase the whole slowdown, capped by the rack (§3.2). Returns
    (power_mult, residual_slowdown) — residual 1.0 when within the cap."""
    p = min(power.required_power_for_speedup(slow), power.max_boost)
    return float(p), float(slow / power.speedup(p))


def replica_throughput(
    tp_red: int,
    tp_full: int,
    geom: WorkloadGeometry,
    method: str,
    power: PowerModel,
    *,
    slow_factor: float = 1.0,
    bw_frac: float = 1.0,
) -> float:
    """Relative samples/iteration of one DP replica whose weakest stage runs
    at tp_red (1.0 = healthy). NTP: shrink local batch to not straggle.
    NTP-PW: boost power to keep full batch; fall back to batch shrink past
    the boost cap. ``slow_factor``/``bw_frac`` price the replica's
    degradation ledger (DESIGN.md §2.11) on top of its TP reduction — a
    straggling full-TP replica is degraded too."""
    if tp_red <= 0:
        return 0.0
    if tp_red == tp_full and slow_factor == 1.0 and bw_frac == 1.0:
        return 1.0
    slow = stage_slowdown(tp_red, tp_full, geom,
                          slow_factor=slow_factor, bw_frac=bw_frac)
    if method == "ntp":
        bs = int(np.floor(geom.local_batch / slow))
        return bs / geom.local_batch
    if method == "ntp_pw":
        # the rack is provisioned for up to max_boost on every survivor
        # (§3.2; Table 1 boosts TP30 to 1.15× > 32/30× of the failed share)
        speed = power.speedup(power.max_boost)
        eff_slow = slow / speed
        if eff_slow <= 1.0 + 1e-9:
            return 1.0
        bs = int(np.floor(geom.local_batch / eff_slow))
        return bs / geom.local_batch
    raise ValueError(method)


def table1_settings(
    geom: WorkloadGeometry = WorkloadGeometry(), power: PowerModel = PowerModel()
):
    """Reproduce Table 1 analytically (TP32 domain, local bs 8): non-boosted
    reduced-TP replicas shrink local batch to not straggle; boosted ones keep
    bs=8 and raise power until iteration time matches."""
    rows = []
    base_tp, base_bs = 32, geom.local_batch
    for tp in (32, 30, 28):
        slow = stage_slowdown(tp, base_tp, geom)
        bs = min(base_bs, int(np.floor(base_bs / slow)))
        rows.append({
            "config": f"TP{tp}", "local_bs": bs, "power": 1.0,
            "rel_iter_time": round(slow * bs / base_bs, 3),
        })
        if tp != base_tp:
            preq, rel = boosted_operating_point(slow, power)
            rows.append({
                "config": f"TP{tp}-PW", "local_bs": base_bs,
                "power": round(preq, 2), "rel_iter_time": round(rel, 3),
            })
    return rows


def cluster_throughput(
    spec: ClusterSpec,
    failed_counts: np.ndarray,
    method: str,
    *,
    geom: WorkloadGeometry = WorkloadGeometry(),
    power: PowerModel = PowerModel(),
    n_spare_domains: int = 0,
) -> Dict:
    """Relative cluster samples/iteration under one failure sample.

    DP-DROP reforms replicas from fully-clean domains (the favourable
    variant — dropping whole original replicas would be strictly worse).
    """
    failed = apply_spares(failed_counts, n_spare_domains)
    n_domains = len(failed)
    n_replicas = n_domains // spec.domains_per_replica

    if method == "dpdrop":
        clean = int((failed == 0).sum())
        usable = clean // spec.domains_per_replica
        thr = usable / n_replicas
        return {"throughput": thr, "replica_throughputs": None,
                "lost_fraction": 1.0 - thr}

    assignments = pack_replicas(failed, spec.domain_size, spec.domains_per_replica)
    thr = [
        replica_throughput(a.tp, spec.domain_size, geom, method, power)
        for a in assignments
    ]
    total = float(np.sum(thr)) / n_replicas
    return {
        "throughput": total,
        "replica_throughputs": thr,
        "lost_fraction": 1.0 - total,
        "affected_replicas": sum(1 for t in thr if t < 1.0),
    }


def throughput_loss_curve(
    spec: ClusterSpec,
    failed_fractions,
    methods=("dpdrop", "ntp", "ntp_pw"),
    *,
    samples: int = 20,
    blast_radius: int = 1,
    seed: int = 0,
    geom: WorkloadGeometry = WorkloadGeometry(),
) -> Dict[str, List[float]]:
    """Fig. 6 / Fig. 10: mean lost-throughput fraction per failed fraction."""
    rng = np.random.default_rng(seed)
    out: Dict[str, List[float]] = {m: [] for m in methods}
    for f in failed_fractions:
        n_failed = int(round(f * spec.n_gpus))
        losses = {m: [] for m in methods}
        for _ in range(samples):
            counts = sample_failed_domains(
                spec.n_gpus, spec.domain_size, n_failed, rng, blast_radius
            )
            for m in methods:
                losses[m].append(
                    cluster_throughput(spec, counts, m, geom=geom)["lost_fraction"]
                )
        for m in methods:
            out[m].append(float(np.mean(losses[m])))
    return out


def spares_analysis(
    spec: ClusterSpec,
    failed_domain_trace: List[np.ndarray],
    spare_range,
    method: str,
    *,
    geom: WorkloadGeometry = WorkloadGeometry(),
) -> List[Dict]:
    """Fig. 7: fixed minibatch — training PAUSES whenever the surviving
    replicas (+ spare replicas) cannot supply the full minibatch. Returns
    per-spare-count {spares, uptime, throughput_per_gpu}."""
    out = []
    n_replicas = (spec.n_gpus // spec.domain_size) // spec.domains_per_replica
    for s in spare_range:
        ok_time = 0
        for counts in failed_domain_trace:
            res = cluster_throughput(
                spec, counts, method if method != "dpdrop" else "dpdrop",
                geom=geom, n_spare_domains=s,
            )
            if method == "dpdrop":
                maintained = res["throughput"] >= 1.0 - 1e-9
            else:
                # lost sample capacity must be covered by whole spare replicas
                lost_replica_equiv = (1.0 - res["throughput"]) * n_replicas
                spare_replicas = s // spec.domains_per_replica
                maintained = spare_replicas >= np.ceil(lost_replica_equiv - 1e-9)
            ok_time += int(maintained)
        uptime = ok_time / max(len(failed_domain_trace), 1)
        total_gpus = spec.n_gpus + s * spec.domain_size
        out.append({
            "spares": int(s),
            "uptime": uptime,
            "throughput_per_gpu": uptime * spec.n_gpus / total_gpus,
        })
    return out
