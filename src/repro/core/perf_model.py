"""Analytic hybrid-parallel performance model (paper §4.2).

The paper's results (Fig. 2, Table 1, Figs. 6/7) come from a calibrated
simulator; this is our equivalent: a closed-form iteration-time model with
compute / TP-collective / PP-bubble / DP-allreduce / NTP-reshard terms. The
Fig.-11 analogue validates it against the XLA-derived roofline terms from the
dry-run artifacts (benchmarks/fig11_model_validation.py).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional

import numpy as np


@dataclass(frozen=True)
class Hardware:
    chip_flops: float = 197e12        # bf16 peak / chip
    hbm_bw: float = 819e9
    scaleup_bw: float = 9e11          # per-GPU intra-domain (NVL/ICI) bytes/s
    scaleout_bw: float = 1e11         # per-GPU inter-domain bytes/s
    domain_size: int = 32
    mfu_ceiling: float = 0.62         # achievable matmul efficiency


@dataclass(frozen=True)
class Workload:
    n_params: float = 480e9
    n_layers: int = 100
    d_model: int = 20480
    seq_len: int = 16384
    minibatch_tokens: float = 16e6
    act_bytes: int = 2


@dataclass(frozen=True)
class Parallel:
    tp: int = 32
    pp: int = 8
    dp: int = 128
    microbatch_seqs: int = 1

    @property
    def gpus(self) -> int:
        return self.tp * self.pp * self.dp


def iteration_time(
    hw: Hardware,
    wl: Workload,
    par: Parallel,
    *,
    tp_reduced: Optional[int] = None,
    local_batch_scale: float = 1.0,
    power_speedup: float = 1.0,
    dp_overlap: float = 0.7,
    tp_overlap: float = 0.3,
    reshard_overlap: Optional[float] = None,
    slow_factor: float = 1.0,
    link_bw_frac: float = 1.0,
) -> Dict[str, float]:
    """Per-iteration time breakdown for ONE DP replica (seconds).

    tp_reduced: NTP — this replica's stages run at a reduced TP degree
    (same work on fewer chips). local_batch_scale scales its sample count.
    power_speedup: NTP-PW compute boost.
    reshard_overlap: None keeps the legacy Fig.-8 heuristic (90% of the
    reshard hidden); an explicit fraction exposes ``(1 - reshard_overlap)``
    of it — `overlap_iteration_time` passes 0.0 to start from a fully
    exposed sync before applying its own overlap window.
    slow_factor: health-state taxonomy (DESIGN.md §2.11) — a straggling
    domain gates its whole TP group, priced the way the bubble is: an
    additive ``straggler_exposed = (compute + tp_exposed) · (slow − 1)``
    term on the busy time. link_bw_frac scales the scale-up bandwidth every
    intra-domain collective (TP, reshard) sees.
    """
    assert slow_factor >= 1.0, slow_factor
    assert 0.0 < link_bw_frac <= 1.0, link_bw_frac
    # ``* 1.0`` is bit-exact: the healthy path's floats are unchanged
    hw = replace(hw, scaleup_bw=hw.scaleup_bw * link_bw_frac)
    tp_eff = tp_reduced or par.tp
    tokens_per_replica = wl.minibatch_tokens / par.dp * local_batch_scale
    seqs = max(tokens_per_replica / wl.seq_len, 1e-9)
    m = max(int(round(seqs / par.microbatch_seqs)), 1)

    # ---- compute ----------------------------------------------------------
    flops_replica = 6.0 * wl.n_params * tokens_per_replica
    flops_gpu = flops_replica / (tp_eff * par.pp)
    t_comp = flops_gpu / (hw.chip_flops * hw.mfu_ceiling) / power_speedup

    # ---- TP collectives (Megatron: 4 allreduce/layer fwd+bwd) -------------
    act_bytes_mb = par.microbatch_seqs * wl.seq_len * wl.d_model * wl.act_bytes
    vol_per_layer = 4 * 2 * act_bytes_mb * (tp_eff - 1) / tp_eff
    layers_local = wl.n_layers / par.pp
    t_tp = layers_local * m * vol_per_layer / hw.scaleup_bw / power_speedup
    t_tp_exposed = t_tp * (1.0 - tp_overlap)

    # ---- PP bubble ---------------------------------------------------------
    bubble = (par.pp - 1) / max(m, 1)
    t_pp = (t_comp + t_tp_exposed) * bubble

    # ---- DP gradient all-reduce -------------------------------------------
    grad_bytes_gpu = 2.0 * wl.n_params / (tp_eff * par.pp)
    # sync at the min TP degree: volume rises when any replica is reduced
    t_dp = 2.0 * grad_bytes_gpu * (par.dp - 1) / par.dp / hw.scaleout_bw
    t_dp_exposed = t_dp * (1.0 - dp_overlap)

    # ---- NTP reshard (§3.1): within scale-up domain, overlapped ------------
    t_reshard_exposed = 0.0
    if tp_reduced is not None and tp_reduced != par.tp:
        shard_bytes = 2.0 * wl.n_params / (par.tp * par.pp)
        reshard_bytes = shard_bytes * (1.0 - tp_reduced / par.tp) * 2  # pre+post
        t_reshard = reshard_bytes / hw.scaleup_bw
        if reshard_overlap is None:
            # Fig. 8: overlapped with the final backward; exposed part is
            # linear in comm:comp with a small slope — model 10% exposed
            t_reshard_exposed = 0.1 * t_reshard
        else:
            t_reshard_exposed = (1.0 - reshard_overlap) * t_reshard

    # ---- straggler gate (taxonomy §2.11): priced like the bubble — an
    # additive multiplier on the busy time (the slow domain stretches every
    # microbatch's compute and collectives; 0.0 exactly when healthy)
    t_straggle = (
        (t_comp + t_tp_exposed) * (slow_factor - 1.0)
        if slow_factor != 1.0 else 0.0
    )

    total = (t_comp + t_tp_exposed + t_pp + t_dp_exposed + t_reshard_exposed
             + t_straggle)
    return {
        "total": total,
        "compute": t_comp,
        "tp_exposed": t_tp_exposed,
        "pp_bubble": t_pp,
        "dp_exposed": t_dp_exposed,
        "reshard_exposed": t_reshard_exposed,
        "straggler_exposed": t_straggle,
        "microbatches": m,
        "per_gpu_tput": tokens_per_replica / total / tp_eff / par.pp,
    }


def staged_iteration_time(
    hw: Hardware,
    wl: Workload,
    par: Parallel,
    stage_tps,
    **kw,
) -> Dict[str, float]:
    """`iteration_time` under per-stage reduced TP degrees (nonuniform PP,
    DESIGN.md §2.6): 1F1B runs every microbatch through every stage, so the
    replica is gated by its SLOWEST stage — the breakdown is exactly
    ``iteration_time(tp_reduced=min(stage_tps))``. This is the analytic twin
    of the live runtime's per-stage ``rel_iter_time`` metric (max over
    stages of `policies.staged_rel_iter_times`)."""
    stage_tps = tuple(stage_tps)
    assert len(stage_tps) == par.pp, (stage_tps, par.pp)
    assert all(1 <= t <= par.tp for t in stage_tps), stage_tps
    tp_red = min(stage_tps)
    return iteration_time(
        hw, wl, par, tp_reduced=(None if tp_red == par.tp else tp_red), **kw
    )


def exposed_comm(sync_s: float, overlappable_compute_s: float) -> float:
    """Exposed gradient-sync time once overlap is on (DESIGN.md §2.10):
    the sync that does not fit inside the backward window stays on the
    critical path — ``max(0, sync − overlappable_compute)``. This is the
    identity `bench_hotpath` and `telemetry_report` both use, so the
    measured and modeled decompositions cannot drift apart."""
    return max(0.0, float(sync_s) - float(overlappable_compute_s))


def overlap_iteration_time(
    hw: Hardware,
    wl: Workload,
    par: Parallel,
    *,
    overlappable_fraction: float = 0.7,
    collective_ratio: float = 1.0,
    **kw,
) -> Dict[str, float]:
    """Overlap-aware iteration time (ISSUE 9 / DESIGN.md §2.10).

    `iteration_time`'s fixed dp_overlap/reshard heuristics model a generic
    well-tuned stack. The overlap engine makes those knobs explicit: start
    from a FULLY exposed sync (dp_overlap=0, reshard_overlap=0), then hide
    it behind the layer-chunked backward window

        window  = overlappable_fraction × (compute + tp_exposed + pp_bubble)
        exposed = exposed_comm(sync, window)

    ``collective_ratio`` scales the sync term by the bucketed/sequential
    launch-count ratio (`overlap.sync_collectives`) for launch-bound
    regimes — on real interconnects bandwidth dominates and 1.0 is right;
    on the CPU emulation bench_hotpath measures a near-linear collapse.
    ``kw`` forwards to `iteration_time` (tp_reduced, local_batch_scale,
    power_speedup, tp_overlap); dp_overlap/reshard_overlap are owned here.

    Returns the `iteration_time` dict plus ``sync`` (full sync time),
    ``overlap_window`` and ``exposed_comm``; ``total``/``per_gpu_tput`` are
    recomputed with the overlapped sync. ``dp_exposed``/``reshard_exposed``
    keep their pre-overlap (fully exposed) values so the sync composition
    stays visible."""
    assert 0.0 <= overlappable_fraction <= 1.0, overlappable_fraction
    assert collective_ratio > 0.0, collective_ratio
    base = iteration_time(
        hw, wl, par, dp_overlap=0.0, reshard_overlap=0.0, **kw
    )
    sync = (base["dp_exposed"] + base["reshard_exposed"]) * collective_ratio
    window = overlappable_fraction * (
        base["compute"] + base["tp_exposed"] + base["pp_bubble"]
    )
    exposed = exposed_comm(sync, window)
    total = (base["compute"] + base["tp_exposed"] + base["pp_bubble"]
             + base["straggler_exposed"] + exposed)
    tokens_per_replica = (
        wl.minibatch_tokens / par.dp * kw.get("local_batch_scale", 1.0)
    )
    tp_eff = kw.get("tp_reduced") or par.tp
    out = dict(base)
    out.update(
        total=total,
        sync=sync,
        overlap_window=window,
        exposed_comm=exposed,
        per_gpu_tput=tokens_per_replica / total / tp_eff / par.pp,
    )
    return out


def best_config(
    hw: Hardware, wl: Workload, n_gpus: int, *, tp_limit: Optional[int] = None,
    min_pp: int = 1,
) -> Dict:
    """Exhaustive hybrid-parallel search (paper Fig. 2b): best per-GPU
    throughput subject to a TP-degree cap (TP ≤ scale-up domain). Candidate
    PP degrees come from the runtime's supported ladder
    (`configs.shapes.candidate_pp` — every stage must own a layer), so the
    search space and the executable stage-partitioned step cannot drift
    apart."""
    from repro.configs.shapes import candidate_pp

    best = None
    tp_max = min(tp_limit or hw.domain_size, hw.domain_size)
    tp = 1
    while tp <= tp_max:
        for pp in candidate_pp(wl.n_layers):
            if pp < min_pp or n_gpus % (tp * pp):
                continue
            dp = n_gpus // (tp * pp)
            if dp < 1:
                continue
            # memory feasibility: params+grads+opt (16 bytes/param ZeRO over
            # dp) + activations must fit 180GB-class HBM per the paper's B200
            bytes_model = wl.n_params * (2 + 2) / (tp * pp) + wl.n_params * 12 / (
                tp * pp * dp
            )
            if bytes_model > 150e9:
                continue
            if wl.minibatch_tokens / dp < wl.seq_len:  # < 1 seq per replica
                continue
            r = iteration_time(hw, wl, Parallel(tp=tp, pp=pp, dp=dp))
            cand = {"tp": tp, "pp": pp, "dp": dp, **r}
            if best is None or cand["per_gpu_tput"] > best["per_gpu_tput"]:
                best = cand
        tp *= 2
    return best
