"""Nonuniform TP layouts for a whole mesh: per-replica health -> per-weight
stacked reshard tables, plus packing between canonical (global) weights and
the padded per-rank unit buffers used inside shard_map.

The JAX/GSPMD adaptation (DESIGN.md §3.1): every rank owns a uniform
``(U, unit, ...)`` buffer; a replica degraded to n_r active ranks holds all k
units on its first n_r ranks (its failed ranks hold zeros — algebraically
inert for Megatron-TP matmuls). U = ceil-max over every replica's layouts, so
one SPMD program serves the whole nonuniform job.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Tuple

import numpy as np

import jax.numpy as jnp

from repro.core import shard_mapping as sm


@dataclass(frozen=True)
class FailurePlan:
    """Static health of one training job on a (data=D, model=N1) mesh.

    replica_tp[d] = number of still-functional ranks in replica d's scale-up
    domain (the resource manager packs failures into low replica ids).
    """

    n1: int
    replica_tp: Tuple[int, ...]

    def __post_init__(self):
        assert all(1 <= t <= self.n1 for t in self.replica_tp)

    @property
    def d(self) -> int:
        return len(self.replica_tp)

    @property
    def n_sync(self) -> int:
        """Sync TP degree — the paper syncs at the minimum degree ("the
        bandwidth is limited by the reduced number of shards anyway")."""
        return min(self.replica_tp)

    @property
    def healthy(self) -> bool:
        return all(t == self.n1 for t in self.replica_tp)

    def local_batch_fraction(self, base_local_batch: int) -> np.ndarray:
        """Paper §3.1: degraded replicas reduce local batch ∝ active ranks
        (floor to whole samples — the quantization the paper notes)."""
        return np.array(
            [
                max(1, int(np.floor(base_local_batch * t / self.n1)))
                for t in self.replica_tp
            ]
        )


@dataclass(frozen=True)
class StagedPlan:
    """Per-(replica, stage) health of one DP×PP×TP job (DESIGN.md §2.6).

    ``stages[s]`` is the `FailurePlan` of pipeline stage ``s`` over the D
    replicas: stage s of replica d runs at ``stages[s].replica_tp[d]``. Every
    stage shares the mesh geometry (same n1, same D); only its failure state
    differs. A pp=1 job is exactly ``StagedPlan((plan,))`` and degenerates to
    the plain `FailurePlan` code path everywhere.
    """

    stages: Tuple[FailurePlan, ...]

    def __post_init__(self):
        assert len(self.stages) >= 1
        n1, d = self.stages[0].n1, self.stages[0].d
        assert all(p.n1 == n1 and p.d == d for p in self.stages), self.stages

    @property
    def pp(self) -> int:
        return len(self.stages)

    @property
    def n1(self) -> int:
        return self.stages[0].n1

    @property
    def d(self) -> int:
        return self.stages[0].d

    @property
    def healthy(self) -> bool:
        return all(p.healthy for p in self.stages)

    @property
    def stage_tp(self) -> Tuple[Tuple[int, ...], ...]:
        """stage_tp[d][s] — surviving TP of replica d's stage s."""
        return tuple(
            tuple(p.replica_tp[d] for p in self.stages) for d in range(self.d)
        )

    @property
    def effective(self) -> FailurePlan:
        """The slowest-stage reduction: replica d's usable rate is gated by
        its worst stage (1F1B — every microbatch crosses every stage), so
        batch-fraction and slowdown math consume a plain `FailurePlan` whose
        replica_tp is the per-replica min over stages."""
        return FailurePlan(
            n1=self.n1,
            replica_tp=tuple(
                min(p.replica_tp[d] for p in self.stages)
                for d in range(self.d)
            ),
        )

    @property
    def replica_tp(self) -> Tuple[int, ...]:
        """EFFECTIVE per-replica TP (min over stages — the degree that gates
        batch fraction and iteration time); per-stage degrees are in
        `stage_tp`/`stages`. Lets plan consumers that only care about the
        operating point (TraceRunner history, goodput) read staged and
        unstaged plans uniformly."""
        return self.effective.replica_tp

    def replace_stage(self, s: int, plan: FailurePlan) -> "StagedPlan":
        assert 0 <= s < self.pp
        return StagedPlan(self.stages[:s] + (plan,) + self.stages[s + 1:])


def as_staged(plan) -> StagedPlan:
    """Coerce a `FailurePlan` (pp=1) or `StagedPlan` to the staged view."""
    if isinstance(plan, StagedPlan):
        return plan
    return StagedPlan((plan,))


@dataclass(frozen=True)
class StackedTables:
    """Per-replica reshard tables stacked over the data axis (jnp arrays),
    indexed inside shard_map by (axis_index('data'), axis_index('model'))."""

    send_idx: jnp.ndarray  # (D, n, n, s_max)
    recv_idx: jnp.ndarray  # (D, n, n, s_max)
    stay_idx: jnp.ndarray  # (D, n, U)
    buf: int
    s_max: int


@dataclass(frozen=True)
class WeightPlan:
    """Everything needed to run one weight nonuniformly."""

    k: int                 # partition units
    buf: int               # units per rank buffer (U)
    comp_slots: np.ndarray  # (D, n, U) unit id per comp slot, -1 pad
    sync_slots: np.ndarray  # (D, n, U) unit id per sync slot, -1 pad
    pre: StackedTables     # comp -> sync
    post: StackedTables    # sync -> comp

    @property
    def comp_mask(self) -> np.ndarray:
        return self.comp_slots >= 0


def _stack(tabs, buf: int) -> StackedTables:
    s_max = max(t.s_max for t in tabs)

    def pad(a, t):
        out = np.full(a.shape[:-1] + (s_max,), buf, dtype=np.int32)
        out[..., : a.shape[-1]] = a
        return out

    return StackedTables(
        send_idx=jnp.asarray(np.stack([pad(t.send_idx, t) for t in tabs])),
        recv_idx=jnp.asarray(np.stack([pad(t.recv_idx, t) for t in tabs])),
        stay_idx=jnp.asarray(np.stack([t.stay_idx for t in tabs])),
        buf=buf,
        s_max=s_max,
    )


@lru_cache(maxsize=None)
def _weight_plan_cached(k: int, n1: int, replica_tp: Tuple[int, ...]) -> WeightPlan:
    # layouts + tables come from the ONE Algorithm-1 planner (repro.reshard.
    # planner): the same cached objects drive the in-step gradient reshard,
    # the fail/repair packed→packed transitions, and the serving state moves
    from repro.reshard import planner

    n_sync = min(replica_tp)
    sync_key = planner.sync_key(k, n1, n_sync)
    comp_keys = [planner.comp_key(k, n1, nr, n_sync) for nr in replica_tp]
    comps = [planner.layout(ck) for ck in comp_keys]
    sync = planner.layout(sync_key)
    buf = max([sync.max_count] + [c.max_count for c in comps])

    pre = [planner.tables(ck, sync_key, buf) for ck in comp_keys]
    post = [planner.tables(sync_key, ck, buf) for ck in comp_keys]

    def slots(layout):
        out = np.full((n1, buf), -1, dtype=np.int64)
        out[:, : layout.max_count] = layout.slots
        return out

    return WeightPlan(
        k=k,
        buf=buf,
        comp_slots=np.stack([slots(c) for c in comps]),
        sync_slots=np.stack([slots(sync)] * len(replica_tp)),
        pre=_stack(pre, buf),
        post=_stack(post, buf),
    )


def weight_plan(k: int, plan: FailurePlan) -> WeightPlan:
    return _weight_plan_cached(k, plan.n1, tuple(plan.replica_tp))


# ---------------------------------------------------------------------------
# packing canonical <-> nonuniform global buffers

def pack_global(w: np.ndarray, wp: WeightPlan, unit: int) -> np.ndarray:
    """Canonical weight (k*unit, ...) -> global NTP buffer
    (D, n1*buf, unit, ...) laid out so shard_map in_specs P('data','model')
    hands each rank its (buf, unit, ...) comp-layout block."""
    k, buf = wp.k, wp.buf
    d, n1, _ = wp.comp_slots.shape
    cols = w.shape[1:]
    wu = np.asarray(w).reshape(k, unit, *cols)
    out = np.zeros((d, n1, buf, unit) + cols, wu.dtype)
    for dd in range(d):
        sl = wp.comp_slots[dd]
        valid = sl >= 0
        out[dd][valid] = wu[sl[valid]]
    return out.reshape(d, n1 * buf, unit, *cols)


def unpack_global(buf_arr: np.ndarray, wp: WeightPlan, unit: int, replica: int = 0) -> np.ndarray:
    """Inverse of pack_global for one replica (e.g. checkpointing)."""
    k, buf = wp.k, wp.buf
    d, n1, _ = wp.comp_slots.shape
    arr = np.asarray(buf_arr).reshape(d, n1, buf, *buf_arr.shape[2:])[replica]
    cols = arr.shape[3:]
    out = np.zeros((k, unit) + cols, arr.dtype)
    sl = wp.comp_slots[replica]
    valid = sl >= 0
    out[sl[valid]] = arr[valid]
    return out.reshape(k * unit, *cols)
