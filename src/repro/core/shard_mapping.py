"""Paper §3.1 "Shard-mapping algorithm" (Algorithm 1): comp/sync rank
assignment for nonuniform tensor parallelism.

Terminology (paper): a weight's TP partition dimension is split into ``k``
*units* (we use 128-row blocks / attention-head groups / experts — DESIGN.md
§3.2, the TPU adaptation of the paper's row granularity).

* **sync layout** — the canonical layout used for cross-replica gradient
  synchronization: units contiguously sharded over the ``n2`` sync ranks
  (``n2`` = the reduced TP degree). A degraded replica *computes* in this
  layout too (the paper: "on unhealthy replicas, A/B … are sharded
  contiguously across N2 GPUs").
* **comp layout** — the layout healthy replicas compute in: balanced over all
  ``n1`` ranks, constructed so that sync rank ``j`` keeps the leading units of
  its own sync shard and the overflow is round-robined over the ``n1-n2``
  *offload* ranks, equalizing pairwise reshard traffic (Algorithm 1's
  ``offload_idx`` rotation).

Pure numpy — imported by both the jax collectives and the analytic models.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, Tuple

import numpy as np


def balanced_sizes(k: int, n: int) -> np.ndarray:
    """Contiguous balanced partition sizes (first k%n ranks get one extra)."""
    q, r = divmod(k, n)
    return np.array([q + 1 if i < r else q for i in range(n)], dtype=np.int64)


def sync_assignment(k: int, n2: int) -> np.ndarray:
    """Unit -> sync rank (contiguous, balanced over ranks [0, n2))."""
    sizes = balanced_sizes(k, n2)
    return np.repeat(np.arange(n2), sizes)


def comp_assignment(k: int, n1: int, n2: int) -> np.ndarray:
    """Algorithm 1: unit -> comp rank for a HEALTHY replica (n1 ranks) whose
    gradients must end up in the n2-contiguous sync layout.

    Invariants (property-tested):
      * comp loads are balanced over n1 ranks (max-min <= 1);
      * sync rank j keeps a prefix of its own sync shard (minimal motion);
      * overflow units rotate over offload ranks [n2, n1) (balanced pairwise
        reshard traffic);
      * n1 == n2  ->  comp == sync (zero reshard traffic).
    """
    assert 1 <= n2 <= n1 and k >= n1, (k, n1, n2)
    sync = sync_assignment(k, n2)
    target = balanced_sizes(k, n1)
    comp = np.empty(k, dtype=np.int64)

    n_off = n1 - n2
    if n_off == 0:
        return sync.copy()

    # overflow capacity of each offload rank
    cap = target[n2:].copy()
    fill = np.zeros(n_off, dtype=np.int64)
    offload_idx = 0
    for j in range(n2):
        units = np.where(sync == j)[0]          # contiguous
        keep = min(len(units), target[j])
        comp[units[:keep]] = j
        for u in units[keep:]:
            # rotate over offload ranks, skipping full ones
            for _ in range(n_off):
                cand = offload_idx % n_off
                offload_idx += 1
                if fill[cand] < cap[cand]:
                    comp[u] = n2 + cand
                    fill[cand] += 1
                    break
            else:  # pragma: no cover — capacities sum to the overflow total
                raise AssertionError("offload capacity exhausted")
    return comp


# ---------------------------------------------------------------------------
# layouts + reshard tables

@dataclass(frozen=True)
class Layout:
    """A placement of k units onto n ranks (ranks may be empty)."""

    k: int
    n: int
    assignment: np.ndarray          # (k,) unit -> rank
    counts: np.ndarray              # (n,)
    local_slot: np.ndarray          # (k,) slot of unit within its rank buffer
    max_count: int

    @property
    def slots(self) -> np.ndarray:
        """(n, max_count) unit id per buffer slot, -1 = padding."""
        out = np.full((self.n, self.max_count), -1, dtype=np.int64)
        for u in range(self.k):
            out[self.assignment[u], self.local_slot[u]] = u
        return out


def make_layout(assignment: np.ndarray, n: int) -> Layout:
    k = len(assignment)
    counts = np.bincount(assignment, minlength=n).astype(np.int64)
    local_slot = np.empty(k, dtype=np.int64)
    next_slot = np.zeros(n, dtype=np.int64)
    for u in range(k):  # unit-id order within each rank (canonical)
        r = assignment[u]
        local_slot[u] = next_slot[r]
        next_slot[r] += 1
    return Layout(k, n, assignment.copy(), counts, local_slot, int(counts.max()))


def comp_layout(k: int, n1: int, n2: int) -> Layout:
    return make_layout(comp_assignment(k, n1, n2), n1)


def sync_layout(k: int, n1: int, n2: int) -> Layout:
    """Sync layout expressed on the full n1-rank axis (ranks >= n2 empty)."""
    return make_layout(sync_assignment(k, n2), n1)


def transfer_matrix(src: Layout, dst: Layout) -> np.ndarray:
    """(n, n) units moved from src-rank to dst-rank (diagonal = stays)."""
    m = np.zeros((src.n, dst.n), dtype=np.int64)
    for u in range(src.k):
        m[src.assignment[u], dst.assignment[u]] += 1
    return m


@dataclass(frozen=True)
class ReshardTables:
    """Static index tables driving the all-to-all reshard (padded messages).

    All buffers are a common ``buf`` units long (zero-padded beyond each
    rank's count); index ``buf`` is the pad sentinel (gathers a zero row /
    scatter-drops).

    send_idx[r, d, s]  = local slot in r's src buffer of the s-th unit that
                         rank r sends to rank d  (pad if none)
    recv_idx[r, j, s]  = local slot in r's dst buffer where the unit received
                         from rank j at message slot s lands (pad drops)
    stay_idx[r, t]     = src slot feeding dst slot t when the unit does not
                         change ranks (pad -> zero)
    """

    n: int
    s_max: int
    buf: int
    send_idx: np.ndarray   # (n, n, s_max) int32
    recv_idx: np.ndarray   # (n, n, s_max) int32
    stay_idx: np.ndarray   # (n, buf) int32

    @property
    def pad(self) -> int:
        return self.buf

    def moved_units_per_rank(self) -> np.ndarray:
        """max(send, recv) units per rank (network-moved, excl. stays)."""
        send = (self.send_idx != self.pad).sum(axis=(1, 2))
        recv = (self.recv_idx != self.pad).sum(axis=(1, 2))
        return np.maximum(send, recv)


def reshard_tables(src: Layout, dst: Layout, buf: int | None = None) -> ReshardTables:
    assert src.k == dst.k and src.n == dst.n
    n, k = src.n, src.k
    buf = buf if buf is not None else max(src.max_count, dst.max_count)
    assert buf >= max(src.max_count, dst.max_count)
    msgs: Dict[Tuple[int, int], list] = {}
    stays: list = []
    for u in range(k):  # unit-id order => deterministic message order
        r, d = int(src.assignment[u]), int(dst.assignment[u])
        if r != d:
            msgs.setdefault((r, d), []).append(u)
        else:
            stays.append(u)
    s_max = max((len(v) for v in msgs.values()), default=0)
    s_max = max(s_max, 1)  # keep arrays non-empty for jax

    send_idx = np.full((n, n, s_max), buf, dtype=np.int32)
    recv_idx = np.full((n, n, s_max), buf, dtype=np.int32)
    stay_idx = np.full((n, buf), buf, dtype=np.int32)
    for (r, d), units in msgs.items():
        for s, u in enumerate(units):
            send_idx[r, d, s] = src.local_slot[u]
            recv_idx[d, r, s] = dst.local_slot[u]
    for u in stays:
        r = int(src.assignment[u])
        stay_idx[r, dst.local_slot[u]] = src.local_slot[u]
    return ReshardTables(
        n=n, s_max=s_max, buf=buf, send_idx=send_idx, recv_idx=recv_idx,
        stay_idx=stay_idx,
    )


@lru_cache(maxsize=None)
def plan(k: int, n1: int, n2: int, buf: int | None = None):
    """(comp_layout, sync_layout, pre_tables, post_tables) for one weight."""
    c = comp_layout(k, n1, n2)
    s = sync_layout(k, n1, n2)
    b = buf if buf is not None else max(c.max_count, s.max_count)
    pre = reshard_tables(c, s, b)
    post = reshard_tables(s, c, b)
    return c, s, pre, post


def reshard_bytes_per_rank(src: Layout, dst: Layout, unit_bytes: int) -> np.ndarray:
    """Max(send, recv) bytes per rank — the paper's Fig. 8 x-axis numerator."""
    m = transfer_matrix(src, dst)
    off = m - np.diag(np.diag(m))
    return np.maximum(off.sum(1), off.sum(0)) * unit_bytes
