"""Sinks: where recorder events land (DESIGN.md §2.9).

A sink is anything with ``write(event: dict)`` (optionally ``flush`` /
``close``). Two ship here:

* `JsonlSink` — one JSON object per line, append-ordered, compact
  separators; the durable stream `launch.telemetry_report` folds into the
  goodput table and the Perfetto trace.
* `MemorySink` — a bounded in-memory ring (``collections.deque``) with
  query helpers; the test/benchmark sink (`bench_hotpath` reads its step
  medians from here instead of bespoke timers).
"""
from __future__ import annotations

import json
from collections import deque
from typing import Dict, List, Optional


class JsonlSink:
    """Writes every event as one compact JSON line. ``path`` is opened
    lazily on the first write (so configuring telemetry never creates empty
    files); pass a file-like object instead to control the stream."""

    def __init__(self, path_or_file, *, flush_every: int = 64):
        if hasattr(path_or_file, "write"):
            self._f, self._path, self._own = path_or_file, None, False
        else:
            self._f, self._path, self._own = None, path_or_file, True
        self._flush_every = max(1, flush_every)
        self._pending = 0
        self.written = 0

    def write(self, event: Dict) -> None:
        if self._f is None:
            self._f = open(self._path, "w")
        self._f.write(json.dumps(event, separators=(",", ":")) + "\n")
        self.written += 1
        self._pending += 1
        if self._pending >= self._flush_every:
            self.flush()

    def flush(self) -> None:
        if self._f is not None:
            self._f.flush()
        self._pending = 0

    def close(self) -> None:
        if self._f is not None:
            self._f.flush()
            if self._own:
                self._f.close()
                self._f = None


class MemorySink:
    """Bounded in-memory ring of events, oldest-dropped, with the query
    helpers the tests and benchmarks hang off."""

    def __init__(self, maxlen: Optional[int] = 65536):
        self._ring: deque = deque(maxlen=maxlen)

    def write(self, event: Dict) -> None:
        self._ring.append(event)

    def __len__(self) -> int:
        return len(self._ring)

    # --------------------------------------------------------------- queries

    @staticmethod
    def _match(ev: Dict, kind, name, labels) -> bool:
        if kind is not None and ev["kind"] != kind:
            return False
        if name is not None and ev["name"] != name:
            return False
        evl = ev.get("labels", {})
        return all(evl.get(k) == v for k, v in labels.items())

    def events(self, kind: Optional[str] = None, name: Optional[str] = None,
               **labels) -> List[Dict]:
        return [e for e in self._ring if self._match(e, kind, name, labels)]

    def values(self, name: str, **labels) -> List[float]:
        """Recorded values of a gauge/hist/counter series, in order."""
        return [e["value"] for e in self._ring
                if e["kind"] != "span" and self._match(e, None, name, labels)]

    def spans(self, name: Optional[str] = None, **labels) -> List[Dict]:
        return self.events(kind="span", name=name, **labels)

    def durations(self, name: str, **labels) -> List[float]:
        """Span durations (seconds) of one span series, in completion
        order."""
        return [e["dur"] for e in self.spans(name, **labels)]

    def clear(self) -> None:
        self._ring.clear()
