"""The recorder: one structured-observability surface for the whole runtime
(DESIGN.md §2.9).

Three primitives, one event stream:

* **counter** — monotonically accumulating count per (name, labels) series;
  every increment is emitted with the running ``total`` so a JSONL stream
  can be cut at any point and still read absolutely.
* **gauge** — a sampled value per (name, labels) series (goodput,
  rel_iter_time, power boost, per-replica rates).
* **hist** — raw observations (TTFT, TPOT, plan latencies); aggregation
  (count/mean/p50/p99) happens at read time (`summarize`), never at record
  time, so the stream stays lossless.
* **span** — a timed region (``with rec.span("session.step"): ...``) on the
  monotonic clock, with attachable attributes (`Span.set`) and intermediate
  phase marks (`Span.mark` — arrival→plan→execute→verified lifecycles).

Events are plain dicts pushed to pluggable sinks (telemetry/sinks.py); the
schema is FIXED per kind (`EVENT_KEYS`, guarded by the golden in
tests/golden/telemetry_schema.json):

* counter: ``{t, kind, name, value, total, labels}``
* gauge:   ``{t, kind, name, value, labels}``
* hist:    ``{t, kind, name, value, labels}``
* span:    ``{t0, t1, dur, kind, name, labels, attrs}``

Timestamps are seconds on ``time.perf_counter`` relative to the recorder's
creation (monotonic — wall-clock jumps never corrupt durations); the clock
is injectable for deterministic tests.

The **off path is the null recorder** (`NULL`): every method is a no-op and
``enabled`` is False, so instrumented code guarded by ``telemetry.get()``
adds a dict lookup and a no-op call — nothing else. Recorder-off behavior
is bit-identical to uninstrumented code by construction (no device syncs,
no numerics anywhere in this module).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

EVENT_KINDS = ("counter", "gauge", "hist", "span")

# the fixed JSONL schema per event kind (tests/golden/telemetry_schema.json)
EVENT_KEYS = {
    "counter": ("t", "kind", "name", "value", "total", "labels"),
    "gauge": ("t", "kind", "name", "value", "labels"),
    "hist": ("t", "kind", "name", "value", "labels"),
    "span": ("t0", "t1", "dur", "kind", "name", "labels", "attrs"),
}


def _series_key(name: str, labels: Dict) -> Tuple:
    return (name,) + tuple(sorted(labels.items()))


class Span:
    """One timed region. Created by `Recorder.span`; emits its event on
    ``__exit__``. ``set(**attrs)`` attaches attributes (e.g. the transition
    ledger's byte counts), ``mark(phase)`` records the phase's offset from
    span start into ``attrs["marks"]``."""

    __slots__ = ("_rec", "name", "labels", "attrs", "t0", "t1")

    def __init__(self, rec: "Recorder", name: str, labels: Dict):
        self._rec = rec
        self.name = name
        self.labels = labels
        self.attrs: Dict = {}
        self.t0 = None
        self.t1 = None

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def mark(self, phase: str) -> "Span":
        marks = self.attrs.setdefault("marks", {})
        marks[phase] = round(self._rec._now() - self.t0, 9)
        return self

    def __enter__(self) -> "Span":
        self.t0 = self._rec._now()
        return self

    def __exit__(self, *exc) -> None:
        self.t1 = self._rec._now()
        self._rec._emit({
            "t0": round(self.t0, 9), "t1": round(self.t1, 9),
            "dur": round(self.t1 - self.t0, 9),
            "kind": "span", "name": self.name,
            "labels": self.labels, "attrs": self.attrs,
        })


class _NullSpan:
    """Reusable no-op span (the off path). Stateless, so one singleton
    serves every ``with`` block."""

    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self

    def mark(self, phase: str) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_SPAN = _NullSpan()


class Recorder:
    """Counters + gauges + histograms + spans over pluggable sinks."""

    enabled = True

    def __init__(self, sinks: Iterable = (), *,
                 clock: Callable[[], float] = time.perf_counter,
                 meta: Optional[Dict] = None):
        self.sinks = list(sinks)
        self._clock = clock
        self._t0 = clock()
        self._totals: Dict[Tuple, float] = {}
        self.meta = dict(meta or {})

    # ------------------------------------------------------------- recording

    def _now(self) -> float:
        return self._clock() - self._t0

    def _emit(self, event: Dict) -> None:
        for s in self.sinks:
            s.write(event)

    def counter(self, name: str, value: float = 1, **labels) -> float:
        """Increment the (name, labels) series; returns the running total."""
        key = _series_key(name, labels)
        total = self._totals.get(key, 0) + value
        self._totals[key] = total
        self._emit({"t": round(self._now(), 9), "kind": "counter",
                    "name": name, "value": value, "total": total,
                    "labels": labels})
        return total

    def gauge(self, name: str, value: float, **labels) -> None:
        self._emit({"t": round(self._now(), 9), "kind": "gauge",
                    "name": name, "value": value, "labels": labels})

    def hist(self, name: str, value: float, **labels) -> None:
        self._emit({"t": round(self._now(), 9), "kind": "hist",
                    "name": name, "value": value, "labels": labels})

    def span(self, name: str, **labels) -> Span:
        return Span(self, name, labels)

    # --------------------------------------------------------------- queries

    def _memory(self):
        from repro.telemetry.sinks import MemorySink

        for s in self.sinks:
            if isinstance(s, MemorySink):
                return s
        raise LookupError(
            "recorder has no MemorySink — series queries need one "
            "(Recorder(sinks=[MemorySink(), ...]))"
        )

    def values(self, name: str, **labels) -> List[float]:
        """All recorded values of a gauge/hist/counter series, in order
        (requires a MemorySink)."""
        return self._memory().values(name, **labels)

    def spans(self, name: Optional[str] = None, **labels) -> List[Dict]:
        """All completed span events (requires a MemorySink)."""
        return self._memory().spans(name, **labels)

    def total(self, name: str, **labels) -> float:
        """Running total of a counter series (0 if never incremented)."""
        return self._totals.get(_series_key(name, labels), 0)

    # ------------------------------------------------------------- lifecycle

    def flush(self) -> None:
        for s in self.sinks:
            flush = getattr(s, "flush", None)
            if flush is not None:
                flush()

    def close(self) -> None:
        for s in self.sinks:
            close = getattr(s, "close", None)
            if close is not None:
                close()


class NullRecorder:
    """The off path: same surface as `Recorder`, every method a no-op.
    ``telemetry.get()`` returns the singleton `NULL` unless a recorder was
    configured, so uninstrumented behavior is preserved exactly."""

    enabled = False
    sinks: List = []
    meta: Dict = {}

    def counter(self, name: str, value: float = 1, **labels) -> float:
        return 0

    def gauge(self, name: str, value: float, **labels) -> None:
        return None

    def hist(self, name: str, value: float, **labels) -> None:
        return None

    def span(self, name: str, **labels) -> _NullSpan:
        return _NULL_SPAN

    def total(self, name: str, **labels) -> float:
        return 0

    def flush(self) -> None:
        return None

    def close(self) -> None:
        return None


NULL = NullRecorder()
