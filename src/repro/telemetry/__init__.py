"""`repro.telemetry` — the unified tracing/metrics spine (DESIGN.md §2.9).

Zero-dependency structured observability for every layer of the runtime:
`Recorder` (counters / gauges / histograms with labeled series), `span()`
context-manager tracing on monotonic clocks, and pluggable sinks (JSONL
stream, in-memory ring, Chrome-trace/Perfetto export). Instrumentation
sites across session / orchestrator / serve / cluster / kernels call
``telemetry.get()`` — the active recorder, or the no-op `NULL` recorder
when telemetry is off, which keeps the off path bit-identical to
uninstrumented code.

Typical wiring (the launchers' ``--telemetry out.jsonl``)::

    from repro import telemetry
    rec = telemetry.configure(jsonl="run.jsonl")   # becomes the active
    ... run ...                                    # recorder process-wide
    telemetry.shutdown()                           # flush + deactivate

    # offline: fold the stream into the goodput table + a Perfetto trace
    #   python -m repro.launch.telemetry_report run.jsonl --perfetto t.json

Scoped activation for tests/benchmarks::

    rec = Recorder(sinks=[MemorySink()])
    with telemetry.recording(rec):
        ...                           # instrumented code records into rec
    rec.spans("session.step")         # query the ring
"""
from __future__ import annotations

import atexit
from contextlib import contextmanager
from typing import Optional

from repro.telemetry.export import (
    chrome_trace, load_jsonl, summarize_hist, write_chrome_trace,
)
from repro.telemetry.recorder import (
    EVENT_KEYS, EVENT_KINDS, NULL, NullRecorder, Recorder, Span,
)
from repro.telemetry.sinks import JsonlSink, MemorySink

__all__ = [
    "Recorder", "NullRecorder", "Span", "NULL", "EVENT_KEYS", "EVENT_KINDS",
    "JsonlSink", "MemorySink",
    "chrome_trace", "write_chrome_trace", "load_jsonl", "summarize_hist",
    "get", "set_active", "configure", "recording", "shutdown",
]

_active = NULL
_atexit_registered = False


def get():
    """The active recorder (`NULL` when telemetry is off). Instrumentation
    sites call this per use — activation is dynamic, never cached."""
    return _active


def set_active(rec) -> None:
    """Install ``rec`` as the process-wide active recorder (None → off)."""
    global _active
    _active = NULL if rec is None else rec


def configure(*, jsonl: Optional[str] = None, memory: bool = False,
              memory_maxlen: Optional[int] = 65536, clock=None) -> Recorder:
    """Build a `Recorder` with the requested sinks, make it active, and
    flush it at interpreter exit. ``jsonl`` adds a `JsonlSink` at that path;
    ``memory=True`` adds a `MemorySink` ring (for in-process queries)."""
    global _atexit_registered
    sinks = []
    if jsonl is not None:
        sinks.append(JsonlSink(jsonl))
    if memory:
        sinks.append(MemorySink(maxlen=memory_maxlen))
    kw = {} if clock is None else {"clock": clock}
    rec = Recorder(sinks=sinks, **kw)
    set_active(rec)
    if not _atexit_registered:
        atexit.register(shutdown)
        _atexit_registered = True
    return rec


def shutdown() -> None:
    """Flush + close the active recorder's sinks and deactivate it."""
    global _active
    rec, _active = _active, NULL
    rec.close()


@contextmanager
def recording(rec):
    """Scoped activation: ``rec`` is active inside the block, the previous
    recorder is restored on exit (exception-safe)."""
    global _active
    prev = _active
    _active = NULL if rec is None else rec
    try:
        yield rec
    finally:
        _active = prev
