"""Exports: JSONL loading, Chrome-trace/Perfetto conversion, histogram
summaries (DESIGN.md §2.9).

The Chrome trace format (loadable by ``chrome://tracing`` and Perfetto's
trace viewer) maps:

* span events → ``ph: "X"`` complete events (``ts``/``dur`` in µs on the
  recorder's monotonic clock; labels + attrs land in ``args``, so a
  transition span carries its `TransferStats` byte counts into the UI);
* gauge/counter events → ``ph: "C"`` counter tracks (one named track per
  series, labels folded into the track name).

Rows are grouped into one process (pid 0) with the event name's dotted
prefix (``session.``, ``serve.``, ``orchestrator.``…) as the thread name,
so each subsystem gets its own swimlane.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional

import numpy as np


def load_jsonl(path: str) -> List[Dict]:
    """Parse one recorder JSONL stream; blank lines are skipped, any other
    parse failure raises (a telemetry file is append-only JSON lines — a
    corrupt line means the run died mid-write and the caller should know)."""
    out = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not a JSON event: {e}")
    return out


def _track(name: str, labels: Dict) -> str:
    if not labels:
        return name
    lbl = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
    return f"{name}{{{lbl}}}"


def chrome_trace(events: Iterable[Dict]) -> Dict:
    """Convert recorder events into a Chrome-trace JSON object (the
    ``chrome://tracing`` / Perfetto 'JSON trace' format)."""
    rows: List[Dict] = []
    tids: Dict[str, int] = {}

    def tid(name: str) -> int:
        group = name.split(".", 1)[0]
        if group not in tids:
            tids[group] = len(tids)
            rows.append({
                "name": "thread_name", "ph": "M", "pid": 0,
                "tid": tids[group], "args": {"name": group},
            })
        return tids[group]

    for ev in events:
        if ev["kind"] == "span":
            rows.append({
                "name": ev["name"], "cat": ev["kind"], "ph": "X",
                "ts": round(ev["t0"] * 1e6, 3),
                "dur": round(ev["dur"] * 1e6, 3),
                "pid": 0, "tid": tid(ev["name"]),
                "args": {**ev.get("labels", {}), **ev.get("attrs", {})},
            })
        elif ev["kind"] in ("gauge", "counter"):
            track = _track(ev["name"], ev.get("labels", {}))
            value = ev["total"] if ev["kind"] == "counter" else ev["value"]
            rows.append({
                "name": track, "cat": ev["kind"], "ph": "C",
                "ts": round(ev["t"] * 1e6, 3), "pid": 0,
                "args": {"value": value},
            })
        # hist events have no Chrome-trace counterpart (they aggregate at
        # report time); skipped by design
    return {"traceEvents": rows, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, events: Iterable[Dict]) -> Dict:
    doc = chrome_trace(events)
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return doc


def summarize_hist(values: List[float]) -> Optional[Dict]:
    """count/mean/p50/p95/p99/max of one histogram series (None if empty)."""
    if not values:
        return None
    v = np.asarray(values, dtype=float)
    return {
        "count": int(v.size),
        "mean": float(v.mean()),
        "p50": float(np.percentile(v, 50)),
        "p95": float(np.percentile(v, 95)),
        "p99": float(np.percentile(v, 99)),
        "max": float(v.max()),
    }
