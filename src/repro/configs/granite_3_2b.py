"""Granite-3.0 2B base.  [hf:ibm-granite/granite-3.0-2b-base]

40L d_model=2048 32H (GQA kv=8, head_dim 64) d_ff=8192 vocab=49155 — SwiGLU.
Pure full attention → long_500k skipped.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="granite-3-2b",
        family="dense",
        citation="hf:ibm-granite/granite-3.0-2b-base",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=49_155,
        layer_pattern=("attn",),
        rope_theta=10_000.0,
        ffn_act="silu",
        ffn_gated=True,
        tie_embeddings=True,
        supports_long_decode=False,
        long_decode_note="skipped: pure full-attention stack",
    )
)
