"""Mamba2-780m.  [arXiv:2405.21060]

48L d_model=1536, attention-free SSD (state-space duality), ssm_state=128,
d_inner = 2*d_model = 3072, SSD head dim 64 (48 heads), vocab=50280.
No MLP sub-block (d_ff=0): the Mamba block itself is the mixer+gate.
O(1)-state decode → long_500k runs natively.
"""
from repro.configs.base import ArchConfig, SSMSpec, register

CONFIG = register(
    ArchConfig(
        arch_id="mamba2-780m",
        family="ssm",
        citation="arXiv:2405.21060",
        n_layers=48,
        d_model=1536,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        layer_pattern=("ssm",),
        ssm=SSMSpec(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=256),
        use_rope=False,
        tie_embeddings=True,
        norm_eps=1e-5,
        supports_long_decode=True,
        long_decode_note="SSD recurrent state, O(1) per token",
    )
)
