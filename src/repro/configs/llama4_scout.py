"""Llama-4 Scout 17B-active / 16 experts.  [hf:meta-llama/Llama-4-Scout-17B-16E]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1
with an always-on shared expert; iRoPE-style chunked-local attention with a
global layer every 4th layer (chunk 8192) — this is what makes long_500k
decode sub-quadratic-feasible for this arch.
"""
from repro.configs.base import ArchConfig, MoESpec, register

CONFIG = register(
    ArchConfig(
        arch_id="llama4-scout-17b-a16e",
        family="moe",
        citation="hf:meta-llama/Llama-4-Scout-17B-16E",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        layer_pattern=("attn_chunked", "attn_chunked", "attn_chunked", "attn"),
        chunk_size=8192,
        rope_theta=500_000.0,
        ffn_act="silu",
        ffn_gated=True,
        moe=MoESpec(n_experts=16, top_k=1, shared_expert=True),
        supports_long_decode=True,
        long_decode_note="chunked-local attention (3:1 local:global, iRoPE)",
    )
)
