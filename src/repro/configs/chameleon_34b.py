"""Chameleon-34B.  [arXiv:2405.09818]

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 — early-fusion VLM:
the vocab interleaves text tokens and VQ-VAE image codes; the backbone is a
dense decoder with qk-layernorm (chameleon's divergence fix). The VQ image
tokenizer / vision frontend is a STUB per the brief — input_specs() supplies
already-fused token ids. Pure full attention → long_500k skipped.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="chameleon-34b",
        family="vlm",
        citation="arXiv:2405.09818",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65_536,
        layer_pattern=("attn",),
        qk_norm=True,
        ffn_act="silu",
        ffn_gated=True,
        norm_eps=1e-5,
        supports_long_decode=False,
        long_decode_note="skipped: pure full-attention stack",
    )
)
