"""Assigned input shapes (public-pool brief), plus the pipeline-stage
geometry helpers shared by the live runtime (`core/ntp_train`,
`runtime/session`) and the analytic config search (`core/perf_model`):
stage boundaries are DATA derived here, in one place, so the executable
stage-partitioned model and the perf model's candidate-PP enumeration can
never disagree about which PP degrees exist."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


# ---------------------------------------------------------------------------
# pipeline-stage geometry (DESIGN.md §2.6)

#: PP degrees the runtime's stage-sequential step supports (powers of two —
#: the same ladder the paper's Fig. 2 search walks). `candidate_pp` filters
#: this by the model's layer count; `core.perf_model.best_config` derives its
#: search space from it instead of a private hard-coded tuple.
SUPPORTED_PP: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)


def stage_boundaries(n_layers: int, pp: int) -> Tuple[int, ...]:
    """Contiguous layer→stage split: ``pp + 1`` boundaries; stage ``s`` owns
    layers ``[b[s], b[s+1])``. Balanced: the first ``n_layers % pp`` stages
    take one extra layer (ceil/floor), so no stage is ever empty."""
    if pp < 1:
        raise ValueError(f"pp must be >= 1, got {pp}")
    if pp > n_layers:
        raise ValueError(
            f"pp={pp} exceeds n_layers={n_layers}: a pipeline stage with no "
            "layers has nothing to compute"
        )
    base, extra = divmod(n_layers, pp)
    bounds = [0]
    for s in range(pp):
        bounds.append(bounds[-1] + base + (1 if s < extra else 0))
    return tuple(bounds)


def layer_stages(n_layers: int, pp: int) -> Tuple[int, ...]:
    """Owning stage per layer (inverse view of `stage_boundaries`)."""
    bounds = stage_boundaries(n_layers, pp)
    out = []
    for s in range(pp):
        out.extend([s] * (bounds[s + 1] - bounds[s]))
    return tuple(out)


def candidate_pp(n_layers: int, max_pp: int = SUPPORTED_PP[-1]) -> Tuple[int, ...]:
    """The runtime-supported PP degrees feasible for ``n_layers`` (every
    stage must own >= 1 layer) up to ``max_pp``."""
    return tuple(p for p in SUPPORTED_PP if p <= min(n_layers, max_pp))


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]
