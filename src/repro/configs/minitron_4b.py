"""Minitron-4B (pruned Nemotron).  [arXiv:2407.14679]

32L d_model=3072 24H (GQA kv=8, head_dim 128) d_ff=9216 vocab=256000.
Nemotron-style squared-ReLU non-gated MLP. Pure full attention →
long_500k skipped.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="minitron-4b",
        family="dense",
        citation="arXiv:2407.14679",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9216,
        vocab_size=256_000,
        layer_pattern=("attn",),
        ffn_act="relu2",
        ffn_gated=False,
        supports_long_decode=False,
        long_decode_note="skipped: pure full-attention stack",
    )
)
