"""Whisper-small.  [arXiv:2212.04356]

Enc-dec: 12L encoder + 12L decoder, d_model=768 12H (MHA, kv=12) d_ff=3072
vocab=51865, GELU (non-gated), absolute positions. Conv/mel frontend is a
STUB per the brief — input_specs() supplies (B, 1500, 768) frame embeddings.
Decoder is 448-token by design → long_500k skipped.
"""
from repro.configs.base import ArchConfig, EncoderSpec, register

CONFIG = register(
    ArchConfig(
        arch_id="whisper-small",
        family="audio",
        citation="arXiv:2212.04356",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51_865,
        layer_pattern=("attn",),
        use_rope=False,
        max_position=65_536,
        ffn_act="gelu",
        ffn_gated=False,
        encoder=EncoderSpec(n_layers=12, enc_seq=1500),
        norm_type="ln",
        tie_embeddings=True,
        supports_long_decode=False,
        long_decode_note="skipped: enc-dec, 448-token decoder by design",
    )
)
