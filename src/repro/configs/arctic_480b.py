"""Snowflake Arctic 480B.  [hf:Snowflake/snowflake-arctic-base]

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000.
Dense-MoE hybrid: every layer has a dense residual FFN (d_ff=4864) in
parallel with a 128-expert top-2 MoE (per-expert d_ff=4864).
Pure full attention → long_500k skipped.
"""
from repro.configs.base import ArchConfig, MoESpec, register

CONFIG = register(
    ArchConfig(
        arch_id="arctic-480b",
        family="moe",
        citation="hf:Snowflake/snowflake-arctic-base",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32_000,
        layer_pattern=("attn",),
        ffn_act="silu",
        ffn_gated=True,
        moe=MoESpec(n_experts=128, top_k=2, dense_residual=True),
        supports_long_decode=False,
        long_decode_note="skipped: pure full-attention stack",
    )
)
