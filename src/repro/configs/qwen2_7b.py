"""Qwen2-7B.  [arXiv:2407.10671]

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 — SwiGLU, QKV bias.
Pure full attention → long_500k skipped (noted in DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="qwen2-7b",
        family="dense",
        citation="arXiv:2407.10671",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152_064,
        layer_pattern=("attn",),
        attn_bias=True,
        rope_theta=1_000_000.0,
        ffn_act="silu",
        ffn_gated=True,
        supports_long_decode=False,
        long_decode_note="skipped: pure full-attention stack",
    )
)
