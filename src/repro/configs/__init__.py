"""Config registry: importing this package registers all assigned archs."""
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    EncoderSpec,
    MoESpec,
    RGLRUSpec,
    SSMSpec,
    all_archs,
    get_arch,
    reduced,
)
from repro.configs.shapes import SHAPES, ShapeSpec, get_shape  # noqa: F401

# registration side effects (order = the assignment table)
from repro.configs import llama4_scout  # noqa: F401,E402
from repro.configs import qwen2_7b  # noqa: F401,E402
from repro.configs import whisper_small  # noqa: F401,E402
from repro.configs import mamba2_780m  # noqa: F401,E402
from repro.configs import recurrentgemma_9b  # noqa: F401,E402
from repro.configs import gemma2_9b  # noqa: F401,E402
from repro.configs import arctic_480b  # noqa: F401,E402
from repro.configs import granite_3_2b  # noqa: F401,E402
from repro.configs import chameleon_34b  # noqa: F401,E402
from repro.configs import minitron_4b  # noqa: F401,E402

ARCH_IDS = tuple(all_archs().keys())
