"""Gemma2-9B.  [arXiv:2408.00118]

42L d_model=3584 16H (GQA kv=8, head_dim 256) d_ff=14336 vocab=256000.
Alternating local(4096-window)/global attention, attention logit softcap 50,
final logit softcap 30, GeGLU, gemma embedding scaling, tied embeddings.
long_500k runs: sliding-window local layers + global layers decode against
the full (data-axis-sharded) cache — linear per decoded token.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="gemma2-9b",
        family="dense",
        citation="arXiv:2408.00118",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256_000,
        layer_pattern=("attn_sw", "attn"),
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        ffn_act="gelu",
        ffn_gated=True,
        post_norms=True,
        scale_embeddings=True,
        tie_embeddings=True,
        supports_long_decode=True,
        long_decode_note="1:1 sliding:global alternation (native gemma2)",
    )
)
