"""RecurrentGemma-9B (Griffin).  [arXiv:2402.19427]

38L d_model=4096 16H (MQA kv=1, head_dim 256) d_ff=12288 vocab=256000.
Pattern: (rglru, rglru, attn_sw) — RG-LRU : local attention 2:1 with a
2048-token sliding window. GeGLU MLP, gemma-style embedding scaling.
Recurrent + local-attn → long_500k runs natively.
"""
from repro.configs.base import ArchConfig, RGLRUSpec, register

CONFIG = register(
    ArchConfig(
        arch_id="recurrentgemma-9b",
        family="hybrid",
        citation="arXiv:2402.19427",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        layer_pattern=("rglru", "rglru", "attn_sw"),
        window=2048,
        rglru=RGLRUSpec(d_conv=4, block_width=128),
        ffn_act="gelu",
        ffn_gated=True,
        scale_embeddings=True,
        tie_embeddings=True,
        supports_long_decode=True,
        long_decode_note="RG-LRU state + 2k sliding-window attention",
    )
)
