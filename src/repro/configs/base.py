"""Architecture configuration system.

Pure dataclasses — this module must NOT import jax (dryrun.py sets
XLA_FLAGS before any jax import and imports configs first).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kinds usable in ``layer_pattern`` (cycled across n_layers):
#   'attn'         full causal self-attention
#   'attn_sw'      sliding-window causal self-attention (window = cfg.window)
#   'attn_chunked' chunked-local causal attention (chunk = cfg.chunk_size)
#   'attn_bidir'   full bidirectional self-attention (encoders)
#   'ssm'          Mamba-2 SSD block (no separate MLP; mixer includes gating)
#   'rglru'        RG-LRU recurrent block (RecurrentGemma/Griffin)
ATTN_KINDS = ("attn", "attn_sw", "attn_chunked", "attn_bidir")
BLOCK_KINDS = ATTN_KINDS + ("ssm", "rglru")


@dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts settings for the FFN sub-block."""

    n_experts: int
    top_k: int
    shared_expert: bool = False      # llama4: always-on shared expert
    dense_residual: bool = False     # arctic: dense FFN in parallel w/ MoE
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01    # load-balance loss coefficient


@dataclass(frozen=True)
class SSMSpec:
    """Mamba-2 SSD settings."""

    d_state: int = 128
    head_dim: int = 64               # SSD head size (d_inner / n_heads)
    expand: int = 2                  # d_inner = expand * d_model
    d_conv: int = 4
    chunk: int = 256                 # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUSpec:
    """RG-LRU (Griffin / RecurrentGemma) settings."""

    expand: int = 3                  # d_inner = ceil(expand/2)*2? griffin uses 3*d/2 rounded
    d_conv: int = 4
    block_width: int = 128           # diagonal-recurrence channel block (NTP unit)

    def d_inner(self, d_model: int) -> int:
        # RecurrentGemma uses lru_width = d_model (9b: 4096); keep 1x.
        return d_model


@dataclass(frozen=True)
class EncoderSpec:
    """Encoder stack for enc-dec models (whisper). Frontend is a stub:
    input_specs() provides precomputed frame embeddings (B, enc_seq, d_model)."""

    n_layers: int
    enc_seq: int = 1500              # whisper: 30 s of audio → 1500 frames


@dataclass(frozen=True)
class ArchConfig:
    # identity
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    citation: str

    # trunk dims
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer structure
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 4096               # for attn_sw
    chunk_size: int = 8192           # for attn_chunked

    # attention details
    attn_bias: bool = False          # qwen2: QKV bias
    qk_norm: bool = False            # chameleon: qk-layernorm
    attn_softcap: Optional[float] = None   # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    rope_theta: float = 10_000.0
    use_rope: bool = True            # whisper uses absolute positions
    max_position: int = 1_048_576    # abs-position table size when use_rope=False

    # ffn details
    ffn_act: str = "silu"            # silu | gelu | relu2
    ffn_gated: bool = True           # SwiGLU/GeGLU vs plain
    moe: Optional[MoESpec] = None

    # alt mixers
    ssm: Optional[SSMSpec] = None
    rglru: Optional[RGLRUSpec] = None

    # enc-dec
    encoder: Optional[EncoderSpec] = None

    # misc
    norm_type: str = "rms"           # rms | ln (whisper)
    post_norms: bool = False         # gemma2: post-sublayer RMSNorms too
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # gemma-style sqrt(d_model) scaling
    norm_eps: float = 1e-6
    logit_dtype: str = "float32"

    # capability flags
    supports_long_decode: bool = False   # eligible for long_500k
    long_decode_note: str = ""

    # ---- derived ----------------------------------------------------------
    def padded_vocab(self, multiple: int = 256) -> int:
        """Megatron-style vocab padding so embedding/LM-head shard over TP."""
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple

    def block_kind(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    def n_params(self) -> int:
        """Approximate parameter count (embedding + trunk), for 6ND math."""
        p = self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            p += self.vocab_size * self.d_model
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind in ATTN_KINDS:
                qkv = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
                out = self.attn_dim * self.d_model
                p += qkv + out
                p += self._ffn_params()
            elif kind == "ssm":
                assert self.ssm is not None
                di = self.ssm.d_inner(self.d_model)
                nh = self.ssm.n_heads(self.d_model)
                # in_proj (z,x,B,C,dt) + out_proj + conv
                p += self.d_model * (2 * di + 2 * self.ssm.d_state + nh)
                p += di * self.d_model
                p += (di + 2 * self.ssm.d_state) * self.ssm.d_conv
            elif kind == "rglru":
                assert self.rglru is not None
                di = self.rglru.d_inner(self.d_model)
                p += self.d_model * di * 2 + di * self.d_model  # x/gate in, out
                p += di * self.rglru.d_conv + 2 * di            # conv + lru gates
                p += self._ffn_params()
            p += 2 * self.d_model  # norms
        if self.encoder is not None:
            # encoder layers: self-attn + ffn; decoder cross-attn extra
            enc = self.encoder.n_layers * (
                4 * self.d_model * self.attn_dim + self._ffn_params() + 2 * self.d_model
            )
            xattn = self.n_layers * (4 * self.d_model * self.attn_dim + self.d_model)
            p += enc + xattn
        return p

    def _ffn_params(self) -> int:
        if self.d_ff == 0:
            return 0
        mult = 3 if self.ffn_gated else 2
        dense = mult * self.d_model * self.d_ff
        if self.moe is None:
            return dense
        p = self.moe.n_experts * dense + self.d_model * self.moe.n_experts  # router
        if self.moe.shared_expert:
            p += dense
        if self.moe.dense_residual:
            p += dense
        return p

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k experts count)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        dense = 3 if self.ffn_gated else 2
        ffn_one = dense * self.d_model * self.d_ff
        inactive = (self.moe.n_experts - self.moe.top_k) * ffn_one
        n_moe_layers = sum(
            1 for i in range(self.n_layers) if self.block_kind(i) in ATTN_KINDS
        )
        return full - n_moe_layers * inactive


# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.arch_id}")
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_arch(arch_id: str) -> ArchConfig:
    from repro import configs  # noqa: F401  (ensure registration ran)

    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def all_archs() -> dict:
    from repro import configs  # noqa: F401

    return dict(_REGISTRY)


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant of the same family: ≤2–4 layers, d_model ≤ 512,
    ≤4 experts. Keeps the layer pattern semantics (one full cycle)."""
    n_layers = max(2, min(4, len(cfg.layer_pattern)))
    n_kv = 1 if cfg.n_kv_heads == 1 else 2
    changes = dict(
        arch_id=cfg.arch_id + "-smoke",
        n_layers=n_layers,
        d_model=256,
        n_heads=4,
        n_kv_heads=n_kv,
        head_dim=64,
        d_ff=0 if cfg.d_ff == 0 else 512,
        vocab_size=512,
        max_position=4096,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(4, cfg.moe.n_experts), top_k=min(2, cfg.moe.top_k)
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=32, head_dim=64, chunk=32)
    if cfg.rglru is not None:
        changes["rglru"] = dataclasses.replace(cfg.rglru, block_width=64)
    if cfg.encoder is not None:
        changes["encoder"] = dataclasses.replace(cfg.encoder, n_layers=2, enc_seq=64)
    return dataclasses.replace(cfg, **changes)
