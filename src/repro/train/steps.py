"""Step functions (train / prefill / decode) with full sharding metadata.

``make_setup`` assembles, for one (arch × input-shape × mesh):
  * the model (with activation-sharding constraints bound to the mesh),
  * parameter / optimizer-state / cache shardings,
  * the jittable step function + its in/out shardings,
so launch/train.py, launch/dryrun.py, benchmarks and tests all share one
code path. mesh=None gives the single-device variant used by unit tests.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import input_specs
from repro.models import ShardCtx, build_model
from repro.models.common import NO_SHARD
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.sharding import param_shardings
from repro.sharding.specs import zero1_shardings


# ---------------------------------------------------------------------------
# loss

def cross_entropy(logits, targets, real_vocab: int):
    """Mean next-token CE over (B,S). Handles Megatron vocab padding by
    masking padded logits; fp32 reductions."""
    logits = logits.astype(jnp.float32)
    vp = logits.shape[-1]
    if vp != real_vocab:
        pad = jnp.arange(vp) >= real_vocab
        logits = jnp.where(pad[None, None, :], -1e30, logits)
    m = jax.lax.stop_gradient(logits.max(-1, keepdims=True))
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


# ---------------------------------------------------------------------------
# setup bundle

@dataclass
class Setup:
    cfg: ArchConfig
    shape: ShapeSpec
    mesh: Optional[Mesh]
    model: Any
    opt_cfg: Optional[AdamWConfig]
    # shardings (None when mesh is None)
    param_sharding: Any = None
    opt_sharding: Any = None
    cache_sharding: Any = None
    batch_specs: Dict[str, jax.ShapeDtypeStruct] = field(default_factory=dict)
    # step callables (un-jitted)
    step_fn: Callable = None
    # jit kwargs
    in_shardings: Any = None
    out_shardings: Any = None
    donate_argnums: Tuple[int, ...] = ()

    def jit_step(self):
        kw = {}
        if self.mesh is not None:
            kw = dict(in_shardings=self.in_shardings, out_shardings=self.out_shardings)
        return jax.jit(self.step_fn, donate_argnums=self.donate_argnums, **kw)

    def abstract_args(self, key=jax.random.PRNGKey(0)):
        """ShapeDtypeStruct args for .lower() — no allocation."""
        pshape = jax.eval_shape(self.model.init, key)
        args = [_attach(pshape, self.param_sharding)]
        if self.shape.kind == "train":
            oshape = jax.eval_shape(lambda p: adamw_init(p, self.opt_cfg), pshape)
            args.append(_attach(oshape, self.opt_sharding))
            args.append(dict(self.batch_specs))
        elif self.shape.kind == "prefill":
            args.append(dict(self.batch_specs))
        else:  # decode
            cshape = jax.eval_shape(
                lambda: self.model.init_cache(
                    self.shape.global_batch, self.shape.seq_len, jnp.bfloat16
                )
            )
            args.append(_attach(cshape, self.cache_sharding))
            args.append(dict(self.batch_specs))
        return tuple(args)


def _attach(shape_tree, sharding_tree):
    if sharding_tree is None:
        return shape_tree
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shape_tree,
        sharding_tree,
    )


# ---------------------------------------------------------------------------

def make_setup(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh: Optional[Mesh] = None,
    *,
    dp_axes: Tuple[str, ...] = ("data",),
    param_dtype=jnp.bfloat16,
    opt_cfg: Optional[AdamWConfig] = None,
    remat: bool = True,
    scan_unroll: bool = False,
    microbatches: int = 1,
    lr_schedule: Callable = functools.partial(warmup_cosine, warmup=100, total=10_000),
) -> Setup:
    """``microbatches`` > 1 splits each train step's batch into that many
    chunks and accumulates gradients across them (the PP-style 1F1B schedule
    at the arch-stack level — one optimizer update per step, peak activation
    memory ∝ 1/m; `core.perf_model.iteration_time` charges the matching
    (pp-1)/m bubble). ``microbatches=1`` is the exact unchanged step."""
    if shape.kind == "train":
        if not 1 <= microbatches <= shape.global_batch:
            raise ValueError(
                f"microbatches={microbatches} outside "
                f"[1, global_batch={shape.global_batch}]"
            )
        if shape.global_batch % microbatches:
            raise ValueError(
                f"global_batch={shape.global_batch} not divisible by "
                f"microbatches={microbatches}"
            )
        if microbatches > 1 and cfg.moe is not None:
            # the MoE load-balance aux loss is nonlinear in per-batch
            # routing statistics: mean-of-chunk aux != full-batch aux, so
            # grad accumulation would NOT equal the microbatches=1 step —
            # refuse rather than silently change training with m
            raise ValueError(
                f"microbatches={microbatches} with a MoE arch "
                f"({cfg.arch_id}): the load-balance aux loss is not "
                "additive over microbatch chunks, so accumulated grads "
                "would differ from the full-batch step"
            )
    elif microbatches != 1:
        raise ValueError(f"microbatches only applies to train shapes, "
                         f"got kind={shape.kind!r}")
    # C2 gate (measured, EXPERIMENTS.md §Perf): SP wins on train steps for
    # non-rglru / non-post-norm archs; it loses slightly on prefill (no
    # backward to amortize the extra seq<->head transitions) and on rglru
    # (sequence recurrence) / post-norm archs (extra transitions).
    sp = (
        shape.kind == "train"
        and "rglru" not in cfg.layer_pattern
        and not cfg.post_norms
    )
    ctx = ShardCtx(mesh=mesh, dp=dp_axes, sp=sp) if mesh is not None else NO_SHARD
    model = build_model(cfg, ctx, param_dtype=param_dtype, remat=remat)
    model.scan_unroll = scan_unroll
    opt_cfg = opt_cfg or AdamWConfig()
    bspecs = input_specs(cfg, shape, mesh, dp_axes)

    su = Setup(cfg=cfg, shape=shape, mesh=mesh, model=model, opt_cfg=opt_cfg,
               batch_specs=bspecs)

    if mesh is not None:
        pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        su.param_sharding = param_shardings(mesh, model.param_specs(), pshape)
        if shape.kind == "train":
            oshape = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), pshape)
            ospec = {
                "m": model.param_specs(),
                "v": model.param_specs(),
                "step": P(),
            }
            if "master" in oshape:
                ospec["master"] = model.param_specs()
            su.opt_sharding = {
                k: (
                    zero1_shardings(mesh, ospec[k], oshape[k], dp_axes)
                    if k != "step"
                    else NamedSharding(mesh, P())
                )
                for k in oshape
            }
        if shape.kind == "decode":
            cshape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len, jnp.bfloat16)
            )
            cspec = model.cache_specs(cshape)
            su.cache_sharding = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp), cspec,
                is_leaf=lambda x: isinstance(x, P),
            )

    # ---- step functions ---------------------------------------------------
    if shape.kind == "train":

        def loss_fn(params, batch):
            logits, aux = model.forward(
                params, batch["tokens"], enc_input=batch.get("enc_input")
            )
            loss = cross_entropy(logits, batch["targets"], cfg.vocab_size)
            return loss + aux["moe_aux_loss"], loss

        def train_step(params, opt_state, batch):
            (total, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            lr_scale = lr_schedule(opt_state["step"])
            params, opt_state, metrics = adamw_update(
                grads, opt_state, params, opt_cfg, lr_scale
            )
            metrics.update(loss=ce, total_loss=total)
            return params, opt_state, metrics

        def microbatched_train_step(params, opt_state, batch):
            # stage-sequential 1F1B emulation: each microbatch runs the full
            # forward/backward; grads (mean-per-microbatch) average to the
            # full-batch gradient since every chunk has equal size
            m = microbatches
            mb = shape.global_batch // m
            grads = None
            total = ce = jnp.float32(0.0)
            for j in range(m):
                sl = {k: (v[j * mb:(j + 1) * mb]
                          if hasattr(v, "ndim") and v.ndim >= 1
                          and v.shape[0] == shape.global_batch else v)
                      for k, v in batch.items()}
                (t, c), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, sl
                )
                total, ce = total + t, ce + c
                grads = g if grads is None else jax.tree.map(
                    jnp.add, grads, g
                )
            grads = jax.tree.map(lambda x: x / m, grads)
            lr_scale = lr_schedule(opt_state["step"])
            params, opt_state, metrics = adamw_update(
                grads, opt_state, params, opt_cfg, lr_scale
            )
            metrics.update(loss=ce / m, total_loss=total / m,
                           microbatches=jnp.int32(m))
            return params, opt_state, metrics

        su.step_fn = train_step if microbatches == 1 else microbatched_train_step
        su.donate_argnums = (0, 1)
        if mesh is not None:
            su.in_shardings = (
                su.param_sharding,
                su.opt_sharding,
                {k: v.sharding for k, v in bspecs.items()},
            )
            su.out_shardings = (
                su.param_sharding,
                su.opt_sharding,
                None,
            )

    elif shape.kind == "prefill":

        def prefill_step(params, batch):
            cache = model.init_cache(shape.global_batch, shape.seq_len, jnp.bfloat16)
            logits, cache = model.prefill(
                params, batch["tokens"], cache, enc_input=batch.get("enc_input")
            )
            return logits[:, -1], cache

        su.step_fn = prefill_step
        if mesh is not None:
            cshape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len, jnp.bfloat16)
            )
            cspec = model.cache_specs(cshape)
            cache_sh = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp), cspec,
                is_leaf=lambda x: isinstance(x, P),
            )
            su.cache_sharding = cache_sh
            su.in_shardings = (
                su.param_sharding,
                {k: v.sharding for k, v in bspecs.items()},
            )
            su.out_shardings = (None, cache_sh)

    else:  # decode

        def serve_step(params, cache, batch):
            logits, cache = model.decode_step(
                params, cache, batch["tokens"], batch["pos"],
                enc_out=batch.get("enc_out"),
            )
            return logits[:, 0], cache

        su.step_fn = serve_step
        su.donate_argnums = (1,)
        if mesh is not None:
            su.in_shardings = (
                su.param_sharding,
                su.cache_sharding,
                {k: (v.sharding if v.sharding is not None else None)
                 for k, v in bspecs.items()},
            )
            su.out_shardings = (None, su.cache_sharding)

    return su
