from repro.train.steps import (  # noqa: F401
    Setup,
    cross_entropy,
    make_setup,
)
