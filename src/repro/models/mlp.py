"""Dense MLP (SwiGLU/GeGLU/plain) and Mixture-of-Experts FFN.

TP layout (paper §3.1, Eq. 1-3): up/gate projections column-partitioned,
down projection row-partitioned over the `model` axis. MoE: the expert
dimension is sharded over `model` (expert parallelism — 16 experts/16 ranks
for llama4, 8 experts/rank for arctic), dispatch/combine is a sort-based
capacity-bounded scatter (drop on overflow), the standard TPU-friendly
formulation (no (N,E,C) one-hot blowup).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoESpec
from repro.models.common import ShardCtx, act_fn, dense_init


# ---------------------------------------------------------------------------
# dense MLP

def mlp_init(cfg: ArchConfig, key, dtype, d_ff: Optional[int] = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d, ff), d, dtype),
        "w_down": dense_init(ks[1], (ff, d), ff, dtype),
    }
    if cfg.ffn_gated:
        p["w_gate"] = dense_init(ks[2], (d, ff), d, dtype)
    return p


def mlp_specs(cfg: ArchConfig, tp: str = "model") -> dict:
    s = {"w_up": P(None, tp), "w_down": P(tp, None)}
    if cfg.ffn_gated:
        s["w_gate"] = P(None, tp)
    return s


def mlp_apply(cfg: ArchConfig, p: dict, x, ctx: ShardCtx):
    act = act_fn(cfg.ffn_act)
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if cfg.ffn_gated:
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * h
    else:
        h = act(h)
    h = ctx.hidden(h)
    return ctx.batch(jnp.einsum("bsf,fd->bsd", h, p["w_down"]))


# ---------------------------------------------------------------------------
# MoE

def moe_init(cfg: ArchConfig, key, dtype) -> dict:
    assert cfg.moe is not None
    m, d, ff = cfg.moe, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), d, jnp.float32),
        "w_up": dense_init(ks[1], (m.n_experts, d, ff), d, dtype),
        "w_down": dense_init(ks[2], (m.n_experts, ff, d), ff, dtype),
    }
    if cfg.ffn_gated:
        p["w_gate"] = dense_init(ks[3], (m.n_experts, d, ff), d, dtype)
    if m.shared_expert:
        p["shared"] = mlp_init(cfg, ks[4], dtype)
    if m.dense_residual:
        p["dense"] = mlp_init(cfg, ks[4], dtype)
    return p


def moe_specs(cfg: ArchConfig, tp: str = "model") -> dict:
    assert cfg.moe is not None
    s = {
        "router": P(None, None),
        "w_up": P(tp, None, None),   # expert-parallel over the scale-up domain
        "w_down": P(tp, None, None),
    }
    if cfg.ffn_gated:
        s["w_gate"] = P(tp, None, None)
    if cfg.moe.shared_expert:
        s["shared"] = mlp_specs(cfg, tp)
    if cfg.moe.dense_residual:
        s["dense"] = mlp_specs(cfg, tp)
    return s


def _route(m: MoESpec, logits):
    """(N,E) router logits -> (N,k) expert ids + fp32 combine weights."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return idx, w, probs


def moe_apply(cfg: ArchConfig, p: dict, x, ctx: ShardCtx) -> Tuple[jnp.ndarray, dict]:
    """Returns (out, aux) — aux carries the load-balance loss (Switch-style)."""
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.n_experts, m.top_k
    cap = int(max(1, round(n * k / e * m.capacity_factor)))
    act = act_fn(cfg.ffn_act)
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    idx, wts, probs = _route(m, logits)  # (N,k)

    # ---- sort-based dispatch -------------------------------------------
    flat_e = idx.reshape(-1)                      # (N*k,) expert of each slot
    order = jnp.argsort(flat_e)                   # stable
    sorted_e = flat_e[order]
    # position within expert = rank among same-expert slots
    start = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos_in_e = jnp.arange(n * k) - start[sorted_e]
    keep = pos_in_e < cap                          # overflow drops (std. Switch)
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # OOB => dropped

    tok = order // k                               # source token of each slot
    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[dest].set(xf[tok], mode="drop")
    buf = buf.reshape(e, cap, d)
    buf = ctx.cons(buf, P(ctx.tp, None, None))     # expert-parallel buffers

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if cfg.ffn_gated:
        h = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * h
    else:
        h = act(h)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(e * cap, d)
    y = ctx.cons(y.reshape(e, cap, d), P(ctx.tp, None, None)).reshape(e * cap, d)

    # ---- combine --------------------------------------------------------
    gathered = jnp.where(keep[:, None], y[dest], 0)      # (N*k, d) sorted order
    slot_w = wts.reshape(-1)[order]
    contrib = gathered * slot_w[:, None].astype(x.dtype)
    out = jnp.zeros((n, d), x.dtype).at[tok].add(contrib)
    out = out.reshape(b, s, d)

    if m.shared_expert:
        out = out + mlp_apply(cfg, p["shared"], x, ctx)
    if m.dense_residual:
        out = out + mlp_apply(cfg, p["dense"], x, ctx)

    # Switch load-balance aux loss: E * sum_e f_e * P_e
    f = jnp.zeros(e, jnp.float32).at[flat_e].add(1.0) / (n * k)
    pmean = probs.mean(0)
    aux = {"moe_aux_loss": e * jnp.sum(f * pmean) * m.router_aux_coef}
    return ctx.batch(out), aux


def _local_dispatch_compute(cfg: ArchConfig, p_local, xf, cap: int):
    """Sort-based dispatch + expert compute for one rank's token slice,
    with experts split across the TP axis via all-to-all (expert parallelism).

    xf: (n, d) local tokens; p_local experts already (E/tp, d, ff).
    Runs INSIDE shard_map. Returns (out (n, d), f, pmean) for the aux loss.
    """
    m = cfg.moe
    act = act_fn(cfg.ffn_act)
    n, d = xf.shape
    e, k = m.n_experts, m.top_k

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p_local["router"])
    idx, wts, probs = _route(m, logits)

    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos_in_e = jnp.arange(n * k) - start[sorted_e]
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
    tok = order // k

    buf = jnp.zeros((e * cap, d), xf.dtype).at[dest].set(xf[tok], mode="drop")
    buf = buf.reshape(e, cap, d)

    # ---- expert parallelism: one all-to-all ships each expert's slots to
    # the rank that owns it (paper's NVL-domain all-to-all -> ICI)
    buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                             tiled=True)              # (E/tp, cap*tp, d)
    h = jnp.einsum("ecd,edf->ecf", buf, p_local["w_up"])
    if cfg.ffn_gated:
        h = act(jnp.einsum("ecd,edf->ecf", buf, p_local["w_gate"])) * h
    else:
        h = act(h)
    y = jnp.einsum("ecf,efd->ecd", h, p_local["w_down"])
    y = jax.lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                           tiled=True)                # (E, cap, d)

    gathered = jnp.where(keep[:, None], y.reshape(e * cap, d)[dest], 0)
    slot_w = wts.reshape(-1)[order]
    out = jnp.zeros((n, d), xf.dtype).at[tok].add(
        gathered * slot_w[:, None].astype(xf.dtype)
    )
    f = jnp.zeros(e, jnp.float32).at[flat_e].add(1.0) / (n * k)
    return out, f, probs.mean(0)


def moe_apply_expert_parallel(cfg: ArchConfig, p: dict, x, ctx: ShardCtx):
    """§Perf iteration A1 (beyond-paper): two-stage MoE dispatch.

    The baseline einsum/scatter dispatch lets GSPMD materialize the global
    (E, C, d) buffer on every rank (TB-scale all-reduces — see EXPERIMENTS.md
    §Perf pair A). Here each (data, model) device dispatches its own token
    slice locally and a single tiled all-to-all over the scale-up domain
    routes slots to the expert owners; tokens return on the reverse
    all-to-all and an all-gather rebuilds the TP-replicated activations.
    Capacity is per (rank, expert) — the standard TPU MoE semantics.
    """
    from jax.sharding import PartitionSpec

    from repro.compat import shard_map

    m = cfg.moe
    mesh, tp = ctx.mesh, ctx.tp
    b, s, d = x.shape
    tp_size = mesh.shape[tp]
    dp_size = 1
    for a in ctx.dp:
        dp_size *= mesh.shape[a]
    n_rep = (b // dp_size) * s              # tokens per replica
    if b % dp_size:
        out, aux = moe_apply(cfg, p, x, ctx)     # fallback
        return out, aux
    # §Perf D1: decode has fewer tokens/replica than TP ranks — pad tokens
    # up to a multiple of tp so the all-to-all path applies there too (the
    # einsum fallback read ~170× the activated-expert weight floor).
    n_pad = (-n_rep) % tp_size
    n_tot = n_rep + n_pad
    n_loc = n_tot // tp_size
    cap = int(max(1, round(n_loc * m.top_k / m.n_experts * m.capacity_factor) + (1 if n_pad else 0)))

    expert_keys = [k_ for k_ in ("w_up", "w_gate", "w_down") if k_ in p]

    def body(xl, router, *expert_ws):
        p_local = dict(zip(expert_keys, expert_ws))
        p_local["router"] = router
        bl = xl.shape[0]
        xf = xl.reshape(bl * s, d)
        if n_pad:
            xf = jnp.concatenate(
                [xf, jnp.zeros((n_pad, d), xf.dtype)], axis=0
            )
        r = jax.lax.axis_index(tp)
        mine = jax.lax.dynamic_slice_in_dim(xf, r * n_loc, n_loc, axis=0)
        out, f, pmean = _local_dispatch_compute(cfg, p_local, mine, cap)
        full = jax.lax.all_gather(out, tp, axis=0, tiled=True)  # (n_tot, d)
        full = full[:n_rep]
        f = jax.lax.pmean(f, (tp,) + tuple(ctx.dp))
        pmean = jax.lax.pmean(pmean, (tp,) + tuple(ctx.dp))
        aux = m.n_experts * jnp.sum(f * pmean) * m.router_aux_coef
        return full.reshape(bl, s, d), aux

    P_ = PartitionSpec
    in_specs = [P_(ctx.dp, None, None), P_(None, None)] + [
        P_(tp, None, None) for _ in expert_keys
    ]
    fn = shard_map(
        body, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P_(ctx.dp, None, None), P_()),
        check_vma=False,
    )
    out, aux = fn(x, p["router"], *[p[k_] for k_ in expert_keys])

    if m.shared_expert:
        out = out + mlp_apply(cfg, p["shared"], x, ctx)
    if m.dense_residual:
        out = out + mlp_apply(cfg, p["dense"], x, ctx)
    return ctx.batch(out), {"moe_aux_loss": aux}


def moe_apply_dense_ref(cfg: ArchConfig, p: dict, x, ctx: ShardCtx):
    """O(E·N) oracle: every expert processes every token, masked combine.
    Used by tests to validate the sort-based dispatch (ignoring capacity
    drops, so tests use capacity_factor high enough that nothing drops)."""
    m = cfg.moe
    b, s, d = x.shape
    act = act_fn(cfg.ffn_act)
    xf = x.reshape(-1, d)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    idx, wts, _ = _route(m, logits)
    h = jnp.einsum("nd,edf->enf", xf, p["w_up"])
    if cfg.ffn_gated:
        h = act(jnp.einsum("nd,edf->enf", xf, p["w_gate"])) * h
    else:
        h = act(h)
    y = jnp.einsum("enf,efd->end", h, p["w_down"])  # (E,N,d)
    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32)  # (N,k,E)
    w_e = (onehot * wts[..., None]).sum(1)  # (N,E)
    out = jnp.einsum("end,ne->nd", y.astype(jnp.float32), w_e).astype(x.dtype)
    out = out.reshape(b, s, d)
    if m.shared_expert:
        out = out + mlp_apply(cfg, p["shared"], x, ctx)
    if m.dense_residual:
        out = out + mlp_apply(cfg, p["dense"], x, ctx)
    return out
