from repro.models.common import NO_SHARD, ShardCtx  # noqa: F401
from repro.models.transformer import Model, build_model  # noqa: F401
