"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrence h_t = a_t ⊙ h_{t-1} + sqrt(1-a_t²) ⊙ (i_t ⊙ x_t) is diagonal,
so training uses jax.lax.associative_scan (log-depth parallel prefix — the
TPU-native formulation of the paper's linear recurrence) and decode is the
O(1) per-token update.

TP layout: d_inner channels sharded over `model`; the gate projections are
block-diagonal (block_width channels per block, Griffin-style) so each block
is a clean NTP partition unit (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.common import ShardCtx, dense_init
from repro.models.ssm import _causal_conv

_C = 8.0  # Griffin's fixed recurrence sharpness


def rglru_init(cfg: ArchConfig, key, dtype) -> dict:
    g = cfg.rglru
    d = cfg.d_model
    di = g.d_inner(d)
    nb, w = di // g.block_width, g.block_width
    ks = jax.random.split(key, 7)
    # Λ init so that a = sigmoid(Λ)^c lands in [0.9, 0.999] (Griffin app. A)
    u = jax.random.uniform(ks[0], (di,), jnp.float32, minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.exp(-jnp.log(u) / _C) - 1.0)  # softplus^-1(-log u / c)
    return {
        "w_x": dense_init(ks[1], (d, di), d, dtype),
        "w_y": dense_init(ks[2], (d, di), d, dtype),
        "conv_w": dense_init(ks[3], (g.d_conv, di), g.d_conv, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "gate_a": dense_init(ks[4], (nb, w, w), w, dtype),
        "gate_i": dense_init(ks[5], (nb, w, w), w, dtype),
        "bias_a": jnp.zeros((di,), jnp.float32),
        "bias_i": jnp.zeros((di,), jnp.float32),
        "lam": lam,
        "w_out": dense_init(ks[6], (di, d), di, dtype),
    }


def rglru_specs(cfg: ArchConfig, tp: str = "model") -> dict:
    return {
        "w_x": P(None, tp),
        "w_y": P(None, tp),
        "conv_w": P(None, tp),
        "conv_b": P(tp),
        "gate_a": P(tp, None, None),
        "gate_i": P(tp, None, None),
        "bias_a": P(tp),
        "bias_i": P(tp),
        "lam": P(tp),
        "w_out": P(tp, None),
    }


def _gates(p, xb, nb, w):
    """Block-diagonal gate projections. xb: (B,S,di) fp32."""
    b, s, di = xb.shape
    xr = xb.reshape(b, s, nb, w)
    ra = jnp.einsum("bsnw,nwv->bsnv", xr, p["gate_a"].astype(jnp.float32))
    ri = jnp.einsum("bsnw,nwv->bsnv", xr, p["gate_i"].astype(jnp.float32))
    r = jax.nn.sigmoid(ra.reshape(b, s, di) + p["bias_a"])
    i = jax.nn.sigmoid(ri.reshape(b, s, di) + p["bias_i"])
    return r, i


def rglru_apply(
    cfg: ArchConfig,
    p: dict,
    x,
    ctx: ShardCtx,
    cache: Optional[dict] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """cache (decode): {'conv': (B,K-1,di), 'h': (B,di) fp32}."""
    g = cfg.rglru
    b, s, d = x.shape
    di = g.d_inner(d)
    nb, w = di // g.block_width, g.block_width

    xb = jnp.einsum("bsd,df->bsf", x, p["w_x"])
    conv_state = cache["conv"] if cache is not None else None
    xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    xb = ctx.hidden(xb)

    xf = xb.astype(jnp.float32)
    r, i = _gates(p, xf, nb, w)
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (B,S,di) ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0, 1.0))
    bterm = beta * (i * xf)

    h0 = cache["h"] if cache is not None else jnp.zeros((b, di), jnp.float32)
    if cache is not None and s == 1:
        h = a[:, 0] * h0 + bterm[:, 0]
        y = h[:, None]
        new_h = h
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        a_sc, b_sc = jax.lax.associative_scan(combine, (a, bterm), axis=1)
        y = b_sc + a_sc * h0[:, None, :]                 # fold in initial state
        new_h = y[:, -1]

    yg = jax.nn.gelu(
        jnp.einsum("bsd,df->bsf", x, p["w_y"]).astype(jnp.float32), approximate=True
    )
    out = (y * yg).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", out, p["w_out"])
    new_cache = {"conv": new_conv, "h": new_h}
    return ctx.batch(out), new_cache


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    g = cfg.rglru
    di = g.d_inner(cfg.d_model)
    return {
        "conv": jnp.zeros((batch, g.d_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di), jnp.float32),
    }
