"""Shared model utilities: sharding context, norms, activations, RoPE."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardCtx:
    """Names the mesh axes the model should constrain activations to.

    ``mesh=None`` (default, e.g. unit tests on 1 device) makes every
    constraint a no-op so model code never branches.
    """

    mesh: Optional[Mesh] = None
    dp: Tuple[str, ...] = ("data",)   # batch axes ('pod','data') multi-pod
    tp: Optional[str] = "model"       # tensor-parallel axis (scale-up domain)
    sp: bool = True                   # §Perf C2: sequence-parallel residual
    #   stream (gated off for rglru patterns — the RG-LRU recurrence runs
    #   over the sequence and cannot compute seq-sharded)

    def cons(self, x, spec: P):
        if self.mesh is None:
            return x
        spec = sanitize_spec(dict(self.mesh.shape), x.shape, spec)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def batch(self, x):
        """(B, ...) activations: batch over dp axes."""
        return self.cons(x, P(self.dp, *([None] * (x.ndim - 1))))

    def residual(self, x):
        """(B, S, d) residual stream between sublayers.

        §Perf iteration C2 (beyond-paper, Megatron-LM sequence parallelism):
        shard the sequence over the TP axis so norms/elementwise run 1/tp-th
        as wide and GSPMD turns the TP all-reduce into reduce-scatter +
        all-gather at the sublayer boundary. REPRO_BASELINE_SP=1 restores
        the replicated residual stream."""
        import os as _os

        if not self.sp:
            return x  # leave layout to XLA (baseline behaviour)
        if (
            self.mesh is None
            or self.tp is None
            or _os.environ.get("REPRO_BASELINE_SP")
            or x.ndim != 3
            or x.shape[1] % self.mesh.shape[self.tp] != 0
            or x.shape[1] < 2 * self.mesh.shape[self.tp]
        ):
            return self.batch(x)
        return self.cons(x, P(self.dp, self.tp, None))

    def heads(self, x):
        """(B, S, H, hd): q heads over tp (kv heads are NOT constrained —
        kv_heads < tp for every assigned arch, Megatron replicates them)."""
        return self.cons(x, P(self.dp, None, self.tp, None))

    def hidden(self, x):
        """(B, S, ff) MLP hidden: ff over tp."""
        return self.cons(x, P(self.dp, None, self.tp))

    def replicated(self, x):
        return self.cons(x, P(*([None] * x.ndim)))


def sanitize_spec(mesh_shape: dict, shape, spec: P) -> P:
    """Drop spec axes whose mesh-size does not divide the dim (e.g. 12 whisper
    heads over model=16 → replicate instead of erroring)."""
    out = []
    for d, names in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if names is None:
            out.append(None)
            continue
        tup = names if isinstance(names, tuple) else (names,)
        size = 1
        for n in tup:
            size *= mesh_shape[n]
        out.append(names if shape[d] % size == 0 else None)
    return P(*out)


NO_SHARD = ShardCtx(mesh=None)


# ---------------------------------------------------------------------------
# norms / activations

def rms_norm(x, weight, eps: float, *, plus_one: bool = False):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    return (y * w).astype(dt)


def layer_norm(x, weight, bias, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# rotary embeddings

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) or (S,) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)|(S,hd/2)
    if angles.ndim == 2:  # (S, hd/2) -> broadcast batch
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# initializers (fan-in scaled normal, Megatron-style)

def dense_init(key, shape, in_axis_size, dtype):
    scale = in_axis_size ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
