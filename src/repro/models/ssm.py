"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD: intra-chunk "dual" (attention-like) form + inter-chunk state
recurrence via lax.scan — the TPU-friendly formulation (matmul-heavy,
MXU-aligned chunk length). Decode is the O(1) recurrent update.

TP layout: d_inner channels (== contiguous SSD heads) sharded over `model`;
B/C state projections replicated (ngroups=1 ≈ MQA for states) — the NTP
partition unit is the SSD head, mirroring attention heads (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.common import ShardCtx, dense_init, rms_norm


def ssm_init(cfg: ArchConfig, key, dtype) -> dict:
    s = cfg.ssm
    d, di, ds, nh = cfg.d_model, s.d_inner(cfg.d_model), s.d_state, s.n_heads(cfg.d_model)
    ks = jax.random.split(key, 8)
    return {
        "w_z": dense_init(ks[0], (d, di), d, dtype),
        "w_x": dense_init(ks[1], (d, di), d, dtype),
        "w_B": dense_init(ks[2], (d, ds), d, dtype),
        "w_C": dense_init(ks[3], (d, ds), d, dtype),
        "w_dt": dense_init(ks[4], (d, nh), d, dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "conv_w": dense_init(ks[5], (s.d_conv, di + 2 * ds), s.d_conv, dtype),
        "conv_b": jnp.zeros((di + 2 * ds,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[6], (nh,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[7], (di, d), di, dtype),
    }


def ssm_specs(cfg: ArchConfig, tp: str = "model") -> dict:
    return {
        "w_z": P(None, tp),
        "w_x": P(None, tp),
        "w_B": P(None, None),
        "w_C": P(None, None),
        "w_dt": P(None, tp),
        "dt_bias": P(tp),
        "conv_w": P(None, None),  # mixed di+2ds channels; small — replicate
        "conv_b": P(None),
        "A_log": P(tp),
        "D": P(tp),
        "norm": P(tp),
        "w_out": P(tp, None),
    }


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv1d. xBC: (B,S,C); conv_w: (K,C).
    conv_state: (B,K-1,C) tail of previous tokens (decode) or None (train)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xBC.shape[0], k - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = conv_state.astype(xBC.dtype)
    full = jnp.concatenate([pad, xBC], axis=1)  # (B, S+K-1, C)
    out = sum(
        full[:, i : i + xBC.shape[1], :] * conv_w[i][None, None, :] for i in range(k)
    )
    new_state = full[:, -(k - 1) :, :]
    return jax.nn.silu(out + conv_b), new_state


def _ssd_chunked(x, dt, A, B, C, h0, chunk: int):
    """Chunked SSD scan.

    x: (B,S,nh,hp)  dt: (B,S,nh)  A: (nh,)<0  B,C: (B,S,ds)
    h0: (B,nh,hp,ds) initial state.
    Returns y (B,S,nh,hp), h_final.
    """
    b, s, nh, hp = x.shape
    ds = B.shape[-1]
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L

    xr = x.reshape(b, nc, L, nh, hp)
    dtr = dt.reshape(b, nc, L, nh)
    Br = B.reshape(b, nc, L, ds)
    Cr = C.reshape(b, nc, L, ds)

    a = dtr * A[None, None, None, :]              # (b,nc,L,nh) ≤ 0
    acum = jnp.cumsum(a, axis=2)                  # inclusive cumsum
    atot = acum[:, :, -1, :]                      # (b,nc,nh)

    # intra-chunk (dual/attention-like) term
    G = jnp.einsum("bcis,bcjs->bcij", Cr, Br)     # (b,nc,L,L)
    decay = jnp.exp(
        jnp.clip(acum[:, :, :, None, :] - acum[:, :, None, :, :], -60.0, 0.0)
    )                                             # (b,nc,i,j,nh)
    mask = jnp.tril(jnp.ones((L, L), bool))
    M = G[:, :, :, :, None] * decay * mask[None, None, :, :, None]
    M = M * dtr[:, :, None, :, :]                 # weight by dt_j
    y_diag = jnp.einsum("bcijn,bcjnp->bcinp", M, xr)

    # per-chunk state injection:  sum_j exp(atot - acum_j) dt_j B_j x_j
    w = jnp.exp(jnp.clip(atot[:, :, None, :] - acum, -60.0, 0.0)) * dtr  # (b,nc,L,nh)
    chunk_state = jnp.einsum("bcjn,bcjs,bcjnp->bcnps", w, Br, xr)

    def step(h, inp):
        cs, at = inp                              # (b,nh,hp,ds), (b,nh)
        h_new = h * jnp.exp(at)[:, :, None, None] + cs
        return h_new, h                           # emit PRE-chunk state

    cs_seq = chunk_state.transpose(1, 0, 2, 3, 4)  # (nc,b,nh,hp,ds)
    at_seq = atot.transpose(1, 0, 2)
    h_final, h_prevs = jax.lax.scan(step, h0, (cs_seq, at_seq))

    # inter-chunk (state) term: C_i exp(acum_i) h_prev
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)    # (b,nc,nh,hp,ds)
    cdec = jnp.exp(jnp.clip(acum, -60.0, 0.0))    # (b,nc,L,nh)
    y_off = jnp.einsum("bcis,bcnps,bcin->bcinp", Cr, h_prevs, cdec)

    y = (y_diag + y_off).reshape(b, s, nh, hp)
    return y, h_final


def ssm_apply(
    cfg: ArchConfig,
    p: dict,
    x,
    ctx: ShardCtx,
    cache: Optional[dict] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """cache (decode): {'conv': (B,K-1,di+2ds), 'h': (B,nh,hp,ds)}."""
    s = cfg.ssm
    b, S, d = x.shape
    di, ds = s.d_inner(d), s.d_state
    nh, hp = s.n_heads(d), s.head_dim

    z = jnp.einsum("bsd,df->bsf", x, p["w_z"])
    xi = jnp.einsum("bsd,df->bsf", x, p["w_x"])
    Bp = jnp.einsum("bsd,df->bsf", x, p["w_B"])
    Cp = jnp.einsum("bsd,df->bsf", x, p["w_C"])
    dt_raw = jnp.einsum("bsd,dn->bsn", x, p["w_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])

    xBC = jnp.concatenate([xi, Bp, Cp], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xi, Bp, Cp = jnp.split(xBC, [di, di + ds], axis=-1)
    xi = ctx.hidden(xi)

    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(b, S, nh, hp).astype(jnp.float32)
    Bf, Cf = Bp.astype(jnp.float32), Cp.astype(jnp.float32)

    if cache is not None and S == 1:
        # recurrent decode: h' = exp(dt A) h + dt B x ; y = C·h' + D x
        h = cache["h"]
        dt1 = dt[:, 0]                             # (b,nh)
        da = jnp.exp(dt1 * A[None, :])             # (b,nh)
        inj = jnp.einsum("bn,bs,bnp->bnps", dt1, Bf[:, 0], xh[:, 0])
        h_new = h * da[:, :, None, None] + inj
        y = jnp.einsum("bs,bnps->bnp", Cf[:, 0], h_new)[:, None]
        new_cache = {"conv": new_conv, "h": h_new}
    else:
        h0 = (
            cache["h"]
            if cache is not None
            else jnp.zeros((b, nh, hp, ds), jnp.float32)
        )
        y, h_final = _ssd_chunked(xh, dt, A, Bf, Cf, h0, s.chunk)
        new_cache = {"conv": new_conv, "h": h_final}

    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(b, S, di).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, p["w_out"])
    return ctx.batch(out), new_cache


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di, ds, nh, hp = s.d_inner(d), s.d_state, s.n_heads(d), s.head_dim
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * ds), dtype),
        "h": jnp.zeros((batch, nh, hp, ds), jnp.float32),
    }
