"""Model assembly: decoder-only / enc-dec transformers over heterogeneous
block patterns (attention variants, SSD, RG-LRU), with lax.scan layer stacks,
KV/state caches, and parallel param/sharding-spec construction.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ATTN_KINDS, ArchConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import NO_SHARD, ShardCtx, embed_init, layer_norm, rms_norm, softcap


# ---------------------------------------------------------------------------
# norms

def norm_init(cfg: ArchConfig, dtype) -> dict:
    p = {"w": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm_type == "ln":
        p["w"] = jnp.ones((cfg.d_model,), dtype)
        p["b"] = jnp.zeros((cfg.d_model,), dtype)
    elif cfg.norm_type == "rms":
        # gemma-style (1+w): init w to zero
        p["w"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def norm_specs(cfg: ArchConfig) -> dict:
    s = {"w": P(None)}
    if cfg.norm_type == "ln":
        s["b"] = P(None)
    return s


def norm_apply(cfg: ArchConfig, p: dict, x):
    if cfg.norm_type == "ln":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps, plus_one=True)


# ---------------------------------------------------------------------------
# blocks

def _has_ffn(cfg: ArchConfig, kind: str) -> bool:
    return cfg.d_ff > 0 and kind != "ssm"


def block_init(cfg: ArchConfig, key, dtype, kind: str, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": norm_init(cfg, dtype)}
    if kind in ATTN_KINDS:
        p["mixer"] = attn_mod.attn_init(cfg, ks[0], dtype)
    elif kind == "ssm":
        p["mixer"] = ssm_mod.ssm_init(cfg, ks[0], dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_mod.rglru_init(cfg, ks[0], dtype)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        p["ln1_post"] = norm_init(cfg, dtype)
    if cross:
        p["ln_cross"] = norm_init(cfg, dtype)
        p["cross"] = attn_mod.attn_init(cfg, ks[1], dtype, cross=True)
    if _has_ffn(cfg, kind):
        p["ln2"] = norm_init(cfg, dtype)
        if cfg.moe is not None and kind in ATTN_KINDS:
            p["ffn"] = mlp_mod.moe_init(cfg, ks[2], dtype)
        else:
            p["ffn"] = mlp_mod.mlp_init(cfg, ks[2], dtype)
        if cfg.post_norms:
            p["ln2_post"] = norm_init(cfg, dtype)
    return p


def block_specs(cfg: ArchConfig, kind: str, tp: str = "model", *, cross: bool = False) -> dict:
    s: Dict[str, Any] = {"ln1": norm_specs(cfg)}
    if kind in ATTN_KINDS:
        s["mixer"] = attn_mod.attn_specs(cfg, tp)
    elif kind == "ssm":
        s["mixer"] = ssm_mod.ssm_specs(cfg, tp)
    elif kind == "rglru":
        s["mixer"] = rglru_mod.rglru_specs(cfg, tp)
    if cfg.post_norms:
        s["ln1_post"] = norm_specs(cfg)
    if cross:
        s["ln_cross"] = norm_specs(cfg)
        s["cross"] = attn_mod.attn_specs(cfg, tp, cross=True)
    if _has_ffn(cfg, kind):
        s["ln2"] = norm_specs(cfg)
        if cfg.moe is not None and kind in ATTN_KINDS:
            s["ffn"] = mlp_mod.moe_specs(cfg, tp)
        else:
            s["ffn"] = mlp_mod.mlp_specs(cfg, tp)
        if cfg.post_norms:
            s["ln2_post"] = norm_specs(cfg)
    return s


def block_apply(
    cfg: ArchConfig,
    p: dict,
    x,
    *,
    kind: str,
    ctx: ShardCtx,
    positions,
    cache: Optional[dict],
    cache_pos,
    enc_out=None,
):
    """Returns (x, new_cache, aux_loss_scalar)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(cfg, p["ln1"], x)
    new_cache = None
    # enc-dec block caches carry the banked encoder K/V ('ek'/'ev') next to
    # the mixer's own state — split them off before the mixer sees the dict
    cross_cache = None
    self_cache = cache
    if cache is not None and "ek" in cache:
        cross_cache = {"ek": cache["ek"], "ev": cache["ev"]}
        self_cache = {n: c for n, c in cache.items() if n not in ("ek", "ev")}
    if kind in ATTN_KINDS:
        out, new_cache = attn_mod.attn_apply(
            cfg, p["mixer"], h, kind=kind, ctx=ctx, positions=positions,
            cache=self_cache, cache_pos=cache_pos,
        )
    elif kind == "ssm":
        out, new_cache = ssm_mod.ssm_apply(cfg, p["mixer"], h, ctx,
                                           cache=self_cache)
    elif kind == "rglru":
        out, new_cache = rglru_mod.rglru_apply(cfg, p["mixer"], h, ctx,
                                               cache=self_cache)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        out = norm_apply(cfg, p["ln1_post"], ctx.residual(out))
    x = ctx.residual(x + out)

    if "cross" in p:
        hc = norm_apply(cfg, p["ln_cross"], x)
        out, new_cross = attn_mod.attn_apply(
            cfg, p["cross"], hc, kind="attn_bidir", ctx=ctx,
            positions=positions, kv_x=enc_out, use_rope=False,
            cross_cache=cross_cache,
        )
        x = ctx.residual(x + out)
        if cross_cache is not None:
            new_cache = dict(new_cache, **new_cross)

    if _has_ffn(cfg, kind):
        h2 = norm_apply(cfg, p["ln2"], x)
        if cfg.moe is not None and kind in ATTN_KINDS:
            # §Perf A1: expert-parallel (shard_map all-to-all) dispatch on a
            # mesh; REPRO_BASELINE_MOE=1 restores the baseline einsum path.
            import os as _os

            if ctx.mesh is not None and not _os.environ.get("REPRO_BASELINE_MOE"):
                out, moe_aux = mlp_mod.moe_apply_expert_parallel(
                    cfg, p["ffn"], h2, ctx
                )
            else:
                out, moe_aux = mlp_mod.moe_apply(cfg, p["ffn"], h2, ctx)
            aux = aux + moe_aux["moe_aux_loss"]
        else:
            out = mlp_mod.mlp_apply(cfg, p["ffn"], h2, ctx)
        if cfg.post_norms:
            out = norm_apply(cfg, p["ln2_post"], ctx.residual(out))
        x = ctx.residual(x + out)
    return x, new_cache, aux


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype):
    if kind in ATTN_KINDS:
        c = attn_mod.init_kv_cache(cfg, batch, max_len, dtype, kind=kind)
    elif kind == "ssm":
        c = ssm_mod.init_ssm_cache(cfg, batch, dtype)
    elif kind == "rglru":
        c = rglru_mod.init_rglru_cache(cfg, batch, dtype)
    else:
        raise ValueError(kind)
    if cfg.encoder is not None:
        # EVERY enc-dec decoder block cross-attends (attention, SSD and
        # rgLRU alike — block_init gives them all a cross module): bank the
        # encoder K/V next to the mixer's own state
        c = dict(c, **attn_mod.init_cross_kv_cache(cfg, batch, dtype))
    return c


# ---------------------------------------------------------------------------
# model

def _split_layers(cfg: ArchConfig) -> Tuple[int, int]:
    pat = len(cfg.layer_pattern)
    return cfg.n_layers // pat, cfg.n_layers % pat


@dataclass
class Model:
    """Functional model bundle for one architecture."""

    cfg: ArchConfig
    ctx: ShardCtx = NO_SHARD
    param_dtype: Any = jnp.float32
    remat: bool = True
    # Dry-run/roofline mode: fully unroll the layer scans. XLA's
    # cost_analysis counts a while-loop body ONCE (not ×trip-count), so
    # straight-line HLO is required for exact FLOP/collective accounting.
    scan_unroll: bool = False

    def _scan(self, body, carry, xs):
        unroll = len(jax.tree.leaves(xs)[0]) if self.scan_unroll else 1
        return jax.lax.scan(body, carry, xs, unroll=unroll)

    # ---- params ----------------------------------------------------------
    def init(self, key) -> dict:
        cfg, dt = self.cfg, self.param_dtype
        n_cyc, n_tail = _split_layers(cfg)
        cross = cfg.encoder is not None
        keys = jax.random.split(key, 8)
        vp = cfg.padded_vocab()

        params: Dict[str, Any] = {
            "embed": embed_init(keys[0], (vp, cfg.d_model), dt),
            "final_norm": norm_init(cfg, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(keys[1], (cfg.d_model, vp), dt)
        if not cfg.use_rope and any(k in ATTN_KINDS for k in cfg.layer_pattern):
            params["pos_embed"] = embed_init(keys[2], (cfg.max_position, cfg.d_model), dt)

        def cycle_init(k):
            kk = jax.random.split(k, len(cfg.layer_pattern))
            return tuple(
                block_init(cfg, kk[i], dt, kind, cross=cross)
                for i, kind in enumerate(cfg.layer_pattern)
            )

        if n_cyc > 0:
            params["layers"] = jax.vmap(cycle_init)(jax.random.split(keys[3], n_cyc))
        params["tail"] = tuple(
            block_init(cfg, k, dt, cfg.layer_pattern[i], cross=cross)
            for i, k in enumerate(jax.random.split(keys[4], n_tail))
        ) if n_tail else ()

        if cfg.encoder is not None:
            ek = jax.random.split(keys[5], cfg.encoder.n_layers + 1)

            def enc_cycle_init(k):
                return (block_init(cfg, k, dt, "attn_bidir"),)

            params["encoder"] = {
                "layers": jax.vmap(enc_cycle_init)(
                    jax.random.split(ek[0], cfg.encoder.n_layers)
                ),
                "final_norm": norm_init(cfg, dt),
            }
        return params

    def param_specs(self) -> dict:
        cfg = self.cfg
        tp = self.ctx.tp or "model"
        n_cyc, n_tail = _split_layers(cfg)
        cross = cfg.encoder is not None

        def stack(spec_tree):
            return jax.tree.map(
                lambda s: P(*((None,) + tuple(s))), spec_tree,
                is_leaf=lambda s: isinstance(s, P),
            )

        specs: Dict[str, Any] = {
            "embed": P(tp, None),
            "final_norm": norm_specs(cfg),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = P(None, tp)
        if not cfg.use_rope and any(k in ATTN_KINDS for k in cfg.layer_pattern):
            specs["pos_embed"] = P(None, None)
        cyc = tuple(
            block_specs(cfg, kind, tp, cross=cross) for kind in cfg.layer_pattern
        )
        if n_cyc > 0:
            specs["layers"] = stack(cyc)
        specs["tail"] = tuple(
            block_specs(cfg, cfg.layer_pattern[i], tp, cross=cross)
            for i in range(n_tail)
        )
        if cfg.encoder is not None:
            specs["encoder"] = {
                "layers": stack((block_specs(cfg, "attn_bidir", tp),)),
                "final_norm": norm_specs(cfg),
            }
        return specs

    # ---- caches ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        n_cyc, n_tail = _split_layers(cfg)

        def one_cycle(_):
            return tuple(
                init_block_cache(cfg, kind, batch, max_len, dtype)
                for kind in cfg.layer_pattern
            )

        cache: Dict[str, Any] = {}
        if n_cyc > 0:
            cache["layers"] = jax.vmap(one_cycle)(jnp.arange(n_cyc))
        cache["tail"] = tuple(
            init_block_cache(cfg, cfg.layer_pattern[i], batch, max_len, dtype)
            for i in range(n_tail)
        )
        return cache

    def cache_specs(self, cache) -> dict:
        """Shard caches: batch over dp if divisible, else KV seq over tp
        (context-parallel decode for batch=1 long-context)."""
        ctx = self.ctx
        mesh = ctx.mesh
        if mesh is None:
            return jax.tree.map(lambda x: P(), cache)
        dp_size = 1
        for a in ctx.dp:
            dp_size *= mesh.shape[a]

        def spec_for(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            stacked = any(
                getattr(q, "key", None) == "layers" for q in path
            )
            lead = (None,) if stacked else ()
            b_axis = ctx.dp if leaf.shape[len(lead)] % dp_size == 0 else None
            if name in ("k", "v", "ek", "ev"):
                # (B, T, kvh, hd): batch over dp; if batch unshardable,
                # sequence over dp AND tp (long_500k context parallelism)
                if b_axis is not None:
                    return P(*lead, b_axis, ctx.tp, None, None)
                return P(*lead, None, tuple(ctx.dp) + (ctx.tp,), None, None)
            if name == "conv":
                return P(*lead, b_axis, None, ctx.tp)
            if name == "h":
                rest = (ctx.tp,) + (None,) * (leaf.ndim - len(lead) - 2)
                return P(*lead, b_axis, *rest)
            return P(*([None] * leaf.ndim))

        from repro.models.common import sanitize_spec

        return jax.tree_util.tree_map_with_path(
            lambda pth, leaf: sanitize_spec(dict(mesh.shape), leaf.shape, spec_for(pth, leaf)),
            cache,
        )

    # ---- forward ---------------------------------------------------------
    def _embed(self, params, tokens, positions):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.scale_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if "pos_embed" in params:
            x = x + jnp.take(params["pos_embed"], positions, axis=0)[None]
        return self.ctx.residual(x)

    def _encode(self, params, enc_input):
        """enc_input: precomputed frame embeddings (B, T_enc, d) (stub)."""
        cfg = self.cfg
        x = self.ctx.batch(enc_input)
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)

        def cycle(x, lp):
            x, _, _ = block_apply(
                cfg, lp[0], x, kind="attn_bidir", ctx=self.ctx,
                positions=pos, cache=None, cache_pos=None,
            )
            return x, None

        body = jax.checkpoint(cycle) if self.remat else cycle
        x, _ = self._scan(body, x, params["encoder"]["layers"])
        return norm_apply(cfg, params["encoder"]["final_norm"], x)

    def _trunk(self, params, x, positions, cache, cache_pos, enc_out):
        cfg = self.cfg
        n_cyc, n_tail = _split_layers(cfg)
        aux0 = jnp.zeros((), jnp.float32)

        def cycle(carry, xs):
            x, aux = carry
            lp, lc = xs
            new_cs = []
            for i, kind in enumerate(cfg.layer_pattern):
                x, nc, a = block_apply(
                    cfg, lp[i], x, kind=kind, ctx=self.ctx, positions=positions,
                    cache=None if lc is None else lc[i],
                    cache_pos=cache_pos, enc_out=enc_out,
                )
                aux = aux + a
                new_cs.append(nc)
            return (x, aux), tuple(new_cs)

        body = jax.checkpoint(cycle) if self.remat else cycle

        new_cache: Dict[str, Any] = {}
        if n_cyc > 0:
            lc = cache["layers"] if cache is not None else None
            if lc is None:
                (x, aux), _ = self._scan(
                    lambda c, lp: body(c, (lp, None)), (x, aux0), params["layers"]
                )
            else:
                (x, aux), new_lc = self._scan(
                    body, (x, aux0), (params["layers"], lc)
                )
                new_cache["layers"] = new_lc
        else:
            aux = aux0

        tail_caches = []
        for i in range(n_tail):
            lc_i = cache["tail"][i] if cache is not None else None
            x, nc, a = block_apply(
                cfg, params["tail"][i], x, kind=cfg.layer_pattern[i], ctx=self.ctx,
                positions=positions, cache=lc_i, cache_pos=cache_pos, enc_out=enc_out,
            )
            aux = aux + a
            tail_caches.append(nc)
        if cache is not None:
            new_cache["tail"] = tuple(tail_caches)
        return x, aux, (new_cache if cache is not None else None)

    def _logits(self, params, x):
        cfg = self.cfg
        x = norm_apply(cfg, params["final_norm"], x)
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        )
        logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
        logits = softcap(logits, cfg.final_softcap)
        return self.ctx.cons(logits, P(self.ctx.dp, None, self.ctx.tp))

    def forward(self, params, tokens, *, enc_input=None, positions=None):
        """Teacher-forced forward. Returns (logits, aux)."""
        if positions is None:
            positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        enc_out = (
            self._encode(params, enc_input) if self.cfg.encoder is not None else None
        )
        x = self._embed(params, tokens, positions)
        x, aux, _ = self._trunk(params, x, positions, None, None, enc_out)
        return self._logits(params, x), {"moe_aux_loss": aux}

    def prefill(self, params, tokens, cache, *, enc_input=None):
        """Forward that also fills the cache from position 0."""
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        enc_out = (
            self._encode(params, enc_input) if self.cfg.encoder is not None else None
        )
        x = self._embed(params, tokens, positions)
        x, aux, new_cache = self._trunk(
            params, x, positions, cache, jnp.int32(0), enc_out
        )
        return self._logits(params, x), new_cache

    def decode_step(self, params, cache, tokens, pos, *, enc_out=None):
        """One-token decode. tokens: (B,1); pos: scalar int32 (write index)."""
        positions = pos + jnp.arange(1, dtype=jnp.int32)
        x = self._embed(params, tokens, positions)
        x, _, new_cache = self._trunk(params, x, positions, cache, pos, enc_out)
        return self._logits(params, x), new_cache

    # ---- continuous-batching decode (repro.serve) ------------------------
    def init_slot_cache(self, slots: int, max_len: int, dtype=jnp.bfloat16) -> dict:
        """Slot-stacked cache for continuous batching: every leaf of the
        single-sequence cache gains a LEADING slot axis, so each slot can sit
        at its own decode position (`decode_slots` vmaps over it)."""
        return jax.vmap(lambda _: self.init_cache(1, max_len, dtype))(
            jnp.arange(slots)
        )

    def decode_slots(self, params, cache, tokens, pos):
        """Per-slot one-token decode over an `init_slot_cache` cache:
        ``tokens`` (slots,) int32 current token per slot, ``pos`` (slots,)
        int32 per-slot write index — positions are ragged across slots.
        Returns (logits (slots, vocab_padded), new_cache)."""

        def one(c, t, p):
            logits, nc = self.decode_step(params, c, t.reshape(1, 1), p)
            return logits[0, 0], nc

        return jax.vmap(one)(cache, tokens, pos)


def build_model(cfg: ArchConfig, ctx: ShardCtx = NO_SHARD, *, param_dtype=jnp.float32,
                remat: bool = True) -> Model:
    return Model(cfg=cfg, ctx=ctx, param_dtype=param_dtype, remat=remat)
