"""Self/cross attention with GQA, sliding-window / chunked-local masks,
logit softcap, qk-norm, RoPE, KV-cache decode, and a memory-safe blockwise
("flash", pure-jnp double-scan) path for long sequences.

Megatron-TP layout (paper §3.1 "Attention blocks"): W_Q/W_O partitioned on the
head dimension over the `model` axis; W_K/W_V replicated whenever
n_kv_heads < TP (every assigned arch) — each rank recomputes the KV heads it
needs, exactly Megatron's GQA behaviour.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.common import ShardCtx, apply_rope, dense_init, rms_norm, softcap

NEG_INF = -2.0e38  # fp32-safe mask value

# §Perf iteration C1 (REFUTED, kept for the record): switching train_4k to
# the blockwise-jnp path cost +18% HBM-proxy traffic vs naive — the fp32
# tile pipeline materializes at fusion boundaries; blockwise only wins when
# tiles stay in VMEM, i.e. via kernels/flash_attention.py on real TPUs.
# Threshold stays 8192: naive ≤8k (where S² scores fit), blockwise above
# (where they cannot). REPRO_FLASH_MIN_SEQ overrides for experiments.
import os as _os

FLASH_SEQ_THRESHOLD = int(_os.environ.get("REPRO_FLASH_MIN_SEQ", "8192"))
FLASH_BLOCK_Q = 512
FLASH_BLOCK_K = 1024


# ---------------------------------------------------------------------------
# params

def attn_init(cfg: ArchConfig, key, dtype, *, cross: bool = False) -> dict:
    d, nh, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, nh * hd), d, dtype),
        "wk": dense_init(ks[1], (d, kvh * hd), d, dtype),
        "wv": dense_init(ks[2], (d, kvh * hd), d, dtype),
        "wo": dense_init(ks[3], (nh * hd, d), nh * hd, dtype),
    }
    if cfg.attn_bias and not cross:
        p["bq"] = jnp.zeros((nh * hd,), dtype)
        p["bk"] = jnp.zeros((kvh * hd,), dtype)
        p["bv"] = jnp.zeros((kvh * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attn_specs(cfg: ArchConfig, tp: str = "model", *, cross: bool = False) -> dict:
    s = {
        "wq": P(None, tp),
        "wk": P(None, None),  # kv_heads < TP: replicated (Megatron GQA)
        "wv": P(None, None),
        "wo": P(tp, None),
    }
    if cfg.attn_bias and not cross:
        s.update(bq=P(tp), bk=P(None), bv=P(None))
    if cfg.qk_norm:
        s.update(q_norm=P(None), k_norm=P(None))
    return s


# ---------------------------------------------------------------------------
# masks

def _mask_bias(kind: str, q_pos, k_pos, window: int, chunk: int):
    """Additive mask bias (..., Sq, Sk) from position vectors."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    if kind == "attn_bidir":
        ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    else:
        ok = k <= q
        if kind == "attn_sw":
            ok &= k > q - window
        elif kind == "attn_chunked":
            ok &= (k // chunk) == (q // chunk)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# cores

def _group(q, kvh):
    """(B,S,nh,hd) -> (B,kvh,qpk,S,hd)."""
    b, s, nh, hd = q.shape
    return q.reshape(b, s, kvh, nh // kvh, hd).transpose(0, 2, 3, 1, 4)


def _attend_naive(q, k, v, bias, cap: Optional[float]):
    """q: (B,kvh,g,Sq,hd); k/v: (B,Sk,kvh,hd); bias: broadcastable (...,Sq,Sk)."""
    hd = q.shape[-1]
    scores = jnp.einsum(
        "bkgqh,bskh->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd ** -0.5)
    scores = softcap(scores, cap) + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bkgqh", probs, v.astype(jnp.float32))
    return out


def _attend_flash(q, k, v, q_pos, k_pos, kind, window, chunk, cap):
    """Blockwise two-level-scan attention; O(block²) live memory.

    q: (B,kvh,g,Sq,hd) fp32-upcast inside; returns (B,kvh,g,Sq,hd) fp32.

    §Perf C1': K/V/Q blocks are fed to scan/map as PRE-SPLIT xs (not
    dynamic_slice'd inside the body) — the transpose of a scan-carried
    dynamic_slice accumulates cotangents through full-size buffer adds every
    step, which cost more HBM traffic than the naive O(S²) path at 4k.
    With xs, scan's native per-slice cotangent stacking applies.
    """
    b, kvh, g, sq, hd = q.shape
    sk = k.shape[1]
    bq, bk = min(FLASH_BLOCK_Q, sq), min(FLASH_BLOCK_K, sk)
    nq, nk = sq // bq, sk // bk
    assert sq % bq == 0 and sk % bk == 0, (sq, sk)
    scale = hd ** -0.5

    # pre-split blocks: k,v (B,Sk,kvh,hd) -> (nk, B, kvh, bk, hd)
    ks = k.reshape(b, nk, bk, kvh, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, bk, kvh, hd).transpose(1, 0, 3, 2, 4)
    kps = k_pos.reshape(nk, bk)
    qs = q.reshape(b, kvh, g, nq, bq, hd).transpose(3, 0, 1, 2, 4, 5) * scale
    qps = q_pos.reshape(nq, bq)

    def q_block(args):
        qi, qp = args  # (B,kvh,g,bq,hd), (bq,)

        def k_step(carry, xs):
            m, l, acc = carry
            kj, vj, kp = xs  # (B,kvh,bk,hd), (B,kvh,bk,hd), (bk,)
            s = jnp.einsum(
                "bkgqh,bksh->bkgqs", qi.astype(jnp.float32), kj.astype(jnp.float32)
            )
            s = softcap(s, cap) if cap is not None else s
            s = s + _mask_bias(kind, qp, kp, window, chunk)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bksh->bkgqh", p, vj.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), (ks, vs, kps))
        return acc / jnp.maximum(l, 1e-38)[..., None]

    # remat each q-block so backward recomputes tiles instead of storing them
    q_block = jax.checkpoint(q_block)
    out = jax.lax.map(q_block, (qs, qps))  # (nq,B,kvh,g,bq,hd)
    return out.transpose(1, 2, 3, 0, 4, 5).reshape(b, kvh, g, sq, hd)


# ---------------------------------------------------------------------------
# public apply

def attn_apply(
    cfg: ArchConfig,
    p: dict,
    x,
    *,
    kind: str,
    ctx: ShardCtx,
    positions=None,          # (S,) absolute positions of x tokens
    kv_x=None,               # cross-attention source (B,T,d); None = self
    cache: Optional[dict] = None,   # {'k','v'} (B,maxT,kvh,hd) + write pos
    cache_pos=None,          # scalar int32: write/valid position for decode
    use_rope: Optional[bool] = None,
    cross_cache: Optional[dict] = None,  # {'ek','ev'} (B,Tenc,kvh,hd): banked
                                         # encoder K/V (fill at prefill when
                                         # kv_x is given, read at decode)
):
    """Returns (out, new_cache)."""
    d, nh, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, s, _ = x.shape
    use_rope = cfg.use_rope if use_rope is None else use_rope
    cross = kv_x is not None or cross_cache is not None
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)

    q = jnp.einsum("bsd,df->bsf", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(b, s, nh, hd)

    if cross and kv_x is None:
        # decode against the banked encoder K/V: computed (and qk-normed)
        # once at prefill — no per-token encoder pass, no re-norm of k
        k, v = cross_cache["ek"], cross_cache["ev"]
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    else:
        src = kv_x if cross else x
        k = jnp.einsum("bsd,df->bsf", src, p["wk"])
        v = jnp.einsum("bsd,df->bsf", src, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = k.reshape(b, src.shape[1], kvh, hd)
        v = v.reshape(b, src.shape[1], kvh, hd)

        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        if use_rope and not cross:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    q = ctx.heads(q)

    new_cache = None
    if cache is not None and not cross:
        # decode/prefill against a persistent cache.
        # §Perf B1: dynamic_update_slice on a sequence-SHARDED cache triggers
        # XLA's "involuntary full rematerialization" (whole cache gathered +
        # rescattered per step); a scatter with explicit indices partitions
        # shard-locally. REPRO_BASELINE_CACHE=1 restores the DUS path.
        # §Perf B2: attn_sw/attn_chunked caches are ring buffers of
        # window/chunk length (init_kv_cache) — slot i holds absolute
        # position last-((last-i) mod w); unwritten slots mask out as p<0.
        w = cache["k"].shape[1]
        ring = kind in ("attn_sw", "attn_chunked") and not _os.environ.get(
            "REPRO_BASELINE_RINGCACHE"
        )
        last = cache_pos + s - 1

        kc = k.astype(cache["k"].dtype)
        vc = v.astype(cache["v"].dtype)
        if s > w:  # prefill longer than the ring: only the tail survives
            kc, vc = kc[:, -w:], vc[:, -w:]
            write_pos, n_write = cache_pos + s - w, w
        else:
            write_pos, n_write = cache_pos, s
        if _os.environ.get("REPRO_BASELINE_CACHE") or (s > 1 and not ring):
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kc, write_pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vc, write_pos, axis=1)
        else:
            idx = write_pos + jnp.arange(n_write, dtype=jnp.int32)
            if ring:
                idx = idx % w
            ck = cache["k"].at[:, idx].set(kc)
            cv = cache["v"].at[:, idx].set(vc)
        new_cache = {"k": ck, "v": cv}

        if s > 1:
            # prefill: attend the fresh full-sequence K/V (the ring stores
            # only the tail; early queries still need their full window)
            k_posm = positions
        else:
            k, v = ck, cv
            slot = jnp.arange(w, dtype=jnp.int32)
            if ring:
                k_pos = last - ((last - slot) % w)
                k_posm = jnp.where(k_pos >= 0, k_pos, jnp.iinfo(jnp.int32).max)
            else:
                k_posm = jnp.where(slot <= last, slot, jnp.iinfo(jnp.int32).max)
    else:
        k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        k_posm = k_pos
        if cross and cross_cache is not None:
            if kv_x is not None:
                # prefill: bank the encoder K/V for cache-driven decode
                new_cache = {"ek": k.astype(cross_cache["ek"].dtype),
                             "ev": v.astype(cross_cache["ev"].dtype)}
            else:
                new_cache = cross_cache

    qg = _group(q, kvh)  # (B,kvh,g,S,hd)

    if cross:
        bias = jnp.zeros((1, 1, 1, s, k.shape[1]), jnp.float32)
        out = _attend_naive(qg, k, v, bias, cfg.attn_softcap)
    elif s > FLASH_SEQ_THRESHOLD:
        # long PREFILL: blockwise. (Decode s==1 stays naive: a 1×T score row
        # is tiny even at T=512k, and XLA turns the softmax reductions over a
        # seq-sharded cache into the psum-LSE combine == flash-decode.)
        out = _attend_flash(
            qg, k, v, positions, k_posm, kind, cfg.window, cfg.chunk_size,
            cfg.attn_softcap,
        )
    else:
        bias = _mask_bias(kind, positions, k_posm, cfg.window, cfg.chunk_size)
        out = _attend_naive(qg, k, v, bias[None, None, None], cfg.attn_softcap)

    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, nh * hd).astype(x.dtype)
    y = jnp.einsum("bsf,fd->bsd", out, p["wo"])
    return ctx.batch(y), new_cache


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype,
                  kind: str = "attn") -> dict:
    """§Perf iteration B2 (beyond-paper): sliding-window / chunked-local
    layers only ever attend the trailing window/chunk, so their cache is a
    RING BUFFER of that length (serving-standard, à la Mistral) — for gemma2
    long_500k this cuts 21 of 42 layers' per-token KV reads 128×.
    REPRO_BASELINE_RINGCACHE=1 restores full-length caches."""
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    length = max_len
    if not _os.environ.get("REPRO_BASELINE_RINGCACHE"):
        if kind == "attn_sw":
            length = min(max_len, cfg.window)
        elif kind == "attn_chunked":
            length = min(max_len, cfg.chunk_size)
    return {
        "k": jnp.zeros((batch, length, kvh, hd), dtype),
        "v": jnp.zeros((batch, length, kvh, hd), dtype),
    }


def init_cross_kv_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    """Encoder K/V bank for enc-dec decode (whisper-style serving): filled
    ONCE at prefill from the encoder output, read by every cross-attention
    decode step — the decoder never re-runs the encoder per token. Fixed
    ``enc_seq`` length (no ring: cross attention is bidirectional over the
    whole encoded input)."""
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    t = cfg.encoder.enc_seq
    return {"ek": jnp.zeros((batch, t, kvh, hd), dtype),
            "ev": jnp.zeros((batch, t, kvh, hd), dtype)}
