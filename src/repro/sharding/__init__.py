from repro.sharding.specs import (  # noqa: F401
    param_shardings,
    sanitize_spec,
    zero1_spec,
)
