"""Parameter / optimizer-state sharding utilities.

TP placement comes from each module's ``*_specs`` (Megatron layout, paper
§3.1). This module turns those PartitionSpecs into NamedShardings for a
concrete mesh, and adds ZeRO-1-style optimizer-state sharding over the DP
axes (the paper's workloads assume Megatron's distributed optimizer; without
it the 480B arch could not fit either).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import sanitize_spec


def _is_spec(x) -> bool:
    return isinstance(x, P)


def param_shardings(mesh: Mesh, spec_tree, shape_tree):
    """PartitionSpec pytree + abstract shapes -> NamedSharding pytree
    (dropping any axis that does not divide the dim)."""
    ms = dict(mesh.shape)

    def one(spec, shaped):
        return NamedSharding(mesh, sanitize_spec(ms, shaped.shape, spec))

    return jax.tree.map(one, spec_tree, shape_tree, is_leaf=_is_spec)


def zero1_spec(mesh: Mesh, spec: P, shape: Tuple[int, ...], dp_axes=("data",)) -> P:
    """Extend a param spec with DP sharding on the largest eligible dim —
    ZeRO-1: optimizer moments are additionally partitioned across the
    data-parallel axis, cutting their footprint |dp|-fold."""
    ms = dict(mesh.shape)
    dp = tuple(a for a in dp_axes if a in ms)
    dp_size = 1
    for a in dp:
        dp_size *= ms[a]
    if dp_size == 1 or not shape:
        return spec
    entries = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    # candidate dims: unsharded, divisible by dp_size; pick the largest
    cands = [
        (shape[d], d)
        for d, e in enumerate(entries)
        if e is None and shape[d] % dp_size == 0
    ]
    if not cands:
        return spec
    _, d = max(cands)
    entries[d] = dp if len(dp) > 1 else dp[0]
    return P(*entries)


def zero1_shardings(mesh: Mesh, spec_tree, shape_tree, dp_axes=("data",)):
    ms = dict(mesh.shape)

    def one(spec, shaped):
        s = sanitize_spec(ms, shaped.shape, spec)
        s = zero1_spec(mesh, s, shaped.shape, dp_axes)
        return NamedSharding(mesh, s)

    return jax.tree.map(one, spec_tree, shape_tree, is_leaf=_is_spec)
