"""Fig. 3: fleet availability vs failed fraction for TP/domain 8..64."""
import numpy as np

from repro.core.availability import ClusterSpec, availability_analytic, availability_full_tp

FRACTIONS = [2.5e-4, 5e-4, 1e-3, 2e-3, 4e-3]
DOMAINS = [8, 16, 32, 64]


def run():
    rows = []
    for tp in DOMAINS:
        spec = ClusterSpec(n_gpus=32_768, domain_size=tp)
        for f in FRACTIONS:
            med, worst = availability_full_tp(spec, f, samples=30)
            rows.append({
                "name": f"fig3/TP{tp}/f={f:g}",
                "value": round(med, 4),
                "derived": f"analytic={availability_analytic(tp, f):.4f} worst={worst:.4f}",
            })
    # headline claim
    rows.append({
        "name": "fig3/claim/TP64@0.1%",
        "value": round(availability_analytic(64, 1e-3), 4),
        "derived": "paper: ~0.94",
    })
    return rows
