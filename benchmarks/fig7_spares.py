"""Fig. 7: spare scale-up domains needed to hold the minibatch fixed."""
import numpy as np

from repro.core.availability import ClusterSpec, sample_failed_domains
from repro.core.failure_model import FailureTraceConfig, simulate_trace
from repro.core.policies import spares_analysis


def run():
    spec = ClusterSpec(n_gpus=32_768, domain_size=32, domains_per_replica=8)
    # failure trace -> per-time failed-domain count samples
    cfg = FailureTraceConfig(n_gpus=spec.n_gpus, days=15, seed=5,
                             hw_recovery_days=(5.0, 5.0))
    _, failed = simulate_trace(cfg)
    rng = np.random.default_rng(0)
    trace = [
        sample_failed_domains(spec.n_gpus, spec.domain_size, int(n), rng)
        for n in failed[::12]
    ]
    rows = []
    for method, spares in (
        ("dpdrop", (0, 32, 64, 90, 128)),
        ("ntp", (0, 8, 16, 24)),
        ("ntp_pw", (0, 8)),
    ):
        res = spares_analysis(spec, trace, spares, method)
        for r in res:
            rows.append({
                "name": f"fig7/{method}/spares={r['spares']}",
                "value": round(r["throughput_per_gpu"], 4),
                "derived": f"uptime={r['uptime']:.3f} "
                           f"(paper: dpdrop needs ~90, ntp 16, ntp_pw 0)",
            })
    return rows
