"""Fig. 9 analogue: NTP end-to-end overhead breakdown, derived structurally
from the compiled NTP train step at the production mesh
(results/ntp_dryrun.json — `python -m repro.launch.dryrun_ntp`).

The dryrun builds its step through the runtime API (`plan_from_health` +
`Mode` + the pluggable-optimizer step builder), so the collectives accounted
here are exactly the ones an `NTPSession` executes after a failure event.
"""
import json
import os

PATH = os.environ.get("REPRO_NTP_DRYRUN", "results/ntp_dryrun_6144_big.json")
if not os.path.exists(PATH):
    PATH = "results/ntp_dryrun.json"


def run():
    if not os.path.exists(PATH):
        return [{
            "name": "fig9/missing",
            "value": 0,
            "derived": f"run `python -m repro.launch.dryrun_ntp` to produce {PATH}",
        }]
    with open(PATH) as f:
        rep = json.load(f)
    h, d, ov = rep["healthy"], rep["degraded"], rep["overhead"]
    plans = f"plans {h.get('replica_tp')} -> {d.get('replica_tp')}"
    rows = [
        {"name": "fig9/modes", "value": 1,
         "derived": f"{h.get('mode')} -> {d.get('mode')} ({plans})"},
        {"name": "fig9/healthy/allreduce_s", "value": round(h["allreduce_s"], 4),
         "derived": f"a2a={h['reshard_s']:.4f}s compute={h['compute_s']:.4f}s"},
        {"name": "fig9/degraded/allreduce_s", "value": round(d["allreduce_s"], 4),
         "derived": f"a2a={d['reshard_s']:.4f}s compute={d['compute_s']:.4f}s"},
        {"name": "fig9/reshard_vs_compute", "value": round(ov["reshard_vs_compute"], 4),
         "derived": "paper: reshard fully overlapped with backward (<1% e2e)"},
        {"name": "fig9/allreduce_increase_s", "value": round(ov["allreduce_increase_s"], 5),
         "derived": "paper: all-reduce volume grows ∝ TP reduction"},
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
