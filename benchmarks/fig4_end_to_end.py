"""Fig. 4 end-to-end: one Llama3-calibrated failure/recovery trace (per-event
(domain, gpu) placement from `simulate_events`) replayed through the
resource-manager packing, reporting trace-mean goodput per fault-tolerance
policy — the availability argument of §2.3/§6.1 as a single number per
policy instead of Fig. 6's per-failed-fraction cross sections."""
import numpy as np

from repro.core.availability import ClusterSpec
from repro.core.failure_model import FailureTraceConfig, simulate_events
from repro.core.policies import cluster_throughput

SAMPLE_EVERY_H = 6.0


def run():
    spec = ClusterSpec(n_gpus=32_768, domain_size=32, domains_per_replica=8)
    rows = []
    for mult in (1.0, 3.0):
        cfg = FailureTraceConfig(
            n_gpus=spec.n_gpus, domain_size=spec.domain_size,
            days=15.0, rate_multiplier=mult, seed=3,
        )
        ev = simulate_events(cfg)
        times = np.arange(0.0, cfg.days * 24.0, SAMPLE_EVERY_H)
        counts_t = [
            ev.failed_counts_at(t, cfg.n_domains, spec.domain_size)
            for t in times
        ]
        goodputs = {}
        for method in ("dpdrop", "ntp", "ntp_pw"):
            thr = [
                cluster_throughput(spec, counts, method)["throughput"]
                for counts in counts_t
            ]
            goodputs[method] = float(np.mean(thr))
            rows.append({
                "name": f"fig4e2e/rate{mult:g}x/{method}/goodput",
                "value": round(goodputs[method], 5),
                "derived": f"trace-mean lost={1 - goodputs[method]:.4f} "
                           f"({len(times)} samples)",
            })
        rows.append({
            "name": f"fig4e2e/rate{mult:g}x/ntp_pw_vs_dpdrop/recovered",
            "value": round(goodputs["ntp_pw"] - goodputs["dpdrop"], 5),
            "derived": "goodput NTP-PW recovers over DP-DROP on the same trace",
        })
    return rows
