"""Fig. 4 end-to-end: one Llama3-calibrated failure/recovery trace (per-event
(domain, gpu) placement from `simulate_events`) replayed through the
resource-manager packing, reporting trace-mean goodput per fault-tolerance
policy — the availability argument of §2.3/§6.1 as a single number per
policy instead of Fig. 6's per-failed-fraction cross sections."""
import numpy as np

from repro.core.availability import ClusterSpec
from repro.core.failure_model import FailureTraceConfig, simulate_events
from repro.core.perf_model import (
    Hardware, Parallel, Workload, iteration_time, staged_iteration_time,
)
from repro.core.policies import (
    WorkloadGeometry, cluster_throughput, staged_rel_iter_times,
)
from repro.core.resource_manager import pack_replicas

SAMPLE_EVERY_H = 6.0

# global-vs-stage-local packing replay (ISSUE 6): a 16-replica × 8-stage
# sub-cluster of the trace (128 active domains + 2 held-out spare domains)
# — small enough that the greedy allocator replays every sample in seconds
PACK_REPLICAS = 16
PACK_SPARES = 2


def _pack_rows(mult, counts_t, spec, hw, wl):
    """Replay the trace through stage-local packing vs the global allocator
    (`repro.cluster.GreedyAllocator`) at identical hardware: both layouts
    get PACK_REPLICAS replicas; the spare domains idle under stage-local
    packing (it cannot address them) and join the allocator's pool."""
    from repro.cluster import GoodputModel, GreedyAllocator, TransitionCostModel
    from repro.runtime.events import (
        ClusterHealth, DeadReplicaError, StagedHealth,
    )

    n1, pp, n_rep = spec.domain_size, spec.domains_per_replica, PACK_REPLICAS
    par = Parallel(tp=n1, pp=pp, dp=n_rep)
    gm = GoodputModel.for_perf(hw, wl, par)
    cost = TransitionCostModel.analytic(wl, par)
    horizon = max(1, int(SAMPLE_EVERY_H * 3600.0 / gm.step_time_s))
    from repro.cluster import AllocatorConfig

    alloc = GreedyAllocator(
        AllocatorConfig(horizon_steps=horizon), goodput=gm, cost=cost)

    active = n_rep * pp
    local_gp, global_gp, moved_bytes = [], [], 0
    cur, skipped = None, 0
    for counts in counts_t:
        stage_counts = [
            np.asarray([counts[r * pp + s] for r in range(n_rep)], dtype=int)
            for s in range(pp)
        ]
        pool = int(sum(counts[active + i] == 0 for i in range(PACK_SPARES)))
        health = StagedHealth(tuple(
            ClusterHealth(n1, tuple(int(x) for x in c))
            for c in stage_counts
        ))
        try:
            gp = alloc.plan(health, spares=pool, current=cur)
        except DeadReplicaError:
            skipped += 1
            cur = None    # layout lost: next sample repacks from scratch
            continue
        local_gp.append(gm.goodput(stage_counts))
        global_gp.append(gp.goodput)
        moved_bytes += gp.predicted_bytes
        cur = gp.staged_plan
    g_local, g_global = float(np.mean(local_gp)), float(np.mean(global_gp))
    # amortized transfer debit: the cost model's predicted wall-seconds of
    # state movement across the whole replay, as a fraction of trace time
    transfer_s = cost.seconds(moved_bytes)
    debit = transfer_s / (len(counts_t) * SAMPLE_EVERY_H * 3600.0)
    tag = f"fig4e2e/rate{mult:g}x/pack"
    return [
        {"name": f"{tag}/stage_local/goodput", "value": round(g_local, 5),
         "derived": f"trace-mean, {n_rep}x{pp} stages, spares idle "
                    f"({len(local_gp)} samples, {skipped} dead-skipped)"},
        {"name": f"{tag}/global/goodput", "value": round(g_global, 5),
         "derived": f"GreedyAllocator, {PACK_SPARES} spare domains, "
                    f"horizon={horizon} steps"},
        {"name": f"{tag}/global_net/goodput",
         "value": round(g_global - debit, 5),
         "derived": f"net of {transfer_s:.2f}s predicted transfer "
                    f"({moved_bytes / 1e9:.2f} GB over the replay)"},
        {"name": f"{tag}/global_vs_stage_local/recovered",
         "value": round(g_global - debit - g_local, 5),
         "derived": "allocator >= stage-local by construction; margin net "
                    "of the TransferStats-calibrated movement cost"},
    ]


def run():
    spec = ClusterSpec(n_gpus=32_768, domain_size=32, domains_per_replica=8)
    rows = []
    for mult in (1.0, 3.0):
        cfg = FailureTraceConfig(
            n_gpus=spec.n_gpus, domain_size=spec.domain_size,
            days=15.0, rate_multiplier=mult, seed=3,
        )
        ev = simulate_events(cfg)
        times = np.arange(0.0, cfg.days * 24.0, SAMPLE_EVERY_H)
        counts_t = [
            ev.failed_counts_at(t, cfg.n_domains, spec.domain_size)
            for t in times
        ]
        goodputs = {}
        for method in ("dpdrop", "ntp", "ntp_pw"):
            thr = [
                cluster_throughput(spec, counts, method)["throughput"]
                for counts in counts_t
            ]
            goodputs[method] = float(np.mean(thr))
            rows.append({
                "name": f"fig4e2e/rate{mult:g}x/{method}/goodput",
                "value": round(goodputs[method], 5),
                "derived": f"trace-mean lost={1 - goodputs[method]:.4f} "
                           f"({len(times)} samples)",
            })
        rows.append({
            "name": f"fig4e2e/rate{mult:g}x/ntp_pw_vs_dpdrop/recovered",
            "value": round(goodputs["ntp_pw"] - goodputs["dpdrop"], 5),
            "derived": "goodput NTP-PW recovers over DP-DROP on the same trace",
        })

        # ---- measured-vs-analytic cross-check (DESIGN.md §2.6): treat each
        # replica's 8 domains as its 8 PP stages (exactly the live runtime's
        # per-(replica, stage) health). At every sample, take the WORST
        # replica's stage TPs and predict its relative iteration time at
        # FULL batch two ways: the runtime's slowest-stage slowdown rule
        # (staged_rel_iter_times — the step-metrics number) and the perf
        # model's staged_iteration_time breakdown. Trace-means must track.
        hw = Hardware(domain_size=spec.domain_size)
        wl = Workload()
        dpr = spec.domains_per_replica
        n_rep = cfg.n_domains // dpr
        par = Parallel(tp=spec.domain_size, pp=dpr, dp=n_rep)
        geom = WorkloadGeometry(n_heads=128, local_batch=8)
        healthy = iteration_time(hw, wl, par)["total"]
        runtime_rels, analytic_rels = [], []
        for counts in counts_t:
            asg = pack_replicas(counts, spec.domain_size, dpr)
            worst = min(asg, key=lambda a: a.tp)
            stage_tps = tuple(
                int(spec.domain_size - f) for f in worst.failed
            )
            if min(stage_tps) == 0:
                continue  # dead replica: outside the NTP regime
            rels = staged_rel_iter_times(
                [stage_tps], spec.domain_size, geom,
                local_batches=[geom.local_batch],
                local_batch=geom.local_batch,
            )
            runtime_rels.append(max(rels))
            analytic_rels.append(
                staged_iteration_time(hw, wl, par, stage_tps)["total"] / healthy
            )
        for name, vals in (("runtime_rel", runtime_rels),
                           ("analytic_rel", analytic_rels)):
            rows.append({
                "name": f"fig4e2e/rate{mult:g}x/xcheck/{name}",
                "value": round(float(np.mean(vals)), 5),
                "derived": "trace-mean worst-replica rel iter time at full "
                           f"batch ({len(vals)} samples; slowest of "
                           f"{dpr} stages gates)",
            })
        rows.extend(_pack_rows(mult, counts_t, spec, hw, wl))
    return rows
