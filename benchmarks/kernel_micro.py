"""Kernel microbenchmarks: wall time of the interpret-mode kernels is NOT
TPU-meaningful — we report the oracle-vs-kernel agreement and the kernels'
arithmetic intensity (useful for the roofline discussion) instead, plus
CPU us/call for the jnp reference paths as a regression canary."""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(f, *a, n=5):
    f(*a)[0].block_until_ready() if isinstance(f(*a), tuple) else jax.block_until_ready(f(*a))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*a))
    return (time.perf_counter() - t0) / n * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    b, h, kvh, s, d = 1, 8, 2, 1024, 128
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, kvh, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, kvh, s, d)), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _time(fa, q, k, v)
    flops = 4 * b * h * s * s * d
    ai = flops / (2 * (q.size + 2 * k.size) + 2 * q.size)
    rows.append({
        "name": "kernel/flash_attn_ref_cpu",
        "value": round(us, 1),
        "derived": f"us/call; arithmetic_intensity={ai:.0f} flop/B (MXU-bound on TPU)",
    })

    x = jnp.asarray(rng.normal(size=(4096, 4096)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(4096,)), jnp.bfloat16)
    rn = jax.jit(lambda x, w: ref.rmsnorm_ref(x, w))
    rows.append({
        "name": "kernel/rmsnorm_ref_cpu",
        "value": round(_time(rn, x, w), 1),
        "derived": f"us/call; AI≈0.75 flop/B (HBM-bound ⇒ fusion win)",
    })
    return rows
