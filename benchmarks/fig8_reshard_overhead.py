"""Fig. 8: backward-pass slowdown vs reshard comm:comp ratio.

The paper's prototype (2×DGX-A100, hidden 6144/12288, seq 4–16K, TP8 →
reduced TP): slowdown is linear in (max reshard bytes per GPU) /
(backward compute time), ≤4% for all settings, <1% for the large-scale
workload. We reproduce the x-axis exactly from Algorithm-1 tables
(shard_mapping.reshard_bytes_per_rank) and apply the linear overlap model;
we also run the reshard collective for real on 8 fake CPU devices elsewhere
(tests/dist) — wall-clock on CPU is not meaningful, volumes are.
"""
import numpy as np

from repro.core import shard_mapping as sm
from repro.runtime import ClusterHealth, plan_from_health

A100_FLOPS = 312e12 * 0.5      # bf16 peak × achievable
NVLINK_BW = 600e9 / 2          # per-direction
SLOPE = 0.55                   # fitted: fraction of reshard time exposed


def workload_points():
    pts = []
    for hidden in (6144, 12288):
        for seq in (4096, 8192, 16384):
            for tp_red in (7, 6, 4):
                pts.append((hidden, seq, tp_red))
    return pts


def comm_comp_ratio(hidden, seq, tp_red, tp=8, local_batch=1, unit=128):
    d_ff = 4 * hidden
    n_params_layer = 4 * hidden * hidden + 3 * hidden * d_ff
    # one domain loses (tp - tp_red) GPUs; the packed plan's sync degree is
    # the Algorithm-1 n2 (runtime event/health bridge, DESIGN.md §2.1)
    fplan = plan_from_health(ClusterHealth(
        domain_size=tp, failed=(tp - tp_red, 0),
    ))
    n2 = fplan.n_sync
    # reshard bytes: per-rank max over the layer's two sharded weights
    k_ff = d_ff // unit
    c = sm.comp_layout(k_ff, tp, n2)
    s = sm.sync_layout(k_ff, tp, n2)
    unit_bytes = unit * hidden * 2 * 3       # gate+up+down rows, bf16
    reshard = sm.reshard_bytes_per_rank(c, s, unit_bytes).max()
    # attention units = kv groups ~ heads/…: fold in as params share
    reshard *= n_params_layer / (3 * hidden * d_ff)
    t_reshard = reshard / NVLINK_BW
    bwd_flops = 4 * n_params_layer * seq * local_batch / tp
    t_bwd = bwd_flops / A100_FLOPS
    return t_reshard / t_bwd, t_reshard, t_bwd


def transition_cost(hidden, tp_red, tp=8, unit=128):
    """Fail→repair TRANSITION cost per layer: the retired dense host
    round-trip (pack∘unpack touches the whole model) vs the unified
    engine's direct packed→packed move (only units whose rank changes
    travel — repro.reshard.transition). SPARe's point: at scale the
    transition, not the steady state, pins fault-tolerant goodput."""
    from repro.reshard import planner

    d_ff = 4 * hidden
    k_ff = d_ff // unit
    unit_bytes = unit * hidden * 2 * 3           # gate+up+down rows, bf16
    fplan = plan_from_health(ClusterHealth(
        domain_size=tp, failed=(tp - tp_red, 0),
    ))
    n2 = fplan.n_sync
    dense = 2 * k_ff * unit_bytes                # both replicas, full weight
    direct = 0
    for nr in fplan.replica_tp:                  # pristine -> degraded plan
        plan = planner.transition_plan(
            planner.comp_key(k_ff, tp, tp, tp),  # healthy comp == sync@tp
            planner.comp_key(k_ff, tp, nr, n2),
        )
        direct += plan.n_moved * unit_bytes
    return direct, dense


def run():
    rows = []
    xs, ys = [], []
    for hidden, seq, tp_red in workload_points():
        ratio, _, _ = comm_comp_ratio(hidden, seq, tp_red)
        slowdown = SLOPE * ratio
        xs.append(ratio)
        ys.append(slowdown)
        rows.append({
            "name": f"fig8/h{hidden}_s{seq}_tp{tp_red}",
            "value": round(ratio, 4),
            "derived": f"bwd_slowdown={slowdown:.4f} (paper: ≤0.04)",
        })
    # ISSUE 4: dense-roundtrip vs direct-transition cost of the fail/repair
    # repack itself (per MLP layer, both replicas)
    for hidden in (6144, 12288):
        for tp_red in (7, 6, 4):
            direct, dense = transition_cost(hidden, tp_red)
            rows.append({
                "name": f"fig8/transition_h{hidden}_tp{tp_red}",
                "value": round(direct / dense, 4),
                "derived": (f"direct={direct/1e6:.1f}MB vs "
                            f"dense_roundtrip={dense/1e6:.1f}MB per layer"),
            })
    # the 480B simulation domain: even a single-GPU failure on a 32-wide
    # domain re-balances most unit boundaries (n2 changes), yet the direct
    # route still undercuts the host round-trip — and moves over ICI, not
    # through host memory
    direct, dense = transition_cost(20480, 31, tp=32)
    rows.append({
        "name": "fig8/transition_480b_tp31",
        "value": round(direct / dense, 4),
        "derived": (f"direct={direct/1e6:.1f}MB vs "
                    f"dense_roundtrip={dense/1e6:.1f}MB per layer"),
    })
    # the 480B simulation workload's ratio (paper: comfortably <1%)
    ratio, _, _ = comm_comp_ratio(20480, 16384, 30, tp=32, local_batch=8)
    rows.append({
        "name": "fig8/simulated_480b_tp30",
        "value": round(ratio, 5),
        "derived": f"bwd_slowdown={SLOPE*ratio:.5f} (paper: <0.01)",
    })
    rows.append({
        "name": "fig8/linearity_r2",
        "value": 1.0,  # by construction of the linear model
        "derived": f"max_modeled_slowdown={max(ys):.4f}",
    })
    return rows
