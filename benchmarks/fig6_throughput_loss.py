"""Fig. 6: lost throughput vs failed fraction — DP-DROP / NTP / NTP-PW."""
from repro.core.availability import ClusterSpec
from repro.core.policies import throughput_loss_curve

FRACTIONS = [5e-4, 1e-3, 2e-3, 4e-3]


def run():
    spec = ClusterSpec(n_gpus=32_768, domain_size=32)
    curve = throughput_loss_curve(spec, FRACTIONS, samples=12, seed=0)
    rows = []
    paper = {"dpdrop": "≤0.12", "ntp": "≤0.03", "ntp_pw": "<0.01"}
    for m, vals in curve.items():
        for f, v in zip(FRACTIONS, vals):
            rows.append({
                "name": f"fig6/{m}/f={f:g}",
                "value": round(v, 4),
                "derived": f"paper@max: {paper[m]}",
            })
    return rows
