"""Roofline table (brief deliverable g): read results/dryrun/*.json and
emit the per-(arch × shape × mesh) three-term roofline with the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and per-device memory."""
import glob
import json
import os

RESULTS = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_records(mesh="pod16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs):
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful(6ND/HLO) | temp GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |"
            )
            continue
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | ERROR | | | | | |"
            )
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"{rl['dominant']} | "
            f"{(r.get('useful_flops_ratio') or 0):.3f} | "
            f"{r['memory']['temp_bytes']/1e9:.1f} |"
        )
    return "\n".join(lines)


def _load_dir(d, mesh="pod16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def comparison_table(base, opt):
    """Baseline (paper-faithful) vs optimized (§Perf) side by side."""
    bi = {(r["arch"], r["shape"]): r for r in base}
    lines = [
        "| arch | shape | dom(base) | base_s | dom(opt) | opt_s | speedup |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in opt:
        key = (r["arch"], r["shape"])
        b = bi.get(key)
        if not (r.get("ok") and b and b.get("ok")):
            continue
        rb, ro = b["roofline"], r["roofline"]
        tb = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
        to = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rb['dominant']} | {tb:.3f} | "
            f"{ro['dominant']} | {to:.3f} | {tb/max(to,1e-12):.2f}× |"
        )
    return "\n".join(lines)


def run():
    recs = load_records()
    rows = []
    for r in recs:
        if r.get("skipped") or not r.get("ok"):
            continue
        rl = r["roofline"]
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "value": round(max(rl["compute_s"], rl["memory_s"],
                               rl["collective_s"]), 4),
            "derived": f"dominant={rl['dominant']} "
                       f"useful={(r.get('useful_flops_ratio') or 0):.3f}",
        })
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write("## Baseline (paper-faithful)\n\n")
        f.write(markdown_table(recs) + "\n")
        opt = _load_dir("results/dryrun_opt")
        if opt:
            f.write("\n## Optimized (§Perf, beyond-paper)\n\n")
            f.write(markdown_table(opt) + "\n")
            f.write("\n## Baseline vs optimized (dominant-term)\n\n")
            f.write(comparison_table(recs, opt) + "\n")
            for r in opt:
                if r.get("ok"):
                    rl = r["roofline"]
                    rows.append({
                        "name": f"roofline_opt/{r['arch']}/{r['shape']}",
                        "value": round(max(rl["compute_s"], rl["memory_s"],
                                           rl["collective_s"]), 4),
                        "derived": f"dominant={rl['dominant']} "
                                   f"useful={(r.get('useful_flops_ratio') or 0):.3f}",
                    })
    rows.append({
        "name": "roofline/table",
        "value": len(recs),
        "derived": "written to results/roofline.md",
    })
    return rows
